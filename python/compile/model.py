"""Layer-2 JAX models: the compute graphs that get AOT-lowered to HLO.

Three families of jittable functions, all calling the L1 Pallas kernels:

* ``subdomain_block`` — the workhorse of the L3 coordinator.  A worker owns
  a slab of the global domain plus a ghost ring of width ``radius * Tb``;
  one call advances the slab Tb steps (valid mode).  The rust scheduler
  chains these calls with halo exchanges in between (paper §5).
* ``mxu_subdomain_block`` — same contract, trapezoid-folding MXU kernel.
* ``thermal_step_block`` — shape-preserving periodic evolution used by the
  thermal-diffusion case study (§6.5) and the FP32-vs-FP64 accuracy study
  (Table 4).

Everything here is traced exactly once by ``aot.py``; no Python survives
to the request path.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import mxu_fold, ref, stencil_step, temporal_block
from .kernels.spec import StencilSpec

jax.config.update("jax_enable_x64", True)


def subdomain_block(
    spec: StencilSpec,
    steps: int,
    tiles: Optional[Sequence[int]] = None,
):
    """Build fn: (core + 2*r*steps, ..) -> (core, ..), Tb fused steps.

    With ``steps == 1`` this is the plain tiled step kernel (the "GPU
    naive" rung of the Fig-12 breakdown); with ``steps > 1`` it is the
    temporal-block kernel (checkerboard/locality-enhancer rung).
    """

    def fn(u: jnp.ndarray) -> Tuple[jnp.ndarray]:
        if steps == 1:
            return (stencil_step.stencil_step(u, spec, tiles),)
        return (temporal_block.temporal_block(u, spec, steps, tiles),)

    fn.__name__ = f"{spec.name}_block{steps}"
    return fn


def mxu_subdomain_block(spec: StencilSpec, steps: int, tile_m: Optional[int] = None):
    """Build the trapezoid-folding variant (2D specs only)."""

    def fn(u: jnp.ndarray) -> Tuple[jnp.ndarray]:
        return (mxu_fold.mxu_fold_block(u, spec, steps, tile_m),)

    fn.__name__ = f"{spec.name}_mxu{steps}"
    return fn


def mxu_step_with_bands(spec: StencilSpec, tile_m: Optional[int] = None):
    """AOT variant of the trapezoid-folding step: takes (u, bands).

    The band stack must be a runtime parameter — as a traced constant the
    HLO *text* printer elides it ("constant({...})") and the rust loader
    would reconstruct zeros.  The rust runtime regenerates the bands from
    the manifest spec (`runtime/client.rs::band_matrices`).
    """

    def fn(u: jnp.ndarray, bands: jnp.ndarray) -> Tuple[jnp.ndarray]:
        return (mxu_fold.mxu_fold(u, spec, tile_m, bands),)

    fn.__name__ = f"{spec.name}_mxu_b"
    return fn


def reference_block(spec: StencilSpec, steps: int):
    """Pure-jnp oracle with the same contract — lowered too, so the rust
    integration tests can diff kernel-vs-oracle entirely inside PJRT."""

    def fn(u: jnp.ndarray) -> Tuple[jnp.ndarray]:
        return (ref.block(u, spec, steps),)

    fn.__name__ = f"{spec.name}_ref{steps}"
    return fn


def thermal_step_block(spec: StencilSpec, steps: int, dtype=jnp.float64):
    """Shape-preserving periodic Tb-block for the case study.

    Uses jnp.roll (exact periodic boundary); jitted into a single fused
    loop by XLA via lax.scan so one PJRT call advances Tb steps.
    """

    def one(u, _):
        out = jnp.zeros_like(u)
        for off, c in sorted(spec.coeffs.items()):
            shifted = u
            for axis, o in enumerate(off):
                if o != 0:
                    shifted = jnp.roll(shifted, -o, axis=axis)
            out = out + u.dtype.type(c) * shifted
        return out, None

    def fn(u: jnp.ndarray) -> Tuple[jnp.ndarray]:
        u = u.astype(dtype)
        out, _ = jax.lax.scan(one, u, None, length=steps)
        return (out,)

    fn.__name__ = f"{spec.name}_thermal{steps}_{jnp.dtype(dtype).name}"
    return fn


def energy_stats(dtype=jnp.float64):
    """Tiny reduction graph: (mean, min, max) of a field — used by the L3
    metrics path so the leader never scans arrays host-side."""

    def fn(u: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        u = u.astype(dtype)
        return (jnp.mean(u), jnp.min(u), jnp.max(u))

    fn.__name__ = f"energy_stats_{jnp.dtype(dtype).name}"
    return fn
