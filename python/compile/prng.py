"""SplitMix64 PRNG — bit-identical twin of ``rust/src/util/prng.rs``.

Golden test vectors in the AOT manifest are generated from this stream so
the rust integration tests can regenerate the exact same inputs without
any Python at runtime.  Keep in lockstep with the rust implementation
(checked by tests on both sides against the shared vectors below).
"""

from __future__ import annotations

import numpy as np

MASK = (1 << 64) - 1


class SplitMix64:
    """Sebastiano Vigna's splitmix64; state advances by the golden gamma."""

    def __init__(self, seed: int):
        self.state = seed & MASK

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return z ^ (z >> 31)

    def next_f64(self) -> float:
        """Uniform in [0, 1): top 53 bits / 2^53 (same as rand's convention)."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def fill(self, shape, dtype=np.float64) -> np.ndarray:
        """Row-major array of next_f64 draws."""
        n = int(np.prod(shape))
        out = np.empty(n, dtype=np.float64)
        for i in range(n):
            out[i] = self.next_f64()
        return out.reshape(shape).astype(dtype)


#: First three u64 draws for seed 42 — assert these on both sides.
VECTORS_SEED42 = [
    0xBDD732262FEB6E95,
    0x28EFE333B266F103,
    0x47526757130F9F52,
]

if __name__ == "__main__":
    rng = SplitMix64(42)
    print([hex(rng.next_u64()) for _ in range(3)])
