"""Single-step tiled Pallas stencil kernel.

This is the Pallas adaptation of the paper's register-level "pattern
mapping" (§3): the output is tessellated into rectangular tiles (the
"straight tetrominoes"); each grid program DMAs its tile plus a halo ring
from the (HBM-resident) input into VMEM, accumulates the weighted taps as
aligned slot-wise FMA chains — the conflict-free schedule of Vector Skewed
Swizzling: no gather, no cross-lane shuffle, every tap is a contiguous
slice — and writes the tile back.

Lowered with ``interpret=True`` everywhere: the CPU PJRT plugin cannot run
Mosaic custom-calls (see DESIGN.md §Hardware-Adaptation); structure — tile
shapes, VMEM footprint, tap schedule — is what we optimize and what the
estimators in :mod:`.vmem` analyse.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .spec import StencilSpec

jax.config.update("jax_enable_x64", True)


def _check_tiles(core: Tuple[int, ...], tiles: Tuple[int, ...]) -> None:
    if len(core) != len(tiles):
        raise ValueError(f"tile rank {len(tiles)} != core rank {len(core)}")
    for n, t in zip(core, tiles):
        if n % t != 0:
            raise ValueError(f"core dim {n} not divisible by tile {t}")


def _kernel(u_ref, out_ref, *, spec: StencilSpec, tiles: Tuple[int, ...]):
    """Grid program: load tile+halo window, accumulate taps, store tile."""
    r = spec.radius
    nd = spec.ndim
    # Element offset of this program's output tile.
    starts = [pl.program_id(d) * tiles[d] for d in range(nd)]
    # Window = tile + halo ring, loaded once into VMEM (registers in
    # interpret mode); all taps below are views into this window.
    window = pl.load(
        u_ref,
        tuple(pl.ds(starts[d], tiles[d] + 2 * r) for d in range(nd)),
    )
    acc = jnp.zeros(tiles, dtype=out_ref.dtype)
    for off, c in sorted(spec.coeffs.items()):
        idx = tuple(slice(r + o, r + o + t) for o, t in zip(off, tiles))
        acc = acc + out_ref.dtype.type(c) * window[idx]
    pl.store(out_ref, tuple(pl.ds(starts[d], tiles[d]) for d in range(nd)), acc)


def stencil_step(
    u: jnp.ndarray,
    spec: StencilSpec,
    tiles: Optional[Sequence[int]] = None,
) -> jnp.ndarray:
    """One valid-mode stencil update via a tiled Pallas kernel.

    Args:
      u: input of shape ``core + 2*radius`` per dim.
      spec: stencil specification.
      tiles: output tile shape; defaults to the whole core (single program).

    Returns:
      Updated array of core shape.
    """
    r = spec.radius
    core = tuple(n - 2 * r for n in u.shape)
    if any(n <= 0 for n in core):
        raise ValueError(f"{spec.name}: input {u.shape} too small for r={r}")
    tiles = tuple(tiles) if tiles is not None else core
    _check_tiles(core, tiles)
    grid = tuple(n // t for n, t in zip(core, tiles))
    kern = functools.partial(_kernel, spec=spec, tiles=tiles)
    return pl.pallas_call(
        kern,
        grid=grid,
        # Whole-array specs: the kernel addresses its own window with
        # dynamic slices (the HBM->VMEM DMA schedule is explicit).
        in_specs=[pl.BlockSpec(u.shape, lambda *_: tuple([0] * spec.ndim))],
        out_specs=pl.BlockSpec(core, lambda *_: tuple([0] * spec.ndim)),
        out_shape=jax.ShapeDtypeStruct(core, u.dtype),
        interpret=True,
    )(u)
