"""Trapezoid-folding kernel: 2D stencil as banded matmuls on the MXU.

The TPU adaptation of the paper's §3.2 Tensor Trapezoid Folding.  The
paper re-expresses non-MM stencil taps as FP64 8x4x8 MMA operations whose
weight "stairs" overlap and fold into the final update.  The same algebra,
MXU-shaped:

    out[i, j] = sum_{dx, dy} c[dx, dy] * u[i + r + dx, j + r + dy]

factorizes row-band by row-band into dense matmuls

    out = sum_{dx = -r..r}  U_dx @ B_dx

where ``U_dx[i, :] = u[i + r + dx, :]`` is a row-shifted slab (a view — no
data movement) and ``B_dx`` is an ``(ny + 2r, ny)`` *banded* matrix with
``B_dx[j + r + dy, j] = c[dx, dy]``.  Each B_dx is the paper's "stair
tetromino": its diagonals are the weight stairs, and the overlap of
adjacent output columns' bands is the fold-accumulate.  Every term is a
dense matmul the MXU executes at full systolic utilization; for star
stencils all off-axis bands vanish and the sum collapses to the classical
``L @ u + u @ R`` two-matmul form.

For FP64 the real MXU would use the float64-as-3xbfloat16 split (as the
paper uses DMMA); under ``interpret=True`` the dots run in native f64,
which upper-bounds accuracy and keeps the oracle comparison exact.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .spec import StencilSpec

jax.config.update("jax_enable_x64", True)


def band_matrices(spec: StencilSpec, ny: int, dtype=np.float64) -> np.ndarray:
    """Stack of banded coefficient matrices, shape (2r+1, ny+2r, ny).

    Entry ``[dx + r, j + r + dy, j] = c[(dx, dy)]``; rows of the stack with
    no taps are all-zero (skipped by the kernel for star stencils).
    """
    if spec.ndim != 2:
        raise ValueError("band_matrices: 2D stencils only")
    r = spec.radius
    bands = np.zeros((2 * r + 1, ny + 2 * r, ny), dtype=dtype)
    for (dx, dy), c in spec.coeffs.items():
        j = np.arange(ny)
        bands[dx + r, j + r + dy, j] = c
    return bands


def _used_rows(spec: StencilSpec) -> Tuple[int, ...]:
    """Which dx-slabs actually carry taps (all for box, 2r+1; star: all too
    since the axis taps live at dy=0) — but star off-center slabs have a
    single diagonal, which XLA folds into a cheap matmul regardless."""
    r = spec.radius
    used = sorted({dx + r for (dx, _dy) in spec.coeffs})
    return tuple(used)


def _kernel(u_ref, bands_ref, out_ref, *, spec, tile_m: int, ny: int):
    r = spec.radius
    i0 = pl.program_id(0) * tile_m
    # 0 as an int32 scalar: mixing python ints (int64 under x64) with the
    # int32 program_id in one dynamic_slice is a type error.
    zero = jnp.zeros((), dtype=jnp.int32)
    # Row slab covering every dx-shift for this tile: (tile_m + 2r, ny + 2r).
    slab = pl.load(u_ref, (pl.ds(i0, tile_m + 2 * r), pl.ds(zero, ny + 2 * r)))
    acc = jnp.zeros((tile_m, ny), dtype=out_ref.dtype)
    for dxr in _used_rows(spec):
        # U_dx: rows shifted by dx (view into the slab) — (tile_m, ny+2r).
        u_dx = slab[dxr : dxr + tile_m, :]
        b_dx = bands_ref[dxr]  # (ny + 2r, ny), banded stair matrix
        # The MXU op: dense matmul; overlapping bands fold-accumulate.
        acc = acc + jnp.dot(u_dx, b_dx, preferred_element_type=out_ref.dtype)
    pl.store(out_ref, (pl.ds(i0, tile_m), pl.ds(zero, ny)), acc)


def mxu_fold(
    u: jnp.ndarray,
    spec: StencilSpec,
    tile_m: Optional[int] = None,
    bands: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """One valid-mode 2D stencil update as banded matmuls.

    Args:
      u: (nx + 2r, ny + 2r) input.
      spec: 2D stencil spec.
      tile_m: output row-tile per grid program (MXU-friendly, e.g. 128);
        defaults to all rows in one program.
      bands: optional precomputed band stack (see band_matrices).  Passed
        as a runtime argument by the AOT pipeline: baking it as a traced
        constant would be elided by the HLO *text* printer
        ("constant({...})"), breaking the rust loader.
    """
    if spec.ndim != 2:
        raise ValueError("mxu_fold supports 2D stencils")
    r = spec.radius
    nx, ny = u.shape[0] - 2 * r, u.shape[1] - 2 * r
    if nx <= 0 or ny <= 0:
        raise ValueError(f"{spec.name}: input {u.shape} too small for r={r}")
    tile_m = tile_m or nx
    if nx % tile_m != 0:
        raise ValueError(f"rows {nx} not divisible by tile_m {tile_m}")
    if bands is None:
        bands = jnp.asarray(band_matrices(spec, ny, dtype=u.dtype))
    if bands.shape != (2 * r + 1, ny + 2 * r, ny):
        raise ValueError(f"bands shape {bands.shape} != {(2*r+1, ny+2*r, ny)}")
    kern = functools.partial(_kernel, spec=spec, tile_m=tile_m, ny=ny)
    return pl.pallas_call(
        kern,
        grid=(nx // tile_m,),
        in_specs=[
            pl.BlockSpec(u.shape, lambda i: (0, 0)),
            pl.BlockSpec(bands.shape, lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((nx, ny), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((nx, ny), u.dtype),
        interpret=True,
    )(u, bands)


def mxu_fold_block(
    u: jnp.ndarray,
    spec: StencilSpec,
    steps: int,
    tile_m: Optional[int] = None,
) -> jnp.ndarray:
    """`steps` fused updates, each via the banded-matmul kernel.

    Input carries a ``radius*steps`` ring; the valid region shrinks by
    ``radius`` per step, i.e. the Octuple-Pipelining stack of §3.2 applied
    block-after-block.
    """
    for s in range(steps):
        tm = tile_m if (tile_m and (u.shape[0] - 2 * spec.radius) % tile_m == 0) else None
        u = mxu_fold(u, spec, tile_m=tm)
    return u
