"""Layer-1 Pallas kernels for the Tetris stencil stack.

Modules:
  spec           - stencil specifications (paper Table 1)
  ref            - pure-jnp correctness oracle
  stencil_step   - single-step tiled Pallas kernel
  temporal_block - Tb-step fused Pallas kernel (tessellation / AN5D analogue)
  mxu_fold       - trapezoid-folding banded-matmul kernel (MXU adaptation)
  vmem           - VMEM-footprint / MXU-utilization estimators
"""

from . import spec, ref, stencil_step, temporal_block, mxu_fold, vmem  # noqa: F401
