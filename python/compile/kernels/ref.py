"""Pure-jnp oracle for every stencil kernel — the CORE correctness signal.

Everything else in the stack (Pallas kernels, AOT artifacts, the rust
engines) is validated against these functions, directly via pytest or
transitively through golden vectors embedded in the artifact manifest.

All functions use valid-mode semantics (see kernels.spec docstring).
"""

from __future__ import annotations

import jax.numpy as jnp

from .spec import StencilSpec


def step(u: jnp.ndarray, spec: StencilSpec) -> jnp.ndarray:
    """One valid-mode stencil update: (n+2r, ..) -> (n, ..).

    out[i] = sum_o c_o * u[i + r + o]  for every interior cell i.
    """
    r = spec.radius
    if u.ndim != spec.ndim:
        raise ValueError(f"{spec.name}: expected {spec.ndim}d input, got {u.ndim}d")
    core = tuple(n - 2 * r for n in u.shape)
    if any(n <= 0 for n in core):
        raise ValueError(f"{spec.name}: input {u.shape} too small for radius {r}")
    out = jnp.zeros(core, dtype=u.dtype)
    for off, c in sorted(spec.coeffs.items()):
        idx = tuple(
            slice(r + o, r + o + n) for o, n in zip(off, core)
        )
        out = out + u.dtype.type(c) * u[idx]
    return out


def block(u: jnp.ndarray, spec: StencilSpec, steps: int) -> jnp.ndarray:
    """`steps` fused valid-mode updates: (n + 2*r*steps, ..) -> (n, ..)."""
    for _ in range(steps):
        u = step(u, spec)
    return u


def evolve_periodic(u: jnp.ndarray, spec: StencilSpec, steps: int) -> jnp.ndarray:
    """`steps` updates on a periodic domain (shape-preserving).

    Used by the thermal-diffusion accuracy study where the global domain
    wraps; implemented with jnp.roll so it is exact for any radius.
    """
    for _ in range(steps):
        out = jnp.zeros_like(u)
        for off, c in sorted(spec.coeffs.items()):
            shifted = u
            for axis, o in enumerate(off):
                if o != 0:
                    shifted = jnp.roll(shifted, -o, axis=axis)
            out = out + u.dtype.type(c) * shifted
        u = out
    return u
