"""Stencil specifications for the Tetris benchmark suite (paper Table 1).

A :class:`StencilSpec` fully describes one stencil dwarf: dimensionality,
shape family (star / box), radius and the FP64 coefficient set.  Both the
pure-jnp oracle (:mod:`.ref`), the Pallas kernels and the AOT pipeline are
driven by these specs, and the rust side mirrors them byte-for-byte in
``rust/src/stencil/spec.rs`` (checked by an integration test through the
artifact manifest).

Semantics
---------
All kernels use *valid-mode* (shrinking) updates: one step maps an array of
shape ``(n_0 + 2r, ..)`` to ``(n_0, ..)``.  A fused temporal block of ``Tb``
steps maps ``(n_0 + 2 r Tb, ..)`` to ``(n_0, ..)``.  This is exactly the
contract the L3 halo-exchange coordinator needs: a worker owns its core
cells plus a halo ring of width ``r * Tb`` and refills the ring once per
block (the paper's §5.3 "centralized communication launch").
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np

Offset = Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class StencilSpec:
    """A single stencil dwarf.

    Attributes:
      name: benchmark name as in paper Table 1 (lower-case).
      ndim: number of spatial dimensions (1, 2 or 3).
      kind: "star" (axis-aligned arms) or "box" (dense hypercube).
      radius: arm length / half-width.
      coeffs: mapping offset-tuple -> FP64 coefficient.
    """

    name: str
    ndim: int
    kind: str
    radius: int
    coeffs: Dict[Offset, float]

    @property
    def points(self) -> int:
        """Number of taps (paper Table 1 "Pts")."""
        return len(self.coeffs)

    @property
    def flops_per_cell(self) -> int:
        """One multiply + one add per tap (fused as FMA on real HW)."""
        return 2 * self.points

    def offsets_array(self) -> np.ndarray:
        """(points, ndim) int32 array of offsets, deterministic order."""
        return np.array(sorted(self.coeffs.keys()), dtype=np.int32)

    def coeffs_array(self) -> np.ndarray:
        """(points,) float64 coefficients, matching offsets_array order."""
        return np.array(
            [self.coeffs[o] for o in sorted(self.coeffs.keys())],
            dtype=np.float64,
        )

    def halo(self, steps: int = 1) -> int:
        """Ghost-ring width consumed by `steps` fused valid-mode steps."""
        return self.radius * steps


def _star(ndim: int, radius: int, center: float, arm: float) -> Dict[Offset, float]:
    """Star coefficients: `center` at origin, `arm` on each axis tap.

    Normalized so the sum is 1 (heat-equation style convex update), which
    keeps long evolutions numerically stable and mirrors Eq. 3 of the
    paper with CFL number mu.
    """
    coeffs: Dict[Offset, float] = {}
    origin = tuple([0] * ndim)
    coeffs[origin] = center
    for d in range(ndim):
        for r in range(1, radius + 1):
            for sign in (-1, 1):
                off = [0] * ndim
                off[d] = sign * r
                # Decay arm weight with distance, as in high-order FD taps.
                coeffs[tuple(off)] = arm / r
    total = sum(coeffs.values())
    return {k: v / total for k, v in coeffs.items()}


def _box(ndim: int, radius: int) -> Dict[Offset, float]:
    """Box coefficients: separable triangular profile, normalized to 1."""
    axis = np.arange(-radius, radius + 1, dtype=np.float64)
    w1 = (radius + 1.0) - np.abs(axis)  # triangular weights per axis
    coeffs: Dict[Offset, float] = {}

    def rec(prefix: Tuple[int, ...], weight: float) -> None:
        if len(prefix) == ndim:
            coeffs[prefix] = weight
            return
        for i, o in enumerate(axis.astype(int)):
            rec(prefix + (int(o),), weight * w1[i])

    rec(tuple(), 1.0)
    total = sum(coeffs.values())
    return {k: v / total for k, v in coeffs.items()}


def heat_coeffs_2d(mu: float) -> Dict[Offset, float]:
    """Paper Eq. 3: u' = (1-4mu) u + mu (N + S + E + W)."""
    return {
        (0, 0): 1.0 - 4.0 * mu,
        (-1, 0): mu,
        (1, 0): mu,
        (0, -1): mu,
        (0, 1): mu,
    }


#: CFL number used in the paper's thermal-diffusion case study (§6.5).
THERMAL_MU = 0.23

#: The 8 benchmark stencils of paper Table 1.
BENCHMARKS: Dict[str, StencilSpec] = {
    "heat1d": StencilSpec("heat1d", 1, "star", 1, _star(1, 1, 0.5, 0.25)),
    "star1d5p": StencilSpec("star1d5p", 1, "star", 2, _star(1, 2, 0.4, 0.2)),
    "heat2d": StencilSpec("heat2d", 2, "star", 1, heat_coeffs_2d(THERMAL_MU)),
    "star2d9p": StencilSpec("star2d9p", 2, "star", 2, _star(2, 2, 0.3, 0.1)),
    "box2d9p": StencilSpec("box2d9p", 2, "box", 1, _box(2, 1)),
    "box2d25p": StencilSpec("box2d25p", 2, "box", 2, _box(2, 2)),
    "heat3d": StencilSpec("heat3d", 3, "star", 1, _star(3, 1, 0.4, 0.1)),
    "box3d27p": StencilSpec("box3d27p", 3, "box", 1, _box(3, 1)),
}


def get(name: str) -> StencilSpec:
    """Look up a benchmark spec by name, raising KeyError with choices."""
    try:
        return BENCHMARKS[name]
    except KeyError:
        raise KeyError(
            f"unknown stencil {name!r}; choices: {sorted(BENCHMARKS)}"
        ) from None
