"""VMEM-footprint and MXU-utilization estimators for the Pallas kernels.

``interpret=True`` timings are CPU-numpy and say nothing about TPU
performance, so — per DESIGN.md §8 — kernel *structure* is validated
analytically: does the chosen tile fit the 16 MiB VMEM budget, what
fraction of HBM traffic the temporal block saves, and what MXU occupancy
the trapezoid-folding matmuls reach.  The same numbers are embedded into
the AOT manifest so the rust scheduler's cost model (rust/src/model/) can
reason about them without Python.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Sequence, Tuple

from .spec import StencilSpec

#: Per-core VMEM on contemporary TPU (v4/v5p), bytes.
VMEM_BYTES = 16 * 1024 * 1024
#: MXU systolic array edge (128x128 MACs).
MXU_EDGE = 128
#: Peak HBM bandwidth proxy (bytes/s) used for roofline ratios only.
HBM_BW = 1.2e12


@dataclasses.dataclass(frozen=True)
class KernelEstimate:
    """Static estimate for one kernel configuration."""

    vmem_bytes: int
    vmem_fraction: float
    flops_per_cell: int
    hbm_bytes_per_cell: float
    arithmetic_intensity: float  # flops / HBM byte
    mxu_utilization: float  # 0 for VPU-only kernels

    def fits(self) -> bool:
        return self.vmem_fraction <= 1.0

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def step_estimate(
    spec: StencilSpec, tiles: Sequence[int], itemsize: int = 8
) -> KernelEstimate:
    """Estimate for the single-step tiled kernel (VPU path)."""
    r = spec.radius
    window = math.prod(t + 2 * r for t in tiles)
    out = math.prod(tiles)
    vmem = (window + 2 * out) * itemsize  # window + acc + out tile
    flops = spec.flops_per_cell
    hbm_per_cell = itemsize * (window / out + 1.0)  # read window, write core
    return KernelEstimate(
        vmem_bytes=vmem,
        vmem_fraction=vmem / VMEM_BYTES,
        flops_per_cell=flops,
        hbm_bytes_per_cell=hbm_per_cell,
        arithmetic_intensity=flops / hbm_per_cell,
        mxu_utilization=0.0,
    )


def temporal_estimate(
    spec: StencilSpec, tiles: Sequence[int], steps: int, itemsize: int = 8
) -> KernelEstimate:
    """Estimate for the Tb-fused kernel: HBM traffic amortized over Tb."""
    r = spec.radius
    halo = r * steps
    window = math.prod(t + 2 * halo for t in tiles)
    out = math.prod(tiles)
    # window + two ping-pong scratch buffers of the first-shrink size.
    scratch = math.prod(t + 2 * r * (steps - 1) for t in tiles)
    vmem = (window + 2 * scratch) * itemsize
    flops = spec.flops_per_cell * steps  # per output cell, Tb updates
    hbm_per_cell = itemsize * (window / out + 1.0)  # ONE round-trip per Tb
    return KernelEstimate(
        vmem_bytes=vmem,
        vmem_fraction=vmem / VMEM_BYTES,
        flops_per_cell=flops,
        hbm_bytes_per_cell=hbm_per_cell,
        arithmetic_intensity=flops / hbm_per_cell,
        mxu_utilization=0.0,
    )


def mxu_estimate(
    spec: StencilSpec, tile_m: int, ny: int, itemsize: int = 8
) -> KernelEstimate:
    """Estimate for the trapezoid-folding banded-matmul kernel.

    MXU utilization = useful MACs / MACs issued.  A dense
    (tile_m x ny+2r) @ (ny+2r x ny) matmul issues tile_m*(ny+2r)*ny MACs,
    of which only the band (2r+1 diagonals) carries taps; however the
    systolic array is *fully busy* either way, so we report both occupancy
    (issue efficiency vs an ideal sparse engine) and the padding
    efficiency of the tile against the 128-lane MXU edge.
    """
    r = spec.radius
    slabs = len({dx for (dx, _dy) in spec.coeffs})
    issued = slabs * tile_m * (ny + 2 * r) * ny * 2  # MACs * 2 flops
    useful = spec.flops_per_cell * tile_m * ny
    # Edge padding: how well tile_m and ny fill 128-multiples.
    pad = (
        (math.ceil(tile_m / MXU_EDGE) * MXU_EDGE / tile_m)
        * (math.ceil(ny / MXU_EDGE) * MXU_EDGE / ny)
    )
    window = (tile_m + 2 * r) * (ny + 2 * r)
    bands = (2 * r + 1) * (ny + 2 * r) * ny
    vmem = (window + bands + 2 * tile_m * ny) * itemsize
    hbm_per_cell = itemsize * (window / (tile_m * ny) + 1.0)
    return KernelEstimate(
        vmem_bytes=vmem,
        vmem_fraction=vmem / VMEM_BYTES,
        flops_per_cell=spec.flops_per_cell,
        hbm_bytes_per_cell=hbm_per_cell,
        arithmetic_intensity=issued / (tile_m * ny) / hbm_per_cell,
        mxu_utilization=(useful / issued) / pad,
    )


def pick_tiles(
    spec: StencilSpec, core: Sequence[int], steps: int = 1, itemsize: int = 8
) -> Tuple[int, ...]:
    """Choose the largest divisor tile per dim whose block fits VMEM.

    Greedy from the full core downward: halve the leading dimension until
    the temporal estimate fits the budget.  Deterministic, so rust and
    python agree on artifact shapes.
    """
    tiles = list(core)
    for _ in range(64):
        est = temporal_estimate(spec, tiles, steps, itemsize)
        if est.fits():
            return tuple(tiles)
        # halve the largest tile dimension that can still be halved evenly
        d = max(range(len(tiles)), key=lambda i: tiles[i])
        if tiles[d] % 2 != 0 or tiles[d] <= 2 * spec.radius:
            return tuple(tiles)  # cannot shrink further; caller may reject
        tiles[d] //= 2
        # keep divisibility of the core
        while core[d] % tiles[d] != 0:
            tiles[d] -= 1
    return tuple(tiles)
