"""Tb-step fused temporal-block Pallas kernel.

The Pallas analogue of the paper's Locality Enhancer (§4): instead of one
sweep per time step (one full HBM round-trip per step), a tile plus a halo
ring of width ``radius*Tb`` is DMA'd into VMEM once and advanced ``Tb``
steps *in scratch memory*, shrinking by ``radius`` per step — the
"trapezoid" a checkerboard block computes in shared memory on the paper's
GPU.  HBM traffic drops by ~Tb for halo-dominated tiles, which is exactly
the in-memory flops/byte argument of §4.1.

The overlap between neighbouring tiles (the re-loaded halo) is the classic
overlapped-trapezoid scheme; the *non-redundant* two-phase tessellation
(triangle + inverted-triangle tetrominoes) is implemented where the paper
implements it — on the CPU, in ``rust/src/engine/tessellate.rs`` — because
its two dependent phases do not map onto a single data-parallel Pallas
grid launch.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .spec import StencilSpec

jax.config.update("jax_enable_x64", True)


def _kernel(u_ref, out_ref, *, spec: StencilSpec, tiles: Tuple[int, ...], steps: int):
    r = spec.radius
    nd = spec.ndim
    halo = r * steps
    starts = [pl.program_id(d) * tiles[d] for d in range(nd)]
    # One DMA: tile + Tb-wide halo ring.
    window = pl.load(
        u_ref,
        tuple(pl.ds(starts[d], tiles[d] + 2 * halo) for d in range(nd)),
    )
    # Advance Tb steps in VMEM scratch; the working set shrinks by r per
    # step (the temporal trapezoid).
    for s in range(steps):
        cur = tuple(tiles[d] + 2 * r * (steps - 1 - s) for d in range(nd))
        acc = jnp.zeros(cur, dtype=window.dtype)
        for off, c in sorted(spec.coeffs.items()):
            idx = tuple(slice(r + o, r + o + n) for o, n in zip(off, cur))
            acc = acc + window.dtype.type(c) * window[idx]
        window = acc
    pl.store(out_ref, tuple(pl.ds(starts[d], tiles[d]) for d in range(nd)), window)


def temporal_block(
    u: jnp.ndarray,
    spec: StencilSpec,
    steps: int,
    tiles: Optional[Sequence[int]] = None,
) -> jnp.ndarray:
    """`steps` fused valid-mode updates: (n + 2*r*steps, ..) -> (n, ..).

    Args:
      u: input with a ``radius*steps`` ghost ring per side.
      spec: stencil specification.
      steps: number of fused time steps (Tb).
      tiles: output tile shape; defaults to whole core.
    """
    if steps < 1:
        raise ValueError("steps must be >= 1")
    halo = spec.radius * steps
    core = tuple(n - 2 * halo for n in u.shape)
    if any(n <= 0 for n in core):
        raise ValueError(
            f"{spec.name}: input {u.shape} too small for r={spec.radius}, Tb={steps}"
        )
    tiles = tuple(tiles) if tiles is not None else core
    for n, t in zip(core, tiles):
        if n % t != 0:
            raise ValueError(f"core dim {n} not divisible by tile {t}")
    grid = tuple(n // t for n, t in zip(core, tiles))
    kern = functools.partial(_kernel, spec=spec, tiles=tiles, steps=steps)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec(u.shape, lambda *_: tuple([0] * spec.ndim))],
        out_specs=pl.BlockSpec(core, lambda *_: tuple([0] * spec.ndim)),
        out_shape=jax.ShapeDtypeStruct(core, u.dtype),
        interpret=True,
    )(u)
