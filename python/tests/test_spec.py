"""Spec invariants: Table-1 benchmark definitions are well-formed."""

import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import spec as specs


ALL = sorted(specs.BENCHMARKS)


@pytest.mark.parametrize("name", ALL)
def test_points_match_table1(name):
    expected = {
        "heat1d": 3, "star1d5p": 5, "heat2d": 5, "star2d9p": 9,
        "box2d9p": 9, "box2d25p": 25, "heat3d": 7, "box3d27p": 27,
    }
    assert specs.get(name).points == expected[name]


@pytest.mark.parametrize("name", ALL)
def test_coeffs_normalized(name):
    s = specs.get(name)
    assert abs(sum(s.coeffs.values()) - 1.0) < 1e-12


@pytest.mark.parametrize("name", ALL)
def test_offsets_within_radius(name):
    s = specs.get(name)
    for off in s.coeffs:
        assert len(off) == s.ndim
        assert all(abs(o) <= s.radius for o in off)
        if s.kind == "star":
            # star: at most one nonzero component
            assert sum(1 for o in off if o != 0) <= 1


@pytest.mark.parametrize("name", ALL)
def test_offsets_symmetric(name):
    s = specs.get(name)
    for off in s.coeffs:
        neg = tuple(-o for o in off)
        assert neg in s.coeffs


@pytest.mark.parametrize("name", ALL)
def test_arrays_consistent(name):
    s = specs.get(name)
    offs = s.offsets_array()
    cs = s.coeffs_array()
    assert offs.shape == (s.points, s.ndim)
    assert cs.shape == (s.points,)
    rebuilt = {tuple(int(x) for x in o): float(c) for o, c in zip(offs, cs)}
    assert rebuilt == {k: pytest.approx(v) for k, v in s.coeffs.items()}


@pytest.mark.parametrize("name", ALL)
def test_halo_scales_with_steps(name):
    s = specs.get(name)
    for steps in (1, 2, 5):
        assert s.halo(steps) == s.radius * steps


@given(ndim=st.integers(1, 3), radius=st.integers(1, 3),
       center=st.floats(0.1, 0.9), arm=st.floats(0.05, 0.5))
def test_star_generator_properties(ndim, radius, center, arm):
    coeffs = specs._star(ndim, radius, center, arm)
    assert abs(sum(coeffs.values()) - 1.0) < 1e-12
    assert len(coeffs) == 1 + 2 * ndim * radius
    assert all(v > 0 for v in coeffs.values())


@given(ndim=st.integers(1, 3), radius=st.integers(1, 2))
def test_box_generator_properties(ndim, radius):
    coeffs = specs._box(ndim, radius)
    assert abs(sum(coeffs.values()) - 1.0) < 1e-12
    assert len(coeffs) == (2 * radius + 1) ** ndim
    # separable triangular profile is symmetric under reflection
    for off, v in coeffs.items():
        assert coeffs[tuple(-o for o in off)] == pytest.approx(v)


def test_heat2d_matches_eq3():
    mu = specs.THERMAL_MU
    s = specs.get("heat2d")
    assert s.coeffs[(0, 0)] == pytest.approx(1 - 4 * mu)
    for off in ((1, 0), (-1, 0), (0, 1), (0, -1)):
        assert s.coeffs[off] == pytest.approx(mu)


def test_unknown_name_raises():
    with pytest.raises(KeyError, match="choices"):
        specs.get("nope")


def test_flops_per_cell():
    assert specs.get("heat2d").flops_per_cell == 10
    assert specs.get("box3d27p").flops_per_cell == 54
