import os
import sys

import jax

# Make `compile` importable when pytest runs from the repo root.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

jax.config.update("jax_enable_x64", True)

from hypothesis import settings

# interpret-mode pallas is slow; keep example counts sane and disable the
# per-example deadline (first call pays trace+lower cost).
settings.register_profile("tetris", max_examples=12, deadline=None)
settings.load_profile("tetris")
