"""AOT pipeline: artifact inventory, seeds, and one real lowering."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile.kernels import spec as specs


def test_bench_configs_cover_table1():
    assert sorted(aot.BENCH_CONFIGS) == sorted(specs.BENCHMARKS)
    for name, cfg in aot.BENCH_CONFIGS.items():
        assert cfg.core[0] % cfg.unit == 0
        assert cfg.tb >= 1
        assert cfg.unit_core()[0] == cfg.unit


def test_artifact_inventory():
    arts = aot.build_artifacts()
    names = [a.name for a in arts]
    assert len(names) == len(set(names)), "duplicate artifact names"
    for bench in aot.BENCH_CONFIGS:
        assert f"{bench}_step" in names
        assert f"{bench}_block" in names
        assert f"{bench}_oracle" in names
    for bench in ("heat2d", "star2d9p", "box2d9p", "box2d25p"):
        assert f"{bench}_mxu" in names
    for dt in ("f64", "f32"):
        assert f"thermal_{dt}" in names
        assert f"stats_{dt}" in names


def test_artifact_shapes_respect_halo():
    for a in aot.build_artifacts():
        meta = a.meta
        if meta["variant"] in ("step", "block", "oracle", "mxu"):
            uc = meta["unit_core"]
            halo = meta["halo"]
            assert list(a.input_shape) == [n + 2 * halo for n in uc]
            assert meta["halo"] == meta["radius"] * meta["steps"]


def test_seed_fnv1a_vectors():
    # FNV-1a 64 of known strings; rust mirrors these in util/prng.rs.
    assert aot._seed_for("") == 0xCBF29CE484222325
    assert aot._seed_for("a") == 0xAF63DC4C8601EC8C
    assert aot._seed_for("heat2d_step") == aot._seed_for("heat2d_step")
    assert aot._seed_for("heat2d_step") != aot._seed_for("heat2d_block")


@pytest.mark.slow
def test_lower_one_artifact(tmp_path):
    (art,) = [a for a in aot.build_artifacts() if a.name == "heat2d_step"]
    entry = art.lower_and_golden(str(tmp_path))
    text = (tmp_path / "heat2d_step.hlo.txt").read_text()
    assert text.startswith("HloModule")
    assert np.isfinite(entry["golden"]["out_mean"])
    assert entry["golden"]["out_shape"] == entry["output_shape"]
    # golden reproducibility
    entry2 = art.lower_and_golden(str(tmp_path))
    assert entry2["golden"]["out_l2"] == entry["golden"]["out_l2"]


def test_manifest_written_by_make(tmp_path):
    """If `make artifacts` has run, the manifest must be consistent."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                        "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built yet")
    with open(path) as f:
        m = json.load(f)
    assert m["version"] == 1
    names = {e["name"] for e in m["artifacts"]}
    for e in m["artifacts"]:
        hlo = os.path.join(os.path.dirname(path), e["file"])
        assert os.path.exists(hlo), e["file"]
    assert {f"{b}_step" for b in m["benches"]} <= names
