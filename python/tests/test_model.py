"""L2 model graphs: contracts, shapes, and agreement with the oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref
from compile.kernels import spec as specs


def _rand(shape, dtype=np.float64, seed=0):
    return jnp.asarray(np.random.default_rng(seed).random(shape).astype(dtype))


@pytest.mark.parametrize("name", sorted(specs.BENCHMARKS))
def test_subdomain_block_contract(name):
    s = specs.get(name)
    steps = 2
    core = tuple(6 for _ in range(s.ndim))
    u = _rand(tuple(n + 2 * s.radius * steps for n in core))
    (out,) = model.subdomain_block(s, steps)(u)
    assert out.shape == core
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.block(u, s, steps)), rtol=1e-12
    )


def test_subdomain_block_step1_uses_step_kernel():
    s = specs.get("heat2d")
    u = _rand((10, 10), seed=1)
    (out,) = model.subdomain_block(s, 1)(u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.step(u, s)), rtol=1e-12)


@pytest.mark.parametrize("name", ["heat2d", "box2d25p"])
def test_mxu_subdomain_block(name):
    s = specs.get(name)
    u = _rand((8 + 2 * s.radius, 8 + 2 * s.radius), seed=2)
    (out,) = model.mxu_subdomain_block(s, 1)(u)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.step(u, s)), rtol=1e-12, atol=1e-13
    )


def test_reference_block_agrees():
    s = specs.get("star1d5p")
    u = _rand((20,), seed=3)
    (a,) = model.reference_block(s, 2)(u)
    (b,) = model.subdomain_block(s, 2)(u)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-12)


def test_thermal_block_matches_periodic_oracle():
    s = specs.get("heat2d")
    u = _rand((16, 16), seed=4)
    (out,) = model.thermal_step_block(s, 5)(u)
    expect = ref.evolve_periodic(u, s, 5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-12)
    assert out.shape == u.shape  # shape-preserving


def test_thermal_block_fp32():
    s = specs.get("heat2d")
    u = _rand((12, 12), dtype=np.float32, seed=5)
    (out,) = model.thermal_step_block(s, 3, jnp.float32)(u)
    assert out.dtype == jnp.float32
    expect = ref.evolve_periodic(u, s, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5)


def test_thermal_preserves_mean():
    s = specs.get("heat2d")
    u = _rand((16, 16), seed=6)
    (out,) = model.thermal_step_block(s, 8)(u)
    assert float(jnp.mean(out)) == pytest.approx(float(jnp.mean(u)), rel=1e-12)


def test_energy_stats():
    u = _rand((9, 9), seed=7)
    mean, lo, hi = model.energy_stats()(u)
    assert float(mean) == pytest.approx(float(jnp.mean(u)))
    assert float(lo) == pytest.approx(float(jnp.min(u)))
    assert float(hi) == pytest.approx(float(jnp.max(u)))


def test_models_are_jittable():
    s = specs.get("heat2d")
    fn = jax.jit(model.subdomain_block(s, 2))
    u = _rand((12, 12), seed=8)
    (out,) = fn(u)
    assert out.shape == (8, 8)
