"""Oracle sanity: ref.py against hand-rolled numpy convolutions."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels import spec as specs


def numpy_step(u: np.ndarray, s: specs.StencilSpec) -> np.ndarray:
    """Direct loop-free numpy implementation, independent of ref.py."""
    r = s.radius
    core = tuple(n - 2 * r for n in u.shape)
    out = np.zeros(core, dtype=u.dtype)
    for off, c in s.coeffs.items():
        idx = tuple(slice(r + o, r + o + n) for o, n in zip(off, core))
        out += c * u[idx]
    return out


@pytest.mark.parametrize("name", sorted(specs.BENCHMARKS))
def test_step_matches_numpy(name):
    s = specs.get(name)
    rng = np.random.default_rng(7)
    shape = tuple(10 + 2 * s.radius for _ in range(s.ndim))
    u = rng.random(shape)
    got = np.asarray(ref.step(jnp.asarray(u), s))
    np.testing.assert_allclose(got, numpy_step(u, s), rtol=1e-13)


@pytest.mark.parametrize("name", sorted(specs.BENCHMARKS))
def test_block_is_iterated_step(name):
    s = specs.get(name)
    rng = np.random.default_rng(8)
    steps = 3
    shape = tuple(6 + 2 * s.radius * steps for _ in range(s.ndim))
    u = jnp.asarray(rng.random(shape))
    via_block = ref.block(u, s, steps)
    via_steps = u
    for _ in range(steps):
        via_steps = ref.step(via_steps, s)
    np.testing.assert_allclose(np.asarray(via_block), np.asarray(via_steps), rtol=1e-13)


@pytest.mark.parametrize("name", ["heat1d", "heat2d", "box2d9p"])
def test_periodic_preserves_mean(name):
    """Normalized convex coefficients conserve the mean on a torus."""
    s = specs.get(name)
    rng = np.random.default_rng(9)
    shape = tuple(12 for _ in range(s.ndim))
    u = jnp.asarray(rng.random(shape))
    out = ref.evolve_periodic(u, s, steps=4)
    assert float(jnp.mean(out)) == pytest.approx(float(jnp.mean(u)), rel=1e-12)


def test_periodic_uniform_fixed_point():
    s = specs.get("heat2d")
    u = jnp.full((9, 9), 3.25)
    out = ref.evolve_periodic(u, s, steps=5)
    np.testing.assert_allclose(np.asarray(out), 3.25, rtol=1e-14)


def test_step_rejects_wrong_rank():
    s = specs.get("heat2d")
    with pytest.raises(ValueError, match="2d"):
        ref.step(jnp.zeros((5,)), s)


def test_step_rejects_too_small():
    s = specs.get("star2d9p")  # r=2 needs > 4 per dim
    with pytest.raises(ValueError, match="too small"):
        ref.step(jnp.zeros((4, 4)), s)


@given(n=st.integers(5, 20), steps=st.integers(1, 3))
def test_block_shrinks_exactly(n, steps):
    s = specs.get("heat1d")
    u = jnp.zeros((n + 2 * s.radius * steps,))
    assert ref.block(u, s, steps).shape == (n,)


def test_linearity():
    """Stencil is linear: step(a*u + b*v) == a*step(u) + b*step(v)."""
    s = specs.get("box2d25p")
    rng = np.random.default_rng(10)
    u = jnp.asarray(rng.random((14, 14)))
    v = jnp.asarray(rng.random((14, 14)))
    lhs = ref.step(2.0 * u + 3.0 * v, s)
    rhs = 2.0 * ref.step(u, s) + 3.0 * ref.step(v, s)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-12)
