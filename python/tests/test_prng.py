"""SplitMix64 lockstep vectors (mirrored by rust/src/util/prng.rs tests)."""

import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from compile.prng import MASK, VECTORS_SEED42, SplitMix64


def test_seed42_vectors():
    rng = SplitMix64(42)
    assert [rng.next_u64() for _ in range(3)] == VECTORS_SEED42


def test_f64_range():
    rng = SplitMix64(7)
    xs = [rng.next_f64() for _ in range(1000)]
    assert all(0.0 <= x < 1.0 for x in xs)
    assert 0.4 < sum(xs) / len(xs) < 0.6


def test_fill_deterministic():
    a = SplitMix64(123).fill((4, 5))
    b = SplitMix64(123).fill((4, 5))
    np.testing.assert_array_equal(a, b)
    assert a.shape == (4, 5) and a.dtype == np.float64


def test_fill_row_major_order():
    flat = SplitMix64(9).fill((6,))
    grid = SplitMix64(9).fill((2, 3))
    np.testing.assert_array_equal(grid.reshape(-1), flat)


@given(seed=st.integers(0, 2**64 - 1))
def test_state_stays_64bit(seed):
    rng = SplitMix64(seed)
    for _ in range(5):
        assert 0 <= rng.next_u64() <= MASK
    assert 0 <= rng.state <= MASK


def test_distinct_seeds_distinct_streams():
    assert SplitMix64(1).next_u64() != SplitMix64(2).next_u64()
