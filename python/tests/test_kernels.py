"""Pallas kernels vs the pure-jnp oracle — the core correctness signal.

hypothesis sweeps shapes, tile factorizations, dtypes and step counts for
every benchmark kernel; assert_allclose against ref.py throughout.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import mxu_fold, ref
from compile.kernels import spec as specs
from compile.kernels import stencil_step, temporal_block

ALL = sorted(specs.BENCHMARKS)
TWO_D = [n for n in ALL if specs.get(n).ndim == 2]


def _rand(shape, dtype=np.float64, seed=0):
    return jnp.asarray(np.random.default_rng(seed).random(shape).astype(dtype))


# ---------------------------------------------------------------- step ----

@pytest.mark.parametrize("name", ALL)
def test_step_single_tile(name):
    s = specs.get(name)
    shape = tuple(12 + 2 * s.radius for _ in range(s.ndim))
    u = _rand(shape)
    np.testing.assert_allclose(
        np.asarray(stencil_step.stencil_step(u, s)),
        np.asarray(ref.step(u, s)),
        rtol=1e-12,
    )


@pytest.mark.parametrize("name", ALL)
def test_step_multi_tile(name):
    s = specs.get(name)
    core = tuple(12 for _ in range(s.ndim))
    u = _rand(tuple(n + 2 * s.radius for n in core), seed=1)
    got = stencil_step.stencil_step(u, s, tiles=tuple(4 for _ in core))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.step(u, s)), rtol=1e-12)


@given(core=st.integers(4, 24), tile=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 99))
def test_step_1d_sweep(core, tile, seed):
    s = specs.get("star1d5p")
    core = core - core % tile or tile
    u = _rand((core + 2 * s.radius,), seed=seed)
    got = stencil_step.stencil_step(u, s, tiles=(tile,))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.step(u, s)), rtol=1e-12)


@given(cx=st.sampled_from([4, 8, 12]), cy=st.sampled_from([4, 6, 10]),
       tx=st.sampled_from([2, 4]), seed=st.integers(0, 9))
def test_step_2d_sweep(cx, cy, tx, seed):
    s = specs.get("box2d9p")
    u = _rand((cx + 2 * s.radius, cy + 2 * s.radius), seed=seed)
    ty = 2 if cy % 2 == 0 else 1
    got = stencil_step.stencil_step(u, s, tiles=(tx if cx % tx == 0 else 1, ty))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.step(u, s)), rtol=1e-12)


@pytest.mark.parametrize("dtype,rtol", [(np.float32, 2e-5), (np.float64, 1e-12)])
def test_step_dtypes(dtype, rtol):
    s = specs.get("heat2d")
    u = _rand((18, 18), dtype=dtype, seed=3)
    got = stencil_step.stencil_step(u, s, tiles=(8, 8))
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.step(u, s)), rtol=rtol)


def test_step_rejects_bad_tiles():
    s = specs.get("heat2d")
    u = _rand((18, 18))
    with pytest.raises(ValueError, match="divisible"):
        stencil_step.stencil_step(u, s, tiles=(5, 8))


def test_step_rejects_small_input():
    s = specs.get("star2d9p")
    with pytest.raises(ValueError, match="too small"):
        stencil_step.stencil_step(jnp.zeros((4, 4)), s)


# ----------------------------------------------------------- temporal ----

@pytest.mark.parametrize("name", ALL)
@pytest.mark.parametrize("steps", [2, 3])
def test_temporal_block_matches_ref(name, steps):
    s = specs.get(name)
    core = tuple(8 for _ in range(s.ndim))
    u = _rand(tuple(n + 2 * s.radius * steps for n in core), seed=4)
    got = temporal_block.temporal_block(u, s, steps)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.block(u, s, steps)), rtol=1e-12
    )


@pytest.mark.parametrize("name", ["heat1d", "heat2d", "heat3d"])
def test_temporal_block_tiled(name):
    s = specs.get(name)
    steps = 2
    core = tuple(8 for _ in range(s.ndim))
    u = _rand(tuple(n + 2 * s.radius * steps for n in core), seed=5)
    got = temporal_block.temporal_block(u, s, steps, tiles=tuple(4 for _ in core))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.block(u, s, steps)), rtol=1e-12
    )


@given(steps=st.integers(1, 4), core=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 9))
def test_temporal_1d_sweep(steps, core, seed):
    s = specs.get("heat1d")
    u = _rand((core + 2 * s.radius * steps,), seed=seed)
    got = temporal_block.temporal_block(u, s, steps, tiles=(4,) if core % 4 == 0 else None)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.block(u, s, steps)), rtol=1e-12
    )


def test_temporal_step1_equals_step():
    s = specs.get("box2d25p")
    u = _rand((12 + 2 * s.radius, 12 + 2 * s.radius), seed=6)
    np.testing.assert_allclose(
        np.asarray(temporal_block.temporal_block(u, s, 1)),
        np.asarray(stencil_step.stencil_step(u, s)),
        rtol=1e-13,
    )


def test_temporal_rejects_zero_steps():
    s = specs.get("heat1d")
    with pytest.raises(ValueError, match="steps"):
        temporal_block.temporal_block(_rand((10,)), s, 0)


# ---------------------------------------------------------------- mxu ----

@pytest.mark.parametrize("name", TWO_D)
def test_mxu_matches_ref(name):
    s = specs.get(name)
    u = _rand((16 + 2 * s.radius, 12 + 2 * s.radius), seed=7)
    got = mxu_fold.mxu_fold(u, s, tile_m=8)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.step(u, s)), rtol=1e-12, atol=1e-13
    )


@pytest.mark.parametrize("name", TWO_D)
def test_mxu_block_matches_ref(name):
    s = specs.get(name)
    steps = 2
    u = _rand((8 + 2 * s.radius * steps, 8 + 2 * s.radius * steps), seed=8)
    got = mxu_fold.mxu_fold_block(u, s, steps)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.block(u, s, steps)), rtol=1e-12, atol=1e-13
    )


@given(nx=st.sampled_from([8, 16]), ny=st.sampled_from([6, 10, 12]),
       seed=st.integers(0, 9))
def test_mxu_sweep(nx, ny, seed):
    s = specs.get("box2d25p")
    u = _rand((nx + 2 * s.radius, ny + 2 * s.radius), seed=seed)
    got = mxu_fold.mxu_fold(u, s, tile_m=8 if nx % 8 == 0 else None)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.step(u, s)), rtol=1e-12, atol=1e-13
    )


def test_band_matrix_structure():
    """B_dx[j + r + dy, j] == c[(dx, dy)] and zero elsewhere."""
    s = specs.get("box2d9p")
    ny, r = 7, s.radius
    bands = mxu_fold.band_matrices(s, ny)
    assert bands.shape == (2 * r + 1, ny + 2 * r, ny)
    for (dx, dy), c in s.coeffs.items():
        for j in range(ny):
            assert bands[dx + r, j + r + dy, j] == pytest.approx(c)
    # total mass: each column of the full stack sums to sum(coeffs) == 1
    col = bands.sum(axis=(0, 1))
    np.testing.assert_allclose(col, 1.0, rtol=1e-12)


def test_mxu_star_band_sparsity():
    """Star stencils: off-center slabs carry exactly one diagonal."""
    s = specs.get("star2d9p")
    bands = mxu_fold.band_matrices(s, 6)
    r = s.radius
    for dx in range(-r, r + 1):
        nnz = np.count_nonzero(bands[dx + r])
        if dx == 0:
            assert nnz > 6  # center slab holds the full y-arm
        else:
            assert nnz == 6  # single diagonal (dy = 0)


def test_mxu_rejects_1d():
    s = specs.get("heat1d")
    with pytest.raises(ValueError, match="2D"):
        mxu_fold.mxu_fold(_rand((10, 10)), s)
