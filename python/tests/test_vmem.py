"""Analytical VMEM / MXU estimator invariants."""

import pytest
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import vmem
from compile.kernels import spec as specs


def test_step_estimate_counts_window():
    s = specs.get("heat2d")
    est = vmem.step_estimate(s, (64, 64))
    assert est.vmem_bytes == ((66 * 66) + 2 * 64 * 64) * 8
    assert est.flops_per_cell == s.flops_per_cell
    assert est.mxu_utilization == 0.0
    assert est.fits()


def test_temporal_estimate_amortizes_hbm():
    s = specs.get("heat2d")
    one = vmem.temporal_estimate(s, (64, 64), 1)
    eight = vmem.temporal_estimate(s, (64, 64), 8)
    # Tb x flops per cell but ~same HBM traffic per block
    assert eight.flops_per_cell == 8 * one.flops_per_cell
    assert eight.hbm_bytes_per_cell < 2 * one.hbm_bytes_per_cell
    assert eight.arithmetic_intensity > 4 * one.arithmetic_intensity


def test_temporal_estimate_vmem_grows_with_tb():
    s = specs.get("box2d25p")
    assert (
        vmem.temporal_estimate(s, (32, 32), 4).vmem_bytes
        > vmem.temporal_estimate(s, (32, 32), 1).vmem_bytes
    )


def test_mxu_estimate_utilization_bounds():
    s = specs.get("box2d25p")
    est = vmem.mxu_estimate(s, 128, 128)
    assert 0.0 < est.mxu_utilization <= 1.0
    # box 5x5: 25 useful taps vs 5 slabs x (ny+2r) issued rows
    assert est.mxu_utilization == pytest.approx(
        (50 * 128 * 128) / (5 * 128 * (128 + 4) * 128 * 2), rel=1e-12
    )


def test_mxu_star_beats_box_utilization():
    star = vmem.mxu_estimate(specs.get("star2d9p"), 128, 128)
    box = vmem.mxu_estimate(specs.get("box2d25p"), 128, 128)
    # star issues fewer dense slabs relative to taps? both reported sanely
    assert 0 < star.mxu_utilization < 1
    assert 0 < box.mxu_utilization < 1


@given(tile=st.sampled_from([16, 32, 64, 128]), steps=st.integers(1, 8))
def test_estimates_positive(tile, steps):
    s = specs.get("heat2d")
    est = vmem.temporal_estimate(s, (tile, tile), steps)
    assert est.vmem_bytes > 0
    assert est.arithmetic_intensity > 0


def test_pick_tiles_fits_and_divides():
    s = specs.get("heat2d")
    core = (2048, 2048)
    tiles = vmem.pick_tiles(s, core, steps=4)
    assert all(c % t == 0 for c, t in zip(core, tiles))
    assert vmem.temporal_estimate(s, tiles, 4).fits()


def test_pick_tiles_small_core_unchanged():
    s = specs.get("heat1d")
    assert vmem.pick_tiles(s, (4096,), steps=4) == (4096,)
