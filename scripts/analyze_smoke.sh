#!/usr/bin/env bash
# Static race-analysis gate for CI: build the release binary, run the
# full `tetris analyze --all` sweep (pipelined-window plans across
# boundary x grid shape (Wy x Wx) x partition/band layout x fields x
# window length x window parity, plus the tetris-wave DAGs) and fail on
# any reported race.  An explicit grid matrix then re-walks Wy x Wx in
# {1,2} x {1..3} — every boundary, both window parities — through the
# single-config path, so a regression in one grid shape names itself.
# Then prove the detector actually detects: `tetris analyze
# --inject-race` drops one writeback -> assemble edge from a known plan
# and MUST exit nonzero while reporting an unordered conflict.
set -euo pipefail

cd "$(dirname "$0")/.."

BIN=rust/target/release/tetris

# Always (re)build: incremental with a warm target dir, and it protects
# against driving a stale cache-restored binary.
cargo build --release --manifest-path rust/Cargo.toml

echo "== tetris analyze --all =="
"$BIN" analyze --all

echo "== grid matrix: Wy x Wx in {1,2} x {1..3} =="
for wy in 1 2; do
  for wx in 1 2 3; do
    echo "-- grid ${wy}x${wx} --"
    "$BIN" analyze --bench heat2d --grid "${wy}x${wx}" \
      --boundary dirichlet:0,neumann,periodic
  done
done

echo "== negative path: injected race must be detected =="
out=$(mktemp)
if "$BIN" analyze --inject-race >"$out" 2>&1; then
    echo "FAIL: 'tetris analyze --inject-race' must exit nonzero" >&2
    cat "$out" >&2
    rm -f "$out"
    exit 1
fi
if ! grep -q "no ordering path" "$out"; then
    echo "FAIL: injected race was not reported as an unordered conflict" >&2
    cat "$out" >&2
    rm -f "$out"
    exit 1
fi
cat "$out"
rm -f "$out"
echo "analyze smoke OK: sweep clean, injected race detected"
