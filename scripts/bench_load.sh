#!/usr/bin/env bash
# Load-harness driver for CI: build the release binary, then run a short
# deterministic Suite A and a 30s stochastic Suite B rung through
# `tetris load`, which spawns the release `tetris serve` as a *separate
# OS process* and drives it over TCP (nothing in-process — this measures
# the real socket path).  Emits single-line JSON reports
# BENCH_serve_suiteA.json / BENCH_serve_suiteB.json with queue/service/
# total latency percentiles up to p99.9, reject counts + retry_after_ms
# hint stats, goodput vs offered load, per-rung server METRICS snapshots
# (flat layer.metric registry dumps; bench check enforces monotone
# _total counters and the queue-depth <= capacity gauge bound), and
# /proc RSS+CPU samples of the server process.  The Suite B rung also
# arms the spawned server's --metrics-scrape (one flat snapshot per
# second appended to BENCH_serve_scrape.jsonl) and retries retryable
# rejects with --retry.  Everything is then gated with `tetris bench
# check`, the scrape file included (strictly increasing ts_ms, monotone
# _total counters line to line).
set -euo pipefail

cd "$(dirname "$0")/.."

SCALE="${TETRIS_LOAD_SCALE:-0.05}"
THREADS="${TETRIS_LOAD_THREADS:-1}"
SEED="${TETRIS_LOAD_SEED:-4242}"
CONNS="${TETRIS_LOAD_CONNS:-4}"
JOBS="${TETRIS_LOAD_JOBS:-25}"
RATE="${TETRIS_LOAD_RATE:-40}"
DURATION="${TETRIS_LOAD_DURATION:-30}"
ZIPF="${TETRIS_LOAD_ZIPF:-1.1}"
RETRY="${TETRIS_LOAD_RETRY:-2}"
A_OUT="${TETRIS_LOAD_A_OUT:-BENCH_serve_suiteA.json}"
B_OUT="${TETRIS_LOAD_B_OUT:-BENCH_serve_suiteB.json}"
SCRAPE_OUT="${TETRIS_LOAD_SCRAPE_OUT:-BENCH_serve_scrape.jsonl}"
BIN=rust/target/release/tetris

# Always (re)build: incremental with a warm target dir, and it protects
# against driving a stale cache-restored binary.
cargo build --release --manifest-path rust/Cargo.toml

# Suite A: deterministic closed-loop baseline (seeded job order, fixed
# concurrency well under the admission queue — zero rejects expected,
# and bench-check enforces that).
"$BIN" load suiteA --scale "$SCALE" --threads "$THREADS" --seed "$SEED" \
  --conns "$CONNS" --jobs "$JOBS" --json-a "$A_OUT"

# Suite B: one 30s open-loop rung — seeded Poisson arrivals over the
# zipfian job mix, retryable rejects obeyed with capped jittered backoff
# (--retry), and the spawned server's periodic metrics scrape armed
# (append-only JSONL; wiped first so reruns start fresh).  (Pass --sweep
# via TETRIS_LOAD_EXTRA to walk rates to saturation locally; CI keeps
# the single calibrated rung.)
rm -f "$SCRAPE_OUT"
# shellcheck disable=SC2086
"$BIN" load suiteB --scale "$SCALE" --threads "$THREADS" --seed "$SEED" \
  --rate "$RATE" --duration "$DURATION" --zipf "$ZIPF" --retry "$RETRY" \
  --metrics-scrape "$SCRAPE_OUT:1" \
  --json-b "$B_OUT" ${TETRIS_LOAD_EXTRA:-}

# Fail fast on structurally broken reports (the CI job re-runs this
# gate as its own step, but local runs should see it too).  The scrape
# JSONL rides through the same gate: strictly increasing ts_ms,
# monotone _total counters across snapshots.  The p99.9 bound is
# deliberately generous (20x the first rung) — it exists to catch
# pathological tail blowups, not to gate honest saturation noise.
"$BIN" bench check "$A_OUT" "$B_OUT" "$SCRAPE_OUT" --p999-degrade-max 20

for f in "$A_OUT" "$B_OUT"; do
  echo "--- $f ---"
  cat "$f"
done
echo "--- $SCRAPE_OUT: $(wc -l < "$SCRAPE_OUT") snapshots ---"
head -n 2 "$SCRAPE_OUT"
