#!/usr/bin/env bash
# Smoke bench: run the Fig-12 breakdown at a tiny scale and emit a
# single-line JSON summary (BENCH_smoke.json) so CI can archive the
# bench trajectory on every commit.
set -euo pipefail

cd "$(dirname "$0")/.."

SCALE="${TETRIS_SMOKE_SCALE:-0.1}"
THREADS="${TETRIS_SMOKE_THREADS:-2}"
OUT="${TETRIS_SMOKE_OUT:-BENCH_smoke.json}"
BIN=rust/target/release/tetris

# Always (re)build: with a warm target dir this is incremental and fast,
# and it protects against running a stale cache-restored binary.
cargo build --release --manifest-path rust/Cargo.toml

"$BIN" bench breakdown --scale "$SCALE" --threads "$THREADS" --json "$OUT"

echo "--- $OUT ---"
cat "$OUT"
