#!/usr/bin/env bash
# Smoke bench: run the Fig-12 breakdown, the boundary/adaptive scheduler
# study, the serving-layer study and the §5.3 overlap study at a tiny
# scale and emit single-line JSON summaries (BENCH_smoke.json,
# BENCH_boundary.json, BENCH_serve.json, BENCH_overlap.json) so CI can
# archive the bench trajectory every commit.  Then boot a real
# `tetris serve` on a loopback port, drive 20 mixed-boundary jobs through
# `tetris submit`, and archive the client-side jobs/sec + p99 as
# BENCH_serve_live.json.
set -euo pipefail

cd "$(dirname "$0")/.."

SCALE="${TETRIS_SMOKE_SCALE:-0.1}"
THREADS="${TETRIS_SMOKE_THREADS:-2}"
OUT="${TETRIS_SMOKE_OUT:-BENCH_smoke.json}"
BOUNDARY_OUT="${TETRIS_SMOKE_BOUNDARY_OUT:-BENCH_boundary.json}"
SERVE_OUT="${TETRIS_SMOKE_SERVE_OUT:-BENCH_serve.json}"
SERVE_LIVE_OUT="${TETRIS_SMOKE_SERVE_LIVE_OUT:-BENCH_serve_live.json}"
OVERLAP_OUT="${TETRIS_SMOKE_OVERLAP_OUT:-BENCH_overlap.json}"
OVERLAP_OFF_OUT="${TETRIS_SMOKE_OVERLAP_OFF_OUT:-BENCH_overlap_off.json}"
OVERLAP_ON_OUT="${TETRIS_SMOKE_OVERLAP_ON_OUT:-BENCH_overlap_on.json}"
OVERLAP_TRACE_OFF_OUT="${TETRIS_SMOKE_OVERLAP_TRACE_OFF_OUT:-BENCH_overlap_trace_off.json}"
OVERLAP_TRACE_ON_OUT="${TETRIS_SMOKE_OVERLAP_TRACE_ON_OUT:-BENCH_overlap_trace_on.json}"
GRID_OUT="${TETRIS_SMOKE_GRID_OUT:-BENCH_grid.json}"
PLAN_OUT="${TETRIS_SMOKE_PLAN_OUT:-BENCH_plan.json}"
PLAN_STORE_OUT="${TETRIS_SMOKE_PLAN_STORE_OUT:-BENCH_plans.jsonl}"
BIN=rust/target/release/tetris

# Always (re)build: with a warm target dir this is incremental and fast,
# and it protects against running a stale cache-restored binary.
cargo build --release --manifest-path rust/Cargo.toml

"$BIN" bench breakdown --scale "$SCALE" --threads "$THREADS" --json "$OUT"

# One periodic + one adaptive rung (plus dirichlet/neumann baselines and
# the O(surface) ghost-fill micro-bench).
"$BIN" bench boundary --scale "$SCALE" --threads "$THREADS" --json "$BOUNDARY_OUT"

# Serving-layer study: session batching (jobs/sec at batch widths 1/4/8
# on the same job mix — batched must beat unbatched) + a TCP loopback
# drive with p99, all in-process.
"$BIN" bench serve --scale "$SCALE" --threads "$THREADS" --json "$SERVE_OUT"

# 2-D worker-grid study: the same 4-worker heat2d run as a flat 1x4 row
# split vs a 2x2 tile grid.  The rows carry halo_bytes= in parseable
# form; `bench check` asserts the 2-D rung ships fewer halo bytes than
# the 1-D split at W >= 4 (the perimeter-over-area claim) and the run
# itself asserts the two shapes stay bit-identical.
"$BIN" bench grid --scale "$SCALE" --threads "$THREADS" --json "$GRID_OUT"
"$BIN" bench check "$GRID_OUT"

# §5.3 overlap study: the pipelined (double-buffered) leader loop vs the
# serial one on an imbalanced 2-worker run — summed worker idle and the
# leader time hidden under compute, tracked per commit.  The combined
# two-row run feeds the idle invariant in bench check.
"$BIN" bench overlap --scale "$SCALE" --threads "$THREADS" --json "$OVERLAP_OUT"

# Per-mode reruns with tracing: each mode gets its own span trace (pool
# tasks, pipelined assemble/compute/writeback chains + flow events,
# leader phases with bytes/rows args) so the two can be diffed.
"$BIN" bench overlap --mode off --scale "$SCALE" --threads "$THREADS" \
  --json "$OVERLAP_OFF_OUT" --trace "$OVERLAP_TRACE_OFF_OUT"
"$BIN" bench overlap --mode on --scale "$SCALE" --threads "$THREADS" \
  --json "$OVERLAP_ON_OUT" --trace "$OVERLAP_TRACE_ON_OUT"

# Gate 1 — structural: balanced spans, monotone timestamps, pipeline
# task ids within the analyze-model universe, flow pairing.  The
# pipelined trace must actually carry flow events (--require-flows).
"$BIN" trace check "$OVERLAP_TRACE_OFF_OUT"
"$BIN" trace check "$OVERLAP_TRACE_ON_OUT" --require-flows

# Gate 2 — trace diff: the pipelined run must show leader time moving
# into pipelined spans (pipeline/* phases exclusive to overlap=on);
# --fail-over is a generous sanity ceiling on shared-phase growth.
DIFF_OUT="$(mktemp)"
"$BIN" trace diff "$OVERLAP_TRACE_OFF_OUT" "$OVERLAP_TRACE_ON_OUT" \
  --fail-over 500 | tee "$DIFF_OUT"
grep -E '^pipeline/(assemble|compute|writeback): only in B' "$DIFF_OUT" >/dev/null || {
  echo "trace diff shows no pipelined spans exclusive to overlap=on" >&2
  exit 1
}
rm -f "$DIFF_OUT"

# Gate 3 — evidence reconciliation: hidden leader time recomputed from
# the trace (pipeline assemble/writeback durations that end inside a
# compute span) must agree with RunMetrics.overlap_hidden within 15%.
"$BIN" trace hidden "$OVERLAP_TRACE_ON_OUT" \
  --bench-json "$OVERLAP_ON_OUT" --tolerance-pct 15

# Plan/autotune study: tune heat2d against a throwaway store (budgeted
# search, seeded for reproducible trial ordering), then the auto-vs-
# fixed-engine rows — heat2d warm-starts/hits the freshly tuned plan,
# heat3d tunes cold and persists.  The store itself is archived next to
# the JSON summaries so the chosen plans have a tracked trajectory.
PLAN_STORE="$(mktemp)"
"$BIN" tune --bench heat2d --budget-ms 500 --seed 1 --plan-store "$PLAN_STORE"
"$BIN" bench plan --scale "$SCALE" --threads "$THREADS" \
  --plan-store "$PLAN_STORE" --json "$PLAN_OUT"
cp "$PLAN_STORE" "$PLAN_STORE_OUT"
rm -f "$PLAN_STORE"

# Live loopback drive through the real server binary: boot `tetris
# serve` on an ephemeral port, push 20 mixed-boundary jobs via `tetris
# submit`, archive client-side jobs/sec + p99, then drain cleanly.
ADDR_FILE="$(mktemp)"
# --plan-store none keeps the smoke drive hermetic: without it the
# server would write observed smoke-scale plans into the user store.
"$BIN" serve --addr 127.0.0.1:0 --workers 2 --queue 64 \
  --scale "$SCALE" --threads "$THREADS" --addr-file "$ADDR_FILE" \
  --plan-store none &
SERVE_PID=$!
trap 'kill "$SERVE_PID" 2>/dev/null || true' EXIT
for _ in $(seq 1 100); do
  [ -s "$ADDR_FILE" ] && break
  sleep 0.1
done
ADDR="$(cat "$ADDR_FILE")"
[ -n "$ADDR" ] || { echo "tetris serve never published its address" >&2; exit 1; }
"$BIN" submit --addr "$ADDR" --bench heat2d \
  --boundary dirichlet:25,neumann,periodic --steps 8 --jobs 20 \
  --json "$SERVE_LIVE_OUT"
"$BIN" submit --addr "$ADDR" --stats
"$BIN" submit --addr "$ADDR" --shutdown
wait "$SERVE_PID"
trap - EXIT
rm -f "$ADDR_FILE"

for f in "$OUT" "$BOUNDARY_OUT" "$GRID_OUT" "$SERVE_OUT" "$OVERLAP_OUT" "$OVERLAP_OFF_OUT" "$OVERLAP_ON_OUT" "$SERVE_LIVE_OUT" "$PLAN_OUT" "$PLAN_STORE_OUT"; do
  echo "--- $f ---"
  cat "$f"
done
for f in "$OVERLAP_TRACE_OFF_OUT" "$OVERLAP_TRACE_ON_OUT"; do
  echo "--- $f: $(wc -c < "$f") bytes (Chrome trace-event JSON, load in Perfetto) ---"
done
