#!/usr/bin/env bash
# Smoke bench: run the Fig-12 breakdown plus the boundary/adaptive
# scheduler study at a tiny scale and emit single-line JSON summaries
# (BENCH_smoke.json, BENCH_boundary.json) so CI can archive the bench
# trajectory — including the periodic and adaptive paths — every commit.
set -euo pipefail

cd "$(dirname "$0")/.."

SCALE="${TETRIS_SMOKE_SCALE:-0.1}"
THREADS="${TETRIS_SMOKE_THREADS:-2}"
OUT="${TETRIS_SMOKE_OUT:-BENCH_smoke.json}"
BOUNDARY_OUT="${TETRIS_SMOKE_BOUNDARY_OUT:-BENCH_boundary.json}"
BIN=rust/target/release/tetris

# Always (re)build: with a warm target dir this is incremental and fast,
# and it protects against running a stale cache-restored binary.
cargo build --release --manifest-path rust/Cargo.toml

"$BIN" bench breakdown --scale "$SCALE" --threads "$THREADS" --json "$OUT"

# One periodic + one adaptive rung (plus dirichlet/neumann baselines and
# the O(surface) ghost-fill micro-bench).
"$BIN" bench boundary --scale "$SCALE" --threads "$THREADS" --json "$BOUNDARY_OUT"

for f in "$OUT" "$BOUNDARY_OUT"; do
  echo "--- $f ---"
  cat "$f"
done
