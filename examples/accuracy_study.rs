//! Accuracy study (paper Table 4): FP64 vs FP32 on the same long thermal
//! evolution, bucketed per-cell deviations.
//!
//! Run: `cargo run --release --example accuracy_study`
//! Env: TETRIS_ACC_BLOCKS (Tb-blocks to evolve; default 50).

use tetris::apps::accuracy;
use tetris::runtime::XlaService;

fn main() -> tetris::util::error::Result<()> {
    let svc = XlaService::spawn_default().ok();
    let blocks: usize = std::env::var("TETRIS_ACC_BLOCKS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(50);
    let n = svc
        .as_ref()
        .and_then(|s| s.manifest().thermal_core.first().copied())
        .unwrap_or(96);

    let rep = accuracy::run_accuracy(svc.as_ref(), n, blocks)?;
    println!(
        "== Table 4: FP64 vs FP32 after {} steps on {n}x{n} ({}) ==",
        rep.steps,
        if rep.used_artifacts { "PJRT artifacts" } else { "rust fallback" }
    );
    println!("{:<20} {:>9} {:>11} {:>9}", "deviation", "<0.1°C", "0.1-1.0°C", ">1.0°C");
    println!(
        "{:<20} {:>8.1}% {:>10.1}% {:>8.1}%",
        "Tetris FP64 (ref)", 100.0, 0.0, 0.0
    );
    println!(
        "{:<20} {:>8.1}% {:>10.1}% {:>8.1}%",
        "FP32 pipeline", rep.fp32_buckets[0], rep.fp32_buckets[1], rep.fp32_buckets[2]
    );
    println!(
        "\nmax |FP64 - FP32| = {:.4} °C, mean drift = {:.6} °C",
        rep.fp64.max_abs_diff(&rep.fp32),
        (rep.fp64.mean() - rep.fp32.mean()).abs()
    );
    // The paper's point: FP32 deviations are NOT ignorable on long
    // evolutions (they report 73.1% of cells off by >= 0.1 °C at 3.8e6
    // steps; scaled runs show the same monotone drift).
    Ok(())
}
