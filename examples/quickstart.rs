//! Quickstart: the public API in ~40 lines.
//!
//! Builds the Heat-2D dwarf, runs the optimized Tetris (CPU) engine,
//! checks it against the reference oracle, and — if `make artifacts` has
//! run — executes the same computation through the AOT-compiled PJRT
//! artifact (the accelerator path).
//!
//! Run: `cargo run --release --example quickstart`

use tetris::engine;
use tetris::runtime::XlaService;
use tetris::stencil::{reference, spec, Field};

fn main() -> tetris::util::error::Result<()> {
    // 1. Pick a stencil dwarf from the paper's Table-1 suite.
    let heat2d = spec::get("heat2d").expect("built-in benchmark");
    println!("dwarf: {} ({} points, radius {})", heat2d.name, heat2d.points(), heat2d.radius);

    // 2. Make a domain with a ghost ring for 4 fused steps (valid mode).
    let steps = 4;
    let halo = heat2d.halo(steps);
    let core = [256usize, 256];
    let input = Field::random(&[core[0] + 2 * halo, core[1] + 2 * halo], 42);

    // 3. Run the optimized engine (tessellate tiling + skewed swizzling).
    let eng = engine::by_name("tetris-cpu", 2).unwrap();
    let t0 = std::time::Instant::now();
    let out = eng.block(&heat2d, &input, steps);
    let dt = t0.elapsed();

    // 4. Verify against the naive oracle.
    let want = reference::block(&input, &heat2d, steps);
    assert!(out.allclose(&want, 1e-12, 1e-14), "engine disagrees with oracle!");
    let gst = (core[0] * core[1] * steps) as f64 / dt.as_secs_f64() / 1e9;
    println!("tetris-cpu: {steps} steps on {core:?} in {dt:?} ({gst:.3} GStencils/s) — verified");

    // 5. Same computation through the AOT PJRT artifact, if built.
    match XlaService::spawn_default() {
        Ok(svc) => {
            let meta = svc.meta("heat2d_block")?.clone();
            let unit_in = Field::random(&meta.input_shape, 7);
            let xla_out = svc.run("heat2d_block", &unit_in)?;
            let oracle = reference::block(&unit_in, &heat2d, meta.steps);
            assert!(xla_out.allclose(&oracle, 1e-12, 1e-14));
            println!(
                "xla artifact {}: {:?} -> {:?} — verified against the oracle",
                meta.name, meta.input_shape, meta.output_shape
            );
        }
        Err(e) => println!("(skipping PJRT path: {e}; run `make artifacts`)"),
    }
    Ok(())
}
