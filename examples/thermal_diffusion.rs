//! End-to-end driver (paper §6.5, Table 3, Fig. 16): thermal diffusion on
//! a square copper plate through the FULL stack — Pallas-lowered AOT
//! artifacts executed by the PJRT runtime, the native Tetris (CPU)
//! engine, and the auto-tuned heterogeneous scheduler coordinating both.
//!
//! Reports the Table-3 rows (time, GStencils/s, speedup vs naive) and
//! writes the Fig-16 heatmaps (before/after + FP32 error map) to
//! `out/thermal/`.  The run is recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example thermal_diffusion`
//! Flags via env: TETRIS_THERMAL_SIZE (default 384: must match artifacts),
//! TETRIS_THERMAL_BLOCKS (default 40 Tb-blocks), TETRIS_THREADS.

use tetris::apps::{accuracy, thermal, viz};
use tetris::runtime::XlaService;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> tetris::util::error::Result<()> {
    let svc = XlaService::spawn_default().ok();
    if svc.is_none() {
        println!("NOTE: no AOT artifacts (run `make artifacts`); CPU rows only.\n");
    }
    let tb = svc.as_ref().map(|s| s.manifest().thermal_tb).unwrap_or(8);
    let size = env_usize(
        "TETRIS_THERMAL_SIZE",
        svc.as_ref()
            .and_then(|s| s.manifest().thermal_core.first().copied())
            .unwrap_or(384),
    );
    let blocks = env_usize("TETRIS_THERMAL_BLOCKS", 40);
    let threads = env_usize("TETRIS_THREADS", 2);
    let steps = blocks * tb;

    println!("== Thermal diffusion case study: {size}x{size} plate, {steps} steps (Tb={tb}) ==\n");
    let (rows, fields) = thermal::run_table3(svc.as_ref(), size, steps, tb, threads)?;

    println!("--- Table 3 ---");
    println!(
        "{:<14} {:>10} {:>14} {:>9} {:>11} {:>14}",
        "method", "time(s)", "GStencils/s", "speedup", "center(°C)", "maxdiff(naive)"
    );
    for r in &rows {
        println!(
            "{:<14} {:>10.3} {:>14.4} {:>8.2}x {:>11.2} {:>14.2e}",
            r.method, r.seconds, r.gstencils, r.speedup, r.final_center, r.max_diff_vs_naive
        );
    }

    // All methods must agree with the naive run to FP64 tolerance —
    // "while preserving the original accuracy".
    for r in &rows[1..] {
        tetris::ensure!(
            r.max_diff_vs_naive < 1e-9,
            "{} diverged from naive by {}",
            r.method,
            r.max_diff_vs_naive
        );
    }

    // Fig. 16 visualizations.
    let dir = "out/thermal";
    std::fs::create_dir_all(dir)?;
    let init = thermal::gaussian_plate(size);
    viz::save_heatmap(&init, thermal::AMBIENT, thermal::PEAK, format!("{dir}/fig16a_before.ppm"))?;
    if let Some((name, last)) = fields.last() {
        viz::save_heatmap(last, thermal::AMBIENT, thermal::PEAK, format!("{dir}/fig16b_after.ppm"))?;
        println!("\nFig.16(a)(b): wrote {dir}/fig16a_before.ppm, {dir}/fig16b_after.ppm ({name})");
    }

    // Fig. 16(c)(d): FP32 run + error map (artifacts only; small fallback
    // otherwise).
    let acc_n = if svc.is_some() { size } else { 96 };
    let rep = accuracy::run_accuracy(svc.as_ref(), acc_n, blocks.min(25))?;
    viz::save_heatmap(&rep.fp32, thermal::AMBIENT, thermal::PEAK, format!("{dir}/fig16c_fp32.ppm"))?;
    viz::save_error_map(&rep.fp64, &rep.fp32, 0.1, format!("{dir}/fig16d_error.ppm"))?;
    println!("Fig.16(c)(d): wrote {dir}/fig16c_fp32.ppm, {dir}/fig16d_error.ppm");
    println!(
        "FP32 deviation buckets after {} steps: <0.1°C {:.1}%, 0.1-1.0°C {:.1}%, >1.0°C {:.1}%",
        rep.steps, rep.fp32_buckets[0], rep.fp32_buckets[1], rep.fp32_buckets[2]
    );
    Ok(())
}
