//! Heterogeneous *serving* demo (paper §5 behind a service): boot the
//! real `tetris serve` server in-process, then act as a client — submit
//! a pipelined stream of boundary-diverse jobs over TCP, read the
//! in-order replies, inspect `STATS` (queue depths, per-session cached
//! partition shares, latency percentiles), and shut the server down
//! cleanly (admission stops, the dispatchers drain, the listener
//! closes).
//!
//! The server's default worker factory uses the AOT artifact worker
//! when compatible artifacts exist and **falls back to two native
//! workers with a warning otherwise**, so this example runs fine in an
//! artifact-less container:
//!
//! Run: `cargo run --release --example hetero_serving`

use tetris::serve::{default_worker_factory, Client, JobSpec, Priority, ServeConfig, Server};
use tetris::stencil::Boundary;

fn main() -> tetris::util::error::Result<()> {
    // A small default scale keeps the demo snappy; jobs could also pick
    // their own shapes per request.
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        dispatchers: 2,
        scale: 0.1,
        ..Default::default()
    };
    let handle = Server::start(cfg, default_worker_factory(2))?;
    println!("tetris serve: listening on {}", handle.addr);

    let mut client = Client::connect(handle.addr)?;
    let jobs: [(&str, Boundary, Priority); 4] = [
        ("ambient-plate", Boundary::Dirichlet(25.0), Priority::Interactive),
        ("cold-wall-plate", Boundary::Dirichlet(0.0), Priority::Normal),
        ("insulated-plate", Boundary::Neumann, Priority::Normal),
        ("torus", Boundary::Periodic, Priority::Batch),
    ];

    // Pipeline the whole stream, then read the in-order replies: equal
    // back-to-back specs coalesce into one multi-field dispatch.
    for (i, (label, boundary, priority)) in jobs.into_iter().enumerate() {
        client.send_spec(&JobSpec {
            id: label.to_string(),
            bench: "heat2d".into(),
            boundary,
            steps: 8,
            priority,
            seed: 100 + i as u64,
            ..Default::default()
        })?;
    }
    for _ in 0..jobs.len() {
        let r = client.recv_result()?;
        if r.ok {
            println!(
                "job {:16} ok: {} x{} steps, mean {:.6}, batch {}, queue {:.2}ms, \
                 exec {:.2}ms, session shares {:?}",
                r.id, r.boundary, r.steps, r.mean, r.batch_size, r.queue_ms, r.exec_ms, r.shares
            );
        } else {
            println!("job {:16} FAILED: {}", r.id, r.error.as_deref().unwrap_or("unknown"));
        }
    }

    let stats = client.stats()?;
    println!(
        "stats: {} submitted, {} completed, {} batches, p99 {} ms",
        stats.at(&["stats", "submitted"]),
        stats.at(&["stats", "completed"]),
        stats.at(&["stats", "batches"]),
        stats.at(&["stats", "latency", "p99_ms"])
    );
    if let Some(sessions) = stats.at(&["sessions"]).as_obj() {
        for (key, s) in sessions {
            println!(
                "session {key}: shares {}, jobs {}, cache hits {}, invalidations {}",
                s.at(&["shares"]),
                s.at(&["jobs"]),
                s.at(&["cache_hits"]),
                s.at(&["invalidations"])
            );
        }
    }

    println!("shutdown ack: {}", client.shutdown()?);
    handle.join(); // admission stopped, queue drained, listener closed
    println!("server drained and stopped");
    Ok(())
}
