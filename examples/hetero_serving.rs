//! Heterogeneous scheduling demo (paper §5, Fig. 11 + Fig. 14 ratios):
//! drive a stream of stencil evolution jobs through the concurrent
//! scheduler, showing profile-initialized partitioning, the in-run §5.2
//! auto-tuner (`adapt_every`), memory squeezing under a constrained
//! "device", boundary-condition diversity (each job picks its physics:
//! ambient Dirichlet plate, insulated Neumann plate, Periodic torus),
//! and the centralized-communication accounting.
//!
//! Run: `make artifacts && cargo run --release --example hetero_serving`

use tetris::coordinator::{
    partition::capacity_units, tuner, CommModel, NativeWorker, Partition, Scheduler, Worker,
    XlaWorker,
};
use tetris::runtime::XlaService;
use tetris::stencil::{spec, Boundary, Field};

fn main() -> tetris::util::error::Result<()> {
    let svc = XlaService::spawn_default()
        .map_err(|e| tetris::err!("this example needs artifacts (`make artifacts`): {e}"))?;
    let bench = "heat2d";
    let meta = svc.bench(bench)?.clone();
    let s = spec::get(bench).unwrap();
    let halo = s.radius * meta.tb;
    let rest_cells: usize = meta.global_core[1..].iter().map(|n| n + 2 * halo).product();

    // Two heterogeneous workers; the "device" (XLA) capacity is squeezed
    // to force bidirectional spill (paper §5.1).
    let device_cap = 5 * 3 * meta.unit * rest_cells * 8; // ~5 units
    let workers = make_workers(&svc, bench, device_cap)?;

    // §5.2 profile initialization.
    let unit_core: Vec<usize> = std::iter::once(meta.unit)
        .chain(meta.global_core[1..].iter().copied())
        .collect();
    let prof = tuner::profile_workers(&workers, &s, &unit_core, meta.tb, 3)?;
    println!("startup profile (s/unit-block): native={:.4} xla={:.4}", prof[0], prof[1]);

    let units = meta.global_core[0] / meta.unit;
    let caps: Vec<usize> = workers
        .iter()
        .map(|w| capacity_units(w.mem_capacity(), meta.unit, rest_cells))
        .collect();
    println!("capacity (units): native={} xla={} (device squeezed)", caps[0], caps[1]);
    let weights: Vec<f64> = prof.iter().map(|t| 1.0 / t).collect();
    let mut partition = Partition::balanced(meta.unit, units, &weights, &caps);
    println!(
        "initial partition: native={} xla={} units (xla ratio {:.1}%)",
        partition.shares[0],
        partition.shares[1],
        partition.ratio(1) * 100.0
    );

    // Serve a stream of jobs with per-job physics; the scheduler retunes
    // itself mid-run (adapt_every) and the converged partition carries
    // over to the next job — the serving-loop version of §5.2.
    let comm_model = CommModel::default();
    let jobs: [(&str, Boundary); 4] = [
        ("ambient plate", Boundary::Dirichlet(25.0)),
        ("cold-wall plate", Boundary::Dirichlet(0.0)),
        ("insulated plate", Boundary::Neumann),
        ("torus", Boundary::Periodic),
    ];
    for (job, (label, boundary)) in jobs.into_iter().enumerate() {
        let sched = Scheduler {
            spec: s.clone(),
            tb: meta.tb,
            workers: make_workers(&svc, bench, device_cap)?,
            partition: partition.clone(),
            comm_model,
            boundary,
            adapt_every: 2,
        };
        let core = Field::random(&meta.global_core, 100 + job as u64);
        let steps = meta.tb * 4;
        let (out, metrics) = sched.run(&core, steps)?;
        println!(
            "\njob {job} ({label}, boundary={boundary}): {} steps, {:.4} GStencils/s, \
             bubble {:.1}%, retunes {}, out mean {:.6}",
            steps,
            metrics.gstencils_per_sec(),
            metrics.bubble_fraction() * 100.0,
            metrics.retunes,
            out.mean()
        );
        let (central, split) = metrics.comm.modeled_cost(&comm_model);
        println!(
            "  comm: {} batched msgs ({} bytes); modeled {:.2}ms centralized vs {:.2}ms per-step",
            metrics.comm.messages,
            metrics.comm.bytes,
            central * 1e3,
            split * 1e3
        );
        // Carry the converged shares into the next job's partition.
        let next_shares = metrics.final_shares.clone();
        if next_shares != partition.shares {
            println!(
                "  carrying retuned partition: native {} -> {}, xla {} -> {}",
                partition.shares[0], next_shares[0], partition.shares[1], next_shares[1]
            );
            partition = Partition { unit: meta.unit, shares: next_shares };
        } else {
            println!("  partition stable (converged)");
        }
    }
    Ok(())
}

fn make_workers(
    svc: &XlaService,
    bench: &str,
    device_cap: usize,
) -> tetris::util::error::Result<Vec<Box<dyn Worker>>> {
    Ok(vec![
        Box::new(NativeWorker::new(tetris::engine::by_name("tetris-cpu", 2).unwrap(), 1 << 33)),
        Box::new(XlaWorker::new(svc.clone(), &format!("{bench}_block"), device_cap)?),
    ])
}
