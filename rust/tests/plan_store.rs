//! Integration tests of the persistent plan store against the
//! checked-in golden file (`tests/golden/plan.jsonl`): wire-format
//! round-trip, unknown-field tolerance, corrupt-line recovery, and the
//! fingerprint-mismatch guarantee (foreign plans are ignored, never
//! misapplied).

use tetris::plan::{Fingerprint, Plan, PlanStore, PLAN_VERSION};
use tetris::util::json::Json;

fn golden_path() -> String {
    format!("{}/tests/golden/plan.jsonl", env!("CARGO_MANIFEST_DIR"))
}

/// This machine, as the golden records describe it (`c8/l64/g2`).
fn golden_fp() -> Fingerprint {
    Fingerprint::synthetic(8, 64, 2.0)
}

#[test]
fn golden_canonical_lines_round_trip_byte_identically() {
    let text = std::fs::read_to_string(golden_path()).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 5, "golden file layout changed");
    for &i in &[0usize, 1] {
        let p = Plan::parse_line(lines[i]).unwrap();
        assert_eq!(
            p.to_json().to_string(),
            lines[i],
            "canonical line {} must re-serialize byte-identically",
            i + 1
        );
    }
    // line 1 carries the full record, tile override + searched overlap
    // preference included
    let p = Plan::parse_line(lines[0]).unwrap();
    assert_eq!(p.version, PLAN_VERSION);
    assert_eq!(p.engine, "tetris-cpu");
    assert_eq!(p.tile_w, Some(64));
    assert_eq!(p.overlap, Some(true));
    assert_eq!(p.bucket, vec![512, 512]);
    // line 2 predates the overlap field: absent key reads as None
    let p = Plan::parse_line(lines[1]).unwrap();
    assert_eq!(p.overlap, None);
}

#[test]
fn golden_store_tolerates_unknown_fields_and_recovers_from_corruption() {
    let store = PlanStore::open(golden_path());
    let plans = store.load();
    // 5 lines: 4 parse (line 4 is a torn write), unknown fields ignored
    assert_eq!(plans.len(), 4, "{plans:?}");
    let future = plans.iter().find(|p| p.bench == "box2d9p").expect("future record kept");
    assert_eq!(future.engine, "tiled");
    assert_eq!(future.version, 2, "newer versions load (forward-tolerant)");
}

#[test]
fn lookup_serves_our_plans_and_ignores_foreign_fingerprints() {
    let store = PlanStore::open(golden_path());
    let ours = golden_fp();
    // the key exists under BOTH fingerprints; ours must win, and the
    // foreign naive plan must never be misapplied
    let p = store.lookup(&ours, "heat2d", "periodic", &[500, 500]).unwrap();
    assert_eq!(p.engine, "tetris-cpu");
    // the foreign machine gets its own plan back
    let theirs = Fingerprint::synthetic(256, 128, 1_048_576.0);
    let p = store.lookup(&theirs, "heat2d", "periodic", &[512, 512]).unwrap();
    assert_eq!(p.engine, "naive");
    // a third machine gets nothing at all
    let nobody = Fingerprint::synthetic(4, 64, 2.0);
    assert!(store.lookup(&nobody, "heat2d", "periodic", &[512, 512]).is_none());
    assert!(store.lookup_near(&nobody, "heat2d", "periodic", &[512, 512]).is_none());
}

#[test]
fn nearest_bucket_warm_start_from_golden_records() {
    let store = PlanStore::open(golden_path());
    let ours = golden_fp();
    // no exact 1024-bucket heat1d plan; the 262144-bucket one is the
    // only same-machine candidate and must be offered as warm start
    assert!(store.lookup(&ours, "heat1d", "dirichlet", &[1000]).is_none());
    let near = store.lookup_near(&ours, "heat1d", "dirichlet", &[1000]).unwrap();
    assert_eq!(near.engine, "simd");
    assert_eq!(near.tb, 8);
}

/// End-to-end durability: append → latest-wins lookup → atomic
/// compaction, on a scratch store (golden stays read-only).
#[test]
fn scratch_store_append_compact_cycle() {
    let path = std::env::temp_dir()
        .join(format!("tetris-plan-it-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    let store = PlanStore::open(&path);
    let fp = golden_fp();
    let mk = |engine: &str, gsps: f64| Plan {
        version: PLAN_VERSION,
        fingerprint: fp.id(),
        bench: "heat2d".into(),
        boundary: "dirichlet".into(),
        bucket: vec![128, 128],
        engine: engine.into(),
        threads: 2,
        tb: 4,
        tile_w: None,
        overlap: None,
        grid: None,
        gsps,
        source: "tuned".into(),
        seed: 9,
    };
    store.append(&mk("simd", 0.8)).unwrap();
    store.append(&mk("tetris-cpu", 1.4)).unwrap();
    assert_eq!(store.load().len(), 2);
    assert_eq!(
        store.lookup(&fp, "heat2d", "dirichlet", &[130, 130]).unwrap().engine,
        "tetris-cpu"
    );
    assert_eq!(store.compact().unwrap(), 1, "one key, latest record survives");
    let left = store.load();
    assert_eq!(left.len(), 1);
    assert_eq!(left[0].engine, "tetris-cpu");
    // compacted lines are canonical bytes
    let text = std::fs::read_to_string(&path).unwrap();
    assert_eq!(text, format!("{}\n", left[0].to_json()));
    assert!(Json::parse(text.trim()).is_ok());
    let _ = std::fs::remove_file(&path);
}
