//! End-to-end tests of the `tetris load` harness against an in-process
//! `tetris serve` (real TCP, no child process): the deterministic
//! Suite A baseline loses nothing and rejects nothing, the open-loop
//! Suite B conserves every offered job even when driven past
//! saturation, and every emitted report passes the `bench check`
//! structural invariants.

use std::sync::Arc;
use std::time::Duration;

use tetris::bench::check::check_json;
use tetris::coordinator::{NativeWorker, Worker};
use tetris::load::{run_suite_a, run_suite_b, LoadConfig};
use tetris::serve::{Client, ServeConfig, Server, ServerHandle, WorkerFactory};

/// Two plain `simd` workers (same idiom as serve_e2e): deterministic,
/// cheap, and bit-invariant under any partition.
fn simd_factory() -> WorkerFactory {
    Arc::new(|_bench, _shape, _tb, _plan| {
        let mk = || -> Box<dyn Worker> {
            Box::new(NativeWorker::new(tetris::engine::by_name("simd", 1).unwrap(), 1 << 33))
        };
        Ok(vec![mk(), mk()])
    })
}

fn start_server(queue_jobs: usize, dispatchers: usize) -> ServerHandle {
    Server::start(
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            dispatchers,
            queue_jobs,
            scale: 0.05,
            plan_store: None,
            ..Default::default()
        },
        simd_factory(),
    )
    .expect("server start")
}

fn shutdown(handle: ServerHandle) {
    let mut c = Client::connect(handle.addr).unwrap();
    c.shutdown().unwrap();
    handle.join();
}

/// Suite A acceptance: a tiny closed-loop run loses zero results,
/// rejects nothing, and its report satisfies every checker invariant.
#[test]
fn suite_a_loses_nothing_and_passes_check() {
    let handle = start_server(64, 2);
    let cfg = LoadConfig {
        conns: 3,
        jobs_per_conn: 4,
        seed: 0xA11CE,
        scale: 0.05,
        ..Default::default()
    };
    let suite = run_suite_a(&handle.addr.to_string(), &cfg).expect("suite A");
    assert_eq!(suite.name, "suiteA");
    assert_eq!(suite.rungs.len(), 1);
    let rung = &suite.rungs[0];
    assert_eq!(rung.rec.offered, 12);
    assert_eq!(rung.rec.completed, 12, "{:?}", rung.rec);
    assert_eq!(rung.rec.rejected, 0);
    assert_eq!(rung.rec.errors, 0);
    assert_eq!(rung.rec.lost, 0);
    assert!(rung.rec.conserved());
    assert_eq!(rung.rec.total.count(), 12);
    assert!(rung.rec.total.percentile_ms(0.999) >= rung.rec.total.percentile_ms(0.50));

    let report = suite.to_json(cfg.scale, cfg.threads, None);
    let text = report.to_string();
    assert!(!text.contains('\n'), "single-line artifact");
    let violations = check_json("suiteA", &report);
    assert!(violations.is_empty(), "{violations:?}");
    shutdown(handle);
}

/// Suite B under a comfortable rate: open loop, everything conserved,
/// report check-clean.
#[test]
fn suite_b_conserves_jobs_at_moderate_rate() {
    let handle = start_server(64, 2);
    let cfg = LoadConfig {
        rate: 40.0,
        duration: Duration::from_millis(700),
        zipf_s: 1.1,
        seed: 7,
        sweep: false,
        ..Default::default()
    };
    let suite = run_suite_b(&handle.addr.to_string(), &cfg).expect("suite B");
    assert_eq!(suite.name, "suiteB");
    assert_eq!(suite.rungs.len(), 1);
    let rung = &suite.rungs[0];
    assert!(rung.rec.offered > 0, "schedule must produce arrivals");
    assert_eq!(rung.rec.lost, 0, "{:?}", rung.rec);
    assert!(rung.rec.conserved());
    assert_eq!(rung.rec.total.count(), rung.rec.completed);

    let report = suite.to_json(cfg.scale, cfg.threads, None);
    let violations = check_json("suiteB", &report);
    assert!(violations.is_empty(), "{violations:?}");
    shutdown(handle);
}

/// Suite B past saturation: a tiny admission queue under a hot rate
/// must produce rejects with retry hints — and still account for every
/// single offered job (no losses, conservation exact).
#[test]
fn suite_b_past_saturation_rejects_but_conserves() {
    let handle = start_server(2, 1);
    let cfg = LoadConfig {
        rate: 800.0,
        duration: Duration::from_millis(500),
        zipf_s: 1.1,
        seed: 99,
        sweep: false,
        ..Default::default()
    };
    let suite = run_suite_b(&handle.addr.to_string(), &cfg).expect("suite B hot");
    let rung = &suite.rungs[0];
    assert!(rung.rec.offered > 50, "{:?}", rung.rec);
    assert!(rung.rec.rejected > 0, "queue of 2 at 800/s must reject: {:?}", rung.rec);
    assert_eq!(rung.rec.lost, 0, "{:?}", rung.rec);
    assert!(rung.rec.conserved());
    assert_eq!(rung.rec.retry_hints_ms.len() as u64, rung.rec.rejected);
    // the server's hints are bounded (queue.rs caps at 5000ms)
    assert!(rung.rec.retry_hints_ms.iter().all(|&h| h <= 5_000));

    let report = suite.to_json(cfg.scale, cfg.threads, None);
    let violations = check_json("suiteB-hot", &report);
    assert!(violations.is_empty(), "{violations:?}");
    shutdown(handle);
}

/// The rate sweep walks rungs upward and stops on sustained rejects
/// (or the rung cap) — against a tiny queue it must reach saturation
/// within the cap and stay check-clean throughout.
#[test]
fn rate_sweep_reaches_saturation_on_a_tiny_queue() {
    let handle = start_server(2, 1);
    let cfg = LoadConfig {
        rate: 100.0,
        duration: Duration::from_millis(400),
        seed: 5,
        sweep: true,
        sweep_factor: 3.0,
        max_rungs: 4,
        stop_reject_frac: 0.2,
        ..Default::default()
    };
    let suite = run_suite_b(&handle.addr.to_string(), &cfg).expect("sweep");
    assert!(!suite.rungs.is_empty() && suite.rungs.len() <= 4);
    for rung in &suite.rungs {
        assert!(rung.rec.conserved(), "{:?}", rung.rec);
        assert_eq!(rung.rec.lost, 0);
    }
    // offered rates must actually climb rung over rung
    for pair in suite.rungs.windows(2) {
        assert!(pair[1].offered_rate > pair[0].offered_rate);
    }
    let report = suite.to_json(cfg.scale, cfg.threads, None);
    let violations = check_json("sweep", &report);
    assert!(violations.is_empty(), "{violations:?}");
    shutdown(handle);
}
