//! Full-stack integration tests: AOT artifacts (Pallas/JAX lowered) →
//! PJRT runtime → heterogeneous coordinator.
//!
//! These tests require `make artifacts`; without the artifact directory
//! they skip (printing a note) so `cargo test` stays green pre-build.

use tetris::coordinator::{CommModel, NativeWorker, Overlap, Partition, Scheduler, Worker, XlaWorker};
use tetris::runtime::{Manifest, XlaService};
use tetris::stencil::{reference, spec, Boundary, Field};

fn service() -> Option<XlaService> {
    for dir in ["artifacts", "../artifacts"] {
        if std::path::Path::new(dir).join("manifest.json").exists() {
            return Some(XlaService::spawn(Manifest::load(dir).unwrap()).unwrap());
        }
    }
    println!("SKIP: artifacts not built (run `make artifacts`)");
    None
}

/// Every artifact's golden stats must reproduce bit-for-bit from the
/// rust SplitMix64 stream — the cross-language correctness seal.
#[test]
fn all_artifacts_validate_against_python_goldens() {
    let Some(svc) = service() else { return };
    let mut checked = 0;
    for name in svc.artifact_names() {
        let (em, el2) = svc.validate(&name).unwrap_or_else(|e| panic!("{name}: {e}"));
        // fp32 artifacts round through f32; everything else is exact-ish.
        let tol = if svc.meta(&name).unwrap().dtype == "f32" { 2e-6 } else { 1e-11 };
        assert!(em < tol && el2 < tol, "{name}: mean_err={em:.2e} l2_err={el2:.2e}");
        checked += 1;
    }
    assert!(checked >= 30, "expected >= 30 artifacts, got {checked}");
}

/// step/block/mxu/oracle artifacts of one bench agree with each other and
/// with the rust oracle on random inputs.
#[test]
fn artifact_variants_cross_agree() {
    let Some(svc) = service() else { return };
    for bench in ["heat2d", "box2d25p"] {
        let s = spec::get(bench).unwrap();
        let block_meta = svc.meta(&format!("{bench}_block")).unwrap().clone();
        let input = Field::random(&block_meta.input_shape, 4242);
        let via_block = svc.run(&format!("{bench}_block"), &input).unwrap();
        let via_oracle_art = svc.run(&format!("{bench}_oracle"), &input).unwrap();
        let via_rust = reference::block(&input, &s, block_meta.steps);
        assert!(via_block.allclose(&via_rust, 1e-12, 1e-14), "{bench} block vs rust");
        assert!(via_oracle_art.allclose(&via_rust, 1e-12, 1e-14), "{bench} oracle vs rust");

        // mxu (single step) vs rust single step
        let mxu_meta = svc.meta(&format!("{bench}_mxu")).unwrap().clone();
        let input1 = Field::random(&mxu_meta.input_shape, 77);
        let via_mxu = svc.run(&format!("{bench}_mxu"), &input1).unwrap();
        let one = reference::step(&input1, &s);
        assert!(via_mxu.allclose(&one, 1e-11, 1e-13), "{bench} mxu vs rust step");
    }
}

/// The headline integration: heterogeneous scheduler mixing the native
/// Tetris (CPU) engine and the XLA artifact worker reproduces the
/// reference evolution exactly.
#[test]
fn hetero_cpu_plus_xla_matches_reference() {
    let Some(svc) = service() else { return };
    for bench in ["heat2d", "heat3d"] {
        let s = spec::get(bench).unwrap();
        let meta = svc.bench(bench).unwrap().clone();
        let workers: Vec<Box<dyn Worker>> = vec![
            Box::new(NativeWorker::new(tetris::engine::by_name("tetris-cpu", 2).unwrap(), 1 << 33)),
            Box::new(XlaWorker::new(svc.clone(), &format!("{bench}_block"), 1 << 33).unwrap()),
        ];
        let units = meta.global_core[0] / meta.unit;
        let partition = Partition::rows(meta.unit, vec![units / 2, units - units / 2]);
        let sched = Scheduler {
            spec: s.clone(),
            tb: meta.tb,
            workers,
            partition,
            comm_model: CommModel::default(),
            boundary: Boundary::Dirichlet(0.25),
            adapt_every: 0,
            overlap: Overlap::Auto,
        };
        let core = Field::random(&meta.global_core, 31337);
        let steps = meta.tb * 2;
        let (got, metrics) = sched.run(&core, steps).unwrap();
        let want = tetris::coordinator::pipeline::reference_evolution(
            &core,
            &s,
            steps,
            meta.tb,
            Boundary::Dirichlet(0.25),
        );
        assert!(
            got.allclose(&want, 1e-11, 1e-13),
            "{bench}: maxdiff={}",
            got.max_abs_diff(&want)
        );
        assert!(metrics.comm.messages > 0);
        println!("{bench}: hetero ok, {:.4} GStencils/s", metrics.gstencils_per_sec());
    }
}

/// Manifest spec coefficients match the rust-side regenerated specs —
/// python and rust compute the same dwarf.
#[test]
fn manifest_coeffs_match_rust_specs() {
    let Some(svc) = service() else { return };
    for (name, bench) in &svc.manifest().benches {
        let s = spec::get(name).unwrap();
        let (offs, cs) = s.taps();
        assert_eq!(bench.points, s.points(), "{name}");
        assert_eq!(bench.radius, s.radius, "{name}");
        assert_eq!(bench.offsets, offs, "{name} offsets");
        assert_eq!(bench.coeffs.len(), cs.len());
        for (a, b) in bench.coeffs.iter().zip(&cs) {
            assert!((a - b).abs() < 1e-12, "{name}: {a} vs {b}");
        }
    }
}

/// Thermal artifacts: FP64 run preserves the mean (periodic), FP32 run
/// drifts but stays bounded; both executable through the service.
#[test]
fn thermal_artifacts_behave() {
    let Some(svc) = service() else { return };
    let n = svc.manifest().thermal_core[0];
    let init = tetris::apps::thermal::gaussian_plate(n);
    let a = svc.run("thermal_f64", &init).unwrap();
    assert!((a.mean() - init.mean()).abs() < 1e-9, "periodic mean preserved");
    let b = svc.run("thermal_f32", &init).unwrap();
    let d = a.max_abs_diff(&b);
    assert!(d > 0.0 && d < 0.5, "fp32 drift bounded: {d}");
}

/// Capacity squeeze forces the partition off the ideal split but the run
/// still matches the reference (spill correctness).
#[test]
fn memory_squeeze_preserves_correctness() {
    let Some(svc) = service() else { return };
    let bench = "heat2d";
    let s = spec::get(bench).unwrap();
    let meta = svc.bench(bench).unwrap().clone();
    let halo = s.radius * meta.tb;
    let rest: usize = meta.global_core[1..].iter().map(|n| n + 2 * halo).product();
    // Device holds only 1 unit.
    let device_cap = 3 * meta.unit * rest * 8 + 1;
    let workers: Vec<Box<dyn Worker>> = vec![
        Box::new(NativeWorker::new(tetris::engine::by_name("simd", 1).unwrap(), 1 << 40)),
        Box::new(XlaWorker::new(svc.clone(), "heat2d_block", device_cap).unwrap()),
    ];
    let units = meta.global_core[0] / meta.unit;
    let p = tetris::coordinator::tuner::tune(meta.unit, units, rest, &[1e-3, 1e-4], &workers);
    assert_eq!(p.shares[1], 1, "squeezed device gets exactly its capacity");
    assert_eq!(p.total_units(), units);
    let sched = Scheduler {
        spec: s.clone(),
        tb: meta.tb,
        workers,
        partition: p,
        comm_model: CommModel::default(),
        boundary: Boundary::Dirichlet(0.0),
        adapt_every: 0,
        overlap: Overlap::Auto,
    };
    let core = Field::random(&meta.global_core, 999);
    let (got, _) = sched.run(&core, meta.tb).unwrap();
    let want = tetris::coordinator::pipeline::reference_evolution(
        &core,
        &s,
        meta.tb,
        meta.tb,
        Boundary::Dirichlet(0.0),
    );
    assert!(got.allclose(&want, 1e-11, 1e-13));
}

/// Boundary-agnostic worker contract: the XLA artifact worker serves a
/// Periodic (torus) run without modification — the leader's ghost refill
/// supplies the wrap, and the result matches the periodic oracle.
#[test]
fn hetero_cpu_plus_xla_periodic_matches_torus_oracle() {
    let Some(svc) = service() else { return };
    let bench = "heat2d";
    let s = spec::get(bench).unwrap();
    let meta = svc.bench(bench).unwrap().clone();
    let workers: Vec<Box<dyn Worker>> = vec![
        Box::new(NativeWorker::new(tetris::engine::by_name("tetris-cpu", 2).unwrap(), 1 << 33)),
        Box::new(XlaWorker::new(svc.clone(), &format!("{bench}_block"), 1 << 33).unwrap()),
    ];
    let units = meta.global_core[0] / meta.unit;
    let sched = Scheduler {
        spec: s.clone(),
        tb: meta.tb,
        workers,
        partition: Partition::rows(meta.unit, vec![units / 2, units - units / 2]),
        comm_model: CommModel::default(),
        boundary: Boundary::Periodic,
        adapt_every: 0,
        overlap: Overlap::Auto,
    };
    let core = Field::random(&meta.global_core, 271828);
    let steps = meta.tb * 2;
    let (got, metrics) = sched.run(&core, steps).unwrap();
    let want = reference::evolve_periodic(&core, &s, steps);
    assert!(got.allclose(&want, 1e-11, 1e-13), "maxdiff={}", got.max_abs_diff(&want));
    // ring topology: 2 workers -> 2 links per block
    assert_eq!(metrics.comm.messages, 2 * (steps / meta.tb));
}

/// A worker failure surfaces as an error, not a hang or a corrupt field.
#[test]
fn worker_failure_propagates() {
    let Some(svc) = service() else { return };
    struct FailingWorker;
    impl Worker for FailingWorker {
        fn name(&self) -> String {
            "failing".into()
        }
        fn mem_capacity(&self) -> usize {
            1 << 40
        }
        fn run_slab(
            &self,
            _: &tetris::stencil::StencilSpec,
            _: &Field,
            _: usize,
        ) -> tetris::util::error::Result<Field> {
            tetris::bail!("injected fault")
        }
    }
    let s = spec::get("heat2d").unwrap();
    let sched = Scheduler {
        spec: s,
        tb: 1,
        workers: vec![
            Box::new(NativeWorker::new(tetris::engine::by_name("simd", 1).unwrap(), 1 << 40)),
            Box::new(FailingWorker),
        ],
        partition: Partition::rows(8, vec![1, 1]),
        comm_model: CommModel::default(),
        boundary: Boundary::Dirichlet(0.0),
        adapt_every: 0,
        overlap: Overlap::Auto,
    };
    let core = Field::random(&[16, 16], 5);
    let err = sched.run(&core, 1).unwrap_err();
    assert!(format!("{err:#}").contains("injected fault"), "{err:#}");
    let _ = svc; // keep service alive through the test
}
