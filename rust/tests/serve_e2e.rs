//! End-to-end tests of the `tetris serve` subsystem over real TCP:
//! the acceptance bit-compare (server results == direct scheduler runs),
//! protocol robustness (golden files, unknown fields, malformed lines),
//! admission backpressure, multi-client concurrency with FIFO-within-
//! class ordering, and graceful drain on `SHUTDOWN`.

use std::sync::Arc;

use tetris::coordinator::{CommModel, NativeWorker, Overlap, Partition, Scheduler, Worker};
use tetris::plan::{shape_bucket, Fingerprint, Plan, PlanStore, PLAN_VERSION};
use tetris::serve::{
    default_worker_factory, Client, JobResult, JobSpec, Priority, ServeConfig, Server,
    ServerHandle, WorkerFactory,
};
use tetris::stencil::{Boundary, Field};

/// Two plain `simd` workers everywhere: the fused row kernel computes
/// every cell from its window in fixed tap order, so results are
/// bit-invariant under any slab decomposition — which lets the tests
/// bit-compare against a direct single-worker scheduler run no matter
/// what partition the session profiled or retuned to.
fn simd_factory() -> WorkerFactory {
    Arc::new(|_bench, _shape, _tb, _plan| {
        let mk = || -> Box<dyn Worker> {
            Box::new(NativeWorker::new(tetris::engine::by_name("simd", 1).unwrap(), 1 << 33))
        };
        Ok(vec![mk(), mk()])
    })
}

fn start_server(cfg: ServeConfig) -> ServerHandle {
    Server::start(cfg, simd_factory()).expect("server start")
}

fn direct_run_tb(
    bench: &str,
    boundary: Boundary,
    shape: &[usize],
    steps: usize,
    seed: u64,
    tb: usize,
) -> Field {
    let s = tetris::stencil::spec::get(bench).unwrap();
    let sched = Scheduler {
        spec: s,
        tb,
        workers: vec![Box::new(NativeWorker::new(
            tetris::engine::by_name("simd", 1).unwrap(),
            1 << 33,
        ))],
        partition: Partition::rows(shape[0], vec![1]),
        comm_model: CommModel::default(),
        boundary,
        adapt_every: 0,
        // serial single-worker reference: the server's sessions run
        // overlap=auto, so these bit-compares also prove the pipelined
        // loop is bit-invisible end-to-end
        overlap: Overlap::Off,
    };
    let core = Field::random(shape, seed);
    let (out, _) = sched.run(&core, steps).unwrap();
    out
}

fn direct_run(bench: &str, boundary: Boundary, shape: &[usize], steps: usize, seed: u64) -> Field {
    let tb = tetris::bench::scaled_problem(bench, 0.05).2;
    direct_run_tb(bench, boundary, shape, steps, seed, tb)
}

/// Acceptance: boot the server in-process, submit boundary-diverse jobs
/// over TCP with `return_field`, and bit-compare every returned field
/// against the corresponding direct `Scheduler` run.
#[test]
fn e2e_tcp_results_bit_match_direct_scheduler_runs() {
    let handle = start_server(ServeConfig {
        addr: "127.0.0.1:0".into(),
        dispatchers: 2,
        scale: 0.05,
        ..Default::default()
    });
    let mut client = Client::connect(handle.addr).unwrap();
    let cases: [(Boundary, u64); 3] = [
        (Boundary::Dirichlet(25.0), 201),
        (Boundary::Neumann, 202),
        (Boundary::Periodic, 203),
    ];
    let shape = vec![24usize, 16];
    for (i, (boundary, seed)) in cases.iter().enumerate() {
        client
            .send_spec(&JobSpec {
                id: format!("e2e-{i}"),
                bench: "heat2d".into(),
                boundary: *boundary,
                steps: 8,
                shape: Some(shape.clone()),
                seed: *seed,
                return_field: true,
                ..Default::default()
            })
            .unwrap();
    }
    for (i, (boundary, seed)) in cases.iter().enumerate() {
        let r = client.recv_result().unwrap();
        assert!(r.ok, "{r:?}");
        assert_eq!(r.id, format!("e2e-{i}"));
        assert_eq!(r.steps, 8, "heat2d Tb=4 keeps 8 steps as-is");
        let got = r.field.expect("return_field requested");
        let want = direct_run("heat2d", *boundary, &shape, r.steps, *seed);
        assert_eq!(got.len(), want.len());
        for (j, (a, b)) in got.iter().zip(want.data()).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{boundary}: cell {j} differs: {a} vs {b}"
            );
        }
        assert_eq!(r.mean.to_bits(), want.mean().to_bits(), "{boundary}");
    }
    client.shutdown().unwrap();
    handle.join();
}

/// Serve/plan acceptance: a session created for a key with a stored
/// plan adopts the plan's engine and Tb (asserted via `STATS`), and the
/// results are bit-identical to the fixed-engine path running the same
/// configuration directly.
#[test]
fn e2e_session_adopts_stored_plan_and_matches_fixed_engine_bits() {
    // Fingerprint detected ONCE and injected on both sides (store key
    // and server config) so the lookup is exact by construction.
    let fp = Fingerprint::detect(40);
    let store_path = std::env::temp_dir()
        .join(format!("tetris-e2e-plans-{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&store_path);
    let store = PlanStore::open(&store_path);
    let shape = vec![24usize];
    // heat1d's default session Tb at this scale is 8; the plan says 4 —
    // observable both in STATS and in the step alignment of the reply.
    let plan_tb = 4usize;
    store
        .append(&Plan {
            version: PLAN_VERSION,
            fingerprint: fp.id(),
            bench: "heat1d".into(),
            boundary: "dirichlet".into(),
            bucket: shape_bucket(&shape),
            engine: "simd".into(),
            threads: 1,
            tb: plan_tb,
            // proxy-grid basis; never compared against live throughput
            gsps: 2.0,
            tile_w: None,
            overlap: Some(true),
            grid: None,
            source: "tuned".into(),
            seed: 0,
        })
        .unwrap();
    assert_eq!(
        tetris::bench::scaled_problem("heat1d", 0.05).2,
        8,
        "test premise: the default Tb must differ from the plan's"
    );
    let handle = Server::start(
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            dispatchers: 1,
            scale: 0.05,
            plan_store: Some(store_path.to_string_lossy().into_owned()),
            fingerprint: Some(fp),
            ..Default::default()
        },
        default_worker_factory(1),
    )
    .expect("server start");
    let mut client = Client::connect(handle.addr).unwrap();
    let r = client
        .submit(&JobSpec {
            id: "planned".into(),
            bench: "heat1d".into(),
            shape: Some(shape.clone()),
            steps: 4,
            seed: 4242,
            return_field: true,
            ..Default::default()
        })
        .unwrap();
    assert!(r.ok, "{r:?}");
    assert_eq!(r.steps, 4, "plan Tb=4 keeps 4 steps; the default Tb=8 would align to 8");

    // STATS: the session runs the plan's engine and Tb
    let stats = client.stats().unwrap();
    let sessions = stats.at(&["sessions"]).as_obj().unwrap();
    assert_eq!(sessions.len(), 1);
    let (key, sess) = sessions.iter().next().unwrap();
    assert!(key.contains("heat1d/dirichlet"), "{key}");
    assert_eq!(sess.at(&["tb"]).as_usize(), Some(plan_tb));
    assert_eq!(sess.at(&["planned"]), &tetris::util::json::Json::Bool(true));
    assert_eq!(
        sess.at(&["overlap"]).as_str(),
        Some("on"),
        "session must adopt the plan's searched overlap preference"
    );
    let engine = sess.at(&["engine"]).as_str().unwrap();
    assert!(engine.contains("native:simd"), "{engine}");
    assert!(!engine.contains("tetris-cpu"), "defaults must not leak in: {engine}");

    // bit-identical to the fixed-engine path at the same Tb
    let got = r.field.expect("return_field requested");
    let want = direct_run_tb("heat1d", Boundary::Dirichlet(0.0), &shape, 4, 4242, plan_tb);
    assert_eq!(got.len(), want.len());
    for (i, (a, b)) in got.iter().zip(want.data()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "cell {i}: {a} vs {b}");
    }

    client.shutdown().unwrap();
    handle.join();
    // A planned session's first batch only sets the live write-back
    // baseline: the store must still hold exactly the seeded plan.
    assert_eq!(store.load().len(), 1, "first batch must not write back over a fresh plan");
    let _ = std::fs::remove_file(&store_path);
}

/// Golden wire format: parse the checked-in request line (which carries
/// unknown future fields), confirm every known field, and round-trip it.
#[test]
fn golden_jobspec_round_trips_with_unknown_fields() {
    let line = include_str!("golden/jobspec.json");
    let spec = JobSpec::parse_line(line).unwrap();
    assert_eq!(spec.id, "golden-42");
    assert_eq!(spec.bench, "heat2d");
    assert_eq!(spec.boundary, Boundary::Neumann);
    assert_eq!(spec.priority, Priority::Interactive);
    assert_eq!(spec.steps, 8);
    assert_eq!(spec.shape.as_deref(), Some(&[24usize, 16][..]));
    assert_eq!(spec.seed, 7);
    assert!(spec.return_field);
    // round trip through our own serializer
    let again = JobSpec::parse_line(&spec.to_json().to_string()).unwrap();
    assert_eq!(again, spec);
}

#[test]
fn golden_jobresult_round_trips_field_bits() {
    let line = include_str!("golden/jobresult.json");
    let r = JobResult::parse_line(line).unwrap();
    assert!(r.ok);
    assert_eq!(r.id, "golden-42");
    assert_eq!(r.boundary, "dirichlet:25");
    assert_eq!(r.batch_size, 4);
    assert_eq!(r.admit_seq, 11);
    assert_eq!(r.start_seq, 9);
    assert_eq!(r.shares, vec![13, 11]);
    let field = r.field.clone().unwrap();
    assert_eq!(field.len(), 6);
    assert_eq!(field[0].to_bits(), (0.30000000000000004f64).to_bits());
    assert_eq!(field[1].to_bits(), (1.0f64 / 3.0).to_bits());
    assert_eq!(field[2], 6.02e23);
    // round trip through our own serializer preserves every bit
    let again = JobResult::parse_line(&r.to_json().to_string()).unwrap();
    assert_eq!(again, r);
}

/// A malformed line gets a structured error reply and the connection
/// stays open for the next (valid) request; same for an unknown bench.
#[test]
fn malformed_lines_answer_structured_errors_and_keep_connection() {
    let handle = start_server(ServeConfig {
        addr: "127.0.0.1:0".into(),
        dispatchers: 1,
        scale: 0.05,
        ..Default::default()
    });
    let mut client = Client::connect(handle.addr).unwrap();

    client.send_line("{this is not json").unwrap();
    let r = client.recv_result().unwrap();
    assert!(!r.ok);
    assert!(r.error.unwrap().contains("parse"), "parse failure must be named");

    client.send_line(r#"{"bench":"warpdrive9000"}"#).unwrap();
    let r = client.recv_result().unwrap();
    assert!(!r.ok);
    assert!(r.error.unwrap().contains("warpdrive9000"));

    client.send_line(r#"{"bench":"heat2d","shape":[5],"steps":4}"#).unwrap();
    let r = client.recv_result().unwrap();
    assert!(!r.ok, "1-d shape for a 2-d bench must be rejected");

    // the connection survived all three: a real job still works
    let r = client
        .submit(&JobSpec {
            id: "after-errors".into(),
            bench: "heat1d".into(),
            shape: Some(vec![24]),
            steps: 8,
            ..Default::default()
        })
        .unwrap();
    assert!(r.ok, "{r:?}");
    assert_eq!(r.id, "after-errors");

    let stats = client.stats().unwrap();
    assert_eq!(stats.at(&["stats", "errors"]).as_usize(), Some(3));
    assert_eq!(stats.at(&["stats", "completed"]).as_usize(), Some(1));
    client.shutdown().unwrap();
    handle.join();
}

/// Memory admission failure surfaces as a structured reject, not a
/// hang, a dropped line, or (for hostile shapes) an OOM: the footprint
/// check runs on the declared shape before any allocation, and a job
/// that can never fit gets `retry_after_ms: 0` ("do not retry").
#[test]
fn memory_admission_rejects_before_allocating() {
    let handle = start_server(ServeConfig {
        addr: "127.0.0.1:0".into(),
        dispatchers: 1,
        queue_bytes: 1, // nothing fits
        scale: 0.05,
        ..Default::default()
    });
    let mut client = Client::connect(handle.addr).unwrap();
    let r = client
        .submit(&JobSpec {
            id: "too-big".into(),
            bench: "heat1d".into(),
            shape: Some(vec![24]),
            ..Default::default()
        })
        .unwrap();
    assert!(!r.ok);
    assert!(r.error.unwrap().contains("memory admission"));
    assert_eq!(r.retry_after_ms, Some(0), "a never-fitting job must not be retried");
    // A shape whose byte count overflows usize is bounced the same way
    // — admission arithmetic, not an allocation attempt.
    client
        .send_line(r#"{"bench":"heat1d","id":"hostile","shape":[18446744073709551615]}"#)
        .unwrap();
    let r = client.recv_result().unwrap();
    assert!(!r.ok);
    assert!(r.error.unwrap().contains("memory admission"), "{:?}", r.id);
    let stats = client.stats().unwrap();
    assert_eq!(stats.at(&["stats", "rejected"]).as_usize(), Some(2));
    client.shutdown().unwrap();
    handle.join();
}

/// Concurrency smoke (satellite): 4 client threads x 8 jobs with mixed
/// priorities against one single-dispatcher server — every job answers
/// (no lost results), and dispatch order is FIFO within each priority
/// class; then a clean `SHUTDOWN` drains pipelined jobs before the
/// listener closes.
#[test]
fn concurrent_clients_keep_fifo_within_class_and_drain_on_shutdown() {
    // start_seq is assigned at queue pop (under the queue lock), so the
    // FIFO-within-class check would hold for any dispatcher count; one
    // dispatcher just keeps the rest of the scenario deterministic.
    let handle = start_server(ServeConfig {
        addr: "127.0.0.1:0".into(),
        dispatchers: 1,
        scale: 0.05,
        ..Default::default()
    });
    let addr = handle.addr;
    let priorities = [Priority::Interactive, Priority::Normal, Priority::Batch];
    let mut joins = Vec::new();
    for t in 0..4u64 {
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).unwrap();
            for j in 0..8u64 {
                client
                    .send_spec(&JobSpec {
                        id: format!("c{t}-{j}"),
                        bench: "heat1d".into(),
                        shape: Some(vec![24]),
                        steps: 8,
                        seed: 1_000 + t * 8 + j,
                        priority: priorities[(t as usize + j as usize) % 3],
                        ..Default::default()
                    })
                    .unwrap();
            }
            (0..8).map(|_| client.recv_result().unwrap()).collect::<Vec<_>>()
        }));
    }
    let mut all: Vec<JobResult> = Vec::new();
    for j in joins {
        all.extend(j.join().unwrap());
    }
    assert_eq!(all.len(), 32, "no lost results");
    assert!(all.iter().all(|r| r.ok), "{all:?}");
    let mut ids: Vec<&str> = all.iter().map(|r| r.id.as_str()).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 32, "every job answered exactly once");
    for class in ["interactive", "normal", "batch"] {
        let mut in_class: Vec<&JobResult> =
            all.iter().filter(|r| r.priority == class).collect();
        assert!(!in_class.is_empty());
        in_class.sort_by_key(|r| r.admit_seq);
        for w in in_class.windows(2) {
            assert!(
                w[0].start_seq < w[1].start_seq,
                "{class}: admit order {} -> {} dispatched {} -> {}",
                w[0].admit_seq,
                w[1].admit_seq,
                w[0].start_seq,
                w[1].start_seq
            );
        }
    }

    // Clean shutdown with work still pipelined on one connection: the
    // jobs were admitted before the SHUTDOWN line (in-order processing),
    // so the pool drains them all before the server exits.
    let mut client = Client::connect(addr).unwrap();
    for j in 0..5u64 {
        client
            .send_spec(&JobSpec {
                id: format!("drain-{j}"),
                bench: "heat1d".into(),
                shape: Some(vec![24]),
                steps: 8,
                seed: 9_000 + j,
                ..Default::default()
            })
            .unwrap();
    }
    client.send_line("SHUTDOWN").unwrap();
    for j in 0..5u64 {
        let r = client.recv_result().unwrap();
        assert!(r.ok, "pipelined job {j} must drain before shutdown: {r:?}");
        assert_eq!(r.id, format!("drain-{j}"));
    }
    let ack = tetris::util::json::Json::parse(client.recv_line().unwrap().trim()).unwrap();
    assert_eq!(ack.at(&["shutdown"]), &tetris::util::json::Json::Bool(true));
    handle.join(); // dispatchers drained, listener closed

    // The listener is gone: a fresh connection must fail (or die on the
    // first read if the OS raced the accept backlog).
    match Client::connect(addr) {
        Err(_) => {}
        Ok(mut late) => {
            late.send_line("STATS").unwrap_or(());
            assert!(late.recv_line().is_err(), "server must be gone after join()");
        }
    }
}

/// `METRICS` verb: a flat `layer.metric -> number` object whose
/// `_total` counters are monotone across snapshots from one server and
/// whose queue-depth gauge respects the configured capacity.
#[test]
fn metrics_verb_returns_flat_monotone_snapshot() {
    let handle = start_server(ServeConfig {
        addr: "127.0.0.1:0".into(),
        dispatchers: 1,
        scale: 0.05,
        ..Default::default()
    });
    let mut client = Client::connect(handle.addr).unwrap();
    let submit = |client: &mut Client, i: u64| {
        let r = client
            .submit(&JobSpec {
                id: format!("m-{i}"),
                bench: "heat1d".into(),
                shape: Some(vec![24]),
                steps: 8,
                seed: 100 + i,
                ..Default::default()
            })
            .unwrap();
        assert!(r.ok, "{r:?}");
    };
    submit(&mut client, 0);
    let m1 = client.metrics().unwrap();
    let m1 = m1.as_obj().expect("METRICS must be a flat JSON object").clone();
    for (k, v) in &m1 {
        assert!(v.as_f64().is_some(), "{k} must be numeric, got {v:?}");
        assert!(k.contains('.'), "metric {k} must follow the layer.metric naming policy");
    }
    for want in [
        "serve.submitted_total",
        "serve.completed_total",
        "serve.rejected_total",
        "serve.errors_total",
        "serve.batches_total",
        "serve.queue_depth",
        "serve.queue_capacity",
        "serve.inflight_bytes",
        "serve.sessions",
        "serve.latency_ms_count_total",
        "serve.latency_ms_p50_ms",
    ] {
        assert!(m1.contains_key(want), "missing {want}: {:?}", m1.keys().collect::<Vec<_>>());
    }
    assert_eq!(m1["serve.completed_total"].as_usize(), Some(1));
    assert!(
        m1["serve.queue_depth"].as_f64().unwrap()
            <= m1["serve.queue_capacity"].as_f64().unwrap(),
        "queue depth gauge must respect the configured capacity"
    );
    submit(&mut client, 1);
    submit(&mut client, 2);
    let m2 = client.metrics().unwrap();
    let m2 = m2.as_obj().unwrap().clone();
    for (k, v1) in &m1 {
        if k.ends_with("_total") {
            let (a, b) = (v1.as_f64().unwrap(), m2[k].as_f64().unwrap());
            assert!(b >= a, "{k} must be monotone across snapshots: {a} -> {b}");
        }
    }
    assert_eq!(m2["serve.completed_total"].as_usize(), Some(3));
    client.shutdown().unwrap();
    handle.join();
}

/// Satellite fix: a connection that spams `STATS` without ever reading
/// its replies (blocking its private writer thread on a full socket
/// buffer) must not stall job replies on other connections — the STATS
/// handler snapshots state under brief locks and formats after release.
#[test]
fn slow_stats_consumer_does_not_stall_job_replies() {
    use std::io::Write;
    let handle = start_server(ServeConfig {
        addr: "127.0.0.1:0".into(),
        dispatchers: 1,
        scale: 0.05,
        ..Default::default()
    });
    let mut hog = std::net::TcpStream::connect(handle.addr).unwrap();
    // Enough unread replies to overrun both socket buffers: the hog
    // connection's writer thread ends up blocked mid-write.
    for _ in 0..2000 {
        hog.write_all(b"STATS\n").unwrap();
    }
    let mut client = Client::connect(handle.addr).unwrap();
    for j in 0..4u64 {
        let r = client
            .submit(&JobSpec {
                id: format!("live-{j}"),
                bench: "heat1d".into(),
                shape: Some(vec![24]),
                steps: 8,
                seed: 500 + j,
                ..Default::default()
            })
            .unwrap();
        assert!(r.ok, "job replies must flow while a STATS hog is blocked: {r:?}");
    }
    // Closing the hog socket errors its blocked writer out so shutdown
    // can proceed.
    drop(hog);
    client.shutdown().unwrap();
    handle.join();
}

/// Tentpole: with the process tracer enabled, one job's serve lifecycle
/// is recorded as the accept -> admit -> dequeue -> run -> reply chain,
/// linked by job id, with monotone timestamps along the chain.  (Only
/// this test in the binary drives the global tracer; concurrent tests
/// merely add foreign events, which the job-id filter discards.)
#[test]
fn trace_records_full_serve_job_lifecycle() {
    use tetris::trace::{self, Arg, Phase};
    let handle = start_server(ServeConfig {
        addr: "127.0.0.1:0".into(),
        dispatchers: 1,
        scale: 0.05,
        ..Default::default()
    });
    let mut client = Client::connect(handle.addr).unwrap();
    let id = format!("traced-{}", trace::fresh_tag());
    trace::enable();
    let r = client
        .submit(&JobSpec {
            id: id.clone(),
            bench: "heat1d".into(),
            shape: Some(vec![24]),
            steps: 8,
            seed: 77,
            ..Default::default()
        })
        .unwrap();
    trace::disable();
    assert!(r.ok, "{r:?}");
    let ours: Vec<trace::Event> = trace::drain()
        .into_iter()
        .flat_map(|t| t.events)
        .filter(|e| {
            e.args.iter().any(|(k, v)| *k == "job" && matches!(v, Arg::S(s) if *s == id))
        })
        .collect();
    for want in ["accept", "admit", "dequeue", "reply"] {
        assert_eq!(
            ours.iter()
                .filter(|e| e.phase == Phase::Instant && e.cat == "serve" && e.name == want)
                .count(),
            1,
            "exactly one {want} instant for {id}: {ours:?}"
        );
    }
    assert_eq!(
        ours.iter().filter(|e| e.phase == Phase::Begin && e.name == "run").count(),
        1,
        "one dispatcher run span for {id}: {ours:?}"
    );
    let ts = |name: &str| ours.iter().find(|e| e.phase != Phase::End && e.name == name).unwrap().ts_us;
    assert!(ts("accept") <= ts("admit"), "accept precedes admit");
    assert!(ts("admit") <= ts("dequeue"), "admit precedes dequeue");
    assert!(ts("dequeue") <= ts("run"), "dequeue precedes run");
    assert!(ts("run") <= ts("reply"), "run begin precedes reply");
    client.shutdown().unwrap();
    handle.join();
}
