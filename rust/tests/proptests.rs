//! Property-based tests on coordinator and engine invariants.
//!
//! The vendored crate set has no `proptest`, so cases are generated from
//! the in-tree SplitMix64 (deterministic, seeds printed on failure) — the
//! same "many random cases + invariant assertions" methodology.

use tetris::analyze::{TaskKind, WindowPlan};
use tetris::coordinator::partition::{capacity_units, Partition};
use tetris::coordinator::{tuner, CommLedger, CommModel, NativeWorker, Overlap, Scheduler, Worker};
use tetris::stencil::{reference, spec, Boundary, Field};
use tetris::util::prng::SplitMix64;

const CASES: usize = 60;

fn rng_for(case: usize) -> SplitMix64 {
    SplitMix64::new(0x7e57 + case as u64)
}

fn pick(rng: &mut SplitMix64, lo: usize, hi: usize) -> usize {
    lo + (rng.next_u64() as usize) % (hi - lo + 1)
}

/// Partition invariant: spans are contiguous, ordered, cover the domain
/// exactly once, and respect capacities.
#[test]
fn prop_partition_covers_domain_exactly() {
    for case in 0..CASES {
        let mut rng = rng_for(case);
        let nworkers = pick(&mut rng, 1, 5);
        let unit = pick(&mut rng, 1, 16);
        let units = pick(&mut rng, nworkers, 64);
        let weights: Vec<f64> = (0..nworkers).map(|_| 0.05 + rng.next_f64()).collect();
        let caps: Vec<usize> = (0..nworkers).map(|_| pick(&mut rng, units, 2 * units)).collect();
        let p = Partition::balanced(unit, units, &weights, &caps);
        assert_eq!(p.total_units(), units, "case {case}");
        let spans = p.spans();
        assert_eq!(spans[0].0, 0);
        assert_eq!(spans.last().unwrap().1, units * unit);
        for w in spans.windows(2) {
            assert_eq!(w[0].1, w[1].0, "case {case}: gap/overlap");
        }
        for (i, &s) in p.shares.iter().enumerate() {
            assert!(s <= caps[i], "case {case}: capacity violated");
        }
        let ratios: f64 = (0..nworkers).map(|i| p.ratio(i)).sum();
        assert!((ratios - 1.0).abs() < 1e-12);
    }
}

/// Squeezer invariant: whatever the capacities (if feasible), nothing is
/// lost and nothing exceeds its cap.
#[test]
fn prop_memory_squeezer_feasible_never_loses_units() {
    for case in 0..CASES {
        let mut rng = rng_for(1000 + case);
        let n = pick(&mut rng, 2, 4);
        let units = pick(&mut rng, 4, 40);
        // Feasible: total capacity >= units.
        let mut caps: Vec<usize> = (0..n).map(|_| pick(&mut rng, 1, units)).collect();
        while caps.iter().sum::<usize>() < units {
            let i = pick(&mut rng, 0, n - 1);
            caps[i] += 1;
        }
        let weights: Vec<f64> = (0..n).map(|_| 0.01 + rng.next_f64() * 10.0).collect();
        let p = Partition::balanced(1, units, &weights, &caps);
        assert_eq!(p.total_units(), units, "case {case}");
        for (s, c) in p.shares.iter().zip(&caps) {
            assert!(s <= c, "case {case}");
        }
    }
}

/// Halo width invariant: a scheduler run equals the reference evolution
/// for random shapes / partitions / Tb (i.e. halo = radius*Tb is
/// sufficient AND the writeback covers every cell exactly once).
#[test]
fn prop_scheduler_equals_reference() {
    for case in 0..12 {
        let mut rng = rng_for(2000 + case);
        let benches = ["heat1d", "star1d5p", "heat2d", "box2d9p", "heat3d"];
        let s = spec::get(benches[case % benches.len()]).unwrap();
        let tb = pick(&mut rng, 1, 3);
        let unit = pick(&mut rng, 2, 5);
        let nworkers = pick(&mut rng, 1, 3);
        let shares: Vec<usize> = (0..nworkers).map(|_| pick(&mut rng, 1, 4)).collect();
        let units: usize = shares.iter().sum();
        let mut shape = vec![units * unit];
        for _ in 1..s.ndim {
            shape.push(pick(&mut rng, 4, 9));
        }
        let core = Field::random(&shape, rng.next_u64());
        let engines = ["naive", "autovec", "simd", "tiled", "tetris-cpu"];
        let workers: Vec<Box<dyn Worker>> = (0..nworkers)
            .map(|i| {
                Box::new(NativeWorker::new(
                    tetris::engine::by_name(engines[(case + i) % engines.len()], 2).unwrap(),
                    1 << 30,
                )) as Box<dyn Worker>
            })
            .collect();
        // Rotate through all three boundary conditions across cases.
        let boundary = match case % 3 {
            0 => Boundary::Dirichlet(rng.next_f64()),
            1 => Boundary::Neumann,
            _ => Boundary::Periodic,
        };
        let sched = Scheduler {
            spec: s.clone(),
            tb,
            workers,
            partition: Partition::rows(unit, shares),
            comm_model: CommModel::default(),
            boundary,
            adapt_every: 0,
            // rotate leader-loop modes across cases: serial, pipelined
            // and auto must all match the oracles
            overlap: [Overlap::Off, Overlap::On, Overlap::Auto][case % 3],
        };
        let steps = tb * pick(&mut rng, 1, 3);
        let (got, metrics) = sched.run(&core, steps).unwrap();
        let want =
            tetris::coordinator::pipeline::reference_evolution(&core, &s, steps, tb, boundary);
        assert!(
            got.allclose(&want, 1e-11, 1e-13),
            "case {case} ({}, tb={tb}, {boundary}): maxdiff={}",
            s.name,
            got.max_abs_diff(&want)
        );
        assert_eq!(metrics.blocks, steps / tb);
        if boundary == Boundary::Periodic {
            // the periodic scheduler path must also match the torus oracle
            let torus = reference::evolve_periodic(&core, &s, steps);
            assert!(
                got.allclose(&torus, 1e-11, 1e-13),
                "case {case} ({}): periodic oracle maxdiff={}",
                s.name,
                got.max_abs_diff(&torus)
            );
        }
    }
}

/// Comm batching invariant: ledger bytes are conserved, and centralized
/// cost <= split cost for every alpha >= 0.
#[test]
fn prop_comm_batching_conserves_bytes() {
    for case in 0..CASES {
        let mut rng = rng_for(3000 + case);
        let mut ledger = CommLedger::default();
        let mut total = 0usize;
        for _ in 0..pick(&mut rng, 1, 20) {
            let bytes = pick(&mut rng, 8, 1 << 20);
            let tb = pick(&mut rng, 1, 16);
            ledger.record_exchange(bytes, tb);
            total += bytes;
        }
        assert_eq!(ledger.bytes, total);
        let model = CommModel { alpha: rng.next_f64() * 1e-4, beta: rng.next_f64() * 1e-9 };
        let (central, split) = ledger.modeled_cost(&model);
        assert!(central <= split + 1e-15, "case {case}");
    }
}

/// Tuner invariant: tuned partitions respect capacity and weight order
/// (faster worker never gets fewer units than a strictly slower one,
/// capacity permitting).
#[test]
fn prop_tuner_orders_by_speed() {
    for case in 0..CASES {
        let mut rng = rng_for(4000 + case);
        let n = pick(&mut rng, 2, 4);
        let units = pick(&mut rng, 2 * n, 60);
        let profile: Vec<f64> = (0..n).map(|_| 1e-4 + rng.next_f64() * 1e-2).collect();
        let workers: Vec<Box<dyn Worker>> = (0..n)
            .map(|_| {
                Box::new(NativeWorker::new(
                    tetris::engine::by_name("simd", 1).unwrap(),
                    1 << 40,
                )) as Box<dyn Worker>
            })
            .collect();
        let p = tuner::tune(1, units, 64, &profile, &workers);
        assert_eq!(p.total_units(), units);
        for i in 0..n {
            for j in 0..n {
                if profile[i] < profile[j] * 0.99 {
                    assert!(
                        p.shares[i] + 1 >= p.shares[j],
                        "case {case}: faster worker {i} got {} vs {}",
                        p.shares[i],
                        p.shares[j]
                    );
                }
            }
        }
    }
}

/// Engine linearity + fixed-point invariants on random engines/benchmarks.
#[test]
fn prop_engines_preserve_constant_fields() {
    for case in 0..24 {
        let mut rng = rng_for(5000 + case);
        let all = spec::benchmarks();
        let s = &all[case % all.len()];
        let names = ["autovec", "simd", "tiled", "tessellate", "tetris-cpu"];
        let eng = tetris::engine::by_name(names[case % names.len()], 2).unwrap();
        let steps = pick(&mut rng, 1, 3);
        let v = rng.next_f64() * 10.0;
        let ext: Vec<usize> = (0..s.ndim).map(|_| 8 + 2 * s.radius * steps).collect();
        let out = eng.block(s, &Field::full(&ext, v), steps);
        // normalized coefficients: constant in -> same constant out
        assert!((out.min() - v).abs() < 1e-10 && (out.max() - v).abs() < 1e-10,
            "case {case}: {} on {}", names[case % names.len()], s.name);
    }
}

/// capacity_units monotonicity.
#[test]
fn prop_capacity_units_monotone() {
    for case in 0..CASES {
        let mut rng = rng_for(6000 + case);
        let unit = pick(&mut rng, 1, 128);
        let rest = pick(&mut rng, 1, 4096);
        let a = pick(&mut rng, 0, 1 << 24);
        let b = a + pick(&mut rng, 0, 1 << 24);
        assert!(capacity_units(a, unit, rest) <= capacity_units(b, unit, rest));
    }
}

/// Random partition/boundary/field/window draw for the race-checker
/// properties: shares may be zero (squeezed-out workers), the window may
/// start at either parity, halos may dwarf individual slabs.
fn random_window_plan(rng: &mut SplitMix64, case: usize, min_bw: usize) -> WindowPlan {
    let nw = pick(rng, 1, 5);
    let mut shares: Vec<usize> = (0..nw).map(|_| pick(rng, 0, 6)).collect();
    if shares.iter().sum::<usize>() == 0 {
        shares[pick(rng, 0, nw - 1)] = pick(rng, 1, 6);
    }
    let p = Partition::rows(pick(rng, 1, 3), shares);
    let spans = p.spans();
    let rows = spans.last().unwrap().1;
    let halo = pick(rng, 1, 4);
    let nf = pick(rng, 1, 3);
    let bw = pick(rng, min_bw, 4);
    let b0 = pick(rng, 0, 3);
    let boundary = match case % 3 {
        0 => Boundary::Dirichlet(rng.next_f64()),
        1 => Boundary::Neumann,
        _ => Boundary::Periodic,
    };
    WindowPlan::build(&spans, halo, rows, boundary, nf, b0, bw)
}

/// Race-checker soundness over the pipelined leader's real dependency
/// scheme: every window plan the scheduler could build — any partition
/// (zero shares included), boundary, field count, window length and
/// start parity — is race-free with NO over-synchronizing and NO
/// redundant edges (the §5.3 edge set is exactly minimal).
#[test]
fn prop_window_plans_race_free_and_minimal() {
    for case in 0..CASES {
        let mut rng = rng_for(7000 + case);
        let plan = random_window_plan(&mut rng, case, 1);
        let r = plan.model.check();
        assert!(r.is_clean(), "case {case}: {:?}", r.races);
        assert!(r.oversync.is_empty(), "case {case}: {:?}", r.oversync);
        assert_eq!(r.redundant_edges, 0, "case {case}");
    }
}

/// Detector completeness: dropping ANY single writeback -> assemble
/// dependency from any window plan produces at least one reported race
/// (every cross-block edge of the scheme is load-bearing, and the
/// checker sees it go missing).
#[test]
fn prop_dropped_assemble_dep_always_races() {
    for case in 0..CASES {
        let mut rng = rng_for(8000 + case);
        let plan = random_window_plan(&mut rng, case, 2);
        let k = pick(&mut rng, 1, plan.bw - 1);
        let f = pick(&mut rng, 0, plan.nf - 1);
        let w = pick(&mut rng, 0, plan.nw - 1);
        let a_id = plan.id(k, f, w, TaskKind::Assemble);
        let deps = plan.model.deps[a_id].clone();
        assert!(!deps.is_empty(), "case {case}: block-{k} assembles always have owners");
        let victim = deps[pick(&mut rng, 0, deps.len() - 1)];
        let mut m = plan.model.clone();
        assert!(m.drop_dep(a_id, victim));
        let races = m.races();
        assert!(
            !races.is_empty(),
            "case {case}: dropping dep #{victim} of assemble #{a_id} must surface a race"
        );
    }
}

/// Grid tiling invariant: for any Wy×Wx partition — zero-share runs
/// and zero-width bands included — the per-worker rects cover every
/// cell of the domain exactly once, and `worker_cells` agrees with the
/// rect areas.
#[test]
fn prop_grid_rects_tile_domain_exactly() {
    for case in 0..CASES {
        let mut rng = rng_for(9000 + case);
        let wx = pick(&mut rng, 1, 4);
        let wy = pick(&mut rng, 1, 4);
        let unit = pick(&mut rng, 1, 3);
        let mut shares: Vec<usize> = (0..wx).map(|_| pick(&mut rng, 0, 5)).collect();
        if shares.iter().sum::<usize>() == 0 {
            shares[pick(&mut rng, 0, wx - 1)] = pick(&mut rng, 1, 5);
        }
        let mut cols: Vec<usize> = (0..wy).map(|_| pick(&mut rng, 0, 6)).collect();
        if cols.iter().sum::<usize>() == 0 {
            cols[pick(&mut rng, 0, wy - 1)] = pick(&mut rng, 1, 6);
        }
        let p = Partition::rows(unit, shares).with_bands(cols);
        let n_rows = p.total_units() * unit;
        let n_cols = if p.cols.is_empty() { pick(&mut rng, 1, 8) } else { p.total_cols() };
        let rects = p.rects(n_cols);
        assert_eq!(rects.len(), p.workers(), "case {case}");
        let mut hits = vec![0u32; n_rows * n_cols];
        for ((r0, r1), (c0, c1)) in &rects {
            for r in *r0..*r1 {
                for c in *c0..*c1 {
                    hits[r * n_cols + c] += 1;
                }
            }
        }
        assert!(
            hits.iter().all(|&h| h == 1),
            "case {case}: {}x{} rects don't tile {n_rows}x{n_cols} exactly once",
            p.wy(),
            p.wx()
        );
        let cells = if p.cols.is_empty() { p.worker_cells(n_cols) } else { p.worker_cells(1) };
        for (w, ((r0, r1), (c0, c1))) in rects.iter().enumerate() {
            assert_eq!(cells[w], (r1 - r0) * (c1 - c0), "case {case}: worker {w}");
        }
    }
}

/// Random Wy×Wx grid draw for the race-checker properties (wy >= 2 so
/// the 2-D owner scheme — corner edges included — is actually
/// exercised; zero-share runs and zero-width bands stay in the pool).
fn random_grid_window_plan(rng: &mut SplitMix64, case: usize, min_bw: usize) -> (WindowPlan, usize) {
    let wx = pick(rng, 1, 3);
    let wy = pick(rng, 2, 3);
    let mut shares: Vec<usize> = (0..wx).map(|_| pick(rng, 0, 5)).collect();
    if shares.iter().sum::<usize>() == 0 {
        shares[pick(rng, 0, wx - 1)] = pick(rng, 1, 5);
    }
    let mut cols: Vec<usize> = (0..wy).map(|_| pick(rng, 0, 6)).collect();
    while cols.iter().sum::<usize>() < 2 {
        cols[pick(rng, 0, wy - 1)] += 1;
    }
    let p = Partition::rows(pick(rng, 1, 3), shares).with_bands(cols);
    let spans = p.spans();
    let rows = spans.last().unwrap().1;
    let n_cols = p.total_cols();
    let bands = p.bands(n_cols);
    let halo = pick(rng, 1, 3);
    let nf = pick(rng, 1, 2);
    let bw = pick(rng, min_bw, 3);
    let b0 = pick(rng, 0, 3);
    let boundary = match case % 3 {
        0 => Boundary::Dirichlet(rng.next_f64()),
        1 => Boundary::Neumann,
        _ => Boundary::Periodic,
    };
    (WindowPlan::build_grid(&spans, &bands, halo, rows, n_cols, boundary, nf, b0, bw), wx)
}

/// The 2-D mirror of `prop_window_plans_race_free_and_minimal`: every
/// grid window plan — zero-area tiles, any boundary, any parity — is
/// race-free with no over-synchronizing and no redundant edges.  The
/// oversync half is the sharp one: per-axis symmetrization before the
/// product would link the hosts of empty tiles spuriously.
#[test]
fn prop_grid_window_plans_race_free_and_minimal() {
    for case in 0..CASES {
        let mut rng = rng_for(10_000 + case);
        let (plan, _) = random_grid_window_plan(&mut rng, case, 1);
        let r = plan.model.check();
        assert!(r.is_clean(), "case {case}: {:?}", r.races);
        assert!(r.oversync.is_empty(), "case {case}: {:?}", r.oversync);
        assert_eq!(r.redundant_edges, 0, "case {case}");
    }
}

/// Detector completeness on grids, corner exchanges included: dropping
/// any single writeback -> assemble dependency — preferring an edge
/// from a *diagonal* neighbour when the draw has one — must surface a
/// race.  This is the 2-D extension of the 1-D dropped-edge property:
/// corner edges are load-bearing, not belt-and-braces.
#[test]
fn prop_dropped_grid_corner_dep_always_races() {
    let mut corner_cases = 0usize;
    for case in 0..CASES {
        let mut rng = rng_for(11_000 + case);
        let (plan, wx) = random_grid_window_plan(&mut rng, case, 2);
        let k = pick(&mut rng, 1, plan.bw - 1);
        let f = pick(&mut rng, 0, plan.nf - 1);
        let w = pick(&mut rng, 0, plan.nw - 1);
        let a_id = plan.id(k, f, w, TaskKind::Assemble);
        let deps = plan.model.deps[a_id].clone();
        assert!(!deps.is_empty(), "case {case}: block-{k} assembles always have owners");
        // Prefer a dependency on a diagonal tile (both axes differ).
        let (gy, gx) = (w / wx, w % wx);
        let is_corner = |dep: &usize| {
            let o = plan.meta[*dep].worker;
            (o / wx != gy) && (o % wx != gx)
        };
        let victim = match deps.iter().find(|d| is_corner(d)) {
            Some(&d) => {
                corner_cases += 1;
                d
            }
            None => deps[pick(&mut rng, 0, deps.len() - 1)],
        };
        let mut m = plan.model.clone();
        assert!(m.drop_dep(a_id, victim));
        assert!(
            !m.races().is_empty(),
            "case {case}: dropping dep #{victim} of assemble #{a_id} must surface a race"
        );
    }
    assert!(corner_cases > 0, "the draw never produced a corner exchange to drop");
}

/// PRNG fill agrees with reference::block determinism: same seed, same
/// result — across engines.
#[test]
fn prop_engines_deterministic() {
    let s = spec::get("box2d25p").unwrap();
    let u = Field::random(&[20, 20], 777);
    let a = reference::block(&u, &s, 2);
    for _ in 0..3 {
        let b = reference::block(&Field::random(&[20, 20], 777), &s, 2);
        assert_eq!(a, b);
    }
}
