//! Paper §5.3: centralized communication launch — k(α + nβ) vs α + k·n·β
//! under the α+β latency-bandwidth model, plus measured halo-copy
//! bandwidth on this host (the memcpy that stands in for the PCIe
//! transfer on a real two-device deployment).
//!
//! Run: `cargo bench --bench comm`

use std::time::Instant;

use tetris::stencil::Field;

fn main() {
    // Modeled: the paper's launch-latency argument.
    tetris::bench::run_comm();

    // Measured: actual halo extract+paste cost per block on this host.
    println!("== measured halo-copy cost (host memcpy standing in for PCIe) ==");
    for (rows, width) in [(4usize, 392usize), (8, 392), (16, 392), (8, 4096)] {
        let global = Field::random(&[512, width], 1);
        let mut slab = Field::zeros(&[rows, width]);
        let reps = 2000;
        let t0 = Instant::now();
        for i in 0..reps {
            let off = (i * 17) % (512 - rows);
            slab = global.extract(&[off, 0], &[rows, width]);
        }
        let dt = t0.elapsed().as_secs_f64() / reps as f64;
        let bytes = rows * width * 8;
        println!(
            "  halo {rows}x{width} ({:>8} B): {:>8.2} us/copy, {:>6.2} GB/s",
            bytes,
            dt * 1e6,
            bytes as f64 / dt / 1e9
        );
        let _ = slab.len();
    }
}
