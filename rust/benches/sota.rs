//! Paper Fig. 13: state-of-the-art comparison — DataReorg, AutoVec,
//! Pluto, Folding, Brick, AN5D, Tetris(CPU), Tetris(GPU), Tetris — on all
//! eight Table-1 benchmarks.
//!
//! Run: `cargo bench --bench sota`
//! Env: TETRIS_BENCH_SCALE (default 0.25), TETRIS_THREADS (default 2).

fn main() {
    let scale: f64 = std::env::var("TETRIS_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.25);
    let threads: usize = std::env::var("TETRIS_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let rt = tetris::runtime::XlaService::spawn_default().ok();
    if rt.is_none() {
        println!("(no artifacts: Tetris(GPU)/Tetris rows skipped — run `make artifacts`)");
    }
    tetris::bench::run_sota(rt.as_ref(), scale, threads);
}
