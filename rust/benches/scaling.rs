//! Paper Fig. 14: scalability of Tetris (CPU) with core count, plus the
//! auto-tuned GPU:CPU scheduling ratio of the heterogeneous run.
//!
//! NOTE: this CI node exposes a single hardware core; thread counts above
//! 1 measure oversubscription, so the expected shape here is a flat line
//! (documented in EXPERIMENTS.md).  On a multi-core host the same bench
//! produces the paper's near-linear curve.
//!
//! Run: `cargo bench --bench scaling`

fn main() {
    let scale: f64 = std::env::var("TETRIS_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.25);
    let max_threads: usize = std::env::var("TETRIS_MAX_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get().max(4)).unwrap_or(4)
        });
    let rt = tetris::runtime::XlaService::spawn_default().ok();
    tetris::bench::run_scaling(rt.as_ref(), scale, max_threads);
}
