//! §3.2 Tensor Trapezoid Folding study: the banded-matmul (MXU) artifact
//! vs the VPU step artifact for every 2-D benchmark, with the analytical
//! MXU-utilization / VMEM estimates the real-TPU discussion is based on
//! (interpret-mode CPU timings are NOT a TPU proxy — see DESIGN.md §8).
//!
//! Run: `make artifacts && cargo bench --bench mxu`

fn main() {
    match tetris::runtime::XlaService::spawn_default() {
        Ok(rt) => {
            tetris::bench::run_mxu(&rt).expect("mxu bench");
        }
        Err(e) => println!("mxu bench needs artifacts (`make artifacts`): {e}"),
    }
}
