//! Paper Fig. 12: performance breakdown of Tetris optimizations on
//! Star-1D5P, Box-2D25P and Box-3D27P.
//!
//! Rungs: naive -> +Tessellate Tiling -> +Vector Skewed Swizzling ->
//! +multicore (Tetris CPU) -> +MXU trapezoid folding -> +checkerboard
//! temporal block (both via PJRT artifacts when built).
//!
//! Run: `cargo bench --bench breakdown`
//! Env: TETRIS_BENCH_SCALE (default 0.25), TETRIS_THREADS (default 2).

fn main() {
    let scale: f64 = std::env::var("TETRIS_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.25);
    let threads: usize = std::env::var("TETRIS_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let rt = tetris::runtime::XlaService::spawn_default().ok();
    if rt.is_none() {
        println!("(no artifacts: MXU/checkerboard rungs skipped — run `make artifacts`)");
    }
    tetris::bench::run_breakdown(rt.as_ref(), scale, threads);
}
