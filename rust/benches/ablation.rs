//! Ablation study for the design choices DESIGN.md calls out:
//!
//!  A. temporal block depth Tb (how deep should fused time tiles be?)
//!  B. tessellation tile budget (pyramid working-set size vs L2)
//!  C. inner-loop strategy: tap-outer axpy vs fused single-pass rows
//!  D. tessellation (non-redundant) vs AN5D-style overlapped blocking
//!
//! Run: `cargo bench --bench ablation`
//! Env: TETRIS_ABL_SCALE (default 1.0 — out-of-cache sizes make the
//! temporal ablations meaningful).

use tetris::bench::{print_table, time_engine, Row};
use tetris::engine::tessellate::{Inner, TessellateEngine};
use tetris::stencil::spec;

fn main() {
    let scale: f64 = std::env::var("TETRIS_ABL_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    let s2 = spec::get("heat2d").unwrap();
    let core2: Vec<usize> = vec![(512.0 * scale) as usize, (512.0 * scale) as usize];

    // A: Tb sweep at fixed total steps.
    let total = 16;
    let mut rows = Vec::new();
    let mut base = 0.0;
    for tb in [1usize, 2, 4, 8] {
        let eng = TessellateEngine::tetris(1);
        let (g, _) = time_engine(&eng, &s2, &core2, total, tb);
        if tb == 1 {
            base = g;
        }
        rows.push(Row {
            label: format!("Tb={tb}"),
            gstencils: g,
            speedup: g / base,
            extra: format!("halo {}", s2.radius * tb),
        });
    }
    print_table("Ablation A: temporal depth (heat2d, tetris-cpu)", &rows);

    // B: tile budget sweep (explicit tile widths standing in for budgets).
    let mut rows = Vec::new();
    let mut base = 0.0;
    for (label, tile_w) in
        [("64 rows", 64usize), ("128 rows", 128), ("256 rows", 256), ("auto", 0)]
    {
        let eng = TessellateEngine {
            inner: Inner::Fused,
            threads: 1,
            tile_w: if tile_w == 0 { None } else { Some(tile_w) },
        };
        let (g, _) = time_engine(&eng, &s2, &core2, total, 4);
        if base == 0.0 {
            base = g;
        }
        rows.push(Row {
            label: label.into(),
            gstencils: g,
            speedup: g / base,
            extra: String::new(),
        });
    }
    print_table("Ablation B: tessellation tile width (heat2d)", &rows);

    // C: inner loop strategy inside the same tessellation schedule.
    let mut rows = Vec::new();
    let mut base = 0.0;
    for (label, inner) in [("tap-outer axpy", Inner::Axpy), ("fused rows", Inner::Fused)] {
        let eng = TessellateEngine { inner, threads: 1, tile_w: None };
        let (g, _) = time_engine(&eng, &s2, &core2, total, 4);
        if base == 0.0 {
            base = g;
        }
        rows.push(Row {
            label: label.into(),
            gstencils: g,
            speedup: g / base,
            extra: String::new(),
        });
    }
    print_table("Ablation C: inner rows (heat2d, tessellated)", &rows);

    // D: non-redundant tessellation vs overlapped temporal blocking,
    // box kernel where redundancy costs most (r=2).
    let s25 = spec::get("box2d25p").unwrap();
    let core25: Vec<usize> = vec![(384.0 * scale) as usize, (384.0 * scale) as usize];
    let mut rows = Vec::new();
    let mut base = 0.0;
    for tb in [2usize, 4] {
        for (label, eng) in [
            (
                format!("tessellate Tb={tb}"),
                Box::new(TessellateEngine::tetris(1)) as Box<dyn tetris::engine::Engine>,
            ),
            (
                format!("an5d-overlap Tb={tb}"),
                Box::new(tetris::baselines::an5d::An5dEngine { tile_w: 64, threads: 1 }),
            ),
        ] {
            let (g, _) = time_engine(eng.as_ref(), &s25, &core25, 2 * tb, tb);
            if base == 0.0 {
                base = g;
            }
            rows.push(Row { label, gstencils: g, speedup: g / base, extra: String::new() });
        }
    }
    print_table("Ablation D: non-redundant vs overlapped (box2d25p)", &rows);
}
