//! Normalized half-open row-interval sets — the row-granularity shape
//! language of the access summaries.
//!
//! The partition splits dim 0 only, so every buffer access the checker
//! reasons about is "these dim-0 rows of that buffer".  An
//! [`IntervalSet`] keeps its intervals sorted, disjoint and
//! non-adjacent, which makes overlap and subset queries a linear merge
//! and keeps `Debug` output humane in race reports.

/// A set of `usize` points stored as sorted, coalesced half-open
/// `[start, end)` intervals.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct IntervalSet {
    ivs: Vec<(usize, usize)>,
}

impl IntervalSet {
    pub fn empty() -> IntervalSet {
        IntervalSet::default()
    }

    /// The single interval `[start, end)`; empty when `start >= end`.
    pub fn single(start: usize, end: usize) -> IntervalSet {
        let mut s = IntervalSet::empty();
        s.insert(start, end);
        s
    }

    /// The whole axis, `[0, usize::MAX)` — the "this access does not
    /// constrain that axis" element of the per-axis interval products.
    /// Using a real interval (rather than an empty-means-full sentinel)
    /// keeps intersection/subset algebra uniform across axes.
    pub fn full() -> IntervalSet {
        IntervalSet::single(0, usize::MAX)
    }

    /// Is this the [`IntervalSet::full`] axis?
    pub fn is_full(&self) -> bool {
        self.ivs == [(0, usize::MAX)]
    }

    pub fn is_empty(&self) -> bool {
        self.ivs.is_empty()
    }

    /// Number of points covered.
    pub fn len(&self) -> usize {
        self.ivs.iter().map(|&(a, b)| b - a).sum()
    }

    pub fn intervals(&self) -> &[(usize, usize)] {
        &self.ivs
    }

    /// Insert `[start, end)`, coalescing with abutting/overlapping
    /// intervals so the representation stays canonical.
    pub fn insert(&mut self, start: usize, end: usize) {
        if start >= end {
            return;
        }
        let (mut start, mut end) = (start, end);
        // Keep intervals strictly before the new one; merge the rest.
        let mut out = Vec::with_capacity(self.ivs.len() + 1);
        let mut placed = false;
        for &(a, b) in &self.ivs {
            if b < start {
                out.push((a, b));
            } else if a > end {
                if !placed {
                    out.push((start, end));
                    placed = true;
                }
                out.push((a, b));
            } else {
                start = start.min(a);
                end = end.max(b);
            }
        }
        if !placed {
            out.push((start, end));
        }
        self.ivs = out;
    }

    /// Does any point belong to both sets?  Linear two-pointer merge.
    pub fn intersects(&self, other: &IntervalSet) -> bool {
        self.first_overlap(other).is_some()
    }

    /// The lowest overlapping interval, if any — used to name the
    /// conflicting rows in a race report.
    pub fn first_overlap(&self, other: &IntervalSet) -> Option<(usize, usize)> {
        let (mut i, mut j) = (0, 0);
        while i < self.ivs.len() && j < other.ivs.len() {
            let (a0, a1) = self.ivs[i];
            let (b0, b1) = other.ivs[j];
            let lo = a0.max(b0);
            let hi = a1.min(b1);
            if lo < hi {
                return Some((lo, hi));
            }
            if a1 <= b1 {
                i += 1;
            } else {
                j += 1;
            }
        }
        None
    }

    /// Is every point of `self` also in `other`?  (The dynamic-mode
    /// validation direction: observed ⊆ declared.)
    pub fn subset_of(&self, other: &IntervalSet) -> bool {
        let mut j = 0;
        'outer: for &(a, b) in &self.ivs {
            while j < other.ivs.len() {
                let (c, d) = other.ivs[j];
                if a >= c && b <= d {
                    continue 'outer;
                }
                if d <= a {
                    j += 1;
                } else {
                    return false;
                }
            }
            return false;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_coalesces_and_sorts() {
        let mut s = IntervalSet::empty();
        s.insert(5, 7);
        s.insert(0, 2);
        s.insert(9, 12);
        assert_eq!(s.intervals(), &[(0, 2), (5, 7), (9, 12)]);
        // abutting intervals merge
        s.insert(2, 5);
        assert_eq!(s.intervals(), &[(0, 7), (9, 12)]);
        // spanning insert swallows everything
        s.insert(1, 20);
        assert_eq!(s.intervals(), &[(0, 20)]);
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn empty_inserts_are_noops() {
        let mut s = IntervalSet::single(3, 3);
        assert!(s.is_empty());
        s.insert(7, 7);
        s.insert(9, 8);
        assert!(s.is_empty());
        assert!(!s.intersects(&IntervalSet::single(0, 100)));
    }

    #[test]
    fn intersects_and_first_overlap() {
        let mut a = IntervalSet::empty();
        a.insert(0, 4);
        a.insert(10, 14);
        assert!(a.intersects(&IntervalSet::single(3, 5)));
        assert!(!a.intersects(&IntervalSet::single(4, 10)));
        assert_eq!(a.first_overlap(&IntervalSet::single(12, 20)), Some((12, 14)));
        let mut b = IntervalSet::empty();
        b.insert(2, 3);
        b.insert(11, 12);
        assert_eq!(a.first_overlap(&b), Some((2, 3)));
    }

    #[test]
    fn subset_queries() {
        let mut a = IntervalSet::empty();
        a.insert(0, 4);
        a.insert(10, 14);
        assert!(IntervalSet::single(1, 3).subset_of(&a));
        assert!(IntervalSet::single(10, 14).subset_of(&a));
        assert!(!IntervalSet::single(3, 11).subset_of(&a));
        assert!(IntervalSet::empty().subset_of(&a));
        assert!(a.subset_of(&a));
        let mut both = IntervalSet::empty();
        both.insert(0, 2);
        both.insert(12, 13);
        assert!(both.subset_of(&a));
        assert!(!a.subset_of(&both));
    }
}
