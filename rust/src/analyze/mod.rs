//! Static region-aliasing race analysis for the scheduled task DAGs
//! (`tetris analyze`).
//!
//! The §5.3 pipelined leader loop is only race-free because its
//! dependency edges exactly cover its buffer aliasing — a proof that
//! used to live in reviewers' heads.  This module makes it a machine
//! artifact: every task declares `(buffer, parity, row-interval)` read/
//! write summaries ([`checker`]), the DAGs are modeled straight from
//! the code that builds them ([`model`] — the pipelined leader now
//! constructs its real `TaskGraph` *from* [`WindowPlan`], so the model
//! cannot drift), and a bitset-transitive-closure checker reports
//! unordered conflicts (races) plus over-synchronizing edges (lost
//! overlap).  Debug builds additionally log real `Field` region traffic
//! per task and assert observed ⊆ declared ([`dynamic`]).
//!
//! Everything here is pure, std-only and Miri-friendly; the CLI sweep
//! (`tetris analyze --all`) covers boundary × grid shape (Wy×Wx, zero
//! shares and zero-width bands included) × fields × window length ×
//! window parity.

pub mod checker;
pub mod dynamic;
pub mod interval;
pub mod model;

pub use checker::{
    check, races, BufferId, Conflict, ConflictKind, Oversync, Region, Report, TaskAccess,
};
pub use dynamic::{Collector, TaskScope};
pub use interval::IntervalSet;
pub use model::{wave_model, wave_model_auto, DagModel, TaskKind, TaskMeta, WindowPlan};

use crate::coordinator::Partition;

/// Partition layouts a sweep should try for `nw` workers over `rows`
/// rows: the balanced split, a skewed split, and (when `nw > 1`)
/// zero-share layouts with squeezed-out edge and interior workers —
/// the shapes retunes actually produce.
pub fn sweep_partitions(nw: usize, rows: usize) -> Vec<Partition> {
    assert!(nw >= 1 && rows >= nw.max(2));
    let mut shares_list: Vec<Vec<usize>> = Vec::new();
    shares_list.push(vec![rows / nw; nw]);
    // skew: worker i gets i+1 proportional units
    let weights: usize = (1..=nw).sum();
    let skew: Vec<usize> = (1..=nw).map(|i| i * rows / weights).collect();
    shares_list.push(skew);
    if nw > 1 {
        let mut edge = vec![0usize; nw];
        edge[nw - 1] = 0;
        edge[0] = 0;
        for s in edge.iter_mut().take(nw).skip(1) {
            *s = rows / (nw - 1);
        }
        shares_list.push(edge);
        let mut interior = vec![rows / nw.max(2); nw];
        interior[nw / 2] = 0;
        shares_list.push(interior);
    }
    // Fix up remainders so every layout covers exactly `rows`.
    shares_list
        .into_iter()
        .map(|mut shares| {
            let sum: usize = shares.iter().sum();
            let grow = shares.iter().position(|&s| s > 0).unwrap_or(0);
            shares[grow] += rows - sum.min(rows);
            if sum > rows {
                // over-allocated: shrink the largest share
                let big = (0..shares.len()).max_by_key(|&i| shares[i]).unwrap();
                shares[big] -= sum - rows;
            }
            Partition::rows(1, shares)
        })
        .collect()
}

/// Band-width layouts a sweep should try for `wy` bands over `cols`
/// columns: the balanced split, a skewed split, and (when `wy > 1`) a
/// zero-width band — the dim-1 mirror of [`sweep_partitions`].  `wy=1`
/// yields the single degenerate full-width layout.
pub fn sweep_band_layouts(wy: usize, cols: usize) -> Vec<Vec<usize>> {
    assert!(wy >= 1 && cols >= wy.max(2));
    if wy == 1 {
        return vec![vec![cols]];
    }
    let mut out = vec![crate::coordinator::partition::even_split(cols, wy)];
    let weights: usize = (1..=wy).sum();
    let mut skew: Vec<usize> = (1..=wy).map(|i| i * cols / weights).collect();
    let sum: usize = skew.iter().sum();
    skew[wy - 1] += cols - sum;
    out.push(skew);
    let mut zero = crate::coordinator::partition::even_split(cols, wy - 1);
    zero.insert(wy / 2, 0);
    out.push(zero);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_partitions_cover_rows_exactly() {
        for nw in 1..=5 {
            for rows in [8usize, 16, 24, 37] {
                for p in sweep_partitions(nw, rows) {
                    assert_eq!(p.shares.len(), nw);
                    assert_eq!(p.shares.iter().sum::<usize>(), rows, "nw={nw} rows={rows}");
                    assert_eq!(p.spans().last().unwrap().1, rows);
                }
            }
        }
        // zero-share layouts really appear for nw > 1
        assert!(sweep_partitions(3, 12).iter().any(|p| p.shares.contains(&0)));
    }

    #[test]
    fn sweep_band_layouts_cover_cols_exactly() {
        for wy in 1..=3 {
            for cols in [8usize, 12, 17] {
                for bands in sweep_band_layouts(wy, cols) {
                    assert_eq!(bands.len(), wy);
                    assert_eq!(bands.iter().sum::<usize>(), cols, "wy={wy} cols={cols}");
                }
            }
        }
        assert_eq!(sweep_band_layouts(1, 12), vec![vec![12]]);
        // zero-width bands really appear for wy > 1
        assert!(sweep_band_layouts(2, 8).iter().any(|b| b.contains(&0)));
    }
}
