//! The static region-aliasing race checker.
//!
//! Input: a task DAG (`deps[i]` lists predecessors of task `i`, which
//! must be earlier ids — the same topological-id invariant
//! [`TaskGraph::add`](crate::coordinator::pool::TaskGraph::add)
//! enforces) where every task declares its reads and writes as
//! `(buffer, row-interval set)` summaries.  The checker computes
//! ancestor sets by bitset transitive closure in id order and reports:
//!
//! * **races** — pairs of tasks that both touch the same rows of the
//!   same buffer, at least one writing, with *no* path between them in
//!   the DAG.  One reported race is one missing dependency.
//! * **over-synchronization** — direct edges whose removal would leave
//!   every conflicting pair in the graph still ordered.  Such an edge
//!   buys no safety, only lost overlap; it is a metric, not an error,
//!   because a redundant edge can still be the honest way to express a
//!   dependency scheme.
//!
//! Declarations may over-approximate (declare more rows than a task
//! touches) but must never under-approximate; the debug-build dynamic
//! mode in [`super::dynamic`] enforces that direction against the real
//! `Field` copies.

use std::collections::BTreeMap;
use std::fmt;

use super::interval::IntervalSet;

/// A shared storage location tasks may alias on.  `Global` carries the
/// double-buffer parity explicitly: the two parities of one field are
/// distinct buffers, which is exactly why the pipelined loop's
/// same-block readers and writers do not conflict.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum BufferId {
    /// Padded global of `field` at double-buffer `parity` (rows are
    /// padded dim-0 coordinates).
    Global { field: usize, parity: usize },
    /// Per-(block, field, worker) assembled slab input slot.
    SlabIn(usize),
    /// Per-(block, field, worker) computed slab output slot.
    SlabOut(usize),
    /// The tetris-wave engine's shared read-only input block.
    WaveInput,
    /// Pyramid result cell of tile `k` (tetris-wave).
    Pyramid(usize),
    /// Inverted-gap result cell at boundary `k+1` (tetris-wave).
    Gap(usize),
}

impl fmt::Display for BufferId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BufferId::Global { field, parity } => write!(f, "global[f{field} parity{parity}]"),
            BufferId::SlabIn(i) => write!(f, "slab_in[{i}]"),
            BufferId::SlabOut(i) => write!(f, "slab_out[{i}]"),
            BufferId::WaveInput => write!(f, "wave_input"),
            BufferId::Pyramid(k) => write!(f, "pyramid[{k}]"),
            BufferId::Gap(k) => write!(f, "gap[{k}]"),
        }
    }
}

/// One declared access: a per-axis interval *product* over one buffer —
/// a set of dim-0 rows times a set of dim-1 columns.  1-D summaries (and
/// any access that does not constrain dim 1) use [`IntervalSet::full`]
/// for `cols`, so the degenerate case keeps exactly the old
/// rows-intersect conflict semantics.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Region {
    pub buffer: BufferId,
    pub rows: IntervalSet,
    pub cols: IntervalSet,
}

impl Region {
    /// Rows-only region: dim 1 unconstrained (full width).
    pub fn new(buffer: BufferId, rows: IntervalSet) -> Region {
        Region { buffer, rows, cols: IntervalSet::full() }
    }

    /// Full 2-D region: rows × cols.
    pub fn rect(buffer: BufferId, rows: IntervalSet, cols: IntervalSet) -> Region {
        Region { buffer, rows, cols }
    }
}

/// A task's declared read/write summary plus a human label for reports.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct TaskAccess {
    pub label: String,
    pub reads: Vec<Region>,
    pub writes: Vec<Region>,
}

impl TaskAccess {
    pub fn new(label: impl Into<String>) -> TaskAccess {
        TaskAccess { label: label.into(), reads: Vec::new(), writes: Vec::new() }
    }

    pub fn read(mut self, buffer: BufferId, rows: IntervalSet) -> TaskAccess {
        self.reads.push(Region::new(buffer, rows));
        self
    }

    pub fn write(mut self, buffer: BufferId, rows: IntervalSet) -> TaskAccess {
        self.writes.push(Region::new(buffer, rows));
        self
    }

    /// 2-D read: rows × cols product region.
    pub fn read_rect(
        mut self,
        buffer: BufferId,
        rows: IntervalSet,
        cols: IntervalSet,
    ) -> TaskAccess {
        self.reads.push(Region::rect(buffer, rows, cols));
        self
    }

    /// 2-D write: rows × cols product region.
    pub fn write_rect(
        mut self,
        buffer: BufferId,
        rows: IntervalSet,
        cols: IntervalSet,
    ) -> TaskAccess {
        self.writes.push(Region::rect(buffer, rows, cols));
        self
    }
}

/// W/W or R/W — which sides of a conflicting pair wrote.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ConflictKind {
    WriteWrite,
    ReadWrite,
}

impl fmt::Display for ConflictKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConflictKind::WriteWrite => write!(f, "W/W"),
            ConflictKind::ReadWrite => write!(f, "R/W"),
        }
    }
}

/// A conflicting, unordered task pair — a race.  `a < b` by task id.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Conflict {
    pub a: usize,
    pub b: usize,
    pub a_label: String,
    pub b_label: String,
    pub kind: ConflictKind,
    pub buffer: BufferId,
    /// An example overlapping row range (first overlap found).
    pub rows: (usize, usize),
    /// An example overlapping column range; `(0, usize::MAX)` when
    /// neither side constrained dim 1 (the 1-D degenerate case).
    pub cols: (usize, usize),
}

impl fmt::Display for Conflict {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "race: {} conflict on {} rows [{}, {})",
            self.kind, self.buffer, self.rows.0, self.rows.1
        )?;
        if self.cols != (0, usize::MAX) {
            write!(f, " cols [{}, {})", self.cols.0, self.cols.1)?;
        }
        write!(
            f,
            " between #{} {} and #{} {} (no ordering path)",
            self.a, self.a_label, self.b, self.b_label
        )
    }
}

/// A direct edge that orders no conflict anywhere: removing it keeps
/// every conflicting pair ordered.  Pure lost overlap.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Oversync {
    pub from: usize,
    pub to: usize,
    pub from_label: String,
    pub to_label: String,
}

impl fmt::Display for Oversync {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "over-sync: edge #{} {} -> #{} {} orders no conflict (removable)",
            self.from, self.from_label, self.to, self.to_label
        )
    }
}

/// Checker verdict over one DAG.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub tasks: usize,
    pub edges: usize,
    /// Conflicting pairs that ARE ordered by some path (the good case).
    pub ordered_conflicts: usize,
    pub races: Vec<Conflict>,
    /// Over-synchronizing edges (metric; empty when `edges` is 0 or the
    /// caller asked for races only).
    pub oversync: Vec<Oversync>,
    /// Edges already implied by another path (metric).
    pub redundant_edges: usize,
}

impl Report {
    pub fn is_clean(&self) -> bool {
        self.races.is_empty()
    }

    /// One-line summary for sweep output.
    pub fn summary(&self) -> String {
        format!(
            "{} tasks, {} edges, {} ordered conflicts, {} races, {} over-sync edges, {} redundant edges",
            self.tasks,
            self.edges,
            self.ordered_conflicts,
            self.races.len(),
            self.oversync.len(),
            self.redundant_edges
        )
    }
}

/// Dense ancestor bitsets, one row of `words` u64 words per task.
struct Closure {
    words: usize,
    bits: Vec<u64>,
}

impl Closure {
    /// Ancestors-and-self closure.  Requires topological ids
    /// (`deps[i]` ⊂ `0..i`); `skip` optionally removes one direct edge
    /// `(from, to)` for the over-sync what-if.
    fn build(deps: &[Vec<usize>], skip: Option<(usize, usize)>) -> Closure {
        let n = deps.len();
        let words = n.div_ceil(64);
        let mut bits = vec![0u64; n * words];
        for (i, ds) in deps.iter().enumerate() {
            let (head, row) = bits.split_at_mut(i * words);
            let row = &mut row[..words];
            row[i / 64] |= 1 << (i % 64);
            for &d in ds {
                assert!(d < i, "checker requires topological task ids ({d} -> {i})");
                if skip == Some((d, i)) {
                    continue;
                }
                let drow = &head[d * words..(d + 1) * words];
                for (w, &dw) in row.iter_mut().zip(drow) {
                    *w |= dw;
                }
            }
        }
        Closure { words, bits }
    }

    /// Is `a` an ancestor of `b` (or equal)?
    fn reaches(&self, a: usize, b: usize) -> bool {
        self.bits[b * self.words + a / 64] >> (a % 64) & 1 == 1
    }

    fn ordered(&self, a: usize, b: usize) -> bool {
        self.reaches(a, b) || self.reaches(b, a)
    }
}

/// All conflicting pairs `(a, b, kind, buffer, rows, cols)` with
/// `a < b`, grouped by buffer.  A pair conflicts only when BOTH axes of
/// the two product regions intersect (a shared row range in disjoint
/// column bands is not aliasing).  A pair conflicting on several
/// buffers is reported once per buffer.
fn conflicting_pairs(
    accesses: &[TaskAccess],
) -> Vec<(usize, usize, ConflictKind, BufferId, (usize, usize), (usize, usize))> {
    // Flatten to per-buffer touch lists: (task, region, wrote).
    let mut by_buffer: BTreeMap<BufferId, Vec<(usize, &Region, bool)>> = BTreeMap::new();
    for (t, acc) in accesses.iter().enumerate() {
        for r in &acc.reads {
            by_buffer.entry(r.buffer).or_default().push((t, r, false));
        }
        for r in &acc.writes {
            by_buffer.entry(r.buffer).or_default().push((t, r, true));
        }
    }
    let mut out = Vec::new();
    for (buf, touches) in &by_buffer {
        for (i, &(ta, ra, wa)) in touches.iter().enumerate() {
            for &(tb, rb, wb) in &touches[i + 1..] {
                if ta == tb || (!wa && !wb) {
                    continue;
                }
                if let (Some(rows), Some(cols)) =
                    (ra.rows.first_overlap(&rb.rows), ra.cols.first_overlap(&rb.cols))
                {
                    let (lo, hi) = (ta.min(tb), ta.max(tb));
                    let kind = if wa && wb {
                        ConflictKind::WriteWrite
                    } else {
                        ConflictKind::ReadWrite
                    };
                    out.push((lo, hi, kind, *buf, rows, cols));
                }
            }
        }
    }
    // A task reading AND writing the same rows of one buffer pairs up
    // with a peer twice (R/W and W/W); keep the W/W (stronger) and drop
    // duplicate pair/buffer entries.
    out.sort_by_key(|&(a, b, k, buf, _, _)| (a, b, buf, k == ConflictKind::ReadWrite));
    out.dedup_by_key(|&mut (a, b, _, buf, _, _)| (a, b, buf));
    out
}

/// Race check only — the cheap subset wired into `run_batch` DAG
/// construction behind `debug_assert!`.
pub fn races(deps: &[Vec<usize>], accesses: &[TaskAccess]) -> Vec<Conflict> {
    assert_eq!(deps.len(), accesses.len(), "deps/accesses length mismatch");
    let closure = Closure::build(deps, None);
    conflicting_pairs(accesses)
        .into_iter()
        .filter(|&(a, b, _, _, _, _)| !closure.ordered(a, b))
        .map(|(a, b, kind, buffer, rows, cols)| Conflict {
            a,
            b,
            a_label: accesses[a].label.clone(),
            b_label: accesses[b].label.clone(),
            kind,
            buffer,
            rows,
            cols,
        })
        .collect()
}

/// Full check: races plus the over-synchronization / redundancy edge
/// metrics (each edge gets a what-if closure with that edge removed).
pub fn check(deps: &[Vec<usize>], accesses: &[TaskAccess]) -> Report {
    assert_eq!(deps.len(), accesses.len(), "deps/accesses length mismatch");
    let closure = Closure::build(deps, None);
    let pairs = conflicting_pairs(accesses);

    let mut report = Report {
        tasks: deps.len(),
        edges: deps.iter().map(|d| d.len()).sum(),
        ..Report::default()
    };
    for &(a, b, kind, buffer, rows, cols) in &pairs {
        if closure.ordered(a, b) {
            report.ordered_conflicts += 1;
        } else {
            report.races.push(Conflict {
                a,
                b,
                a_label: accesses[a].label.clone(),
                b_label: accesses[b].label.clone(),
                kind,
                buffer,
                rows,
                cols,
            });
        }
    }

    // Edge metrics: an edge is redundant when the DAG minus that edge
    // still orders its endpoints; it over-synchronizes when the DAG
    // minus that edge still orders every conflicting pair.  Note an
    // edge with no *direct* endpoint conflict can still be essential:
    // the symmetrized anti-dependency edges of the pipelined loop order
    // WAR pairs two hops apart, and correctly escape this metric.
    for (to, ds) in deps.iter().enumerate() {
        for &from in ds {
            let without = Closure::build(deps, Some((from, to)));
            if without.ordered(from, to) {
                report.redundant_edges += 1;
            }
            if pairs.iter().all(|&(a, b, _, _, _, _)| without.ordered(a, b)) {
                report.oversync.push(Oversync {
                    from,
                    to,
                    from_label: accesses[from].label.clone(),
                    to_label: accesses[to].label.clone(),
                });
            }
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn acc(label: &str) -> TaskAccess {
        TaskAccess::new(label)
    }

    const G0: BufferId = BufferId::Global { field: 0, parity: 0 };
    const G1: BufferId = BufferId::Global { field: 0, parity: 1 };

    #[test]
    fn ordered_conflicts_are_not_races() {
        // writer -> reader chain on the same rows: clean.
        let deps = vec![vec![], vec![0]];
        let accesses = vec![
            acc("write").write(G0, IntervalSet::single(0, 8)),
            acc("read").read(G0, IntervalSet::single(2, 6)),
        ];
        let r = check(&deps, &accesses);
        assert!(r.is_clean(), "{:?}", r.races);
        assert_eq!(r.ordered_conflicts, 1);
        assert_eq!(r.redundant_edges, 0);
        assert!(r.oversync.is_empty(), "edge orders the conflict");
    }

    #[test]
    fn unordered_overlap_is_a_race() {
        let deps = vec![vec![], vec![]];
        let accesses = vec![
            acc("writer").write(G0, IntervalSet::single(0, 8)),
            acc("reader").read(G0, IntervalSet::single(4, 12)),
        ];
        let got = races(&deps, &accesses);
        assert_eq!(got.len(), 1);
        assert_eq!((got[0].a, got[0].b), (0, 1));
        assert_eq!(got[0].kind, ConflictKind::ReadWrite);
        assert_eq!(got[0].buffer, G0);
        assert_eq!(got[0].rows, (4, 8));
        assert!(format!("{}", got[0]).contains("writer"));
    }

    #[test]
    fn disjoint_rows_or_buffers_never_conflict() {
        let deps = vec![vec![], vec![], vec![]];
        let accesses = vec![
            acc("a").write(G0, IntervalSet::single(0, 4)),
            acc("b").write(G0, IntervalSet::single(4, 8)), // abutting, disjoint
            acc("c").write(G1, IntervalSet::single(0, 8)), // other parity
        ];
        assert!(races(&deps, &accesses).is_empty());
        // two pure readers never conflict either
        let accesses = vec![
            acc("a").read(G0, IntervalSet::single(0, 8)),
            acc("b").read(G0, IntervalSet::single(0, 8)),
            acc("c"),
        ];
        assert!(races(&deps, &accesses).is_empty());
    }

    #[test]
    fn disjoint_cols_make_shared_rows_conflict_free() {
        // Two unordered writers share rows but live in disjoint column
        // bands — a 2-D grid's side-by-side tiles.  No conflict.
        let deps = vec![vec![], vec![]];
        let accesses = vec![
            acc("west")
                .write_rect(G0, IntervalSet::single(0, 8), IntervalSet::single(0, 4)),
            acc("east")
                .write_rect(G0, IntervalSet::single(0, 8), IntervalSet::single(4, 8)),
        ];
        assert!(races(&deps, &accesses).is_empty());
        // A rows-only (full-width) access DOES conflict with either.
        let accesses = vec![
            acc("west")
                .write_rect(G0, IntervalSet::single(0, 8), IntervalSet::single(0, 4)),
            acc("fullwidth").read(G0, IntervalSet::single(2, 3)),
        ];
        let got = races(&deps, &accesses);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].rows, (2, 3));
        assert_eq!(got[0].cols, (0, 4));
        assert!(format!("{}", got[0]).contains("cols [0, 4)"));
    }

    #[test]
    fn corner_products_conflict_only_on_both_axes() {
        // Diagonal tiles overlap only in the halo corner: both axes must
        // intersect for a conflict, and the reported rect is the corner.
        let deps = vec![vec![], vec![], vec![]];
        let accesses = vec![
            acc("nw")
                .write_rect(G0, IntervalSet::single(0, 6), IntervalSet::single(0, 6)),
            acc("se_corner_reader")
                .read_rect(G0, IntervalSet::single(4, 10), IntervalSet::single(4, 10)),
            acc("far")
                .write_rect(G0, IntervalSet::single(4, 10), IntervalSet::single(20, 30)),
        ];
        let got = races(&deps, &accesses);
        assert_eq!(got.len(), 1, "{got:?}");
        assert_eq!((got[0].a, got[0].b), (0, 1));
        assert_eq!(got[0].rows, (4, 6));
        assert_eq!(got[0].cols, (4, 6));
    }

    #[test]
    fn transitive_ordering_counts() {
        // 0 -> 1 -> 2; 0 and 2 conflict but are ordered through 1.
        let deps = vec![vec![], vec![0], vec![1]];
        let accesses = vec![
            acc("w").write(G0, IntervalSet::single(0, 8)),
            acc("mid"),
            acc("r").read(G0, IntervalSet::single(0, 8)),
        ];
        let r = check(&deps, &accesses);
        assert!(r.is_clean());
        assert_eq!(r.ordered_conflicts, 1);
        // neither edge is individually removable: each breaks the only
        // ordering path for the (0, 2) conflict.
        assert!(r.oversync.is_empty());
    }

    #[test]
    fn ww_reported_over_rw_for_same_pair() {
        // task 1 both reads and writes what task 0 writes → one W/W.
        let deps = vec![vec![], vec![]];
        let accesses = vec![
            acc("a").write(G0, IntervalSet::single(0, 4)),
            acc("b").read(G0, IntervalSet::single(0, 4)).write(G0, IntervalSet::single(0, 4)),
        ];
        let got = races(&deps, &accesses);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].kind, ConflictKind::WriteWrite);
    }

    #[test]
    fn oversync_and_redundancy_metrics() {
        // 0 -> 1 -> 2 carries the only conflict (0 vs 2 through 1);
        // 0 -> 3 orders nothing (task 3 touches nothing) and
        // 0 -> 2 is redundant (implied by 0 -> 1 -> 2).
        let deps = vec![vec![], vec![0], vec![1, 0], vec![0]];
        let accesses = vec![
            acc("w").write(G0, IntervalSet::single(0, 8)),
            acc("mid"),
            acc("r").read(G0, IntervalSet::single(0, 8)),
            acc("idle"),
        ];
        let r = check(&deps, &accesses);
        assert!(r.is_clean());
        assert_eq!(r.redundant_edges, 1, "0->2 is implied");
        let removable: Vec<(usize, usize)> =
            r.oversync.iter().map(|o| (o.from, o.to)).collect();
        assert!(removable.contains(&(0, 3)), "{removable:?}");
        assert!(removable.contains(&(0, 2)), "redundant edges are removable");
        assert!(!removable.contains(&(0, 1)), "load-bearing edge");
        assert!(!removable.contains(&(1, 2)), "load-bearing edge");
    }

    #[test]
    fn anti_dependency_style_edge_is_not_oversync() {
        // The pipelined loop's symmetrization shape in miniature:
        //   0 = read(G0 rows R)      (assemble, block b)
        //   1 = noop                 (paste, block b — other parity)
        //   2 = noop                 (assemble, block b+1)
        //   3 = write(G0 rows R)     (paste, block b+1)
        // Edges 0->1->2->3.  Edge 1->2 has no direct conflict but is
        // the only path ordering the (0, 3) WAR pair.
        let deps = vec![vec![], vec![0], vec![1], vec![2]];
        let accesses = vec![
            acc("assemble_b").read(G0, IntervalSet::single(2, 6)),
            acc("paste_b"),
            acc("assemble_b1"),
            acc("paste_b1").write(G0, IntervalSet::single(0, 8)),
        ];
        let r = check(&deps, &accesses);
        assert!(r.is_clean());
        assert_eq!(r.ordered_conflicts, 1);
        assert!(
            !r.oversync.iter().any(|o| (o.from, o.to) == (1, 2)),
            "anti-dependency carrier must not be flagged: {:?}",
            r.oversync
        );
    }

    #[test]
    #[should_panic(expected = "topological")]
    fn forward_deps_rejected() {
        let deps = vec![vec![1], vec![]];
        let accesses = vec![acc("a"), acc("b")];
        let _ = races(&deps, &accesses);
    }

    #[test]
    fn closure_spans_word_boundaries() {
        // A 130-task chain exercises multi-word bitsets: ends conflict,
        // ordered only through the whole chain.
        let n = 130;
        let mut deps = vec![Vec::new()];
        for i in 1..n {
            deps.push(vec![i - 1]);
        }
        let mut accesses: Vec<TaskAccess> = (0..n).map(|i| acc(&format!("t{i}"))).collect();
        accesses[0] = acc("t0").write(G0, IntervalSet::single(0, 4));
        accesses[n - 1] = acc("last").read(G0, IntervalSet::single(0, 4));
        assert!(races(&deps, &accesses).is_empty());
        // cut one middle link and the ends race
        deps[64] = vec![];
        let got = races(&deps, &accesses);
        assert_eq!(got.len(), 1);
        assert_eq!((got[0].a, got[0].b), (0, n - 1));
    }
}
