//! Access-summary models of the two task DAGs the repo schedules.
//!
//! [`WindowPlan`] is the load-bearing one: it enumerates every task of
//! one pipelined-leader window (`coordinator/pipeline.rs`) — kind,
//! (block, field, worker) coordinates, dependency ids, and declared
//! read/write regions — and `run_batch_pipelined` builds its *real*
//! `TaskGraph` by iterating this plan, so the analyzed DAG and the
//! executed DAG are identical by construction rather than by parallel
//! maintenance.  [`wave_model`] mirrors the tetris-wave engine's
//! pyramid/gap DAG the same way.
//!
//! Conventions: `Global` row coordinates are padded dim-0 indices
//! (`0..n_rows + 2*halo`), matching both `Boundary::source_index` and
//! the writeback paste offsets.  Slot buffers (`SlabIn`/`SlabOut`,
//! `Pyramid`/`Gap`) model the `Mutex<Option<_>>`/`OnceLock` cell itself
//! as the single row `[0, 1)`: each put/take is a whole-cell access, so
//! a chain's handoff conflicts stay visible even for zero-share slabs
//! whose field content is empty.

use crate::stencil::Boundary;

use super::checker::{self, BufferId, Conflict, Report, TaskAccess};
use super::interval::IntervalSet;

/// A task DAG plus its declared access summaries — what the checker
/// consumes and what negative tests mutate.
#[derive(Clone, Debug, Default)]
pub struct DagModel {
    pub deps: Vec<Vec<usize>>,
    pub accesses: Vec<TaskAccess>,
}

impl DagModel {
    pub fn len(&self) -> usize {
        self.deps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.deps.is_empty()
    }

    /// Full report: races + over-sync/redundancy edge metrics.
    pub fn check(&self) -> Report {
        checker::check(&self.deps, &self.accesses)
    }

    /// Races only (the cheap debug-assert path).
    pub fn races(&self) -> Vec<Conflict> {
        checker::races(&self.deps, &self.accesses)
    }

    /// Remove the direct edge `dep -> task` if present (negative-path
    /// testing: a dropped dependency must surface as a reported race).
    pub fn drop_dep(&mut self, task: usize, dep: usize) -> bool {
        let ds = &mut self.deps[task];
        match ds.iter().position(|&d| d == dep) {
            Some(i) => {
                ds.remove(i);
                true
            }
            None => false,
        }
    }
}

/// Pipeline task kinds, in per-chain id order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    Assemble,
    Compute,
    Writeback,
}

/// Where a plan task sits in the window.
#[derive(Clone, Copy, Debug)]
pub struct TaskMeta {
    pub kind: TaskKind,
    /// Absolute block index (`b0 + k`) — parity source.
    pub block: usize,
    /// Block index within the window.
    pub k: usize,
    pub field: usize,
    pub worker: usize,
}

/// The rows of `Global{field, parity}` one slab assembly reads: the
/// boundary-mapped sources of every padded row in `[s, e + 2*halo)` —
/// exactly the `copy_region_from` sources of `assemble_slab` (Dirichlet
/// ghost rows map to no source; they are constant fills).
pub(crate) fn assemble_reads(
    span: (usize, usize),
    halo: usize,
    n_rows: usize,
    boundary: Boundary,
) -> IntervalSet {
    let (s, e) = span;
    let mut rows = IntervalSet::empty();
    for pr in s..e + 2 * halo {
        if let Some(src) = boundary.source_index(pr, halo, n_rows) {
            rows.insert(src, src + 1);
        }
    }
    rows
}

/// One pipelined-leader window as an analyzable plan.  Task ids are
/// `3 * ((k * nf + f) * nw + w) + stage` with stage 0/1/2 = assemble/
/// compute/writeback — the exact order `run_batch_pipelined` registers
/// closures in.
#[derive(Clone, Debug)]
pub struct WindowPlan {
    pub model: DagModel,
    pub meta: Vec<TaskMeta>,
    pub nf: usize,
    pub nw: usize,
    pub bw: usize,
    pub b0: usize,
}

impl WindowPlan {
    /// Mirror of the leader-loop task construction: per `(k, f, w)` an
    /// assemble → compute → writeback chain; block `k > 0` assembles
    /// wait on the symmetric-owner writebacks of block `k - 1`.
    /// Degenerate single-band wrapper of [`WindowPlan::build_grid`] —
    /// the 1-D shape every pre-grid call site keeps.
    pub fn build(
        spans: &[(usize, usize)],
        halo: usize,
        n_rows: usize,
        boundary: Boundary,
        nf: usize,
        b0: usize,
        bw: usize,
    ) -> WindowPlan {
        WindowPlan::build_grid(spans, &[], halo, n_rows, 0, boundary, nf, b0, bw)
    }

    /// 2-D grid plan: workers are the row-major product of dim-0 runs
    /// (`rows`, one span per grid column) and dim-1 bands (`bands`, one
    /// interval per grid row), `w = gy * rows.len() + gx`.  Region
    /// summaries become per-axis interval products and the block-to-
    /// block dependencies become 2-D symmetric-owner sets — edge AND
    /// corner neighbours, since an assemble's halo rect reads into
    /// diagonal tiles.  `bands` with zero or one entry selects the
    /// degenerate path: column summaries stay full-width and `n_cols`
    /// is ignored, which reproduces the pre-grid plan exactly.
    #[allow(clippy::too_many_arguments)]
    pub fn build_grid(
        rows: &[(usize, usize)],
        bands: &[(usize, usize)],
        halo: usize,
        n_rows: usize,
        n_cols: usize,
        boundary: Boundary,
        nf: usize,
        b0: usize,
        bw: usize,
    ) -> WindowPlan {
        let wx = rows.len();
        let grid = bands.len() > 1;
        let wy = if grid { bands.len() } else { 1 };
        let nw = wy * wx;
        let owners = if grid {
            crate::coordinator::pipeline::symmetric_owners_grid(
                rows, bands, halo, n_rows, n_cols, boundary,
            )
        } else {
            crate::coordinator::pipeline::symmetric_owners(rows, halo, n_rows, boundary)
        };
        let mut model = DagModel::default();
        let mut meta = Vec::with_capacity(3 * bw * nf * nw);
        let cell = || IntervalSet::single(0, 1);
        let mut prev_paste: Vec<usize> = Vec::new();
        for k in 0..bw {
            let b = b0 + k;
            let read_par = b % 2;
            let write_par = (b + 1) % 2;
            let mut this_paste = Vec::with_capacity(nf * nw);
            for f in 0..nf {
                for w in 0..nw {
                    let idx = (k * nf + f) * nw + w;
                    let (s, e) = rows[w % wx];
                    let (read_cols, write_cols) = if grid {
                        let (c0, c1) = bands[w / wx];
                        (
                            assemble_reads((c0, c1), halo, n_cols, boundary),
                            IntervalSet::single(c0 + halo, c1 + halo),
                        )
                    } else {
                        (IntervalSet::full(), IntervalSet::full())
                    };
                    let a_deps: Vec<usize> = if k == 0 {
                        Vec::new()
                    } else {
                        owners[w].iter().map(|&o| prev_paste[f * nw + o]).collect()
                    };
                    let a_id = model.deps.len();
                    model.deps.push(a_deps);
                    model.accesses.push(
                        TaskAccess::new(format!("assemble[b{b} f{f} w{w}]"))
                            .read_rect(
                                BufferId::Global { field: f, parity: read_par },
                                assemble_reads((s, e), halo, n_rows, boundary),
                                read_cols,
                            )
                            .write(BufferId::SlabIn(idx), cell()),
                    );
                    meta.push(TaskMeta { kind: TaskKind::Assemble, block: b, k, field: f, worker: w });
                    model.deps.push(vec![a_id]);
                    model.accesses.push(
                        TaskAccess::new(format!("compute[b{b} f{f} w{w}]"))
                            .read(BufferId::SlabIn(idx), cell())
                            .write(BufferId::SlabIn(idx), cell())
                            .write(BufferId::SlabOut(idx), cell()),
                    );
                    meta.push(TaskMeta { kind: TaskKind::Compute, block: b, k, field: f, worker: w });
                    let p_id = model.deps.len();
                    model.deps.push(vec![a_id + 1]);
                    model.accesses.push(
                        TaskAccess::new(format!("writeback[b{b} f{f} w{w}]"))
                            .read(BufferId::SlabOut(idx), cell())
                            .write(BufferId::SlabOut(idx), cell())
                            .write_rect(
                                BufferId::Global { field: f, parity: write_par },
                                IntervalSet::single(s + halo, e + halo),
                                write_cols,
                            ),
                    );
                    meta.push(TaskMeta {
                        kind: TaskKind::Writeback,
                        block: b,
                        k,
                        field: f,
                        worker: w,
                    });
                    this_paste.push(p_id);
                }
            }
            prev_paste = this_paste;
        }
        WindowPlan { model, meta, nf, nw, bw, b0 }
    }

    /// Task id of `(k, f, w, kind)` under the fixed registration order.
    pub fn id(&self, k: usize, f: usize, w: usize, kind: TaskKind) -> usize {
        let stage = match kind {
            TaskKind::Assemble => 0,
            TaskKind::Compute => 1,
            TaskKind::Writeback => 2,
        };
        3 * ((k * self.nf + f) * self.nw + w) + stage
    }
}

/// The tetris-wave engine's DAG: pyramid task `A_k` reads the shared
/// input rows `[bs[k], bs[k+1])` and publishes its pyramid cell; gap
/// task `B_k` reads input around boundary `bs[k+1]` (declared at the
/// conservative `±2*halo` envelope of its level-1 base) plus both
/// neighbouring pyramid cells, and publishes its gap cell.  Ids match
/// the engine: pyramids `0..ntiles`, then gaps `ntiles..2*ntiles-1`.
pub fn wave_model(bs: &[usize], halo: usize) -> DagModel {
    let ntiles = bs.len() - 1;
    let ext0 = bs[ntiles];
    let mut model = DagModel::default();
    let cell = || IntervalSet::single(0, 1);
    for k in 0..ntiles {
        model.deps.push(Vec::new());
        model.accesses.push(
            TaskAccess::new(format!("pyramid[{k}]"))
                .read(BufferId::WaveInput, IntervalSet::single(bs[k], bs[k + 1]))
                .write(BufferId::Pyramid(k), cell()),
        );
    }
    for k in 0..ntiles.saturating_sub(1) {
        let b = bs[k + 1];
        model.deps.push(vec![k, k + 1]);
        model.accesses.push(
            TaskAccess::new(format!("gap[{k}]"))
                .read(
                    BufferId::WaveInput,
                    IntervalSet::single(b.saturating_sub(2 * halo), (b + 2 * halo).min(ext0)),
                )
                .read(BufferId::Pyramid(k), cell())
                .read(BufferId::Pyramid(k + 1), cell())
                .write(BufferId::Gap(k), cell()),
        );
    }
    model
}

/// [`wave_model`] over the tile layout the tetris-wave engine itself
/// would pick for a padded extent of `ext0` dim-0 cells (`halo` =
/// `radius * steps`) — the CLI entry point for analyzing realistic
/// wavefront DAGs without re-deriving tile boundaries by hand.
pub fn wave_model_auto(
    ext0: usize,
    halo: usize,
    rest_cells: usize,
    steps: usize,
    threads: usize,
) -> DagModel {
    let min_tiles = if threads > 1 { 2 * threads } else { 1 };
    let bs = crate::engine::tessellate::tile_boundaries(
        None,
        ext0,
        halo,
        rest_cells,
        steps,
        min_tiles,
    );
    wave_model(&bs, halo)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assemble_reads_map_boundaries() {
        // 8 core rows, halo 2 → padded 0..12.  Interior span (2, 6):
        // reads padded [2, 10) identically.
        let r = assemble_reads((2, 6), 2, 8, Boundary::Neumann);
        assert_eq!(r.intervals(), &[(2, 10)]);
        // Edge span (0, 4) under Dirichlet: ghost rows 0..2 are
        // constant fills, so reads start at the first core row.
        let r = assemble_reads((0, 4), 2, 8, Boundary::Dirichlet(0.0));
        assert_eq!(r.intervals(), &[(2, 8)]);
        // Same span under Periodic: ghosts wrap to the far edge rows
        // 8..10, which coalesce with the core reads.
        let r = assemble_reads((0, 4), 2, 8, Boundary::Periodic);
        assert_eq!(r.intervals(), &[(2, 10)]);
        // Neumann reflects back into the near rows.
        let r = assemble_reads((0, 4), 2, 8, Boundary::Neumann);
        assert_eq!(r.intervals(), &[(2, 8)]);
        // Zero-share span still reads its neighbourhood.
        let r = assemble_reads((4, 4), 2, 8, Boundary::Neumann);
        assert_eq!(r.intervals(), &[(4, 8)]);
    }

    #[test]
    fn window_plan_matches_hand_layout() {
        let spans = vec![(0usize, 8usize), (8, 16)];
        let p = WindowPlan::build(&spans, 2, 16, Boundary::Dirichlet(0.0), 1, 0, 2);
        assert_eq!(p.model.len(), 2 * 1 * 2 * 3);
        assert_eq!(p.model.len(), p.meta.len());
        // k=0 assembles have no deps; k=1 assembles wait on both
        // neighbours' writebacks (halo 2 crosses the single cut).
        let a10 = p.id(1, 0, 0, TaskKind::Assemble);
        assert_eq!(p.meta[a10].kind, TaskKind::Assemble);
        assert_eq!(p.meta[a10].block, 1);
        assert_eq!(
            p.model.deps[a10],
            vec![p.id(0, 0, 0, TaskKind::Writeback), p.id(0, 0, 1, TaskKind::Writeback)]
        );
        assert!(p.model.deps[p.id(0, 0, 1, TaskKind::Assemble)].is_empty());
        // chain edges
        let c = p.id(0, 0, 1, TaskKind::Compute);
        assert_eq!(p.model.deps[c], vec![p.id(0, 0, 1, TaskKind::Assemble)]);
        assert_eq!(p.model.deps[c + 1], vec![c]);
        // and the whole plan is race-free with zero over-sync.
        let r = p.model.check();
        assert!(r.is_clean(), "{:?}", r.races);
        assert!(r.oversync.is_empty(), "{:?}", r.oversync);
        assert_eq!(r.redundant_edges, 0);
    }

    #[test]
    fn window_plan_clean_for_odd_window_start() {
        // b0 = 1 flips every parity; the scheme must hold either way.
        let spans = vec![(0usize, 5usize), (5, 12), (12, 12), (12, 16)];
        for b in [Boundary::Dirichlet(1.0), Boundary::Neumann, Boundary::Periodic] {
            for b0 in [0usize, 1] {
                for nf in [1usize, 2] {
                    let p = WindowPlan::build(&spans, 3, 16, b, nf, b0, 3);
                    let r = p.model.check();
                    assert!(r.is_clean(), "{b} b0={b0} nf={nf}: {:?}", r.races);
                    assert!(r.oversync.is_empty(), "{b} b0={b0} nf={nf}: {:?}", r.oversync);
                }
            }
        }
    }

    #[test]
    fn wave_model_is_clean_and_tight() {
        let bs = vec![0usize, 10, 20, 30, 40];
        let m = wave_model(&bs, 2);
        assert_eq!(m.len(), 4 + 3);
        let r = m.check();
        assert!(r.is_clean(), "{:?}", r.races);
        assert!(r.oversync.is_empty(), "every gap edge orders a pyramid handoff");
        assert_eq!(r.redundant_edges, 0);
    }

    #[test]
    fn window_plan_detects_dropped_writeback_edge() {
        // 2 workers, halo 2 across the single cut, 2 blocks: drop the
        // writeback(b0, w0) -> assemble(b1, w1) dependency.  Exactly two
        // conflicts lose their ordering:
        //  * RAW on Global{f0, parity 1}: writeback(b0, w0) writes rows
        //    [2, 10), assemble(b1, w1) reads [8, 18) — its halo reaches
        //    into w0's slab;
        //  * WAR on Global{f0, parity 0}: assemble(b0, w0) reads rows
        //    [2, 12) that writeback(b1, w1) overwrites ([10, 18)) — the
        //    symmetrization path that ordered them (a(0,w0) -> p(0,w0)
        //    -> a(1,w1) -> p(1,w1)) ran through the dropped edge.
        let spans = vec![(0usize, 8usize), (8, 16)];
        let mut p = WindowPlan::build(&spans, 2, 16, Boundary::Dirichlet(0.0), 1, 0, 2);
        let wb00 = p.id(0, 0, 0, TaskKind::Writeback);
        let a11 = p.id(1, 0, 1, TaskKind::Assemble);
        assert!(p.model.drop_dep(a11, wb00));
        let races = p.model.races();
        assert_eq!(races.len(), 2, "{races:?}");
        // the RAW pair is (writeback b0 w0, assemble b1 w1) itself
        assert!(
            races.iter().any(|r| (r.a, r.b) == (wb00, a11)
                && r.buffer == BufferId::Global { field: 0, parity: 1 }),
            "missing the dropped-edge RAW race: {races:?}"
        );
        // the WAR pair is assemble(b0, w0) vs writeback(b1, w1)
        let a00 = p.id(0, 0, 0, TaskKind::Assemble);
        let wb11 = p.id(1, 0, 1, TaskKind::Writeback);
        assert!(
            races.iter().any(|r| (r.a, r.b) == (a00, wb11)
                && r.buffer == BufferId::Global { field: 0, parity: 0 }),
            "missing the symmetrization WAR race: {races:?}"
        );
        // restoring the edge restores cleanliness
        p.model.deps[a11].push(wb00);
        assert!(p.model.races().is_empty());
    }

    #[test]
    fn grid_plan_degenerate_band_matches_rows_only_plan() {
        // A single (or absent) band is the old 1-D plan, access summary
        // for access summary — the refactor's safety rail.
        let spans = vec![(0usize, 5usize), (5, 12), (12, 16)];
        for b in [Boundary::Dirichlet(0.0), Boundary::Neumann, Boundary::Periodic] {
            let p1 = WindowPlan::build(&spans, 2, 16, b, 2, 1, 2);
            let p2 = WindowPlan::build_grid(&spans, &[(0, 9)], 2, 16, 9, b, 2, 1, 2);
            assert_eq!(p1.model.deps, p2.model.deps);
            assert_eq!(p1.model.accesses, p2.model.accesses);
            assert_eq!(p1.nw, p2.nw);
        }
    }

    #[test]
    fn grid_window_plan_clean_across_boundaries() {
        // 3×2 grid with a zero-share run and (second config) a
        // zero-width band: clean, zero over-sync, zero redundancy for
        // every boundary × window parity × field count.
        let layouts: Vec<(Vec<(usize, usize)>, Vec<(usize, usize)>)> = vec![
            (vec![(0, 6), (6, 16)], vec![(0, 5), (5, 12), (12, 12)]),
            (vec![(0, 0), (0, 9), (9, 16)], vec![(0, 8), (8, 12)]),
            // zero-share run AND zero-width band together: the
            // empty-on-one-axis tile pair must get neither a race nor a
            // conflict-free (over-sync) edge.
            (vec![(0, 0), (0, 16)], vec![(0, 12), (12, 12)]),
        ];
        for (rows, bands) in &layouts {
            for b in [Boundary::Dirichlet(1.0), Boundary::Neumann, Boundary::Periodic] {
                for b0 in [0usize, 1] {
                    for nf in [1usize, 2] {
                        let p = WindowPlan::build_grid(rows, bands, 2, 16, 12, b, nf, b0, 3);
                        assert_eq!(p.nw, rows.len() * bands.len());
                        let r = p.model.check();
                        assert!(r.is_clean(), "{b} b0={b0} nf={nf}: {:?}", r.races);
                        assert!(r.oversync.is_empty(), "{b} b0={b0} nf={nf}: {:?}", r.oversync);
                        assert_eq!(r.redundant_edges, 0, "{b} b0={b0} nf={nf}");
                    }
                }
            }
        }
    }

    #[test]
    fn grid_window_plan_detects_dropped_corner_edge() {
        // 2×2 grid, halo 2: drop the *diagonal* dependency
        // writeback(b0, NW) -> assemble(b1, SE).  Exactly the corner's
        // RAW/WAR pair must surface:
        //  * RAW on Global{f0, parity 1}: wb(0, w0) writes rows [2, 10)
        //    × cols [2, 10); asm(1, w3) reads rows [8, 18) × cols
        //    [8, 18) — overlap is the 2×2 halo corner [8, 10)².
        //  * WAR on Global{f0, parity 0}: asm(0, w0) reads [2, 12)²,
        //    wb(1, w3) overwrites [10, 18)² — ordered only through the
        //    dropped edge's chain.
        let rows = vec![(0usize, 8usize), (8, 16)];
        let bands = vec![(0usize, 8usize), (8, 16)];
        let mut p = WindowPlan::build_grid(
            &rows,
            &bands,
            2,
            16,
            16,
            Boundary::Dirichlet(0.0),
            1,
            0,
            2,
        );
        // w = gy * wx + gx: w0 = NW tile, w3 = SE tile.
        let wb00 = p.id(0, 0, 0, TaskKind::Writeback);
        let a13 = p.id(1, 0, 3, TaskKind::Assemble);
        assert!(p.model.deps[a13].contains(&wb00), "corner dep must exist");
        assert!(p.model.drop_dep(a13, wb00));
        let races = p.model.races();
        assert_eq!(races.len(), 2, "{races:?}");
        assert!(
            races.iter().any(|r| (r.a, r.b) == (wb00, a13)
                && r.buffer == BufferId::Global { field: 0, parity: 1 }
                && r.rows == (8, 10)
                && r.cols == (8, 10)),
            "missing the dropped-corner RAW race: {races:?}"
        );
        let a00 = p.id(0, 0, 0, TaskKind::Assemble);
        let wb13 = p.id(1, 0, 3, TaskKind::Writeback);
        assert!(
            races.iter().any(|r| (r.a, r.b) == (a00, wb13)
                && r.buffer == BufferId::Global { field: 0, parity: 0 }
                && r.rows == (10, 12)
                && r.cols == (10, 12)),
            "missing the corner WAR race: {races:?}"
        );
        // restoring the corner edge restores cleanliness
        p.model.deps[a13].push(wb00);
        assert!(p.model.races().is_empty());
    }

    #[test]
    fn wave_model_detects_dropped_pyramid_edge() {
        let bs = vec![0usize, 10, 20, 30];
        let mut m = wave_model(&bs, 2);
        // gap[0] is task 3 with deps [0, 1]; dropping A_1 -> B_0 races
        // on pyramid[1]'s cell.
        assert!(m.drop_dep(3, 1));
        let races = m.races();
        assert_eq!(races.len(), 1, "{races:?}");
        assert_eq!(races[0].buffer, BufferId::Pyramid(1));
        assert_eq!((races[0].a, races[0].b), (1, 3));
    }
}
