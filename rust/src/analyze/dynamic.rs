//! Debug-build dynamic cross-validation of the declared summaries.
//!
//! The static checker is only as honest as the declarations it is fed,
//! so debug builds log the *actual* `Field` region traffic per task and
//! assert the observed rows are a subset of the declared ones —
//! summaries may over-approximate but can never silently drift below
//! what the closures really touch.
//!
//! Mechanics: the leader tags its traced buffers (the double-buffered
//! padded globals) with a non-zero trace id via [`Field::set_trace`];
//! each pool task enters a [`TaskScope`] carrying the window's shared
//! [`Collector`] plus its task id through thread-local state; the
//! region primitives (`copy_region_from`, `copy_region_within`,
//! `fill_region`, `paste`, `extract`) report their dim-0 row ranges on
//! traced fields to whatever scope is active.  Scopes are per-run
//! (`Arc`, not process-global), so concurrent pipelined runs in one
//! test binary cannot crosstalk.  In release builds every entry point
//! compiles to a no-op and `Field` carries no trace id at all.

use std::sync::Arc;

use super::checker::{BufferId, TaskAccess};

#[cfg(debug_assertions)]
use super::interval::IntervalSet;
#[cfg(debug_assertions)]
use std::cell::RefCell;
#[cfg(debug_assertions)]
use std::sync::Mutex;

/// Trace id for the padded global of `field` at double-buffer `parity`
/// (0 stays "untraced").
pub fn global_trace(field: usize, parity: usize) -> u64 {
    1 + (field * 2 + parity) as u64
}

/// Inverse of [`global_trace`].
#[cfg_attr(not(debug_assertions), allow(dead_code))]
fn decode_trace(trace: u64) -> Option<BufferId> {
    if trace == 0 {
        return None;
    }
    let t = (trace - 1) as usize;
    Some(BufferId::Global { field: t / 2, parity: t % 2 })
}

#[cfg(debug_assertions)]
#[derive(Clone, Copy, Debug)]
struct Event {
    task: usize,
    trace: u64,
    write: bool,
    rows: (usize, usize),
    /// Dim-1 columns of the access; `(0, usize::MAX)` for fields with
    /// fewer than two dims (no column axis to constrain).
    cols: (usize, usize),
}

/// Per-run sink for observed accesses.  Fieldless (and `validate`
/// trivially `Ok`) in release builds.
#[derive(Default)]
pub struct Collector {
    #[cfg(debug_assertions)]
    events: Mutex<Vec<Event>>,
}

#[cfg(debug_assertions)]
thread_local! {
    static CURRENT: RefCell<Option<(Arc<Collector>, usize)>> = const { RefCell::new(None) };
}

impl Collector {
    /// A fresh shared sink (tasks clone the `Arc` into their scopes).
    pub fn shared() -> Arc<Collector> {
        Arc::new(Collector::default())
    }

    /// Check observed ⊆ declared for every recorded access.  Each event
    /// is a contiguous rect (rows × cols); it passes when its rows fit
    /// inside the union of declared row sets over the regions of that
    /// buffer/direction whose column set covers the event's columns.
    /// With full-width columns everywhere (1-D summaries) this reduces
    /// exactly to the old rows-union subset check.  Only buffers with a
    /// trace mapping (the globals) are validated.
    pub fn validate(&self, accesses: &[TaskAccess]) -> Result<(), String> {
        #[cfg(debug_assertions)]
        {
            for ev in self.events.lock().unwrap().iter() {
                let Some(buf) = decode_trace(ev.trace) else { continue };
                if ev.task >= accesses.len() {
                    let task = ev.task;
                    return Err(format!("observed access from unknown task #{task} on {buf}"));
                }
                let acc = &accesses[ev.task];
                let declared = if ev.write { &acc.writes } else { &acc.reads };
                let cols = IntervalSet::single(ev.cols.0, ev.cols.1);
                let mut allowed = IntervalSet::empty();
                for r in declared.iter().filter(|r| r.buffer == buf) {
                    if cols.subset_of(&r.cols) {
                        for &(a, b) in r.rows.intervals() {
                            allowed.insert(a, b);
                        }
                    }
                }
                let rows = IntervalSet::single(ev.rows.0, ev.rows.1);
                if !rows.subset_of(&allowed) {
                    let task = ev.task;
                    return Err(format!(
                        "task #{task} {} observed {} rows {:?} cols {:?} of {buf} outside its declared rows {:?}",
                        acc.label,
                        if ev.write { "writing" } else { "reading" },
                        ev.rows,
                        ev.cols,
                        allowed.intervals()
                    ));
                }
            }
        }
        let _ = accesses;
        Ok(())
    }
}

/// RAII guard binding the current thread to `(collector, task)` for the
/// duration of one task closure.
pub struct TaskScope {
    #[cfg(debug_assertions)]
    prev: Option<(Arc<Collector>, usize)>,
}

impl TaskScope {
    pub fn enter(collector: &Arc<Collector>, task: usize) -> TaskScope {
        #[cfg(debug_assertions)]
        {
            let prev = CURRENT
                .with(|c| c.borrow_mut().replace((Arc::clone(collector), task)));
            TaskScope { prev }
        }
        #[cfg(not(debug_assertions))]
        {
            let _ = (collector, task);
            TaskScope {}
        }
    }
}

impl Drop for TaskScope {
    fn drop(&mut self) {
        #[cfg(debug_assertions)]
        CURRENT.with(|c| *c.borrow_mut() = self.prev.take());
    }
}

/// Report one observed access on a traced field (called by the `Field`
/// region primitives).  `(c0, c1)` is the dim-1 column range; callers
/// on fields without a column axis pass `(0, usize::MAX)`.  No-op
/// unless a scope is active, the field is traced, and the rect is
/// non-empty on both axes.
#[cfg(debug_assertions)]
pub(crate) fn record(trace: u64, write: bool, lo: usize, hi: usize, c0: usize, c1: usize) {
    if trace == 0 || lo >= hi || c0 >= c1 {
        return;
    }
    CURRENT.with(|c| {
        if let Some((collector, task)) = &*c.borrow() {
            collector
                .events
                .lock()
                .unwrap()
                .push(Event { task: *task, trace, write, rows: (lo, hi), cols: (c0, c1) });
        }
    });
}

#[cfg(all(test, debug_assertions))]
mod tests {
    use super::*;
    use crate::stencil::Field;

    #[test]
    fn trace_codec_roundtrips() {
        assert_eq!(decode_trace(0), None);
        for f in 0..3 {
            for p in 0..2 {
                assert_eq!(
                    decode_trace(global_trace(f, p)),
                    Some(BufferId::Global { field: f, parity: p })
                );
            }
        }
    }

    #[test]
    fn observed_subset_passes_superset_fails() {
        let buf = BufferId::Global { field: 0, parity: 0 };
        let collector = Collector::shared();
        {
            let _scope = TaskScope::enter(&collector, 0);
            record(global_trace(0, 0), false, 2, 5, 0, usize::MAX);
            record(global_trace(0, 0), true, 8, 9, 0, usize::MAX);
        }
        let declared = vec![TaskAccess::new("t0")
            .read(buf, IntervalSet::single(0, 6))
            .write(buf, IntervalSet::single(8, 10))];
        assert!(collector.validate(&declared).is_ok());
        // under-declared read: observed [2,5) vs declared [0,3)
        let narrow =
            vec![TaskAccess::new("t0").read(buf, IntervalSet::single(0, 3)).write(
                buf,
                IntervalSet::single(8, 10),
            )];
        let err = collector.validate(&narrow).unwrap_err();
        assert!(err.contains("reading"), "{err}");
        assert!(err.contains("t0"), "{err}");
    }

    #[test]
    fn recording_requires_scope_and_trace() {
        let collector = Collector::shared();
        // no scope: dropped on the floor
        record(global_trace(0, 0), true, 0, 4, 0, usize::MAX);
        {
            let _scope = TaskScope::enter(&collector, 0);
            record(0, true, 0, 4, 0, usize::MAX); // untraced field
            record(global_trace(0, 0), true, 3, 3, 0, usize::MAX); // empty rows
            record(global_trace(0, 0), true, 0, 4, 2, 2); // empty cols
        }
        assert!(collector.events.lock().unwrap().is_empty());
        // validation with nothing observed always passes
        assert!(collector.validate(&[]).is_ok());
    }

    #[test]
    fn field_primitives_report_while_scoped() {
        let collector = Collector::shared();
        let mut global = Field::zeros(&[10, 6]);
        global.set_trace(global_trace(1, 0));
        let src = Field::full(&[2, 4], 3.0);
        {
            let _scope = TaskScope::enter(&collector, 7);
            global.paste(&[4, 1], &src); // write rows [4, 6)
            let _slab = global.extract(&[2, 0], &[3, 6]); // read rows [2, 5)
        }
        // outside any scope: invisible
        global.paste(&[0, 1], &src);
        let buf = BufferId::Global { field: 1, parity: 0 };
        let mut declared: Vec<TaskAccess> = (0..8).map(|i| TaskAccess::new(format!("t{i}"))).collect();
        declared[7] = TaskAccess::new("t7")
            .read(buf, IntervalSet::single(2, 5))
            .write(buf, IntervalSet::single(4, 6));
        assert!(collector.validate(&declared).is_ok(), "{:?}", collector.validate(&declared));
        // tighten the write declaration and the paste is caught
        declared[7] = TaskAccess::new("t7")
            .read(buf, IntervalSet::single(2, 5))
            .write(buf, IntervalSet::single(4, 5));
        assert!(collector.validate(&declared).is_err());
        // tighten only the write's *columns* (paste touched cols [1, 5))
        // and the 2-D check catches it too
        declared[7] = TaskAccess::new("t7")
            .read(buf, IntervalSet::single(2, 5))
            .write_rect(buf, IntervalSet::single(4, 6), IntervalSet::single(0, 3));
        let err = collector.validate(&declared).unwrap_err();
        assert!(err.contains("writing"), "{err}");
        // widen the columns back out (over-approximation is fine)
        declared[7] = TaskAccess::new("t7")
            .read(buf, IntervalSet::single(2, 5))
            .write_rect(buf, IntervalSet::single(4, 6), IntervalSet::single(0, 6));
        assert!(collector.validate(&declared).is_ok());
    }

    #[test]
    fn column_ranges_validate_per_event() {
        // Two rects declared as two product regions: each observed rect
        // must fit one covering region — the product of the folded row
        // and column unions is NOT assumed.
        let buf = BufferId::Global { field: 0, parity: 0 };
        let collector = Collector::shared();
        {
            let _scope = TaskScope::enter(&collector, 0);
            record(global_trace(0, 0), false, 0, 4, 0, 4);
            record(global_trace(0, 0), false, 8, 12, 8, 12);
        }
        let two_rects = vec![TaskAccess::new("t0")
            .read_rect(buf, IntervalSet::single(0, 4), IntervalSet::single(0, 4))
            .read_rect(buf, IntervalSet::single(8, 12), IntervalSet::single(8, 12))];
        assert!(collector.validate(&two_rects).is_ok());
        // swap the column bands: every event now falls outside both
        let swapped = vec![TaskAccess::new("t0")
            .read_rect(buf, IntervalSet::single(0, 4), IntervalSet::single(8, 12))
            .read_rect(buf, IntervalSet::single(8, 12), IntervalSet::single(0, 4))];
        assert!(collector.validate(&swapped).is_err());
    }
}
