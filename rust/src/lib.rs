//! # Tetris — Stencil Dwarf on heterogeneous workers
//!
//! Reproduction of *"Gamify Stencil Dwarf on Cloud for Democratizing
//! Scientific Computing"* (CS.DC 2023) as a three-layer rust + JAX +
//! Pallas stack (AOT via PJRT).  See DESIGN.md for the architecture and
//! the paper-to-module map.
//!
//! Layer map:
//! * [`stencil`] — specs, fields, reference oracle (substrate).
//! * [`analyze`] — static region-aliasing race checker over the task
//!   DAGs (`tetris analyze`): declared read/write row-interval
//!   summaries + bitset reachability ⇒ races and over-synchronization;
//!   debug builds cross-validate declarations against real `Field`
//!   region traffic.
//! * [`engine`] — optimized CPU engines: tessellate tiling + skewed
//!   swizzling (the paper's §3.1/§4.1), i.e. **Tetris (CPU)**, plus the
//!   dependency-DAG temporal wavefront (**tetris-wave**).
//! * [`baselines`] — Fig-13 comparator engines (DataReorg, Pluto,
//!   Folding, Brick, AN5D).
//! * [`runtime`] — manifest-driven artifact runtime (**Tetris (GPU)**
//!   stand-in; interpreter backend in this offline build).
//! * [`coordinator`] — the paper's §5 concurrent scheduler: two-way
//!   partitioning, auto-tuned balance, batched halo exchange, and the
//!   work-stealing pool primitives.
//! * [`serve`] — the long-lived serving layer on top of the scheduler:
//!   admission queue (priority classes + backpressure), job batching,
//!   partition-caching sessions with TTL/LRU eviction, and the TCP line
//!   protocol (`tetris serve` / `tetris submit`).
//! * [`load`] — stochastic load harness on top of [`serve`]: spawns the
//!   release server as its own process and drives deterministic
//!   (Suite A) and Poisson/zipfian open-loop (Suite B) job streams at
//!   it over TCP, reporting tail latencies, rejects and `/proc` use
//!   (`tetris load`).
//! * [`plan`] — the autotuning Pattern Mapper (§4): hardware
//!   fingerprinting, cost-pruned timed search over (engine, threads,
//!   Tb, tile), and the persistent plan store behind `--engine auto`
//!   and `tetris tune`.
//! * [`model`] — analytical cost models (α+β communication, roofline).
//! * [`trace`] — cross-layer span tracing + unified metrics registry:
//!   a process-global tracer (`--trace FILE` / `TETRIS_TRACE`) records
//!   pool/pipeline/retune/plan/serve spans into per-thread buffers and
//!   exports Chrome trace-event JSON (`tetris trace check` validates
//!   it against the analyze model's task ids).
//! * [`apps`] — thermal-diffusion case study (§6.5), accuracy study.
//! * [`bench`] — harness that regenerates every paper table/figure.

// The whole stack is std-only safe Rust: the pool, the pipelined
// leader and the serving layer get their concurrency from scoped
// threads + locks/atomics, never from `unsafe` — so the race checker's
// task-graph model (plus TSAN/Miri in CI) covers everything there is.
#![forbid(unsafe_code)]
// Stencil index arithmetic reads better with explicit loops and wide
// argument lists; keep clippy focused on correctness lints.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::manual_memcpy,
    clippy::uninlined_format_args
)]

pub mod analyze;
pub mod apps;
pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod engine;
pub mod load;
pub mod model;
pub mod plan;
pub mod runtime;
pub mod serve;
pub mod stencil;
pub mod trace;
pub mod util;

pub use stencil::{Field, StencilSpec};
