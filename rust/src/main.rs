//! `tetris` — leader CLI for the Tetris stencil stack.
//!
//! Subcommands:
//!   info                      platform + artifact inventory
//!   validate                  golden-check every AOT artifact via PJRT
//!   analyze  [--all] [--bench B --tb N --boundary C[,C...] --workers W
//!            --grid WyxWx --fields F --adapt K --rows R --cols N]
//!            [--verbose] [--inject-race]
//!                              static region-aliasing race check of the task DAGs
//!   run      --bench B --engine E|auto [--steps N] [--threads T]
//!            [--boundary C] [--adapt K] [--workers W]  scheduler mode
//!            [--grid WyxWx|auto]  2-D worker grid (Wy*Wx = W)
//!            [--overlap on|off|auto]  §5.3 pipelined leader loop
//!            [--plan-store FILE] [--budget-ms MS] [--seed S]  for auto
//!   hetero   --bench B [--engine E|auto] [--steps N] [--threads T]
//!            [--boundary C] [--adapt K] [--overlap M] [--grid G]
//!   tune     --bench B [--boundary C] [--shape NxM] [--steps N]
//!            [--budget-ms MS] [--seed S] [--plan-store FILE] [--force]
//!   serve    [--addr A] [--workers W] [--queue N] [--batch B] [--threads T]
//!            [--adapt K] [--drift F] [--scale F] [--addr-file FILE]
//!            [--session-ttl SECS] [--max-sessions N] [--overlap M]
//!            [--plan-store FILE|none] [--metrics-scrape FILE[:SECS]]
//!   submit   [--addr A] --bench B [--boundary C[,C...]] [--steps N]
//!            [--jobs K] [--priority P] [--shape NxM] [--seed S]
//!            [--json FILE] | --stats | --shutdown
//!   load     [suiteA|suiteB|both] [--addr A | --bin PATH] [--seed S]
//!            [--conns N --jobs K] [--rate R --duration SECS --zipf S]
//!            [--sweep --sweep-factor F --max-rungs N --stop-reject-frac F]
//!            [--retry N] [--metrics-scrape FILE[:SECS]]
//!            [--json-a FILE] [--json-b FILE]   stochastic load harness
//!   thermal  [--size N] [--steps N] [--viz DIR] [--insulated]
//!   accuracy [--blocks K]
//!   bench    breakdown|sota|scaling|comm|mxu|boundary|serve|plan|overlap|grid
//!            [--scale F] [--threads T] [--json FILE]   single-line JSON for CI
//!            overlap also takes [--mode on|off|both] for per-mode traces
//!   bench    check FILE... [--p999-degrade-max F]
//!                                 assert structural invariants over BENCH_*.json
//!                                 (metrics-scrape JSONL files included)
//!   trace    check FILE... [--strict] [--require-flows]
//!                                 validate Chrome trace-event JSON from --trace
//!   trace    diff A B [--fail-over PCT]   per-phase count/us/bytes deltas
//!   trace    hidden TRACE --bench-json FILE [--tolerance-pct P]
//!                                 reconcile trace-derived hidden leader time
//!                                 with RunMetrics.overlap_hidden
//!
//! `run`, `hetero`, `serve` and `bench` all accept `--trace FILE` (or
//! `$TETRIS_TRACE`) to record a cross-layer span trace and write it as
//! Chrome trace-event JSON (loadable in Perfetto / chrome://tracing)
//! when the command finishes; `run`/`hetero` also accept `--metrics`
//! to print the flat metrics-registry snapshot of the run.

#![allow(clippy::uninlined_format_args)]

use std::collections::HashMap;

use tetris::bail;
use tetris::util::error::{Context, Result};

use tetris::bench as harness;
use tetris::coordinator::{CommModel, NativeWorker, Overlap, Partition, Scheduler, Worker};
use tetris::runtime::XlaService;
use tetris::stencil::{spec, Boundary, Field};

/// Flags that never take a value.  Listing them here makes boolean
/// flags position-independent: `trace check --strict a.json` no longer
/// swallows `a.json` as the value of `--strict`.  `--trace` is NOT
/// listed — it keeps its optional-path operand (`--trace [FILE]`).
const BOOL_FLAGS: &[&str] = &[
    "all",
    "force",
    "inject-race",
    "insulated",
    "metrics",
    "require-flows",
    "shutdown",
    "stats",
    "strict",
    "sweep",
    "verbose",
];

/// Minimal `--key value` / `--key=value` flag parser (the vendored
/// crate set has no clap).
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                    i += 1;
                } else if BOOL_FLAGS.contains(&key) {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(argv[i].clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }
}

fn runtime_opt() -> Option<XlaService> {
    XlaService::spawn_default().ok()
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_help();
        return Ok(());
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "info" => cmd_info(),
        "validate" => cmd_validate(),
        "analyze" => cmd_analyze(&args),
        "run" => cmd_run(&args),
        "hetero" => cmd_hetero(&args),
        "tune" => cmd_tune(&args),
        "serve" => cmd_serve(&args),
        "submit" => cmd_submit(&args),
        "load" => cmd_load(&args),
        "thermal" => cmd_thermal(&args),
        "accuracy" => cmd_accuracy(&args),
        "bench" => cmd_bench(&args),
        "trace" => cmd_trace(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `tetris help`)"),
    }
}

fn print_help() {
    println!(
        "tetris — Stencil Dwarf on heterogeneous workers\n\
         \n\
         USAGE: tetris <subcommand> [flags]\n\
         \n\
         info                          platform + artifact inventory\n\
         validate                      golden-check every AOT artifact\n\
         analyze [--all]               static region-aliasing race check: every task\n\
                                       of the pipelined window DAGs and tetris-wave\n\
                                       DAGs declares (buffer, parity, rows); report\n\
                                       unordered conflicts (races) and over-sync\n\
                                       edges.  [--bench B --tb N --boundary C[,C...]\n\
                                       --workers W --grid WyxWx --fields F --adapt K\n\
                                       --rows R --cols N --verbose]; --all sweeps the\n\
                                       full matrix (grid shapes included);\n\
                                       --inject-race drops one writeback->assemble\n\
                                       edge and must exit nonzero\n\
         run    --bench B --engine E   single-engine run  [--steps N --threads T --scale F]\n\
                [--boundary C --adapt K --workers W]   scheduler run on W native workers\n\
                [--grid WyxWx|auto]    2-D worker grid: Wy column bands x Wx row runs\n\
                                       (Wy*Wx = W; auto picks by halo perimeter)\n\
                [--overlap on|off|auto]   §5.3 double-buffered leader loop: prefetch\n\
                                       block N+1 halos while block N computes\n\
                --engine auto          resolve engine/threads/Tb through the plan\n\
                                       store [--plan-store FILE --budget-ms MS --seed S]\n\
         hetero --bench B              auto-tuned CPU+XLA run [--engine E|auto\n\
                                       --steps N --threads T --boundary C --adapt K\n\
                                       --overlap on|off|auto --grid WyxWx|auto]\n\
         tune   --bench B              search (engine, threads, Tb, tile) for this\n\
                                       machine and persist the plan [--boundary C\n\
                                       --shape NxM --steps N --budget-ms MS --seed S\n\
                                       --plan-store FILE --force]\n\
         serve  [--addr A]             long-lived job server (queue, batching,\n\
                                       partition-caching sessions)  [--workers W\n\
                                       --queue N --batch B --threads T --adapt K\n\
                                       --drift F --scale F --addr-file FILE\n\
                                       --session-ttl SECS --max-sessions N\n\
                                       --overlap on|off|auto --plan-store FILE|none\n\
                                       --metrics-scrape FILE[:SECS]]  the scrape\n\
                                       appends one flat metrics snapshot per line\n\
         submit [--addr A]             send jobs over the line protocol [--bench B\n\
                                       --boundary C[,C...] --steps N --jobs K\n\
                                       --priority P --shape NxM --seed S --json FILE]\n\
                                       or --stats / --shutdown\n\
         load   [suiteA|suiteB|both]   stochastic load harness: spawn the release\n\
                                       server (or --addr A an existing one) and drive\n\
                                       it over TCP.  Suite A: deterministic closed\n\
                                       loop [--conns N --jobs K].  Suite B: seeded\n\
                                       Poisson open loop [--rate R --duration SECS\n\
                                       --zipf S], --sweep walks rates to saturation\n\
                                       [--sweep-factor F --max-rungs N\n\
                                       --stop-reject-frac F].  --retry N obeys\n\
                                       retry_after_ms hints with jittered backoff;\n\
                                       --metrics-scrape FILE[:SECS] arms the spawned\n\
                                       server's scrape.  Reports land in\n\
                                       --json-a/--json-b (BENCH_serve_suite*.json)\n\
         thermal [--size N --steps N --viz DIR --threads T]   Table-3 case study\n\
                [--insulated]          Neumann zero-flux plate (conserves total heat)\n\
         accuracy [--blocks K]         Table-4 FP64-vs-FP32 study\n\
         bench  breakdown|sota|scaling|comm|mxu|boundary|serve|plan|overlap|grid\n\
                                       [--scale F --threads T --json FILE]\n\
                                       (overlap: --mode on|off|both for per-mode traces;\n\
                                       grid: 1xW vs 2x(W/2) halo-byte comparison)\n\
         bench  check FILE... [--p999-degrade-max F]\n\
                                       fail on broken BENCH_*.json invariants;\n\
                                       metrics-scrape JSONL files checked too; the\n\
                                       flag bounds Suite-B p99.9 growth across rungs\n\
         trace  check FILE... [--strict] [--require-flows]\n\
                                       validate Chrome trace-event JSON (balanced\n\
                                       spans, monotone timestamps, plan-model ids,\n\
                                       flow pairing; flags may go anywhere)\n\
         trace  diff A B [--fail-over PCT]   per-phase count/us/bytes deltas\n\
         trace  hidden TRACE --bench-json FILE [--tolerance-pct P]\n\
                                       trace-derived hidden leader time must match\n\
                                       RunMetrics.overlap_hidden within P percent\n\
         \n\
         observability: run/hetero/serve/bench accept --trace FILE (or $TETRIS_TRACE)\n\
                        to record a cross-layer span trace as Chrome trace-event JSON\n\
                        (open in Perfetto); run/hetero accept --metrics to print the\n\
                        flat metrics snapshot; serve answers a METRICS verb\n\
         \n\
         boundaries (C): dirichlet[:V] (fixed-value ghosts), neumann (zero-flux),\n\
                         periodic (torus wrap); --adapt K retunes the partition\n\
                         from measured busy times every K blocks (0 = static)\n\
         engines (--engine E, every run/serve surface accepts both sets):\n\
           optimized: {}\n\
           baselines: {}\n\
           auto:      resolve through the plan store (tune-on-miss; see `tetris tune`)",
        tetris::engine::ENGINE_NAMES.join(", "),
        tetris::baselines::BASELINE_NAMES.join(", ")
    );
}

fn cmd_info() -> Result<()> {
    match XlaService::spawn_default() {
        Ok(rt) => {
            println!("artifact dir:  {:?}", rt.manifest().dir);
            println!("artifacts ({}):", rt.manifest().artifacts.len());
            for (name, a) in &rt.manifest().artifacts {
                println!(
                    "  {name:24} {:>12} -> {:>12}  steps={} dtype={}",
                    format!("{:?}", a.input_shape),
                    format!("{:?}", a.output_shape),
                    a.steps,
                    a.dtype
                );
            }
        }
        Err(e) => println!("no artifacts loaded ({e}); run `make artifacts`"),
    }
    Ok(())
}

fn cmd_validate() -> Result<()> {
    let rt = XlaService::spawn_default().context("artifacts required: run `make artifacts`")?;
    let names: Vec<String> = rt.artifact_names();
    let mut failed = 0;
    for name in names {
        match rt.validate(&name) {
            Ok((em, el2)) => {
                let ok = em < 1e-6 && el2 < 1e-6;
                if !ok {
                    failed += 1;
                }
                println!(
                    "  {name:24} mean_err={em:.2e} l2_err={el2:.2e} {}",
                    if ok { "OK" } else { "FAIL" }
                );
            }
            Err(e) => {
                failed += 1;
                println!("  {name:24} ERROR: {e}");
            }
        }
    }
    if failed > 0 {
        bail!("{failed} artifacts failed golden validation");
    }
    println!("all artifacts validated against python goldens");
    Ok(())
}

/// Running totals across one `tetris analyze` sweep.
#[derive(Default)]
struct AnalyzeTotals {
    cases: usize,
    races: usize,
    oversync: usize,
    redundant: usize,
}

/// Fold one DAG's report into the sweep totals, printing failures
/// always and clean cases only under `--verbose`.
fn analyze_report(desc: &str, report: &tetris::analyze::Report, verbose: bool, t: &mut AnalyzeTotals) {
    t.cases += 1;
    t.races += report.races.len();
    t.oversync += report.oversync.len();
    t.redundant += report.redundant_edges;
    if !report.is_clean() {
        println!("FAIL {desc}: {}", report.summary());
        for r in &report.races {
            println!("  {r}");
        }
    } else if verbose {
        println!("ok   {desc}: {}", report.summary());
    }
}

/// Check every window plan of one pipeline configuration: each
/// partition layout the retuner could plausibly produce (balanced,
/// skewed, zero-share) crossed with each band layout (balanced, skewed,
/// zero-width — `wy = 1` is the degenerate 1-D grid) at both window
/// start parities.
#[allow(clippy::too_many_arguments)]
fn analyze_pipeline_config(
    label: &str,
    halo: usize,
    rows: usize,
    cols: usize,
    boundary: Boundary,
    wx: usize,
    wy: usize,
    nf: usize,
    bw: usize,
    verbose: bool,
    t: &mut AnalyzeTotals,
) {
    use tetris::analyze::{sweep_band_layouts, sweep_partitions, WindowPlan};
    for (pi, part) in sweep_partitions(wx, rows).iter().enumerate() {
        let spans = part.spans();
        for (bi, widths) in sweep_band_layouts(wy, cols).iter().enumerate() {
            let bands: Vec<(usize, usize)> = {
                let mut at = 0usize;
                widths
                    .iter()
                    .map(|&w| {
                        let s = at;
                        at += w;
                        (s, at)
                    })
                    .collect()
            };
            for b0 in [0usize, 1] {
                let plan = WindowPlan::build_grid(
                    &spans, &bands, halo, rows, cols, boundary, nf, b0, bw,
                );
                let desc = format!(
                    "pipeline[{label} {boundary} grid{wy}x{wx} part{pi} bands{bi} nf{nf} b0={b0} bw{bw}]"
                );
                analyze_report(&desc, &plan.model.check(), verbose, t);
            }
        }
    }
}

/// Negative path: drop one writeback -> assemble edge from a canonical
/// window plan; the checker MUST report the resulting races and this
/// command MUST exit nonzero (CI asserts both).
fn analyze_inject_race() -> Result<()> {
    use tetris::analyze::{TaskKind, WindowPlan};
    let spans = vec![(0usize, 8usize), (8, 16)];
    let mut plan = WindowPlan::build(&spans, 2, 16, Boundary::Dirichlet(0.0), 1, 0, 2);
    let wb = plan.id(0, 0, 0, TaskKind::Writeback);
    let a = plan.id(1, 0, 1, TaskKind::Assemble);
    assert!(plan.model.drop_dep(a, wb), "canonical edge missing from plan");
    let report = plan.model.check();
    println!("injected: dropped edge writeback[b0 f0 w0] -> assemble[b1 f0 w1]");
    println!("{}", report.summary());
    for r in &report.races {
        println!("  {r}");
    }
    if report.is_clean() {
        bail!("checker MISSED the injected race — detector is broken");
    }
    bail!("{} race(s) detected from the injected edge drop", report.races.len())
}

/// `tetris analyze` — static region-aliasing race check over the task
/// DAGs the repo schedules (pipelined leader windows + tetris-wave).
fn cmd_analyze(args: &Args) -> Result<()> {
    use tetris::analyze::wave_model_auto;
    if args.flags.contains_key("inject-race") {
        return analyze_inject_race();
    }
    let verbose = args.flags.contains_key("verbose");
    let mut t = AnalyzeTotals::default();
    if args.flags.contains_key("all") {
        // Full matrix: bench (radius) x Tb (halo depth) x boundary x
        // grid shape (Wy×Wx, zero-share rows and zero-width bands
        // included) x fields x partition/band layout x window parity x
        // window length — the configurations `run`/`hetero`/`serve`
        // actually reach.
        let rows = 24;
        let cols = 12;
        for bench in ["heat2d", "box2d25p"] {
            let radius = spec::get(bench).expect("builtin bench").radius;
            for tb in [1usize, 2, 4] {
                for boundary in
                    [Boundary::Dirichlet(0.0), Boundary::Neumann, Boundary::Periodic]
                {
                    for wy in 1..=2 {
                        for wx in 1..=3 {
                            for nf in 1..=3 {
                                for bw in [2usize, 3] {
                                    analyze_pipeline_config(
                                        &format!("{bench} tb{tb}"),
                                        radius * tb,
                                        rows,
                                        cols,
                                        boundary,
                                        wx,
                                        wy,
                                        nf,
                                        bw,
                                        verbose,
                                        &mut t,
                                    );
                                }
                            }
                        }
                    }
                }
            }
            for steps in [1usize, 2, 4] {
                for threads in [1usize, 2, 4] {
                    let halo = radius * steps;
                    let model = wave_model_auto(64 + 2 * halo, halo, 64, steps, threads);
                    let desc = format!("wave[{bench} steps{steps} threads{threads}]");
                    analyze_report(&desc, &model.check(), verbose, &mut t);
                }
            }
        }
    } else {
        let bench = args.str("bench", "heat2d");
        let Some(s) = spec::get(&bench) else {
            bail!("unknown bench {bench:?}");
        };
        let tb = args.get("tb", 2usize).max(1);
        let nw = args.get("workers", 3usize).max(1);
        let nf = args.get("fields", 2usize).max(1);
        let bw = args.get("adapt", 4usize).max(1);
        // `--grid WyxWx` checks a 2-D worker grid (default: the 1-D
        // Wy=1 row split over `--workers`).
        let (wy, wx) = match args.flags.get("grid") {
            Some(g) => parse_grid_spec(g)?,
            None => (1, nw),
        };
        let rows = args.get("rows", 24usize).max(wx.max(2));
        let cols = args.get("cols", 12usize).max(wy.max(2));
        for spec_str in args.str("boundary", "dirichlet:0,neumann,periodic").split(',') {
            let boundary: Boundary = spec_str.trim().parse().context("--boundary")?;
            analyze_pipeline_config(
                &format!("{bench} tb{tb}"),
                s.radius * tb,
                rows,
                cols,
                boundary,
                wx,
                wy,
                nf,
                bw,
                verbose,
                &mut t,
            );
        }
        let halo = s.radius * tb;
        let model = wave_model_auto(64 + 2 * halo, halo, 64, tb, nw);
        analyze_report(&format!("wave[{bench} steps{tb} threads{nw}]"), &model.check(), verbose, &mut t);
    }
    println!(
        "analyzed {} DAGs: {} race(s), {} over-sync edge(s), {} redundant edge(s)",
        t.cases, t.races, t.oversync, t.redundant
    );
    if t.races > 0 {
        bail!("{} race(s) detected across {} DAGs", t.races, t.cases);
    }
    println!("race-free: every conflicting pair is ordered by its DAG");
    Ok(())
}

/// Arm the process tracer when `--trace FILE` (or `$TETRIS_TRACE`)
/// asks for it; returns the output path to hand to [`trace_finish`].
/// A bare `--trace` with no operand falls back to `TRACE.json`.
fn trace_setup(args: &Args) -> Option<String> {
    let path = args
        .flags
        .get("trace")
        .cloned()
        .or_else(|| std::env::var("TETRIS_TRACE").ok())?;
    let path = if path.is_empty() || path == "true" { "TRACE.json".to_string() } else { path };
    tetris::trace::enable();
    Some(path)
}

/// Stop the tracer and write everything recorded as Chrome trace-event
/// JSON; a no-op when [`trace_setup`] didn't arm it.
fn trace_finish(path: Option<String>) -> Result<()> {
    let Some(path) = path else { return Ok(()) };
    tetris::trace::disable();
    let events = tetris::trace::write_chrome_file(&path)?;
    let dropped = tetris::trace::dropped();
    println!(
        "trace: wrote {events} events to {path}{} (open in Perfetto or chrome://tracing)",
        if dropped > 0 { format!(", {dropped} dropped at the ring-buffer cap") } else { String::new() }
    );
    Ok(())
}

/// `tetris trace check|diff|hidden` — the trace analysis surface.
///
/// * `check FILE... [--strict] [--require-flows]` — structural
///   validation (balanced spans, monotone timestamps, pipeline-model
///   ids, flow-event pairing).  Truncated traces (`dropped_events > 0`)
///   demote balance/flow findings to warnings unless `--strict`;
///   `--require-flows` fails traces recorded without flow events.
/// * `diff A B [--fail-over PCT]` — align two traces by span phase and
///   report count/total-µs/total-bytes deltas; with `--fail-over`,
///   error when any shared phase's total time grew by more than PCT%.
/// * `hidden TRACE --bench-json FILE [--tolerance-pct P]` — recompute
///   the §5.3 hidden leader time from the trace and fail unless it
///   agrees with the bench row's `RunMetrics.overlap_hidden`.
///
/// Boolean flags are position-independent (see [`BOOL_FLAGS`]): they
/// may appear before, between or after the file operands.
fn cmd_trace(args: &Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("check") => {
            let strict = args.flags.contains_key("strict");
            let require_flows = args.flags.contains_key("require-flows");
            tetris::trace::check::check_files(&args.positional[1..], strict, require_flows)
        }
        Some("diff") => {
            let [a, b] = &args.positional[1..] else {
                bail!(
                    "trace diff needs exactly two trace files (got {})",
                    args.positional.len() - 1
                );
            };
            let fail_over = args
                .flags
                .get("fail-over")
                .map(|v| v.parse::<f64>())
                .transpose()
                .context("--fail-over")?;
            tetris::trace::diff::diff_files(a, b, fail_over)
        }
        Some("hidden") => {
            let Some(trace) = args.positional.get(1) else {
                bail!("trace hidden needs a trace file (plus --bench-json FILE)");
            };
            let Some(bench_json) = args.flags.get("bench-json") else {
                bail!("trace hidden needs --bench-json FILE (the overlap bench artifact)");
            };
            tetris::trace::diff::hidden_files(trace, bench_json, args.get("tolerance-pct", 15.0f64))
        }
        other => bail!("unknown trace subcommand {other:?} (expected check, diff or hidden)"),
    }
}

/// Print the flat metrics-registry snapshot of one scheduler run when
/// `--metrics` asks for it.
fn print_run_metrics(args: &Args, metrics: &tetris::coordinator::RunMetrics) {
    if args.flags.contains_key("metrics") {
        let mut reg = tetris::trace::MetricsRegistry::new();
        reg.feed_run_metrics(metrics);
        println!("{}", reg.snapshot_json());
    }
}

/// Parse a `WyxWx` grid spec ("2x3" → 2 bands of 3 runs each).
fn parse_grid_spec(spec: &str) -> Result<(usize, usize)> {
    let parsed = spec.split_once('x').and_then(|(a, b)| {
        let wy: usize = a.trim().parse().ok()?;
        let wx: usize = b.trim().parse().ok()?;
        (wy >= 1 && wx >= 1).then_some((wy, wx))
    });
    match parsed {
        Some(g) => Ok(g),
        None => bail!("--grid expects WyxWx (e.g. 2x3) or auto, got {spec:?}"),
    }
}

/// Apply `--grid WyxWx|auto` to a scheduler whose partition holds the
/// default 1-D row split: rebuild it as a `Wy×Wx` grid of even tiles
/// (the §5.2 retuner refines both axes at run time).  `auto` asks the
/// planner's perimeter-over-area prior ([`CostModel::choose_grid`])
/// and keeps the 1-D split when no factorization wins.
///
/// [`CostModel::choose_grid`]: tetris::plan::CostModel::choose_grid
fn apply_grid_flag(args: &Args, sched: &mut Scheduler, shape: &[usize]) -> Result<()> {
    let Some(spec_str) = args.flags.get("grid") else { return Ok(()) };
    let workers = sched.workers.len();
    let halo = sched.spec.radius * sched.tb;
    let (wy, wx) = if spec_str == "auto" {
        let model =
            tetris::plan::CostModel { comm: sched.comm_model, calib_gsps: 1.0 };
        match model.choose_grid(workers, shape, halo) {
            Some(g) => g,
            None => {
                println!("grid: auto kept the 1-D row split");
                return Ok(());
            }
        }
    } else {
        parse_grid_spec(spec_str)?
    };
    if wy * wx != workers {
        bail!("--grid {wy}x{wx} needs {} workers, have {workers} (--workers)", wy * wx);
    }
    if wy > 1 && shape.len() < 2 {
        bail!("--grid {wy}x{wx}: a 1-D field has no column axis to band");
    }
    let unit = sched.partition.unit;
    let units = sched.partition.total_units();
    if wx > units {
        bail!("--grid {wy}x{wx}: only {units} dim-0 units for {wx} runs");
    }
    let mut part =
        Partition::rows(unit, tetris::coordinator::partition::even_split(units, wx));
    if wy > 1 {
        if wy > shape[1] {
            bail!("--grid {wy}x{wx}: only {} columns for {wy} bands", shape[1]);
        }
        part = part.with_bands(tetris::coordinator::partition::even_split(shape[1], wy));
    }
    sched.partition = part;
    println!("grid: {wy}x{wx} worker tiles over {shape:?}");
    Ok(())
}

/// Parse the shared `--overlap on|off|auto` flag (auto by default);
/// `explicit` reports whether the user passed it (a stored plan's
/// searched preference only applies when they did not).
fn overlap_flag(args: &Args) -> Result<(Overlap, bool)> {
    let explicit = args.flags.contains_key("overlap");
    let mode: Overlap = args.str("overlap", "auto").parse().context("--overlap")?;
    Ok((mode, explicit))
}

/// Parse the shared `--boundary C` / `--adapt K` flags.
fn boundary_flags(args: &Args) -> Result<(Boundary, usize)> {
    let b: Boundary = args
        .str("boundary", "dirichlet:0")
        .parse()
        .context("--boundary")?;
    Ok((b, args.get("adapt", 0usize)))
}

/// The plan store a command should use: `--plan-store FILE` or the
/// user default (`$TETRIS_PLAN_STORE`, else `~/.tetris/plans.jsonl`).
fn plan_store_from(args: &Args) -> tetris::plan::PlanStore {
    use tetris::plan::PlanStore;
    match args.flags.get("plan-store") {
        Some(p) => PlanStore::open(p),
        None => PlanStore::open(PlanStore::default_path()),
    }
}

/// Resolve `--engine auto` for a bench/boundary/shape through the plan
/// store (exact hit → warm start → budgeted search), logging how.
fn resolve_auto_flag(
    args: &Args,
    bench: &str,
    boundary: &Boundary,
    shape: &[usize],
    steps_hint: usize,
) -> Result<tetris::plan::Resolution> {
    use tetris::plan::{resolve_auto, Fingerprint, SearchConfig};
    let store = plan_store_from(args);
    let fp = Fingerprint::detect(args.get("calib-ms", 120u64));
    let cfg = SearchConfig {
        budget_ms: args.get("budget-ms", 500u64),
        seed: args.get("seed", 0x7E7215u64),
        ..Default::default()
    };
    let res = resolve_auto(&store, &fp, bench, boundary.kind(), shape, steps_hint, &cfg)?;
    let p = &res.plan;
    println!(
        "plan: {} ({} threads={} Tb={}{}) [{} @ {:?}]",
        if res.cached { "cached" } else if res.warmed { "warm-start" } else { "tuned" },
        p.engine,
        p.threads,
        p.tb,
        p.tile_w.map(|w| format!(" tile_w={w}")).unwrap_or_default(),
        fp.id(),
        store.path
    );
    Ok(res)
}

fn cmd_run(args: &Args) -> Result<()> {
    let trace_path = trace_setup(args);
    let bench = args.str("bench", "heat2d");
    let mut engine = args.str("engine", "tetris-cpu");
    let mut threads = args.get("threads", 1usize);
    let scale = args.get("scale", 0.5f64);
    let s = spec::get(&bench).with_context(|| format!("unknown bench {bench}"))?;
    let (core, mut steps, mut tb) = harness::scaled_problem(&bench, scale);
    steps = args.get("steps", steps);
    let (boundary, adapt) = boundary_flags(args)?;
    let (mut overlap, overlap_explicit) = overlap_flag(args)?;
    let mut tile_w = None;
    let mut plan_grid = None;
    if engine == "auto" {
        let res = resolve_auto_flag(args, &bench, &boundary, &core, steps)?;
        engine = res.plan.engine.clone();
        tb = res.plan.tb.max(1);
        tile_w = res.plan.tile_w;
        plan_grid = res.plan.grid;
        if !args.flags.contains_key("threads") {
            threads = res.plan.threads;
        }
        if !overlap_explicit {
            if let Some(o) = res.plan.overlap {
                overlap = if o { Overlap::On } else { Overlap::Off };
            }
        }
    }
    steps -= steps % tb;
    if steps == 0 {
        steps = tb;
    }
    let build_engine = || {
        tetris::plan::Candidate { engine: engine.clone(), threads, tb, tile_w }
            .build()
            .with_context(|| format!("unknown engine {engine}"))
    };
    let scheduler_mode = ["boundary", "adapt", "workers", "grid"]
        .iter()
        .any(|k| args.flags.contains_key(*k));
    if scheduler_mode {
        // Boundary-aware scheduler run: W native workers of the chosen
        // engine (either registry), row-granular partition, optional
        // adaptive retune.
        let nworkers = args.get("workers", 2usize).max(1);
        let workers: Vec<Box<dyn Worker>> = (0..nworkers)
            .map(|_| -> Result<Box<dyn Worker>> {
                Ok(Box::new(NativeWorker::new(build_engine()?, 1 << 33)))
            })
            .collect::<Result<_>>()?;
        let mut sched = Scheduler::from_plan(s, tb, workers, core[0], boundary, adapt);
        sched.overlap = overlap;
        apply_grid_flag(args, &mut sched, &core)?;
        // A stored plan's searched grid shape applies when the flag was
        // not passed and the worker fleet matches the factorization —
        // same deference rule as the plan's overlap preference.
        if !args.flags.contains_key("grid") {
            if let Some((wy, wx)) = plan_grid {
                if wy > 1 && wy * wx == nworkers && core.len() >= 2 && wy <= core[1] {
                    use tetris::coordinator::partition::even_split;
                    sched.partition = Partition::rows(1, even_split(core[0], wx))
                        .with_bands(even_split(core[1], wy));
                    println!("grid: {wy}x{wx} worker tiles (stored plan)");
                }
            }
        }
        let field = Field::random(&core, 0xA11CE);
        let (out, metrics) = sched.run(&field, steps)?;
        println!(
            "{bench} x {steps} steps on {nworkers}x{engine} (threads={threads}, boundary={boundary}, adapt={adapt}, overlap={overlap})"
        );
        println!("{}", metrics.report(&sched.comm_model));
        println!("final field mean={:.6} l2={:.3}", out.mean(), out.l2());
        print_run_metrics(args, &metrics);
        return trace_finish(trace_path);
    }
    let eng = build_engine()?;
    let (g, d) = harness::time_engine(eng.as_ref(), &s, &core, steps, tb);
    println!(
        "{bench} x {steps} steps on {engine} (threads={threads}, Tb={tb}): {:.4} GStencils/s ({})",
        g,
        tetris::util::timer::fmt_duration(d)
    );
    trace_finish(trace_path)
}

fn cmd_hetero(args: &Args) -> Result<()> {
    let trace_path = trace_setup(args);
    let bench = args.str("bench", "heat2d");
    let mut engine = args.str("engine", "tetris-cpu");
    let mut threads = args.get("threads", 1usize);
    let rt = XlaService::spawn_default().context("hetero needs artifacts: run `make artifacts`")?;
    let (boundary, adapt) = boundary_flags(args)?;
    let (mut overlap, overlap_explicit) = overlap_flag(args)?;
    if engine == "auto" {
        // The artifact fixes Tb and the slab quantum; the plan picks the
        // CPU-side engine, thread count and leader-loop mode.
        let meta = rt.bench(&bench)?.clone();
        let res = resolve_auto_flag(args, &bench, &boundary, &meta.global_core, meta.tb * 4)?;
        engine = res.plan.engine.clone();
        if !args.flags.contains_key("threads") {
            threads = res.plan.threads;
        }
        if !overlap_explicit {
            if let Some(o) = res.plan.overlap {
                overlap = if o { Overlap::On } else { Overlap::Off };
            }
        }
    }
    let (mut sched, global) = harness::hetero_scheduler(&rt, &bench, threads, &engine)?;
    sched.boundary = boundary;
    sched.adapt_every = adapt;
    sched.overlap = overlap;
    apply_grid_flag(args, &mut sched, &global)?;
    let steps = {
        let s = args.get("steps", sched.tb * 4);
        s - s % sched.tb
    };
    let core = Field::random(&global, 1);
    let (out, metrics) = sched.run(&core, steps)?;
    println!("{}", metrics.report(&sched.comm_model));
    println!("final field mean={:.6} l2={:.3}", out.mean(), out.l2());
    print_run_metrics(args, &metrics);
    trace_finish(trace_path)
}

/// `tetris tune`: run (or refresh) the Pattern Mapper search for a
/// `(bench, boundary, shape)` and persist the winning plan.
fn cmd_tune(args: &Args) -> Result<()> {
    use tetris::plan::{resolve_auto, search, Fingerprint, SearchConfig};
    let bench = args.str("bench", "heat2d");
    let scale = args.get("scale", 0.5f64);
    spec::get(&bench).with_context(|| format!("unknown bench {bench}"))?;
    let (default_shape, default_steps, _) = harness::scaled_problem(&bench, scale);
    let shape: Vec<usize> = match args.flags.get("shape") {
        Some(s) => s
            .split('x')
            .map(|n| n.parse().context("--shape"))
            .collect::<Result<_>>()?,
        None => default_shape,
    };
    let steps = args.get("steps", default_steps);
    let boundary: Boundary = args
        .str("boundary", "dirichlet:0")
        .parse()
        .context("--boundary")?;
    let store = plan_store_from(args);
    let fp = Fingerprint::detect(args.get("calib-ms", 150u64));
    println!(
        "fingerprint: {} ({} cores, {}B cache line, calib {:.3} GStencils/s)",
        fp.id(),
        fp.cores,
        fp.cache_line,
        fp.calib_gsps
    );
    let cfg = SearchConfig {
        budget_ms: args.get("budget-ms", 2_000u64),
        seed: args.get("seed", 0x7E7215u64),
        ..Default::default()
    };
    let (plan, how) = if args.flags.contains_key("force") {
        // --force re-searches even over a fresh cache hit.
        let p = search(&bench, boundary.kind(), &shape, steps, &fp, &cfg)?;
        store.append(&p)?;
        (p, "tuned (forced)".to_string())
    } else {
        let res = resolve_auto(&store, &fp, &bench, boundary.kind(), &shape, steps, &cfg)?;
        let how = if res.cached {
            "cached (use --force to re-search)"
        } else if res.warmed {
            "warm-start"
        } else {
            "tuned"
        };
        (res.plan, how.to_string())
    };
    println!("plan [{how}]: {}", plan.to_json());
    let kept = store.compact()?;
    println!("store: {:?} ({kept} plans after compaction)", store.path);
    Ok(())
}

/// `tetris serve`: boot the long-lived job server and block until a
/// `SHUTDOWN` line (or handle signal) drains it.
fn cmd_serve(args: &Args) -> Result<()> {
    use tetris::serve::{default_worker_factory, ServeConfig, Server};
    let trace_path = trace_setup(args);
    let threads = args.get("threads", 2usize);
    let (overlap, overlap_explicit) = overlap_flag(args)?;
    // Planning defaults ON for the real server (that's the point of a
    // persistent store); `--plan-store none` opts out.
    let plan_store = match args.str("plan-store", "").as_str() {
        "none" => None,
        "" => Some(tetris::plan::PlanStore::default_path().to_string_lossy().into_owned()),
        p => Some(p.to_string()),
    };
    // `--metrics-scrape FILE[:SECS]`: split on the LAST ':' so paths
    // with colons still work; a non-numeric suffix is part of the path.
    let metrics_scrape = args.flags.get("metrics-scrape").map(|spec| {
        match spec.rsplit_once(':') {
            Some((path, secs)) if !path.is_empty() => match secs.parse::<u64>() {
                Ok(s) => (path.to_string(), s.max(1)),
                Err(_) => (spec.clone(), 1),
            },
            _ => (spec.clone(), 1),
        }
    });
    let cfg = ServeConfig {
        addr: args.str("addr", "127.0.0.1:7466"),
        dispatchers: args.get("workers", 2usize).max(1),
        queue_jobs: args.get("queue", 64usize),
        queue_bytes: args.get("queue-bytes", 1usize << 30),
        max_batch: args.get("batch", 8usize).max(1),
        threads,
        adapt_every: args.get("adapt", 2usize),
        drift_threshold: args.get("drift", 0.25f64),
        scale: args.get("scale", 0.25f64),
        session_ttl: std::time::Duration::from_secs_f64(
            args.get("session-ttl", 900.0f64).max(0.0),
        ),
        max_sessions: args.get("max-sessions", 64usize),
        plan_store,
        fingerprint: None,
        overlap,
        overlap_explicit,
        metrics_scrape,
    };
    let handle = Server::start(cfg.clone(), default_worker_factory(threads))?;
    if let Some(path) = args.flags.get("addr-file") {
        std::fs::write(path, format!("{}\n", handle.addr))?;
    }
    println!(
        "tetris serve: listening on {} (dispatchers={}, queue={} jobs, batch<={})",
        handle.addr, cfg.dispatchers, cfg.queue_jobs, cfg.max_batch
    );
    println!("protocol: one JSON job per line; STATS; METRICS; SHUTDOWN (see README \"Serving\")");
    handle.join();
    println!("tetris serve: drained and stopped");
    // the trace flushes at drain, so a whole serve lifetime lands in one file
    trace_finish(trace_path)
}

/// `tetris submit`: drive a pipelined job stream (or STATS/SHUTDOWN) at
/// a running server and summarize client-side throughput.
fn cmd_submit(args: &Args) -> Result<()> {
    use tetris::serve::{Client, JobSpec};
    let addr = args.str("addr", "127.0.0.1:7466");
    let mut client = Client::connect(addr.as_str())?;
    if args.flags.contains_key("stats") {
        println!("{}", client.stats()?);
        return Ok(());
    }
    if args.flags.contains_key("shutdown") {
        println!("{}", client.shutdown()?);
        return Ok(());
    }
    let bench = args.str("bench", "heat2d");
    let steps = args.get("steps", 8usize);
    let jobs = args.get("jobs", 4usize).max(1);
    let seed0 = args.get("seed", 1u64);
    let priority = args.str("priority", "normal").parse().context("--priority")?;
    let boundaries: Vec<Boundary> = args
        .str("boundary", "dirichlet:0")
        .split(',')
        .map(|b| b.parse().context("--boundary"))
        .collect::<Result<_>>()?;
    let shape: Option<Vec<usize>> = match args.flags.get("shape") {
        Some(s) => Some(
            s.split('x')
                .map(|n| n.parse().context("--shape"))
                .collect::<Result<_>>()?,
        ),
        None => None,
    };
    let t0 = std::time::Instant::now();
    for i in 0..jobs {
        client.send_spec(&JobSpec {
            id: format!("cli-{i}"),
            bench: bench.clone(),
            boundary: boundaries[i % boundaries.len()],
            steps,
            priority,
            shape: shape.clone(),
            seed: seed0 + i as u64,
            field: None,
            return_field: false,
        })?;
    }
    let mut latencies_ms: Vec<f64> = Vec::with_capacity(jobs);
    let mut ok = 0usize;
    for _ in 0..jobs {
        let r = client.recv_result()?;
        if r.ok {
            ok += 1;
            latencies_ms.push(r.queue_ms + r.exec_ms);
            println!(
                "  {} ok: {} {} x{} mean={:.6} batch={} queue={:.2}ms exec={:.2}ms shares={:?}",
                r.id, r.bench, r.boundary, r.steps, r.mean, r.batch_size, r.queue_ms, r.exec_ms,
                r.shares
            );
        } else {
            println!(
                "  {} REJECTED: {}{}",
                r.id,
                r.error.as_deref().unwrap_or("unknown"),
                r.retry_after_ms.map(|ms| format!(" (retry after {ms}ms)")).unwrap_or_default()
            );
        }
    }
    let wall = t0.elapsed();
    let jps = ok as f64 / wall.as_secs_f64().max(1e-12);
    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| -> f64 {
        if latencies_ms.is_empty() {
            0.0
        } else {
            let idx = ((p * latencies_ms.len() as f64).ceil() as usize).max(1) - 1;
            latencies_ms[idx.min(latencies_ms.len() - 1)]
        }
    };
    println!(
        "{ok}/{jobs} jobs ok in {:?}: {jps:.2} jobs/sec, p50 {:.2}ms, p99 {:.2}ms, p99.9 {:.2}ms",
        wall,
        pct(0.50),
        pct(0.99),
        pct(0.999)
    );
    if let Some(path) = args.flags.get("json") {
        use std::collections::BTreeMap;
        use tetris::util::json::Json;
        let mut m = BTreeMap::new();
        m.insert("bench".to_string(), Json::Str(bench));
        m.insert("jobs".to_string(), Json::Num(jobs as f64));
        m.insert("ok".to_string(), Json::Num(ok as f64));
        m.insert("wall_ms".to_string(), Json::Num(wall.as_secs_f64() * 1e3));
        m.insert("jobs_per_sec".to_string(), Json::Num(jps));
        m.insert("p50_ms".to_string(), Json::Num(pct(0.50)));
        m.insert("p99_ms".to_string(), Json::Num(pct(0.99)));
        m.insert("p999_ms".to_string(), Json::Num(pct(0.999)));
        std::fs::write(path, format!("{}\n", Json::Obj(m)))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `tetris load`: spawn (or target) a release server and run the
/// deterministic Suite A and/or stochastic Suite B load studies against
/// it, archiving `BENCH_serve_suite*.json` reports.
fn cmd_load(args: &Args) -> Result<()> {
    use std::time::Duration;
    use tetris::load::{self, LoadConfig, ProcMonitor};
    let which = args.positional.first().map(String::as_str).unwrap_or("both");
    let (run_a, run_b) = match which {
        "suiteA" | "suitea" | "a" => (true, false),
        "suiteB" | "suiteb" | "b" => (false, true),
        "both" => (true, true),
        other => bail!("unknown load suite {other:?} (expected suiteA, suiteB or both)"),
    };
    let cfg = LoadConfig {
        addr: args.flags.get("addr").cloned(),
        bin: args.flags.get("bin").cloned(),
        scale: args.get("scale", 0.05f64),
        threads: args.get("threads", 1usize).max(1),
        dispatchers: args.get("workers", 2usize).max(1),
        queue_jobs: args.get("queue", 64usize).max(1),
        seed: args.get("seed", 0x10ADu64),
        conns: args.get("conns", 4usize).max(1),
        jobs_per_conn: args.get("jobs", 16usize).max(1),
        rate: args.get("rate", 50.0f64),
        duration: Duration::from_secs_f64(args.get("duration", 5.0f64).max(0.1)),
        zipf_s: args.get("zipf", 1.1f64),
        sweep: args.flags.contains_key("sweep"),
        sweep_factor: args.get("sweep-factor", 2.0f64),
        max_rungs: args.get("max-rungs", 6usize).max(1),
        stop_reject_frac: args.get("stop-reject-frac", 0.5f64),
        retry: args.get("retry", 0usize),
        metrics_scrape: args.flags.get("metrics-scrape").cloned(),
    };
    if cfg.metrics_scrape.is_some() && cfg.addr.is_some() {
        println!(
            "tetris load: note: --metrics-scrape only applies to a server this harness \
             spawns; pass it to the running `tetris serve` instead"
        );
    }
    // Target: an already-running server via --addr (no /proc sampling —
    // we may not own the pid), else spawn the release binary ourselves.
    let (addr, mut spawned) = match &cfg.addr {
        Some(a) => (a.clone(), None),
        None => {
            let s = load::spawn_server(&cfg)?;
            println!("tetris load: spawned server pid {} on {}", s.pid(), s.addr);
            (s.addr.clone(), Some(s))
        }
    };
    let monitor =
        spawned.as_ref().map(|s| ProcMonitor::start(s.pid(), Duration::from_millis(250)));
    let mut reports = Vec::new();
    if run_a {
        println!(
            "tetris load: suite A (closed loop, {} conns x {} jobs, seed {})",
            cfg.conns, cfg.jobs_per_conn, cfg.seed
        );
        reports.push(("json-a", "BENCH_serve_suiteA.json", load::run_suite_a(&addr, &cfg)?));
    }
    if run_b {
        println!(
            "tetris load: suite B (open loop, rate {}/s x {:.1}s, zipf {}{}, seed {})",
            cfg.rate,
            cfg.duration.as_secs_f64(),
            cfg.zipf_s,
            if cfg.sweep { ", sweeping" } else { "" },
            cfg.seed
        );
        reports.push(("json-b", "BENCH_serve_suiteB.json", load::run_suite_b(&addr, &cfg)?));
    }
    // Stop sampling before reporting so both suites share the run's
    // whole-window /proc summary.
    let proc = monitor.map(ProcMonitor::stop);
    for (flag, default_path, suite) in &reports {
        for rung in &suite.rungs {
            println!(
                "  {} {}: {:.1} jobs/sec goodput (offered {:.1}/s), {} ok / {} rejected / {} lost, \
                 total p50 {:.2}ms p99 {:.2}ms p99.9 {:.2}ms",
                suite.name,
                rung.label,
                rung.goodput_per_sec(),
                rung.offered_per_sec(),
                rung.rec.completed,
                rung.rec.rejected,
                rung.rec.lost,
                rung.rec.total.percentile_ms(0.50),
                rung.rec.total.percentile_ms(0.99),
                rung.rec.total.percentile_ms(0.999),
            );
        }
        let path = args.str(flag, default_path);
        let j = suite.to_json(cfg.scale, cfg.threads, proc.as_ref());
        std::fs::write(&path, format!("{j}\n"))?;
        println!("wrote {path}");
    }
    if let Some(p) = &proc {
        println!(
            "  server /proc: rss max {:.1} MiB, cpu {:.2}s over {} samples",
            p.rss_max_bytes as f64 / (1 << 20) as f64,
            p.cpu_secs,
            p.samples
        );
    }
    if let Some(s) = spawned.as_mut() {
        s.shutdown()?;
        println!("tetris load: server drained and stopped");
    }
    Ok(())
}

fn cmd_thermal(args: &Args) -> Result<()> {
    let rt = runtime_opt();
    let size = args.get("size", 384usize);
    let tb = rt.as_ref().map(|r| r.manifest().thermal_tb).unwrap_or(8);
    let steps = {
        let s = args.get("steps", 40 * tb);
        s - s % tb
    };
    let threads = args.get("threads", 1usize);
    if args.flags.contains_key("insulated") {
        // Neumann zero-flux plate: no heat escapes, mean is invariant.
        let adapt = args.get("adapt", 0usize);
        let init = tetris::apps::thermal::gaussian_plate(size);
        let (out, metrics) = tetris::apps::thermal::run_insulated(size, steps, tb, threads, adapt)?;
        println!("== insulated plate ({size}x{size}, {steps} steps, Neumann walls) ==");
        println!("{}", metrics.report(&CommModel::default()));
        println!(
            "mean {:.6} -> {:.6} (drift {:.2e}, conserved), center {:.2} -> {:.2} °C",
            init.mean(),
            out.mean(),
            (out.mean() - init.mean()).abs(),
            init.get(&[size / 2, size / 2]),
            out.get(&[size / 2, size / 2])
        );
        if let Some(dir) = args.flags.get("viz") {
            std::fs::create_dir_all(dir)?;
            tetris::apps::viz::save_heatmap(&out, 25.0, 100.0, format!("{dir}/insulated.ppm"))?;
            println!("wrote {dir}/insulated.ppm");
        }
        return Ok(());
    }
    let (rows, fields) = tetris::apps::thermal::run_table3(rt.as_ref(), size, steps, tb, threads)?;
    println!("== Table 3: thermal diffusion ({size}x{size}, {steps} steps) ==");
    println!("{:<14} {:>10} {:>14} {:>9} {:>12}", "method", "time(s)", "GStencils/s", "speedup", "center(°C)");
    for r in &rows {
        println!(
            "{:<14} {:>10.3} {:>14.4} {:>8.2}x {:>12.2}  (maxdiff vs naive {:.2e})",
            r.method, r.seconds, r.gstencils, r.speedup, r.final_center, r.max_diff_vs_naive
        );
    }
    if let Some(dir) = args.flags.get("viz") {
        std::fs::create_dir_all(dir)?;
        let init = tetris::apps::thermal::gaussian_plate(size);
        tetris::apps::viz::save_heatmap(&init, 25.0, 100.0, format!("{dir}/before.ppm"))?;
        if let Some((_, last)) = fields.last() {
            tetris::apps::viz::save_heatmap(last, 25.0, 100.0, format!("{dir}/after.ppm"))?;
        }
        println!("wrote {dir}/before.ppm, {dir}/after.ppm");
    }
    Ok(())
}

fn cmd_accuracy(args: &Args) -> Result<()> {
    let rt = runtime_opt();
    let blocks = args.get("blocks", 25usize);
    let n = rt
        .as_ref()
        .and_then(|r| r.manifest().thermal_core.first().copied())
        .unwrap_or(96);
    let rep = tetris::apps::accuracy::run_accuracy(rt.as_ref(), n, blocks)?;
    println!(
        "== Table 4: FP64 vs FP32 deviation after {} steps ({}, {}x{}) ==",
        rep.steps,
        if rep.used_artifacts { "PJRT artifacts" } else { "rust fallback" },
        n,
        n
    );
    println!("{:<18} {:>8} {:>10} {:>8}", "|error| bucket", "<0.1°C", "0.1-1.0°C", ">1.0°C");
    println!(
        "{:<18} {:>7.1}% {:>9.1}% {:>7.1}%",
        "FP32 vs FP64", rep.fp32_buckets[0], rep.fp32_buckets[1], rep.fp32_buckets[2]
    );
    if let Some(dir) = args.flags.get("viz") {
        std::fs::create_dir_all(dir)?;
        tetris::apps::viz::save_heatmap(&rep.fp32, 25.0, 100.0, format!("{dir}/fp32.ppm"))?;
        tetris::apps::viz::save_error_map(&rep.fp64, &rep.fp32, 0.1, format!("{dir}/error.ppm"))?;
        println!("wrote {dir}/fp32.ppm, {dir}/error.ppm");
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("breakdown");
    if which == "check" {
        // invariant gate over already-emitted artifacts; no timing runs
        let p999 = args
            .flags
            .get("p999-degrade-max")
            .map(|v| v.parse::<f64>())
            .transpose()
            .context("--p999-degrade-max")?;
        return tetris::bench::check::check_files_with(&args.positional[1..], p999);
    }
    let trace_path = trace_setup(args);
    let scale = args.get("scale", 0.25f64);
    // scaling sweeps up to at least 4 threads; record what actually ran.
    let threads = match which {
        "scaling" => args.get("threads", 2usize).max(4),
        _ => args.get("threads", 2usize),
    };
    let rt = runtime_opt();
    let sections: Vec<(String, Vec<harness::Row>)> = match which {
        "breakdown" => harness::run_breakdown(rt.as_ref(), scale, threads),
        "sota" => harness::run_sota(rt.as_ref(), scale, threads),
        "scaling" => harness::run_scaling(rt.as_ref(), scale, threads),
        "boundary" => harness::run_boundary(scale, threads),
        "grid" => harness::run_grid(scale, threads),
        "serve" => harness::run_serve(scale, threads),
        "plan" => harness::run_plan(scale, threads, args.flags.get("plan-store").map(String::as_str)),
        "overlap" => {
            let mode = match args.str("mode", "both").as_str() {
                "on" => Some(Overlap::On),
                "off" => Some(Overlap::Off),
                "both" => None,
                other => bail!("unknown overlap --mode {other:?} (expected on, off or both)"),
            };
            harness::run_overlap_mode(scale, threads, mode)
        }
        "comm" => vec![("comm".to_string(), harness::run_comm())],
        "mxu" => {
            let rt = rt.context("mxu bench needs artifacts")?;
            vec![("mxu".to_string(), harness::run_mxu(&rt)?)]
        }
        other => bail!("unknown bench {other:?}"),
    };
    if let Some(path) = args.flags.get("json") {
        let summary = harness::summary_json(which, scale, threads, &sections);
        std::fs::write(path, format!("{summary}\n"))?;
        println!("wrote {path}");
    }
    trace_finish(trace_path)
}

/// Smoke-usable single-worker scheduler for quick CLI experiments.
#[allow(dead_code)]
fn single_worker_sched(bench: &str, engine: &str, threads: usize) -> Result<Scheduler> {
    let s = spec::get(bench).context("bench")?;
    Ok(Scheduler {
        spec: s,
        tb: 2,
        workers: vec![Box::new(NativeWorker::new(
            tetris::engine::by_name(engine, threads).context("engine")?,
            1 << 33,
        ))],
        partition: Partition::rows(8, vec![1]),
        comm_model: CommModel::default(),
        boundary: Boundary::Dirichlet(0.0),
        adapt_every: 0,
        overlap: Overlap::Auto,
    })
}
