//! Unified metrics registry: counters, gauges and latency histograms
//! behind one flat, stable namespace.
//!
//! Every aggregate the stack already keeps — the coordinator's
//! [`RunMetrics`], the server's [`ServeStats`], the load harness's
//! [`Recorder`] — feeds the same registry type, and every consumer
//! (the serve `METRICS` verb, per-rung snapshots in
//! `BENCH_serve_suite*.json`, `tetris run --metrics`) reads the same
//! flat JSON shape, so `tetris bench check` can assert cross-layer
//! invariants without per-source parsing.
//!
//! **Naming policy (stable API):** metric names are dot-separated
//! `layer.metric` strings.  Monotone counters end in `_total`; gauges
//! carry a unit suffix where meaningful (`_ms`, `_bytes`); a histogram
//! named `x_ms` flattens to `x_ms_count_total` plus
//! `x_ms_p50_ms`/`_p90_ms`/`_p99_ms`/`_p999_ms`.  Renaming or
//! repurposing a published name is a breaking change: add a new name
//! and keep emitting the old one for a deprecation window instead.
//! `tetris bench check` relies on exactly two conventions: `_total`
//! keys never decrease across snapshots of one process, and flattened
//! percentile ladders are monotone.

use std::collections::BTreeMap;

use crate::coordinator::RunMetrics;
use crate::load::Recorder;
use crate::serve::{LatencyHistogram, ServeStats};
use crate::util::json::Json;

/// Counters (monotone, `_total`), gauges and histograms under one flat
/// namespace.  Build one per snapshot and feed it from cumulative
/// sources — an absolute `counter_add` onto a fresh registry yields the
/// source's running total, which keeps successive snapshots monotone.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, LatencyHistogram>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add to a monotone counter (name must end in `_total`).
    pub fn counter_add(&mut self, name: &str, v: u64) {
        debug_assert!(name.ends_with("_total"), "counter {name:?} must end in _total");
        *self.counters.entry(name.to_string()).or_insert(0) += v;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Set a gauge to its current value.
    pub fn gauge_set(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Merge a latency histogram under `name` (use a `_ms` suffix).
    pub fn hist_merge(&mut self, name: &str, h: &LatencyHistogram) {
        self.hists.entry(name.to_string()).or_default().merge(h);
    }

    /// Feed the server's cumulative counters + end-to-end latency.
    pub fn feed_serve_stats(&mut self, s: &ServeStats) {
        self.counter_add("serve.submitted_total", s.submitted);
        self.counter_add("serve.completed_total", s.completed);
        self.counter_add("serve.rejected_total", s.rejected);
        self.counter_add("serve.errors_total", s.errors);
        self.counter_add("serve.batches_total", s.batches);
        self.counter_add("serve.batched_jobs_total", s.batched_jobs);
        self.counter_add("serve.evictions_total", s.evictions);
        self.gauge_set("serve.overlap_hidden_ms", s.overlap_hidden_ms);
        self.hist_merge("serve.latency_ms", s.histogram());
    }

    /// Feed one completed scheduler run's aggregates.
    pub fn feed_run_metrics(&mut self, m: &RunMetrics) {
        self.counter_add("run.steps_total", m.total_steps as u64);
        self.counter_add("run.blocks_total", m.blocks as u64);
        self.counter_add("run.retunes_total", m.retunes as u64);
        self.counter_add("run.comm_messages_total", m.comm.messages as u64);
        self.counter_add("run.comm_bytes_total", m.comm.bytes as u64);
        self.gauge_set("run.gstencils_per_sec", m.gstencils_per_sec());
        self.gauge_set("run.bubble_fraction", m.bubble_fraction());
        self.gauge_set("run.summed_idle_ms", m.summed_idle_secs() * 1e3);
        self.gauge_set("run.overlap", if m.overlap { 1.0 } else { 0.0 });
        self.gauge_set("run.overlap_hidden_ms", m.overlap_hidden.as_secs_f64() * 1e3);
    }

    /// Feed the load harness's client-side view of one rung.
    pub fn feed_recorder(&mut self, r: &Recorder) {
        self.counter_add("load.offered_total", r.offered);
        self.counter_add("load.completed_total", r.completed);
        self.counter_add("load.rejected_total", r.rejected);
        self.counter_add("load.errors_total", r.errors);
        self.counter_add("load.lost_total", r.lost);
        self.counter_add("load.retried_total", r.retried);
        self.counter_add("load.gave_up_total", r.gave_up);
        self.hist_merge("load.queue_ms", &r.queue);
        self.hist_merge("load.service_ms", &r.service);
        self.hist_merge("load.total_ms", &r.total);
    }

    /// The flat snapshot: one JSON object, counters as integers, gauges
    /// as numbers, histograms flattened to `<name>_count_total` +
    /// `<name>_p50_ms`…`_p999_ms`.
    pub fn snapshot_json(&self) -> Json {
        let mut m = BTreeMap::new();
        for (k, v) in &self.counters {
            m.insert(k.clone(), Json::Num(*v as f64));
        }
        for (k, v) in &self.gauges {
            m.insert(k.clone(), Json::Num(*v));
        }
        for (k, h) in &self.hists {
            m.insert(format!("{k}_count_total"), Json::Num(h.count() as f64));
            m.insert(format!("{k}_p50_ms"), Json::Num(h.percentile_ms(0.50)));
            m.insert(format!("{k}_p90_ms"), Json::Num(h.percentile_ms(0.90)));
            m.insert(format!("{k}_p99_ms"), Json::Num(h.percentile_ms(0.99)));
            m.insert(format!("{k}_p999_ms"), Json::Num(h.percentile_ms(0.999)));
        }
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn counters_accumulate_and_gauges_overwrite() {
        let mut r = MetricsRegistry::new();
        r.counter_add("serve.completed_total", 3);
        r.counter_add("serve.completed_total", 2);
        r.gauge_set("serve.queue_depth", 4.0);
        r.gauge_set("serve.queue_depth", 1.0);
        assert_eq!(r.counter("serve.completed_total"), 5);
        assert_eq!(r.gauge("serve.queue_depth"), Some(1.0));
        assert_eq!(r.counter("serve.missing_total"), 0);
        assert_eq!(r.gauge("serve.missing"), None);
    }

    #[test]
    fn snapshot_is_flat_and_stable() {
        let mut r = MetricsRegistry::new();
        r.counter_add("serve.completed_total", 7);
        r.gauge_set("serve.queue_depth", 2.0);
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_millis(3));
        r.hist_merge("serve.latency_ms", &h);
        let j = r.snapshot_json();
        let text = j.to_string();
        assert!(!text.contains('\n'));
        // every value is a top-level scalar — flat by construction
        for (k, v) in j.as_obj().unwrap() {
            assert!(v.as_f64().is_some(), "{k} is not a scalar");
        }
        assert_eq!(j.at(&["serve.completed_total"]).as_usize(), Some(7));
        assert_eq!(j.at(&["serve.latency_ms_count_total"]).as_usize(), Some(1));
        assert!(j.at(&["serve.latency_ms_p999_ms"]).as_f64().unwrap() > 0.0);
        // flattened ladder is monotone
        let ladder: Vec<f64> = ["p50", "p90", "p99", "p999"]
            .iter()
            .map(|p| j.at(&[&format!("serve.latency_ms_{p}_ms")[..]]).as_f64().unwrap())
            .collect();
        for w in ladder.windows(2) {
            assert!(w[0] <= w[1], "{ladder:?}");
        }
    }

    #[test]
    fn feeds_produce_the_documented_names() {
        let mut stats = ServeStats::new();
        stats.submitted = 5;
        stats.completed = 4;
        stats.rejected = 1;
        stats.record_latency(Duration::from_millis(2));
        let mut reg = MetricsRegistry::new();
        reg.feed_serve_stats(&stats);
        assert_eq!(reg.counter("serve.submitted_total"), 5);
        assert_eq!(reg.counter("serve.completed_total"), 4);
        assert_eq!(reg.counter("serve.rejected_total"), 1);

        let m = RunMetrics {
            total_steps: 8,
            blocks: 4,
            retunes: 1,
            core_cells: 1000,
            elapsed: Duration::from_millis(10),
            overlap: true,
            ..Default::default()
        };
        let mut reg = MetricsRegistry::new();
        reg.feed_run_metrics(&m);
        assert_eq!(reg.counter("run.steps_total"), 8);
        assert_eq!(reg.counter("run.retunes_total"), 1);
        assert_eq!(reg.gauge("run.overlap"), Some(1.0));

        let mut rec = Recorder::new();
        rec.on_send();
        rec.on_lost();
        rec.on_retry(50);
        let mut reg = MetricsRegistry::new();
        reg.feed_recorder(&rec);
        assert_eq!(reg.counter("load.offered_total"), 1);
        assert_eq!(reg.counter("load.lost_total"), 1);
        assert_eq!(reg.counter("load.retried_total"), 1);
        assert_eq!(reg.counter("load.gave_up_total"), 0);
    }

    /// Successive snapshots fed from a cumulative source are monotone in
    /// every `_total` key — the invariant `bench check` gates on.
    #[test]
    fn successive_snapshots_are_monotone() {
        let mut stats = ServeStats::new();
        stats.completed = 3;
        let mut a = MetricsRegistry::new();
        a.feed_serve_stats(&stats);
        stats.completed = 9;
        stats.record_latency(Duration::from_millis(1));
        let mut b = MetricsRegistry::new();
        b.feed_serve_stats(&stats);
        let (ja, jb) = (a.snapshot_json(), b.snapshot_json());
        for (k, va) in ja.as_obj().unwrap() {
            if k.ends_with("_total") {
                let vb = jb.at(&[k.as_str()]).as_f64().unwrap();
                assert!(vb >= va.as_f64().unwrap(), "{k} regressed");
            }
        }
    }
}
