//! Quantitative trace comparison — `tetris trace diff A B` aligns two
//! exported Chrome traces by `(cat, name)` phase and reports per-phase
//! count / total-µs / total-bytes deltas, so "paste grew 40%" reads as
//! exactly that instead of "the run got slower".  `--fail-over PCT`
//! turns the report into a CI gate: any phase present in both traces
//! whose total µs grew by more than PCT% is a violation.
//!
//! The same module derives the §5.3 overlap witness (`tetris trace
//! hidden`): summed assemble/writeback span time whose *end* falls
//! inside some `pipeline/compute` span interval — leader work that
//! demonstrably ran while a compute slab was in flight.  CI compares it
//! against `RunMetrics.overlap_hidden` from the matching
//! `BENCH_overlap_on.json`, making the trace an independent second
//! witness for the overlap claim.
//!
//! Output is byte-stable: phases sort by key, durations round to whole
//! µs, and growth percentages print with one decimal — golden-file
//! tests assert the exact text.

use std::collections::{BTreeMap, BTreeSet};

use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// Aggregate of one `(cat, name)` phase in one trace: `count` is the
/// number of `B` spans plus `i` instants, `total_us` the summed
/// (LIFO-paired) span durations, `total_bytes` the summed `bytes` args
/// on begin/instant events.  Flow events carry no duration or payload
/// and are excluded.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PhaseAgg {
    pub count: u64,
    pub total_us: u64,
    pub total_bytes: u64,
}

/// Fold a parsed Chrome trace into per-phase aggregates, keyed
/// `"{cat}/{name}"`.  Span pairing mirrors `trace check`: a LIFO stack
/// per `(pid, tid)` track, so a malformed trace degrades gracefully
/// (orphan ends attribute nothing) rather than erroring — `check` is
/// the well-formedness gate, `diff` only measures.
pub fn aggregate(j: &Json) -> BTreeMap<String, PhaseAgg> {
    let mut out: BTreeMap<String, PhaseAgg> = BTreeMap::new();
    let Some(events) = j.at(&["traceEvents"]).as_arr() else {
        return out;
    };
    let mut stacks: BTreeMap<(u64, u64), Vec<(String, f64)>> = BTreeMap::new();
    for e in events {
        let cat = e.at(&["cat"]).as_str().unwrap_or("");
        let name = e.at(&["name"]).as_str().unwrap_or("");
        let ts = e.at(&["ts"]).as_f64().unwrap_or(0.0);
        let bytes = e.at(&["args", "bytes"]).as_u64().unwrap_or(0);
        let track =
            (e.at(&["pid"]).as_u64().unwrap_or(0), e.at(&["tid"]).as_u64().unwrap_or(0));
        match e.at(&["ph"]).as_str().unwrap_or("") {
            "B" => {
                let key = format!("{cat}/{name}");
                let agg = out.entry(key.clone()).or_default();
                agg.count += 1;
                agg.total_bytes += bytes;
                stacks.entry(track).or_default().push((key, ts));
            }
            "E" => {
                if let Some((bkey, bts)) = stacks.entry(track).or_default().pop() {
                    out.entry(bkey).or_default().total_us += (ts - bts).max(0.0).round() as u64;
                }
            }
            "i" => {
                let agg = out.entry(format!("{cat}/{name}")).or_default();
                agg.count += 1;
                agg.total_bytes += bytes;
            }
            _ => {}
        }
    }
    out
}

/// Render the per-phase comparison (byte-stable) and collect
/// `--fail-over` violations: phases present in both whose total µs grew
/// by more than `fail_over` percent.
pub fn diff_report(
    a_name: &str,
    b_name: &str,
    a: &BTreeMap<String, PhaseAgg>,
    b: &BTreeMap<String, PhaseAgg>,
    fail_over: Option<f64>,
) -> (String, Vec<String>) {
    let mut lines = vec![format!("trace diff: A={a_name} B={b_name}")];
    let mut violations = Vec::new();
    let keys: BTreeSet<&String> = a.keys().chain(b.keys()).collect();
    for key in keys {
        match (a.get(key), b.get(key)) {
            (Some(x), None) => lines.push(format!(
                "{key}: only in A (count {}, us {}, bytes {})",
                x.count, x.total_us, x.total_bytes
            )),
            (None, Some(y)) => lines.push(format!(
                "{key}: only in B (count {}, us {}, bytes {})",
                y.count, y.total_us, y.total_bytes
            )),
            (Some(x), Some(y)) => {
                let pct = (x.total_us > 0).then(|| {
                    (y.total_us as f64 - x.total_us as f64) / x.total_us as f64 * 100.0
                });
                let pct_s = match pct {
                    Some(p) => format!("{p:+.1}%"),
                    None => "n/a".into(),
                };
                lines.push(format!(
                    "{key}: count {} -> {}; us {} -> {} ({pct_s}); bytes {} -> {}",
                    x.count, y.count, x.total_us, y.total_us, x.total_bytes, y.total_bytes
                ));
                if let (Some(limit), Some(p)) = (fail_over, pct) {
                    if p > limit {
                        violations.push(format!(
                            "{key}: total us grew {p:+.1}% > {limit}% ({} -> {})",
                            x.total_us, y.total_us
                        ));
                    }
                }
            }
            (None, None) => unreachable!("key came from one of the maps"),
        }
    }
    (lines.join("\n"), violations)
}

/// Driver for `tetris trace diff A B [--fail-over PCT]`: print the
/// report, error out when any phase crossed the threshold.
pub fn diff_files(a_path: &str, b_path: &str, fail_over: Option<f64>) -> Result<()> {
    let read = |p: &str| -> Result<BTreeMap<String, PhaseAgg>> {
        let text = std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?;
        let j = Json::parse(text.trim()).with_context(|| format!("parsing {p}"))?;
        Ok(aggregate(&j))
    };
    let a = read(a_path)?;
    let b = read(b_path)?;
    let (report, violations) = diff_report(a_path, b_path, &a, &b, fail_over);
    println!("{report}");
    for v in &violations {
        println!("trace diff: VIOLATION: {v}");
    }
    crate::ensure!(
        violations.is_empty(),
        "{} phase(s) over the --fail-over threshold",
        violations.len()
    );
    Ok(())
}

/// Disagreements smaller than this are never flagged: the compute span
/// brackets the whole task closure (slightly wider than the timed
/// `run_slab` the `inflight` gauge brackets), so the two witnesses can
/// legitimately differ by scheduling-noise amounts on short runs.
pub const HIDDEN_FLOOR_MS: f64 = 2.0;

/// Trace-derived §5.3 hidden-leader-time: summed duration (ms) of
/// `pipeline` assemble/writeback spans whose **end** timestamp falls
/// inside some `pipeline/compute` span interval — the same "leader work
/// finished while a slab was in flight" accounting
/// `RunMetrics.overlap_hidden` keeps, reconstructed independently from
/// the trace (intervals may live on different threads; the comparison
/// is global, which is the point of a cross-thread trace).
pub fn hidden_ms_from_trace(j: &Json) -> f64 {
    let Some(events) = j.at(&["traceEvents"]).as_arr() else {
        return 0.0;
    };
    let mut stacks: BTreeMap<(u64, u64), Vec<(String, String, f64)>> = BTreeMap::new();
    let mut compute: Vec<(f64, f64)> = Vec::new();
    let mut moved: Vec<(f64, f64)> = Vec::new();
    for e in events {
        let ts = e.at(&["ts"]).as_f64().unwrap_or(0.0);
        let track =
            (e.at(&["pid"]).as_u64().unwrap_or(0), e.at(&["tid"]).as_u64().unwrap_or(0));
        match e.at(&["ph"]).as_str().unwrap_or("") {
            "B" => {
                let cat = e.at(&["cat"]).as_str().unwrap_or("").to_string();
                let name = e.at(&["name"]).as_str().unwrap_or("").to_string();
                stacks.entry(track).or_default().push((cat, name, ts));
            }
            "E" => {
                if let Some((cat, name, bts)) = stacks.entry(track).or_default().pop() {
                    if cat == "pipeline" {
                        match name.as_str() {
                            "compute" => compute.push((bts, ts)),
                            "assemble" | "writeback" => moved.push((bts, ts)),
                            _ => {}
                        }
                    }
                }
            }
            _ => {}
        }
    }
    let hidden_us: f64 = moved
        .iter()
        .filter(|&&(_, end)| compute.iter().any(|&(cb, ce)| cb <= end && end <= ce))
        .map(|&(b, e)| e - b)
        .sum();
    hidden_us / 1e3
}

/// Pull the `hidden {:.3} ms` figure out of a `run_overlap` row's
/// `extra` string — the format is the contract (see
/// `crate::bench::run_overlap`); a format change there must update this.
pub fn extract_hidden_ms(extra: &str) -> Option<f64> {
    let rest = &extra[extra.find("hidden ")? + "hidden ".len()..];
    rest[..rest.find(" ms")?].parse().ok()
}

/// Driver for `tetris trace hidden TRACE --bench-json FILE`: the trace
/// and `RunMetrics.overlap_hidden` (from the bench artifact's
/// `overlap=on` row) must agree within `tolerance_pct` percent of the
/// larger figure, with a [`HIDDEN_FLOOR_MS`] absolute floor.
pub fn hidden_files(trace_path: &str, bench_path: &str, tolerance_pct: f64) -> Result<()> {
    let text =
        std::fs::read_to_string(trace_path).with_context(|| format!("reading {trace_path}"))?;
    let trace =
        Json::parse(text.trim()).with_context(|| format!("parsing {trace_path}"))?;
    let trace_ms = hidden_ms_from_trace(&trace);
    let btext =
        std::fs::read_to_string(bench_path).with_context(|| format!("reading {bench_path}"))?;
    let bench = Json::parse(btext.trim()).with_context(|| format!("parsing {bench_path}"))?;
    let metric_ms = bench
        .at(&["sections", "overlap"])
        .as_arr()
        .into_iter()
        .flatten()
        .filter(|r| r.at(&["label"]).as_str() == Some("overlap=on"))
        .find_map(|r| extract_hidden_ms(r.at(&["extra"]).as_str().unwrap_or("")));
    let Some(metric_ms) = metric_ms else {
        crate::bail!("{bench_path}: no overlap=on row with a 'hidden X ms' extra");
    };
    let tol = (tolerance_pct / 100.0 * trace_ms.max(metric_ms)).max(HIDDEN_FLOOR_MS);
    println!(
        "trace hidden: {trace_path}: trace-derived {trace_ms:.3} ms vs \
         RunMetrics.overlap_hidden {metric_ms:.3} ms (tolerance +/-{tol:.3} ms)"
    );
    crate::ensure!(
        (trace_ms - metric_ms).abs() <= tol,
        "trace-derived hidden time {trace_ms:.3} ms disagrees with \
         RunMetrics.overlap_hidden {metric_ms:.3} ms beyond +/-{tol:.3} ms"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOLDEN_A: &str = include_str!("../../tests/golden/trace_diff_a.json");
    const GOLDEN_B: &str = include_str!("../../tests/golden/trace_diff_b.json");
    const GOLDEN_EXPECTED: &str = include_str!("../../tests/golden/trace_diff.expected");

    fn parse(s: &str) -> Json {
        Json::parse(s.trim()).unwrap()
    }

    #[test]
    fn aggregate_counts_durations_and_bytes() {
        let j = parse(
            r#"{"traceEvents":[
              {"ph":"B","ts":10,"pid":1,"tid":0,"cat":"leader","name":"extract","args":{"bytes":100}},
              {"ph":"E","ts":40,"pid":1,"tid":0,"cat":"leader","name":"extract"},
              {"ph":"B","ts":50,"pid":1,"tid":0,"cat":"leader","name":"extract","args":{"bytes":60}},
              {"ph":"E","ts":55,"pid":1,"tid":0,"cat":"leader","name":"extract"},
              {"ph":"i","ts":60,"pid":1,"tid":0,"cat":"serve","name":"batch","args":{"bytes":7}},
              {"ph":"s","ts":61,"pid":1,"tid":0,"cat":"serve","name":"job","id":"ab"}
            ]}"#,
        );
        let agg = aggregate(&j);
        let ex = agg.get("leader/extract").unwrap();
        assert_eq!((ex.count, ex.total_us, ex.total_bytes), (2, 35, 160));
        let batch = agg.get("serve/batch").unwrap();
        assert_eq!((batch.count, batch.total_us, batch.total_bytes), (1, 0, 7));
        // flow events are excluded from aggregation
        assert!(!agg.contains_key("serve/job"), "{agg:?}");
    }

    /// Nested and cross-thread spans pair per-track LIFO, like `check`.
    #[test]
    fn aggregate_pairs_per_track() {
        let j = parse(
            r#"{"traceEvents":[
              {"ph":"B","ts":0,"pid":1,"tid":0,"cat":"pool","name":"task"},
              {"ph":"B","ts":5,"pid":1,"tid":1,"cat":"pool","name":"task"},
              {"ph":"E","ts":7,"pid":1,"tid":1,"cat":"pool","name":"task"},
              {"ph":"E","ts":20,"pid":1,"tid":0,"cat":"pool","name":"task"}
            ]}"#,
        );
        let agg = aggregate(&j);
        assert_eq!(agg.get("pool/task").unwrap().total_us, 22);
    }

    /// The golden pair's report is byte-identical to the checked-in
    /// expectation — the CLI output is a stable format.
    #[test]
    fn golden_diff_is_byte_stable() {
        let a = aggregate(&parse(GOLDEN_A));
        let b = aggregate(&parse(GOLDEN_B));
        let (report, violations) = diff_report("A", "B", &a, &b, None);
        assert_eq!(report, GOLDEN_EXPECTED.trim_end(), "golden drift");
        assert!(violations.is_empty());
    }

    #[test]
    fn fail_over_threshold_gates_growth() {
        let a = aggregate(&parse(GOLDEN_A));
        let b = aggregate(&parse(GOLDEN_B));
        // leader/extract grows +30.0% in the golden pair
        let (_, v) = diff_report("A", "B", &a, &b, Some(20.0));
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("leader/extract"), "{v:?}");
        let (_, v) = diff_report("A", "B", &a, &b, Some(50.0));
        assert!(v.is_empty(), "{v:?}");
        // shrinkage never violates
        let (_, v) = diff_report("B", "A", &b, &a, Some(0.0));
        assert!(v.iter().all(|m| !m.contains("leader/extract")), "{v:?}");
    }

    #[test]
    fn diff_files_exit_codes() {
        let dir = std::env::temp_dir();
        let pa = dir.join(format!("trace_diff_a_{}.json", std::process::id()));
        let pb = dir.join(format!("trace_diff_b_{}.json", std::process::id()));
        std::fs::write(&pa, GOLDEN_A).unwrap();
        std::fs::write(&pb, GOLDEN_B).unwrap();
        let (pa, pb) = (pa.to_string_lossy().into_owned(), pb.to_string_lossy().into_owned());
        assert!(diff_files(&pa, &pb, None).is_ok());
        assert!(diff_files(&pa, &pb, Some(50.0)).is_ok());
        assert!(diff_files(&pa, &pb, Some(20.0)).is_err());
        assert!(diff_files("/nonexistent/a.json", &pb, None).is_err());
        let _ = std::fs::remove_file(&pa);
        let _ = std::fs::remove_file(&pb);
    }

    #[test]
    fn hidden_counts_only_ends_inside_compute() {
        let j = parse(
            r#"{"traceEvents":[
              {"ph":"B","ts":100,"pid":1,"tid":1,"cat":"pipeline","name":"compute","args":{"task":1}},
              {"ph":"E","ts":200,"pid":1,"tid":1,"cat":"pipeline","name":"compute"},
              {"ph":"B","ts":50,"pid":1,"tid":2,"cat":"pipeline","name":"assemble","args":{"task":0}},
              {"ph":"E","ts":90,"pid":1,"tid":2,"cat":"pipeline","name":"assemble"},
              {"ph":"B","ts":120,"pid":1,"tid":2,"cat":"pipeline","name":"writeback","args":{"task":2}},
              {"ph":"E","ts":180,"pid":1,"tid":2,"cat":"pipeline","name":"writeback"},
              {"ph":"B","ts":190,"pid":1,"tid":3,"cat":"leader","name":"paste"},
              {"ph":"E","ts":195,"pid":1,"tid":3,"cat":"leader","name":"paste"}
            ]}"#,
        );
        // assemble ends at 90 (outside compute [100,200]) — not hidden;
        // writeback ends at 180 (inside) — its full 60us counts; the
        // leader span is not a pipeline stage and never counts.
        let ms = hidden_ms_from_trace(&j);
        assert!((ms - 0.060).abs() < 1e-9, "{ms}");
    }

    #[test]
    fn hidden_extraction_from_overlap_extra() {
        let extra = "summed idle 12.500 ms; hidden 3.250 ms; overlapped msgs 5/9";
        assert_eq!(extract_hidden_ms(extra), Some(3.25));
        assert_eq!(extract_hidden_ms("no such key"), None);
        assert_eq!(extract_hidden_ms("hidden x ms"), None);
    }

    #[test]
    fn hidden_files_agreement_gate() {
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        let tp = dir.join(format!("trace_hidden_{pid}.json"));
        std::fs::write(
            &tp,
            r#"{"traceEvents":[
              {"ph":"B","ts":0,"pid":1,"tid":1,"cat":"pipeline","name":"compute"},
              {"ph":"E","ts":10000,"pid":1,"tid":1,"cat":"pipeline","name":"compute"},
              {"ph":"B","ts":1000,"pid":1,"tid":2,"cat":"pipeline","name":"writeback"},
              {"ph":"E","ts":5000,"pid":1,"tid":2,"cat":"pipeline","name":"writeback"}
            ]}"#,
        )
        .unwrap();
        let bench = |hidden: f64| {
            let bp = dir.join(format!("bench_hidden_{pid}_{hidden}.json"));
            std::fs::write(
                &bp,
                format!(
                    r#"{{"sections":{{"overlap":[{{"label":"overlap=off","extra":"summed idle 9.000 ms; hidden 0.000 ms; overlapped msgs 0/9"}},{{"label":"overlap=on","extra":"summed idle 2.000 ms; hidden {hidden:.3} ms; overlapped msgs 5/9"}}]}}}}"#
                ),
            )
            .unwrap();
            bp.to_string_lossy().into_owned()
        };
        let tp = tp.to_string_lossy().into_owned();
        // trace-derived hidden = 4 ms; 4.5 ms agrees within 15%+floor
        assert!(hidden_files(&tp, &bench(4.5), 15.0).is_ok());
        // 60 ms disagrees far beyond tolerance
        assert!(hidden_files(&tp, &bench(60.0), 15.0).is_err());
        // a bench json without the overlap=on row is an error
        let empty = dir.join(format!("bench_hidden_{pid}_empty.json"));
        std::fs::write(&empty, r#"{"sections":{}}"#).unwrap();
        assert!(hidden_files(&tp, &empty.to_string_lossy(), 15.0).is_err());
        for f in [tp, bench(4.5), bench(60.0), empty.to_string_lossy().into_owned()] {
            let _ = std::fs::remove_file(f);
        }
    }
}
