//! Cross-layer span tracing (`--trace FILE` / `TETRIS_TRACE`).
//!
//! A process-global [`Tracer`] collects begin/end spans and instant
//! events from every layer — the work-stealing pool, the pipelined
//! leader loop, §5.2 retune decisions, plan-search trials and the serve
//! job lifecycle — into per-thread buffers, and exports them as Chrome
//! trace-event JSON (`chrome://tracing` / Perfetto loadable).
//!
//! Design constraints, in order:
//! * **disabled cost ≈ zero** — every recording entry point starts with
//!   one `Relaxed` atomic load ([`enabled`]); nothing allocates, locks
//!   or reads the clock before that branch.  The disabled-path overhead
//!   test in this module gates the property in CI.
//! * **no `unsafe`** — the crate forbids it, so "per-thread lock-free"
//!   is implemented as a thread-local `Arc<Mutex<Vec<Event>>>`: the
//!   owning thread's push takes an uncontended mutex (one CAS, no
//!   syscall), and the only contention ever seen is a quiescent-time
//!   [`drain`].  Buffers are bounded ([`BUFFER_CAP`]): past the cap new
//!   begin/instant events are counted in [`dropped`] and discarded
//!   (drop-newest keeps the recorded prefix well-formed); end events
//!   for already-recorded begins always land so spans stay balanced.
//! * **spans are diffable against the analyze model** — pipeline-stage
//!   spans carry the same task ids a [`crate::analyze::WindowPlan`]
//!   certifies, so `tetris trace check` can verify a recorded window
//!   against the statically checked DAG (see [`check`]).
//!
//! Event vocabulary (category → names):
//! * `pool` — `task` spans (args: `task`, `worker`, `wait_us` queue
//!   wait between ready-release and execution start);
//! * `pipeline` — `assemble`/`compute`/`writeback` spans (args: `task`
//!   = WindowPlan id, `block`, `field`, `worker`, `sched` tag) and a
//!   `window` instant announcing each window's geometry (`b0`, `bw`,
//!   `nf`, `nw`, `sched`);
//! * `leader` — serial-loop `ghost`/`extract`/`dispatch`/`paste` spans;
//! * `retune` — `kept`/`migrated` instants with the §5.2 gain vs
//!   k·(α+nβ) migration-cost estimate as args;
//! * `plan` — one `trial` span per timed plan-search candidate;
//! * `serve` — `accept`/`admit`/`reject`/`dequeue`/`batch`/`reply`
//!   instants plus `run` spans, linked across threads by the `job` arg
//!   **and** by a `job` flow (`ph:"s"` at accept, `ph:"t"` at
//!   admit/dequeue, `ph:"f"` at the reply — one finish per job, even
//!   rejects), so Perfetto draws the cross-thread arrow;
//! * `pipeline` flows — a `chain` flow per `(block, field, worker)`
//!   linking assemble (`s`) → compute (`t`) → writeback (`f`), id =
//!   `window_tag << 20 | task/3` with a [`fresh_tag`] per window so
//!   chains never collide across windows or schedulers.
//!
//! Data-volume args: leader `ghost`/`extract`/`dispatch`/`paste` spans
//! and pipeline `assemble`/`compute`/`writeback` spans carry `bytes`
//! (f64 payload actually moved/shipped), and the per-slab stages add
//! `rows`/`slab_cells`, so a Perfetto track shows volume, not just
//! duration — and `tetris trace diff` (see [`diff`]) can report
//! per-phase byte deltas between two runs.

pub mod check;
pub mod diff;
pub mod metrics;

pub use metrics::MetricsRegistry;

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// Per-thread event cap (drop-newest past this; see [`dropped`]).
pub const BUFFER_CAP: usize = 1 << 20;

/// One span-argument value.
#[derive(Clone, Debug, PartialEq)]
pub enum Arg {
    U(u64),
    F(f64),
    S(String),
}

impl Arg {
    fn to_json(&self) -> Json {
        match self {
            Arg::U(x) => Json::Num(*x as f64),
            Arg::F(x) => Json::Num(*x),
            Arg::S(s) => Json::Str(s.clone()),
        }
    }
}

impl From<u64> for Arg {
    fn from(x: u64) -> Arg {
        Arg::U(x)
    }
}

impl From<usize> for Arg {
    fn from(x: usize) -> Arg {
        Arg::U(x as u64)
    }
}

impl From<f64> for Arg {
    fn from(x: f64) -> Arg {
        Arg::F(x)
    }
}

impl From<&str> for Arg {
    fn from(s: &str) -> Arg {
        Arg::S(s.to_string())
    }
}

/// Chrome trace-event phase of one recorded event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Duration begin (`ph:"B"`).
    Begin,
    /// Duration end (`ph:"E"`).
    End,
    /// Thread-scoped instant (`ph:"i"`).
    Instant,
    /// Flow start (`ph:"s"`) — the tail of a cross-thread arrow.
    FlowStart,
    /// Flow step (`ph:"t"`) — an intermediate hop of a flow.
    FlowStep,
    /// Flow finish (`ph:"f"`, `bp:"e"`) — the arrowhead.
    FlowFinish,
}

impl Phase {
    fn ph(self) -> &'static str {
        match self {
            Phase::Begin => "B",
            Phase::End => "E",
            Phase::Instant => "i",
            Phase::FlowStart => "s",
            Phase::FlowStep => "t",
            Phase::FlowFinish => "f",
        }
    }
}

/// One recorded event; `ts_us` is microseconds since the tracer epoch.
/// `id` is meaningful only for the flow phases (0 elsewhere): events of
/// one flow share it, and the chrome export writes it as a hex string so
/// full-width u64 ids survive the f64 JSON number space.
#[derive(Clone, Debug)]
pub struct Event {
    pub ts_us: u64,
    pub phase: Phase,
    pub cat: &'static str,
    pub name: String,
    pub id: u64,
    pub args: Vec<(&'static str, Arg)>,
}

/// All events one thread recorded, in emission (= timestamp) order.
#[derive(Clone, Debug)]
pub struct ThreadEvents {
    /// Dense tracer-assigned thread index (the chrome `tid`).
    pub tid: u64,
    pub events: Vec<Event>,
}

struct Buffer {
    tid: u64,
    events: Mutex<Vec<Event>>,
}

struct Tracer {
    enabled: AtomicBool,
    epoch: OnceLock<Instant>,
    buffers: Mutex<Vec<Arc<Buffer>>>,
    next_tid: AtomicU64,
    dropped: AtomicU64,
}

static TRACER: Tracer = Tracer {
    enabled: AtomicBool::new(false),
    epoch: OnceLock::new(),
    buffers: Mutex::new(Vec::new()),
    next_tid: AtomicU64::new(0),
    dropped: AtomicU64::new(0),
};

thread_local! {
    static LOCAL: RefCell<Option<Arc<Buffer>>> = const { RefCell::new(None) };
}

/// The disabled-path guard: one `Relaxed` load, nothing else.  Call
/// sites whose argument marshalling allocates should branch on this
/// before building the args.
#[inline(always)]
pub fn enabled() -> bool {
    TRACER.enabled.load(Ordering::Relaxed)
}

/// Turn recording on (idempotent).  The epoch is pinned on first use so
/// timestamps from every thread share one zero.
pub fn enable() {
    TRACER.epoch.get_or_init(Instant::now);
    TRACER.enabled.store(true, Ordering::Relaxed);
}

/// Turn recording off; buffered events stay drainable.
pub fn disable() {
    TRACER.enabled.store(false, Ordering::Relaxed);
}

/// Microseconds since the tracer epoch (pins the epoch if unset).
pub fn now_us() -> u64 {
    TRACER.epoch.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// Events discarded because a thread buffer hit [`BUFFER_CAP`].
pub fn dropped() -> u64 {
    TRACER.dropped.load(Ordering::Relaxed)
}

fn with_buffer<R>(f: impl FnOnce(&Buffer) -> R) -> R {
    LOCAL.with(|cell| {
        let mut slot = cell.borrow_mut();
        if slot.is_none() {
            let buf = Arc::new(Buffer {
                tid: TRACER.next_tid.fetch_add(1, Ordering::Relaxed),
                events: Mutex::new(Vec::new()),
            });
            TRACER.buffers.lock().unwrap().push(buf.clone());
            *slot = Some(buf);
        }
        f(slot.as_ref().unwrap())
    })
}

/// `force` bypasses the cap — used for end events so a begin that made
/// it into the buffer is always balanced by its end.
fn record(
    phase: Phase,
    cat: &'static str,
    name: String,
    id: u64,
    args: Vec<(&'static str, Arg)>,
    force: bool,
) -> bool {
    let ts_us = now_us();
    with_buffer(|buf| {
        let mut events = buf.events.lock().unwrap();
        if !force && events.len() >= BUFFER_CAP {
            TRACER.dropped.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        events.push(Event { ts_us, phase, cat, name, id, args });
        true
    })
}

/// Record a thread-scoped instant event.
#[inline]
pub fn instant(cat: &'static str, name: &str, args: &[(&'static str, Arg)]) {
    if !enabled() {
        return;
    }
    record(Phase::Instant, cat, name.to_string(), 0, args.to_vec(), false);
}

/// FNV-1a of a string — the flow-id convention for serve jobs, so the
/// start (accept thread), steps (queue) and finish (dispatcher thread)
/// of one job's flow agree on an id without sharing state.
pub fn flow_id(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

/// Record a flow start (`ph:"s"`) — the tail of a cross-thread arrow.
/// Events of one flow share `(cat, name, id)`.
#[inline]
pub fn flow_start(cat: &'static str, name: &str, id: u64, args: &[(&'static str, Arg)]) {
    if !enabled() {
        return;
    }
    record(Phase::FlowStart, cat, name.to_string(), id, args.to_vec(), false);
}

/// Record a flow step (`ph:"t"`) — an intermediate hop.
#[inline]
pub fn flow_step(cat: &'static str, name: &str, id: u64, args: &[(&'static str, Arg)]) {
    if !enabled() {
        return;
    }
    record(Phase::FlowStep, cat, name.to_string(), id, args.to_vec(), false);
}

/// Record a flow finish (`ph:"f"`, binding `bp:"e"`) — the arrowhead.
/// Forced past the cap like span ends: a started flow always finishes,
/// so `trace check`'s pairing invariant survives ring-buffer pressure.
#[inline]
pub fn flow_finish(cat: &'static str, name: &str, id: u64, args: &[(&'static str, Arg)]) {
    if !enabled() {
        return;
    }
    record(Phase::FlowFinish, cat, name.to_string(), id, args.to_vec(), true);
}

/// RAII duration span: records `Begin` on creation (when tracing is on)
/// and the matching `End` on drop, on the same thread.
pub struct Span {
    /// `Some((cat, name))` only when the begin event was recorded.
    live: Option<(&'static str, String)>,
}

impl Span {
    /// Inert span (nothing recorded, drop is free).
    pub fn off() -> Span {
        Span { live: None }
    }
}

/// Open a duration span; the returned guard closes it.
#[inline]
pub fn span(cat: &'static str, name: &str, args: &[(&'static str, Arg)]) -> Span {
    if !enabled() {
        return Span::off();
    }
    let recorded = record(Phase::Begin, cat, name.to_string(), 0, args.to_vec(), false);
    Span { live: recorded.then(|| (cat, name.to_string())) }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some((cat, name)) = self.live.take() {
            record(Phase::End, cat, name, 0, Vec::new(), true);
        }
    }
}

/// Collect every thread's buffered events, clearing the buffers.  Call
/// at quiescence (after joins / run completion): a span still open while
/// its begin is drained would close into the *next* drain.
pub fn drain() -> Vec<ThreadEvents> {
    let buffers = TRACER.buffers.lock().unwrap();
    buffers
        .iter()
        .filter_map(|buf| {
            let events = std::mem::take(&mut *buf.events.lock().unwrap());
            if events.is_empty() {
                None
            } else {
                Some(ThreadEvents { tid: buf.tid, events })
            }
        })
        .collect()
}

/// Render drained events as a Chrome trace-event document
/// (`{"traceEvents": [...]}`), one `pid` (this process), tracer thread
/// indices as `tid`s, timestamps in microseconds.
pub fn chrome_json(threads: &[ThreadEvents]) -> Json {
    let mut events = Vec::new();
    for t in threads {
        for e in &t.events {
            let mut m = BTreeMap::new();
            m.insert("ph".into(), Json::Str(e.phase.ph().into()));
            m.insert("ts".into(), Json::Num(e.ts_us as f64));
            m.insert("pid".into(), Json::Num(1.0));
            m.insert("tid".into(), Json::Num(t.tid as f64));
            m.insert("cat".into(), Json::Str(e.cat.into()));
            m.insert("name".into(), Json::Str(e.name.clone()));
            match e.phase {
                Phase::Instant => {
                    // thread-scoped instants; chrome wants the scope key
                    m.insert("s".into(), Json::Str("t".into()));
                }
                Phase::FlowStart | Phase::FlowStep | Phase::FlowFinish => {
                    // hex string: u64 flow ids survive the f64 number space
                    m.insert("id".into(), Json::Str(format!("{:x}", e.id)));
                    if e.phase == Phase::FlowFinish {
                        // bind the arrowhead to the enclosing slice's end
                        m.insert("bp".into(), Json::Str("e".into()));
                    }
                }
                Phase::Begin | Phase::End => {}
            }
            if !e.args.is_empty() {
                let args: BTreeMap<String, Json> =
                    e.args.iter().map(|(k, v)| (k.to_string(), v.to_json())).collect();
                m.insert("args".into(), Json::Obj(args));
            }
            events.push(Json::Obj(m));
        }
    }
    let mut top = BTreeMap::new();
    top.insert("traceEvents".into(), Json::Arr(events));
    top.insert("displayTimeUnit".into(), Json::Str("ms".into()));
    if dropped() > 0 {
        let mut meta = BTreeMap::new();
        meta.insert("dropped_events".into(), Json::Num(dropped() as f64));
        top.insert("metadata".into(), Json::Obj(meta));
    }
    Json::Obj(top)
}

/// Drain and write the Chrome trace-event JSON to `path`.
pub fn write_chrome_file(path: &str) -> Result<usize> {
    let threads = drain();
    let n: usize = threads.iter().map(|t| t.events.len()).sum();
    let doc = chrome_json(&threads);
    std::fs::write(path, format!("{doc}\n")).with_context(|| format!("writing trace {path}"))?;
    Ok(n)
}

/// Fresh tag for one scheduler/session instance; pipeline spans carry
/// it so traces with several concurrent schedulers stay separable.
pub fn fresh_tag() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

#[cfg(test)]
pub(crate) mod testutil {
    use std::sync::{Mutex, MutexGuard};

    /// Tests that enable the global tracer serialize on this lock and
    /// drain before releasing, so parallel tests never see each other's
    /// events.  (Filtering by a per-scheduler `sched` tag additionally
    /// isolates pipeline assertions.)
    static LOCK: Mutex<()> = Mutex::new(());

    pub fn lock() -> MutexGuard<'static, ()> {
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    /// Concurrent tests emit events of their own whenever the global
    /// tracer is enabled (the instrumented pool/pipeline/serve paths run
    /// constantly under `cargo test`), but no thread ever writes to
    /// another thread's buffer — so assertions are scoped to the tracks
    /// carrying a test-unique marker.  Leading `End` events are dropped:
    /// a foreign span that began during an *earlier* test's enabled
    /// window can force-record its end into a reused harness thread's
    /// buffer after that test drained.
    fn own_events(threads: Vec<ThreadEvents>, marker: impl Fn(&Event) -> bool) -> Vec<Event> {
        let mut out = Vec::new();
        for t in threads {
            if !t.events.iter().any(&marker) {
                continue;
            }
            let start =
                t.events.iter().position(|e| e.phase != Phase::End).unwrap_or(t.events.len());
            out.extend(t.events.into_iter().skip(start));
        }
        out
    }

    #[test]
    fn disabled_records_nothing() {
        let _g = testutil::lock();
        disable();
        let _ = drain();
        instant("pool", "nonce-disabled", &[("task", Arg::U(1))]);
        {
            let _s = span("pool", "nonce-disabled", &[]);
        }
        let drained = drain();
        let ours: Vec<&Event> = drained
            .iter()
            .flat_map(|t| &t.events)
            .filter(|e| e.name == "nonce-disabled")
            .collect();
        assert!(ours.is_empty(), "{ours:?}");
    }

    #[test]
    fn spans_balance_and_timestamps_are_monotone() {
        let _g = testutil::lock();
        enable();
        let _ = drain();
        {
            let _outer = span("pool", "outer", &[("task", Arg::U(7))]);
            std::thread::sleep(Duration::from_millis(1));
            {
                let _inner = span("pool", "inner", &[]);
            }
            instant("retune", "kept", &[("gain_s", Arg::F(0.5))]);
        }
        disable();
        let events = own_events(drain(), |e| e.name == "outer");
        assert_eq!(events.len(), 5, "{events:?}");
        let mut stack = Vec::new();
        let mut last_ts = 0u64;
        for e in &events {
            assert!(e.ts_us >= last_ts, "timestamps must be monotone: {events:?}");
            last_ts = e.ts_us;
            match e.phase {
                Phase::Begin => stack.push(e.name.clone()),
                Phase::End => assert_eq!(stack.pop().as_deref(), Some(e.name.as_str())),
                _ => {}
            }
        }
        assert!(stack.is_empty(), "unbalanced spans: {events:?}");
        // LIFO closing order: inner ends before outer
        assert_eq!(events[0].name, "outer");
        assert_eq!(events.last().unwrap().name, "outer");
    }

    #[test]
    fn chrome_export_shape() {
        let _g = testutil::lock();
        enable();
        let _ = drain();
        {
            let _s = span("testcat", "compute", &[("task", Arg::U(4)), ("sched", Arg::U(9))]);
        }
        instant("testcat", "admit", &[("job", Arg::S("j1".into()))]);
        disable();
        let events = own_events(drain(), |e| e.cat == "testcat");
        let doc = chrome_json(&[ThreadEvents { tid: 0, events }]);
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap();
        let evs = back.at(&["traceEvents"]).as_arr().unwrap();
        assert_eq!(evs.len(), 3);
        let begin = evs.iter().find(|e| e.at(&["ph"]).as_str() == Some("B")).unwrap();
        assert_eq!(begin.at(&["cat"]).as_str(), Some("testcat"));
        assert_eq!(begin.at(&["name"]).as_str(), Some("compute"));
        assert_eq!(begin.at(&["args", "task"]).as_usize(), Some(4));
        assert_eq!(begin.at(&["pid"]).as_usize(), Some(1));
        let inst = evs.iter().find(|e| e.at(&["ph"]).as_str() == Some("i")).unwrap();
        assert_eq!(inst.at(&["s"]).as_str(), Some("t"));
        assert_eq!(inst.at(&["args", "job"]).as_str(), Some("j1"));
    }

    /// Flow events export with `ph:"s"/"t"/"f"`, a shared hex-string id
    /// (u64-lossless) and `bp:"e"` on the finish only.
    #[test]
    fn flow_export_shape() {
        let _g = testutil::lock();
        enable();
        let _ = drain();
        // an id above 2^53: would be mangled as an f64 JSON number
        let id = flow_id("job-xyz") | (1u64 << 63);
        flow_start("flowcat", "job", id, &[("job", Arg::S("job-xyz".into()))]);
        flow_step("flowcat", "job", id, &[]);
        flow_finish("flowcat", "job", id, &[]);
        disable();
        let events = own_events(drain(), |e| e.cat == "flowcat");
        let doc = chrome_json(&[ThreadEvents { tid: 0, events }]);
        let back = Json::parse(&doc.to_string()).unwrap();
        let evs = back.at(&["traceEvents"]).as_arr().unwrap();
        assert_eq!(evs.len(), 3);
        let by_ph = |ph: &str| {
            evs.iter().find(|e| e.at(&["ph"]).as_str() == Some(ph)).unwrap_or_else(|| {
                panic!("no {ph} event in {evs:?}");
            })
        };
        let want_id = format!("{id:x}");
        for ph in ["s", "t", "f"] {
            let e = by_ph(ph);
            assert_eq!(e.at(&["id"]).as_str(), Some(want_id.as_str()), "{ph}");
            assert_eq!(e.at(&["name"]).as_str(), Some("job"), "{ph}");
        }
        assert_eq!(by_ph("f").at(&["bp"]).as_str(), Some("e"));
        assert!(by_ph("s").at(&["bp"]).as_str().is_none());
    }

    #[test]
    fn flow_id_is_deterministic_and_spread() {
        assert_eq!(flow_id("job-1"), flow_id("job-1"));
        assert_ne!(flow_id("job-1"), flow_id("job-2"));
        // the empty string hashes to the FNV offset basis
        assert_eq!(flow_id(""), 0xcbf2_9ce4_8422_2325);
    }

    /// Satellite: multi-thread emission racing a mid-stream drain must
    /// lose nothing — every recorded event shows up in exactly one
    /// drain, per-thread order intact.
    #[test]
    fn multithread_drain_race_loses_nothing() {
        let _g = testutil::lock();
        enable();
        let _ = drain();
        const THREADS: usize = 4;
        const SPANS: usize = 500;
        // High unique id base: no production call site emits task ids up
        // here, so our tracks are identifiable among concurrent tests'.
        let base = fresh_tag() << 32;
        let collected = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for t in 0..THREADS {
                s.spawn(move || {
                    for i in 0..SPANS {
                        let _sp = span(
                            "pool",
                            "task",
                            &[("task", Arg::U(base + (t * SPANS + i) as u64))],
                        );
                    }
                });
            }
            // drain concurrently with the emitters
            for _ in 0..20 {
                collected.lock().unwrap().extend(drain());
                std::thread::yield_now();
            }
        });
        collected.lock().unwrap().extend(drain());
        disable();
        let collected = collected.into_inner().unwrap();
        let ours = |e: &Event| {
            matches!(e.args.iter().find(|(k, _)| *k == "task"),
                Some((_, Arg::U(x))) if *x >= base && *x < base + (THREADS * SPANS) as u64)
        };
        // Fresh scope threads own fresh buffers, so a track with one of
        // our ids carries exclusively this test's events.
        let tids: std::collections::BTreeSet<u64> = collected
            .iter()
            .filter(|t| t.events.iter().any(|e| ours(e)))
            .map(|t| t.tid)
            .collect();
        let mut seen = std::collections::BTreeSet::new();
        let mut begins = 0usize;
        let mut ends = 0usize;
        for t in collected.iter().filter(|t| tids.contains(&t.tid)) {
            for e in &t.events {
                match e.phase {
                    Phase::Begin => {
                        begins += 1;
                        assert!(ours(e), "foreign begin on our track: {e:?}");
                        let id = match e.args.iter().find(|(k, _)| *k == "task") {
                            Some((_, Arg::U(x))) => *x,
                            other => panic!("begin without task arg: {other:?}"),
                        };
                        assert!(seen.insert(id), "duplicate span id {id}");
                    }
                    Phase::End => ends += 1,
                    _ => {}
                }
            }
        }
        assert_eq!(begins, THREADS * SPANS, "lost begin events");
        assert_eq!(ends, THREADS * SPANS, "lost end events");
        assert_eq!(seen.len(), THREADS * SPANS);
    }

    /// Satellite: the disabled fast path must stay branch-cheap — no
    /// allocation, no locking, no clock read.  10⁶ guarded calls in
    /// well under a second even on a loaded CI runner.
    #[test]
    fn disabled_path_overhead_is_negligible() {
        let _g = testutil::lock();
        disable();
        let best = (0..3)
            .map(|_| {
                let t0 = Instant::now();
                for i in 0..1_000_000u64 {
                    if enabled() {
                        instant("pool", "task", &[("task", Arg::U(i))]);
                    }
                }
                t0.elapsed()
            })
            .min()
            .unwrap();
        assert!(
            best < Duration::from_millis(250),
            "disabled tracing cost {best:?} for 1e6 call sites"
        );
    }

    #[test]
    fn fresh_tags_are_unique() {
        let a = fresh_tag();
        let b = fresh_tag();
        assert_ne!(a, b);
    }
}
