//! Well-formedness validation of exported Chrome traces — `tetris
//! trace check FILE...` in CI fails when an instrumented run emitted a
//! malformed or model-inconsistent trace.
//!
//! Checked invariants:
//! * the document is a Chrome trace-event object with a `traceEvents`
//!   array of `ph`/`ts`/`tid` events;
//! * per `(pid, tid)` track, timestamps are monotone non-decreasing in
//!   array order;
//! * per track, `B`/`E` duration events balance as a LIFO stack with
//!   matching `name` and `cat`, and no span is left open at the end;
//! * pipeline-stage spans are consistent with the analyze model: every
//!   `pipeline` span's `task` arg must be a valid
//!   [`crate::analyze::WindowPlan`] id for a `window` instant with the
//!   same `sched` tag — `task < 3·bw·nf·nw` — and the span's name must
//!   match the id's stage under the fixed `3·chain + stage` layout
//!   (stage 0/1/2 = assemble/compute/writeback), so recorded ids are
//!   bit-equal to the ids the static race checker certified;
//! * flow events (`ph:"s"/"t"/"f"`) pair per `(cat, name, id)`: exactly
//!   one start and exactly one finish each, and every `serve`/`job`
//!   flow id must be the FNV-1a of some job id seen on a serve instant
//!   — the cross-thread arrows point at real traced jobs.
//!
//! Traces truncated at the ring-buffer cap (`metadata.dropped_events >
//! 0`) get their balance/flow findings demoted to counted warnings —
//! drop-newest truncation legitimately leaves spans unclosed and flows
//! unpaired on the affected tids — unless `--strict`.  `--require-flows`
//! additionally fails a trace containing no flow events at all.

use std::collections::{BTreeMap, BTreeSet};

use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// Stage names in WindowPlan id order (`id % 3` indexes this).
const STAGES: [&str; 3] = ["assemble", "compute", "writeback"];

/// All violations in one parsed trace (strict: truncation demotes
/// nothing); empty means it passed.
pub fn check_json(name: &str, j: &Json) -> Vec<String> {
    check_json_opts(name, j, true).0
}

/// `(violations, warnings)`.  With `strict == false` and
/// `metadata.dropped_events > 0`, span-balance and flow-pairing
/// findings are demoted to warnings reporting the truncated tids;
/// timestamp and pipeline-model violations stay fatal either way (the
/// drop-newest policy cannot produce those).
pub fn check_json_opts(name: &str, j: &Json, strict: bool) -> (Vec<String>, Vec<String>) {
    let mut out = Vec::new();
    // balance/flow findings: demotable under truncation
    let mut soft = Vec::new();
    let mut truncated_tids: BTreeSet<u64> = BTreeSet::new();
    let Some(events) = j.at(&["traceEvents"]).as_arr() else {
        out.push(format!("{name}: no traceEvents array"));
        return (out, Vec::new());
    };
    if events.is_empty() {
        out.push(format!("{name}: traceEvents is empty"));
        return (out, Vec::new());
    }

    // group per (pid, tid) track, preserving array order
    let mut tracks: BTreeMap<(u64, u64), Vec<&Json>> = BTreeMap::new();
    for (i, e) in events.iter().enumerate() {
        if e.at(&["ph"]).as_str().is_none() {
            out.push(format!("{name}: traceEvents[{i}] has no ph"));
            continue;
        }
        let pid = e.at(&["pid"]).as_u64().unwrap_or(0);
        let tid = e.at(&["tid"]).as_u64().unwrap_or(0);
        tracks.entry((pid, tid)).or_default().push(e);
    }

    // per-sched window geometry: sched tag -> max valid task-id bound
    let mut universe: BTreeMap<u64, u64> = BTreeMap::new();
    for e in events {
        if e.at(&["cat"]).as_str() == Some("pipeline") && e.at(&["name"]).as_str() == Some("window")
        {
            let bw = e.at(&["args", "bw"]).as_u64().unwrap_or(0);
            let nf = e.at(&["args", "nf"]).as_u64().unwrap_or(0);
            let nw = e.at(&["args", "nw"]).as_u64().unwrap_or(0);
            let sched = e.at(&["args", "sched"]).as_u64().unwrap_or(0);
            let bound = universe.entry(sched).or_insert(0);
            *bound = (*bound).max(3 * bw * nf * nw);
        }
    }

    for ((pid, tid), track) in &tracks {
        let mut last_ts = f64::NEG_INFINITY;
        let mut stack: Vec<(String, String)> = Vec::new();
        for e in track {
            let ts = e.at(&["ts"]).as_f64().unwrap_or(f64::NEG_INFINITY);
            if ts < last_ts {
                out.push(format!(
                    "{name}: pid {pid} tid {tid}: timestamps regress ({ts} after {last_ts})"
                ));
            }
            last_ts = last_ts.max(ts);
            let ename = e.at(&["name"]).as_str().unwrap_or("").to_string();
            let cat = e.at(&["cat"]).as_str().unwrap_or("").to_string();
            match e.at(&["ph"]).as_str().unwrap_or("") {
                "B" => stack.push((cat, ename)),
                "E" => match stack.pop() {
                    None => {
                        truncated_tids.insert(*tid);
                        soft.push(format!(
                            "{name}: pid {pid} tid {tid}: end of {cat}/{ename:?} with no open span"
                        ));
                    }
                    Some((bcat, bname)) => {
                        if bname != ename || bcat != cat {
                            truncated_tids.insert(*tid);
                            soft.push(format!(
                                "{name}: pid {pid} tid {tid}: span mismatch: \
                                 {bcat}/{bname:?} closed by {cat}/{ename:?}"
                            ));
                        }
                    }
                },
                // instants, metadata, counters, flow events: no pairing
                _ => {}
            }
        }
        for (cat, sname) in &stack {
            truncated_tids.insert(*tid);
            soft.push(format!("{name}: pid {pid} tid {tid}: unclosed span {cat}/{sname:?}"));
        }
    }

    // pipeline task-id ⊆ analyze-model id universe, stage-consistent
    for (i, e) in events.iter().enumerate() {
        if e.at(&["cat"]).as_str() != Some("pipeline") || e.at(&["ph"]).as_str() != Some("B") {
            continue;
        }
        let ename = e.at(&["name"]).as_str().unwrap_or("");
        if !STAGES.contains(&ename) {
            continue;
        }
        let Some(task) = e.at(&["args", "task"]).as_u64() else {
            out.push(format!("{name}: traceEvents[{i}]: pipeline {ename} span without task id"));
            continue;
        };
        let sched = e.at(&["args", "sched"]).as_u64().unwrap_or(0);
        match universe.get(&sched) {
            None => out.push(format!(
                "{name}: traceEvents[{i}]: pipeline {ename} task {task} (sched {sched}) \
                 has no window geometry event"
            )),
            Some(&bound) => {
                if task >= bound {
                    out.push(format!(
                        "{name}: traceEvents[{i}]: task {task} outside the analyze model \
                         (window has {bound} tasks)"
                    ));
                }
            }
        }
        let stage = STAGES[(task % 3) as usize];
        if stage != ename {
            out.push(format!(
                "{name}: traceEvents[{i}]: task {task} is a {stage} id but span is {ename:?}"
            ));
        }
    }

    // flow pairing per (cat, name, id): exactly one start, exactly one
    // finish; serve/job flow ids must hash back to a traced job id
    #[derive(Default)]
    struct FlowAgg {
        starts: u64,
        steps: u64,
        finishes: u64,
    }
    let mut flows: BTreeMap<(String, String, String), FlowAgg> = BTreeMap::new();
    let mut job_ids: BTreeSet<String> = BTreeSet::new();
    for (i, e) in events.iter().enumerate() {
        let ph = e.at(&["ph"]).as_str().unwrap_or("");
        if ph == "i" && e.at(&["cat"]).as_str() == Some("serve") {
            if let Some(job) = e.at(&["args", "job"]).as_str() {
                job_ids.insert(format!("{:x}", super::flow_id(job)));
            }
        }
        if !matches!(ph, "s" | "t" | "f") {
            continue;
        }
        let Some(id) = e.at(&["id"]).as_str() else {
            soft.push(format!("{name}: traceEvents[{i}]: flow event without a string id"));
            continue;
        };
        let cat = e.at(&["cat"]).as_str().unwrap_or("").to_string();
        let fname = e.at(&["name"]).as_str().unwrap_or("").to_string();
        let f = flows.entry((cat, fname, id.to_string())).or_default();
        match ph {
            "s" => f.starts += 1,
            "t" => f.steps += 1,
            _ => f.finishes += 1,
        }
    }
    for ((cat, fname, id), f) in &flows {
        if f.starts == 0 {
            soft.push(format!(
                "{name}: flow {cat}/{fname} id {id}: {} step/finish event(s) with no start",
                f.steps + f.finishes
            ));
        } else if f.starts > 1 {
            soft.push(format!(
                "{name}: flow {cat}/{fname} id {id}: {} starts (want exactly 1)",
                f.starts
            ));
        } else if f.finishes != 1 {
            soft.push(format!(
                "{name}: flow {cat}/{fname} id {id}: started but {} finish(es) (want exactly 1)",
                f.finishes
            ));
        }
        if cat == "serve" && fname == "job" && !job_ids.contains(id) {
            soft.push(format!("{name}: flow serve/job id {id} matches no traced job id"));
        }
    }

    let mut warnings = Vec::new();
    let dropped = j.at(&["metadata", "dropped_events"]).as_u64().unwrap_or(0);
    if dropped > 0 && !strict {
        warnings.push(format!(
            "{name}: {dropped} event(s) dropped at the ring-buffer cap; \
             {} balance/flow finding(s) demoted to warnings (tids {:?})",
            soft.len(),
            truncated_tids
        ));
        warnings.append(&mut soft);
    } else {
        out.append(&mut soft);
    }
    (out, warnings)
}

/// Driver for `tetris trace check [--strict] [--require-flows]
/// FILE...`: parse each trace, print per-file verdicts (warnings are
/// printed but not fatal), error out if anything is violated.
pub fn check_files(paths: &[String], strict: bool, require_flows: bool) -> Result<()> {
    crate::ensure!(!paths.is_empty(), "trace check needs at least one trace-file path");
    let mut violations = Vec::new();
    for path in paths {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let parsed = Json::parse(text.trim()).with_context(|| format!("parsing {path}"))?;
        let (mut v, warnings) = check_json_opts(path, &parsed, strict);
        let events = parsed.at(&["traceEvents"]).as_arr().unwrap_or(&[]);
        let nflows = events
            .iter()
            .filter(|e| matches!(e.at(&["ph"]).as_str(), Some("s" | "t" | "f")))
            .count();
        if require_flows && nflows == 0 {
            v.push(format!("{path}: no flow events (--require-flows)"));
        }
        for w in &warnings {
            println!("trace check: WARNING: {w}");
        }
        if v.is_empty() {
            println!("trace check: {path}: OK ({} events, {nflows} flow)", events.len());
        } else {
            for msg in &v {
                println!("trace check: VIOLATION: {msg}");
            }
            violations.extend(v);
        }
    }
    crate::ensure!(
        violations.is_empty(),
        "{} trace violation(s) across {} file(s)",
        violations.len(),
        paths.len()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Json {
        Json::parse(s).unwrap()
    }

    fn ev(ph: &str, ts: f64, tid: u64, cat: &str, name: &str, extra: &str) -> String {
        let comma = if extra.is_empty() { "" } else { "," };
        format!(
            r#"{{"ph":"{ph}","ts":{ts},"pid":1,"tid":{tid},"cat":"{cat}","name":"{name}"{comma}{extra}}}"#
        )
    }

    fn doc(events: &[String]) -> Json {
        parse(&format!(r#"{{"traceEvents":[{}]}}"#, events.join(",")))
    }

    #[test]
    fn balanced_trace_passes() {
        let d = doc(&[
            ev("B", 0.0, 0, "pool", "task", r#""args":{"task":0,"worker":1}"#),
            ev("B", 1.0, 0, "pool", "inner", ""),
            ev("E", 2.0, 0, "pool", "inner", ""),
            ev("i", 2.5, 0, "retune", "kept", ""),
            ev("E", 3.0, 0, "pool", "task", ""),
        ]);
        assert!(check_json("t", &d).is_empty());
    }

    #[test]
    fn missing_or_empty_trace_events_fail() {
        assert_eq!(check_json("t", &parse("{}")).len(), 1);
        assert_eq!(check_json("t", &parse(r#"{"traceEvents":[]}"#)).len(), 1);
    }

    #[test]
    fn unbalanced_and_mismatched_spans_fail() {
        let unclosed = doc(&[ev("B", 0.0, 0, "pool", "task", "")]);
        let v = check_json("t", &unclosed);
        assert!(v.iter().any(|m| m.contains("unclosed span")), "{v:?}");

        let orphan = doc(&[ev("E", 0.0, 0, "pool", "task", "")]);
        let v = check_json("t", &orphan);
        assert!(v.iter().any(|m| m.contains("no open span")), "{v:?}");

        let crossed = doc(&[
            ev("B", 0.0, 0, "pool", "a", ""),
            ev("E", 1.0, 0, "pool", "b", ""),
        ]);
        let v = check_json("t", &crossed);
        assert!(v.iter().any(|m| m.contains("span mismatch")), "{v:?}");
    }

    #[test]
    fn timestamp_regressions_fail_per_track_only() {
        let bad = doc(&[
            ev("i", 5.0, 0, "serve", "admit", ""),
            ev("i", 1.0, 0, "serve", "admit", ""),
        ]);
        let v = check_json("t", &bad);
        assert!(v.iter().any(|m| m.contains("timestamps regress")), "{v:?}");
        // different tids are independent tracks
        let ok = doc(&[
            ev("i", 5.0, 0, "serve", "admit", ""),
            ev("i", 1.0, 1, "serve", "admit", ""),
        ]);
        assert!(check_json("t", &ok).is_empty());
    }

    #[test]
    fn pipeline_ids_must_fit_the_window_model() {
        let win = ev("i", 0.0, 0, "pipeline", "window", r#""args":{"b0":0,"bw":2,"nf":1,"nw":2,"sched":3}"#);
        // bound = 3*2*1*2 = 12; task 7 is id (k=1,f=0,w=0,stage=compute)
        let ok = doc(&[
            win.clone(),
            ev("B", 1.0, 1, "pipeline", "compute", r#""args":{"task":7,"sched":3}"#),
            ev("E", 2.0, 1, "pipeline", "compute", ""),
        ]);
        assert!(check_json("t", &ok).is_empty(), "{:?}", check_json("t", &ok));

        let out_of_range = doc(&[
            win.clone(),
            ev("B", 1.0, 1, "pipeline", "writeback", r#""args":{"task":14,"sched":3}"#),
            ev("E", 2.0, 1, "pipeline", "writeback", ""),
        ]);
        let v = check_json("t", &out_of_range);
        assert!(v.iter().any(|m| m.contains("outside the analyze model")), "{v:?}");

        let wrong_stage = doc(&[
            win.clone(),
            ev("B", 1.0, 1, "pipeline", "assemble", r#""args":{"task":7,"sched":3}"#),
            ev("E", 2.0, 1, "pipeline", "assemble", ""),
        ]);
        let v = check_json("t", &wrong_stage);
        assert!(v.iter().any(|m| m.contains("is a compute id")), "{v:?}");

        let no_window = doc(&[
            ev("B", 1.0, 1, "pipeline", "compute", r#""args":{"task":7,"sched":9}"#),
            ev("E", 2.0, 1, "pipeline", "compute", ""),
        ]);
        let v = check_json("t", &no_window);
        assert!(v.iter().any(|m| m.contains("no window geometry")), "{v:?}");

        let no_task = doc(&[
            win,
            ev("B", 1.0, 1, "pipeline", "compute", r#""args":{"sched":3}"#),
            ev("E", 2.0, 1, "pipeline", "compute", ""),
        ]);
        let v = check_json("t", &no_task);
        assert!(v.iter().any(|m| m.contains("without task id")), "{v:?}");
    }

    #[test]
    fn check_files_flags_missing_and_bad_files() {
        assert!(check_files(&[], false, false).is_err());
        assert!(check_files(&["/nonexistent/trace.json".into()], false, false).is_err());
        let dir = std::env::temp_dir();
        let good = dir.join(format!("trace_check_good_{}.json", std::process::id()));
        std::fs::write(&good, r#"{"traceEvents":[{"ph":"i","ts":0,"pid":1,"tid":0,"cat":"serve","name":"accept"}]}"#).unwrap();
        let good_path = good.to_string_lossy().into_owned();
        assert!(check_files(&[good_path.clone()], false, false).is_ok());
        // --require-flows fails a trace with no flow events at all
        assert!(check_files(&[good_path], false, true).is_err());
        let bad = dir.join(format!("trace_check_bad_{}.json", std::process::id()));
        std::fs::write(&bad, r#"{"traceEvents":[{"ph":"B","ts":0,"pid":1,"tid":0,"cat":"x","name":"y"}]}"#).unwrap();
        assert!(check_files(&[bad.to_string_lossy().into_owned()], false, false).is_err());
        let _ = std::fs::remove_file(&good);
        let _ = std::fs::remove_file(&bad);
    }

    fn flow(ph: &str, ts: f64, tid: u64, cat: &str, name: &str, id: &str) -> String {
        format!(
            r#"{{"ph":"{ph}","ts":{ts},"pid":1,"tid":{tid},"cat":"{cat}","name":"{name}","id":"{id}"}}"#
        )
    }

    /// The satellite negative test: an orphaned flow start (no matching
    /// finish) must fail `trace check`; a paired flow passes, including
    /// across threads.
    #[test]
    fn orphaned_flow_start_fails() {
        let hex = format!("{:x}", crate::trace::flow_id("j1"));
        let accept = ev("i", 0.0, 0, "serve", "accept", r#""args":{"job":"j1"}"#);
        let orphan = doc(&[accept.clone(), flow("s", 1.0, 0, "serve", "job", &hex)]);
        let v = check_json("t", &orphan);
        assert!(v.iter().any(|m| m.contains("0 finish(es)")), "{v:?}");

        let paired = doc(&[
            accept.clone(),
            flow("s", 1.0, 0, "serve", "job", &hex),
            flow("t", 2.0, 1, "serve", "job", &hex),
            flow("f", 3.0, 2, "serve", "job", &hex),
        ]);
        assert!(check_json("t", &paired).is_empty(), "{:?}", check_json("t", &paired));

        let finish_only = doc(&[accept.clone(), flow("f", 1.0, 0, "serve", "job", &hex)]);
        let v = check_json("t", &finish_only);
        assert!(v.iter().any(|m| m.contains("with no start")), "{v:?}");

        let double_start = doc(&[
            accept,
            flow("s", 1.0, 0, "serve", "job", &hex),
            flow("s", 2.0, 1, "serve", "job", &hex),
            flow("f", 3.0, 2, "serve", "job", &hex),
        ]);
        let v = check_json("t", &double_start);
        assert!(v.iter().any(|m| m.contains("2 starts")), "{v:?}");
    }

    /// serve/job flow ids must hash back to a job id seen on a serve
    /// instant; other categories' flows are exempt from the subset rule.
    #[test]
    fn serve_flow_ids_must_match_traced_jobs() {
        let hex = format!("{:x}", crate::trace::flow_id("ghost-job"));
        let d = doc(&[
            ev("i", 0.0, 0, "serve", "accept", r#""args":{"job":"other"}"#),
            flow("s", 1.0, 0, "serve", "job", &hex),
            flow("f", 2.0, 1, "serve", "job", &hex),
        ]);
        let v = check_json("t", &d);
        assert!(v.iter().any(|m| m.contains("matches no traced job id")), "{v:?}");

        let pipeline = doc(&[
            flow("s", 1.0, 0, "pipeline", "chain", "100000"),
            flow("f", 2.0, 1, "pipeline", "chain", "100000"),
        ]);
        assert!(check_json("t", &pipeline).is_empty(), "{:?}", check_json("t", &pipeline));
    }

    /// Truncated traces (`metadata.dropped_events > 0`) demote balance
    /// and flow findings to warnings unless strict; fatal model errors
    /// (timestamp regressions) stay fatal either way.
    #[test]
    fn dropped_events_demote_balance_findings() {
        let with_meta = |events: &[String]| {
            parse(&format!(
                r#"{{"traceEvents":[{}],"metadata":{{"dropped_events":3}}}}"#,
                events.join(",")
            ))
        };
        let truncated = with_meta(&[ev("B", 0.0, 0, "pool", "task", "")]);
        let (v, w) = check_json_opts("t", &truncated, false);
        assert!(v.is_empty(), "{v:?}");
        assert!(w.iter().any(|m| m.contains("unclosed span")), "{w:?}");
        assert!(w.iter().any(|m| m.contains("3 event(s) dropped")), "{w:?}");
        // --strict keeps the finding fatal
        let (v, _) = check_json_opts("t", &truncated, true);
        assert!(v.iter().any(|m| m.contains("unclosed span")), "{v:?}");
        // without the metadata key, non-strict still fails
        let plain = doc(&[ev("B", 0.0, 0, "pool", "task", "")]);
        let (v, _) = check_json_opts("t", &plain, false);
        assert!(v.iter().any(|m| m.contains("unclosed span")), "{v:?}");
        // a timestamp regression is fatal even under truncation
        let regress = with_meta(&[
            ev("i", 5.0, 0, "serve", "admit", ""),
            ev("i", 1.0, 0, "serve", "admit", ""),
        ]);
        let (v, _) = check_json_opts("t", &regress, false);
        assert!(v.iter().any(|m| m.contains("timestamps regress")), "{v:?}");
    }
}
