//! Well-formedness validation of exported Chrome traces — `tetris
//! trace check FILE...` in CI fails when an instrumented run emitted a
//! malformed or model-inconsistent trace.
//!
//! Checked invariants:
//! * the document is a Chrome trace-event object with a `traceEvents`
//!   array of `ph`/`ts`/`tid` events;
//! * per `(pid, tid)` track, timestamps are monotone non-decreasing in
//!   array order;
//! * per track, `B`/`E` duration events balance as a LIFO stack with
//!   matching `name` and `cat`, and no span is left open at the end;
//! * pipeline-stage spans are consistent with the analyze model: every
//!   `pipeline` span's `task` arg must be a valid
//!   [`crate::analyze::WindowPlan`] id for a `window` instant with the
//!   same `sched` tag — `task < 3·bw·nf·nw` — and the span's name must
//!   match the id's stage under the fixed `3·chain + stage` layout
//!   (stage 0/1/2 = assemble/compute/writeback), so recorded ids are
//!   bit-equal to the ids the static race checker certified.

use std::collections::BTreeMap;

use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// Stage names in WindowPlan id order (`id % 3` indexes this).
const STAGES: [&str; 3] = ["assemble", "compute", "writeback"];

/// All violations in one parsed trace; empty means it passed.
pub fn check_json(name: &str, j: &Json) -> Vec<String> {
    let mut out = Vec::new();
    let Some(events) = j.at(&["traceEvents"]).as_arr() else {
        out.push(format!("{name}: no traceEvents array"));
        return out;
    };
    if events.is_empty() {
        out.push(format!("{name}: traceEvents is empty"));
        return out;
    }

    // group per (pid, tid) track, preserving array order
    let mut tracks: BTreeMap<(u64, u64), Vec<&Json>> = BTreeMap::new();
    for (i, e) in events.iter().enumerate() {
        if e.at(&["ph"]).as_str().is_none() {
            out.push(format!("{name}: traceEvents[{i}] has no ph"));
            continue;
        }
        let pid = e.at(&["pid"]).as_u64().unwrap_or(0);
        let tid = e.at(&["tid"]).as_u64().unwrap_or(0);
        tracks.entry((pid, tid)).or_default().push(e);
    }

    // per-sched window geometry: sched tag -> max valid task-id bound
    let mut universe: BTreeMap<u64, u64> = BTreeMap::new();
    for e in events {
        if e.at(&["cat"]).as_str() == Some("pipeline") && e.at(&["name"]).as_str() == Some("window")
        {
            let bw = e.at(&["args", "bw"]).as_u64().unwrap_or(0);
            let nf = e.at(&["args", "nf"]).as_u64().unwrap_or(0);
            let nw = e.at(&["args", "nw"]).as_u64().unwrap_or(0);
            let sched = e.at(&["args", "sched"]).as_u64().unwrap_or(0);
            let bound = universe.entry(sched).or_insert(0);
            *bound = (*bound).max(3 * bw * nf * nw);
        }
    }

    for ((pid, tid), track) in &tracks {
        let mut last_ts = f64::NEG_INFINITY;
        let mut stack: Vec<(String, String)> = Vec::new();
        for e in track {
            let ts = e.at(&["ts"]).as_f64().unwrap_or(f64::NEG_INFINITY);
            if ts < last_ts {
                out.push(format!(
                    "{name}: pid {pid} tid {tid}: timestamps regress ({ts} after {last_ts})"
                ));
            }
            last_ts = last_ts.max(ts);
            let ename = e.at(&["name"]).as_str().unwrap_or("").to_string();
            let cat = e.at(&["cat"]).as_str().unwrap_or("").to_string();
            match e.at(&["ph"]).as_str().unwrap_or("") {
                "B" => stack.push((cat, ename)),
                "E" => match stack.pop() {
                    None => out.push(format!(
                        "{name}: pid {pid} tid {tid}: end of {cat}/{ename:?} with no open span"
                    )),
                    Some((bcat, bname)) => {
                        if bname != ename || bcat != cat {
                            out.push(format!(
                                "{name}: pid {pid} tid {tid}: span mismatch: \
                                 {bcat}/{bname:?} closed by {cat}/{ename:?}"
                            ));
                        }
                    }
                },
                // instants, metadata, counters, flow events: no pairing
                _ => {}
            }
        }
        for (cat, sname) in &stack {
            out.push(format!("{name}: pid {pid} tid {tid}: unclosed span {cat}/{sname:?}"));
        }
    }

    // pipeline task-id ⊆ analyze-model id universe, stage-consistent
    for (i, e) in events.iter().enumerate() {
        if e.at(&["cat"]).as_str() != Some("pipeline") || e.at(&["ph"]).as_str() != Some("B") {
            continue;
        }
        let ename = e.at(&["name"]).as_str().unwrap_or("");
        if !STAGES.contains(&ename) {
            continue;
        }
        let Some(task) = e.at(&["args", "task"]).as_u64() else {
            out.push(format!("{name}: traceEvents[{i}]: pipeline {ename} span without task id"));
            continue;
        };
        let sched = e.at(&["args", "sched"]).as_u64().unwrap_or(0);
        match universe.get(&sched) {
            None => out.push(format!(
                "{name}: traceEvents[{i}]: pipeline {ename} task {task} (sched {sched}) \
                 has no window geometry event"
            )),
            Some(&bound) => {
                if task >= bound {
                    out.push(format!(
                        "{name}: traceEvents[{i}]: task {task} outside the analyze model \
                         (window has {bound} tasks)"
                    ));
                }
            }
        }
        let stage = STAGES[(task % 3) as usize];
        if stage != ename {
            out.push(format!(
                "{name}: traceEvents[{i}]: task {task} is a {stage} id but span is {ename:?}"
            ));
        }
    }
    out
}

/// Driver for `tetris trace check FILE...`: parse each trace, print
/// per-file verdicts, error out if anything is violated.
pub fn check_files(paths: &[String]) -> Result<()> {
    crate::ensure!(!paths.is_empty(), "trace check needs at least one trace-file path");
    let mut violations = Vec::new();
    for path in paths {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let parsed = Json::parse(text.trim()).with_context(|| format!("parsing {path}"))?;
        let v = check_json(path, &parsed);
        let n = parsed.at(&["traceEvents"]).as_arr().map_or(0, |a| a.len());
        if v.is_empty() {
            println!("trace check: {path}: OK ({n} events)");
        } else {
            for msg in &v {
                println!("trace check: VIOLATION: {msg}");
            }
            violations.extend(v);
        }
    }
    crate::ensure!(
        violations.is_empty(),
        "{} trace violation(s) across {} file(s)",
        violations.len(),
        paths.len()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Json {
        Json::parse(s).unwrap()
    }

    fn ev(ph: &str, ts: f64, tid: u64, cat: &str, name: &str, extra: &str) -> String {
        let comma = if extra.is_empty() { "" } else { "," };
        format!(
            r#"{{"ph":"{ph}","ts":{ts},"pid":1,"tid":{tid},"cat":"{cat}","name":"{name}"{comma}{extra}}}"#
        )
    }

    fn doc(events: &[String]) -> Json {
        parse(&format!(r#"{{"traceEvents":[{}]}}"#, events.join(",")))
    }

    #[test]
    fn balanced_trace_passes() {
        let d = doc(&[
            ev("B", 0.0, 0, "pool", "task", r#""args":{"task":0,"worker":1}"#),
            ev("B", 1.0, 0, "pool", "inner", ""),
            ev("E", 2.0, 0, "pool", "inner", ""),
            ev("i", 2.5, 0, "retune", "kept", ""),
            ev("E", 3.0, 0, "pool", "task", ""),
        ]);
        assert!(check_json("t", &d).is_empty());
    }

    #[test]
    fn missing_or_empty_trace_events_fail() {
        assert_eq!(check_json("t", &parse("{}")).len(), 1);
        assert_eq!(check_json("t", &parse(r#"{"traceEvents":[]}"#)).len(), 1);
    }

    #[test]
    fn unbalanced_and_mismatched_spans_fail() {
        let unclosed = doc(&[ev("B", 0.0, 0, "pool", "task", "")]);
        let v = check_json("t", &unclosed);
        assert!(v.iter().any(|m| m.contains("unclosed span")), "{v:?}");

        let orphan = doc(&[ev("E", 0.0, 0, "pool", "task", "")]);
        let v = check_json("t", &orphan);
        assert!(v.iter().any(|m| m.contains("no open span")), "{v:?}");

        let crossed = doc(&[
            ev("B", 0.0, 0, "pool", "a", ""),
            ev("E", 1.0, 0, "pool", "b", ""),
        ]);
        let v = check_json("t", &crossed);
        assert!(v.iter().any(|m| m.contains("span mismatch")), "{v:?}");
    }

    #[test]
    fn timestamp_regressions_fail_per_track_only() {
        let bad = doc(&[
            ev("i", 5.0, 0, "serve", "admit", ""),
            ev("i", 1.0, 0, "serve", "admit", ""),
        ]);
        let v = check_json("t", &bad);
        assert!(v.iter().any(|m| m.contains("timestamps regress")), "{v:?}");
        // different tids are independent tracks
        let ok = doc(&[
            ev("i", 5.0, 0, "serve", "admit", ""),
            ev("i", 1.0, 1, "serve", "admit", ""),
        ]);
        assert!(check_json("t", &ok).is_empty());
    }

    #[test]
    fn pipeline_ids_must_fit_the_window_model() {
        let win = ev("i", 0.0, 0, "pipeline", "window", r#""args":{"b0":0,"bw":2,"nf":1,"nw":2,"sched":3}"#);
        // bound = 3*2*1*2 = 12; task 7 is id (k=1,f=0,w=0,stage=compute)
        let ok = doc(&[
            win.clone(),
            ev("B", 1.0, 1, "pipeline", "compute", r#""args":{"task":7,"sched":3}"#),
            ev("E", 2.0, 1, "pipeline", "compute", ""),
        ]);
        assert!(check_json("t", &ok).is_empty(), "{:?}", check_json("t", &ok));

        let out_of_range = doc(&[
            win.clone(),
            ev("B", 1.0, 1, "pipeline", "writeback", r#""args":{"task":14,"sched":3}"#),
            ev("E", 2.0, 1, "pipeline", "writeback", ""),
        ]);
        let v = check_json("t", &out_of_range);
        assert!(v.iter().any(|m| m.contains("outside the analyze model")), "{v:?}");

        let wrong_stage = doc(&[
            win.clone(),
            ev("B", 1.0, 1, "pipeline", "assemble", r#""args":{"task":7,"sched":3}"#),
            ev("E", 2.0, 1, "pipeline", "assemble", ""),
        ]);
        let v = check_json("t", &wrong_stage);
        assert!(v.iter().any(|m| m.contains("is a compute id")), "{v:?}");

        let no_window = doc(&[
            ev("B", 1.0, 1, "pipeline", "compute", r#""args":{"task":7,"sched":9}"#),
            ev("E", 2.0, 1, "pipeline", "compute", ""),
        ]);
        let v = check_json("t", &no_window);
        assert!(v.iter().any(|m| m.contains("no window geometry")), "{v:?}");

        let no_task = doc(&[
            win,
            ev("B", 1.0, 1, "pipeline", "compute", r#""args":{"sched":3}"#),
            ev("E", 2.0, 1, "pipeline", "compute", ""),
        ]);
        let v = check_json("t", &no_task);
        assert!(v.iter().any(|m| m.contains("without task id")), "{v:?}");
    }

    #[test]
    fn check_files_flags_missing_and_bad_files() {
        assert!(check_files(&[]).is_err());
        assert!(check_files(&["/nonexistent/trace.json".into()]).is_err());
        let dir = std::env::temp_dir();
        let good = dir.join(format!("trace_check_good_{}.json", std::process::id()));
        std::fs::write(&good, r#"{"traceEvents":[{"ph":"i","ts":0,"pid":1,"tid":0,"cat":"serve","name":"accept"}]}"#).unwrap();
        assert!(check_files(&[good.to_string_lossy().into_owned()]).is_ok());
        let bad = dir.join(format!("trace_check_bad_{}.json", std::process::id()));
        std::fs::write(&bad, r#"{"traceEvents":[{"ph":"B","ts":0,"pid":1,"tid":0,"cat":"x","name":"y"}]}"#).unwrap();
        assert!(check_files(&[bad.to_string_lossy().into_owned()]).is_err());
        let _ = std::fs::remove_file(&good);
        let _ = std::fs::remove_file(&bad);
    }
}
