//! FP64-vs-FP32 accuracy study (paper Table 4, Fig. 16(c)(d)).
//!
//! Evolves the same Gaussian initial state with the FP64 and FP32
//! periodic thermal artifacts (or a pure-rust fallback when artifacts are
//! absent) and buckets per-cell deviations against the FP64 run, exactly
//! the paper's error histogram (<0.1 °C, 0.1–1.0 °C, >1.0 °C).

use crate::util::error::Result;

use crate::runtime::XlaService;
use crate::stencil::{spec, Field, StencilSpec};

/// Percentage of cells in each |error| bucket: [<0.1, 0.1..1.0, >=1.0].
pub fn deviation_buckets(reference: &Field, other: &Field) -> [f64; 3] {
    assert_eq!(reference.shape(), other.shape());
    let n = reference.len() as f64;
    let mut buckets = [0usize; 3];
    for (a, b) in reference.data().iter().zip(other.data()) {
        let e = (a - b).abs();
        if e < 0.1 {
            buckets[0] += 1;
        } else if e < 1.0 {
            buckets[1] += 1;
        } else {
            buckets[2] += 1;
        }
    }
    [
        100.0 * buckets[0] as f64 / n,
        100.0 * buckets[1] as f64 / n,
        100.0 * buckets[2] as f64 / n,
    ]
}

/// Pure-rust FP32 periodic evolution (fallback oracle): true f32
/// arithmetic throughout.  Shared with the runtime's f32 artifact path —
/// see [`crate::stencil::reference::evolve_periodic_f32`].
pub fn evolve_periodic_f32(u: &Field, s: &StencilSpec, steps: usize) -> Field {
    crate::stencil::reference::evolve_periodic_f32(u, s, steps)
}

/// Result of the accuracy study.
#[derive(Clone, Debug)]
pub struct AccuracyReport {
    pub steps: usize,
    /// [<0.1, 0.1..1.0, >=1.0] percentage buckets for FP32 vs FP64.
    pub fp32_buckets: [f64; 3],
    pub fp64: Field,
    pub fp32: Field,
    pub used_artifacts: bool,
}

/// Run the study: `blocks` x Tb steps from the Gaussian plate.
pub fn run_accuracy(rt: Option<&XlaService>, n: usize, blocks: usize) -> Result<AccuracyReport> {
    let s = spec::get("heat2d").unwrap();
    let init = super::thermal::gaussian_plate(n);
    if let Some(svc) = rt {
        let meta64 = svc.meta("thermal_f64")?.clone();
        let shape = &meta64.input_shape;
        crate::ensure!(
            shape == &init.shape().to_vec(),
            "thermal artifacts are {shape:?}; pass n={}",
            shape[0]
        );
        let tb = meta64.steps;
        let mut a = init.clone();
        let mut b = init.clone();
        for _ in 0..blocks {
            a = svc.run("thermal_f64", &a)?;
            b = svc.run("thermal_f32", &b)?;
        }
        Ok(AccuracyReport {
            steps: blocks * tb,
            fp32_buckets: deviation_buckets(&a, &b),
            fp64: a,
            fp32: b,
            used_artifacts: true,
        })
    } else {
        let steps = blocks * 8;
        let a = crate::stencil::reference::evolve_periodic(&init, &s, steps);
        let b = evolve_periodic_f32(&init, &s, steps);
        Ok(AccuracyReport {
            steps,
            fp32_buckets: deviation_buckets(&a, &b),
            fp64: a,
            fp32: b,
            used_artifacts: false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_sum_to_100() {
        let a = Field::random(&[20, 20], 1);
        let mut b = a.clone();
        b.data_mut()[0] += 0.5; // one cell in the middle bucket
        b.data_mut()[1] += 5.0; // one cell in the top bucket
        let k = deviation_buckets(&a, &b);
        assert!((k[0] + k[1] + k[2] - 100.0).abs() < 1e-9);
        assert!((k[1] - 0.25).abs() < 1e-9); // 1/400 cells
        assert!((k[2] - 0.25).abs() < 1e-9);
    }

    #[test]
    fn fp32_drifts_from_fp64() {
        let s = spec::get("heat2d").unwrap();
        let init = super::super::thermal::gaussian_plate(24);
        let a = crate::stencil::reference::evolve_periodic(&init, &s, 30);
        let b = evolve_periodic_f32(&init, &s, 30);
        let d = a.max_abs_diff(&b);
        assert!(d > 0.0, "fp32 should differ");
        assert!(d < 1.0, "but not catastrophically at 30 steps: {d}");
    }

    #[test]
    fn fallback_study_runs() {
        let rep = run_accuracy(None, 16, 2).unwrap();
        assert!(!rep.used_artifacts);
        assert!((rep.fp32_buckets.iter().sum::<f64>() - 100.0).abs() < 1e-9);
    }
}
