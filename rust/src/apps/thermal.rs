//! Thermal-diffusion case study (paper §6.5, Table 3, Fig. 16).
//!
//! Simulates heat diffusion on a square copper plate: Gaussian initial
//! condition (hottest at the centre, 100 °C), 5-point Heat-2D stencil
//! with the paper's CFL number μ = 0.23, ambient Dirichlet boundary.
//! The Table-3 method rows map to scheduler configurations:
//!   Naive        — one native `naive` worker
//!   Tetris (CPU) — one native `tetris-cpu` worker
//!   Tetris (GPU) — one XLA worker (AOT temporal-block artifact)
//!   Tetris       — auto-tuned heterogeneous mix of both

use crate::util::error::Result;

use crate::coordinator::{
    partition::capacity_units, tuner, CommModel, NativeWorker, Overlap, Partition, Scheduler,
    Worker, XlaWorker,
};
use crate::runtime::XlaService;
use crate::stencil::{spec, Boundary, Field};

/// Ambient plate temperature (°C) at the boundary and far field.
pub const AMBIENT: f64 = 25.0;
/// Peak initial temperature (°C) at the plate centre.
pub const PEAK: f64 = 100.0;

/// Gaussian initial temperature distribution (paper Fig. 16(a)).
pub fn gaussian_plate(n: usize) -> Field {
    let mut f = Field::zeros(&[n, n]);
    let c = (n as f64 - 1.0) / 2.0;
    let sigma = n as f64 / 6.0;
    for i in 0..n {
        for j in 0..n {
            let d2 = ((i as f64 - c).powi(2) + (j as f64 - c).powi(2)) / (2.0 * sigma * sigma);
            f.set(&[i, j], AMBIENT + (PEAK - AMBIENT) * (-d2).exp());
        }
    }
    f
}

/// One Table-3 row.
#[derive(Clone, Debug)]
pub struct ThermalRow {
    pub method: String,
    pub seconds: f64,
    pub gstencils: f64,
    pub speedup: f64,
    pub final_center: f64,
    pub max_diff_vs_naive: f64,
}

/// Build the Table-3 scheduler for a given method name.
fn scheduler_for(
    method: &str,
    rt: Option<&XlaService>,
    spec_: &crate::stencil::StencilSpec,
    n: usize,
    tb: usize,
    threads: usize,
) -> Result<Scheduler> {
    let unit = n / 8;
    let units = 8;
    let mk_native = |eng: &str| -> Box<dyn Worker> {
        Box::new(NativeWorker::new(crate::engine::by_name(eng, threads).unwrap(), 1 << 33))
    };
    let workers: Vec<Box<dyn Worker>> = match method {
        "naive" => vec![mk_native("naive")],
        "tetris-cpu" => vec![mk_native("tetris-cpu")],
        "tetris-gpu" => {
            let svc = rt.ok_or_else(|| crate::err!("tetris-gpu needs artifacts"))?;
            vec![Box::new(XlaWorker::new(svc.clone(), "thermal_block", 1 << 33)?)]
        }
        "tetris" => {
            let svc = rt.ok_or_else(|| crate::err!("tetris needs artifacts"))?;
            vec![
                mk_native("tetris-cpu"),
                Box::new(XlaWorker::new(svc.clone(), "thermal_block", 1 << 33)?),
            ]
        }
        _ => crate::bail!("unknown method {method}"),
    };
    let partition = if workers.len() == 1 {
        Partition::rows(unit, vec![units])
    } else {
        // §5.2 profile initialization + balanced partition.
        let prof = tuner::profile_workers(&workers, spec_, &[unit, n], tb, 2)?;
        let rest_cells = (n + 2 * spec_.radius * tb) as usize;
        let caps: Vec<usize> = workers
            .iter()
            .map(|w| capacity_units(w.mem_capacity(), unit, rest_cells))
            .collect();
        let weights: Vec<f64> = prof.iter().map(|t| 1.0 / t.max(1e-12)).collect();
        Partition::balanced(unit, units, &weights, &caps)
    };
    Ok(Scheduler {
        spec: spec_.clone(),
        tb,
        workers,
        partition,
        comm_model: CommModel::default(),
        boundary: Boundary::Dirichlet(AMBIENT),
        adapt_every: 0,
        overlap: Overlap::Auto,
    })
}

/// Run the full Table-3 sweep.  `steps` must be a multiple of `tb`.
pub fn run_table3(
    rt: Option<&XlaService>,
    n: usize,
    steps: usize,
    tb: usize,
    threads: usize,
) -> Result<(Vec<ThermalRow>, Vec<(String, Field)>)> {
    let s = spec::get("heat2d").unwrap();
    let init = gaussian_plate(n);
    let methods: Vec<&str> = if rt.is_some() {
        vec!["naive", "tetris-cpu", "tetris-gpu", "tetris"]
    } else {
        vec!["naive", "tetris-cpu"]
    };
    let mut rows = Vec::new();
    let mut fields = Vec::new();
    let mut naive_secs = 0.0;
    let mut naive_field: Option<Field> = None;
    for m in methods {
        let sched = scheduler_for(m, rt, &s, n, tb, threads)?;
        let t0 = std::time::Instant::now();
        let (out, metrics) = sched.run(&init, steps)?;
        let secs = t0.elapsed().as_secs_f64();
        if m == "naive" {
            naive_secs = secs;
            naive_field = Some(out.clone());
        }
        let diff = naive_field
            .as_ref()
            .map(|f| out.max_abs_diff(f))
            .unwrap_or(0.0);
        rows.push(ThermalRow {
            method: m.to_string(),
            seconds: secs,
            gstencils: metrics.gstencils_per_sec(),
            speedup: if naive_secs > 0.0 { naive_secs / secs } else { 1.0 },
            final_center: out.get(&[n / 2, n / 2]),
            max_diff_vs_naive: diff,
        });
        fields.push((m.to_string(), out));
    }
    Ok((rows, fields))
}

/// Insulated-plate scenario: the same Gaussian plate behind Neumann
/// zero-flux walls.  No heat escapes, so the total (mean) temperature is
/// a run invariant while the peak diffuses flat — the boundary-diversity
/// counterpart to Table 3's ambient-wall (Dirichlet) study.  Runs
/// heterogeneously on two native workers; `adapt_every` forwards to the
/// §5.2 rebalancer.
pub fn run_insulated(
    n: usize,
    steps: usize,
    tb: usize,
    threads: usize,
    adapt_every: usize,
) -> Result<(Field, crate::coordinator::RunMetrics)> {
    crate::ensure!(n >= 16 && n % 8 == 0, "plate size {n} must be a multiple of 8 (>= 16)");
    crate::ensure!(steps % tb == 0, "steps {steps} not a multiple of tb {tb}");
    let s = spec::get("heat2d").unwrap();
    let init = gaussian_plate(n);
    let sched = Scheduler {
        spec: s,
        tb,
        workers: vec![
            Box::new(NativeWorker::new(crate::engine::by_name("tetris-cpu", threads).unwrap(), 1 << 33)),
            Box::new(NativeWorker::new(crate::engine::by_name("simd", 1).unwrap(), 1 << 33)),
        ],
        partition: Partition::rows(n / 8, vec![4, 4]),
        comm_model: CommModel::default(),
        boundary: Boundary::Neumann,
        adapt_every,
        overlap: Overlap::Auto,
    };
    sched.run(&init, steps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_shape_and_range() {
        let f = gaussian_plate(33);
        assert!((f.get(&[16, 16]) - PEAK).abs() < 1e-9);
        assert!(f.get(&[0, 0]) < 40.0);
        assert!(f.min() >= AMBIENT - 1e-12);
    }

    #[test]
    fn diffusion_cools_the_center() {
        let s = spec::get("heat2d").unwrap();
        let init = gaussian_plate(33);
        let out = crate::coordinator::pipeline::reference_evolution(
            &init,
            &s,
            40,
            4,
            Boundary::Dirichlet(AMBIENT),
        );
        assert!(out.get(&[16, 16]) < init.get(&[16, 16]) - 5.0);
        // heat flows out through the ambient boundary: mean decreases
        assert!(out.mean() < init.mean());
        // nothing dips below ambient or exceeds the initial peak
        assert!(out.min() >= AMBIENT - 1e-9 && out.max() <= PEAK + 1e-9);
    }

    #[test]
    fn table3_cpu_rows_agree() {
        let (rows, fields) = run_table3(None, 64, 8, 4, 1).unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows[1].max_diff_vs_naive < 1e-10, "{}", rows[1].max_diff_vs_naive);
        assert_eq!(fields.len(), 2);
        assert!(rows[0].speedup == 1.0 || rows[0].speedup > 0.0);
    }

    #[test]
    fn insulated_plate_conserves_heat() {
        let n = 64;
        let init = gaussian_plate(n);
        let (out, metrics) = run_insulated(n, 16, 4, 1, 0).unwrap();
        // zero-flux walls: total heat is invariant, peak diffuses down,
        // nothing dips below ambient
        assert!(
            (out.mean() - init.mean()).abs() < 1e-8,
            "mean drift {}",
            out.mean() - init.mean()
        );
        assert!(out.get(&[n / 2, n / 2]) < init.get(&[n / 2, n / 2]));
        assert!(out.min() >= AMBIENT - 1e-9 && out.max() <= PEAK + 1e-9);
        assert!(metrics.comm.messages > 0);
        // and the heterogeneous run equals the single-worker evolution
        let s = spec::get("heat2d").unwrap();
        let want = crate::coordinator::pipeline::reference_evolution(
            &init,
            &s,
            16,
            4,
            Boundary::Neumann,
        );
        assert!(out.allclose(&want, 1e-12, 1e-14), "maxdiff={}", out.max_abs_diff(&want));
    }

    #[test]
    fn insulated_rejects_bad_sizes() {
        assert!(run_insulated(63, 8, 4, 1, 0).is_err());
        assert!(run_insulated(64, 7, 4, 1, 0).is_err());
    }
}
