//! Applications built on the Tetris stack: the paper's §6.5 case study.

pub mod accuracy;
pub mod thermal;
pub mod viz;
