//! PPM visualization for the thermal case study (paper Fig. 16).
//!
//! Binary PPM (P6) writers: a blue→red temperature ramp and the paper's
//! signed error map (reds = positive/hotter, greens = zero, blues =
//! negative/colder).

use std::io::Write;
use std::path::Path;

use crate::util::error::Result;

use crate::stencil::Field;

/// Map t in [0,1] to a blue->cyan->yellow->red heat ramp.
fn heat_rgb(t: f64) -> [u8; 3] {
    let t = t.clamp(0.0, 1.0);
    let seg = |a: f64, b: f64| ((t - a) / (b - a)).clamp(0.0, 1.0);
    let (r, g, b) = if t < 0.25 {
        (0.0, seg(0.0, 0.25), 1.0)
    } else if t < 0.5 {
        (0.0, 1.0, 1.0 - seg(0.25, 0.5))
    } else if t < 0.75 {
        (seg(0.5, 0.75), 1.0, 0.0)
    } else {
        (1.0, 1.0 - seg(0.75, 1.0), 0.0)
    };
    [(r * 255.0) as u8, (g * 255.0) as u8, (b * 255.0) as u8]
}

/// Signed error map: positive -> red, ~zero -> green, negative -> blue.
fn error_rgb(e: f64, scale: f64) -> [u8; 3] {
    let t = (e / scale).clamp(-1.0, 1.0);
    if t > 0.0 {
        let s = t;
        [(255.0 * s) as u8, (255.0 * (1.0 - s)) as u8, 0]
    } else {
        let s = -t;
        [0, (255.0 * (1.0 - s)) as u8, (255.0 * s) as u8]
    }
}

fn write_ppm(path: &Path, w: usize, h: usize, rgb: &[u8]) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write!(f, "P6\n{w} {h}\n255\n")?;
    f.write_all(rgb)?;
    Ok(())
}

/// Render a 2D field with the heat ramp over [lo, hi].
pub fn save_heatmap(field: &Field, lo: f64, hi: f64, path: impl AsRef<Path>) -> Result<()> {
    crate::ensure!(field.ndim() == 2, "heatmap needs a 2D field");
    let (h, w) = (field.shape()[0], field.shape()[1]);
    let span = (hi - lo).max(1e-300);
    let mut rgb = Vec::with_capacity(3 * w * h);
    for &v in field.data() {
        rgb.extend_from_slice(&heat_rgb((v - lo) / span));
    }
    write_ppm(path.as_ref(), w, h, &rgb)
}

/// Render the signed difference a-b (paper Fig. 16(d)).
pub fn save_error_map(a: &Field, b: &Field, scale: f64, path: impl AsRef<Path>) -> Result<()> {
    crate::ensure!(a.shape() == b.shape() && a.ndim() == 2, "shape mismatch");
    let (h, w) = (a.shape()[0], a.shape()[1]);
    let mut rgb = Vec::with_capacity(3 * w * h);
    for (&x, &y) in a.data().iter().zip(b.data()) {
        rgb.extend_from_slice(&error_rgb(x - y, scale));
    }
    write_ppm(path.as_ref(), w, h, &rgb)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ramp_endpoints() {
        assert_eq!(heat_rgb(0.0), [0, 0, 255]);
        assert_eq!(heat_rgb(1.0), [255, 0, 0]);
        let mid = heat_rgb(0.5);
        assert_eq!(mid[1], 255); // green-ish middle
    }

    #[test]
    fn error_colors() {
        assert_eq!(error_rgb(1.0, 1.0), [255, 0, 0]);
        assert_eq!(error_rgb(-1.0, 1.0), [0, 0, 255]);
        assert_eq!(error_rgb(0.0, 1.0), [0, 255, 0]);
    }

    #[test]
    fn writes_valid_ppm() {
        let f = Field::random(&[4, 6], 1);
        let dir = std::env::temp_dir().join("tetris_viz_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.ppm");
        save_heatmap(&f, 0.0, 1.0, &p).unwrap();
        let data = std::fs::read(&p).unwrap();
        assert!(data.starts_with(b"P6\n6 4\n255\n"));
        assert_eq!(data.len(), 11 + 3 * 24);
    }
}
