//! Structural invariants over emitted `BENCH_*.json` artifacts —
//! `tetris bench check FILE...` in CI fails the job when a bench is
//! broken, instead of silently archiving nonsense.
//!
//! Checked invariants (each only where its shape is present, so one
//! checker covers every artifact kind):
//! * any percentile block is monotone: `p50_ms ≤ p90_ms ≤ p99_ms ≤
//!   p999_ms`, and likewise for bare `p50/p99` keys — recursively,
//!   anywhere in the document;
//! * serve session batching: the best batched rung's jobs/sec is at
//!   least the unbatched (`batch=1`) rung's;
//! * §5.3 overlap: the pipelined loop's summed worker idle is at most
//!   the serial loop's (parsed from the rows' `extra` strings);
//! * load suites: every rung conserves jobs (`offered = completed +
//!   rejected + errors + lost`), nothing is lost, the deterministic
//!   Suite A has zero rejects and zero errors, and retry accounting is
//!   sane (`gave_up ≤ rejected` and `gave_up ≤ retried`);
//! * per-rung `METRICS` snapshots: flat numeric maps whose `_total`
//!   counters are monotone from rung to rung (one server's cumulative
//!   stats), whose queue-depth gauge respects the capacity gauge, and
//!   whose flattened histogram ladders (`*_p50_ms` … `*_p999_ms`) are
//!   monotone within each snapshot;
//! * metrics-scrape JSONL files (`serve --metrics-scrape`, recognized
//!   by a `ts_ms` key on the first snapshot line): every line is a flat
//!   numeric registry snapshot, `ts_ms` strictly increases, and every
//!   `_total` counter is monotone line to line.

use std::collections::BTreeMap;

use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// Percentile key ladders checked for monotonicity wherever they appear.
const LADDERS: [&[&str]; 2] = [
    &["p50_ms", "p90_ms", "p99_ms", "p999_ms"],
    &["p50", "p90", "p99", "p999"],
];

/// All violations in one parsed artifact; empty means it passed.
/// `name` prefixes each message so multi-file output stays attributable.
pub fn check_json(name: &str, j: &Json) -> Vec<String> {
    check_json_with(name, j, None)
}

/// [`check_json`] plus the opt-in latency gate: with
/// `p999_degrade_max = Some(f)`, every Suite-B rung's total p99.9 must
/// stay within `f x` the first rung's (off by default — saturated
/// sweep tails are load-bearing noise unless the caller arms a bound).
pub fn check_json_with(name: &str, j: &Json, p999_degrade_max: Option<f64>) -> Vec<String> {
    let mut v = Vec::new();
    walk_percentiles(name, "$", j, &mut v);
    check_serve_batching(name, j, &mut v);
    check_overlap_idle(name, j, &mut v);
    check_grid_halo_bytes(name, j, &mut v);
    check_suite(name, j, &mut v);
    check_rung_metrics(name, j, &mut v);
    if let Some(max) = p999_degrade_max {
        check_p999_degrade(name, j, max, &mut v);
    }
    v
}

fn walk_percentiles(name: &str, path: &str, j: &Json, out: &mut Vec<String>) {
    match j {
        Json::Obj(m) => {
            for ladder in LADDERS {
                let present: Vec<(&str, f64)> = ladder
                    .iter()
                    .filter_map(|k| m.get(*k).and_then(|x| x.as_f64()).map(|v| (*k, v)))
                    .collect();
                for w in present.windows(2) {
                    if w[0].1 > w[1].1 {
                        out.push(format!(
                            "{name}: {path}: percentiles not monotone: {}={} > {}={}",
                            w[0].0, w[0].1, w[1].0, w[1].1
                        ));
                    }
                }
            }
            for (k, child) in m {
                walk_percentiles(name, &format!("{path}.{k}"), child, out);
            }
        }
        Json::Arr(a) => {
            for (i, child) in a.iter().enumerate() {
                walk_percentiles(name, &format!("{path}[{i}]"), child, out);
            }
        }
        _ => {}
    }
}

fn row_gstencils(rows: &[Json], label: &str) -> Option<f64> {
    rows.iter()
        .find(|r| r.at(&["label"]).as_str() == Some(label))
        .and_then(|r| r.at(&["gstencils_per_sec"]).as_f64())
}

/// Serve bench: the best batched rung must not lose to batch=1 — the
/// whole point of the multi-field dispatch.  Comparing the *best*
/// batched width keeps the invariant about batching, not about which
/// width wins on a noisy runner.
fn check_serve_batching(name: &str, j: &Json, out: &mut Vec<String>) {
    let Some(rows) = j.at(&["sections", "session-batching"]).as_arr() else { return };
    let Some(base) = row_gstencils(rows, "batch=1") else { return };
    let best_batched = rows
        .iter()
        .filter(|r| {
            matches!(r.at(&["label"]).as_str(), Some(l) if l.starts_with("batch=") && l != "batch=1")
        })
        .filter_map(|r| r.at(&["gstencils_per_sec"]).as_f64())
        .fold(f64::NEG_INFINITY, f64::max);
    if best_batched.is_finite() && base > 0.0 && best_batched < base {
        out.push(format!(
            "{name}: session-batching: best batched rate {best_batched:.3} jobs/sec \
             below unbatched {base:.3}"
        ));
    }
}

/// Pull `summed idle X ms` out of an overlap row's `extra` string.
fn idle_ms_from_extra(extra: &str) -> Option<f64> {
    let rest = extra.strip_prefix("summed idle ").or_else(|| {
        extra.split("summed idle ").nth(1)
    })?;
    rest.split_whitespace().next()?.parse().ok()
}

fn check_overlap_idle(name: &str, j: &Json, out: &mut Vec<String>) {
    let Some(rows) = j.at(&["sections", "overlap"]).as_arr() else { return };
    let idle_of = |label: &str| -> Option<f64> {
        rows.iter()
            .find(|r| r.at(&["label"]).as_str() == Some(label))
            .and_then(|r| r.at(&["extra"]).as_str())
            .and_then(idle_ms_from_extra)
    };
    if let (Some(off), Some(on)) = (idle_of("overlap=off"), idle_of("overlap=on")) {
        if on > off {
            out.push(format!(
                "{name}: overlap: pipelined summed idle {on:.3} ms exceeds serial {off:.3} ms"
            ));
        }
    }
}

/// Pull `key=N` out of a machine-parseable `extra` string
/// (`"halo_bytes=1024 msgs=8 workers=4"`).
fn extra_field(extra: &str, key: &str) -> Option<f64> {
    extra
        .split_whitespace()
        .find_map(|tok| tok.strip_prefix(key)?.strip_prefix('='))
        .and_then(|v| v.parse().ok())
}

/// `BENCH_grid.json`: at `W >= 4` workers the `WyxWx` tile grid (wy >
/// 1) must ship fewer halo bytes than the flat `1xW` row split — the
/// perimeter-over-area claim the 2-D refactor exists to cash in.
fn check_grid_halo_bytes(name: &str, j: &Json, out: &mut Vec<String>) {
    let Some(rows) = j.at(&["sections", "grid"]).as_arr() else { return };
    let stats_of = |r: &Json| -> Option<(String, f64, f64)> {
        let label = r.at(&["label"]).as_str()?.to_string();
        let extra = r.at(&["extra"]).as_str()?;
        Some((label, extra_field(extra, "halo_bytes")?, extra_field(extra, "workers")?))
    };
    let parsed: Vec<(String, f64, f64)> = rows.iter().filter_map(stats_of).collect();
    let Some((_, flat_bytes, flat_workers)) =
        parsed.iter().find(|(l, _, _)| l.starts_with("grid=1x")).cloned()
    else {
        return;
    };
    for (label, bytes, workers) in &parsed {
        if label.starts_with("grid=1x") || *workers < 4.0 || *workers != flat_workers {
            continue;
        }
        if *bytes >= flat_bytes {
            out.push(format!(
                "{name}: grid: {label} ships {bytes} halo bytes, not fewer than the flat \
                 1-D split's {flat_bytes} at {workers} workers"
            ));
        }
    }
}

/// Opt-in Suite-B tail-latency gate (`--p999-degrade-max F`): each
/// rung's total p99.9 must stay within `F x` the first rung's.
fn check_p999_degrade(name: &str, j: &Json, max: f64, out: &mut Vec<String>) {
    let Some(suite) = j.get("suite") else { return };
    if suite.at(&["name"]).as_str() != Some("suiteB") {
        return;
    }
    let Some(rungs) = suite.at(&["rungs"]).as_arr() else { return };
    let p999 = |r: &Json| r.at(&["latency_ms", "total", "p999_ms"]).as_f64();
    let Some(base) = rungs.first().and_then(&p999).filter(|&b| b > 0.0) else { return };
    for (i, rung) in rungs.iter().enumerate().skip(1) {
        let label = rung.at(&["label"]).as_str().unwrap_or("?");
        if let Some(p) = p999(rung) {
            if p > base * max {
                out.push(format!(
                    "{name}: suiteB rung {i} ({label}): total p99.9 {p:.3} ms exceeds \
                     {max}x the first rung's {base:.3} ms"
                ));
            }
        }
    }
}

fn rung_count(rung: &Json, key: &str) -> f64 {
    rung.at(&[key]).as_f64().unwrap_or(0.0)
}

fn check_suite(name: &str, j: &Json, out: &mut Vec<String>) {
    let Some(suite) = j.get("suite") else { return };
    let suite_name = suite.at(&["name"]).as_str().unwrap_or("").to_string();
    let Some(rungs) = suite.at(&["rungs"]).as_arr() else {
        out.push(format!("{name}: suite {suite_name:?} has no rungs array"));
        return;
    };
    if rungs.is_empty() {
        out.push(format!("{name}: suite {suite_name:?} has zero rungs"));
    }
    for (i, rung) in rungs.iter().enumerate() {
        let label = rung.at(&["label"]).as_str().unwrap_or("?");
        let (offered, completed) = (rung_count(rung, "offered"), rung_count(rung, "completed"));
        let (rejected, errors) = (rung_count(rung, "rejected"), rung_count(rung, "errors"));
        let lost = rung_count(rung, "lost");
        if offered != completed + rejected + errors + lost {
            out.push(format!(
                "{name}: suite rung {i} ({label}): jobs not conserved: offered {offered} != \
                 {completed} ok + {rejected} rejected + {errors} errors + {lost} lost"
            ));
        }
        if lost > 0.0 {
            out.push(format!("{name}: suite rung {i} ({label}): {lost} lost replies"));
        }
        if offered == 0.0 {
            out.push(format!("{name}: suite rung {i} ({label}): offered nothing"));
        }
        let (retried, gave_up) = (rung_count(rung, "retried"), rung_count(rung, "gave_up"));
        if gave_up > rejected {
            out.push(format!(
                "{name}: suite rung {i} ({label}): gave_up {gave_up} exceeds rejected {rejected}"
            ));
        }
        if gave_up > retried {
            out.push(format!(
                "{name}: suite rung {i} ({label}): gave_up {gave_up} exceeds retried {retried}"
            ));
        }
        if suite_name == "suiteA" {
            if rejected > 0.0 {
                out.push(format!(
                    "{name}: suiteA rung {i} ({label}): {rejected} rejects in the \
                     deterministic closed-loop baseline"
                ));
            }
            if errors > 0.0 {
                out.push(format!("{name}: suiteA rung {i} ({label}): {errors} errored jobs"));
            }
        }
        // a latency count above zero must come with completions, and
        // vice versa (only completions are recorded)
        let lat_count = rung.at(&["latency_ms", "total", "count"]).as_f64().unwrap_or(0.0);
        if lat_count != completed {
            out.push(format!(
                "{name}: suite rung {i} ({label}): {lat_count} total-latency samples for \
                 {completed} completions"
            ));
        }
    }
}

/// The flattened-percentile suffix ladder a `MetricsRegistry` snapshot
/// writes for each merged histogram.
const FLAT_LADDER: [&str; 4] = ["_p50_ms", "_p90_ms", "_p99_ms", "_p999_ms"];

/// Per-rung server `METRICS` snapshots (attached by `tetris load`).
fn check_rung_metrics(name: &str, j: &Json, out: &mut Vec<String>) {
    let Some(suite) = j.get("suite") else { return };
    let Some(rungs) = suite.at(&["rungs"]).as_arr() else { return };
    let mut prev: Option<(usize, &BTreeMap<String, Json>)> = None;
    for (i, rung) in rungs.iter().enumerate() {
        let label = rung.at(&["label"]).as_str().unwrap_or("?");
        let Some(m) = rung.at(&["metrics"]).as_obj() else { continue };
        for (k, v) in m {
            if v.as_f64().is_none() {
                out.push(format!(
                    "{name}: suite rung {i} ({label}): metrics.{k} is not a number"
                ));
            }
        }
        if let (Some(depth), Some(cap)) = (
            m.get("serve.queue_depth").and_then(Json::as_f64),
            m.get("serve.queue_capacity").and_then(Json::as_f64),
        ) {
            if depth > cap {
                out.push(format!(
                    "{name}: suite rung {i} ({label}): serve.queue_depth {depth} above \
                     serve.queue_capacity {cap}"
                ));
            }
        }
        // flattened histogram ladders within one snapshot
        for k in m.keys() {
            let Some(stem) = k.strip_suffix(FLAT_LADDER[0]) else { continue };
            let present: Vec<(String, f64)> = FLAT_LADDER
                .iter()
                .filter_map(|suf| {
                    let key = format!("{stem}{suf}");
                    m.get(&key).and_then(Json::as_f64).map(|v| (key, v))
                })
                .collect();
            for w in present.windows(2) {
                if w[0].1 > w[1].1 {
                    out.push(format!(
                        "{name}: suite rung {i} ({label}): metrics ladder not monotone: \
                         {}={} > {}={}",
                        w[0].0, w[0].1, w[1].0, w[1].1
                    ));
                }
            }
        }
        // cumulative counters must be monotone from rung to rung
        if let Some((pi, pm)) = prev {
            for (k, v) in m {
                if !k.ends_with("_total") {
                    continue;
                }
                if let (Some(a), Some(b)) = (pm.get(k).and_then(Json::as_f64), v.as_f64()) {
                    if b < a {
                        out.push(format!(
                            "{name}: metrics.{k} not monotone across rungs: {a} (rung {pi}) \
                             -> {b} (rung {i})"
                        ));
                    }
                }
            }
        }
        prev = Some((i, m));
    }
}

/// A metrics-scrape JSONL file (`serve --metrics-scrape FILE[:SECS]`):
/// one flat `MetricsRegistry` snapshot per line, each stamped `ts_ms`
/// (ms since the scrape thread started).  Checked: every line parses to
/// a flat numeric object, timestamps strictly increase, and every
/// `_total` counter is monotone line to line (they come from one
/// process's cumulative stats, so a decrease means a broken feed).
pub fn check_scrape(name: &str, text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut prev: Option<(usize, f64, BTreeMap<String, f64>)> = None;
    let mut lines = 0usize;
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        lines += 1;
        let j = match Json::parse(line) {
            Ok(j) => j,
            Err(e) => {
                out.push(format!("{name}: line {}: unparseable scrape snapshot: {e}", i + 1));
                continue;
            }
        };
        let Some(m) = j.as_obj() else {
            out.push(format!("{name}: line {}: snapshot is not an object", i + 1));
            continue;
        };
        let mut flat = BTreeMap::new();
        for (k, v) in m {
            match v.as_f64() {
                Some(x) => {
                    flat.insert(k.clone(), x);
                }
                None => out.push(format!("{name}: line {}: {k} is not a number", i + 1)),
            }
        }
        let Some(ts) = flat.get("ts_ms").copied() else {
            out.push(format!("{name}: line {}: snapshot has no ts_ms", i + 1));
            continue;
        };
        if let Some((pi, pts, pflat)) = &prev {
            if ts <= *pts {
                out.push(format!(
                    "{name}: ts_ms not strictly increasing: {pts} (line {}) -> {ts} (line {})",
                    pi + 1,
                    i + 1,
                ));
            }
            for (k, v) in &flat {
                if !k.ends_with("_total") {
                    continue;
                }
                if let Some(p) = pflat.get(k) {
                    if v < p {
                        out.push(format!(
                            "{name}: {k} not monotone across snapshots: {p} (line {}) -> {v} \
                             (line {})",
                            pi + 1,
                            i + 1,
                        ));
                    }
                }
            }
        }
        prev = Some((i, ts, flat));
    }
    if lines == 0 {
        out.push(format!("{name}: scrape file has no snapshots"));
    }
    out
}

/// Driver for `tetris bench check FILE...`: parse each artifact, print
/// per-file verdicts, error out if anything is violated.  A file whose
/// first non-empty line is an object with a `ts_ms` key is checked as a
/// metrics-scrape JSONL; anything else as one whole-file JSON document.
pub fn check_files(paths: &[String]) -> Result<()> {
    check_files_with(paths, None)
}

/// [`check_files`] plus the opt-in `--p999-degrade-max` Suite-B
/// tail-latency bound (see [`check_json_with`]).
pub fn check_files_with(paths: &[String], p999_degrade_max: Option<f64>) -> Result<()> {
    crate::ensure!(!paths.is_empty(), "bench check needs at least one BENCH_*.json path");
    let mut violations = Vec::new();
    for path in paths {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let first = text.lines().find(|l| !l.trim().is_empty()).unwrap_or("").trim();
        let is_scrape =
            Json::parse(first).ok().map_or(false, |j| j.get("ts_ms").is_some());
        let v = if is_scrape {
            check_scrape(path, &text)
        } else {
            let parsed = Json::parse(text.trim()).with_context(|| format!("parsing {path}"))?;
            check_json_with(path, &parsed, p999_degrade_max)
        };
        if v.is_empty() {
            println!("bench check: {path}: OK");
        } else {
            for msg in &v {
                println!("bench check: VIOLATION: {msg}");
            }
            violations.extend(v);
        }
    }
    crate::ensure!(
        violations.is_empty(),
        "{} bench invariant violation(s) across {} file(s)",
        violations.len(),
        paths.len()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Json {
        Json::parse(s).unwrap()
    }

    #[test]
    fn monotone_percentiles_pass_inverted_fail() {
        let good = parse(r#"{"latency":{"p50_ms":1.0,"p90_ms":2.0,"p99_ms":3.0,"p999_ms":3.0}}"#);
        assert!(check_json("g", &good).is_empty());
        let bad = parse(r#"{"deep":[{"x":{"p50_ms":5.0,"p99_ms":1.0}}]}"#);
        let v = check_json("b", &bad);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("not monotone") && v[0].contains("$.deep[0].x"), "{v:?}");
    }

    #[test]
    fn bare_percentile_ladder_is_checked_too() {
        let bad = parse(r#"{"p50":2.0,"p99":1.0}"#);
        assert_eq!(check_json("b", &bad).len(), 1);
    }

    #[test]
    fn batching_invariant() {
        let good = parse(
            r#"{"sections":{"session-batching":[
                {"label":"batch=1","gstencils_per_sec":10.0},
                {"label":"batch=4","gstencils_per_sec":9.0},
                {"label":"batch=8","gstencils_per_sec":12.0}]}}"#,
        );
        assert!(check_json("g", &good).is_empty(), "best batched (12) beats base (10)");
        let bad = parse(
            r#"{"sections":{"session-batching":[
                {"label":"batch=1","gstencils_per_sec":10.0},
                {"label":"batch=4","gstencils_per_sec":8.0},
                {"label":"batch=8","gstencils_per_sec":9.5}]}}"#,
        );
        let v = check_json("b", &bad);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("below unbatched"), "{v:?}");
    }

    #[test]
    fn overlap_idle_invariant_parses_extra() {
        assert_eq!(idle_ms_from_extra("summed idle 12.500 ms; hidden 3 ms"), Some(12.5));
        let good = parse(
            r#"{"sections":{"overlap":[
                {"label":"overlap=off","gstencils_per_sec":1.0,"extra":"summed idle 20.000 ms; hidden 0.000 ms"},
                {"label":"overlap=on","gstencils_per_sec":1.1,"extra":"summed idle 12.000 ms; hidden 6.000 ms"}]}}"#,
        );
        assert!(check_json("g", &good).is_empty());
        let bad = parse(
            r#"{"sections":{"overlap":[
                {"label":"overlap=off","gstencils_per_sec":1.0,"extra":"summed idle 10.000 ms"},
                {"label":"overlap=on","gstencils_per_sec":1.1,"extra":"summed idle 15.000 ms"}]}}"#,
        );
        let v = check_json("b", &bad);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("exceeds serial"), "{v:?}");
    }

    #[test]
    fn suite_a_rejects_and_conservation() {
        let good = parse(
            r#"{"suite":{"name":"suiteA","rungs":[
                {"label":"conns=4","offered":64,"completed":64,"rejected":0,"errors":0,"lost":0,
                 "latency_ms":{"total":{"count":64}}}]}}"#,
        );
        assert!(check_json("g", &good).is_empty());
        let bad = parse(
            r#"{"suite":{"name":"suiteA","rungs":[
                {"label":"conns=4","offered":64,"completed":60,"rejected":3,"errors":0,"lost":1,
                 "latency_ms":{"total":{"count":60}}}]}}"#,
        );
        let v = check_json("b", &bad);
        assert!(v.iter().any(|m| m.contains("rejects in the deterministic")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("lost replies")), "{v:?}");
    }

    #[test]
    fn suite_b_allows_rejects_but_not_loss_or_leaks() {
        let good = parse(
            r#"{"suite":{"name":"suiteB","rungs":[
                {"label":"rate=100","offered":50,"completed":40,"rejected":10,"errors":0,"lost":0,
                 "latency_ms":{"total":{"count":40}}}]}}"#,
        );
        assert!(check_json("g", &good).is_empty());
        let leak = parse(
            r#"{"suite":{"name":"suiteB","rungs":[
                {"label":"rate=100","offered":50,"completed":40,"rejected":8,"errors":0,"lost":0,
                 "latency_ms":{"total":{"count":40}}}]}}"#,
        );
        let v = check_json("b", &leak);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("not conserved"), "{v:?}");
    }

    #[test]
    fn latency_count_must_match_completions() {
        let bad = parse(
            r#"{"suite":{"name":"suiteB","rungs":[
                {"label":"rate=10","offered":5,"completed":5,"rejected":0,"errors":0,"lost":0,
                 "latency_ms":{"total":{"count":3}}}]}}"#,
        );
        let v = check_json("b", &bad);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("latency samples"), "{v:?}");
    }

    #[test]
    fn rung_metrics_envelope_passes_monotone_snapshots() {
        let good = parse(
            r#"{"suite":{"name":"suiteB","rungs":[
                {"label":"rate=10","offered":5,"completed":5,"rejected":0,"errors":0,"lost":0,
                 "latency_ms":{"total":{"count":5}},
                 "metrics":{"serve.completed_total":5,"serve.queue_depth":0,
                            "serve.queue_capacity":64,
                            "serve.latency_ms_p50_ms":1.0,"serve.latency_ms_p99_ms":2.0}},
                {"label":"rate=20","offered":8,"completed":8,"rejected":0,"errors":0,"lost":0,
                 "latency_ms":{"total":{"count":8}},
                 "metrics":{"serve.completed_total":13,"serve.queue_depth":2,
                            "serve.queue_capacity":64,
                            "serve.latency_ms_p50_ms":1.0,"serve.latency_ms_p99_ms":3.0}}]}}"#,
        );
        let v = check_json("g", &good);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn rung_metrics_envelope_flags_violations() {
        let bad = parse(
            r#"{"suite":{"name":"suiteB","rungs":[
                {"label":"rate=10","offered":5,"completed":5,"rejected":0,"errors":0,"lost":0,
                 "latency_ms":{"total":{"count":5}},
                 "metrics":{"serve.completed_total":9,"serve.queue_depth":70,
                            "serve.queue_capacity":64,
                            "serve.latency_ms_p50_ms":4.0,"serve.latency_ms_p99_ms":2.0,
                            "serve.engine":"simd"}},
                {"label":"rate=20","offered":8,"completed":8,"rejected":0,"errors":0,"lost":0,
                 "latency_ms":{"total":{"count":8}},
                 "metrics":{"serve.completed_total":7}}]}}"#,
        );
        let v = check_json("b", &bad);
        assert!(v.iter().any(|m| m.contains("not monotone across rungs")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("above") && m.contains("capacity")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("ladder not monotone")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("is not a number")), "{v:?}");
        // rungs without a metrics block stay vacuously fine
        let none = parse(
            r#"{"suite":{"name":"suiteB","rungs":[
                {"label":"rate=10","offered":5,"completed":5,"rejected":0,"errors":0,"lost":0,
                 "latency_ms":{"total":{"count":5}}}]}}"#,
        );
        assert!(check_json("g", &none).is_empty());
    }

    #[test]
    fn retry_accounting_must_stay_sane() {
        // gave_up beyond rejected (or retried) is impossible by
        // construction in the recorder — flag a forged report.
        let bad = parse(
            r#"{"suite":{"name":"suiteB","rungs":[
                {"label":"rate=100","offered":50,"completed":40,"rejected":10,"errors":0,"lost":0,
                 "retried":4,"gave_up":12,
                 "latency_ms":{"total":{"count":40}}}]}}"#,
        );
        let v = check_json("b", &bad);
        assert!(v.iter().any(|m| m.contains("exceeds rejected")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("exceeds retried")), "{v:?}");
        let good = parse(
            r#"{"suite":{"name":"suiteB","rungs":[
                {"label":"rate=100","offered":50,"completed":40,"rejected":10,"errors":0,"lost":0,
                 "retried":12,"gave_up":8,
                 "latency_ms":{"total":{"count":40}}}]}}"#,
        );
        assert!(check_json("g", &good).is_empty());
        // pre-retry artifacts have neither key: vacuously fine (0 <= 0)
        let old = parse(
            r#"{"suite":{"name":"suiteB","rungs":[
                {"label":"rate=100","offered":5,"completed":5,"rejected":0,"errors":0,"lost":0,
                 "latency_ms":{"total":{"count":5}}}]}}"#,
        );
        assert!(check_json("g", &old).is_empty());
    }

    #[test]
    fn scrape_jsonl_monotone_and_timestamped() {
        let good = "{\"ts_ms\":0.0,\"serve.completed_total\":3}\n\
                    {\"ts_ms\":1000.5,\"serve.completed_total\":9}\n";
        assert!(check_scrape("g", good).is_empty());
        let regressed = "{\"ts_ms\":0.0,\"serve.completed_total\":9}\n\
                         {\"ts_ms\":1000.0,\"serve.completed_total\":3}\n";
        let v = check_scrape("b", regressed);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("not monotone across snapshots"), "{v:?}");
        let backwards = "{\"ts_ms\":5.0}\n{\"ts_ms\":5.0}\n";
        let v = check_scrape("b", backwards);
        assert!(v.iter().any(|m| m.contains("strictly increasing")), "{v:?}");
        let missing_ts = "{\"ts_ms\":0.0}\n{\"serve.completed_total\":1}\n";
        let v = check_scrape("b", missing_ts);
        assert!(v.iter().any(|m| m.contains("no ts_ms")), "{v:?}");
        let nonnumeric = "{\"ts_ms\":0.0,\"serve.engine\":\"simd\"}\n";
        let v = check_scrape("b", nonnumeric);
        assert!(v.iter().any(|m| m.contains("is not a number")), "{v:?}");
        assert!(check_scrape("b", "\n\n").iter().any(|m| m.contains("no snapshots")));
    }

    #[test]
    fn check_files_routes_scrape_jsonl_by_first_line() {
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        let good = dir.join(format!("BENCH_scrape_good_{pid}.jsonl"));
        std::fs::write(
            &good,
            "{\"ts_ms\":0.0,\"load.offered_total\":1}\n{\"ts_ms\":2.0,\"load.offered_total\":4}\n",
        )
        .unwrap();
        assert!(check_files(&[good.to_string_lossy().into_owned()]).is_ok());
        let bad = dir.join(format!("BENCH_scrape_bad_{pid}.jsonl"));
        std::fs::write(
            &bad,
            "{\"ts_ms\":3.0,\"load.offered_total\":9}\n{\"ts_ms\":1.0,\"load.offered_total\":9}\n",
        )
        .unwrap();
        assert!(check_files(&[bad.to_string_lossy().into_owned()]).is_err());
        let _ = std::fs::remove_file(&good);
        let _ = std::fs::remove_file(&bad);
    }

    #[test]
    fn grid_halo_bytes_invariant() {
        let good = parse(
            r#"{"sections":{"grid":[
                {"label":"grid=1x4","gstencils_per_sec":1.0,"extra":"halo_bytes=4096 msgs=12 workers=4"},
                {"label":"grid=2x2","gstencils_per_sec":1.1,"extra":"halo_bytes=2304 msgs=16 workers=4"}]}}"#,
        );
        assert!(check_json("g", &good).is_empty());
        let bad = parse(
            r#"{"sections":{"grid":[
                {"label":"grid=1x4","gstencils_per_sec":1.0,"extra":"halo_bytes=2048 msgs=12 workers=4"},
                {"label":"grid=2x2","gstencils_per_sec":1.1,"extra":"halo_bytes=4096 msgs=16 workers=4"}]}}"#,
        );
        let v = check_json("b", &bad);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("not fewer than the flat"), "{v:?}");
        // below the W >= 4 crossover the comparison is vacuous
        let small = parse(
            r#"{"sections":{"grid":[
                {"label":"grid=1x2","gstencils_per_sec":1.0,"extra":"halo_bytes=1024 msgs=6 workers=2"},
                {"label":"grid=2x1","gstencils_per_sec":1.0,"extra":"halo_bytes=2048 msgs=6 workers=2"}]}}"#,
        );
        assert!(check_json("g", &small).is_empty());
    }

    #[test]
    fn p999_degrade_gate_is_opt_in_and_bounded() {
        let j = parse(
            r#"{"suite":{"name":"suiteB","rungs":[
                {"label":"rate=10","offered":5,"completed":5,"rejected":0,"errors":0,"lost":0,
                 "latency_ms":{"total":{"count":5,"p999_ms":10.0}}},
                {"label":"rate=20","offered":8,"completed":8,"rejected":0,"errors":0,"lost":0,
                 "latency_ms":{"total":{"count":8,"p999_ms":45.0}}}]}}"#,
        );
        // off by default
        assert!(check_json("g", &j).is_empty());
        // generous bound passes, tight bound trips
        assert!(check_json_with("g", &j, Some(5.0)).is_empty());
        let v = check_json_with("b", &j, Some(2.0));
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("p99.9") && v[0].contains("2x"), "{v:?}");
        // suiteA is never gated (closed loop, no rate ladder)
        let a = parse(
            r#"{"suite":{"name":"suiteA","rungs":[
                {"label":"conns=4","offered":4,"completed":4,"rejected":0,"errors":0,"lost":0,
                 "latency_ms":{"total":{"count":4,"p999_ms":1.0}}},
                {"label":"conns=8","offered":8,"completed":8,"rejected":0,"errors":0,"lost":0,
                 "latency_ms":{"total":{"count":8,"p999_ms":99.0}}}]}}"#,
        );
        assert!(check_json_with("g", &a, Some(2.0)).is_empty());
    }

    #[test]
    fn non_serve_artifacts_pass_vacuously() {
        let j = parse(r#"{"bench":"breakdown","sections":{"heat2d":[{"label":"naive","gstencils_per_sec":0.2}]}}"#);
        assert!(check_json("g", &j).is_empty());
    }

    #[test]
    fn check_files_flags_missing_and_bad_files() {
        assert!(check_files(&[]).is_err());
        assert!(check_files(&["/nonexistent/BENCH_x.json".into()]).is_err());
        let dir = std::env::temp_dir();
        let good = dir.join(format!("BENCH_check_good_{}.json", std::process::id()));
        std::fs::write(&good, "{\"bench\":\"smoke\",\"sections\":{}}\n").unwrap();
        assert!(check_files(&[good.to_string_lossy().into_owned()]).is_ok());
        let bad = dir.join(format!("BENCH_check_bad_{}.json", std::process::id()));
        std::fs::write(&bad, "{\"p50_ms\":9.0,\"p99_ms\":1.0}\n").unwrap();
        assert!(check_files(&[bad.to_string_lossy().into_owned()]).is_err());
        let _ = std::fs::remove_file(&good);
        let _ = std::fs::remove_file(&bad);
    }
}
