//! Bench harness: workload generators, timed sweeps, table printers.
//!
//! Every paper exhibit has a `run_*` entry point here; the `[[bench]]`
//! binaries and the `tetris bench` CLI subcommand are thin wrappers.
//! Problem sizes are scaled from paper Table 1 (see DESIGN.md §4) and
//! configurable through [`BenchScale`].

pub mod check;

use std::collections::BTreeMap;
use std::time::Duration;

use crate::util::error::{Context, Result};
use crate::util::json::Json;

use crate::coordinator::{
    partition::capacity_units, tuner, CommModel, NativeWorker, Overlap, Partition, Scheduler,
    Worker, XlaWorker,
};
use crate::engine::Engine;
use crate::runtime::XlaService;
use crate::stencil::{spec, Boundary, Field, StencilSpec};
use crate::util::timer;

/// Scaled problem sizes per benchmark: (core shape, total steps, Tb).
pub fn scaled_problem(name: &str, scale: f64) -> (Vec<usize>, usize, usize) {
    let s = |x: usize| ((x as f64 * scale) as usize).max(8);
    match name {
        "heat1d" => (vec![s(262144)], 16, 8),
        "star1d5p" => (vec![s(262144)], 16, 4),
        "heat2d" => (vec![s(512), s(512)], 16, 4),
        "star2d9p" => (vec![s(512), s(512)], 16, 2),
        "box2d9p" => (vec![s(512), s(512)], 16, 4),
        "box2d25p" => (vec![s(384), s(384)], 16, 2),
        "heat3d" => (vec![s(64), s(64), s(64)], 8, 2),
        "box3d27p" => (vec![s(64), s(64), s(64)], 8, 2),
        _ => panic!("unknown bench {name}"),
    }
}

/// One table row: label + throughput + speedup vs the row marked base.
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    pub gstencils: f64,
    pub speedup: f64,
    pub extra: String,
}

/// Render rows as an aligned text table (and return it).
pub fn print_table(title: &str, rows: &[Row]) -> String {
    let mut s = format!("== {title} ==\n");
    let wl = rows.iter().map(|r| r.label.len()).max().unwrap_or(8).max(12);
    s.push_str(&format!(
        "{:<wl$} {:>14} {:>9}  note\n",
        "method", "GStencils/s", "speedup"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<wl$} {:>14.4} {:>8.2}x  {}\n",
            r.label, r.gstencils, r.speedup, r.extra
        ));
    }
    println!("{s}");
    s
}

/// Time one engine on a benchmark's scaled problem (valid-mode blocks).
pub fn time_engine(
    eng: &dyn Engine,
    spec_: &StencilSpec,
    core: &[usize],
    total_steps: usize,
    tb: usize,
) -> (f64, Duration) {
    let halo = spec_.radius * tb;
    let ext: Vec<usize> = core.iter().map(|n| n + 2 * halo).collect();
    let input = Field::random(&ext, 0xA11CE);
    let blocks = total_steps / tb;
    let d = timer::time_median(0, 1, || {
        let mut cur = input.clone();
        for _ in 0..blocks {
            let out = eng.block(spec_, &cur, tb);
            // re-pad to keep iterating (Dirichlet ring)
            cur = out.pad(halo, 0.0);
        }
        cur
    });
    let cells: usize = core.iter().product();
    (timer::gstencils_per_sec(cells, total_steps, d), d)
}

/// Time a scheduler configuration end-to-end.
pub fn time_scheduler(
    sched: &Scheduler,
    core: &Field,
    total_steps: usize,
) -> Result<(f64, crate::coordinator::RunMetrics)> {
    let (_, metrics) = sched.run(core, total_steps)?;
    Ok((metrics.gstencils_per_sec(), metrics))
}

fn native(eng: &str, threads: usize) -> Box<dyn Worker> {
    Box::new(NativeWorker::new(crate::engine::by_name(eng, threads).unwrap(), 1 << 33))
}

/// Build the auto-tuned heterogeneous scheduler for a bench, mixing a
/// CPU engine (any name from either registry — `tetris-cpu` unless a
/// plan resolved otherwise) with the XLA block artifact when available.
pub fn hetero_scheduler(
    rt: &XlaService,
    bench: &str,
    threads: usize,
    cpu_engine: &str,
) -> Result<(Scheduler, Vec<usize>)> {
    let meta = rt.bench(bench)?.clone();
    let s = spec::get(bench).unwrap();
    let cpu: Box<dyn Worker> = Box::new(NativeWorker::new(
        crate::plan::resolve_engine(cpu_engine, threads)
            .with_context(|| format!("unknown engine {cpu_engine}"))?,
        1 << 33,
    ));
    let workers: Vec<Box<dyn Worker>> = vec![
        cpu,
        Box::new(XlaWorker::new(rt.clone(), &format!("{bench}_block"), 1 << 33)?),
    ];
    let unit_core: Vec<usize> = {
        let mut u = vec![meta.unit];
        u.extend(&meta.global_core[1..]);
        u
    };
    let prof = tuner::profile_workers(&workers, &s, &unit_core, meta.tb, 2)?;
    let halo = s.radius * meta.tb;
    let rest_cells: usize = meta.global_core[1..]
        .iter()
        .map(|n| n + 2 * halo)
        .product::<usize>()
        .max(1);
    let caps: Vec<usize> = workers
        .iter()
        .map(|w| capacity_units(w.mem_capacity(), meta.unit, rest_cells))
        .collect();
    let weights: Vec<f64> = prof.iter().map(|t| 1.0 / t.max(1e-12)).collect();
    let units = meta.global_core[0] / meta.unit;
    let partition = Partition::balanced(meta.unit, units, &weights, &caps);
    Ok((
        Scheduler {
            spec: s,
            tb: meta.tb,
            workers,
            partition,
            comm_model: CommModel::default(),
            boundary: Boundary::Dirichlet(0.0),
            adapt_every: 0,
            overlap: Overlap::Auto,
        },
        meta.global_core.clone(),
    ))
}

// ---------------------------------------------------------------------
// Paper exhibits
// ---------------------------------------------------------------------

/// Fig. 12: performance breakdown, extended with the heat benchmarks and
/// the work-stealing wavefront rung (tetris-wave vs tetris-cpu is the
/// scheduler ablation the runtime work tracks).
pub fn run_breakdown(rt: Option<&XlaService>, scale: f64, threads: usize) -> Vec<(String, Vec<Row>)> {
    let mut out = Vec::new();
    for bench in ["star1d5p", "heat2d", "box2d25p", "heat3d", "box3d27p"] {
        let s = spec::get(bench).unwrap();
        let (core, steps, tb) = scaled_problem(bench, scale);
        let mut rows = Vec::new();
        let mut base = 0.0;
        let rungs: Vec<(&str, Box<dyn Engine>)> = vec![
            ("naive", crate::engine::by_name("naive", 1).unwrap()),
            ("+tessellate", crate::engine::by_name("tessellate", 1).unwrap()),
            ("+skewed-swizzle", Box::new(crate::engine::tessellate::TessellateEngine {
                inner: crate::engine::tessellate::Inner::Fused,
                threads: 1,
                tile_w: None,
            })),
            ("+multicore (Tetris CPU)", crate::engine::by_name("tetris-cpu", threads).unwrap()),
            ("+wavefront DAG (tetris-wave)", crate::engine::by_name("tetris-wave", threads).unwrap()),
        ];
        for (label, eng) in rungs {
            let (g, _) = time_engine(eng.as_ref(), &s, &core, steps, tb);
            if base == 0.0 {
                base = g;
            }
            rows.push(Row {
                label: label.into(),
                gstencils: g,
                speedup: g / base,
                extra: String::new(),
            });
        }
        if let Some(rt) = rt {
            // +Tensor Cores (MXU trapezoid folding) and +Checkerboard
            // (temporal-block artifact) rungs via PJRT, unit-slab sized.
            for (label, art) in [("+mxu (trapezoid)", format!("{bench}_mxu")),
                                  ("+checkerboard (block)", format!("{bench}_block"))] {
                if let Ok(meta) = rt.meta(&art).cloned() {
                    let input = Field::random(&meta.input_shape, 0xF00D);
                    let d = timer::time_median(1, 3, || rt.run(&art, &input).unwrap());
                    let cells: usize = meta.unit_core.iter().product();
                    let g = timer::gstencils_per_sec(cells, meta.steps, d);
                    rows.push(Row {
                        label: label.into(),
                        gstencils: g,
                        speedup: g / base,
                        extra: format!("artifact {art}"),
                    });
                }
            }
        }
        print_table(&format!("Fig.12 breakdown: {bench}"), &rows);
        out.push((bench.to_string(), rows));
    }
    out
}

/// Fig. 13: state-of-the-art comparison across all 8 benchmarks.
pub fn run_sota(rt: Option<&XlaService>, scale: f64, threads: usize) -> Vec<(String, Vec<Row>)> {
    let mut out = Vec::new();
    for bench in spec::benchmarks() {
        let name = bench.name;
        let (core, steps, tb) = scaled_problem(name, scale);
        let mut rows: Vec<Row> = Vec::new();
        let engines: Vec<(&str, Box<dyn Engine>)> = vec![
            ("DataReorg", crate::baselines::by_name("datareorg").unwrap()),
            ("AutoVec", crate::engine::by_name("autovec", 1).unwrap()),
            ("Pluto", crate::baselines::by_name("pluto").unwrap()),
            ("Folding", crate::baselines::by_name("folding").unwrap()),
            ("Brick", crate::baselines::by_name("brick").unwrap()),
            ("AN5D", crate::baselines::by_name("an5d").unwrap()),
            ("Tetris(CPU)", crate::engine::by_name("tetris-cpu", threads).unwrap()),
        ];
        for (label, eng) in engines {
            let (g, _) = time_engine(eng.as_ref(), &bench, &core, steps, tb);
            rows.push(Row { label: label.into(), gstencils: g, speedup: 0.0, extra: String::new() });
        }
        if let Some(rt) = rt {
            let art = format!("{name}_block");
            if let Ok(meta) = rt.meta(&art).cloned() {
                let input = Field::random(&meta.input_shape, 0xF00D);
                let d = timer::time_median(1, 3, || rt.run(&art, &input).unwrap());
                let cells: usize = meta.unit_core.iter().product();
                rows.push(Row {
                    label: "Tetris(GPU)".into(),
                    gstencils: timer::gstencils_per_sec(cells, meta.steps, d),
                    speedup: 0.0,
                    extra: "xla block artifact".into(),
                });
            }
            if let Ok((sched, global)) = hetero_scheduler(rt, name, threads, "tetris-cpu") {
                let core_f = Field::random(&global, 0xF00D);
                let total = sched.tb * 2;
                if let Ok((g, m)) = time_scheduler(&sched, &core_f, total) {
                    rows.push(Row {
                        label: "Tetris".into(),
                        gstencils: g,
                        speedup: 0.0,
                        extra: format!("ratio {:.1}%", m.ratios.last().unwrap_or(&0.0) * 100.0),
                    });
                }
            }
        }
        let base = rows
            .iter()
            .map(|r| r.gstencils)
            .fold(f64::INFINITY, f64::min)
            .max(1e-12);
        for r in &mut rows {
            r.speedup = r.gstencils / base;
        }
        print_table(&format!("Fig.13: {name}"), &rows);
        out.push((name.to_string(), rows));
    }
    out
}

/// Fig. 14: scalability vs thread count + scheduling ratio.
pub fn run_scaling(rt: Option<&XlaService>, scale: f64, max_threads: usize) -> Vec<(String, Vec<Row>)> {
    let mut out = Vec::new();
    for bench in ["heat1d", "heat2d", "heat3d"] {
        let s = spec::get(bench).unwrap();
        let (core, steps, tb) = scaled_problem(bench, scale);
        let mut rows = Vec::new();
        let mut base = 0.0;
        let mut t = 1;
        while t <= max_threads {
            let eng = crate::engine::by_name("tetris-cpu", t).unwrap();
            let (g, _) = time_engine(eng.as_ref(), &s, &core, steps, tb);
            if t == 1 {
                base = g;
            }
            rows.push(Row {
                label: format!("{t} threads"),
                gstencils: g,
                speedup: g / base,
                extra: String::new(),
            });
            t *= 2;
        }
        if let Some(rt) = rt {
            if let Ok((sched, _)) = hetero_scheduler(rt, bench, max_threads, "tetris-cpu") {
                let ratio = sched.partition.ratio(sched.partition.shares.len() - 1);
                rows.push(Row {
                    label: "hetero (tuned)".into(),
                    gstencils: 0.0,
                    speedup: 0.0,
                    extra: format!("scheduling ratio GPU:CPU = {:.1}%", ratio * 100.0),
                });
            }
        }
        print_table(&format!("Fig.14 scaling: {bench}"), &rows);
        out.push((bench.to_string(), rows));
    }
    out
}

/// Boundary & adaptivity study: ghost-fill throughput plus end-to-end
/// scheduler rungs under each boundary condition and the §5.2 adaptive
/// loop.  CI smoke archives this as `BENCH_boundary.json`, so the
/// periodic and adaptive paths have a tracked trajectory.
pub fn run_boundary(scale: f64, threads: usize) -> Vec<(String, Vec<Row>)> {
    let mut out = Vec::new();

    // O(surface) ghost-fill micro-bench: cells-of-ring per second must
    // stay ~flat as the domain grows (an O(volume) fill collapses here).
    let halo = 4usize;
    let mut rows = Vec::new();
    for n in [128usize, 256, 512] {
        let core = Field::random(&[n, n], 0x9B);
        let mut ext = core.pad(halo, 0.0);
        let d = timer::time_median(1, 5, || Boundary::Periodic.fill(&mut ext, halo));
        let surface = ext.len() - core.len();
        rows.push(Row {
            label: format!("ghostfill {n}x{n}"),
            gstencils: surface as f64 / d.as_secs_f64() / 1e9,
            speedup: 0.0,
            extra: format!("{surface} ghost cells in {}", timer::fmt_duration(d)),
        });
    }
    print_table("ghost-fill (periodic, halo 4): Gcells/s over the ring", &rows);
    out.push(("ghostfill".to_string(), rows));

    // End-to-end scheduler rungs: heat2d on two native workers, one per
    // boundary condition, plus the adaptive-retune configuration.
    let bench = "heat2d";
    let s = spec::get(bench).unwrap();
    let (core_shape, steps, tb) = scaled_problem(bench, scale);
    let rows0 = core_shape[0];
    let core = Field::random(&core_shape, 0xB0B);
    let mk = |boundary: Boundary, adapt_every: usize| Scheduler {
        spec: s.clone(),
        tb,
        workers: vec![native("tetris-cpu", threads), native("simd", 1)],
        partition: Partition::balanced(1, rows0, &[1.0, 1.0], &[rows0, rows0]),
        comm_model: CommModel::default(),
        boundary,
        adapt_every,
        overlap: Overlap::Auto,
    };
    let mut rows = Vec::new();
    let mut base = 0.0;
    for (label, boundary, adapt) in [
        ("dirichlet", Boundary::Dirichlet(0.0), 0usize),
        ("neumann", Boundary::Neumann, 0),
        ("periodic", Boundary::Periodic, 0),
        ("periodic+adapt2", Boundary::Periodic, 2),
    ] {
        match mk(boundary, adapt).run(&core, steps) {
            Ok((_, m)) => {
                let g = m.gstencils_per_sec();
                if base == 0.0 {
                    base = g;
                }
                rows.push(Row {
                    label: label.into(),
                    gstencils: g,
                    speedup: g / base.max(1e-12),
                    extra: format!(
                        "bubble {:.1}%, retunes {}",
                        m.bubble_fraction() * 100.0,
                        m.retunes
                    ),
                });
            }
            Err(e) => rows.push(Row {
                label: label.into(),
                gstencils: 0.0,
                speedup: 0.0,
                extra: format!("ERROR: {e}"),
            }),
        }
    }
    print_table("boundary-aware scheduler: heat2d, 2 native workers", &rows);
    out.push((bench.to_string(), rows));
    out
}

/// 2-D worker-grid study: the same `W`-worker heat2d problem split as
/// the flat `1xW` row partition vs a `2x(W/2)` tile grid — identical
/// physics and inputs, so the gap in the comm ledger is purely the
/// tile perimeter (full-width dim-1 links vs half-width links plus
/// tiny corner exchanges).  `extra` carries `halo_bytes=` / `msgs=` in
/// machine-parseable form; CI archives this as `BENCH_grid.json` and
/// asserts the 2-D rung ships fewer halo bytes at `W >= 4`.
pub fn run_grid(scale: f64, threads: usize) -> Vec<(String, Vec<Row>)> {
    use crate::coordinator::partition::even_split;
    let bench = "heat2d";
    let s = spec::get(bench).unwrap();
    let (core_shape, steps, tb) = scaled_problem(bench, scale);
    let w = 4usize;
    let core = Field::random(&core_shape, 0x6121D);
    let mk = |wy: usize, wx: usize| Scheduler {
        spec: s.clone(),
        tb,
        workers: (0..wy * wx).map(|_| native("tetris-cpu", threads)).collect(),
        partition: Partition::rows(1, even_split(core_shape[0], wx))
            .with_bands(if wy > 1 { even_split(core_shape[1], wy) } else { Vec::new() }),
        comm_model: CommModel::default(),
        boundary: Boundary::Periodic,
        adapt_every: 0,
        overlap: Overlap::Auto,
    };
    let mut rows = Vec::new();
    let mut outs: Vec<Field> = Vec::new();
    let mut base = 0.0;
    for (wy, wx) in [(1, w), (2, w / 2)] {
        match mk(wy, wx).run(&core, steps) {
            Ok((out, m)) => {
                let g = m.gstencils_per_sec();
                if base == 0.0 {
                    base = g;
                }
                rows.push(Row {
                    label: format!("grid={wy}x{wx}"),
                    gstencils: g,
                    speedup: g / base.max(1e-12),
                    extra: format!(
                        "halo_bytes={} msgs={} workers={}",
                        m.comm.bytes,
                        m.comm.messages,
                        wy * wx
                    ),
                });
                outs.push(out);
            }
            Err(e) => rows.push(Row {
                label: format!("grid={wy}x{wx}"),
                gstencils: 0.0,
                speedup: 0.0,
                extra: format!("ERROR: {e}"),
            }),
        }
    }
    // Slab decomposition is numerically invisible, so the grid shape
    // must not change a single bit of the result.
    if outs.len() == 2 {
        assert!(
            outs[0].data() == outs[1].data(),
            "1x{w} and 2x{} grids diverged numerically",
            w / 2
        );
    }
    print_table(
        &format!("2-D worker grid: heat2d, {w} workers, periodic"),
        &rows,
    );
    vec![("grid".to_string(), rows)]
}

/// Serving-layer throughput study: jobs/sec at varying batch widths.
///
/// The first section runs the same 8-job mix through one partition-
/// caching [`crate::serve::Session`] at batch widths 1/4/8 — identical
/// physics and inputs, so the gap is purely the per-block pool-spawn,
/// snapshot and retune amortization of the multi-field dispatch.  The
/// second section drives a real loopback `tetris serve` over TCP with a
/// mixed-boundary job stream and reports end-to-end jobs/sec + p99.
/// `gstencils_per_sec` carries **jobs/sec** in this bench's rows (the
/// JSON field name is shared across benches; `extra` spells the unit).
pub fn run_serve(scale: f64, threads: usize) -> Vec<(String, Vec<Row>)> {
    use crate::serve::Session;
    let mut out = Vec::new();

    let bench = "heat2d";
    let (shape, _, tb) = scaled_problem(bench, scale);
    let steps = tb * 2;
    let jobs = 8usize;
    let inputs: Vec<Field> =
        (0..jobs).map(|i| Field::random(&shape, 0x5E47E + i as u64)).collect();
    let mk_workers = || vec![native("tetris-cpu", threads), native("simd", 1)];
    let mut rows = Vec::new();
    let mut base_jps = 0.0;
    for &batch in &[1usize, 4, 8] {
        match Session::new(bench, shape.clone(), tb, mk_workers(), 2, 0.25, Overlap::Auto) {
            Ok(mut sess) => {
                let t0 = std::time::Instant::now();
                let mut ok = true;
                for chunk in inputs.chunks(batch) {
                    ok &= sess.run_batch(Boundary::Periodic, chunk, steps).is_ok();
                }
                let wall = t0.elapsed();
                let jps = jobs as f64 / wall.as_secs_f64().max(1e-12);
                if batch == 1 {
                    base_jps = jps;
                }
                rows.push(Row {
                    label: format!("batch={batch}"),
                    gstencils: jps,
                    speedup: jps / base_jps.max(1e-12),
                    extra: format!(
                        "jobs/sec; {jobs} jobs ({bench} {shape:?} x{steps}) in {}{}",
                        timer::fmt_duration(wall),
                        if ok { "" } else { " [ERRORS]" }
                    ),
                });
            }
            Err(e) => rows.push(Row {
                label: format!("batch={batch}"),
                gstencils: 0.0,
                speedup: 0.0,
                extra: format!("ERROR: {e}"),
            }),
        }
    }
    print_table("serve: session batching (jobs/sec, same 8-job mix)", &rows);
    out.push(("session-batching".to_string(), rows));

    // End-to-end loopback drive: mixed-boundary stream through the real
    // TCP server (admission, batching and sessions all in the path).
    let mut rows = Vec::new();
    match serve_loopback_drive(scale, threads) {
        Ok(row) => rows.push(row),
        Err(e) => rows.push(Row {
            label: "tcp-loopback".into(),
            gstencils: 0.0,
            speedup: 0.0,
            extra: format!("ERROR: {e}"),
        }),
    }
    print_table("serve: TCP loopback (jobs/sec end-to-end)", &rows);
    out.push(("tcp-loopback".to_string(), rows));
    out
}

fn serve_loopback_drive(scale: f64, threads: usize) -> Result<Row> {
    use crate::serve::{Client, JobSpec, Priority, ServeConfig, Server};
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        dispatchers: 2,
        threads,
        scale,
        ..Default::default()
    };
    let handle = Server::start(cfg, crate::serve::default_worker_factory(threads))?;
    let mut client = Client::connect(handle.addr)?;
    let boundaries = ["dirichlet:25", "neumann", "periodic"];
    let jobs = 12usize;
    let t0 = std::time::Instant::now();
    for i in 0..jobs {
        client.send_spec(&JobSpec {
            id: format!("bench-{i}"),
            bench: "heat2d".into(),
            boundary: boundaries[i % boundaries.len()].parse().unwrap(),
            steps: 4,
            seed: 7_000 + i as u64,
            priority: Priority::Normal,
            ..Default::default()
        })?;
    }
    let mut ok = 0usize;
    for _ in 0..jobs {
        if client.recv_result()?.ok {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    let stats = client.stats()?;
    let p99 = stats.at(&["stats", "latency", "p99_ms"]).as_f64().unwrap_or(0.0);
    let p999 = stats.at(&["stats", "latency", "p999_ms"]).as_f64().unwrap_or(0.0);
    client.shutdown()?;
    handle.join();
    crate::ensure!(ok == jobs, "loopback drive lost {} results", jobs - ok);
    Ok(Row {
        label: "tcp-loopback".into(),
        gstencils: jobs as f64 / wall.as_secs_f64().max(1e-12),
        speedup: 1.0,
        extra: format!("jobs/sec; {jobs} mixed-boundary jobs, p99 {p99:.3} ms, p99.9 {p999:.3} ms"),
    })
}

/// Planned-execution study: what `--engine auto` resolves to vs fixed
/// engines on heat2d/heat3d.  Fixed rungs run first (speedups are
/// relative to fixed `tetris-cpu`); the `auto` rung resolves through a
/// plan store — `store_path` when given (so a pre-run `tetris tune`
/// shows up as a warm start/cache hit), else a throwaway in the temp
/// dir — and then times the winning configuration on the full-scale
/// problem.  CI archives this as `BENCH_plan.json`, tracking the
/// advantage (or cost) of planned execution over time.
pub fn run_plan(scale: f64, threads: usize, store_path: Option<&str>) -> Vec<(String, Vec<Row>)> {
    use crate::plan::{resolve_auto, Fingerprint, PlanStore, SearchConfig};
    let store = match store_path {
        Some(p) => PlanStore::open(p),
        None => {
            let tmp = std::env::temp_dir()
                .join(format!("tetris-bench-plans-{}.jsonl", std::process::id()));
            let _ = std::fs::remove_file(&tmp);
            PlanStore::open(tmp)
        }
    };
    let fp = Fingerprint::detect(100);
    let mut out = Vec::new();
    for bench in ["heat2d", "heat3d"] {
        let s = spec::get(bench).unwrap();
        let (core, steps, tb) = scaled_problem(bench, scale);
        let mut rows = Vec::new();
        let mut base = 0.0;
        for eng_name in ["tetris-cpu", "simd"] {
            let t = if eng_name == "tetris-cpu" { threads } else { 1 };
            let eng = crate::engine::by_name(eng_name, t).unwrap();
            let (g, _) = time_engine(eng.as_ref(), &s, &core, steps, tb);
            if base == 0.0 {
                base = g;
            }
            rows.push(Row {
                label: eng_name.into(),
                gstencils: g,
                speedup: g / base.max(1e-12),
                extra: format!("fixed Tb={tb}"),
            });
        }
        let cfg = SearchConfig { budget_ms: 400, seed: 1, ..Default::default() };
        let auto_row = match resolve_auto(&store, &fp, bench, "dirichlet", &core, steps, &cfg) {
            Ok(res) => {
                let p = &res.plan;
                match p.candidate().build() {
                    Some(eng) => {
                        let tbp = p.tb.max(1);
                        let stepsp = steps.max(1).div_ceil(tbp) * tbp;
                        let (g, _) = time_engine(eng.as_ref(), &s, &core, stepsp, tbp);
                        Row {
                            label: "auto".into(),
                            gstencils: g,
                            speedup: g / base.max(1e-12),
                            extra: format!(
                                "plan: {} threads={} Tb={} ({})",
                                p.engine,
                                p.threads,
                                p.tb,
                                if res.cached {
                                    "cached"
                                } else if res.warmed {
                                    "warm-start"
                                } else {
                                    "tuned"
                                }
                            ),
                        }
                    }
                    None => Row {
                        label: "auto".into(),
                        gstencils: 0.0,
                        speedup: 0.0,
                        extra: format!("ERROR: plan names unknown engine {:?}", p.engine),
                    },
                }
            }
            Err(e) => Row {
                label: "auto".into(),
                gstencils: 0.0,
                speedup: 0.0,
                extra: format!("ERROR: {e}"),
            },
        };
        rows.push(auto_row);
        print_table(&format!("plan: auto vs fixed engines ({bench})"), &rows);
        out.push((bench.to_string(), rows));
    }
    out
}

/// §5.3 overlap study: the pipelined (double-buffered) leader loop vs
/// the serial one on an **imbalanced** 2-worker heat2d run (3:1 row
/// split across unequal engines, so the fast worker idles through every
/// serial leader phase).  Rows report throughput; `extra` carries the
/// summed worker idle (`workers x elapsed − Σ busy`, the §5.3 target)
/// and the leader-phase time the pipelined loop hid under compute.
/// Both rows compute bit-identical fields (asserted in `cargo test`);
/// CI archives this as `BENCH_overlap.json`.
pub fn run_overlap(scale: f64, threads: usize) -> Vec<(String, Vec<Row>)> {
    run_overlap_mode(scale, threads, None)
}

/// `run_overlap` restricted to one mode (`bench overlap --mode on|off`):
/// CI records separate per-mode runs so each gets its own trace file,
/// then diffs the two traces and reconciles the pipelined trace against
/// its `RunMetrics.overlap_hidden`.  `None` runs both rows as always.
pub fn run_overlap_mode(
    scale: f64,
    threads: usize,
    mode: Option<Overlap>,
) -> Vec<(String, Vec<Row>)> {
    let (_, steps, _) = scaled_problem("heat2d", scale);
    let core = overlap_bench_field(scale);
    let mut rows = Vec::new();
    let mut base = 0.0;
    let both = [("overlap=off", Overlap::Off), ("overlap=on", Overlap::On)];
    let modes: Vec<(&str, Overlap)> = both
        .into_iter()
        .filter(|(_, o)| mode.map_or(true, |m| m == *o))
        .collect();
    for (label, overlap) in modes {
        match overlap_bench_sched(scale, threads, overlap).run(&core, steps) {
            Ok((_, m)) => {
                let g = m.gstencils_per_sec();
                if base == 0.0 {
                    base = g;
                }
                rows.push(Row {
                    label: label.into(),
                    gstencils: g,
                    speedup: g / base.max(1e-12),
                    // `check::idle_ms_from_extra` and
                    // `trace::diff::extract_hidden_ms` parse this string:
                    // the "summed idle"/"hidden … ms" wording is a
                    // published contract, not cosmetics.
                    extra: format!(
                        "summed idle {:.3} ms; hidden {:.3} ms; overlapped msgs {}/{}",
                        m.summed_idle_secs() * 1e3,
                        m.overlap_hidden.as_secs_f64() * 1e3,
                        m.comm.overlapped_messages,
                        m.comm.messages,
                    ),
                });
            }
            Err(e) => rows.push(Row {
                label: label.into(),
                gstencils: 0.0,
                speedup: 0.0,
                extra: format!("ERROR: {e}"),
            }),
        }
    }
    print_table("§5.3 overlap: pipelined vs serial leader loop (heat2d, 3:1 split)", &rows);
    vec![("overlap".to_string(), rows)]
}

/// The overlap study's single source of configuration: heat2d input and
/// the imbalanced 2-worker scheduler (`run_overlap` rows and the
/// `overlap_idle_ms` acceptance probe must measure the same setup).
fn overlap_bench_field(scale: f64) -> Field {
    let (core_shape, _, _) = scaled_problem("heat2d", scale);
    Field::random(&core_shape, 0x0E21)
}

fn overlap_bench_sched(scale: f64, threads: usize, overlap: Overlap) -> Scheduler {
    let s = spec::get("heat2d").unwrap();
    let (core_shape, _, tb) = scaled_problem("heat2d", scale);
    let rows0 = core_shape[0];
    Scheduler {
        spec: s,
        tb,
        workers: vec![native("tetris-cpu", threads), native("naive", 1)],
        partition: Partition::balanced(1, rows0, &[3.0, 1.0], &[rows0, rows0]),
        comm_model: CommModel::default(),
        boundary: Boundary::Periodic,
        adapt_every: 0,
        overlap,
    }
}

/// Summed worker idle (ms) for one overlap mode on the `run_overlap`
/// configuration — the comparison the overlap bench acceptance test
/// retries (timing-based, so callers take the best of a few attempts).
pub fn overlap_idle_ms(scale: f64, threads: usize, overlap: Overlap) -> Result<f64> {
    let (_, steps, _) = scaled_problem("heat2d", scale);
    let core = overlap_bench_field(scale);
    let (_, m) = overlap_bench_sched(scale, threads, overlap).run(&core, steps)?;
    Ok(m.summed_idle_secs() * 1e3)
}

/// §5.3 communication study: centralized vs per-step launch cost.
pub fn run_comm() -> Vec<Row> {
    let m = CommModel::default();
    let mut rows = Vec::new();
    for tb in [1usize, 2, 4, 8, 16, 32] {
        // Halo bytes for the heat2d thermal grid: 2 sides x r*Tb x width x 8.
        let bytes = 2 * tb * 392 * 8;
        let (central, split) = m.centralized_vs_split(bytes, tb);
        rows.push(Row {
            label: format!("Tb={tb}"),
            gstencils: 0.0,
            speedup: split / central,
            extra: format!(
                "central {:.1}us vs per-step {:.1}us ({} B)",
                central * 1e6,
                split * 1e6,
                bytes
            ),
        });
    }
    print_table("§5.3 centralized communication launch (modeled)", &rows);
    rows
}

/// MXU study: trapezoid-folding artifact vs VPU step artifact + estimates.
pub fn run_mxu(rt: &XlaService) -> Result<Vec<Row>> {
    let mut rows = Vec::new();
    for bench in ["heat2d", "star2d9p", "box2d9p", "box2d25p"] {
        let meta = rt.bench(bench)?.clone();
        for variant in ["step", "mxu"] {
            let name = format!("{bench}_{variant}");
            let ameta = rt.meta(&name)?.clone();
            let input = Field::random(&ameta.input_shape, 0xC0FFEE);
            let d = timer::time_median(1, 3, || rt.run(&name, &input).unwrap());
            let cells: usize = ameta.unit_core.iter().product();
            let g = timer::gstencils_per_sec(cells, ameta.steps, d);
            let est = crate::model::mxu_estimate(
                meta.flops_per_cell,
                meta.radius,
                2 * meta.radius + 1,
                meta.unit,
                meta.global_core[1],
            );
            rows.push(Row {
                label: name,
                gstencils: g,
                speedup: 0.0,
                extra: if variant == "mxu" {
                    format!("est. MXU util {:.3}, VMEM {:.1}%", est.mxu_utilization, est.vmem_fraction * 100.0)
                } else {
                    String::new()
                },
            });
        }
    }
    let base = rows.iter().map(|r| r.gstencils).fold(f64::INFINITY, f64::min);
    for r in &mut rows {
        r.speedup = r.gstencils / base;
    }
    print_table("MXU trapezoid folding vs VPU step (CPU-PJRT timings)", &rows);
    Ok(rows)
}

/// Single-line JSON summary of a bench run — the CI artifact format
/// written by `tetris bench <which> --json FILE` / scripts/bench_smoke.sh.
pub fn summary_json(which: &str, scale: f64, threads: usize, sections: &[(String, Vec<Row>)]) -> Json {
    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str(which.to_string()));
    top.insert("scale".to_string(), Json::Num(scale));
    top.insert("threads".to_string(), Json::Num(threads as f64));
    let mut secs = BTreeMap::new();
    for (name, rows) in sections {
        let arr: Vec<Json> = rows
            .iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("label".to_string(), Json::Str(r.label.clone()));
                m.insert("gstencils_per_sec".to_string(), Json::Num(r.gstencils));
                m.insert("speedup".to_string(), Json::Num(r.speedup));
                if !r.extra.is_empty() {
                    m.insert("extra".to_string(), Json::Str(r.extra.clone()));
                }
                Json::Obj(m)
            })
            .collect();
        secs.insert(name.clone(), Json::Arr(arr));
    }
    top.insert("sections".to_string(), Json::Obj(secs));
    Json::Obj(top)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_problem_covers_all() {
        for s in spec::benchmarks() {
            let (core, steps, tb) = scaled_problem(s.name, 0.1);
            assert_eq!(core.len(), s.ndim);
            assert_eq!(steps % tb, 0, "{}", s.name);
        }
    }

    #[test]
    fn time_engine_positive() {
        let s = spec::get("heat1d").unwrap();
        let eng = crate::engine::by_name("simd", 1).unwrap();
        let (g, d) = time_engine(eng.as_ref(), &s, &[128], 4, 2);
        assert!(g > 0.0 && d.as_nanos() > 0);
    }

    /// Regression guard for the face-wise rewrite: growing the domain
    /// 64x in volume grows the ghost ring only ~8x, so the fill time
    /// ratio must stay far below the volume ratio.  The old per-cell
    /// full-domain scan (with a `Vec` allocation per ghost cell) sat at
    /// ~the volume ratio and trips this bound.
    #[test]
    fn ghost_fill_scales_with_surface_not_volume() {
        let halo = 2usize;
        let time_fill = |n: usize| {
            let mut ext = Field::random(&[n + 2 * halo, n + 2 * halo], 5);
            timer::time_median(1, 5, || {
                for _ in 0..8 {
                    Boundary::Periodic.fill(&mut ext, halo);
                }
            })
        };
        let small = time_fill(64).as_secs_f64().max(1e-9);
        let big = time_fill(512).as_secs_f64().max(1e-9);
        assert!(
            big / small < 32.0,
            "ghost fill not O(surface): {small}s -> {big}s ({}x)",
            big / small
        );
    }

    #[test]
    fn boundary_section_has_all_rungs() {
        let sections = run_boundary(0.05, 1);
        assert_eq!(sections.len(), 2);
        let (name, rows) = &sections[1];
        assert_eq!(name, "heat2d");
        let labels: Vec<&str> = rows.iter().map(|r| r.label.as_str()).collect();
        assert_eq!(labels, vec!["dirichlet", "neumann", "periodic", "periodic+adapt2"]);
        assert!(rows.iter().all(|r| r.gstencils > 0.0), "{rows:?}");
        // and it serializes into the CI artifact format
        let j = summary_json("boundary", 0.05, 1, &sections);
        assert!(j.to_string().contains("periodic+adapt2"));
    }

    /// Serving acceptance: on the same 8-job mix, the batched (>= 4)
    /// session throughput beats unbatched — the multi-field dispatch
    /// amortizes per-block pool spawns and bookkeeping.  Timing-based,
    /// so take the best of a few attempts before judging.
    #[test]
    fn serve_bench_batched_beats_unbatched() {
        let mut best_ratio = 0.0f64;
        for _ in 0..3 {
            let sections = run_serve(0.03, 1);
            let rows = &sections[0].1;
            assert_eq!(rows[0].label, "batch=1");
            assert_eq!(rows[1].label, "batch=4");
            assert!(rows.iter().all(|r| r.gstencils > 0.0), "{rows:?}");
            best_ratio = best_ratio.max(rows[1].gstencils / rows[0].gstencils);
            if best_ratio > 1.0 {
                break;
            }
        }
        assert!(
            best_ratio > 1.0,
            "batch=4 never beat batch=1 (best ratio {best_ratio:.3})"
        );
    }

    #[test]
    fn serve_summary_json_records_batching() {
        let sections = run_serve(0.03, 1);
        let j = summary_json("serve", 0.03, 1, &sections);
        let text = j.to_string();
        assert!(!text.contains('\n'));
        let back = Json::parse(&text).unwrap();
        let batching = back.at(&["sections", "session-batching"]).as_arr().unwrap();
        assert_eq!(batching[0].at(&["label"]).as_str(), Some("batch=1"));
        assert!(batching[0].at(&["extra"]).as_str().unwrap().contains("jobs/sec"));
        let loopback = back.at(&["sections", "tcp-loopback"]).as_arr().unwrap();
        assert!(loopback[0].at(&["extra"]).as_str().unwrap().contains("p99"));
    }

    /// The plan section must produce a real `auto` rung (plan resolved,
    /// engine timed) next to the fixed rows, and serialize for CI; a
    /// second pass over the same store must report a cache hit.
    #[test]
    fn plan_section_resolves_auto_and_hits_cache_on_rerun() {
        let path = std::env::temp_dir()
            .join(format!("tetris-bench-plan-test-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let p = path.to_string_lossy().into_owned();
        let sections = run_plan(0.03, 1, Some(&p));
        assert_eq!(sections.len(), 2);
        for (name, rows) in &sections {
            assert_eq!(rows.len(), 3, "{name}: {rows:?}");
            let auto = rows.iter().find(|r| r.label == "auto").unwrap();
            assert!(auto.gstencils > 0.0, "{name}: {auto:?}");
            assert!(auto.extra.contains("plan:"), "{name}: {auto:?}");
        }
        let j = summary_json("plan", 0.03, 1, &sections);
        assert!(j.to_string().contains("auto"));
        // same store, second run: both benches resolve from cache
        let again = run_plan(0.03, 1, Some(&p));
        for (name, rows) in &again {
            let auto = rows.iter().find(|r| r.label == "auto").unwrap();
            assert!(auto.extra.contains("cached"), "{name}: {auto:?}");
        }
        let _ = std::fs::remove_file(&path);
    }

    /// §5.3 acceptance: on the imbalanced 2-worker run, the pipelined
    /// leader loop reduces summed worker idle (workers x elapsed − Σ
    /// busy) vs the serial loop — the fast worker no longer sits
    /// through the leader's ghost/extract/paste phases.  Timing-based,
    /// so take the best of a few attempts before judging.
    #[test]
    fn overlap_bench_reduces_summed_worker_idle() {
        let mut best_ratio = f64::INFINITY;
        // single-thread engines keep the comparison about the leader
        // loop, not pool-vs-engine thread oversubscription on small CI
        // runners
        for _ in 0..5 {
            let off = overlap_idle_ms(0.15, 1, Overlap::Off).unwrap();
            let on = overlap_idle_ms(0.15, 1, Overlap::On).unwrap();
            assert!(off > 0.0 && on > 0.0, "idle must be measurable: off={off} on={on}");
            best_ratio = best_ratio.min(on / off);
            if best_ratio < 1.0 {
                break;
            }
        }
        assert!(
            best_ratio < 1.0,
            "pipelined leader loop never reduced summed idle (best on/off ratio {best_ratio:.3})"
        );
    }

    #[test]
    fn overlap_section_reports_both_modes() {
        let sections = run_overlap(0.05, 1);
        assert_eq!(sections.len(), 1);
        let rows = &sections[0].1;
        assert_eq!(rows[0].label, "overlap=off");
        assert_eq!(rows[1].label, "overlap=on");
        assert!(rows.iter().all(|r| r.gstencils > 0.0), "{rows:?}");
        assert!(rows[0].extra.contains("summed idle"), "{rows:?}");
        let j = summary_json("overlap", 0.05, 1, &sections);
        assert!(j.to_string().contains("overlap=on"));
    }

    #[test]
    fn comm_rows_monotone() {
        let rows = run_comm();
        // centralized advantage grows with Tb
        for w in rows.windows(2) {
            assert!(w[1].speedup >= w[0].speedup);
        }
    }

    #[test]
    fn print_table_formats() {
        let s = print_table(
            "t",
            &[Row { label: "x".into(), gstencils: 1.0, speedup: 2.0, extra: "e".into() }],
        );
        assert!(s.contains("GStencils/s"));
        assert!(s.contains("2.00x"));
    }

    #[test]
    fn summary_json_is_single_line_and_parses() {
        let sections = vec![(
            "heat2d".to_string(),
            vec![Row { label: "naive".into(), gstencils: 0.25, speedup: 1.0, extra: String::new() }],
        )];
        let j = summary_json("breakdown", 0.1, 2, &sections);
        let text = j.to_string();
        assert!(!text.contains('\n'));
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.at(&["bench"]).as_str(), Some("breakdown"));
        assert_eq!(back.at(&["sections", "heat2d"]).as_arr().unwrap().len(), 1);
        assert_eq!(
            back.at(&["sections", "heat2d"]).as_arr().unwrap()[0].at(&["label"]).as_str(),
            Some("naive")
        );
    }
}
