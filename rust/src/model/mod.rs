//! Analytical performance models: roofline + TPU kernel estimates.
//!
//! Rust mirror of `python/compile/kernels/vmem.py` (same constants, same
//! arithmetic) so the scheduler and the benches can reason about the
//! Pallas kernels' structure without Python.  `interpret=True` timings
//! are CPU-numpy and not a TPU proxy — these estimates are the documented
//! basis for the DESIGN.md real-TPU performance discussion.

/// Per-core VMEM on contemporary TPU (v4/v5p), bytes.
pub const VMEM_BYTES: usize = 16 * 1024 * 1024;
/// MXU systolic edge.
pub const MXU_EDGE: usize = 128;
/// HBM bandwidth proxy (B/s) for roofline ratios.
pub const HBM_BW: f64 = 1.2e12;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KernelEstimate {
    pub vmem_bytes: usize,
    pub vmem_fraction: f64,
    pub flops_per_cell: usize,
    pub hbm_bytes_per_cell: f64,
    pub arithmetic_intensity: f64,
    pub mxu_utilization: f64,
}

impl KernelEstimate {
    pub fn fits(&self) -> bool {
        self.vmem_fraction <= 1.0
    }

    /// Memory-bound roofline throughput in GStencils/s at `HBM_BW`.
    pub fn roofline_gstencils(&self) -> f64 {
        HBM_BW / self.hbm_bytes_per_cell / 1e9
    }
}

/// Estimate for the Tb-fused temporal-block kernel (VPU path) — mirrors
/// `vmem.temporal_estimate`.
pub fn temporal_estimate(
    flops_per_cell: usize,
    radius: usize,
    tiles: &[usize],
    steps: usize,
) -> KernelEstimate {
    let itemsize = 8usize;
    let halo = radius * steps;
    let window: usize = tiles.iter().map(|t| t + 2 * halo).product();
    let out: usize = tiles.iter().product();
    let scratch: usize = tiles.iter().map(|t| t + 2 * radius * (steps - 1)).product();
    let vmem = (window + 2 * scratch) * itemsize;
    let flops = flops_per_cell * steps;
    let hbm = itemsize as f64 * (window as f64 / out as f64 + 1.0);
    KernelEstimate {
        vmem_bytes: vmem,
        vmem_fraction: vmem as f64 / VMEM_BYTES as f64,
        flops_per_cell: flops,
        hbm_bytes_per_cell: hbm,
        arithmetic_intensity: flops as f64 / hbm,
        mxu_utilization: 0.0,
    }
}

/// Estimate for the trapezoid-folding banded-matmul kernel — mirrors
/// `vmem.mxu_estimate`.
pub fn mxu_estimate(
    flops_per_cell: usize,
    radius: usize,
    dx_slabs: usize,
    tile_m: usize,
    ny: usize,
) -> KernelEstimate {
    let itemsize = 8usize;
    let r = radius;
    let issued = dx_slabs * tile_m * (ny + 2 * r) * ny * 2;
    let useful = flops_per_cell * tile_m * ny;
    let pad = (tile_m.div_ceil(MXU_EDGE) * MXU_EDGE) as f64 / tile_m as f64
        * (ny.div_ceil(MXU_EDGE) * MXU_EDGE) as f64 / ny as f64;
    let window = (tile_m + 2 * r) * (ny + 2 * r);
    let bands = (2 * r + 1) * (ny + 2 * r) * ny;
    let vmem = (window + bands + 2 * tile_m * ny) * itemsize;
    let hbm = itemsize as f64 * (window as f64 / (tile_m * ny) as f64 + 1.0);
    KernelEstimate {
        vmem_bytes: vmem,
        vmem_fraction: vmem as f64 / VMEM_BYTES as f64,
        flops_per_cell,
        hbm_bytes_per_cell: hbm,
        arithmetic_intensity: issued as f64 / (tile_m * ny) as f64 / hbm,
        mxu_utilization: (useful as f64 / issued as f64) / pad,
    }
}

/// Host-side roofline: measured GStencils/s / memory-bound bound given a
/// measured stream bandwidth (B/s).  The paper-efficiency figure the
/// §Perf pass tracks.
pub fn roofline_efficiency(
    gstencils: f64,
    bytes_per_cell_step: f64,
    stream_bw: f64,
) -> f64 {
    let bound = stream_bw / bytes_per_cell_step / 1e9;
    gstencils / bound
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn temporal_matches_python_example() {
        // Same numbers as python/tests/test_vmem.py expectations.
        let e1 = temporal_estimate(10, 1, &[64, 64], 1);
        let e8 = temporal_estimate(10, 1, &[64, 64], 8);
        assert_eq!(e8.flops_per_cell, 8 * e1.flops_per_cell);
        assert!(e8.hbm_bytes_per_cell < 2.0 * e1.hbm_bytes_per_cell);
        assert!(e8.arithmetic_intensity > 4.0 * e1.arithmetic_intensity);
        assert!(e1.fits() && e8.fits());
    }

    #[test]
    fn mxu_utilization_matches_python() {
        // box2d25p: flops 50, r=2, 5 slabs, 128x128 tile.
        let e = mxu_estimate(50, 2, 5, 128, 128);
        let want = (50.0 * 128.0 * 128.0) / (5.0 * 128.0 * 132.0 * 128.0 * 2.0);
        assert!((e.mxu_utilization - want).abs() < 1e-12);
        assert!(e.mxu_utilization > 0.0 && e.mxu_utilization < 1.0);
    }

    #[test]
    fn roofline_positive() {
        let e = temporal_estimate(10, 1, &[64, 256], 4);
        assert!(e.roofline_gstencils() > 0.0);
    }

    #[test]
    fn efficiency_is_ratio() {
        // 1 GStencil/s against a 16 B/cell, 16 GB/s machine => bound 1.0
        let eff = roofline_efficiency(0.5, 16.0, 16e9);
        assert!((eff - 0.5).abs() < 1e-12);
    }
}
