//! State-of-the-art baseline engines for the Fig-13 comparison.
//!
//! Each module reimplements the *algorithmic strategy* of one comparator
//! from paper Table 2, sharing the same [`Engine`] contract so the bench
//! harness sweeps them uniformly.  These are honest analogues, not
//! strawmen: each uses the best inner loop its strategy admits.
//!
//! | Module     | Paper row              | Strategy reproduced              |
//! |------------|------------------------|----------------------------------|
//! | datareorg  | Data Reorg. [64]       | split tiling + lane reorg passes |
//! | pluto      | Pluto [7]              | diamond/time-skewed tiling       |
//! | folding    | Folding [34]           | in-register reuse, per-step      |
//! | brick      | Brick [66]             | fixed micro-brick layout         |
//! | an5d       | AN5D [37]              | overlapped (redundant) temporal  |
//!
//! ("Auto Vec." is `engine::autovec`; Tetris rows are `engine::*` and the
//! XLA workers.)

pub mod an5d;
pub mod brick;
pub mod datareorg;
pub mod folding;
pub mod pluto;

use crate::engine::Engine;

/// Baseline registry by paper name.
pub fn by_name(name: &str) -> Option<Box<dyn Engine>> {
    match name {
        "datareorg" => Some(Box::new(datareorg::DataReorgEngine)),
        "pluto" => Some(Box::new(pluto::PlutoEngine::default())),
        "folding" => Some(Box::new(folding::FoldingEngine)),
        "brick" => Some(Box::new(brick::BrickEngine::default())),
        "an5d" => Some(Box::new(an5d::An5dEngine::default())),
        _ => None,
    }
}

pub const BASELINE_NAMES: &[&str] = &["datareorg", "pluto", "folding", "brick", "an5d"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{reference, spec, Field};

    /// Every baseline must agree with the oracle on every benchmark.
    #[test]
    fn baselines_match_reference() {
        for name in BASELINE_NAMES {
            let eng = by_name(name).unwrap();
            for s in spec::benchmarks() {
                for steps in [1usize, 3] {
                    let ext: Vec<usize> =
                        (0..s.ndim).map(|_| 9 + 2 * s.radius * steps).collect();
                    let u = Field::random(&ext, 31);
                    let got = eng.block(&s, &u, steps);
                    let want = reference::block(&u, &s, steps);
                    assert!(
                        got.allclose(&want, 1e-12, 1e-14),
                        "{name} vs ref: {} steps={steps} maxdiff={}",
                        s.name,
                        got.max_abs_diff(&want)
                    );
                }
            }
        }
    }
}
