//! AN5D baseline (Matsumura et al. [37]): high-degree temporal blocking
//! with *overlapped* (redundant) halos.
//!
//! Each dim-0 tile independently loads tile + `radius*Tb` halo and
//! advances Tb steps locally — the GPU-style associative temporal
//! blocking AN5D generates.  Unlike tessellation, the overlap regions are
//! recomputed by both neighbouring tiles (the redundancy the paper's §4.1
//! eliminates); unlike Tetris (GPU) there is no MXU mapping.

use crate::engine::{rowwise, Engine, FlatTaps};
use crate::stencil::{Field, StencilSpec};

pub struct An5dEngine {
    /// Tile width along dim 0 (output cells per tile).
    pub tile_w: usize,
    pub threads: usize,
}

impl Default for An5dEngine {
    fn default() -> Self {
        An5dEngine { tile_w: 256, threads: 1 }
    }
}

impl Engine for An5dEngine {
    fn name(&self) -> &'static str {
        "an5d"
    }

    fn preferred_tb(&self) -> usize {
        4
    }

    fn block(&self, spec: &StencilSpec, input: &Field, steps: usize) -> Field {
        let r = spec.radius;
        let halo = r * steps;
        let ext = input.shape().to_vec();
        let core: Vec<usize> = ext.iter().map(|n| n - 2 * halo).collect();
        let mut out = Field::zeros(&core);
        let tile_w = self.tile_w.max(1);
        let ntiles = core[0].div_ceil(tile_w);
        let results: Vec<(usize, Field)> = crate::engine::parallel_map(
            self.threads,
            ntiles,
            |k| {
                let x0 = k * tile_w;
                let x1 = ((k + 1) * tile_w).min(core[0]);
                // Load tile + full halo (the overlapped/redundant read).
                let mut off = vec![x0];
                off.extend(vec![0usize; ext.len() - 1]);
                let mut shape = vec![(x1 - x0) + 2 * halo];
                shape.extend(ext[1..].iter().copied());
                let mut cur = input.extract(&off, &shape);
                for _ in 0..steps {
                    let taps = FlatTaps::build(spec, cur.shape());
                    cur = rowwise::fused_step(&cur, spec, &taps);
                }
                (x0, cur)
            },
        );
        for (x0, f) in results {
            let mut off = vec![x0];
            off.extend(vec![0usize; ext.len() - 1]);
            out.paste(&off, &f);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{reference, spec};

    #[test]
    fn matches_reference() {
        for name in ["heat1d", "box2d25p", "heat3d"] {
            let s = spec::get(name).unwrap();
            let eng = An5dEngine { tile_w: 6, threads: 2 };
            let ext: Vec<usize> = (0..s.ndim).map(|_| 14 + 2 * s.radius * 3).collect();
            let u = Field::random(&ext, 51);
            let got = eng.block(&s, &u, 3);
            let want = reference::block(&u, &s, 3);
            assert!(got.allclose(&want, 1e-13, 1e-15), "{name}");
        }
    }

    #[test]
    fn uneven_last_tile() {
        let s = spec::get("heat1d").unwrap();
        let eng = An5dEngine { tile_w: 7, threads: 1 };
        let u = Field::random(&[33], 52); // core 29 = 4*7 + 1
        let got = eng.block(&s, &u, 2);
        assert!(got.allclose(&reference::block(&u, &s, 2), 1e-14, 0.0));
    }
}
