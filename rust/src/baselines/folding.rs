//! Folding baseline (Li et al. [34]): in-register data reuse without
//! layout transposes.
//!
//! Reproduces the strategy of folding neighbouring loads into running
//! partial sums so each input element is loaded once per row sweep: for
//! star kernels the symmetric taps are folded as `c * (left + right)`
//! pairs before scaling (halving the multiplies), computed row by row
//! with a single write pass.  No temporal tiling — the gap Tetris's
//! tessellation closes (paper §6.3: Tetris(CPU) beats Folding by ~21%).

use crate::engine::{rowwise, Engine, FlatTaps};
use crate::stencil::{Field, Kind, StencilSpec};

pub struct FoldingEngine;

impl Engine for FoldingEngine {
    fn name(&self) -> &'static str {
        "folding"
    }

    fn block(&self, spec: &StencilSpec, input: &Field, steps: usize) -> Field {
        let mut cur = input.clone();
        for _ in 0..steps {
            cur = fold_step(&cur, spec);
        }
        cur
    }
}

/// One valid step with symmetric-pair folding.
fn fold_step(src: &Field, spec: &StencilSpec) -> Field {
    let r = spec.radius;
    let core: Vec<usize> = src.shape().iter().map(|n| n - 2 * r).collect();
    let w = *core.last().unwrap();
    let mut out = Field::zeros(&core);

    // Pair up symmetric taps (off, -off) with equal coefficients; the
    // remainder (centre tap, or unequal pairs) stays unpaired.
    let (offs, cs) = spec.taps();
    let taps = FlatTaps::build(spec, src.shape());
    let mut paired: Vec<(isize, isize, f64)> = Vec::new(); // (fa, fb, c)
    let mut single: Vec<(isize, f64)> = Vec::new();
    let mut used = vec![false; offs.len()];
    for i in 0..offs.len() {
        if used[i] {
            continue;
        }
        let neg: Vec<i64> = offs[i].iter().map(|o| -o).collect();
        if neg != offs[i] {
            if let Some(j) = offs.iter().position(|o| *o == neg) {
                if !used[j] && (cs[i] - cs[j]).abs() < 1e-15 {
                    used[i] = true;
                    used[j] = true;
                    paired.push((taps.offs[i], taps.offs[j], cs[i]));
                    continue;
                }
            }
        }
        used[i] = true;
        single.push((taps.offs[i], cs[i]));
    }
    debug_assert!(
        spec.kind != Kind::Star || paired.len() * 2 + single.len() == offs.len()
    );

    let sdata = src.data();
    let odata = out.data_mut();
    const BLK: usize = 8;
    rowwise::for_each_row(src.shape(), &core, |dst0, src0| {
        let dst_row = &mut odata[dst0..dst0 + w];
        let mut x = 0usize;
        while x + BLK <= w {
            let mut acc = [0.0f64; BLK];
            // Folded pairs: one multiply per pair.
            for (fa, fb, c) in &paired {
                let a = (src0 as isize + fa) as usize + x;
                let b = (src0 as isize + fb) as usize + x;
                let sa = &sdata[a..a + BLK];
                let sb = &sdata[b..b + BLK];
                for j in 0..BLK {
                    acc[j] += c * (sa[j] + sb[j]);
                }
            }
            for (f, c) in &single {
                let a = (src0 as isize + f) as usize + x;
                let sa = &sdata[a..a + BLK];
                for j in 0..BLK {
                    acc[j] += c * sa[j];
                }
            }
            dst_row[x..x + BLK].copy_from_slice(&acc);
            x += BLK;
        }
        while x < w {
            let mut acc = 0.0;
            for (fa, fb, c) in &paired {
                acc += c
                    * (sdata[(src0 as isize + fa) as usize + x]
                        + sdata[(src0 as isize + fb) as usize + x]);
            }
            for (f, c) in &single {
                acc += c * sdata[(src0 as isize + f) as usize + x];
            }
            dst_row[x] = acc;
            x += 1;
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{reference, spec};

    #[test]
    fn matches_reference_all() {
        for s in spec::benchmarks() {
            let ext: Vec<usize> = (0..s.ndim).map(|_| 13 + 2 * s.radius * 2).collect();
            let u = Field::random(&ext, 61);
            let got = FoldingEngine.block(&s, &u, 2);
            let want = reference::block(&u, &s, 2);
            assert!(got.allclose(&want, 1e-12, 1e-14), "{}", s.name);
        }
    }

    #[test]
    fn symmetric_taps_actually_fold() {
        // heat2d has 2 symmetric pairs + centre: the fold halves multiplies.
        let s = spec::get("heat2d").unwrap();
        let u = Field::random(&[10, 10], 62);
        let got = fold_step(&u, &s);
        assert!(got.allclose(&reference::step(&u, &s), 1e-13, 0.0));
    }
}
