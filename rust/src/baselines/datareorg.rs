//! Data Reorganization baseline (Yuan et al. [64], paper Table 2 row 1).
//!
//! Strategy: before each sweep, reorganize the row data into a
//! lane-major (SoA) layout so vector lanes read stride-1; compute; then
//! reorganize back.  The transposes buy alignment-conflict-free inner
//! loops at the price of two extra passes over the data per step — the
//! overhead Tetris's skewed swizzling eliminates (paper §3.1).

use crate::engine::{rowwise, Engine, FlatTaps};
use crate::stencil::{Field, StencilSpec};

pub struct DataReorgEngine;

const LANES: usize = 4;

/// Reorganize a row into lane-major order: [a0 a1 a2 a3 a4 ..] ->
/// [a0 a4 a8 .. | a1 a5 .. | a2 .. | a3 ..] (pad ignored by callers).
fn to_lanes(row: &[f64], scratch: &mut Vec<f64>) {
    scratch.clear();
    for l in 0..LANES {
        scratch.extend(row.iter().skip(l).step_by(LANES));
    }
}

fn from_lanes(scratch: &[f64], row: &mut [f64]) {
    let n = row.len();
    let per = n.div_ceil(LANES);
    let mut k = 0;
    for l in 0..LANES {
        let cnt = (n - l).div_ceil(LANES);
        for i in 0..cnt {
            row[l + i * LANES] = scratch[k];
            k += 1;
        }
        let _ = per;
    }
}

impl Engine for DataReorgEngine {
    fn name(&self) -> &'static str {
        "datareorg"
    }

    fn block(&self, spec: &StencilSpec, input: &Field, steps: usize) -> Field {
        let r = spec.radius;
        let mut cur = input.clone();
        let mut scratch = Vec::new();
        for _ in 0..steps {
            let ext = cur.shape().to_vec();
            let core: Vec<usize> = ext.iter().map(|n| n - 2 * r).collect();
            let taps = FlatTaps::build(spec, &ext);
            let w = *core.last().unwrap();
            let mut out = Field::zeros(&core);

            // The reorganization passes: lane-split each source row and
            // restore it (the compute itself reads the original layout —
            // the reorg models [64]'s pre/post data-layout transforms).
            let mut reorg = cur.clone();
            {
                let data = reorg.data_mut();
                let ext_w = *ext.last().unwrap();
                let rows = data.len() / ext_w;
                for row_i in 0..rows {
                    let row = &mut data[row_i * ext_w..(row_i + 1) * ext_w];
                    to_lanes(row, &mut scratch);
                    from_lanes(&scratch, row);
                }
            }

            let sdata = reorg.data();
            let odata = out.data_mut();
            rowwise::for_each_row(&ext, &core, |dst0, src0| {
                let dst_row = &mut odata[dst0..dst0 + w];
                for (off, c) in taps.offs.iter().zip(&taps.coeffs) {
                    let s0 = (src0 as isize + off) as usize;
                    rowwise::axpy(dst_row, *c, &sdata[s0..s0 + w]);
                }
            });
            cur = out;
        }
        cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{reference, spec};

    #[test]
    fn lane_roundtrip() {
        for n in [4usize, 7, 12, 13] {
            let row: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let mut scratch = Vec::new();
            to_lanes(&row, &mut scratch);
            let mut back = vec![0.0; n];
            from_lanes(&scratch, &mut back);
            assert_eq!(back, row, "n={n}");
        }
    }

    #[test]
    fn matches_reference() {
        let s = spec::get("star1d5p").unwrap();
        let u = Field::random(&[37], 5);
        let got = DataReorgEngine.block(&s, &u, 2);
        assert!(got.allclose(&reference::block(&u, &s, 2), 1e-13, 0.0));
    }
}
