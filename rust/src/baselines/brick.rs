//! Brick baseline (Zhao et al. [66]): fixed-size micro-brick layout.
//!
//! The domain is processed through fixed 8-wide bricks: each brick (plus
//! its ghost cells) is copied into a small contiguous buffer, updated
//! there, and copied back.  Bricks give excellent locality for complex
//! kernels but pay per-brick copy overhead and (like Folding/AutoVec)
//! have no temporal reuse across steps — and per the paper they run CPU
//! and GPU paths separately rather than coordinating them.

use crate::engine::{Engine, FlatTaps};
use crate::stencil::{Field, StencilSpec};

pub struct BrickEngine {
    pub brick: usize,
}

impl Default for BrickEngine {
    fn default() -> Self {
        BrickEngine { brick: 8 }
    }
}

impl Engine for BrickEngine {
    fn name(&self) -> &'static str {
        "brick"
    }

    fn block(&self, spec: &StencilSpec, input: &Field, steps: usize) -> Field {
        let r = spec.radius;
        let mut cur = input.clone();
        for _ in 0..steps {
            let ext = cur.shape().to_vec();
            let core: Vec<usize> = ext.iter().map(|n| n - 2 * r).collect();
            let mut out = Field::zeros(&core);
            // Brick grid over the core.
            let b = self.brick;
            let nbricks: Vec<usize> = core.iter().map(|n| n.div_ceil(b)).collect();
            let total: usize = nbricks.iter().product();
            let mut bid = vec![0usize; core.len()];
            for _ in 0..total {
                // Brick core region.
                let off: Vec<usize> = bid.iter().map(|&i| i * b).collect();
                let shape: Vec<usize> = off
                    .iter()
                    .zip(&core)
                    .map(|(&o, &n)| b.min(n - o))
                    .collect();
                // Copy brick + ghosts into the contiguous brick buffer.
                let gshape: Vec<usize> = shape.iter().map(|n| n + 2 * r).collect();
                let buf = cur.extract(&off, &gshape);
                let taps = FlatTaps::build(spec, &gshape);
                let mut bout = Field::zeros(&shape);
                brick_update(&buf, &mut bout, &taps);
                out.paste(&off, &bout);
                for k in (0..bid.len()).rev() {
                    bid[k] += 1;
                    if bid[k] < nbricks[k] {
                        break;
                    }
                    bid[k] = 0;
                }
            }
            cur = out;
        }
        cur
    }
}

/// Scalar update of one brick buffer (buffers are tiny: stays in L1).
fn brick_update(buf: &Field, out: &mut Field, taps: &FlatTaps) {
    let core = out.shape().to_vec();
    let w = *core.last().unwrap();
    let bdata = buf.data();
    let odata = out.data_mut();
    crate::engine::rowwise::for_each_row(buf.shape(), &core, |dst0, src0| {
        crate::engine::rowwise::fused_row(&mut odata[dst0..dst0 + w], bdata, src0, taps);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{reference, spec};

    #[test]
    fn matches_reference_all() {
        for s in spec::benchmarks() {
            let eng = BrickEngine { brick: 4 };
            let ext: Vec<usize> = (0..s.ndim).map(|_| 11 + 2 * s.radius * 2).collect();
            let u = Field::random(&ext, 71);
            let got = eng.block(&s, &u, 2);
            let want = reference::block(&u, &s, 2);
            assert!(got.allclose(&want, 1e-12, 1e-14), "{}", s.name);
        }
    }

    #[test]
    fn non_divisible_core() {
        let s = spec::get("heat1d").unwrap();
        let eng = BrickEngine { brick: 8 };
        let u = Field::random(&[23], 72); // core 21 = 2*8 + 5
        let got = eng.block(&s, &u, 1);
        assert!(got.allclose(&reference::step(&u, &s), 1e-14, 0.0));
    }
}
