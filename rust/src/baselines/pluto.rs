//! Pluto baseline (Bandishti et al. [7]): diamond/time-skewed tiling.
//!
//! Reproduces the polyhedral time-skewing strategy: the (t, x) iteration
//! space is tiled with a skew of `radius` per step so each tile's
//! dependences point into already-computed tiles; tiles execute in a
//! sequential wavefront.  Temporal reuse is real (like tessellation) but
//! the skew serializes inter-tile execution along dim 0 and the inner
//! loop stays tap-outer — the two gaps Tetris closes.

use crate::engine::{rowwise, Engine, FlatTaps};
use crate::stencil::{Field, StencilSpec};

pub struct PlutoEngine {
    /// Tile width along dim 0 (pre-skew).
    pub tile_w: usize,
}

impl Default for PlutoEngine {
    fn default() -> Self {
        PlutoEngine { tile_w: 128 }
    }
}

impl Engine for PlutoEngine {
    fn name(&self) -> &'static str {
        "pluto"
    }

    fn preferred_tb(&self) -> usize {
        4
    }

    fn block(&self, spec: &StencilSpec, input: &Field, steps: usize) -> Field {
        let r = spec.radius;
        // Time-skewed execution over a persistent extended buffer: we
        // keep `steps + 1` time levels alive in a rolling window, sweep
        // skewed tiles left-to-right; within a tile we advance each level
        // over the tile's skewed x-range.  A faithful-but-simple
        // realization: maintain full level arrays (the "rolling window"
        // over time) and update them tile by tile with the skew.
        let ext = input.shape().to_vec();
        let mut levels: Vec<Field> = vec![input.clone()];
        for t in 1..=steps {
            let shape: Vec<usize> = ext.iter().map(|n| n - 2 * r * t).collect();
            levels.push(Field::zeros(&shape));
        }
        let ext0 = ext[0];
        let tile_w = self.tile_w.max(2 * r * steps + 1);
        // Wavefront over skewed tiles: tile k covers x in
        // [k*w - r*t, (k+1)*w - r*t) at level t (intersected with the
        // level's valid range) — dependences resolved because level t-1
        // of that range was produced by tiles k and k-1 (already done).
        let ntiles = ext0.div_ceil(tile_w);
        // Extra trailing tiles so the left-shifted ranges still cover the
        // right edge at the deepest level (shift reaches 2*r*steps).
        let extra = (2 * r * steps).div_ceil(tile_w) + 1;
        for k in 0..ntiles + extra {
            for t in 1..=steps {
                // Level-t valid range (in level-t local coordinates, which
                // start at ext coordinate r*t).
                let lvl_len = ext0 as i64 - 2 * (r * t) as i64;
                if lvl_len <= 0 {
                    continue;
                }
                // Skew: level t shifts LEFT by 2r per level so the
                // dependence window [x, x+2r] at level t-1 is entirely in
                // tiles <= k (wavefront-legal).
                let x_lo = k as i64 * tile_w as i64 - 2 * (r * t) as i64;
                let x_hi = x_lo + tile_w as i64;
                let lo = x_lo.max(0) as usize;
                let hi = (x_hi.min(lvl_len)) as usize;
                if lo >= hi {
                    continue;
                }
                // Compute level t cells [lo, hi) from level t-1
                // [lo, hi + 2r) (local coords of level t-1).
                let (below, here) = {
                    let (a, b) = levels.split_at_mut(t);
                    (&a[t - 1], &mut b[0])
                };
                step_range_dim0(spec, below, here, lo, hi);
            }
        }
        levels.pop().unwrap()
    }
}

/// Valid step restricted to dim-0 range [lo, hi) of the output level.
fn step_range_dim0(spec: &StencilSpec, src: &Field, dst: &mut Field, lo: usize, hi: usize) {
    let taps = FlatTaps::build(spec, src.shape());
    rowwise::step_range_dim0(src, spec, &taps, dst, lo, hi, false);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{reference, spec};

    #[test]
    fn matches_reference_multiple_tiles() {
        let s = spec::get("heat1d").unwrap();
        let eng = PlutoEngine { tile_w: 16 };
        let u = Field::random(&[100], 41);
        for steps in [1usize, 2, 4] {
            let got = eng.block(&s, &u, steps);
            let want = reference::block(&u, &s, steps);
            assert!(got.allclose(&want, 1e-13, 0.0), "steps={steps}");
        }
    }

    #[test]
    fn matches_reference_2d3d() {
        for name in ["box2d25p", "heat3d"] {
            let s = spec::get(name).unwrap();
            let eng = PlutoEngine { tile_w: 8 };
            let ext: Vec<usize> = (0..s.ndim).map(|_| 10 + 2 * s.radius * 2).collect();
            let u = Field::random(&ext, 42);
            let got = eng.block(&s, &u, 2);
            assert!(got.allclose(&reference::block(&u, &s, 2), 1e-13, 0.0), "{name}");
        }
    }

    #[test]
    fn step_range_partial() {
        let s = spec::get("heat1d").unwrap();
        let u = Field::random(&[20], 43);
        let mut out = Field::zeros(&[18]);
        step_range_dim0(&s, &u, &mut out, 5, 9);
        let want = reference::step(&u, &s);
        for i in 5..9 {
            assert!((out.data()[i] - want.data()[i]).abs() < 1e-14);
        }
        assert_eq!(out.data()[0], 0.0); // untouched outside the range
    }
}
