//! Work-stealing task pool shared by the engines and the coordinator.
//!
//! Std-only (mutex deques rather than chase-lev): each worker owns a
//! deque — LIFO pop from its own tail for locality, FIFO steal from
//! other queues' heads when empty — and a global injector seeds
//! initially-ready work.  [`run_dag`] adds per-task dependency tracking:
//! successors are released the instant their last predecessor finishes,
//! with no global phase barrier (the temporal-wavefront enabler).
//! [`steal_map`] is the order-preserving dynamic parallel map built on
//! top — the replacement for the old even-chunk fork-join
//! `parallel_map`, which serialized on the slowest chunk whenever tile
//! costs are irregular (boundary tiles, squeezed partitions, mixed
//! worker speeds).
//!
//! Pools are ephemeral and scoped: threads live for one `run_dag` call
//! and may borrow the caller's stack, so engines can schedule tasks over
//! fields they only hold by reference.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::analyze::checker::TaskAccess;
use crate::trace;

/// A unit of work scheduled on the pool.
pub type Task<'a> = Box<dyn FnOnce() + Send + 'a>;

struct Shared<'a> {
    /// One deque per worker: own tail = LIFO, thief head = FIFO.
    queues: Vec<Mutex<VecDeque<usize>>>,
    /// Seed queue for initially-ready tasks.
    injector: Mutex<VecDeque<usize>>,
    /// Task bodies, taken exactly once.
    slots: Vec<Mutex<Option<Task<'a>>>>,
    /// Unmet-dependency count per task.
    pending: Vec<AtomicUsize>,
    /// Reverse edges: tasks to release on completion.
    succs: Vec<Vec<usize>>,
    /// Tasks not yet finished (0 = shutdown).
    remaining: AtomicUsize,
    /// A task panicked: stop scheduling, re-raise on the caller.
    poisoned: AtomicBool,
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    idle: Mutex<()>,
    wake: Condvar,
    /// Tracer timestamp (µs) at which each task became runnable — only
    /// written while tracing is enabled, so `pool` spans can report the
    /// ready-to-execute queue wait.
    released_us: Vec<AtomicU64>,
}

impl<'a> Shared<'a> {
    fn pop(&self, w: usize) -> Option<usize> {
        if let Some(t) = self.queues[w].lock().unwrap().pop_back() {
            return Some(t);
        }
        if let Some(t) = self.injector.lock().unwrap().pop_front() {
            return Some(t);
        }
        let n = self.queues.len();
        for d in 1..n {
            if let Some(t) = self.queues[(w + d) % n].lock().unwrap().pop_front() {
                return Some(t);
            }
        }
        None
    }

    fn has_work(&self) -> bool {
        if !self.injector.lock().unwrap().is_empty() {
            return true;
        }
        self.queues.iter().any(|q| !q.lock().unwrap().is_empty())
    }

    fn run_task(&self, w: usize, t: usize) {
        let task = self.slots[t].lock().unwrap().take().expect("task scheduled twice");
        let _span = if trace::enabled() {
            let released = self.released_us[t].load(Ordering::Relaxed);
            let wait_us =
                if released == 0 { 0 } else { trace::now_us().saturating_sub(released) };
            trace::span(
                "pool",
                "task",
                &[("task", t.into()), ("worker", w.into()), ("wait_us", wait_us.into())],
            )
        } else {
            trace::Span::off()
        };
        if let Err(p) = catch_unwind(AssertUnwindSafe(task)) {
            // Abort the whole graph; run_dag re-raises on the caller.
            *self.panic.lock().unwrap() = Some(p);
            self.poisoned.store(true, Ordering::Release);
            self.wake.notify_all();
            return;
        }
        for &s in &self.succs[t] {
            if self.pending[s].fetch_sub(1, Ordering::AcqRel) == 1 {
                if trace::enabled() {
                    self.released_us[s].store(trace::now_us(), Ordering::Relaxed);
                }
                self.queues[w].lock().unwrap().push_back(s);
                self.wake.notify_all();
            }
        }
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.wake.notify_all();
        }
    }

    fn done(&self) -> bool {
        self.poisoned.load(Ordering::Acquire) || self.remaining.load(Ordering::Acquire) == 0
    }

    fn worker(&self, w: usize) {
        loop {
            if self.done() {
                return;
            }
            if let Some(t) = self.pop(w) {
                self.run_task(w, t);
                continue;
            }
            let guard = self.idle.lock().unwrap();
            if self.done() || self.has_work() {
                continue;
            }
            // Bounded park: a push can race past the checks above, so
            // never sleep unboundedly on a missed notification.
            let _ = self.wake.wait_timeout(guard, Duration::from_millis(1)).unwrap();
        }
    }
}

/// Execute a dependency graph of tasks on up to `threads` workers.
///
/// `deps[i]` lists the predecessor indices of task `i`; a task becomes
/// runnable when all its predecessors have finished.  The caller's thread
/// is worker 0, so `threads == 1` runs everything inline (deterministic
/// topological order).  Panics in any task are re-raised here after the
/// pool drains.
pub fn run_dag<'a>(threads: usize, tasks: Vec<Task<'a>>, deps: &[Vec<usize>]) {
    let n = tasks.len();
    assert_eq!(deps.len(), n, "deps/tasks length mismatch");
    if n == 0 {
        return;
    }
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut pending_init: Vec<usize> = vec![0; n];
    for (i, ds) in deps.iter().enumerate() {
        for &d in ds {
            assert!(d < n && d != i, "bad dependency {d} -> {i}");
            succs[d].push(i);
            pending_init[i] += 1;
        }
    }
    // Cheap Kahn pass up-front: a cycle would deadlock the pool.
    {
        let mut p = pending_init.clone();
        let mut q: VecDeque<usize> = (0..n).filter(|&i| p[i] == 0).collect();
        let mut seen = 0usize;
        while let Some(i) = q.pop_front() {
            seen += 1;
            for &s in &succs[i] {
                p[s] -= 1;
                if p[s] == 0 {
                    q.push_back(s);
                }
            }
        }
        assert_eq!(seen, n, "dependency cycle in task graph");
    }
    if threads <= 1 || n == 1 {
        let mut slots: Vec<Option<Task<'a>>> = tasks.into_iter().map(Some).collect();
        let mut ready: VecDeque<usize> = (0..n).filter(|&i| pending_init[i] == 0).collect();
        while let Some(i) = ready.pop_front() {
            let _span = if trace::enabled() {
                trace::span(
                    "pool",
                    "task",
                    &[("task", i.into()), ("worker", 0u64.into()), ("wait_us", 0u64.into())],
                )
            } else {
                trace::Span::off()
            };
            (slots[i].take().expect("task ran twice"))();
            drop(_span);
            for &s in &succs[i] {
                pending_init[s] -= 1;
                if pending_init[s] == 0 {
                    ready.push_back(s);
                }
            }
        }
        return;
    }
    let nworkers = threads.min(n);
    let shared = Shared {
        queues: (0..nworkers).map(|_| Mutex::new(VecDeque::new())).collect(),
        injector: Mutex::new((0..n).filter(|&i| pending_init[i] == 0).collect()),
        slots: tasks.into_iter().map(|t| Mutex::new(Some(t))).collect(),
        pending: pending_init.iter().map(|&p| AtomicUsize::new(p)).collect(),
        succs,
        remaining: AtomicUsize::new(n),
        poisoned: AtomicBool::new(false),
        panic: Mutex::new(None),
        idle: Mutex::new(()),
        wake: Condvar::new(),
        released_us: (0..n).map(|_| AtomicU64::new(0)).collect(),
    };
    if trace::enabled() {
        // Initially-ready tasks became runnable "now": their pool spans
        // report queue wait from graph start, not from the epoch.
        let now = trace::now_us();
        for (i, &p) in pending_init.iter().enumerate() {
            if p == 0 {
                shared.released_us[i].store(now, Ordering::Relaxed);
            }
        }
    }
    let sh = &shared;
    std::thread::scope(|scope| {
        for w in 1..nworkers {
            scope.spawn(move || sh.worker(w));
        }
        sh.worker(0);
    });
    if let Some(p) = shared.panic.into_inner().unwrap() {
        resume_unwind(p);
    }
}

/// Incremental builder for a [`run_dag`] task graph — the pipelined
/// leader uses it to wire the assemble → compute → writeback stages per
/// `(block, field, worker)` slab, where each stage's dependencies are
/// task ids returned by earlier [`TaskGraph::add`] calls.
///
/// Each task can optionally carry a declared read/write access summary
/// ([`TaskAccess`]); [`TaskGraph::assert_race_free`] feeds the deps and
/// summaries to the static checker (`analyze::checker`) so debug builds
/// verify the graph they are about to execute is race-free.
#[derive(Default)]
pub struct TaskGraph<'a> {
    tasks: Vec<Task<'a>>,
    deps: Vec<Vec<usize>>,
    accesses: Vec<TaskAccess>,
}

impl<'a> TaskGraph<'a> {
    pub fn new() -> TaskGraph<'a> {
        TaskGraph::default()
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Register a task that runs after every task in `deps`; returns its
    /// id for later stages to depend on.  The task carries an empty
    /// access summary (declares no shared-buffer traffic).
    pub fn add(&mut self, task: impl FnOnce() + Send + 'a, deps: Vec<usize>) -> usize {
        self.add_with_access(task, deps, TaskAccess::default())
    }

    /// [`TaskGraph::add`], declaring the task's shared-buffer reads and
    /// writes for the race checker.
    pub fn add_with_access(
        &mut self,
        task: impl FnOnce() + Send + 'a,
        deps: Vec<usize>,
        access: TaskAccess,
    ) -> usize {
        debug_assert!(deps.iter().all(|&d| d < self.tasks.len()), "dep on a future task");
        self.tasks.push(Box::new(task));
        self.deps.push(deps);
        self.accesses.push(access);
        self.tasks.len() - 1
    }

    /// The declared access summaries, indexed by task id.
    pub fn accesses(&self) -> &[TaskAccess] {
        &self.accesses
    }

    /// Debug-assert that no two conflicting tasks are unordered.  Call
    /// after construction, before [`TaskGraph::run`]; compiles to
    /// nothing in release builds.
    pub fn assert_race_free(&self) {
        if cfg!(debug_assertions) {
            let races = crate::analyze::checker::races(&self.deps, &self.accesses);
            debug_assert!(
                races.is_empty(),
                "task graph has {} race(s):\n{}",
                races.len(),
                races.iter().map(|r| r.to_string()).collect::<Vec<_>>().join("\n")
            );
        }
    }

    /// Execute the graph on up to `threads` workers (see [`run_dag`]).
    pub fn run(self, threads: usize) {
        run_dag(threads, self.tasks, &self.deps);
    }
}

/// Dynamic (self-scheduling) parallel map over `0..n`, order-preserving.
///
/// Unlike an even-chunk fork-join split, workers pull one index at a
/// time and steal from each other, so wall-clock tracks total work
/// rather than the slowest chunk.
pub fn steal_map<T: Send>(threads: usize, n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    if n == 0 {
        return Vec::new();
    }
    if threads <= 1 || n == 1 {
        return (0..n).map(f).collect();
    }
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    {
        let fr = &f;
        let sr = &slots;
        let tasks: Vec<Task<'_>> = (0..n)
            .map(|i| {
                Box::new(move || {
                    let v = fr(i);
                    *sr[i].lock().unwrap() = Some(v);
                }) as Task<'_>
            })
            .collect();
        run_dag(threads, tasks, &vec![Vec::new(); n]);
    }
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("steal_map task skipped"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn steal_map_preserves_order() {
        for threads in [1usize, 2, 4, 16] {
            let v = steal_map(threads, 23, |i| i * i);
            assert_eq!(v, (0..23).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn steal_map_empty_and_single() {
        assert!(steal_map(4, 0, |i| i).is_empty());
        assert_eq!(steal_map(4, 1, |i| i + 10), vec![10]);
    }

    #[test]
    fn steal_map_irregular_costs() {
        // One task is 100x the others: dynamic scheduling must still
        // return every result in order (the perf property is benched,
        // not tested).
        let v = steal_map(4, 12, |i| {
            let reps: u64 = if i == 0 { 200_000 } else { 2_000 };
            let acc: u64 = (0..reps).fold(0, |a, k| a.wrapping_add(k));
            (i, acc)
        });
        for (i, (slot, acc)) in v.iter().enumerate() {
            let reps: u64 = if i == 0 { 200_000 } else { 2_000 };
            assert_eq!(*slot, i);
            assert_eq!(*acc, reps * (reps - 1) / 2);
        }
    }

    #[test]
    fn run_dag_respects_dependencies() {
        // Diamond: 0 -> {1, 2} -> 3, plus a chain 4 -> 5.
        for threads in [1usize, 2, 8] {
            let order = Mutex::new(Vec::new());
            let mark = |i: usize| {
                let order = &order;
                move || order.lock().unwrap().push(i)
            };
            let tasks: Vec<Task<'_>> = vec![
                Box::new(mark(0)),
                Box::new(mark(1)),
                Box::new(mark(2)),
                Box::new(mark(3)),
                Box::new(mark(4)),
                Box::new(mark(5)),
            ];
            let deps = vec![vec![], vec![0], vec![0], vec![1, 2], vec![], vec![4]];
            run_dag(threads, tasks, &deps);
            let order = order.into_inner().unwrap();
            assert_eq!(order.len(), 6);
            let pos = |i: usize| order.iter().position(|&x| x == i).unwrap();
            assert!(pos(0) < pos(1) && pos(0) < pos(2), "{order:?}");
            assert!(pos(1) < pos(3) && pos(2) < pos(3), "{order:?}");
            assert!(pos(4) < pos(5), "{order:?}");
        }
    }

    #[test]
    fn run_dag_wide_wavefront() {
        // Two-layer wavefront like the tessellation DAG: B_k depends on
        // A_k and A_{k+1}.  Every task must run exactly once.
        let n = 17;
        let ran = (0..2 * n - 1).map(|_| AtomicUsize::new(0)).collect::<Vec<_>>();
        let mut tasks: Vec<Task<'_>> = Vec::new();
        let mut deps: Vec<Vec<usize>> = Vec::new();
        for i in 0..2 * n - 1 {
            let r = &ran;
            tasks.push(Box::new(move || {
                r[i].fetch_add(1, Ordering::Relaxed);
            }));
            deps.push(if i < n { vec![] } else { vec![i - n, i - n + 1] });
        }
        run_dag(4, tasks, &deps);
        assert!(ran.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn run_dag_panic_propagates() {
        for threads in [1usize, 4] {
            let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
                steal_map(threads, 8, |i| {
                    if i == 3 {
                        panic!("injected pool fault");
                    }
                    i
                })
            }));
            let err = r.expect_err("panic must propagate");
            let msg = err
                .downcast_ref::<&str>()
                .copied()
                .map(String::from)
                .or_else(|| err.downcast_ref::<String>().cloned())
                .unwrap_or_default();
            assert!(msg.contains("injected pool fault"), "threads={threads}: {msg}");
        }
    }

    #[test]
    fn task_graph_builds_staged_pipelines() {
        // Three stages per item, cross-linked like the leader pipeline:
        // stage C of item k depends on stage B of items k-1, k, k+1.
        for threads in [1usize, 4] {
            let n = 6;
            let log = Mutex::new(Vec::new());
            let mut g = TaskGraph::new();
            let mut a_ids = Vec::new();
            let mut b_ids = Vec::new();
            for k in 0..n {
                let log = &log;
                a_ids.push(g.add(move || log.lock().unwrap().push(("a", k)), vec![]));
            }
            for k in 0..n {
                let log = &log;
                b_ids.push(g.add(move || log.lock().unwrap().push(("b", k)), vec![a_ids[k]]));
            }
            for k in 0..n {
                let log = &log;
                let deps: Vec<usize> = (k.saturating_sub(1)..(k + 2).min(n))
                    .map(|j| b_ids[j])
                    .collect();
                g.add(move || log.lock().unwrap().push(("c", k)), deps);
            }
            assert_eq!(g.len(), 3 * n);
            g.run(threads);
            let log = log.into_inner().unwrap();
            assert_eq!(log.len(), 3 * n);
            let pos = |s: &str, k: usize| {
                log.iter().position(|&(t, i)| t == s && i == k).unwrap()
            };
            for k in 0..n {
                assert!(pos("a", k) < pos("b", k));
                for j in k.saturating_sub(1)..(k + 2).min(n) {
                    assert!(pos("b", j) < pos("c", k), "threads={threads} b{j} c{k}");
                }
            }
        }
    }

    /// With tracing enabled, every task of a DAG run produces exactly
    /// one balanced `pool`/`task` span carrying task/worker/wait_us
    /// args, in both the inline and the threaded path.  Concurrent
    /// tests may emit their own events while the global tracer is on,
    /// but never into another thread's buffer — so each task body marks
    /// its track with a unique nonce instant and assertions stay scoped
    /// to the nonce-marked tracks (after dropping leading `End`s that a
    /// foreign span from an earlier enabled window can force-record on
    /// a reused harness thread).
    #[test]
    fn run_dag_emits_one_pool_span_per_task() {
        use crate::trace::{Arg, Phase};
        let _guard = crate::trace::testutil::lock();
        for threads in [1usize, 4] {
            crate::trace::enable();
            let nonce = crate::trace::fresh_tag() << 32;
            let n = 9;
            let tasks: Vec<Task<'_>> = (0..n)
                .map(|_| {
                    Box::new(move || {
                        crate::trace::instant("test", "pool-nonce", &[("nonce", nonce.into())]);
                    }) as Task<'_>
                })
                .collect();
            let deps: Vec<Vec<usize>> =
                (0..n).map(|i| if i < 3 { vec![] } else { vec![i - 3] }).collect();
            run_dag(threads, tasks, &deps);
            crate::trace::disable();
            let drained = crate::trace::drain();
            let marked = |ev: &crate::trace::Event| {
                ev.name == "pool-nonce"
                    && ev.args.iter().any(|(k, v)| *k == "nonce" && *v == Arg::U(nonce))
            };
            let mut begun: Vec<u64> = Vec::new();
            let mut ended = 0usize;
            for te in &drained {
                if !te.events.iter().any(|e| marked(e)) {
                    continue;
                }
                let start = te
                    .events
                    .iter()
                    .position(|e| e.phase != Phase::End)
                    .unwrap_or(te.events.len());
                for ev in &te.events[start..] {
                    if marked(ev) {
                        continue;
                    }
                    assert_eq!(ev.cat, "pool", "threads={threads}: {ev:?}");
                    assert_eq!(ev.name, "task");
                    match ev.phase {
                        Phase::Begin => {
                            let arg = |k: &str| {
                                ev.args.iter().find(|(n, _)| *n == k).map(|(_, v)| v)
                            };
                            match arg("task") {
                                Some(Arg::U(t)) => begun.push(*t),
                                other => panic!("bad task arg {other:?}"),
                            }
                            assert!(matches!(arg("worker"), Some(Arg::U(_))));
                            assert!(matches!(arg("wait_us"), Some(Arg::U(_))));
                        }
                        Phase::End => ended += 1,
                        other => panic!("unexpected {other:?} {ev:?}"),
                    }
                }
            }
            begun.sort_unstable();
            assert_eq!(begun, (0..n as u64).collect::<Vec<_>>(), "threads={threads}");
            assert_eq!(ended, n, "threads={threads}");
        }
    }

    #[test]
    #[should_panic(expected = "dependency cycle")]
    fn run_dag_rejects_cycles() {
        let tasks: Vec<Task<'_>> = vec![Box::new(|| {}), Box::new(|| {})];
        run_dag(2, tasks, &[vec![1], vec![0]]);
    }

    #[test]
    fn task_graph_carries_access_summaries() {
        use crate::analyze::{BufferId, IntervalSet};
        let buf = BufferId::Global { field: 0, parity: 0 };
        let mut g = TaskGraph::new();
        let w = g.add_with_access(
            || {},
            vec![],
            TaskAccess::new("write").write(buf, IntervalSet::single(0, 4)),
        );
        g.add_with_access(
            || {},
            vec![w],
            TaskAccess::new("read").read(buf, IntervalSet::single(0, 4)),
        );
        assert_eq!(g.accesses().len(), 2);
        assert_eq!(g.accesses()[0].label, "write");
        g.assert_race_free(); // ordered: fine in every build
        g.run(2);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "race")]
    fn task_graph_debug_asserts_on_unordered_conflict() {
        use crate::analyze::{BufferId, IntervalSet};
        let buf = BufferId::Global { field: 0, parity: 0 };
        let mut g = TaskGraph::new();
        g.add_with_access(
            || {},
            vec![],
            TaskAccess::new("w0").write(buf, IntervalSet::single(0, 4)),
        );
        g.add_with_access(
            || {},
            vec![], // missing edge: unordered W/R on the same rows
            TaskAccess::new("r1").read(buf, IntervalSet::single(2, 6)),
        );
        g.assert_race_free();
    }
}
