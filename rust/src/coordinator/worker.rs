//! Heterogeneous workers: the compute backends the scheduler coordinates.
//!
//! Two worker species stand in for the paper's CPU and GPU (DESIGN.md
//! §Hardware-Adaptation):
//! * [`NativeWorker`] — any in-process CPU [`Engine`] (Tetris (CPU),
//!   or a baseline engine for ablations);
//! * [`XlaWorker`] — executes the AOT-compiled PJRT artifact, one
//!   unit-slab per invocation (the accelerator stand-in; its artifacts
//!   embed the Pallas temporal-block / MXU kernels).

use crate::util::error::Result;

use crate::engine::Engine;
use crate::runtime::{ArtifactMeta, XlaService};
use crate::stencil::{Field, StencilSpec};

/// A compute backend with the valid-mode slab contract: input slab
/// carries a `radius*steps` ghost ring on every side.
pub trait Worker: Send + Sync {
    fn name(&self) -> String;

    /// Memory capacity in bytes (for the memory squeezer).
    fn mem_capacity(&self) -> usize;

    /// Advance a slab `steps` fused steps (valid mode).
    fn run_slab(&self, spec: &StencilSpec, input: &Field, steps: usize) -> Result<Field>;

    /// Steps the worker's backend fuses per block.
    fn preferred_tb(&self) -> usize {
        1
    }
}

/// In-process CPU engine worker.
pub struct NativeWorker {
    pub engine: Box<dyn Engine>,
    pub capacity: usize,
}

impl NativeWorker {
    pub fn new(engine: Box<dyn Engine>, capacity: usize) -> Self {
        NativeWorker { engine, capacity }
    }
}

impl Worker for NativeWorker {
    fn name(&self) -> String {
        format!("native:{}", self.engine.name())
    }

    fn mem_capacity(&self) -> usize {
        self.capacity
    }

    fn run_slab(&self, spec: &StencilSpec, input: &Field, steps: usize) -> Result<Field> {
        Ok(self.engine.block(spec, input, steps))
    }

    fn preferred_tb(&self) -> usize {
        self.engine.preferred_tb()
    }
}

/// PJRT artifact worker: the slab is processed unit-by-unit with the
/// fixed-shape executable (each unit is one memory-level tetromino).
/// Jobs go through the [`XlaService`] device queue, which serializes
/// execution exactly like a single accelerator stream.
pub struct XlaWorker {
    pub service: XlaService,
    pub meta: ArtifactMeta,
    pub capacity: usize,
}

impl XlaWorker {
    pub fn new(service: XlaService, artifact: &str, capacity: usize) -> Result<Self> {
        let meta = service.meta(artifact)?.clone();
        Ok(XlaWorker { service, meta, capacity })
    }

    /// Unit rows along dim 0.
    pub fn unit(&self) -> usize {
        self.meta.unit_core[0]
    }
}

impl Worker for XlaWorker {
    fn name(&self) -> String {
        format!("xla:{}", self.meta.name)
    }

    fn mem_capacity(&self) -> usize {
        self.capacity
    }

    fn run_slab(&self, spec: &StencilSpec, input: &Field, steps: usize) -> Result<Field> {
        let meta = &self.meta;
        crate::ensure!(
            steps == meta.steps,
            "{}: artifact fuses {} steps, scheduler asked {steps}",
            meta.name,
            meta.steps
        );
        let halo = spec.radius * steps;
        let nd = input.ndim();
        let unit = self.unit();
        let slab_core0 = input.shape()[0] - 2 * halo;
        crate::ensure!(
            slab_core0 % unit == 0,
            "slab rows {slab_core0} not unit-aligned (unit {unit})"
        );
        let rest_core: Vec<usize> = meta.unit_core[1..].to_vec();
        crate::ensure!(
            input.shape()[1..]
                .iter()
                .zip(&rest_core)
                .all(|(&a, &b)| a == b + 2 * halo),
            "{}: slab rest shape {:?} incompatible with artifact {:?}",
            meta.name,
            &input.shape()[1..],
            rest_core
        );
        let mut out_shape = vec![slab_core0];
        out_shape.extend(&rest_core);
        let mut out = Field::zeros(&out_shape);
        for j in 0..slab_core0 / unit {
            let mut off = vec![j * unit];
            off.extend(vec![0usize; nd - 1]);
            let unit_in = input.extract(&off, &meta.input_shape);
            let unit_out = self.service.run(&meta.name, &unit_in)?;
            out.paste(&off, &unit_out);
        }
        Ok(out)
    }

    fn preferred_tb(&self) -> usize {
        self.meta.steps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{reference, spec};

    #[test]
    fn native_worker_runs_engine() {
        let s = spec::get("heat2d").unwrap();
        let w = NativeWorker::new(crate::engine::by_name("simd", 1).unwrap(), 1 << 30);
        let u = Field::random(&[14, 14], 5);
        let got = w.run_slab(&s, &u, 2).unwrap();
        assert!(got.allclose(&reference::block(&u, &s, 2), 1e-13, 0.0));
        assert_eq!(w.name(), "native:simd");
    }

    fn service() -> Option<XlaService> {
        for dir in ["artifacts", "../artifacts"] {
            if std::path::Path::new(dir).join("manifest.json").exists() {
                let m = crate::runtime::Manifest::load(dir).unwrap();
                return XlaService::spawn(m).ok();
            }
        }
        None
    }

    #[test]
    fn xla_worker_unit_slabs() {
        let Some(svc) = service() else { return };
        let s = spec::get("heat2d").unwrap();
        let w = XlaWorker::new(svc, "heat2d_block", 1 << 30).unwrap();
        let halo = w.meta.halo;
        // Two-unit slab: 128 core rows + halo, full rest width.
        let shape = vec![128 + 2 * halo, 256 + 2 * halo];
        let u = Field::random(&shape, 6);
        let got = w.run_slab(&s, &u, w.meta.steps).unwrap();
        let want = reference::block(&u, &s, w.meta.steps);
        assert!(
            got.allclose(&want, 1e-12, 1e-14),
            "maxdiff={}",
            got.max_abs_diff(&want)
        );
    }

    #[test]
    fn xla_worker_rejects_wrong_steps() {
        let Some(svc) = service() else { return };
        let s = spec::get("heat2d").unwrap();
        let w = XlaWorker::new(svc, "heat2d_block", 1 << 30).unwrap();
        let u = Field::random(&[70, 262], 7);
        assert!(w.run_slab(&s, &u, 999).is_err());
    }
}
