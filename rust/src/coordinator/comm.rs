//! Communication accounting + the α+β latency-bandwidth model (paper §5.3).
//!
//! The coordinator records every halo message it would send on a real
//! two-device deployment (here the copies are host memcpys, so the model
//! supplies the deployment-cost view).  Centralized launch — one batched
//! message per boundary per Tb-block instead of Tb per-step messages —
//! is the paper's k(α + nβ) ≫ α + k·n·β argument, reproduced by
//! [`CommModel::centralized_vs_split`] and the `comm` bench.

/// Latency-bandwidth model: cost(k msgs, B bytes) = k*α + B*β seconds.
#[derive(Clone, Copy, Debug)]
pub struct CommModel {
    /// Per-message launch latency (s).  PCIe/NVLink-ish default: 10 µs.
    pub alpha: f64,
    /// Per-byte transfer time (s/B).  Default 16 GB/s => 6.25e-11.
    pub beta: f64,
}

impl Default for CommModel {
    fn default() -> Self {
        CommModel { alpha: 10e-6, beta: 1.0 / 16e9 }
    }
}

impl CommModel {
    pub fn cost(&self, messages: usize, bytes: usize) -> f64 {
        messages as f64 * self.alpha + bytes as f64 * self.beta
    }

    /// (centralized, split) cost of exchanging `bytes` once per Tb block
    /// vs `tb` per-step messages of `bytes/tb` each.
    pub fn centralized_vs_split(&self, bytes: usize, tb: usize) -> (f64, f64) {
        let central = self.cost(1, bytes);
        let split = self.cost(tb, bytes);
        (central, split)
    }
}

/// Ledger of halo traffic accumulated over a run.
#[derive(Clone, Debug, Default)]
pub struct CommLedger {
    pub messages: usize,
    pub bytes: usize,
    /// Messages that WOULD have been sent without centralized launch.
    pub split_messages: usize,
    /// Messages whose halo extraction ran concurrently with in-flight
    /// compute (§5.3 overlap) — always 0 under the serial leader loop.
    pub overlapped_messages: usize,
}

impl CommLedger {
    /// Record one centralized halo exchange covering `tb` steps.
    pub fn record_exchange(&mut self, bytes: usize, tb: usize) {
        self.messages += 1;
        self.bytes += bytes;
        self.split_messages += tb;
    }

    /// Mark the `n` most recent exchanges as compute-overlapped.
    pub fn record_overlapped(&mut self, n: usize) {
        self.overlapped_messages = (self.overlapped_messages + n).min(self.messages);
    }

    /// Modeled seconds under `m`, centralized vs per-step launch.
    pub fn modeled_cost(&self, m: &CommModel) -> (f64, f64) {
        (
            m.cost(self.messages, self.bytes),
            m.cost(self.split_messages, self.bytes),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_dominates_small_messages() {
        let m = CommModel::default();
        let (central, split) = m.centralized_vs_split(1024, 8);
        assert!(central < split);
        // 8 messages pay 8 alphas
        assert!((split - central - 7.0 * m.alpha).abs() < 1e-12);
    }

    #[test]
    fn big_transfers_are_bandwidth_bound() {
        let m = CommModel::default();
        let c = m.cost(1, 1 << 30);
        assert!(c > 0.05, "1 GiB at 16 GB/s is > 60 ms, got {c}");
    }

    #[test]
    fn ledger_accumulates() {
        let mut l = CommLedger::default();
        l.record_exchange(4096, 4);
        l.record_exchange(4096, 4);
        assert_eq!(l.messages, 2);
        assert_eq!(l.split_messages, 8);
        assert_eq!(l.bytes, 8192);
        let m = CommModel::default();
        let (c, s) = l.modeled_cost(&m);
        assert!(c < s);
    }

    #[test]
    fn overlapped_messages_never_exceed_total() {
        let mut l = CommLedger::default();
        l.record_exchange(64, 2);
        l.record_overlapped(5);
        assert_eq!(l.overlapped_messages, 1);
        l.record_exchange(64, 2);
        l.record_overlapped(1);
        assert_eq!(l.overlapped_messages, 2);
    }
}
