//! Communication accounting + the α+β latency-bandwidth model (paper §5.3).
//!
//! The coordinator records every halo message it would send on a real
//! two-device deployment (here the copies are host memcpys, so the model
//! supplies the deployment-cost view).  Centralized launch — one batched
//! message per boundary per Tb-block instead of Tb per-step messages —
//! is the paper's k(α + nβ) ≫ α + k·n·β argument, reproduced by
//! [`CommModel::centralized_vs_split`] and the `comm` bench.

/// Latency-bandwidth model: cost(k msgs, B bytes) = k*α + B*β seconds.
#[derive(Clone, Copy, Debug)]
pub struct CommModel {
    /// Per-message launch latency (s).  PCIe/NVLink-ish default: 10 µs.
    pub alpha: f64,
    /// Per-byte transfer time (s/B).  Default 16 GB/s => 6.25e-11.
    pub beta: f64,
}

impl Default for CommModel {
    fn default() -> Self {
        CommModel { alpha: 10e-6, beta: 1.0 / 16e9 }
    }
}

impl CommModel {
    pub fn cost(&self, messages: usize, bytes: usize) -> f64 {
        messages as f64 * self.alpha + bytes as f64 * self.beta
    }

    /// (centralized, split) cost of exchanging `bytes` once per Tb block
    /// vs `tb` per-step messages of `bytes/tb` each.
    pub fn centralized_vs_split(&self, bytes: usize, tb: usize) -> (f64, f64) {
        let central = self.cost(1, bytes);
        let split = self.cost(tb, bytes);
        (central, split)
    }
}

/// Per-block halo exchanges implied by a 2-D worker-grid topology: one
/// entry per inter-worker link, valued at that link's bytes (both
/// directions folded into one centralized message, exactly like the
/// historical 1-D accounting).
///
/// * dim-0 edge links: adjacent non-empty row runs, once per non-empty
///   band — `2 * halo * band_width * rest2 * 8` bytes each;
/// * dim-1 edge links: adjacent non-empty bands, once per non-empty
///   run — `2 * halo * run_rows * rest2 * 8` bytes each;
/// * corner links: per (adjacent run pair × adjacent band pair), two
///   diagonal exchanges of `2 * halo * halo * rest2 * 8` bytes each —
///   only a true grid (both axes split) has corners.
///
/// `periodic` adds the wrap link on any axis with more than one active
/// run/band.  `rest2` is the product of the *core* extents of dims 2+
/// (1 for 2-D fields).  With a single band this reproduces the 1-D
/// ledger exactly: one message per adjacent run pair (plus the
/// periodic wrap), each `2 * halo * band_width * rest2 * 8` bytes.
pub fn grid_exchanges(
    rows: &[(usize, usize)],
    bands: &[(usize, usize)],
    halo: usize,
    rest2: usize,
    periodic: bool,
) -> Vec<usize> {
    // Adjacent pairs among the non-empty runs of one axis, in order;
    // periodic adds the wrap pair when more than one run is active.
    fn adjacent_pairs(spans: &[(usize, usize)], periodic: bool) -> usize {
        let active = spans.iter().filter(|&&(s, e)| e > s).count();
        if active == 0 {
            return 0;
        }
        if periodic && active > 1 {
            active
        } else {
            active - 1
        }
    }
    let x_pairs = adjacent_pairs(rows, periodic);
    let y_pairs = adjacent_pairs(bands, periodic);
    let mut out = Vec::new();
    // dim-0 edges: once per non-empty band
    for &(c0, c1) in bands.iter().filter(|&&(c0, c1)| c1 > c0) {
        for _ in 0..x_pairs {
            out.push(2 * halo * (c1 - c0) * rest2 * 8);
        }
    }
    // dim-1 edges: once per non-empty run
    for &(s, e) in rows.iter().filter(|&&(s, e)| e > s) {
        for _ in 0..y_pairs {
            out.push(2 * halo * (e - s) * rest2 * 8);
        }
    }
    // corners: only when both axes are split
    if bands.len() > 1 && rows.len() > 1 {
        for _ in 0..x_pairs * y_pairs {
            out.push(2 * halo * halo * rest2 * 8);
            out.push(2 * halo * halo * rest2 * 8);
        }
    }
    out
}

/// Ledger of halo traffic accumulated over a run.
#[derive(Clone, Debug, Default)]
pub struct CommLedger {
    pub messages: usize,
    pub bytes: usize,
    /// Messages that WOULD have been sent without centralized launch.
    pub split_messages: usize,
    /// Messages whose halo extraction ran concurrently with in-flight
    /// compute (§5.3 overlap) — always 0 under the serial leader loop.
    pub overlapped_messages: usize,
}

impl CommLedger {
    /// Record one centralized halo exchange covering `tb` steps.
    pub fn record_exchange(&mut self, bytes: usize, tb: usize) {
        self.messages += 1;
        self.bytes += bytes;
        self.split_messages += tb;
    }

    /// Mark the `n` most recent exchanges as compute-overlapped.
    pub fn record_overlapped(&mut self, n: usize) {
        self.overlapped_messages = (self.overlapped_messages + n).min(self.messages);
    }

    /// Modeled seconds under `m`, centralized vs per-step launch.
    pub fn modeled_cost(&self, m: &CommModel) -> (f64, f64) {
        (
            m.cost(self.messages, self.bytes),
            m.cost(self.split_messages, self.bytes),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_dominates_small_messages() {
        let m = CommModel::default();
        let (central, split) = m.centralized_vs_split(1024, 8);
        assert!(central < split);
        // 8 messages pay 8 alphas
        assert!((split - central - 7.0 * m.alpha).abs() < 1e-12);
    }

    #[test]
    fn big_transfers_are_bandwidth_bound() {
        let m = CommModel::default();
        let c = m.cost(1, 1 << 30);
        assert!(c > 0.05, "1 GiB at 16 GB/s is > 60 ms, got {c}");
    }

    #[test]
    fn ledger_accumulates() {
        let mut l = CommLedger::default();
        l.record_exchange(4096, 4);
        l.record_exchange(4096, 4);
        assert_eq!(l.messages, 2);
        assert_eq!(l.split_messages, 8);
        assert_eq!(l.bytes, 8192);
        let m = CommModel::default();
        let (c, s) = l.modeled_cost(&m);
        assert!(c < s);
    }

    #[test]
    fn grid_exchanges_single_band_matches_1d_accounting() {
        // 4 non-empty runs, one full band of 64 cols, halo 2: the 1-D
        // ledger — 3 links (4 with wrap) of 2*halo*row_width*8 bytes.
        let rows = vec![(0, 16), (16, 32), (32, 48), (48, 64)];
        let ex = grid_exchanges(&rows, &[(0, 64)], 2, 1, false);
        assert_eq!(ex, vec![2048; 3]);
        let ex = grid_exchanges(&rows, &[(0, 64)], 2, 1, true);
        assert_eq!(ex, vec![2048; 4]);
        // zero-share runs don't form links
        let rows = vec![(0, 32), (32, 32), (32, 64)];
        let ex = grid_exchanges(&rows, &[(0, 64)], 2, 1, false);
        assert_eq!(ex, vec![2048; 1]);
        // a single worker exchanges nothing
        assert!(grid_exchanges(&[(0, 64)], &[(0, 64)], 2, 1, false).is_empty());
    }

    #[test]
    fn grid_cuts_halo_bytes_versus_1d_at_four_workers() {
        // 64×64, halo 2, W=4: the 2×2 grid trades more messages
        // (perimeter has corners) for strictly fewer halo bytes than
        // the 1×4 split — the perimeter-over-area argument.
        let flat = grid_exchanges(
            &[(0, 16), (16, 32), (32, 48), (48, 64)],
            &[(0, 64)],
            2,
            1,
            false,
        );
        let grid = grid_exchanges(&[(0, 32), (32, 64)], &[(0, 32), (32, 64)], 2, 1, false);
        // 2 x-links + 2 y-links of 1024 B plus 2 corner links of 64 B
        assert_eq!(grid.len(), 6);
        assert_eq!(grid.iter().sum::<usize>(), 2 * 1024 + 2 * 1024 + 2 * 64);
        assert_eq!(flat.iter().sum::<usize>(), 3 * 2048);
        assert!(grid.iter().sum::<usize>() < flat.iter().sum::<usize>());
    }

    #[test]
    fn overlapped_messages_never_exceed_total() {
        let mut l = CommLedger::default();
        l.record_exchange(64, 2);
        l.record_overlapped(5);
        assert_eq!(l.overlapped_messages, 1);
        l.record_exchange(64, 2);
        l.record_overlapped(1);
        assert_eq!(l.overlapped_messages, 2);
    }
}
