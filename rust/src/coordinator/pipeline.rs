//! The concurrent heterogeneous pipeline driver (paper §5, Fig. 11).
//!
//! The leader holds the global extended field.  Per Tb-block it
//! (0) refreshes the global ghost ring from the boundary condition
//! (Dirichlet ghosts are static, but Neumann mirrors and Periodic wraps
//! depend on the evolving core, so the ring is refilled every block),
//! (1) snapshots each worker's slab + ghost ring (the halo exchange —
//! batched once per block, the §5.3 centralized communication launch),
//! (2) dispatches every worker concurrently on the work-stealing pool,
//! (3) writes the slabs back, accounting busy/idle time and comm volume,
//! (4) optionally re-partitions the domain from measured busy times
//! every `adapt_every` blocks — the §5.2 architecture-aware rebalance.
//!
//! Workers stay boundary-agnostic: their valid-mode slab contract only
//! consumes the ghost ring the leader hands them, so any worker species
//! (native engine or AOT artifact) serves any boundary condition.

use std::time::{Duration, Instant};

use crate::util::error::{Context, Result};

use crate::stencil::{Boundary, Field, StencilSpec};

use super::comm::{CommLedger, CommModel};
use super::metrics::RunMetrics;
use super::partition::{capacity_units, Partition};
use super::tuner;
use super::worker::Worker;

pub struct Scheduler {
    pub spec: StencilSpec,
    /// Fused steps per block (every worker must support it).
    pub tb: usize,
    pub workers: Vec<Box<dyn Worker>>,
    pub partition: Partition,
    pub comm_model: CommModel,
    /// Ghost-ring physics of the global domain.
    pub boundary: Boundary,
    /// Re-partition from measured per-block busy times every this many
    /// blocks (0 = static partition).
    pub adapt_every: usize,
}

impl Scheduler {
    /// Build a scheduler for a `rows`-row domain from a tuned execution
    /// plan's Tb: one slab per worker, even row-granular split (the
    /// §5.2 profile/retune machinery refines it at run time), default
    /// comm model.  The shared constructor behind `tetris run`'s
    /// scheduler mode and the plan-resolved `--engine auto` path.
    pub fn from_plan(
        spec: StencilSpec,
        tb: usize,
        workers: Vec<Box<dyn Worker>>,
        rows: usize,
        boundary: Boundary,
        adapt_every: usize,
    ) -> Scheduler {
        let n = workers.len().max(1);
        Scheduler {
            spec,
            tb: tb.max(1),
            workers,
            partition: Partition::balanced(1, rows, &vec![1.0; n], &vec![rows; n]),
            comm_model: CommModel::default(),
            boundary,
            adapt_every,
        }
    }

    /// Evolve `core` by `total_steps` (a multiple of Tb) under
    /// `self.boundary`.  Returns the final core and run metrics.
    pub fn run(&self, core: &Field, total_steps: usize) -> Result<(Field, RunMetrics)> {
        let (mut outs, metrics) = self.run_batch(std::slice::from_ref(core), total_steps)?;
        Ok((outs.pop().unwrap(), metrics))
    }

    /// Evolve a batch of same-shape fields together under one partition.
    /// Per Tb-block every worker advances its slab of *every* field in a
    /// single pool dispatch, so the per-block pool spawn, the halo
    /// snapshots, and the (migration-gated) retune decision amortize
    /// across the batch — the multi-field engine behind `serve`'s job
    /// batcher.  Slab decomposition is numerically invisible, so each
    /// returned field is bit-identical to running it alone.  Returns the
    /// final fields in input order plus combined metrics (`core_cells`
    /// and comm totals sum over the batch; `fields` records the width).
    pub fn run_batch(&self, cores: &[Field], total_steps: usize) -> Result<(Vec<Field>, RunMetrics)> {
        crate::ensure!(!cores.is_empty(), "empty batch");
        crate::ensure!(
            cores.iter().all(|c| c.shape() == cores[0].shape()),
            "batch fields must share one shape"
        );
        crate::ensure!(self.tb >= 1, "tb must be >= 1");
        crate::ensure!(
            total_steps % self.tb == 0,
            "total_steps {total_steps} not a multiple of Tb {}",
            self.tb
        );
        crate::ensure!(
            !self.workers.is_empty() && self.workers.len() == self.partition.shares.len(),
            "workers/partition mismatch"
        );
        let core0 = &cores[0];
        let nf = cores.len();
        let mut partition = self.partition.clone();
        let mut spans = partition.spans();
        crate::ensure!(
            spans.last().unwrap().1 == core0.shape()[0],
            "partition covers {} rows, domain has {}",
            spans.last().unwrap().1,
            core0.shape()[0]
        );
        let halo = self.spec.radius * self.tb;
        let nd = core0.ndim();
        let mut globals: Vec<Field> =
            cores.iter().map(|c| c.pad(halo, self.boundary.pad_value())).collect();
        let ext_rest: Vec<usize> = globals[0].shape()[1..].to_vec();
        let ext_rest_cells: usize = ext_rest.iter().product::<usize>().max(1);
        // What one internal-boundary halo message actually ships on a
        // real two-device deployment: core-row cells.  The padding of the
        // non-split dims is each device's own ghost ring, filled locally
        // from the boundary condition, never sent over the link.
        let core_rest_cells: usize = core0.shape()[1..].iter().product::<usize>().max(1);

        let blocks = total_steps / self.tb;
        let nw = self.workers.len();
        let mut busy = vec![Duration::ZERO; nw];
        let mut idle = vec![Duration::ZERO; nw];
        let mut comm = CommLedger::default();
        let mut retunes = 0usize;
        let mut window_busy = vec![0f64; nw];
        let mut window_blocks = 0usize;
        let t0 = Instant::now();

        for b in 0..blocks {
            // (0) Ghost refresh from each field's current core state.
            for g in globals.iter_mut() {
                self.boundary.fill(g, halo);
            }

            // (1) Halo snapshot: one extraction per worker per field per
            // block — the centralized communication launch.  Internal-
            // boundary bytes are what a real deployment would ship; under
            // Periodic the workers form a ring (worker 0 <-> worker
            // W-1 exchange the wrap halo too), so W workers have W
            // inter-device links instead of W-1.  A single worker's
            // wrap-around is a local copy, not a message.
            let inputs: Vec<Vec<Field>> = globals
                .iter()
                .map(|g| {
                    spans
                        .iter()
                        .map(|&(s, e)| {
                            let mut off = vec![s];
                            off.extend(vec![0usize; nd - 1]);
                            let mut shape = vec![(e - s) + 2 * halo];
                            shape.extend(&ext_rest);
                            g.extract(&off, &shape)
                        })
                        .collect()
                })
                .collect();
            // Only boundaries between *non-empty* spans are real links: a
            // zero-share worker holds no rows, so its neighbours abut
            // directly (and a lone active worker's wrap is a local copy).
            let active_spans = spans.iter().filter(|&&(s, e)| e > s).count();
            let internal_links = match self.boundary {
                Boundary::Periodic if active_spans > 1 => active_spans,
                _ => active_spans.saturating_sub(1),
            };
            for _ in 0..internal_links * nf {
                // two directions x halo rows x core-row cells
                comm.record_exchange(2 * halo * core_rest_cells * 8, self.tb);
            }

            // (2) One concurrent dispatch over all (field, worker) slabs.
            let results = dispatch(&self.workers, &self.spec, &inputs, self.tb, halo);

            // (3) Writeback + accounting.  A worker's block busy time is
            // the sum over its fields; bubbles are judged against the
            // slowest worker, exactly as in the single-field run.
            let mut block_busy = vec![Duration::ZERO; nw];
            for per_field in &results {
                for (w, (_, dt)) in per_field.iter().enumerate() {
                    block_busy[w] += *dt;
                }
            }
            let slowest = block_busy.iter().copied().max().unwrap_or_default();
            for (f, per_field) in results.into_iter().enumerate() {
                for (i, ((res, _), &(s, _e))) in per_field.into_iter().zip(&spans).enumerate() {
                    let out = res.with_context(|| format!("worker {i} failed (field {f})"))?;
                    let mut off = vec![s + halo];
                    off.extend(vec![halo; nd - 1]);
                    globals[f].paste(&off, &out);
                }
            }
            for i in 0..nw {
                busy[i] += block_busy[i];
                idle[i] += slowest - block_busy[i];
                window_busy[i] += block_busy[i].as_secs_f64();
            }

            // (4) §5.2 architecture-aware rebalance: slab redistribution
            // through Partition::spans, fed by the measured busy times
            // and gated by the slab-migration cost model (hysteresis:
            // a marginal imbalance is not worth shipping slabs for).
            window_blocks += 1;
            if self.adapt_every > 0 && window_blocks >= self.adapt_every && b + 1 < blocks {
                let per_block: Vec<f64> =
                    window_busy.iter().map(|t| t / window_blocks as f64).collect();
                let tmax = per_block.iter().cloned().fold(0.0, f64::max);
                // The squeezer can only rebalance if the declared worker
                // capacities cover the domain; a hand-built static
                // partition is allowed to ignore capacities, so skip the
                // retune (rather than panic mid-run) when they don't.
                let caps_cover = self
                    .workers
                    .iter()
                    .map(|w| capacity_units(w.mem_capacity(), partition.unit, ext_rest_cells))
                    .sum::<usize>()
                    >= partition.total_units();
                if tmax > 0.0 && caps_cover {
                    // A zero-share worker measured ~nothing; feed it the
                    // slowest time so its exploration weight stays modest.
                    let measured: Vec<f64> = partition
                        .shares
                        .iter()
                        .zip(&per_block)
                        .map(|(&s, &t)| if s == 0 || t <= 0.0 { tmax } else { t })
                        .collect();
                    if let Some(next) = tuner::retune_gated(
                        &partition,
                        &measured,
                        &self.workers,
                        ext_rest_cells,
                        &self.comm_model,
                        core_rest_cells,
                        blocks - (b + 1),
                    ) {
                        partition = next;
                        spans = partition.spans();
                        retunes += 1;
                    }
                }
                window_busy.fill(0.0);
                window_blocks = 0;
            }
        }

        let metrics = RunMetrics {
            total_steps,
            blocks,
            fields: nf,
            core_cells: core0.len() * nf,
            elapsed: t0.elapsed(),
            worker_names: self.workers.iter().map(|w| w.name()).collect(),
            worker_busy: busy,
            worker_idle: idle,
            comm,
            ratios: (0..nw).map(|i| partition.ratio(i)).collect(),
            final_shares: partition.shares.clone(),
            retunes,
        };
        Ok((globals.into_iter().map(|g| g.unpad(halo)).collect(), metrics))
    }
}

/// Run every (field, worker) slab concurrently on one pool scope; returns
/// per-field, per-worker (result, busy time) in order.  `inputs` is
/// indexed `[field][worker]`.  Pools are ephemeral per call, so
/// engine-internal tile pools nested inside a worker stay independent of
/// this dispatch scope.  A worker whose slab has zero core rows (share
/// squeezed/retuned to 0) is skipped and yields an empty result.  Thread
/// count grows with the batch but never oversubscribes the host.
fn dispatch(
    workers: &[Box<dyn Worker>],
    spec: &StencilSpec,
    inputs: &[Vec<Field>],
    tb: usize,
    halo: usize,
) -> Vec<Vec<(Result<Field>, Duration)>> {
    let nw = workers.len();
    let nf = inputs.len();
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let threads = (nw * nf).min(nw.max(host));
    let mut flat = super::pool::steal_map(threads, nw * nf, |i| {
        let (f, w) = (i / nw, i % nw);
        let input = &inputs[f][w];
        if input.shape()[0] == 2 * halo {
            let shape: Vec<usize> = input.shape().iter().map(|&n| n - 2 * halo).collect();
            return (Ok(Field::zeros(&shape)), Duration::ZERO);
        }
        let t0 = Instant::now();
        let res = workers[w].run_slab(spec, input, tb);
        (res, t0.elapsed())
    });
    let mut out = Vec::with_capacity(nf);
    for _ in 0..nf {
        out.push(flat.drain(..nw).collect());
    }
    out
}

/// Single-worker reference evolution with the same leader-side boundary
/// semantics — used by tests and by the thermal case study's "Naive" row.
pub fn reference_evolution(
    core: &Field,
    spec: &StencilSpec,
    total_steps: usize,
    tb: usize,
    boundary: Boundary,
) -> Field {
    assert_eq!(total_steps % tb, 0);
    let halo = spec.radius * tb;
    let mut global = core.pad(halo, boundary.pad_value());
    for _ in 0..total_steps / tb {
        boundary.fill(&mut global, halo);
        let out = crate::stencil::reference::block(&global, spec, tb);
        global.paste(&vec![halo; core.ndim()], &out);
    }
    global.unpad(halo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::NativeWorker;
    use crate::stencil::{reference, spec};

    fn native(name: &str) -> Box<dyn Worker> {
        Box::new(NativeWorker::new(crate::engine::by_name(name, 1).unwrap(), 1 << 30))
    }

    fn sched(
        s: &StencilSpec,
        tb: usize,
        workers: Vec<Box<dyn Worker>>,
        unit: usize,
        shares: Vec<usize>,
        boundary: Boundary,
    ) -> Scheduler {
        Scheduler {
            spec: s.clone(),
            tb,
            workers,
            partition: Partition { unit, shares },
            comm_model: CommModel::default(),
            boundary,
            adapt_every: 0,
        }
    }

    #[test]
    fn hetero_run_matches_reference_evolution() {
        for bench in ["heat1d", "heat2d", "box2d25p", "heat3d"] {
            let s = spec::get(bench).unwrap();
            let mut shape = vec![24usize];
            shape.extend(vec![10usize; s.ndim - 1]);
            let core = Field::random(&shape, 17);
            let tb = 2;
            let sched = sched(
                &s,
                tb,
                vec![native("simd"), native("autovec"), native("tetris-cpu")],
                4,
                vec![2, 1, 3],
                Boundary::Dirichlet(0.5),
            );
            let (got, metrics) = sched.run(&core, 8).unwrap();
            let want = reference_evolution(&core, &s, 8, tb, Boundary::Dirichlet(0.5));
            assert!(
                got.allclose(&want, 1e-12, 1e-14),
                "{bench}: maxdiff={}",
                got.max_abs_diff(&want)
            );
            assert_eq!(metrics.blocks, 4);
            assert_eq!(metrics.comm.messages, 2 * 4); // 2 boundaries x 4 blocks
            // Each batched exchange ships core-row cells only — the
            // non-split-dim padding is locally-filled ghosts, not traffic.
            let halo = s.radius * tb;
            let core_rest: usize = shape[1..].iter().product::<usize>().max(1);
            assert_eq!(
                metrics.comm.bytes,
                metrics.comm.messages * 2 * halo * core_rest * 8,
                "{bench}"
            );
        }
    }

    #[test]
    fn from_plan_builds_even_partition_and_runs() {
        let s = spec::get("heat2d").unwrap();
        let sc = Scheduler::from_plan(
            s.clone(),
            2,
            vec![native("simd"), native("autovec")],
            16,
            Boundary::Periodic,
            0,
        );
        assert_eq!(sc.partition.total_units(), 16);
        assert_eq!(sc.partition.shares, vec![8, 8]);
        let core = Field::random(&[16, 8], 91);
        let (got, _) = sc.run(&core, 4).unwrap();
        let want = reference::evolve_periodic(&core, &s, 4);
        assert!(got.allclose(&want, 1e-12, 1e-14), "maxdiff={}", got.max_abs_diff(&want));
    }

    #[test]
    fn single_worker_covers_domain() {
        let s = spec::get("heat2d").unwrap();
        let core = Field::random(&[16, 8], 18);
        let sched = sched(&s, 1, vec![native("naive")], 16, vec![1], Boundary::Dirichlet(0.0));
        let (got, m) = sched.run(&core, 3).unwrap();
        let want = reference_evolution(&core, &s, 3, 1, Boundary::Dirichlet(0.0));
        assert!(got.allclose(&want, 1e-12, 0.0));
        assert_eq!(m.comm.messages, 0); // no internal boundary
    }

    #[test]
    fn rejects_partition_mismatch() {
        let s = spec::get("heat1d").unwrap();
        let core = Field::random(&[20], 19);
        // 12 != 20 rows
        let sched = sched(&s, 1, vec![native("naive")], 4, vec![3], Boundary::Dirichlet(0.0));
        assert!(sched.run(&core, 1).is_err());
    }

    #[test]
    fn rejects_non_multiple_steps() {
        let s = spec::get("heat1d").unwrap();
        let core = Field::random(&[8], 20);
        let sched = sched(&s, 4, vec![native("naive")], 8, vec![1], Boundary::Dirichlet(0.0));
        assert!(sched.run(&core, 6).is_err());
    }

    #[test]
    fn boundary_value_is_respected() {
        // An all-boundary-value field must stay constant.
        let s = spec::get("heat2d").unwrap();
        let core = Field::full(&[12, 12], 1.5);
        let sched = sched(
            &s,
            2,
            vec![native("simd"), native("simd")],
            6,
            vec![1, 1],
            Boundary::Dirichlet(1.5),
        );
        let (got, _) = sched.run(&core, 4).unwrap();
        assert!((got.min() - 1.5).abs() < 1e-12 && (got.max() - 1.5).abs() < 1e-12);
    }

    /// Acceptance: a 3-worker heterogeneous Periodic run matches the
    /// shape-preserving periodic oracle to 1e-12 relative tolerance.
    #[test]
    fn hetero_periodic_matches_torus_oracle() {
        for bench in ["heat1d", "heat2d", "heat3d"] {
            let s = spec::get(bench).unwrap();
            let mut shape = vec![24usize];
            shape.extend(vec![8usize; s.ndim - 1]);
            let core = Field::random(&shape, 23);
            let tb = 2;
            let sched = sched(
                &s,
                tb,
                vec![native("simd"), native("autovec"), native("tetris-cpu")],
                4,
                vec![2, 1, 3],
                Boundary::Periodic,
            );
            let steps = 6;
            let (got, metrics) = sched.run(&core, steps).unwrap();
            let want = reference::evolve_periodic(&core, &s, steps);
            assert!(
                got.allclose(&want, 1e-12, 1e-14),
                "{bench}: maxdiff={}",
                got.max_abs_diff(&want)
            );
            // torus conserves the mean
            assert!((got.mean() - core.mean()).abs() < 1e-11, "{bench}");
            // ring topology: W links per block, not W-1
            assert_eq!(metrics.comm.messages, 3 * steps / tb, "{bench}");
        }
    }

    /// Heterogeneous Neumann runs match the single-worker (leader-side)
    /// Neumann evolution across dimensions and mixed worker sets.
    #[test]
    fn hetero_neumann_matches_single_worker_evolution() {
        for bench in ["heat1d", "heat2d", "heat3d"] {
            let s = spec::get(bench).unwrap();
            let mut shape = vec![24usize];
            shape.extend(vec![8usize; s.ndim - 1]);
            let core = Field::random(&shape, 29);
            let tb = 2;
            let sched = sched(
                &s,
                tb,
                vec![native("tetris-cpu"), native("naive"), native("simd")],
                4,
                vec![3, 2, 1],
                Boundary::Neumann,
            );
            let (got, _) = sched.run(&core, 6).unwrap();
            let want = reference_evolution(&core, &s, 6, tb, Boundary::Neumann);
            assert!(
                got.allclose(&want, 1e-12, 1e-14),
                "{bench}: maxdiff={}",
                got.max_abs_diff(&want)
            );
        }
    }

    /// Insulated walls conserve total heat: the Neumann reflection keeps
    /// the deep halo an even extension, so the mean is a run invariant
    /// even with fused Tb-blocks.
    #[test]
    fn neumann_run_conserves_mean() {
        let s = spec::get("heat2d").unwrap();
        let core = Field::random(&[16, 12], 31);
        let sched =
            sched(&s, 2, vec![native("simd"), native("autovec")], 4, vec![2, 2], Boundary::Neumann);
        let (got, _) = sched.run(&core, 8).unwrap();
        assert!((got.mean() - core.mean()).abs() < 1e-12, "drift {}", got.mean() - core.mean());
    }

    /// A worker whose share is 0 (squeezed out or retuned away) is
    /// skipped, not crashed into a zero-row engine call.
    #[test]
    fn zero_share_worker_is_skipped() {
        let s = spec::get("heat2d").unwrap();
        let core = Field::random(&[16, 8], 37);
        let sched = sched(
            &s,
            2,
            vec![native("simd"), native("autovec")],
            4,
            vec![0, 4],
            Boundary::Periodic,
        );
        let (got, metrics) = sched.run(&core, 4).unwrap();
        let want = reference::evolve_periodic(&core, &s, 4);
        assert!(got.allclose(&want, 1e-12, 1e-14), "maxdiff={}", got.max_abs_diff(&want));
        assert_eq!(metrics.worker_busy[0], Duration::ZERO);
        // one active worker = no inter-device links, even on the torus
        assert_eq!(metrics.comm.messages, 0);
    }

    /// Delays each slab by a fixed per-core-row cost on top of a real
    /// engine — a deterministic stand-in for a skewed heterogeneous set.
    struct DelayWorker {
        inner: Box<dyn Worker>,
        per_row: Duration,
    }

    impl Worker for DelayWorker {
        fn name(&self) -> String {
            format!("delay:{}", self.inner.name())
        }
        fn mem_capacity(&self) -> usize {
            self.inner.mem_capacity()
        }
        fn run_slab(&self, spec: &StencilSpec, input: &Field, steps: usize) -> Result<Field> {
            let rows = input.shape()[0] - 2 * spec.radius * steps;
            std::thread::sleep(self.per_row * rows as u32);
            self.inner.run_slab(spec, input, steps)
        }
    }

    fn delayed(eng: &str, per_row_us: u64) -> Box<dyn Worker> {
        Box::new(DelayWorker { inner: native(eng), per_row: Duration::from_micros(per_row_us) })
    }

    /// Acceptance: on a skewed worker set, the adaptive run (a) computes
    /// the same field as the static run, and (b) strictly reduces the
    /// max worker idle-time share vs the static partition.
    #[test]
    fn adaptive_retune_reduces_idle_and_preserves_field() {
        let s = spec::get("heat1d").unwrap();
        let core = Field::random(&[16], 41);
        let steps = 8;
        // worker 0 is 4x slower per row; a fair split strands worker 1.
        let make = || {
            sched(
                &s,
                1,
                vec![delayed("simd", 2000), delayed("simd", 500)],
                2,
                vec![4, 4],
                Boundary::Dirichlet(0.25),
            )
        };
        let static_sched = make();
        let mut adaptive_sched = make();
        adaptive_sched.adapt_every = 1;

        let (want, static_m) = static_sched.run(&core, steps).unwrap();
        let (got, adaptive_m) = adaptive_sched.run(&core, steps).unwrap();

        // (a) slab redistribution is numerically invisible
        assert!(got.allclose(&want, 1e-12, 1e-14), "maxdiff={}", got.max_abs_diff(&want));
        let oracle = reference_evolution(&core, &s, steps, 1, Boundary::Dirichlet(0.25));
        assert!(got.allclose(&oracle, 1e-12, 1e-14));

        // (b) the retuner moved rows to the fast worker and cut bubbles
        assert!(adaptive_m.retunes >= 1, "no retune happened");
        assert_eq!(static_m.retunes, 0);
        assert!(
            adaptive_m.ratios[1] > static_m.ratios[1],
            "fast worker share did not grow: {:?} vs {:?}",
            adaptive_m.ratios,
            static_m.ratios
        );
        let max_idle_share = |m: &RunMetrics| {
            m.worker_idle
                .iter()
                .zip(&m.worker_busy)
                .map(|(i, b)| {
                    let (i, b) = (i.as_secs_f64(), b.as_secs_f64());
                    if i + b == 0.0 {
                        0.0
                    } else {
                        i / (i + b)
                    }
                })
                .fold(0.0, f64::max)
        };
        let (si, ai) = (max_idle_share(&static_m), max_idle_share(&adaptive_m));
        assert!(ai < si, "adaptive idle share {ai:.3} not below static {si:.3}");
    }

    /// The batched run computes, for every field, exactly the bits the
    /// single-field run computes — slab decomposition and batching are
    /// numerically invisible — while amortizing dispatch per block.
    #[test]
    fn batch_run_matches_individual_runs_bitwise() {
        let s = spec::get("heat2d").unwrap();
        let sched = sched(
            &s,
            2,
            vec![native("simd"), native("autovec")],
            4,
            vec![1, 2],
            Boundary::Periodic,
        );
        let fields: Vec<Field> = (0..3).map(|i| Field::random(&[12, 8], 50 + i)).collect();
        let (outs, m) = sched.run_batch(&fields, 4).unwrap();
        assert_eq!(m.fields, 3);
        assert_eq!(m.core_cells, 3 * 12 * 8);
        for (f, out) in fields.iter().zip(&outs) {
            let (want, _) = sched.run(f, 4).unwrap();
            assert_eq!(out.data(), want.data(), "batched result must be bit-identical");
        }
        // comm scales with the batch: 2 active workers on the torus = 2
        // links, x3 fields x2 blocks
        assert_eq!(m.comm.messages, 2 * 3 * 2);
    }

    #[test]
    fn batch_rejects_empty_and_mixed_shapes() {
        let s = spec::get("heat1d").unwrap();
        let sc = sched(&s, 1, vec![native("naive")], 8, vec![1], Boundary::Dirichlet(0.0));
        assert!(sc.run_batch(&[], 1).is_err());
        let a = Field::random(&[8], 1);
        let b = Field::random(&[16], 2);
        assert!(sc.run_batch(&[a, b], 1).is_err());
    }

    /// A single-field run through the batch path keeps the historical
    /// metrics contract (fields=1, per-field cells).
    #[test]
    fn single_field_batch_metrics_unchanged() {
        let s = spec::get("heat1d").unwrap();
        let core = Field::random(&[16], 3);
        let sc = sched(&s, 1, vec![native("simd")], 16, vec![1], Boundary::Dirichlet(0.0));
        let (_, m) = sc.run(&core, 2).unwrap();
        assert_eq!(m.fields, 1);
        assert_eq!(m.core_cells, 16);
    }

    /// A static partition may ignore declared capacities; turning on
    /// `adapt_every` for the same configuration must skip the retune
    /// (not panic in the squeezer) and still complete correctly.
    #[test]
    fn adapt_skips_retune_when_capacities_cannot_cover() {
        let s = spec::get("heat1d").unwrap();
        let core = Field::random(&[16], 47);
        // 16-byte "memories": capacity_units = 0 for both workers.
        let tiny = |eng: &str| -> Box<dyn Worker> {
            Box::new(NativeWorker::new(crate::engine::by_name(eng, 1).unwrap(), 16))
        };
        let mut sc = sched(
            &s,
            1,
            vec![tiny("simd"), tiny("naive")],
            2,
            vec![4, 4],
            Boundary::Dirichlet(0.0),
        );
        sc.adapt_every = 1;
        let (got, m) = sc.run(&core, 4).unwrap();
        let want = reference_evolution(&core, &s, 4, 1, Boundary::Dirichlet(0.0));
        assert!(got.allclose(&want, 1e-12, 1e-14));
        assert_eq!(m.retunes, 0);
    }

    /// Retuning mid-run keeps the partition covering the domain exactly —
    /// the run must keep matching the oracle while shares move.
    #[test]
    fn adaptive_run_stays_correct_under_periodic() {
        let s = spec::get("heat2d").unwrap();
        let core = Field::random(&[16, 8], 43);
        let mut sc = sched(
            &s,
            1,
            vec![delayed("simd", 800), delayed("simd", 200)],
            2,
            vec![4, 4],
            Boundary::Periodic,
        );
        sc.adapt_every = 2;
        let steps = 6;
        let (got, m) = sc.run(&core, steps).unwrap();
        let want = reference::evolve_periodic(&core, &s, steps);
        assert!(got.allclose(&want, 1e-12, 1e-14), "maxdiff={}", got.max_abs_diff(&want));
        let total: f64 = m.ratios.iter().sum();
        assert!((total - 1.0).abs() < 1e-12);
        // the converged partition still covers the domain exactly
        assert_eq!(m.final_shares.iter().sum::<usize>(), 8);
    }
}
