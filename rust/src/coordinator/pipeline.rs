//! The concurrent heterogeneous pipeline driver (paper §5, Fig. 11).
//!
//! The leader holds the global extended field and drives one of two
//! loops per Tb-block:
//!
//! * **serial leader loop** (`Overlap::Off`): (0) refresh the global
//!   ghost ring from the boundary condition, (1) snapshot each worker's
//!   slab + ghost ring (the halo exchange — batched once per block, the
//!   §5.3 centralized communication launch), (2) dispatch every worker
//!   concurrently on the work-stealing pool, (3) write the slabs back,
//!   (4) optionally re-partition every `adapt_every` blocks (§5.2).
//!   Workers idle through the leader's extract/paste phases.
//!
//! * **pipelined leader loop** (`Overlap::On`/`Auto`, §5.3): the padded
//!   globals are double-buffered — the front buffer holds the state a
//!   block reads, writebacks land in the back buffer — and the whole
//!   window between repartition points runs as ONE dependency DAG on the
//!   pool: block N+1's slab assembly (ghost mapping + halo extraction)
//!   depends only on the *neighbouring* slabs' block-N writebacks, never
//!   on a block barrier, so halo traffic for the next block is prefetched
//!   while slower slabs still compute.  When `adapt_every` fires, the
//!   window ends at the repartition point and the leader falls back to
//!   the synchronous retune decision before pipelining the next window.
//!   Slab assembly is bit-identical to ghost-fill + extract (copies of
//!   the same f64 bits), so overlap on/off produce identical fields.
//!
//! Workers stay boundary-agnostic: their valid-mode slab contract only
//! consumes the ghost ring the leader hands them, so any worker species
//! (native engine or AOT artifact) serves any boundary condition.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::util::error::{Context, Result};

use crate::analyze::dynamic::{global_trace, Collector, TaskScope};
use crate::analyze::model::{TaskKind, WindowPlan};
use crate::stencil::{Boundary, Field, StencilSpec};
use crate::trace;

use super::comm::{CommLedger, CommModel};
use super::metrics::RunMetrics;
use super::partition::{capacity_units, Partition};
use super::pool::TaskGraph;
use super::tuner;
use super::worker::Worker;

/// Leader-loop mode: overlap halo exchange with compute (§5.3)?
///
/// `Auto` enables the pipelined loop whenever it can help (more than
/// one worker and more than one block); results are bit-identical
/// either way, so the knob only moves wall-clock and idle time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Overlap {
    On,
    Off,
    #[default]
    Auto,
}

impl Overlap {
    /// Whether the pipelined loop runs for this worker/block count.
    pub fn enabled(&self, workers: usize, blocks: usize) -> bool {
        match self {
            Overlap::On => blocks > 0,
            Overlap::Off => false,
            Overlap::Auto => workers > 1 && blocks > 1,
        }
    }
}

impl std::fmt::Display for Overlap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Overlap::On => write!(f, "on"),
            Overlap::Off => write!(f, "off"),
            Overlap::Auto => write!(f, "auto"),
        }
    }
}

/// CLI syntax: `--overlap on|off|auto`.
impl std::str::FromStr for Overlap {
    type Err = crate::util::error::TetrisError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "on" => Ok(Overlap::On),
            "off" => Ok(Overlap::Off),
            "auto" => Ok(Overlap::Auto),
            other => Err(crate::err!("unknown overlap mode {other:?} (expected on|off|auto)")),
        }
    }
}

pub struct Scheduler {
    pub spec: StencilSpec,
    /// Fused steps per block (every worker must support it).
    pub tb: usize,
    pub workers: Vec<Box<dyn Worker>>,
    pub partition: Partition,
    pub comm_model: CommModel,
    /// Ghost-ring physics of the global domain.
    pub boundary: Boundary,
    /// Re-partition from measured per-block busy times every this many
    /// blocks (0 = static partition).
    pub adapt_every: usize,
    /// §5.3 leader-loop mode (see [`Overlap`]).
    pub overlap: Overlap,
}

impl Scheduler {
    /// Build a scheduler for a `rows`-row domain from a tuned execution
    /// plan's Tb: one slab per worker, even row-granular split (the
    /// §5.2 profile/retune machinery refines it at run time), default
    /// comm model.  The shared constructor behind `tetris run`'s
    /// scheduler mode and the plan-resolved `--engine auto` path.
    pub fn from_plan(
        spec: StencilSpec,
        tb: usize,
        workers: Vec<Box<dyn Worker>>,
        rows: usize,
        boundary: Boundary,
        adapt_every: usize,
    ) -> Scheduler {
        let n = workers.len().max(1);
        Scheduler {
            spec,
            tb: tb.max(1),
            workers,
            partition: Partition::balanced(1, rows, &vec![1.0; n], &vec![rows; n]),
            comm_model: CommModel::default(),
            boundary,
            adapt_every,
            overlap: Overlap::Auto,
        }
    }

    /// Evolve `core` by `total_steps` (a multiple of Tb) under
    /// `self.boundary`.  Returns the final core and run metrics.
    pub fn run(&self, core: &Field, total_steps: usize) -> Result<(Field, RunMetrics)> {
        let (mut outs, metrics) = self.run_batch(std::slice::from_ref(core), total_steps)?;
        Ok((outs.pop().unwrap(), metrics))
    }

    /// Evolve a batch of same-shape fields together under one partition.
    /// Per Tb-block every worker advances its slab of *every* field in a
    /// single pool dispatch, so the per-block pool spawn, the halo
    /// snapshots, and the (migration-gated) retune decision amortize
    /// across the batch — the multi-field engine behind `serve`'s job
    /// batcher.  Slab decomposition is numerically invisible, so each
    /// returned field is bit-identical to running it alone (and overlap
    /// on/off are bit-identical too).  Returns the final fields in input
    /// order plus combined metrics (`core_cells` and comm totals sum
    /// over the batch; `fields` records the width).
    pub fn run_batch(&self, cores: &[Field], total_steps: usize) -> Result<(Vec<Field>, RunMetrics)> {
        crate::ensure!(!cores.is_empty(), "empty batch");
        crate::ensure!(
            cores.iter().all(|c| c.shape() == cores[0].shape()),
            "batch fields must share one shape"
        );
        crate::ensure!(self.tb >= 1, "tb must be >= 1");
        crate::ensure!(
            total_steps % self.tb == 0,
            "total_steps {total_steps} not a multiple of Tb {}",
            self.tb
        );
        crate::ensure!(
            !self.workers.is_empty() && self.workers.len() == self.partition.workers(),
            "workers/partition mismatch"
        );
        let spans = self.partition.spans();
        crate::ensure!(
            spans.last().unwrap().1 == cores[0].shape()[0],
            "partition covers {} rows, domain has {}",
            spans.last().unwrap().1,
            cores[0].shape()[0]
        );
        if self.partition.cols.len() > 1 {
            crate::ensure!(
                cores[0].ndim() >= 2,
                "2-D worker grid needs a field with a column axis"
            );
            crate::ensure!(
                self.partition.total_cols() == cores[0].shape()[1],
                "grid bands cover {} cols, domain has {}",
                self.partition.total_cols(),
                cores[0].shape()[1]
            );
        }
        let blocks = total_steps / self.tb;
        if self.overlap.enabled(self.workers.len(), blocks) {
            self.run_batch_pipelined(cores, total_steps)
        } else {
            self.run_batch_serial(cores, total_steps)
        }
    }

    /// The serial (block-synchronous) leader loop — see the module docs.
    fn run_batch_serial(&self, cores: &[Field], total_steps: usize) -> Result<(Vec<Field>, RunMetrics)> {
        let core0 = &cores[0];
        let nf = cores.len();
        let mut partition = self.partition.clone();
        let mut spans = partition.spans();
        let halo = self.spec.radius * self.tb;
        let nd = core0.ndim();
        let mut globals: Vec<Field> =
            cores.iter().map(|c| c.pad(halo, self.boundary.pad_value())).collect();
        let ext_rest_cells: usize = globals[0].shape()[1..].iter().product::<usize>().max(1);
        // What one internal-boundary halo message actually ships on a
        // real two-device deployment: core-row cells.  The padding of the
        // non-split dims is each device's own ghost ring, filled locally
        // from the boundary condition, never sent over the link.
        let core_rest_cells: usize = core0.shape()[1..].iter().product::<usize>().max(1);
        // Grid geometry: dim-1 cells per band plus the dims-2+ rest
        // products; 1-D fields carry the single unit-width band so the
        // per-link byte formulas stay uniform.
        let n_cols = if nd >= 2 { core0.shape()[1] } else { 1 };
        let ext2: Vec<usize> = if nd >= 2 { globals[0].shape()[2..].to_vec() } else { Vec::new() };
        let rest2: usize =
            if nd >= 2 { core0.shape()[2..].iter().product::<usize>().max(1) } else { 1 };
        let periodic = matches!(self.boundary, Boundary::Periodic);
        let mut rects = partition.rects(n_cols);
        let mut bands = partition.bands(n_cols);

        let blocks = total_steps / self.tb;
        let nw = self.workers.len();
        let mut busy = vec![Duration::ZERO; nw];
        let mut idle = vec![Duration::ZERO; nw];
        let mut comm = CommLedger::default();
        let mut retunes = 0usize;
        let mut window_busy = vec![0f64; nw];
        let mut window_blocks = 0usize;
        let mut leader_ghost = Duration::ZERO;
        let mut leader_extract = Duration::ZERO;
        let mut leader_paste = Duration::ZERO;
        let t0 = Instant::now();

        // Data-volume span args (bytes of f64 payload each leader phase
        // touches/ships), so a Perfetto track shows volume, not just
        // duration, and `tetris trace diff` can report per-phase deltas.
        let ghost_bytes = nf * (globals[0].len() - core0.len()) * 8;
        let extract_rows: usize = rects.iter().map(|&((s, e), _)| (e - s) + 2 * halo).sum();
        let paste_bytes = nf * core0.len() * 8;

        for b in 0..blocks {
            // (0) Ghost refresh from each field's current core state.
            let tg = Instant::now();
            let sp = trace::span(
                "leader",
                "ghost",
                &[("block", b.into()), ("bytes", ghost_bytes.into())],
            );
            for g in globals.iter_mut() {
                self.boundary.fill(g, halo);
            }
            drop(sp);
            leader_ghost += tg.elapsed();

            // (1) Halo snapshot: one extraction per worker per field per
            // block — the centralized communication launch.  Internal-
            // boundary bytes are what a real deployment would ship; under
            // Periodic the workers form a ring (worker 0 <-> worker
            // W-1 exchange the wrap halo too), so W workers have W
            // inter-device links instead of W-1.  A single worker's
            // wrap-around is a local copy, not a message.
            let te = Instant::now();
            // rows sums (e-s)+2·halo over worker rects (invariant under
            // retunes); bytes is the full slab snapshot.
            let ext2_cells = ext2.iter().product::<usize>().max(1);
            let snapshot_cells: usize = rects
                .iter()
                .map(|&((s, e), (c0, c1))| {
                    let r = (e - s) + 2 * halo;
                    if nd >= 2 { r * ((c1 - c0) + 2 * halo) * ext2_cells } else { r }
                })
                .sum();
            let sp = trace::span(
                "leader",
                "extract",
                &[
                    ("block", b.into()),
                    ("rows", extract_rows.into()),
                    ("bytes", (nf * snapshot_cells * 8).into()),
                ],
            );
            let inputs: Vec<Vec<Field>> = globals
                .iter()
                .map(|g| {
                    rects
                        .iter()
                        .map(|&((s, e), (c0, c1))| {
                            let mut off = vec![s];
                            let mut shape = vec![(e - s) + 2 * halo];
                            if nd >= 2 {
                                off.push(c0);
                                off.extend(vec![0usize; nd - 2]);
                                shape.push((c1 - c0) + 2 * halo);
                                shape.extend(&ext2);
                            }
                            g.extract(&off, &shape)
                        })
                        .collect()
                })
                .collect();
            drop(sp);
            leader_extract += te.elapsed();
            // Only boundaries between *non-empty* runs/bands are real
            // links: a zero-area worker holds no cells, so its
            // neighbours abut directly (and a lone active worker's wrap
            // is a local copy).  Per link, two directions x halo depth x
            // the link's cross-section, once per block.
            let exchanges =
                super::comm::grid_exchanges(&spans, &bands, halo, rest2, periodic);
            for _ in 0..nf {
                for &bytes in &exchanges {
                    comm.record_exchange(bytes, self.tb);
                }
            }

            // (2) One concurrent dispatch over all (field, worker) slabs.
            // bytes = this block's inter-device halo traffic (the same
            // quantity the CommLedger records above).
            let sp = trace::span(
                "leader",
                "dispatch",
                &[
                    ("block", b.into()),
                    ("bytes", (nf * exchanges.iter().sum::<usize>()).into()),
                ],
            );
            let results = dispatch(&self.workers, &self.spec, &inputs, self.tb, halo);
            drop(sp);

            // (3) Writeback + accounting.  A worker's block busy time is
            // the sum over its fields; bubbles are judged against the
            // slowest worker, exactly as in the single-field run.
            let mut block_busy = vec![Duration::ZERO; nw];
            for per_field in &results {
                for (w, (_, dt)) in per_field.iter().enumerate() {
                    block_busy[w] += *dt;
                }
            }
            let slowest = block_busy.iter().copied().max().unwrap_or_default();
            let tp = Instant::now();
            let sp = trace::span(
                "leader",
                "paste",
                &[("block", b.into()), ("bytes", paste_bytes.into())],
            );
            for (f, per_field) in results.into_iter().enumerate() {
                for (i, ((res, _), &((s, _e), (c0, _c1)))) in
                    per_field.into_iter().zip(&rects).enumerate()
                {
                    let out = res.with_context(|| format!("worker {i} failed (field {f})"))?;
                    let mut off = vec![s + halo];
                    if nd >= 2 {
                        off.push(c0 + halo);
                        off.extend(vec![halo; nd - 2]);
                    }
                    globals[f].paste(&off, &out);
                }
            }
            drop(sp);
            leader_paste += tp.elapsed();
            for i in 0..nw {
                busy[i] += block_busy[i];
                idle[i] += slowest - block_busy[i];
                window_busy[i] += block_busy[i].as_secs_f64();
            }

            // (4) §5.2 architecture-aware rebalance: slab redistribution
            // through Partition::spans, fed by the measured busy times
            // and gated by the slab-migration cost model (hysteresis:
            // a marginal imbalance is not worth shipping slabs for).
            window_blocks += 1;
            if self.adapt_every > 0 && window_blocks >= self.adapt_every && b + 1 < blocks {
                let per_block: Vec<f64> =
                    window_busy.iter().map(|t| t / window_blocks as f64).collect();
                if let Some(next) = self.retune_decision(
                    &partition,
                    &per_block,
                    ext_rest_cells,
                    core_rest_cells,
                    blocks - (b + 1),
                ) {
                    partition = next;
                    spans = partition.spans();
                    rects = partition.rects(n_cols);
                    bands = partition.bands(n_cols);
                    retunes += 1;
                }
                window_busy.fill(0.0);
                window_blocks = 0;
            }
        }

        let metrics = RunMetrics {
            total_steps,
            blocks,
            fields: nf,
            core_cells: core0.len() * nf,
            elapsed: t0.elapsed(),
            worker_names: self.workers.iter().map(|w| w.name()).collect(),
            worker_busy: busy,
            worker_idle: idle,
            comm,
            ratios: (0..nw).map(|i| partition.ratio(i)).collect(),
            final_shares: partition.shares.clone(),
            final_bands: partition.cols.clone(),
            retunes,
            overlap: false,
            overlap_hidden: Duration::ZERO,
            leader_ghost,
            leader_extract,
            leader_paste,
        };
        Ok((globals.into_iter().map(|g| g.unpad(halo)).collect(), metrics))
    }

    /// The §5.3 pipelined leader loop — see the module docs.  Processes
    /// blocks in windows of `adapt_every` (the whole run when static),
    /// each window one dependency DAG on the pool: per `(block, field,
    /// worker)` an assemble → compute → writeback chain, where block
    /// N+1's assembly depends only on its *neighbouring* slabs' block-N
    /// writebacks (double-buffered globals make the read and write sides
    /// disjoint), so halo prefetch hides under the slower slabs' compute.
    fn run_batch_pipelined(
        &self,
        cores: &[Field],
        total_steps: usize,
    ) -> Result<(Vec<Field>, RunMetrics)> {
        let core0 = &cores[0];
        let nf = cores.len();
        let mut partition = self.partition.clone();
        let mut spans = partition.spans();
        let halo = self.spec.radius * self.tb;
        let nd = core0.ndim();
        let n_rows = core0.shape()[0];
        let ext_rest_cells: usize =
            core0.shape()[1..].iter().map(|n| n + 2 * halo).product::<usize>().max(1);
        let core_rest_cells: usize = core0.shape()[1..].iter().product::<usize>().max(1);
        // Grid geometry (see run_batch_serial): per-band dim-1 spans
        // plus the dims-2+ rest products behind the per-link byte and
        // slab-volume formulas.
        let n_cols = if nd >= 2 { core0.shape()[1] } else { 1 };
        let ext_rest2: usize = if nd >= 2 {
            core0.shape()[2..].iter().map(|n| n + 2 * halo).product::<usize>().max(1)
        } else {
            1
        };
        let core_rest2: usize =
            if nd >= 2 { core0.shape()[2..].iter().product::<usize>().max(1) } else { 1 };
        let periodic = matches!(self.boundary, Boundary::Periodic);
        let mut rects = partition.rects(n_cols);
        let mut bands = partition.bands(n_cols);
        let blocks = total_steps / self.tb;
        let nw = self.workers.len();
        let tb = self.tb;
        let boundary = self.boundary;
        let spec = &self.spec;
        let workers = &self.workers;

        // Double buffer: parity b%2 holds the state block b reads; its
        // writebacks land in parity (b+1)%2.  Neither buffer's ghost
        // ring is ever read (assembly maps ghosts from core rows), so no
        // ring fill happens at all in this mode.
        let mut front: Vec<Field> =
            cores.iter().map(|c| c.pad(halo, self.boundary.pad_value())).collect();
        let mut back: Vec<Field> = front.clone();
        // Tag each parity buffer for the debug-build dynamic validator:
        // region traffic on these fields is logged per task and checked
        // against the window plan's declared summaries (release: no-op).
        for (f, buf) in front.iter_mut().enumerate() {
            buf.set_trace(global_trace(f, 0));
        }
        for (f, buf) in back.iter_mut().enumerate() {
            buf.set_trace(global_trace(f, 1));
        }
        // RwLock so concurrent assembles of one field share read access
        // (writebacks target the other parity, so within a block readers
        // and writers never meet; across blocks the DAG orders them).
        let buffers: [Vec<RwLock<Field>>; 2] = [
            front.into_iter().map(RwLock::new).collect(),
            back.into_iter().map(RwLock::new).collect(),
        ];

        let mut busy = vec![Duration::ZERO; nw];
        let mut idle = vec![Duration::ZERO; nw];
        let mut comm = CommLedger::default();
        let mut retunes = 0usize;
        let mut overlap_hidden = Duration::ZERO;
        let mut leader_extract = Duration::ZERO;
        let mut leader_paste = Duration::ZERO;
        let t0 = Instant::now();

        let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        // Same compute width as the serial dispatch, plus one slot so a
        // copy task can run while every compute slot is busy.
        let threads = (nw * nf + 1).min(nw.max(host) + 1).max(2);
        // Static runs still window the DAG: task slots are O(window x
        // fields x workers), so an uncapped 100k-block run would box
        // 300k closures up front for prefetch depth nobody needs —
        // one block of lookahead is the whole win.
        const MAX_WINDOW: usize = 256;
        let window = if self.adapt_every > 0 { self.adapt_every } else { MAX_WINDOW };

        // One tag per pipelined run: its stage spans stay separable from
        // concurrent schedulers (serve sessions, parallel tests) in a
        // shared trace, and `tetris trace check` scopes the task-id
        // universe per tag.
        let sched_tag = trace::fresh_tag();

        let mut b0 = 0usize;
        while b0 < blocks {
            let bw = window.min(blocks - b0);
            // The window's DAG is *derived from* its analyzable plan:
            // dependencies and access summaries come straight out of
            // `WindowPlan::build` (which owns the symmetric-owner
            // wiring), and the closures below are registered in plan
            // order — so the graph the race checker certifies is the
            // graph the pool executes, by construction.
            let plan = WindowPlan::build_grid(&spans, &bands, halo, n_rows, n_cols, boundary, nf, b0, bw);
            // Announce the window geometry so `tetris trace check` can
            // bound this tag's task-id universe (3·bw·nf·nw).
            trace::instant(
                "pipeline",
                "window",
                &[
                    ("b0", b0.into()),
                    ("bw", bw.into()),
                    ("nf", nf.into()),
                    ("nw", nw.into()),
                    ("sched", sched_tag.into()),
                ],
            );
            // Debug-build sink for the tasks' observed region traffic.
            let collector = Collector::shared();
            // Per-window flow namespace: each (block,field,worker) chain
            // gets one `chain` flow (assemble s → compute t → writeback
            // f), id = window_tag<<20 | slot, so flows from concurrent
            // windows/schedulers never collide.
            let window_tag = trace::fresh_tag();
            let nslots = bw * nf * nw;
            let inputs: Vec<Mutex<Option<Field>>> = (0..nslots).map(|_| Mutex::new(None)).collect();
            let outputs: Vec<Mutex<Option<Field>>> =
                (0..nslots).map(|_| Mutex::new(None)).collect();
            let busy_ns: Vec<AtomicU64> = (0..bw * nw).map(|_| AtomicU64::new(0)).collect();
            let extract_ns = AtomicU64::new(0);
            let paste_ns = AtomicU64::new(0);
            let hidden_ns = AtomicU64::new(0);
            let inflight = AtomicUsize::new(0);
            let block_overlapped: Vec<AtomicBool> = (0..bw).map(|_| AtomicBool::new(false)).collect();
            let failures: Mutex<Vec<String>> = Mutex::new(Vec::new());
            // First failure flips this; the rest of the window's tasks
            // degrade to no-ops so a doomed run drains fast instead of
            // computing every remaining block before reporting.
            let aborted = AtomicBool::new(false);

            {
                let bufs = &buffers;
                let rects_r = &rects;
                let inputs_r = &inputs;
                let outputs_r = &outputs;
                let busy_r = &busy_ns;
                let extract_r = &extract_ns;
                let paste_r = &paste_ns;
                let hidden_r = &hidden_ns;
                let inflight_r = &inflight;
                let overlapped_r = &block_overlapped;
                let failures_r = &failures;
                let aborted_r = &aborted;
                let collector_r = &collector;

                // Memory-ordering notes for the atomics below:
                //  * `aborted` is Release on store / Acquire on load —
                //    the failing task pushes its message *then* raises
                //    the flag, and any task that observes the flag must
                //    also observe the message (and skip stale work).
                //  * The metrics counters (extract/paste/hidden ns,
                //    per-slab busy ns, `inflight`, `block_overlapped`)
                //    stay Relaxed on purpose: they are monotone
                //    accumulators that only need atomicity, and every
                //    read happens after the pool joins — a full
                //    happens-before point — so stronger orderings would
                //    buy nothing.
                let mut g = TaskGraph::new();
                for (tid, m) in plan.meta.iter().enumerate() {
                    let (k, b, f, w) = (m.k, m.block, m.field, m.worker);
                    let read_par = b % 2;
                    let write_par = (b + 1) % 2;
                    let idx = (k * nf + f) * nw + w;
                    let ((s, e), (c0, c1)) = rects_r[w];
                    let deps = plan.model.deps[tid].clone();
                    let access = plan.model.accesses[tid].clone();
                    // Slab geometry for the volume args: assemble/compute
                    // move the padded slab, writeback the unpadded core.
                    let slab_rows = (e - s) + 2 * halo;
                    let slab_cells = slab_rows
                        * if nd >= 2 { ((c1 - c0) + 2 * halo) * ext_rest2 } else { 1 };
                    let out_rows = e - s;
                    let out_cells =
                        out_rows * if nd >= 2 { (c1 - c0) * core_rest2 } else { 1 };
                    let chain = (window_tag << 20) | idx as u64;
                    let id = match m.kind {
                        // Assemble: the §5.3 prefetch.  Its plan deps are
                        // only the neighbouring slabs' previous-block
                        // writebacks, never a whole-block barrier.
                        TaskKind::Assemble => g.add_with_access(
                            move || {
                                let _scope = TaskScope::enter(collector_r, tid);
                                let _span = trace::span(
                                    "pipeline",
                                    "assemble",
                                    &[
                                        ("task", tid.into()),
                                        ("block", b.into()),
                                        ("field", f.into()),
                                        ("worker", w.into()),
                                        ("sched", sched_tag.into()),
                                        ("rows", slab_rows.into()),
                                        ("slab_cells", slab_cells.into()),
                                        ("bytes", (slab_cells * 8).into()),
                                    ],
                                );
                                trace::flow_start("pipeline", "chain", chain, &[]);
                                if aborted_r.load(Ordering::Acquire) {
                                    return;
                                }
                                let t = Instant::now();
                                let slab = {
                                    let gbuf = bufs[read_par][f].read().unwrap();
                                    assemble_slab(&gbuf, s, e, c0, c1, halo, boundary)
                                };
                                *inputs_r[idx].lock().unwrap() = Some(slab);
                                let dt = t.elapsed().as_nanos() as u64;
                                extract_r.fetch_add(dt, Ordering::Relaxed);
                                if inflight_r.load(Ordering::Relaxed) > 0 {
                                    hidden_r.fetch_add(dt, Ordering::Relaxed);
                                    overlapped_r[k].store(true, Ordering::Relaxed);
                                }
                            },
                            deps,
                            access,
                        ),
                        // Compute: same zero-share skip as dispatch().
                        TaskKind::Compute => g.add_with_access(
                            move || {
                                let _scope = TaskScope::enter(collector_r, tid);
                                let _span = trace::span(
                                    "pipeline",
                                    "compute",
                                    &[
                                        ("task", tid.into()),
                                        ("block", b.into()),
                                        ("field", f.into()),
                                        ("worker", w.into()),
                                        ("sched", sched_tag.into()),
                                        ("rows", slab_rows.into()),
                                        ("slab_cells", slab_cells.into()),
                                        ("bytes", (slab_cells * 8).into()),
                                    ],
                                );
                                trace::flow_step("pipeline", "chain", chain, &[]);
                                // None = assembly skipped by an abort
                                let Some(input) = inputs_r[idx].lock().unwrap().take() else {
                                    return;
                                };
                                if aborted_r.load(Ordering::Acquire) {
                                    return;
                                }
                                if let Some(out) = empty_slab_output(&input, halo) {
                                    *outputs_r[idx].lock().unwrap() = Some(out);
                                    return;
                                }
                                inflight_r.fetch_add(1, Ordering::Relaxed);
                                let t = Instant::now();
                                let res = workers[w].run_slab(spec, &input, tb);
                                let dt = t.elapsed();
                                inflight_r.fetch_sub(1, Ordering::Relaxed);
                                busy_r[k * nw + w]
                                    .fetch_add(dt.as_nanos() as u64, Ordering::Relaxed);
                                match res {
                                    Ok(out) => {
                                        *outputs_r[idx].lock().unwrap() = Some(out);
                                    }
                                    Err(err) => {
                                        failures_r.lock().unwrap().push(format!(
                                            "worker {w} failed (field {f}, block {b}): {err}"
                                        ));
                                        aborted_r.store(true, Ordering::Release);
                                    }
                                }
                            },
                            deps,
                            access,
                        ),
                        // Writeback into the back buffer.
                        TaskKind::Writeback => g.add_with_access(
                            move || {
                                let _scope = TaskScope::enter(collector_r, tid);
                                let _span = trace::span(
                                    "pipeline",
                                    "writeback",
                                    &[
                                        ("task", tid.into()),
                                        ("block", b.into()),
                                        ("field", f.into()),
                                        ("worker", w.into()),
                                        ("sched", sched_tag.into()),
                                        ("rows", out_rows.into()),
                                        ("slab_cells", out_cells.into()),
                                        ("bytes", (out_cells * 8).into()),
                                    ],
                                );
                                trace::flow_finish("pipeline", "chain", chain, &[]);
                                let t = Instant::now();
                                let taken = outputs_r[idx].lock().unwrap().take();
                                if let Some(out) = taken {
                                    let mut off = vec![s + halo];
                                    if nd >= 2 {
                                        off.push(c0 + halo);
                                        off.extend(vec![halo; nd - 2]);
                                    }
                                    bufs[write_par][f].write().unwrap().paste(&off, &out);
                                }
                                let dt = t.elapsed().as_nanos() as u64;
                                paste_r.fetch_add(dt, Ordering::Relaxed);
                                if inflight_r.load(Ordering::Relaxed) > 0 {
                                    hidden_r.fetch_add(dt, Ordering::Relaxed);
                                    overlapped_r[k].store(true, Ordering::Relaxed);
                                }
                            },
                            deps,
                            access,
                        ),
                    };
                    debug_assert_eq!(id, tid, "plan/graph id drift");
                }
                // Certify the DAG we are about to run (no-op in release).
                g.assert_race_free();
                g.run(threads);
            }

            // Debug builds: the tasks' observed Field traffic must stay
            // within what the plan declared (trivially Ok in release).
            if let Err(msg) = collector.validate(&plan.model.accesses) {
                panic!("pipelined window failed dynamic access validation: {msg}");
            }

            if let Some(msg) = failures.into_inner().unwrap().into_iter().next() {
                crate::bail!("{msg}");
            }

            // Per-block accounting, identical quantities to the serial
            // loop (busy from the timed compute tasks, idle against the
            // slowest slab, comm counts from the grid topology).
            let exchanges = super::comm::grid_exchanges(&spans, &bands, halo, core_rest2, periodic);
            for k in 0..bw {
                let mut block_busy = vec![Duration::ZERO; nw];
                for w in 0..nw {
                    block_busy[w] =
                        Duration::from_nanos(busy_ns[k * nw + w].load(Ordering::Relaxed));
                }
                let slowest = block_busy.iter().copied().max().unwrap_or_default();
                for w in 0..nw {
                    busy[w] += block_busy[w];
                    idle[w] += slowest - block_busy[w];
                }
                for _ in 0..nf {
                    for &bytes in &exchanges {
                        comm.record_exchange(bytes, tb);
                    }
                }
                if block_overlapped[k].load(Ordering::Relaxed) {
                    comm.record_overlapped(exchanges.len() * nf);
                }
            }
            leader_extract += Duration::from_nanos(extract_ns.load(Ordering::Relaxed));
            leader_paste += Duration::from_nanos(paste_ns.load(Ordering::Relaxed));
            overlap_hidden += Duration::from_nanos(hidden_ns.load(Ordering::Relaxed));

            // §5.2 retune at the window boundary — the synchronous
            // fallback the pipelined windows bracket.
            if self.adapt_every > 0 && b0 + bw < blocks && bw >= self.adapt_every {
                let per_block: Vec<f64> = (0..nw)
                    .map(|w| {
                        (0..bw)
                            .map(|k| busy_ns[k * nw + w].load(Ordering::Relaxed) as f64 * 1e-9)
                            .sum::<f64>()
                            / bw as f64
                    })
                    .collect();
                if let Some(next) = self.retune_decision(
                    &partition,
                    &per_block,
                    ext_rest_cells,
                    core_rest_cells,
                    blocks - (b0 + bw),
                ) {
                    partition = next;
                    spans = partition.spans();
                    rects = partition.rects(n_cols);
                    bands = partition.bands(n_cols);
                    retunes += 1;
                }
            }
            b0 += bw;
        }

        let final_par = blocks % 2;
        let [par0, par1] = buffers;
        let chosen = if final_par == 0 { par0 } else { par1 };
        let outs: Vec<Field> = chosen
            .into_iter()
            .map(|m| m.into_inner().unwrap().unpad(halo))
            .collect();

        let metrics = RunMetrics {
            total_steps,
            blocks,
            fields: nf,
            core_cells: core0.len() * nf,
            elapsed: t0.elapsed(),
            worker_names: self.workers.iter().map(|w| w.name()).collect(),
            worker_busy: busy,
            worker_idle: idle,
            comm,
            ratios: (0..nw).map(|i| partition.ratio(i)).collect(),
            final_shares: partition.shares.clone(),
            final_bands: partition.cols.clone(),
            retunes,
            overlap: true,
            overlap_hidden,
            leader_ghost: Duration::ZERO,
            leader_extract,
            leader_paste,
        };
        Ok((outs, metrics))
    }

    /// The shared §5.2 retune decision: feed measured window-mean busy
    /// times to the migration-gated tuner, skipping (rather than
    /// panicking mid-run) when the declared capacities cannot cover a
    /// hand-built static partition.
    fn retune_decision(
        &self,
        partition: &Partition,
        per_block: &[f64],
        ext_rest_cells: usize,
        core_rest_cells: usize,
        blocks_left: usize,
    ) -> Option<Partition> {
        let tmax = per_block.iter().cloned().fold(0.0, f64::max);
        if tmax <= 0.0 {
            return None;
        }
        let grid = partition.cols.len() > 1;
        if !grid {
            let caps_cover = self
                .workers
                .iter()
                .map(|w| capacity_units(w.mem_capacity(), partition.unit, ext_rest_cells))
                .sum::<usize>()
                >= partition.total_units();
            if !caps_cover {
                return None;
            }
        }
        // A zero-area worker measured ~nothing; feed it the slowest
        // time so its exploration weight stays modest.
        let cells = partition.worker_cells(1);
        let measured: Vec<f64> = cells
            .iter()
            .zip(per_block)
            .map(|(&c, &t)| if c == 0 || t <= 0.0 { tmax } else { t })
            .collect();
        if grid {
            // Per-axis rest products: the tuner's grid path reasons in
            // (row x col) cells, so rest means dims 2+ only.
            let halo = self.spec.radius * self.tb;
            let ext_rest2 = ext_rest_cells / (partition.total_cols() + 2 * halo).max(1);
            let core_rest2 = (core_rest_cells / partition.total_cols().max(1)).max(1);
            return tuner::retune_gated_grid(
                partition,
                &measured,
                &self.workers,
                ext_rest2.max(1),
                &self.comm_model,
                core_rest2,
                blocks_left,
            );
        }
        tuner::retune_gated(
            partition,
            &measured,
            &self.workers,
            ext_rest_cells,
            &self.comm_model,
            core_rest_cells,
            blocks_left,
        )
    }
}

/// The zero-share slab contract, shared by both leader loops: a slab
/// whose core was squeezed/retuned to 0 rows (input = bare ghost ring)
/// is never handed to an engine — it yields an empty result of the
/// unpadded shape.  Returns `None` for slabs that must actually compute.
fn empty_slab_output(input: &Field, halo: usize) -> Option<Field> {
    let empty_rows = input.shape()[0] == 2 * halo;
    let empty_cols = input.ndim() >= 2 && input.shape()[1] == 2 * halo;
    if !empty_rows && !empty_cols {
        return None;
    }
    let shape: Vec<usize> = input.shape().iter().map(|&n| n - 2 * halo).collect();
    Some(Field::zeros(&shape))
}

/// Assemble worker slab input for core rect `[s, e) × [c0, c1)` directly
/// from the padded global's **core cells** (its ghost ring may be
/// stale): every value is either a copy of a core cell (split-dim rows
/// and columns via the boundary's index map, non-split-dim ghosts via
/// the same axis passes as [`Boundary::fill`]) or the Dirichlet wall
/// constant — bit-identical to `boundary.fill(global);
/// global.extract(...)` over the rect's padded window.  1-D fields have
/// no column axis and ignore `(c0, c1)`.
pub(crate) fn assemble_slab(
    global: &Field,
    s: usize,
    e: usize,
    c0: usize,
    c1: usize,
    halo: usize,
    boundary: Boundary,
) -> Field {
    let nd = global.ndim();
    let gshape = global.shape().to_vec();
    let n_rows = gshape[0] - 2 * halo;
    let rows = (e - s) + 2 * halo;
    if nd == 1 {
        let mut out = Field::zeros(&[rows]);
        for i in 0..rows {
            match boundary.source_index(s + i, halo, n_rows) {
                Some(src) => out.copy_region_from(global, &[src], &[i], &[1]),
                None => out.fill_region(&[i], &[1], boundary.pad_value()),
            }
        }
        return out;
    }
    let n_cols = gshape[1] - 2 * halo;
    let cols = (c1 - c0) + 2 * halo;
    let mut shape = vec![rows, cols];
    shape.extend(&gshape[2..]);
    let mut out = Field::zeros(&shape);
    let rest_core_cnt: Vec<usize> = gshape[2..].iter().map(|n| n - 2 * halo).collect();
    // Identity columns: the padded window's overlap with the global's
    // core columns `[halo, halo + n_cols)` — copied in place in one run
    // per row.  Everything outside is a dim-1 ghost of this rect,
    // mapped column by column exactly like the dim-0 rows.
    let id_lo = c0.max(halo);
    let id_hi = (c1 + 2 * halo).min(halo + n_cols);
    for i in 0..rows {
        let pr = s + i;
        let Some(src) = boundary.source_index(pr, halo, n_rows) else {
            // Dirichlet ghost row: wall constant across the whole row.
            let mut off = vec![i, 0];
            off.extend(vec![0; nd - 2]);
            let mut cnt = vec![1, cols];
            cnt.extend(&gshape[2..]);
            out.fill_region(&off, &cnt, boundary.pad_value());
            continue;
        };
        if id_lo < id_hi {
            let mut soff = vec![src, id_lo];
            soff.extend(vec![halo; nd - 2]);
            let mut doff = vec![i, id_lo - c0];
            doff.extend(vec![halo; nd - 2]);
            let mut cnt = vec![1, id_hi - id_lo];
            cnt.extend(&rest_core_cnt);
            out.copy_region_from(global, &soff, &doff, &cnt);
        }
        for pc in (c0..id_lo).chain(id_hi..c1 + 2 * halo) {
            match boundary.source_index(pc, halo, n_cols) {
                Some(srcc) => {
                    let mut soff = vec![src, srcc];
                    soff.extend(vec![halo; nd - 2]);
                    let mut doff = vec![i, pc - c0];
                    doff.extend(vec![halo; nd - 2]);
                    let mut cnt = vec![1, 1];
                    cnt.extend(&rest_core_cnt);
                    out.copy_region_from(global, &soff, &doff, &cnt);
                }
                None => {
                    let mut off = vec![i, pc - c0];
                    off.extend(vec![0; nd - 2]);
                    let mut cnt = vec![1, 1];
                    cnt.extend(&gshape[2..]);
                    out.fill_region(&off, &cnt, boundary.pad_value());
                }
            }
        }
    }
    // Non-split-dim ghost faces: the same axis-by-axis passes as the
    // global ring fill, restricted to this slab's rows/cols — each pass
    // sources coordinates whose earlier axes were already mapped, so
    // corners come out all-axes-mapped exactly like the full fill.
    for d in 2..nd {
        match boundary {
            Boundary::Dirichlet(v) => {
                let mut cnt = shape.clone();
                cnt[d] = halo;
                let mut off = vec![0; nd];
                out.fill_region(&off, &cnt, v);
                off[d] = shape[d] - halo;
                out.fill_region(&off, &cnt, v);
            }
            _ => {
                let core_d = gshape[d] - 2 * halo;
                let mut cnt = shape.clone();
                cnt[d] = 1;
                for ghost in (0..halo).chain(shape[d] - halo..shape[d]) {
                    let src = boundary
                        .source_index(ghost, halo, core_d)
                        .expect("non-Dirichlet ghosts always map");
                    let mut soff = vec![0; nd];
                    soff[d] = src;
                    let mut doff = vec![0; nd];
                    doff[d] = ghost;
                    out.copy_region_within(&soff, &doff, &cnt);
                }
            }
        }
    }
    out
}

/// Per span of one axis: which spans own the core cells its padded
/// window `[s, e + 2*halo)` reads through the boundary's index map —
/// the *forward* (read-direction) scan, before any symmetrization.
fn forward_scan_owners(
    spans: &[(usize, usize)],
    halo: usize,
    n: usize,
    boundary: Boundary,
) -> Vec<BTreeSet<usize>> {
    let owner_of = |r: usize| spans.iter().position(|&(a, b)| r >= a && r < b);
    spans
        .iter()
        .map(|&(s, e)| {
            let mut need = BTreeSet::new();
            for pr in s..e + 2 * halo {
                if let Some(src) = boundary.source_index(pr, halo, n) {
                    if let Some(o) = owner_of(src - halo) {
                        need.insert(o);
                    }
                }
            }
            need
        })
        .collect()
}

/// Close the read sets under symmetry: if A reads cells B owns, B also
/// waits on A's previous-block writeback — the anti-dependency that
/// keeps the two-buffer scheme race-free by construction.
fn symmetrize(mut owners: Vec<BTreeSet<usize>>) -> Vec<Vec<usize>> {
    for w in 0..owners.len() {
        let reads: Vec<usize> = owners[w].iter().copied().collect();
        for o in reads {
            owners[o].insert(w);
        }
    }
    owners.into_iter().map(|set| set.into_iter().collect()).collect()
}

/// For each worker: which workers own the core rows its slab assembly
/// reads (direct `[s-halo, e+halo)` neighbourhood plus boundary-mapped
/// edge rows), symmetrized.
pub(crate) fn symmetric_owners(
    spans: &[(usize, usize)],
    halo: usize,
    n_rows: usize,
    boundary: Boundary,
) -> Vec<Vec<usize>> {
    symmetrize(forward_scan_owners(spans, halo, n_rows, boundary))
}

/// 2-D owner sets for a `bands.len() × rows.len()` worker grid
/// (`w = gy * wx + gx`): each worker's forward read set is the
/// *product* of its per-axis forward scans — its halo rect reads rows
/// owned by the X-scan runs and columns owned by the Y-scan bands, so
/// edge AND corner neighbours appear — then the whole set is
/// symmetrized at the worker level.  Symmetrizing per axis *before*
/// taking the product would over-approximate: a (zero-row, live-col)
/// tile and a (live-row, zero-col) tile share no cells in either
/// direction, and the product of symmetrized axis sets would still
/// link them (a conflict-free edge the checker flags as over-sync).
pub(crate) fn symmetric_owners_grid(
    rows: &[(usize, usize)],
    bands: &[(usize, usize)],
    halo: usize,
    n_rows: usize,
    n_cols: usize,
    boundary: Boundary,
) -> Vec<Vec<usize>> {
    let xscan = forward_scan_owners(rows, halo, n_rows, boundary);
    let yscan = forward_scan_owners(bands, halo, n_cols, boundary);
    let wx = rows.len();
    let mut owners: Vec<BTreeSet<usize>> = Vec::with_capacity(wx * bands.len());
    for gy in 0..bands.len() {
        for gx in 0..wx {
            let mut need = BTreeSet::new();
            for &oy in &yscan[gy] {
                for &ox in &xscan[gx] {
                    need.insert(oy * wx + ox);
                }
            }
            owners.push(need);
        }
    }
    symmetrize(owners)
}

/// Run every (field, worker) slab concurrently on one pool scope; returns
/// per-field, per-worker (result, busy time) in order.  `inputs` is
/// indexed `[field][worker]`.  Pools are ephemeral per call, so
/// engine-internal tile pools nested inside a worker stay independent of
/// this dispatch scope.  A worker whose slab has zero core rows (share
/// squeezed/retuned to 0) is skipped and yields an empty result.  Thread
/// count grows with the batch but never oversubscribes the host.
fn dispatch(
    workers: &[Box<dyn Worker>],
    spec: &StencilSpec,
    inputs: &[Vec<Field>],
    tb: usize,
    halo: usize,
) -> Vec<Vec<(Result<Field>, Duration)>> {
    let nw = workers.len();
    let nf = inputs.len();
    let host = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let threads = (nw * nf).min(nw.max(host));
    let mut flat = super::pool::steal_map(threads, nw * nf, |i| {
        let (f, w) = (i / nw, i % nw);
        let input = &inputs[f][w];
        if let Some(out) = empty_slab_output(input, halo) {
            return (Ok(out), Duration::ZERO);
        }
        let t0 = Instant::now();
        let res = workers[w].run_slab(spec, input, tb);
        (res, t0.elapsed())
    });
    let mut out = Vec::with_capacity(nf);
    for _ in 0..nf {
        out.push(flat.drain(..nw).collect());
    }
    out
}

/// Single-worker reference evolution with the same leader-side boundary
/// semantics — used by tests and by the thermal case study's "Naive" row.
pub fn reference_evolution(
    core: &Field,
    spec: &StencilSpec,
    total_steps: usize,
    tb: usize,
    boundary: Boundary,
) -> Field {
    assert_eq!(total_steps % tb, 0);
    let halo = spec.radius * tb;
    let mut global = core.pad(halo, boundary.pad_value());
    for _ in 0..total_steps / tb {
        boundary.fill(&mut global, halo);
        let out = crate::stencil::reference::block(&global, spec, tb);
        global.paste(&vec![halo; core.ndim()], &out);
    }
    global.unpad(halo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::NativeWorker;
    use crate::stencil::{reference, spec};

    fn native(name: &str) -> Box<dyn Worker> {
        Box::new(NativeWorker::new(crate::engine::by_name(name, 1).unwrap(), 1 << 30))
    }

    fn sched(
        s: &StencilSpec,
        tb: usize,
        workers: Vec<Box<dyn Worker>>,
        unit: usize,
        shares: Vec<usize>,
        boundary: Boundary,
    ) -> Scheduler {
        Scheduler {
            spec: s.clone(),
            tb,
            workers,
            partition: Partition::rows(unit, shares),
            comm_model: CommModel::default(),
            boundary,
            adapt_every: 0,
            overlap: Overlap::Off,
        }
    }

    fn gsched(
        s: &StencilSpec,
        tb: usize,
        workers: Vec<Box<dyn Worker>>,
        unit: usize,
        shares: Vec<usize>,
        cols: Vec<usize>,
        boundary: Boundary,
    ) -> Scheduler {
        Scheduler {
            spec: s.clone(),
            tb,
            workers,
            partition: Partition::rows(unit, shares).with_bands(cols),
            comm_model: CommModel::default(),
            boundary,
            adapt_every: 0,
            overlap: Overlap::Off,
        }
    }

    #[test]
    fn hetero_run_matches_reference_evolution() {
        for bench in ["heat1d", "heat2d", "box2d25p", "heat3d"] {
            let s = spec::get(bench).unwrap();
            let mut shape = vec![24usize];
            shape.extend(vec![10usize; s.ndim - 1]);
            let core = Field::random(&shape, 17);
            let tb = 2;
            let sched = sched(
                &s,
                tb,
                vec![native("simd"), native("autovec"), native("tetris-cpu")],
                4,
                vec![2, 1, 3],
                Boundary::Dirichlet(0.5),
            );
            let (got, metrics) = sched.run(&core, 8).unwrap();
            let want = reference_evolution(&core, &s, 8, tb, Boundary::Dirichlet(0.5));
            assert!(
                got.allclose(&want, 1e-12, 1e-14),
                "{bench}: maxdiff={}",
                got.max_abs_diff(&want)
            );
            assert_eq!(metrics.blocks, 4);
            assert_eq!(metrics.comm.messages, 2 * 4); // 2 boundaries x 4 blocks
            // Each batched exchange ships core-row cells only — the
            // non-split-dim padding is locally-filled ghosts, not traffic.
            let halo = s.radius * tb;
            let core_rest: usize = shape[1..].iter().product::<usize>().max(1);
            assert_eq!(
                metrics.comm.bytes,
                metrics.comm.messages * 2 * halo * core_rest * 8,
                "{bench}"
            );
        }
    }

    #[test]
    fn from_plan_builds_even_partition_and_runs() {
        let s = spec::get("heat2d").unwrap();
        let sc = Scheduler::from_plan(
            s.clone(),
            2,
            vec![native("simd"), native("autovec")],
            16,
            Boundary::Periodic,
            0,
        );
        assert_eq!(sc.partition.total_units(), 16);
        assert_eq!(sc.partition.shares, vec![8, 8]);
        assert_eq!(sc.overlap, Overlap::Auto);
        let core = Field::random(&[16, 8], 91);
        let (got, _) = sc.run(&core, 4).unwrap();
        let want = reference::evolve_periodic(&core, &s, 4);
        assert!(got.allclose(&want, 1e-12, 1e-14), "maxdiff={}", got.max_abs_diff(&want));
    }

    #[test]
    fn single_worker_covers_domain() {
        let s = spec::get("heat2d").unwrap();
        let core = Field::random(&[16, 8], 18);
        let sched = sched(&s, 1, vec![native("naive")], 16, vec![1], Boundary::Dirichlet(0.0));
        let (got, m) = sched.run(&core, 3).unwrap();
        let want = reference_evolution(&core, &s, 3, 1, Boundary::Dirichlet(0.0));
        assert!(got.allclose(&want, 1e-12, 0.0));
        assert_eq!(m.comm.messages, 0); // no internal boundary
    }

    #[test]
    fn rejects_partition_mismatch() {
        let s = spec::get("heat1d").unwrap();
        let core = Field::random(&[20], 19);
        // 12 != 20 rows
        let sched = sched(&s, 1, vec![native("naive")], 4, vec![3], Boundary::Dirichlet(0.0));
        assert!(sched.run(&core, 1).is_err());
    }

    #[test]
    fn rejects_non_multiple_steps() {
        let s = spec::get("heat1d").unwrap();
        let core = Field::random(&[8], 20);
        let sched = sched(&s, 4, vec![native("naive")], 8, vec![1], Boundary::Dirichlet(0.0));
        assert!(sched.run(&core, 6).is_err());
    }

    /// Tentpole acceptance: a pipelined run's drained stage spans carry
    /// exactly the task ids the analyze [`WindowPlan`] certifies — one
    /// span per plan id, span name matching the id's stage, block/field/
    /// worker args matching the plan meta — plus a window-geometry
    /// instant on the leader track.  Results stay bit-identical under
    /// tracing.  Assertions are scoped to this run's `sched` tag, read
    /// off the nonce-marked leader track, so concurrently-running tests
    /// (which also emit while the global tracer is on) cannot interfere.
    #[test]
    fn pipelined_trace_ids_match_window_plan() {
        use crate::trace::{self, Arg, Phase};
        let _guard = trace::testutil::lock();
        let s = spec::get("heat1d").unwrap();
        let core = Field::random(&[24], 5);
        let (tb, blocks, nf, nw) = (1usize, 3usize, 1usize, 2usize);
        let mut sc = sched(
            &s,
            tb,
            vec![native("simd"), native("autovec")],
            4,
            vec![3, 3],
            Boundary::Dirichlet(0.0),
        );
        sc.overlap = Overlap::On;
        trace::enable();
        let nonce = trace::fresh_tag() << 32;
        trace::instant("test", "pipe-nonce", &[("nonce", nonce.into())]);
        let (got, m) = sc.run(&core, blocks * tb).unwrap();
        trace::disable();
        let drained = trace::drain();
        assert!(m.overlap);
        let want = reference_evolution(&core, &s, blocks * tb, tb, Boundary::Dirichlet(0.0));
        assert!(got.allclose(&want, 1e-12, 1e-14), "tracing changed results");

        // Our sched tag: the window instant following the nonce on the
        // leader track (the test thread; nothing else writes there).
        let mut tag = None;
        for te in &drained {
            let Some(pos) = te.events.iter().position(|e| {
                e.name == "pipe-nonce"
                    && e.args.iter().any(|(k, v)| *k == "nonce" && *v == Arg::U(nonce))
            }) else {
                continue;
            };
            for ev in &te.events[pos..] {
                if ev.cat == "pipeline" && ev.name == "window" {
                    let f = |k: &str| ev.args.iter().find(|(n, _)| *n == k).map(|(_, v)| v.clone());
                    assert_eq!(f("b0"), Some(Arg::U(0)));
                    assert_eq!(f("bw"), Some(Arg::U(blocks as u64)));
                    assert_eq!(f("nf"), Some(Arg::U(nf as u64)));
                    assert_eq!(f("nw"), Some(Arg::U(nw as u64)));
                    match f("sched") {
                        Some(Arg::U(t)) => tag = Some(t),
                        other => panic!("window instant without sched tag: {other:?}"),
                    }
                }
            }
        }
        let tag = tag.expect("no window instant on the leader track");

        // Rebuild the same plan the scheduler derived and diff the span
        // set against it, across every worker track.
        let plan = WindowPlan::build(
            &[(0, 12), (12, 24)],
            s.radius * tb,
            24,
            Boundary::Dirichlet(0.0),
            nf,
            0,
            blocks,
        );
        assert_eq!(plan.meta.len(), 3 * blocks * nf * nw);
        let stage_name = |k: &TaskKind| match k {
            TaskKind::Assemble => "assemble",
            TaskKind::Compute => "compute",
            TaskKind::Writeback => "writeback",
        };
        let mut seen = vec![0usize; plan.meta.len()];
        for te in &drained {
            for ev in &te.events {
                if ev.cat != "pipeline" || ev.phase != Phase::Begin || ev.name == "window" {
                    continue;
                }
                let f = |k: &str| {
                    ev.args.iter().find(|(n, _)| *n == k).and_then(|(_, v)| match v {
                        Arg::U(x) => Some(*x),
                        _ => None,
                    })
                };
                if f("sched") != Some(tag) {
                    continue;
                }
                let task = f("task").expect("stage span without task id") as usize;
                assert!(task < plan.meta.len(), "task {task} outside the plan universe");
                let meta = &plan.meta[task];
                assert_eq!(ev.name, stage_name(&meta.kind), "task {task}");
                assert_eq!(f("block"), Some(meta.block as u64), "task {task}");
                assert_eq!(f("field"), Some(meta.field as u64), "task {task}");
                assert_eq!(f("worker"), Some(meta.worker as u64), "task {task}");
                seen[task] += 1;
            }
        }
        assert!(
            seen.iter().all(|&c| c == 1),
            "trace span multiset != WindowPlan ids: {seen:?}"
        );
    }

    #[test]
    fn boundary_value_is_respected() {
        // An all-boundary-value field must stay constant.
        let s = spec::get("heat2d").unwrap();
        let core = Field::full(&[12, 12], 1.5);
        let sched = sched(
            &s,
            2,
            vec![native("simd"), native("simd")],
            6,
            vec![1, 1],
            Boundary::Dirichlet(1.5),
        );
        let (got, _) = sched.run(&core, 4).unwrap();
        assert!((got.min() - 1.5).abs() < 1e-12 && (got.max() - 1.5).abs() < 1e-12);
    }

    /// Acceptance: a 3-worker heterogeneous Periodic run matches the
    /// shape-preserving periodic oracle to 1e-12 relative tolerance.
    #[test]
    fn hetero_periodic_matches_torus_oracle() {
        for bench in ["heat1d", "heat2d", "heat3d"] {
            let s = spec::get(bench).unwrap();
            let mut shape = vec![24usize];
            shape.extend(vec![8usize; s.ndim - 1]);
            let core = Field::random(&shape, 23);
            let tb = 2;
            let sched = sched(
                &s,
                tb,
                vec![native("simd"), native("autovec"), native("tetris-cpu")],
                4,
                vec![2, 1, 3],
                Boundary::Periodic,
            );
            let steps = 6;
            let (got, metrics) = sched.run(&core, steps).unwrap();
            let want = reference::evolve_periodic(&core, &s, steps);
            assert!(
                got.allclose(&want, 1e-12, 1e-14),
                "{bench}: maxdiff={}",
                got.max_abs_diff(&want)
            );
            // torus conserves the mean
            assert!((got.mean() - core.mean()).abs() < 1e-11, "{bench}");
            // ring topology: W links per block, not W-1
            assert_eq!(metrics.comm.messages, 3 * steps / tb, "{bench}");
        }
    }

    /// Heterogeneous Neumann runs match the single-worker (leader-side)
    /// Neumann evolution across dimensions and mixed worker sets.
    #[test]
    fn hetero_neumann_matches_single_worker_evolution() {
        for bench in ["heat1d", "heat2d", "heat3d"] {
            let s = spec::get(bench).unwrap();
            let mut shape = vec![24usize];
            shape.extend(vec![8usize; s.ndim - 1]);
            let core = Field::random(&shape, 29);
            let tb = 2;
            let sched = sched(
                &s,
                tb,
                vec![native("tetris-cpu"), native("naive"), native("simd")],
                4,
                vec![3, 2, 1],
                Boundary::Neumann,
            );
            let (got, _) = sched.run(&core, 6).unwrap();
            let want = reference_evolution(&core, &s, 6, tb, Boundary::Neumann);
            assert!(
                got.allclose(&want, 1e-12, 1e-14),
                "{bench}: maxdiff={}",
                got.max_abs_diff(&want)
            );
        }
    }

    /// Insulated walls conserve total heat: the Neumann reflection keeps
    /// the deep halo an even extension, so the mean is a run invariant
    /// even with fused Tb-blocks.
    #[test]
    fn neumann_run_conserves_mean() {
        let s = spec::get("heat2d").unwrap();
        let core = Field::random(&[16, 12], 31);
        let sched =
            sched(&s, 2, vec![native("simd"), native("autovec")], 4, vec![2, 2], Boundary::Neumann);
        let (got, _) = sched.run(&core, 8).unwrap();
        assert!((got.mean() - core.mean()).abs() < 1e-12, "drift {}", got.mean() - core.mean());
    }

    /// A worker whose share is 0 (squeezed out or retuned away) is
    /// skipped, not crashed into a zero-row engine call.
    #[test]
    fn zero_share_worker_is_skipped() {
        let s = spec::get("heat2d").unwrap();
        let core = Field::random(&[16, 8], 37);
        let sched = sched(
            &s,
            2,
            vec![native("simd"), native("autovec")],
            4,
            vec![0, 4],
            Boundary::Periodic,
        );
        let (got, metrics) = sched.run(&core, 4).unwrap();
        let want = reference::evolve_periodic(&core, &s, 4);
        assert!(got.allclose(&want, 1e-12, 1e-14), "maxdiff={}", got.max_abs_diff(&want));
        assert_eq!(metrics.worker_busy[0], Duration::ZERO);
        // one active worker = no inter-device links, even on the torus
        assert_eq!(metrics.comm.messages, 0);
    }

    /// Delays each slab by a fixed per-core-row cost on top of a real
    /// engine — a deterministic stand-in for a skewed heterogeneous set.
    struct DelayWorker {
        inner: Box<dyn Worker>,
        per_row: Duration,
    }

    impl Worker for DelayWorker {
        fn name(&self) -> String {
            format!("delay:{}", self.inner.name())
        }
        fn mem_capacity(&self) -> usize {
            self.inner.mem_capacity()
        }
        fn run_slab(&self, spec: &StencilSpec, input: &Field, steps: usize) -> Result<Field> {
            let rows = input.shape()[0] - 2 * spec.radius * steps;
            std::thread::sleep(self.per_row * rows as u32);
            self.inner.run_slab(spec, input, steps)
        }
    }

    fn delayed(eng: &str, per_row_us: u64) -> Box<dyn Worker> {
        Box::new(DelayWorker { inner: native(eng), per_row: Duration::from_micros(per_row_us) })
    }

    /// Acceptance: on a skewed worker set, the adaptive run (a) computes
    /// the same field as the static run, and (b) strictly reduces the
    /// max worker idle-time share vs the static partition.
    #[test]
    fn adaptive_retune_reduces_idle_and_preserves_field() {
        let s = spec::get("heat1d").unwrap();
        let core = Field::random(&[16], 41);
        let steps = 8;
        // worker 0 is 4x slower per row; a fair split strands worker 1.
        let make = || {
            sched(
                &s,
                1,
                vec![delayed("simd", 2000), delayed("simd", 500)],
                2,
                vec![4, 4],
                Boundary::Dirichlet(0.25),
            )
        };
        let static_sched = make();
        let mut adaptive_sched = make();
        adaptive_sched.adapt_every = 1;

        let (want, static_m) = static_sched.run(&core, steps).unwrap();
        let (got, adaptive_m) = adaptive_sched.run(&core, steps).unwrap();

        // (a) slab redistribution is numerically invisible
        assert!(got.allclose(&want, 1e-12, 1e-14), "maxdiff={}", got.max_abs_diff(&want));
        let oracle = reference_evolution(&core, &s, steps, 1, Boundary::Dirichlet(0.25));
        assert!(got.allclose(&oracle, 1e-12, 1e-14));

        // (b) the retuner moved rows to the fast worker and cut bubbles
        assert!(adaptive_m.retunes >= 1, "no retune happened");
        assert_eq!(static_m.retunes, 0);
        assert!(
            adaptive_m.ratios[1] > static_m.ratios[1],
            "fast worker share did not grow: {:?} vs {:?}",
            adaptive_m.ratios,
            static_m.ratios
        );
        let max_idle_share = |m: &RunMetrics| {
            m.worker_idle
                .iter()
                .zip(&m.worker_busy)
                .map(|(i, b)| {
                    let (i, b) = (i.as_secs_f64(), b.as_secs_f64());
                    if i + b == 0.0 {
                        0.0
                    } else {
                        i / (i + b)
                    }
                })
                .fold(0.0, f64::max)
        };
        let (si, ai) = (max_idle_share(&static_m), max_idle_share(&adaptive_m));
        assert!(ai < si, "adaptive idle share {ai:.3} not below static {si:.3}");
    }

    /// The batched run computes, for every field, exactly the bits the
    /// single-field run computes — slab decomposition and batching are
    /// numerically invisible — while amortizing dispatch per block.
    #[test]
    fn batch_run_matches_individual_runs_bitwise() {
        let s = spec::get("heat2d").unwrap();
        let sched = sched(
            &s,
            2,
            vec![native("simd"), native("autovec")],
            4,
            vec![1, 2],
            Boundary::Periodic,
        );
        let fields: Vec<Field> = (0..3).map(|i| Field::random(&[12, 8], 50 + i)).collect();
        let (outs, m) = sched.run_batch(&fields, 4).unwrap();
        assert_eq!(m.fields, 3);
        assert_eq!(m.core_cells, 3 * 12 * 8);
        for (f, out) in fields.iter().zip(&outs) {
            let (want, _) = sched.run(f, 4).unwrap();
            assert_eq!(out.data(), want.data(), "batched result must be bit-identical");
        }
        // comm scales with the batch: 2 active workers on the torus = 2
        // links, x3 fields x2 blocks
        assert_eq!(m.comm.messages, 2 * 3 * 2);
    }

    #[test]
    fn batch_rejects_empty_and_mixed_shapes() {
        let s = spec::get("heat1d").unwrap();
        let sc = sched(&s, 1, vec![native("naive")], 8, vec![1], Boundary::Dirichlet(0.0));
        assert!(sc.run_batch(&[], 1).is_err());
        let a = Field::random(&[8], 1);
        let b = Field::random(&[16], 2);
        assert!(sc.run_batch(&[a, b], 1).is_err());
    }

    /// A single-field run through the batch path keeps the historical
    /// metrics contract (fields=1, per-field cells).
    #[test]
    fn single_field_batch_metrics_unchanged() {
        let s = spec::get("heat1d").unwrap();
        let core = Field::random(&[16], 3);
        let sc = sched(&s, 1, vec![native("simd")], 16, vec![1], Boundary::Dirichlet(0.0));
        let (_, m) = sc.run(&core, 2).unwrap();
        assert_eq!(m.fields, 1);
        assert_eq!(m.core_cells, 16);
    }

    /// A static partition may ignore declared capacities; turning on
    /// `adapt_every` for the same configuration must skip the retune
    /// (not panic in the squeezer) and still complete correctly.
    #[test]
    fn adapt_skips_retune_when_capacities_cannot_cover() {
        let s = spec::get("heat1d").unwrap();
        let core = Field::random(&[16], 47);
        // 16-byte "memories": capacity_units = 0 for both workers.
        let tiny = |eng: &str| -> Box<dyn Worker> {
            Box::new(NativeWorker::new(crate::engine::by_name(eng, 1).unwrap(), 16))
        };
        let mut sc = sched(
            &s,
            1,
            vec![tiny("simd"), tiny("naive")],
            2,
            vec![4, 4],
            Boundary::Dirichlet(0.0),
        );
        sc.adapt_every = 1;
        let (got, m) = sc.run(&core, 4).unwrap();
        let want = reference_evolution(&core, &s, 4, 1, Boundary::Dirichlet(0.0));
        assert!(got.allclose(&want, 1e-12, 1e-14));
        assert_eq!(m.retunes, 0);
    }

    /// Retuning mid-run keeps the partition covering the domain exactly —
    /// the run must keep matching the oracle while shares move.
    #[test]
    fn adaptive_run_stays_correct_under_periodic() {
        let s = spec::get("heat2d").unwrap();
        let core = Field::random(&[16, 8], 43);
        let mut sc = sched(
            &s,
            1,
            vec![delayed("simd", 800), delayed("simd", 200)],
            2,
            vec![4, 4],
            Boundary::Periodic,
        );
        sc.adapt_every = 2;
        let steps = 6;
        let (got, m) = sc.run(&core, steps).unwrap();
        let want = reference::evolve_periodic(&core, &s, steps);
        assert!(got.allclose(&want, 1e-12, 1e-14), "maxdiff={}", got.max_abs_diff(&want));
        let total: f64 = m.ratios.iter().sum();
        assert!((total - 1.0).abs() < 1e-12);
        // the converged partition still covers the domain exactly
        assert_eq!(m.final_shares.iter().sum::<usize>(), 8);
    }

    // -----------------------------------------------------------------
    // §5.3 overlap: the pipelined leader loop
    // -----------------------------------------------------------------

    /// The load-bearing equivalence behind the pipelined loop: slab
    /// assembly from an unfilled global is bit-identical to a full ghost
    /// ring fill + extract, for every boundary kind, rank, halo depth
    /// and rect layout (including spans/runs smaller than the halo and
    /// rects pinned to the domain edges or corners).
    #[test]
    fn assemble_slab_matches_fill_plus_extract_bitwise() {
        for shape in [vec![12usize], vec![9, 5], vec![6, 4, 5]] {
            for halo in [1usize, 2, 3] {
                let core = Field::random(&shape, 0xA55E + halo as u64);
                for b in [Boundary::Dirichlet(-2.5), Boundary::Neumann, Boundary::Periodic] {
                    // unfilled global: stale pad values in the ring
                    let global = core.pad(halo, b.pad_value());
                    let mut filled = global.clone();
                    b.fill(&mut filled, halo);
                    let rows = shape[0];
                    let spans: Vec<(usize, usize)> = vec![
                        (0, 1),
                        (1, rows / 2),
                        (rows / 2, rows / 2), // empty span
                        (rows / 2, rows),
                        (0, rows),
                    ];
                    let runs: Vec<(usize, usize)> = if shape.len() == 1 {
                        vec![(0, 1)] // no column axis: (c0, c1) is ignored
                    } else {
                        let nc = shape[1];
                        vec![
                            (0, nc),
                            (0, nc / 2),
                            (nc / 2, nc),
                            (1, nc - 1),
                            (nc / 2, nc / 2), // empty run
                        ]
                    };
                    for &(s, e) in &spans {
                        for &(c0, c1) in &runs {
                            let got = assemble_slab(&global, s, e, c0, c1, halo, b);
                            let mut off = vec![s];
                            let mut sl_shape = vec![(e - s) + 2 * halo];
                            if shape.len() >= 2 {
                                off.push(c0);
                                sl_shape.push((c1 - c0) + 2 * halo);
                            }
                            off.extend(vec![0usize; shape.len().saturating_sub(2)]);
                            sl_shape.extend(&filled.shape()[2..]);
                            let want = filled.extract(&off, &sl_shape);
                            assert_eq!(
                                got.data(),
                                want.data(),
                                "{b} shape {shape:?} halo {halo} rect ({s},{e})x({c0},{c1})"
                            );
                        }
                    }
                }
            }
        }
    }

    /// Owner sets cover the direct halo neighbourhood and the
    /// boundary-mapped edge rows, and are symmetric by construction.
    #[test]
    fn symmetric_owners_cover_neighbours_and_wrap() {
        let spans = vec![(0usize, 4usize), (4, 8), (8, 12), (12, 16)];
        // halo 2: interior slabs need only their direct neighbours
        let o = symmetric_owners(&spans, 2, 16, Boundary::Dirichlet(0.0));
        assert_eq!(o[1], vec![0, 1, 2]);
        assert_eq!(o[0], vec![0, 1]);
        // periodic wrap links the two edge slabs
        let o = symmetric_owners(&spans, 2, 16, Boundary::Periodic);
        assert_eq!(o[0], vec![0, 1, 3]);
        assert_eq!(o[3], vec![0, 2, 3]);
        // symmetry even with a halo deeper than a slab
        for b in [Boundary::Neumann, Boundary::Periodic, Boundary::Dirichlet(1.0)] {
            let o = symmetric_owners(&spans, 6, 16, b);
            for w in 0..spans.len() {
                for &x in &o[w] {
                    assert!(o[x].contains(&w), "{b}: {w} reads {x} but not vice versa");
                }
            }
        }
    }

    /// Tentpole acceptance: overlap on vs off is bit-identical (exact
    /// f64) across all three boundary kinds and mixed worker sets.
    #[test]
    fn overlap_on_bit_matches_off_for_all_boundaries() {
        for bench in ["heat1d", "heat2d", "heat3d"] {
            let s = spec::get(bench).unwrap();
            let mut shape = vec![24usize];
            shape.extend(vec![8usize; s.ndim - 1]);
            let core = Field::random(&shape, 61);
            for boundary in [Boundary::Dirichlet(0.75), Boundary::Neumann, Boundary::Periodic] {
                let make = || {
                    sched(
                        &s,
                        2,
                        vec![native("simd"), native("autovec"), native("tetris-cpu")],
                        4,
                        vec![2, 1, 3],
                        boundary,
                    )
                };
                let (off, m_off) = make().run(&core, 8).unwrap();
                let mut on_sched = make();
                on_sched.overlap = Overlap::On;
                let (on, m_on) = on_sched.run(&core, 8).unwrap();
                assert_eq!(
                    off.data(),
                    on.data(),
                    "{bench}/{boundary}: overlap must be bit-invisible"
                );
                assert!(!m_off.overlap && m_on.overlap);
                // identical comm accounting either way
                assert_eq!(m_off.comm.messages, m_on.comm.messages, "{bench}/{boundary}");
                assert_eq!(m_off.comm.bytes, m_on.comm.bytes, "{bench}/{boundary}");
                assert_eq!(m_off.comm.overlapped_messages, 0);
                assert!(m_on.comm.overlapped_messages <= m_on.comm.messages);
            }
        }
    }

    /// Multi-field batches ride the same pipelined path bit-exactly.
    #[test]
    fn overlap_batch_bit_matches_off() {
        let s = spec::get("heat2d").unwrap();
        let fields: Vec<Field> = (0..3).map(|i| Field::random(&[16, 8], 80 + i)).collect();
        for boundary in [Boundary::Dirichlet(0.0), Boundary::Neumann, Boundary::Periodic] {
            let make = || {
                sched(
                    &s,
                    2,
                    vec![native("simd"), native("autovec")],
                    4,
                    vec![1, 3],
                    boundary,
                )
            };
            let (off, _) = make().run_batch(&fields, 8).unwrap();
            let mut on_sched = make();
            on_sched.overlap = Overlap::On;
            let (on, m) = on_sched.run_batch(&fields, 8).unwrap();
            assert_eq!(m.fields, 3);
            for (a, b) in off.iter().zip(&on) {
                assert_eq!(a.data(), b.data(), "{boundary}");
            }
        }
    }

    /// A mid-run retune (window boundary in the pipelined loop) keeps
    /// the result bit-identical to the serial adaptive run and correct
    /// against the oracle.
    #[test]
    fn overlap_with_midrun_retune_stays_bit_exact() {
        let s = spec::get("heat1d").unwrap();
        let core = Field::random(&[16], 67);
        let steps = 8;
        for boundary in [Boundary::Dirichlet(0.25), Boundary::Neumann, Boundary::Periodic] {
            let make = || {
                let mut sc = sched(
                    &s,
                    1,
                    vec![delayed("simd", 1500), delayed("simd", 400)],
                    2,
                    vec![4, 4],
                    boundary,
                );
                sc.adapt_every = 2;
                sc
            };
            let (want, _) = make().run(&core, steps).unwrap();
            let mut on_sched = make();
            on_sched.overlap = Overlap::On;
            let (got, m) = on_sched.run(&core, steps).unwrap();
            // retune decisions are timing-fed but slab decomposition is
            // bit-invisible, so the fields agree bit-for-bit regardless
            // of which partitions each mode converged through.
            assert_eq!(got.data(), want.data(), "{boundary}");
            assert_eq!(m.final_shares.iter().sum::<usize>(), 8, "{boundary}");
            let oracle = reference_evolution(&core, &s, steps, 1, boundary);
            assert!(got.allclose(&oracle, 1e-12, 1e-14), "{boundary}");
        }
    }

    /// Degenerate layouts: spans thinner than the halo, zero-share
    /// workers, and the torus wrap all survive the pipelined loop.
    #[test]
    fn overlap_handles_tiny_spans_and_zero_shares() {
        let s = spec::get("heat2d").unwrap();
        let core = Field::random(&[12, 6], 71);
        for boundary in [Boundary::Dirichlet(0.0), Boundary::Neumann, Boundary::Periodic] {
            // tb=2, radius 1 => halo 2 > 1-row spans
            let make = |shares: Vec<usize>| {
                sched(
                    &s,
                    2,
                    vec![native("simd"), native("autovec"), native("naive")],
                    1,
                    shares,
                    boundary,
                )
            };
            for shares in [vec![1usize, 1, 10], vec![0, 6, 6], vec![5, 0, 7]] {
                let (want, _) = make(shares.clone()).run(&core, 8).unwrap();
                let mut on = make(shares.clone());
                on.overlap = Overlap::On;
                let (got, _) = on.run(&core, 8).unwrap();
                assert_eq!(got.data(), want.data(), "{boundary} shares {shares:?}");
            }
        }
    }

    /// Overlap accounting: the pipelined loop reports hidden leader time
    /// and overlapped halo messages on a run where compute dominates.
    #[test]
    fn overlap_metrics_report_hidden_prefetch() {
        let s = spec::get("heat1d").unwrap();
        let core = Field::random(&[32], 73);
        // 4x skew: worker 0 finishes each block ~15 ms before worker 1,
        // so its writeback + next-block assembly are guaranteed to land
        // while worker 1 still computes.
        let mut sc = sched(
            &s,
            1,
            vec![delayed("simd", 300), delayed("simd", 1200)],
            4,
            vec![4, 4],
            Boundary::Periodic,
        );
        sc.overlap = Overlap::On;
        let (_, m) = sc.run(&core, 6).unwrap();
        assert!(m.overlap);
        assert!(m.leader_extract > Duration::ZERO);
        assert!(m.leader_paste > Duration::ZERO);
        // with multi-ms sleeps in every slab, some assembly/writeback
        // must land while a neighbour still computes
        assert!(m.overlap_hidden > Duration::ZERO, "{m:?}");
        assert!(m.comm.overlapped_messages > 0, "{m:?}");
    }

    /// A worker failure in the pipelined loop surfaces as an error (with
    /// the worker named), not a hang or a corrupt field.
    #[test]
    fn overlap_propagates_worker_failure() {
        struct FailingWorker;
        impl Worker for FailingWorker {
            fn name(&self) -> String {
                "failing".into()
            }
            fn mem_capacity(&self) -> usize {
                1 << 40
            }
            fn run_slab(&self, _: &StencilSpec, _: &Field, _: usize) -> Result<Field> {
                crate::bail!("injected fault")
            }
        }
        let s = spec::get("heat1d").unwrap();
        let mut sc = sched(
            &s,
            1,
            vec![native("simd"), Box::new(FailingWorker)],
            8,
            vec![1, 1],
            Boundary::Dirichlet(0.0),
        );
        sc.overlap = Overlap::On;
        let err = sc.run(&Field::random(&[16], 5), 2).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("worker 1 failed"), "{msg}");
        assert!(msg.contains("injected fault"), "{msg}");
    }

    #[test]
    fn overlap_mode_parses_and_gates() {
        assert_eq!("on".parse::<Overlap>().unwrap(), Overlap::On);
        assert_eq!("off".parse::<Overlap>().unwrap(), Overlap::Off);
        assert_eq!("auto".parse::<Overlap>().unwrap(), Overlap::Auto);
        assert!("sometimes".parse::<Overlap>().is_err());
        assert_eq!(Overlap::Auto.to_string(), "auto");
        assert!(Overlap::On.enabled(1, 1));
        assert!(!Overlap::Off.enabled(8, 8));
        assert!(Overlap::Auto.enabled(2, 2));
        assert!(!Overlap::Auto.enabled(1, 8), "single worker gains nothing");
        assert!(!Overlap::Auto.enabled(4, 1), "single block has no next block to prefetch");
    }

    // -----------------------------------------------------------------
    // 2-D worker grids (Wy×Wx tiles)
    // -----------------------------------------------------------------

    /// Tentpole acceptance: a 2×2 tile grid computes exactly what the
    /// single-worker evolution computes, across ranks and all three
    /// boundary kinds, and its comm ledger carries exactly the per-link
    /// perimeter accounting `grid_exchanges` declares (edges + corners).
    #[test]
    fn grid_run_matches_reference_evolution() {
        for bench in ["heat2d", "box2d25p", "heat3d"] {
            let s = spec::get(bench).unwrap();
            let mut shape = vec![24usize, 12];
            shape.extend(vec![8usize; s.ndim - 2]);
            let core = Field::random(&shape, 117);
            let tb = 2;
            for boundary in [Boundary::Dirichlet(0.5), Boundary::Neumann, Boundary::Periodic] {
                let sc = gsched(
                    &s,
                    tb,
                    vec![native("simd"), native("autovec"), native("tetris-cpu"), native("naive")],
                    4,
                    vec![2, 4],
                    vec![5, 7],
                    boundary,
                );
                let (got, m) = sc.run(&core, 8).unwrap();
                let want = reference_evolution(&core, &s, 8, tb, boundary);
                assert!(
                    got.allclose(&want, 1e-12, 1e-14),
                    "{bench}/{boundary}: maxdiff={}",
                    got.max_abs_diff(&want)
                );
                let halo = s.radius * tb;
                let part = Partition::rows(4, vec![2, 4]).with_bands(vec![5, 7]);
                let rest2: usize = shape[2..].iter().product::<usize>().max(1);
                let ex = crate::coordinator::comm::grid_exchanges(
                    &part.spans(),
                    &part.bands(12),
                    halo,
                    rest2,
                    matches!(boundary, Boundary::Periodic),
                );
                assert_eq!(m.comm.messages, ex.len() * 4, "{bench}/{boundary}");
                assert_eq!(m.comm.bytes, ex.iter().sum::<usize>() * 4, "{bench}/{boundary}");
                assert_eq!(m.final_bands, vec![5, 7], "{bench}/{boundary}");
            }
        }
    }

    /// A column-only split (Wx=1, Wy=2) exercises the dim-1 path alone.
    #[test]
    fn grid_split_only_on_columns_matches_reference() {
        let s = spec::get("heat2d").unwrap();
        let core = Field::random(&[24, 12], 119);
        for boundary in [Boundary::Dirichlet(0.0), Boundary::Neumann, Boundary::Periodic] {
            let sc = gsched(
                &s,
                2,
                vec![native("simd"), native("autovec")],
                4,
                vec![6],
                vec![4, 8],
                boundary,
            );
            let (got, m) = sc.run(&core, 8).unwrap();
            let want = reference_evolution(&core, &s, 8, 2, boundary);
            assert!(
                got.allclose(&want, 1e-12, 1e-14),
                "{boundary}: maxdiff={}",
                got.max_abs_diff(&want)
            );
            // one run, two bands: only dim-1 links, no corners
            let links = if matches!(boundary, Boundary::Periodic) { 2 } else { 1 };
            assert_eq!(m.comm.messages, links * 4, "{boundary}");
        }
    }

    /// §5.3 on the grid: the pipelined leader loop is bit-invisible for
    /// every boundary kind, with identical comm accounting.
    #[test]
    fn grid_overlap_bit_matches_serial() {
        let s = spec::get("heat2d").unwrap();
        let core = Field::random(&[24, 12], 123);
        for boundary in [Boundary::Dirichlet(0.75), Boundary::Neumann, Boundary::Periodic] {
            let make = || {
                gsched(
                    &s,
                    2,
                    vec![native("simd"), native("autovec"), native("tetris-cpu"), native("naive")],
                    4,
                    vec![2, 4],
                    vec![5, 7],
                    boundary,
                )
            };
            let (off, m_off) = make().run(&core, 8).unwrap();
            let mut on_sched = make();
            on_sched.overlap = Overlap::On;
            let (on, m_on) = on_sched.run(&core, 8).unwrap();
            assert_eq!(off.data(), on.data(), "{boundary}: grid overlap must be bit-invisible");
            assert!(!m_off.overlap && m_on.overlap);
            assert_eq!(m_off.comm.messages, m_on.comm.messages, "{boundary}");
            assert_eq!(m_off.comm.bytes, m_on.comm.bytes, "{boundary}");
        }
    }

    /// Multi-field batches ride the grid path bit-exactly too.
    #[test]
    fn grid_batch_matches_individual_runs_bitwise() {
        let s = spec::get("heat2d").unwrap();
        let make = || {
            gsched(
                &s,
                2,
                vec![native("simd"), native("autovec"), native("tetris-cpu"), native("naive")],
                4,
                vec![1, 2],
                vec![6, 6],
                Boundary::Periodic,
            )
        };
        let fields: Vec<Field> = (0..3).map(|i| Field::random(&[12, 12], 150 + i)).collect();
        let (outs, m) = make().run_batch(&fields, 4).unwrap();
        assert_eq!(m.fields, 3);
        for (f, out) in fields.iter().zip(&outs) {
            let (want, _) = make().run(f, 4).unwrap();
            assert_eq!(out.data(), want.data(), "batched grid result must be bit-identical");
        }
    }

    /// Config validation: band widths must cover the column extent, the
    /// worker list must match Wy×Wx, and a 1-D field has no column axis
    /// to band.
    #[test]
    fn grid_rejects_bad_configs() {
        let s2 = spec::get("heat2d").unwrap();
        let core = Field::random(&[16, 8], 19);
        let four = || vec![native("naive"), native("naive"), native("naive"), native("naive")];
        // 4 + 2 != 8 cols
        let sc = gsched(&s2, 1, four(), 4, vec![2, 2], vec![4, 2], Boundary::Dirichlet(0.0));
        assert!(sc.run(&core, 1).is_err());
        // 2 workers can't fill a 2x2 grid
        let sc = gsched(
            &s2,
            1,
            vec![native("naive"), native("naive")],
            4,
            vec![2, 2],
            vec![4, 4],
            Boundary::Dirichlet(0.0),
        );
        assert!(sc.run(&core, 1).is_err());
        // 1-D fields have no dim 1 to band
        let s1 = spec::get("heat1d").unwrap();
        let core1 = Field::random(&[16], 21);
        let sc = gsched(&s1, 1, four(), 4, vec![2, 2], vec![8, 8], Boundary::Dirichlet(0.0));
        assert!(sc.run(&core1, 1).is_err());
    }

    /// Zero-area tiles (zero-share run and zero-width band) are skipped,
    /// not crashed into zero-extent engine calls — and a single live
    /// tile exchanges nothing, even on the torus.
    #[test]
    fn grid_zero_area_tiles_are_skipped() {
        let s = spec::get("heat2d").unwrap();
        let core = Field::random(&[24, 12], 77);
        let make = || {
            gsched(
                &s,
                2,
                vec![native("simd"), native("autovec"), native("tetris-cpu"), native("naive")],
                4,
                vec![0, 6],
                vec![0, 12],
                Boundary::Periodic,
            )
        };
        let (got, m) = make().run(&core, 4).unwrap();
        let want = reference::evolve_periodic(&core, &s, 4);
        assert!(got.allclose(&want, 1e-12, 1e-14), "maxdiff={}", got.max_abs_diff(&want));
        for w in [0usize, 1, 2] {
            assert_eq!(m.worker_busy[w], Duration::ZERO, "tile {w} owns no cells");
        }
        assert_eq!(m.comm.messages, 0);
        let mut on_sched = make();
        on_sched.overlap = Overlap::On;
        let (on, _) = on_sched.run(&core, 4).unwrap();
        assert_eq!(on.data(), got.data());
    }

    /// Grid owner sets are per-axis forward-scan *products* symmetrized
    /// at the worker level: interior 2×2 tiles link all four neighbours
    /// (corners included), while a layout mixing an empty run with an
    /// empty band must NOT link the two zero-area tiles' hosts — the
    /// over-sync edge a per-axis symmetrization would invent.
    #[test]
    fn symmetric_owners_grid_covers_corners_without_phantom_links() {
        let b = Boundary::Dirichlet(0.0);
        let o = symmetric_owners_grid(
            &[(0, 8), (8, 16)],
            &[(0, 8), (8, 16)],
            2,
            16,
            16,
            b,
        );
        for w in 0..4 {
            assert_eq!(o[w], vec![0, 1, 2, 3], "tile {w} must see edge + corner neighbours");
        }
        // worker 1 owns everything; 0, 2, 3 own nothing
        let o = symmetric_owners_grid(
            &[(0, 0), (0, 16)],
            &[(0, 12), (12, 12)],
            2,
            16,
            12,
            b,
        );
        assert_eq!(o[0], vec![1]);
        assert_eq!(o[1], vec![0, 1, 2, 3]);
        assert_eq!(o[2], vec![1]);
        assert_eq!(o[3], vec![1]);
        // symmetry holds for every boundary with a deep halo
        for b in [Boundary::Neumann, Boundary::Periodic, Boundary::Dirichlet(1.0)] {
            let o = symmetric_owners_grid(
                &[(0, 4), (4, 10), (10, 16)],
                &[(0, 6), (6, 12)],
                6,
                16,
                12,
                b,
            );
            for w in 0..o.len() {
                for &x in &o[w] {
                    assert!(o[x].contains(&w), "{b}: {w} reads {x} but not vice versa");
                }
            }
        }
    }

    /// A mid-run grid retune keeps the run correct against the oracle,
    /// preserves both axis totals, and stays bit-identical between the
    /// serial and pipelined leader loops.
    #[test]
    fn grid_midrun_retune_stays_correct() {
        let s = spec::get("heat2d").unwrap();
        let core = Field::random(&[16, 8], 83);
        let steps = 8;
        let make = || {
            let mut sc = gsched(
                &s,
                1,
                vec![
                    delayed("simd", 1500),
                    delayed("simd", 400),
                    delayed("simd", 1500),
                    delayed("simd", 400),
                ],
                2,
                vec![4, 4],
                vec![4, 4],
                Boundary::Neumann,
            );
            sc.adapt_every = 2;
            sc
        };
        let (want, m) = make().run(&core, steps).unwrap();
        let oracle = reference_evolution(&core, &s, steps, 1, Boundary::Neumann);
        assert!(
            want.allclose(&oracle, 1e-12, 1e-14),
            "maxdiff={}",
            want.max_abs_diff(&oracle)
        );
        // run gx=0 is ~4x slower at ms scale: the x-axis must rebalance
        assert!(m.retunes >= 1, "no grid retune happened");
        assert_eq!(m.final_shares.iter().sum::<usize>(), 8);
        assert_eq!(m.final_bands.iter().sum::<usize>(), 8);
        assert_eq!(m.final_bands.len(), 2, "retune must preserve the grid shape");
        let mut on_sched = make();
        on_sched.overlap = Overlap::On;
        let (got, _) = on_sched.run(&core, steps).unwrap();
        assert_eq!(got.data(), want.data(), "grid retune must stay bit-identical under overlap");
    }
}
