//! The concurrent heterogeneous pipeline driver (paper §5, Fig. 11).
//!
//! The leader holds the global extended field.  Per Tb-block it
//! (1) snapshots each worker's slab + ghost ring (the halo exchange —
//! batched once per block, the §5.3 centralized communication launch),
//! (2) dispatches every worker concurrently on the work-stealing pool,
//! (3) writes the slabs back, accounting busy/idle time and comm volume.
//!
//! Boundary condition: Dirichlet — the ghost ring keeps its initial
//! value, identical to the valid-mode contract the artifacts and engines
//! share, so a heterogeneous run is bit-comparable to a single-worker
//! reference evolution (tested below).

use std::time::{Duration, Instant};

use crate::util::error::{Context, Result};

use crate::stencil::{Field, StencilSpec};

use super::comm::{CommLedger, CommModel};
use super::metrics::RunMetrics;
use super::partition::Partition;
use super::worker::Worker;

pub struct Scheduler {
    pub spec: StencilSpec,
    /// Fused steps per block (every worker must support it).
    pub tb: usize,
    pub workers: Vec<Box<dyn Worker>>,
    pub partition: Partition,
    pub comm_model: CommModel,
}

impl Scheduler {
    /// Evolve `core` by `total_steps` (a multiple of Tb) with constant
    /// `boundary` ghost cells.  Returns the final core and run metrics.
    pub fn run(
        &self,
        core: &Field,
        total_steps: usize,
        boundary: f64,
    ) -> Result<(Field, RunMetrics)> {
        crate::ensure!(self.tb >= 1, "tb must be >= 1");
        crate::ensure!(
            total_steps % self.tb == 0,
            "total_steps {total_steps} not a multiple of Tb {}",
            self.tb
        );
        crate::ensure!(
            !self.workers.is_empty() && self.workers.len() == self.partition.shares.len(),
            "workers/partition mismatch"
        );
        let spans = self.partition.spans();
        crate::ensure!(
            spans.last().unwrap().1 == core.shape()[0],
            "partition covers {} rows, domain has {}",
            spans.last().unwrap().1,
            core.shape()[0]
        );
        let halo = self.spec.radius * self.tb;
        let nd = core.ndim();
        let mut global = core.pad(halo, boundary);
        let ext_rest: Vec<usize> = global.shape()[1..].to_vec();
        let rest_cells: usize = ext_rest.iter().product::<usize>().max(1);

        let blocks = total_steps / self.tb;
        let mut busy = vec![Duration::ZERO; self.workers.len()];
        let mut idle = vec![Duration::ZERO; self.workers.len()];
        let mut comm = CommLedger::default();
        let t0 = Instant::now();

        for _ in 0..blocks {
            // (1) Halo snapshot: one extraction per worker per block —
            // the centralized communication launch.  Internal-boundary
            // bytes are what a two-device deployment would ship.
            let inputs: Vec<Field> = spans
                .iter()
                .map(|&(s, e)| {
                    let mut off = vec![s];
                    off.extend(vec![0usize; nd - 1]);
                    let mut shape = vec![(e - s) + 2 * halo];
                    shape.extend(&ext_rest);
                    global.extract(&off, &shape)
                })
                .collect();
            for _ in 0..spans.len().saturating_sub(1) {
                // two directions x halo rows x extended row cells
                comm.record_exchange(2 * halo * rest_cells * 8, self.tb);
            }

            // (2) Concurrent dispatch on the shared work-stealing pool.
            let results: Vec<(Result<Field>, Duration)> =
                dispatch(&self.workers, &self.spec, &inputs, self.tb);

            // (3) Writeback + accounting.
            let slowest = results.iter().map(|(_, d)| *d).max().unwrap_or_default();
            for (i, ((res, dt), &(s, _e))) in results.into_iter().zip(&spans).enumerate() {
                let out = res.with_context(|| format!("worker {i} failed"))?;
                let mut off = vec![s + halo];
                off.extend(vec![halo; nd - 1]);
                global.paste(&off, &out);
                busy[i] += dt;
                idle[i] += slowest - dt;
            }
        }

        let metrics = RunMetrics {
            total_steps,
            blocks,
            core_cells: core.len(),
            elapsed: t0.elapsed(),
            worker_names: self.workers.iter().map(|w| w.name()).collect(),
            worker_busy: busy,
            worker_idle: idle,
            comm,
            ratios: (0..self.workers.len()).map(|i| self.partition.ratio(i)).collect(),
        };
        Ok((global.unpad(halo), metrics))
    }
}

/// Run every worker on its input concurrently on a pool scope; returns
/// per-worker (result, busy time) in worker order.  One task per worker
/// — pools are ephemeral per call, so engine-internal tile pools nested
/// inside a worker stay independent of this dispatch scope.
fn dispatch(workers: &[Box<dyn Worker>], spec: &StencilSpec, inputs: &[Field], tb: usize) -> Vec<(Result<Field>, Duration)> {
    super::pool::steal_map(workers.len(), workers.len(), |i| {
        let t0 = Instant::now();
        let res = workers[i].run_slab(spec, &inputs[i], tb);
        (res, t0.elapsed())
    })
}

/// Single-worker reference evolution with the same Dirichlet semantics —
/// used by tests and by the thermal case study's "Naive" row.
pub fn reference_evolution(
    core: &Field,
    spec: &StencilSpec,
    total_steps: usize,
    tb: usize,
    boundary: f64,
) -> Field {
    assert_eq!(total_steps % tb, 0);
    let halo = spec.radius * tb;
    let mut global = core.pad(halo, boundary);
    for _ in 0..total_steps / tb {
        let out = crate::stencil::reference::block(&global, spec, tb);
        global.paste(&vec![halo; core.ndim()], &out);
    }
    global.unpad(halo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::NativeWorker;
    use crate::stencil::spec;

    fn native(name: &str) -> Box<dyn Worker> {
        Box::new(NativeWorker::new(crate::engine::by_name(name, 1).unwrap(), 1 << 30))
    }

    #[test]
    fn hetero_run_matches_reference_evolution() {
        for bench in ["heat1d", "heat2d", "box2d25p", "heat3d"] {
            let s = spec::get(bench).unwrap();
            let mut shape = vec![24usize];
            shape.extend(vec![10usize; s.ndim - 1]);
            let core = Field::random(&shape, 17);
            let tb = 2;
            let sched = Scheduler {
                spec: s.clone(),
                tb,
                workers: vec![native("simd"), native("autovec"), native("tetris-cpu")],
                partition: Partition { unit: 4, shares: vec![2, 1, 3] },
                comm_model: CommModel::default(),
            };
            let (got, metrics) = sched.run(&core, 8, 0.5).unwrap();
            let want = reference_evolution(&core, &s, 8, tb, 0.5);
            assert!(
                got.allclose(&want, 1e-12, 1e-14),
                "{bench}: maxdiff={}",
                got.max_abs_diff(&want)
            );
            assert_eq!(metrics.blocks, 4);
            assert_eq!(metrics.comm.messages, 2 * 4); // 2 boundaries x 4 blocks
        }
    }

    #[test]
    fn single_worker_covers_domain() {
        let s = spec::get("heat2d").unwrap();
        let core = Field::random(&[16, 8], 18);
        let sched = Scheduler {
            spec: s.clone(),
            tb: 1,
            workers: vec![native("naive")],
            partition: Partition { unit: 16, shares: vec![1] },
            comm_model: CommModel::default(),
        };
        let (got, m) = sched.run(&core, 3, 0.0).unwrap();
        let want = reference_evolution(&core, &s, 3, 1, 0.0);
        assert!(got.allclose(&want, 1e-12, 0.0));
        assert_eq!(m.comm.messages, 0); // no internal boundary
    }

    #[test]
    fn rejects_partition_mismatch() {
        let s = spec::get("heat1d").unwrap();
        let core = Field::random(&[20], 19);
        let sched = Scheduler {
            spec: s.clone(),
            tb: 1,
            workers: vec![native("naive")],
            partition: Partition { unit: 4, shares: vec![3] }, // 12 != 20
            comm_model: CommModel::default(),
        };
        assert!(sched.run(&core, 1, 0.0).is_err());
    }

    #[test]
    fn rejects_non_multiple_steps() {
        let s = spec::get("heat1d").unwrap();
        let core = Field::random(&[8], 20);
        let sched = Scheduler {
            spec: s.clone(),
            tb: 4,
            workers: vec![native("naive")],
            partition: Partition { unit: 8, shares: vec![1] },
            comm_model: CommModel::default(),
        };
        assert!(sched.run(&core, 6, 0.0).is_err());
    }

    #[test]
    fn boundary_value_is_respected() {
        // An all-boundary-value field must stay constant.
        let s = spec::get("heat2d").unwrap();
        let core = Field::full(&[12, 12], 1.5);
        let sched = Scheduler {
            spec: s.clone(),
            tb: 2,
            workers: vec![native("simd"), native("simd")],
            partition: Partition { unit: 6, shares: vec![1, 1] },
            comm_model: CommModel::default(),
        };
        let (got, _) = sched.run(&core, 4, 1.5).unwrap();
        assert!((got.min() - 1.5).abs() < 1e-12 && (got.max() - 1.5).abs() < 1e-12);
    }
}
