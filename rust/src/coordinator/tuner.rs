//! Auto-tuning computation scheduling (paper §5.2).
//!
//! "In the startup phase … the computation time taken by the first
//! iteration … is recorded as part of a profile initialization.  This
//! profile is employed as the input to the scheduler for performing a
//! balanced partition."  Exactly that: [`profile_workers`] times one
//! unit-slab block per worker, [`tune`] converts the profile into a
//! capacity-respecting balanced partition, and [`retune`] refines it
//! from measured per-block times (architecture-aware rebalance).

use crate::util::error::{Context, Result};

use crate::stencil::{Field, StencilSpec};

use super::comm::CommModel;
use super::partition::{capacity_units, Partition};
use super::worker::Worker;

/// Seconds per unit-slab block for each worker (the startup profile).
pub fn profile_workers(
    workers: &[Box<dyn Worker>],
    spec: &StencilSpec,
    unit_core: &[usize],
    tb: usize,
    reps: usize,
) -> Result<Vec<f64>> {
    let halo = spec.radius * tb;
    let shape: Vec<usize> = unit_core.iter().map(|n| n + 2 * halo).collect();
    let input = Field::random(&shape, 0xBEEF);
    let mut out = Vec::with_capacity(workers.len());
    for w in workers {
        // warmup (compile caches, page-in), then median of `reps`.  Every
        // timed call propagates its Result: a failing worker must surface
        // as an error, not as a near-zero profile that would hand it the
        // whole partition.
        w.run_slab(spec, &input, tb)
            .with_context(|| format!("profiling {} (warmup)", w.name()))?;
        let mut samples: Vec<f64> = Vec::with_capacity(reps.max(1));
        for _ in 0..reps.max(1) {
            let t0 = std::time::Instant::now();
            w.run_slab(spec, &input, tb)
                .with_context(|| format!("profiling {}", w.name()))?;
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out.push(samples[samples.len() / 2].max(1e-12));
    }
    Ok(out)
}

/// Balanced partition from a profile: weight_i = 1 / t_i, clamped by each
/// worker's memory capacity (the squeezer).
pub fn tune(
    unit: usize,
    units: usize,
    rest_cells: usize,
    profile_secs: &[f64],
    workers: &[Box<dyn Worker>],
) -> Partition {
    let weights: Vec<f64> = profile_secs.iter().map(|t| 1.0 / t.max(1e-12)).collect();
    let caps: Vec<usize> = workers
        .iter()
        .map(|w| capacity_units(w.mem_capacity(), unit, rest_cells))
        .collect();
    Partition::balanced(unit, units, &weights, &caps)
}

/// One rebalance iteration from measured per-block busy times: the new
/// weight is the worker's measured throughput share / t_i.
pub fn retune(
    partition: &Partition,
    measured_secs: &[f64],
    workers: &[Box<dyn Worker>],
    rest_cells: usize,
) -> Partition {
    assert_eq!(partition.shares.len(), measured_secs.len());
    let weights: Vec<f64> = partition
        .shares
        .iter()
        .zip(measured_secs)
        .map(|(&s, &t)| {
            if s == 0 {
                // never measured: keep a small exploration weight
                0.25 / t.max(1e-12)
            } else {
                s as f64 / t.max(1e-12)
            }
        })
        .collect();
    let caps: Vec<usize> = workers
        .iter()
        .map(|w| capacity_units(w.mem_capacity(), partition.unit, rest_cells))
        .collect();
    Partition::balanced(partition.unit, partition.total_units(), &weights, &caps)
}

/// Deployment cost of migrating from partition `from` to `to`: every
/// moved cell ships once, and every worker whose owned-cell count
/// changed participates in (at least) one transfer — the k·(α+nβ) term
/// the ROADMAP's slab-migration item asks for.  `rest_cells` is the
/// core-row cell count of the non-split dims (what a halo/slab message
/// actually carries; locally-filled ghost padding is never shipped).
/// Works for both 1-D row partitions and 2-D grids: cells are counted
/// per worker rect, so a pure band reshuffle costs too.
pub fn migration_cost(model: &CommModel, from: &Partition, to: &Partition, rest_cells: usize) -> f64 {
    let a = from.worker_cells(rest_cells);
    let b = to.worker_cells(rest_cells);
    let moved_cells: usize =
        a.iter().zip(&b).map(|(&x, &y)| x.abs_diff(y)).sum::<usize>() / 2;
    let links = a.iter().zip(&b).filter(|(x, y)| x != y).count();
    model.cost(links, moved_cells * 8)
}

/// Hysteresis-gated rebalance: compute the [`retune`] candidate, then
/// only adopt it when the projected idle-time saving over the remaining
/// blocks exceeds the migration cost of actually moving the slabs.
/// A marginal imbalance (noise-scale busy-time skew) therefore no longer
/// thrashes shares back and forth; a genuine skew still repartitions.
///
/// `cap_rest_cells` feeds the capacity squeezer (extended-dim cells, as
/// a worker must hold the ghost ring too); `move_rest_cells` feeds the
/// migration-cost estimate (core cells, what a transfer ships).
pub fn retune_gated(
    partition: &Partition,
    measured_secs: &[f64],
    workers: &[Box<dyn Worker>],
    cap_rest_cells: usize,
    model: &CommModel,
    move_rest_cells: usize,
    remaining_blocks: usize,
) -> Option<Partition> {
    let cand = retune(partition, measured_secs, workers, cap_rest_cells);
    if cand == *partition || remaining_blocks == 0 {
        return None;
    }
    // Projected per-block time under the candidate shares, from measured
    // per-unit times.  A zero-share worker was never measured; assume it
    // is comparable to the best active worker rather than charging it a
    // whole block per unit — a pessimistic prior would let the gate
    // permanently strand a squeezed-out worker, while an optimistic one
    // costs at most one cheap exploration migration before the next
    // window measures the truth.
    let best_active = partition
        .shares
        .iter()
        .zip(measured_secs)
        .filter(|(&s, _)| s > 0)
        .map(|(&s, &t)| t / s as f64)
        .fold(f64::INFINITY, f64::min);
    let per_unit: Vec<f64> = partition
        .shares
        .iter()
        .zip(measured_secs)
        .map(|(&s, &t)| {
            if s > 0 {
                t / s as f64
            } else if best_active.is_finite() {
                best_active
            } else {
                t
            }
        })
        .collect();
    let cur = measured_secs.iter().cloned().fold(0.0, f64::max);
    let proj = cand
        .shares
        .iter()
        .zip(&per_unit)
        .map(|(&s, &u)| s as f64 * u)
        .fold(0.0, f64::max);
    let gain = (cur - proj) * remaining_blocks as f64;
    let cost = migration_cost(model, partition, &cand, move_rest_cells);
    let migrate = gain > cost;
    // The §5.2 decision, auditable in a trace: projected idle saving vs
    // the k·(α+nβ) slab-migration estimate it has to beat.
    crate::trace::instant(
        "retune",
        if migrate { "migrated" } else { "kept" },
        &[
            ("gain_s", gain.into()),
            ("migration_cost_s", cost.into()),
            ("remaining_blocks", remaining_blocks.into()),
        ],
    );
    if migrate {
        Some(cand)
    } else {
        None
    }
}

/// Hysteresis-gated rebalance for a 2-D worker grid: redistribute the
/// dim-0 row shares and the dim-1 band widths independently (the grid
/// stays a product partition — worker (gy,gx) always owns
/// rows(gx) × cols(gy)), then adopt the candidate only when the
/// projected idle saving over the remaining blocks beats the slab
/// migration cost.
///
/// Axis times come from the grid structure itself: run gx is as slow as
/// its slowest tile (max over gy), and symmetrically for bands.  The
/// capacity squeezer is evaluated against the worst-case tile of each
/// run/band.  `rest2` is the per-(row,col) cell count of dims 2+
/// (extended extents, for capacity); `move_rest2` the core dims-2+
/// cells (what a migration ships).  Returns `None` when the candidate
/// equals the current grid, is infeasible under capacity, or fails the
/// migration gate.
pub fn retune_gated_grid(
    partition: &Partition,
    measured_secs: &[f64],
    workers: &[Box<dyn Worker>],
    rest2: usize,
    model: &CommModel,
    move_rest2: usize,
    remaining_blocks: usize,
) -> Option<Partition> {
    assert!(!partition.cols.is_empty(), "grid retune needs a banded partition");
    let (wy, wx) = (partition.wy(), partition.wx());
    assert_eq!(measured_secs.len(), wy * wx);
    assert_eq!(workers.len(), wy * wx);
    if remaining_blocks == 0 {
        return None;
    }
    // Per-run (dim 0) rebalance: a run is as slow as its slowest tile.
    let time_x: Vec<f64> = (0..wx)
        .map(|gx| {
            (0..wy)
                .map(|gy| measured_secs[gy * wx + gx])
                .fold(0.0_f64, f64::max)
                .max(1e-12)
        })
        .collect();
    let weight_x: Vec<f64> = partition
        .shares
        .iter()
        .zip(&time_x)
        .map(|(&s, &t)| if s == 0 { 0.25 / t } else { s as f64 / t })
        .collect();
    // Capacity in row units: the worst-case (widest-band) tile of the
    // run must still fit, whatever band it lands in.
    let caps_x: Vec<usize> = (0..wx)
        .map(|gx| {
            (0..wy)
                .map(|gy| {
                    let band_cells = partition.cols[gy].max(1) * rest2;
                    capacity_units(workers[gy * wx + gx].mem_capacity(), partition.unit, band_cells)
                })
                .min()
                .unwrap_or(0)
        })
        .collect();
    if caps_x.iter().sum::<usize>() < partition.total_units() {
        return None; // infeasible: keep the current grid
    }
    let cand_rows = Partition::balanced(partition.unit, partition.total_units(), &weight_x, &caps_x);
    // Per-band (dim 1) rebalance, symmetric, in single-column units.
    let time_y: Vec<f64> = (0..wy)
        .map(|gy| {
            (0..wx)
                .map(|gx| measured_secs[gy * wx + gx])
                .fold(0.0_f64, f64::max)
                .max(1e-12)
        })
        .collect();
    let weight_y: Vec<f64> = partition
        .cols
        .iter()
        .zip(&time_y)
        .map(|(&c, &t)| if c == 0 { 0.25 / t } else { c as f64 / t })
        .collect();
    let caps_y: Vec<usize> = (0..wy)
        .map(|gy| {
            (0..wx)
                .filter(|&gx| partition.shares[gx] > 0)
                .map(|gx| {
                    let run_cells = partition.shares[gx] * partition.unit * rest2;
                    capacity_units(workers[gy * wx + gx].mem_capacity(), 1, run_cells)
                })
                .min()
                .unwrap_or(0)
        })
        .collect();
    if caps_y.iter().sum::<usize>() < partition.total_cols() {
        return None;
    }
    let cand_cols = Partition::balanced(1, partition.total_cols(), &weight_y, &caps_y);
    let cand = Partition::rows(partition.unit, cand_rows.shares).with_bands(cand_cols.shares);
    if cand == *partition {
        return None;
    }
    // Migration gate, per tile: project each worker's block time from
    // its measured per-cell throughput, optimistic prior for empty
    // tiles (same rationale as the 1-D gate).
    let cells = partition.worker_cells(1);
    let best_active = cells
        .iter()
        .zip(measured_secs)
        .filter(|(&c, _)| c > 0)
        .map(|(&c, &t)| t / c as f64)
        .fold(f64::INFINITY, f64::min);
    let per_cell: Vec<f64> = cells
        .iter()
        .zip(measured_secs)
        .map(|(&c, &t)| {
            if c > 0 {
                t / c as f64
            } else if best_active.is_finite() {
                best_active
            } else {
                t
            }
        })
        .collect();
    let cur = measured_secs.iter().cloned().fold(0.0, f64::max);
    let proj = cand
        .worker_cells(1)
        .iter()
        .zip(&per_cell)
        .map(|(&c, &u)| c as f64 * u)
        .fold(0.0, f64::max);
    let gain = (cur - proj) * remaining_blocks as f64;
    let cost = migration_cost(model, partition, &cand, move_rest2);
    let migrate = gain > cost;
    crate::trace::instant(
        "retune",
        if migrate { "migrated" } else { "kept" },
        &[
            ("gain_s", gain.into()),
            ("migration_cost_s", cost.into()),
            ("remaining_blocks", remaining_blocks.into()),
        ],
    );
    if migrate {
        Some(cand)
    } else {
        None
    }
}

/// Convergence driver: retune until the expected per-block times differ by
/// less than `tol` relatively, or `max_iters`.  Returns the partition and
/// the number of iterations taken.
pub fn converge(
    mut partition: Partition,
    per_unit_secs: &[f64],
    workers: &[Box<dyn Worker>],
    rest_cells: usize,
    tol: f64,
    max_iters: usize,
) -> (Partition, usize) {
    for it in 0..max_iters {
        let times: Vec<f64> = partition
            .shares
            .iter()
            .zip(per_unit_secs)
            .map(|(&s, &t)| s as f64 * t)
            .collect();
        let tmax = times.iter().cloned().fold(0.0, f64::max);
        let tmin = times
            .iter()
            .cloned()
            .filter(|&t| t > 0.0)
            .fold(f64::INFINITY, f64::min);
        if tmax <= 0.0 || (tmax - tmin) / tmax <= tol {
            return (partition, it);
        }
        let next = retune(&partition, &times, workers, rest_cells);
        if next == partition {
            return (partition, it);
        }
        partition = next;
    }
    (partition, max_iters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::NativeWorker;
    use crate::stencil::spec;

    fn workers(caps: &[usize]) -> Vec<Box<dyn Worker>> {
        caps.iter()
            .map(|&c| {
                Box::new(NativeWorker::new(crate::engine::by_name("simd", 1).unwrap(), c))
                    as Box<dyn Worker>
            })
            .collect()
    }

    #[test]
    fn profile_returns_positive_times() {
        let s = spec::get("heat2d").unwrap();
        let ws = workers(&[1 << 30, 1 << 30]);
        let p = profile_workers(&ws, &s, &[8, 8], 2, 3).unwrap();
        assert_eq!(p.len(), 2);
        assert!(p.iter().all(|&t| t > 0.0));
    }

    #[test]
    fn tune_weights_by_inverse_time() {
        let ws = workers(&[1 << 30, 1 << 30]);
        // worker 1 is 3x faster
        let p = tune(4, 8, 64, &[3e-3, 1e-3], &ws);
        assert_eq!(p.total_units(), 8);
        assert_eq!(p.shares, vec![2, 6]);
    }

    #[test]
    fn tune_respects_capacity() {
        // fast worker limited to ~2 units: 2 units x (3*4*64*8)B = 12 KiB
        let ws = workers(&[1 << 30, 2 * 3 * 4 * 64 * 8]);
        let p = tune(4, 8, 64, &[3e-3, 1e-3], &ws);
        assert_eq!(p.shares, vec![6, 2]);
    }

    #[test]
    fn converge_reaches_balance() {
        let ws = workers(&[1 << 30, 1 << 30]);
        let start = Partition::rows(1, vec![15, 1]);
        // per-unit: worker1 4x faster
        let (p, iters) = converge(start, &[4e-3, 1e-3], &ws, 64, 0.26, 10);
        // balanced split is ~(3.2, 12.8): within tol of equal times
        let t0 = p.shares[0] as f64 * 4e-3;
        let t1 = p.shares[1] as f64 * 1e-3;
        assert!((t0 - t1).abs() / t0.max(t1) <= 0.26, "{p:?} {t0} {t1} after {iters}");
        assert_eq!(p.total_units(), 16);
    }

    #[test]
    fn retune_keeps_total() {
        let ws = workers(&[1 << 30, 1 << 30]);
        let p = Partition::rows(2, vec![5, 5]);
        let q = retune(&p, &[0.010, 0.002], &ws, 64);
        assert_eq!(q.total_units(), 10);
        assert!(q.shares[1] > q.shares[0]);
    }

    #[test]
    fn tune_zero_capacity_worker_is_skipped() {
        // Worker 1 reports a memory capacity below one unit: the tuner
        // must hand its whole ideal share to worker 0 (fast profile or
        // not), never a negative / wrapped share.
        let ws = workers(&[1 << 30, 16]);
        let p = tune(4, 8, 64, &[5e-3, 1e-3], &ws);
        assert_eq!(p.shares, vec![8, 0]);
        assert_eq!(p.total_units(), 8);
    }

    #[test]
    fn tune_single_unit_grid() {
        let ws = workers(&[1 << 30, 1 << 30]);
        // One unit total: it lands on the faster worker, and retuning a
        // single-unit partition stays feasible.
        let p = tune(16, 1, 64, &[4e-3, 1e-3], &ws);
        assert_eq!(p.total_units(), 1);
        assert_eq!(p.shares, vec![0, 1]);
        let q = retune(&p, &[1e-9, 2e-3], &ws, 64);
        assert_eq!(q.total_units(), 1);
    }

    #[test]
    fn retune_zero_share_worker_keeps_exploration_weight() {
        // A squeezed-out worker (share 0) must keep a nonzero weight so
        // a later rebalance can bring it back when the loaded worker
        // turns out to be slow.
        let ws = workers(&[1 << 30, 1 << 30]);
        let p = Partition::rows(1, vec![0, 12]);
        let q = retune(&p, &[1e-3, 1e-1], &ws, 64);
        assert_eq!(q.total_units(), 12);
        assert!(q.shares[0] > 0, "{q:?}");
    }

    #[test]
    fn migration_cost_counts_moved_units_and_links() {
        let m = CommModel::default();
        let from = Partition::rows(2, vec![6, 2]);
        let to = Partition::rows(2, vec![4, 4]);
        // 2 moved units x 2 rows x 64 cells x 8 B = 2048 B across 2 links
        let c = migration_cost(&m, &from, &to, 64);
        assert!((c - (2.0 * m.alpha + 2048.0 * m.beta)).abs() < 1e-15, "{c}");
        // no movement, no cost
        assert_eq!(migration_cost(&m, &from, &from, 64), 0.0);
    }

    #[test]
    fn migration_cost_counts_band_reshuffles() {
        // Same row shares, different band widths: a pure dim-1 move.
        let m = CommModel::default();
        let from = Partition::rows(1, vec![4, 4]).with_bands(vec![6, 2]);
        let to = Partition::rows(1, vec![4, 4]).with_bands(vec![4, 4]);
        // cells/worker go [24,24,8,8] -> [16,16,16,16]: 16 moved cells
        // x 8 B across 4 links
        let c = migration_cost(&m, &from, &to, 1);
        assert!((c - (4.0 * m.alpha + 128.0 * m.beta)).abs() < 1e-15, "{c}");
    }

    #[test]
    fn retune_gated_grid_shifts_rows_on_run_skew() {
        // 2x2 grid, run gx=1 uniformly 4x slower at ms scale: the x-axis
        // repartitions, the bands stay put.
        let ws = workers(&[1 << 30; 4]);
        let m = CommModel::default();
        let p = Partition::rows(1, vec![8, 8]).with_bands(vec![8, 8]);
        let q = retune_gated_grid(&p, &[10e-3, 40e-3, 10e-3, 40e-3], &ws, 1, &m, 1, 4)
            .expect("genuine run skew must repartition");
        assert!(q.shares[0] > q.shares[1], "{q:?}");
        assert_eq!(q.total_units(), 16);
        assert_eq!(q.cols, vec![8, 8], "band widths must not move on a pure run skew");
    }

    #[test]
    fn retune_gated_grid_shifts_bands_on_band_skew() {
        // Band gy=1 uniformly 4x slower: dim-1 rebalances, shares stay.
        let ws = workers(&[1 << 30; 4]);
        let m = CommModel::default();
        let p = Partition::rows(1, vec![8, 8]).with_bands(vec![8, 8]);
        let q = retune_gated_grid(&p, &[10e-3, 10e-3, 40e-3, 40e-3], &ws, 1, &m, 1, 4)
            .expect("genuine band skew must repartition");
        assert_eq!(q.shares, vec![8, 8], "row shares must not move on a pure band skew");
        assert!(q.cols[0] > q.cols[1], "{q:?}");
        assert_eq!(q.total_cols(), 16);
    }

    #[test]
    fn retune_gated_grid_skips_marginal_imbalance() {
        // µs-scale tile skew: the candidate exists but the projected gain
        // is far below the 4-link migration latency.
        let ws = workers(&[1 << 30; 4]);
        let m = CommModel::default();
        let p = Partition::rows(1, vec![8, 8]).with_bands(vec![8, 8]);
        assert!(retune_gated_grid(&p, &[1.2e-6, 0.8e-6, 1.2e-6, 0.8e-6], &ws, 1, &m, 1, 4)
            .is_none());
    }

    #[test]
    fn retune_gated_grid_infeasible_capacity_keeps_grid() {
        // Every worker can hold exactly one row unit of an 8-col band:
        // 2 cap units total < 16 units, so the grid must stay as-is even
        // under a genuine skew instead of panicking in the squeezer.
        let ws = workers(&[192; 4]);
        let m = CommModel::default();
        let p = Partition::rows(1, vec![8, 8]).with_bands(vec![8, 8]);
        assert!(retune_gated_grid(&p, &[10e-3, 40e-3, 10e-3, 40e-3], &ws, 1, &m, 1, 4)
            .is_none());
    }

    /// ROADMAP hysteresis acceptance: a noise-scale imbalance produces a
    /// retune candidate, but the gate rejects it because the projected
    /// gain over the remaining blocks is far below one launch latency.
    #[test]
    fn retune_gated_skips_marginal_imbalance() {
        let ws = workers(&[1 << 30, 1 << 30]);
        let m = CommModel::default();
        let p = Partition::rows(1, vec![8, 8]);
        let measured = [1.2e-6, 0.8e-6]; // µs-scale blocks: gain ≪ α
        assert_ne!(retune(&p, &measured, &ws, 64), p, "imbalance must produce a candidate");
        assert!(retune_gated(&p, &measured, &ws, 64, &m, 64, 4).is_none());
    }

    /// Alternating measurement noise must never move slabs: the gated
    /// retune holds the partition perfectly still where the ungated one
    /// would flip shares every window.
    #[test]
    fn retune_gated_does_not_thrash_on_noise() {
        let ws = workers(&[1 << 30, 1 << 30]);
        let m = CommModel::default();
        let mut p = Partition::rows(1, vec![8, 8]);
        for i in 0..10 {
            let measured =
                if i % 2 == 0 { [1.2e-6, 0.8e-6] } else { [0.8e-6, 1.2e-6] };
            if let Some(next) = retune_gated(&p, &measured, &ws, 64, &m, 64, 8) {
                p = next;
            }
        }
        assert_eq!(p.shares, vec![8, 8], "noise-scale imbalance thrashed the shares");
    }

    #[test]
    fn retune_gated_fires_on_genuine_skew() {
        let ws = workers(&[1 << 30, 1 << 30]);
        let m = CommModel::default();
        let p = Partition::rows(1, vec![8, 8]);
        // 4x skew at ms scale: projected gain (tens of ms) ≫ migration cost
        let q = retune_gated(&p, &[40e-3, 10e-3], &ws, 64, &m, 64, 4)
            .expect("genuine skew must repartition");
        assert!(q.shares[1] > q.shares[0], "{q:?}");
        assert_eq!(q.total_units(), 16);
    }

    #[test]
    fn retune_gated_never_fires_on_last_block() {
        let ws = workers(&[1 << 30, 1 << 30]);
        let m = CommModel::default();
        let p = Partition::rows(1, vec![8, 8]);
        // migrating with no blocks left to amortize it is pure cost
        assert!(retune_gated(&p, &[40e-3, 10e-3], &ws, 64, &m, 64, 0).is_none());
    }

    #[test]
    fn converge_single_worker_trivial() {
        let ws = workers(&[1 << 30]);
        let start = Partition::rows(2, vec![6]);
        let (p, iters) = converge(start.clone(), &[1e-3], &ws, 64, 0.1, 5);
        assert_eq!(p, start);
        assert_eq!(iters, 0);
    }

    /// Fails only on calls after the warmup: exactly the case the old
    /// `let _ = w.run_slab(...)` swallowed, turning a broken worker into
    /// a near-zero (i.e. "infinitely fast") profile.
    struct FailsAfterWarmup {
        calls: std::sync::atomic::AtomicUsize,
    }

    impl Worker for FailsAfterWarmup {
        fn name(&self) -> String {
            "fails-after-warmup".into()
        }
        fn mem_capacity(&self) -> usize {
            1 << 30
        }
        fn run_slab(
            &self,
            spec: &crate::stencil::StencilSpec,
            input: &Field,
            steps: usize,
        ) -> Result<Field> {
            if self.calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst) >= 1 {
                crate::bail!("device lost");
            }
            Ok(crate::stencil::reference::block(input, spec, steps))
        }
    }

    #[test]
    fn profile_propagates_timed_call_failure() {
        let s = spec::get("heat2d").unwrap();
        let ws: Vec<Box<dyn Worker>> = vec![
            Box::new(NativeWorker::new(crate::engine::by_name("simd", 1).unwrap(), 1 << 30)),
            Box::new(FailsAfterWarmup { calls: std::sync::atomic::AtomicUsize::new(0) }),
        ];
        let err = profile_workers(&ws, &s, &[8, 8], 2, 3).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("device lost"), "{msg}");
        assert!(msg.contains("fails-after-warmup"), "{msg}");
    }

    #[test]
    fn profile_propagates_warmup_failure() {
        struct AlwaysFails;
        impl Worker for AlwaysFails {
            fn name(&self) -> String {
                "always-fails".into()
            }
            fn mem_capacity(&self) -> usize {
                1 << 30
            }
            fn run_slab(
                &self,
                _: &crate::stencil::StencilSpec,
                _: &Field,
                _: usize,
            ) -> Result<Field> {
                crate::bail!("no backend")
            }
        }
        let s = spec::get("heat1d").unwrap();
        let ws: Vec<Box<dyn Worker>> = vec![Box::new(AlwaysFails)];
        let err = profile_workers(&ws, &s, &[8], 1, 2).unwrap_err();
        assert!(format!("{err:#}").contains("warmup"), "{err:#}");
    }

    #[test]
    fn profile_workers_empty_list() {
        let s = spec::get("heat1d").unwrap();
        let ws: Vec<Box<dyn Worker>> = Vec::new();
        assert!(profile_workers(&ws, &s, &[8], 1, 1).unwrap().is_empty());
    }
}
