//! Run metrics: throughput (paper Eq. 5), per-worker utilization, pipeline
//! bubbles, and communication totals.

use std::time::Duration;

use super::comm::{CommLedger, CommModel};

#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub total_steps: usize,
    pub blocks: usize,
    /// Fields evolved together in one run (1 for a plain run; the batch
    /// width for `Scheduler::run_batch`).
    pub fields: usize,
    /// Core cells advanced per step, summed over the batch.
    pub core_cells: usize,
    pub elapsed: Duration,
    pub worker_names: Vec<String>,
    /// Total busy time per worker across all blocks.
    pub worker_busy: Vec<Duration>,
    /// Sum over blocks of (slowest worker - this worker): idle time.
    pub worker_idle: Vec<Duration>,
    pub comm: CommLedger,
    /// Scheduling share per worker (units fraction) at the END of the
    /// run — under `adapt_every` this is the converged partition.
    pub ratios: Vec<f64>,
    /// Exact per-worker unit shares at the end of the run (the converged
    /// partition under `adapt_every`; callers can reuse it as the next
    /// run's starting partition without a lossy ratio round-trip).
    pub final_shares: Vec<usize>,
    /// Per-band dim-1 cell widths at the end of the run — empty for the
    /// degenerate 1-D partition, mirroring `Partition::cols`.  Together
    /// with [`final_shares`] this reconstructs the converged 2-D grid.
    ///
    /// [`final_shares`]: RunMetrics::final_shares
    pub final_bands: Vec<usize>,
    /// §5.2 mid-run rebalances that actually moved slabs (0 = static).
    pub retunes: usize,
    /// Whether the §5.3 pipelined (double-buffered) leader loop ran.
    pub overlap: bool,
    /// Leader-phase work (ghost/extract/paste) executed while at least
    /// one worker slab was computing — the halo-exchange latency the
    /// pipelined loop hid.  Zero under the serial leader loop.
    pub overlap_hidden: Duration,
    /// Cumulative leader-phase durations across all blocks (divide by
    /// `blocks` for the per-block breakdown).  In the pipelined loop the
    /// ghost refresh is folded into slab assembly and reported under
    /// `leader_extract`.
    pub leader_ghost: Duration,
    pub leader_extract: Duration,
    pub leader_paste: Duration,
}

impl RunMetrics {
    /// Stencils per second (paper Eq. 5): Nx*Ny*Nz * T / time.
    pub fn gstencils_per_sec(&self) -> f64 {
        (self.core_cells as f64 * self.total_steps as f64) / self.elapsed.as_secs_f64() / 1e9
    }

    /// Total worker-seconds NOT spent computing over the run's wall
    /// clock: `workers * elapsed - Σ busy`.  Unlike [`worker_idle`]
    /// (which only counts per-block bubbles against the slowest slab),
    /// this includes the leader's serial ghost/extract/paste phases — the
    /// quantity the §5.3 overlapped leader loop exists to shrink.
    ///
    /// [`worker_idle`]: RunMetrics::worker_idle
    pub fn summed_idle_secs(&self) -> f64 {
        let busy: f64 = self.worker_busy.iter().map(|d| d.as_secs_f64()).sum();
        (self.worker_busy.len() as f64 * self.elapsed.as_secs_f64() - busy).max(0.0)
    }

    /// Fraction of worker-time lost to pipeline bubbles (0 = perfectly
    /// balanced partition — the §5.2 auto-tuning target).
    pub fn bubble_fraction(&self) -> f64 {
        let busy: f64 = self.worker_busy.iter().map(|d| d.as_secs_f64()).sum();
        let idle: f64 = self.worker_idle.iter().map(|d| d.as_secs_f64()).sum();
        if busy + idle == 0.0 {
            0.0
        } else {
            idle / (busy + idle)
        }
    }

    /// Human-readable report block.
    pub fn report(&self, model: &CommModel) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "steps={} blocks={} fields={} cells={} elapsed={:?} throughput={:.3} GStencils/s\n",
            self.total_steps,
            self.blocks,
            self.fields.max(1),
            self.core_cells,
            self.elapsed,
            self.gstencils_per_sec()
        ));
        for (i, name) in self.worker_names.iter().enumerate() {
            s.push_str(&format!(
                "  worker[{i}] {name}: share={:.1}% busy={:?} idle={:?}\n",
                self.ratios.get(i).copied().unwrap_or(0.0) * 100.0,
                self.worker_busy.get(i).copied().unwrap_or_default(),
                self.worker_idle.get(i).copied().unwrap_or_default(),
            ));
        }
        let (central, split) = self.comm.modeled_cost(model);
        s.push_str(&format!(
            "  comm: {} msgs, {} bytes (modeled {:.2}ms centralized vs {:.2}ms per-step)\n",
            self.comm.messages,
            self.comm.bytes,
            central * 1e3,
            split * 1e3
        ));
        s.push_str(&format!(
            "  bubble fraction: {:.1}% (retunes: {})\n",
            self.bubble_fraction() * 100.0,
            self.retunes
        ));
        s.push_str(&format!(
            "  leader: {} — ghost {:?} extract {:?} paste {:?} (hidden under compute: {:?}, \
             overlapped msgs: {}/{})\n",
            if self.overlap { "pipelined" } else { "serial" },
            self.leader_ghost,
            self.leader_extract,
            self.leader_paste,
            self.overlap_hidden,
            self.comm.overlapped_messages,
            self.comm.messages,
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_eq5() {
        let m = RunMetrics {
            total_steps: 100,
            core_cells: 1_000_000,
            elapsed: Duration::from_secs(1),
            ..Default::default()
        };
        assert!((m.gstencils_per_sec() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn bubble_fraction_balanced_is_zero() {
        let m = RunMetrics {
            worker_busy: vec![Duration::from_secs(1), Duration::from_secs(1)],
            worker_idle: vec![Duration::ZERO, Duration::ZERO],
            ..Default::default()
        };
        assert_eq!(m.bubble_fraction(), 0.0);
    }

    #[test]
    fn bubble_fraction_imbalanced() {
        let m = RunMetrics {
            worker_busy: vec![Duration::from_secs(3), Duration::from_secs(1)],
            worker_idle: vec![Duration::ZERO, Duration::from_secs(2)],
            ..Default::default()
        };
        assert!((m.bubble_fraction() - 2.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn report_contains_workers() {
        let m = RunMetrics {
            worker_names: vec!["native:simd".into()],
            worker_busy: vec![Duration::from_millis(5)],
            worker_idle: vec![Duration::ZERO],
            ratios: vec![1.0],
            elapsed: Duration::from_millis(10),
            total_steps: 1,
            core_cells: 100,
            ..Default::default()
        };
        let r = m.report(&CommModel::default());
        assert!(r.contains("native:simd"));
        assert!(r.contains("bubble"));
        assert!(r.contains("leader: serial"));
    }

    #[test]
    fn summed_idle_counts_leader_phases_too() {
        // 2 workers over a 10s run with 4s+6s busy: 20 - 10 = 10s idle,
        // regardless of how worker_idle attributed per-block bubbles.
        let m = RunMetrics {
            worker_busy: vec![Duration::from_secs(4), Duration::from_secs(6)],
            worker_idle: vec![Duration::from_secs(2), Duration::ZERO],
            elapsed: Duration::from_secs(10),
            ..Default::default()
        };
        assert!((m.summed_idle_secs() - 10.0).abs() < 1e-12);
    }
}
