//! Two-way (N-way) partitioning on memory-level tetrominoes (paper §5.1).
//!
//! The global domain's leading dimension is quantized into *units* (the
//! slab quantum fixed by the AOT artifacts — one memory-level tetromino).
//! A partition assigns each worker a contiguous run of units.  Balanced
//! partitioning weights the split by measured worker throughput; the
//! memory squeezer then clamps every share to its worker's capacity and
//! spills the remainder bidirectionally (paper: "once the GPU memory is
//! fully occupied, the remaining part left on CPU is still
//! well-addressed").

/// Assignment of `unit`-row slabs to workers, in worker order.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    /// Rows per unit (dim-0 quantum).
    pub unit: usize,
    /// Units owned by each worker (contiguous, in order).
    pub shares: Vec<usize>,
}

impl Partition {
    pub fn total_units(&self) -> usize {
        self.shares.iter().sum()
    }

    /// Row spans [start, end) per worker (dim-0, core coordinates).
    pub fn spans(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.shares.len());
        let mut x = 0;
        for &s in &self.shares {
            out.push((x * self.unit, (x + s) * self.unit));
            x += s;
        }
        out
    }

    /// GPU:CPU style scheduling ratio of worker `i` (paper Fig. 14).
    pub fn ratio(&self, i: usize) -> f64 {
        self.shares[i] as f64 / self.total_units() as f64
    }

    /// Split `units` across workers proportionally to `weights`
    /// (typically 1/latency), honouring per-worker capacity in units.
    /// Every worker with weight > 0 gets at least 0; leftovers spill to
    /// the workers with remaining capacity, largest weight first.
    pub fn balanced(unit: usize, units: usize, weights: &[f64], cap_units: &[usize]) -> Partition {
        assert_eq!(weights.len(), cap_units.len());
        assert!(!weights.is_empty());
        let wsum: f64 = weights.iter().sum();
        assert!(wsum > 0.0, "all weights zero");
        let n = weights.len();
        // Ideal real-valued shares, floored; then distribute the
        // remainder by largest fractional part (Hamilton method).
        let ideal: Vec<f64> = weights.iter().map(|w| units as f64 * w / wsum).collect();
        let mut shares: Vec<usize> = ideal.iter().map(|x| x.floor() as usize).collect();
        let mut assigned: usize = shares.iter().sum();
        let mut frac: Vec<(usize, f64)> = ideal
            .iter()
            .enumerate()
            .map(|(i, x)| (i, x - x.floor()))
            .collect();
        frac.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut fi = 0;
        while assigned < units {
            let i = frac[fi % n].0;
            shares[i] += 1;
            assigned += 1;
            fi += 1;
        }
        // Memory squeeze: clamp to capacity, spill bidirectionally.
        let mut spill: usize = 0;
        for i in 0..n {
            if shares[i] > cap_units[i] {
                spill += shares[i] - cap_units[i];
                shares[i] = cap_units[i];
            }
        }
        if spill > 0 {
            // order receivers by weight, highest throughput first
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| weights[b].partial_cmp(&weights[a]).unwrap());
            for &i in &order {
                let room = cap_units[i] - shares[i];
                let take = room.min(spill);
                shares[i] += take;
                spill -= take;
                if spill == 0 {
                    break;
                }
            }
        }
        assert_eq!(spill, 0, "total capacity smaller than the domain");
        Partition { unit, shares }
    }
}

/// Units a worker with `capacity_bytes` can hold: each unit needs
/// input + output + one scratch copy of the unit slab.
pub fn capacity_units(capacity_bytes: usize, unit_rows: usize, rest_cells: usize) -> usize {
    let per_unit = 3 * unit_rows * rest_cells * 8;
    capacity_bytes / per_unit.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_are_contiguous_and_cover() {
        let p = Partition { unit: 4, shares: vec![3, 1, 2] };
        assert_eq!(p.spans(), vec![(0, 12), (12, 16), (16, 24)]);
        assert_eq!(p.total_units(), 6);
    }

    #[test]
    fn balanced_respects_weights() {
        let p = Partition::balanced(64, 10, &[1.0, 4.0], &[100, 100]);
        assert_eq!(p.total_units(), 10);
        assert_eq!(p.shares, vec![2, 8]);
    }

    #[test]
    fn balanced_equal_weights_splits_evenly() {
        let p = Partition::balanced(1, 9, &[1.0, 1.0, 1.0], &[10, 10, 10]);
        assert_eq!(p.total_units(), 9);
        assert!(p.shares.iter().all(|&s| s == 3));
    }

    #[test]
    fn squeezer_spills_over_capacity() {
        // fast worker capped at 3 units: spill lands on the slow one
        let p = Partition::balanced(64, 10, &[1.0, 9.0], &[100, 3]);
        assert_eq!(p.shares, vec![7, 3]);
        assert_eq!(p.total_units(), 10);
    }

    #[test]
    fn squeezer_bidirectional() {
        // both capped; spill routed wherever room remains
        let p = Partition::balanced(1, 12, &[1.0, 1.0, 1.0], &[2, 100, 2]);
        assert_eq!(p.total_units(), 12);
        assert!(p.shares[0] <= 2 && p.shares[2] <= 2);
        assert_eq!(p.shares[1], 8);
    }

    #[test]
    #[should_panic(expected = "total capacity")]
    fn impossible_capacity_panics() {
        Partition::balanced(1, 10, &[1.0, 1.0], &[2, 2]);
    }

    #[test]
    fn zero_capacity_worker_gets_nothing() {
        // A worker with no memory must end with share 0, regardless of
        // its weight; the whole domain spills to the others.
        let p = Partition::balanced(4, 10, &[9.0, 1.0], &[0, 100]);
        assert_eq!(p.shares, vec![0, 10]);
        assert_eq!(p.total_units(), 10);
        assert_eq!(p.ratio(0), 0.0);
        // spans stay contiguous even with an empty leading share
        assert_eq!(p.spans(), vec![(0, 0), (0, 40)]);
    }

    #[test]
    fn single_unit_grid_goes_to_heaviest() {
        let p = Partition::balanced(64, 1, &[0.2, 0.7, 0.1], &[10, 10, 10]);
        assert_eq!(p.total_units(), 1);
        assert_eq!(p.shares, vec![0, 1, 0]);
    }

    #[test]
    fn squeeze_underflow_spills_everything() {
        // The fast worker's floored ideal share (9) far exceeds its
        // capacity (2): the squeezer must not underflow, and the slow
        // worker absorbs the rest.
        let p = Partition::balanced(1, 10, &[99.0, 1.0], &[2, 100]);
        assert_eq!(p.shares, vec![2, 8]);
        assert_eq!(p.total_units(), 10);
    }

    #[test]
    fn exact_capacity_fit_is_feasible() {
        // Total capacity == units: every worker is filled to its cap.
        let p = Partition::balanced(2, 7, &[1.0, 1.0, 1.0], &[3, 2, 2]);
        assert_eq!(p.total_units(), 7);
        assert_eq!(p.shares, vec![3, 2, 2]);
    }

    #[test]
    fn capacity_units_zero_bytes() {
        assert_eq!(capacity_units(0, 64, 256), 0);
        // sub-unit capacity also rounds down to zero
        assert_eq!(capacity_units(3 * 64 * 256 * 8 - 1, 64, 256), 0);
    }

    #[test]
    fn ratio_matches_shares() {
        let p = Partition { unit: 1, shares: vec![1, 3] };
        assert!((p.ratio(1) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn capacity_units_math() {
        // 3 copies x 64 rows x 256 cells x 8B = 393216 B per unit
        assert_eq!(capacity_units(800_000, 64, 256), 2);
    }
}
