//! Two-way (N-way) partitioning on memory-level tetrominoes (paper §5.1).
//!
//! The global domain's leading dimension is quantized into *units* (the
//! slab quantum fixed by the AOT artifacts — one memory-level tetromino).
//! A partition assigns each worker a contiguous run of units.  Balanced
//! partitioning weights the split by measured worker throughput; the
//! memory squeezer then clamps every share to its worker's capacity and
//! spills the remainder bidirectionally (paper: "once the GPU memory is
//! fully occupied, the remaining part left on CPU is still
//! well-addressed").

/// Assignment of tiles to workers, in worker order.
///
/// 1-D (the historical shape): `cols` is empty and each worker owns a
/// contiguous run of `unit`-row slabs — worker `i` gets `shares[i]`
/// units of dim 0.  2-D: `cols` holds the dim-1 cell widths of `wy`
/// grid bands, the `shares` run along dim 0 is shared by every band,
/// and worker `w = gy * wx + gx` owns the rect
/// `rows(gx) × band(gy)`.  `cols.is_empty()` is the degenerate `wy = 1`
/// grid and must behave bit-identically to the pre-grid partition.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Partition {
    /// Rows per unit (dim-0 quantum).
    pub unit: usize,
    /// Dim-0 units owned by each grid column (contiguous, in order).
    pub shares: Vec<usize>,
    /// Dim-1 cells owned by each grid band (contiguous, in order);
    /// empty for the degenerate 1-D partition.
    pub cols: Vec<usize>,
}

impl Partition {
    /// The historical 1-D shape: dim-0 runs only.
    pub fn rows(unit: usize, shares: Vec<usize>) -> Partition {
        Partition { unit, shares, cols: Vec::new() }
    }

    /// Attach dim-1 bands, turning this into a `cols.len() × wx` grid.
    /// A single band covers the whole axis and is normalized away — a
    /// `1 × wx` grid IS the degenerate partition, by construction.
    pub fn with_bands(mut self, cols: Vec<usize>) -> Partition {
        self.cols = if cols.len() > 1 { cols } else { Vec::new() };
        self
    }

    /// Grid height (bands along dim 1).
    pub fn wy(&self) -> usize {
        self.cols.len().max(1)
    }

    /// Grid width (runs along dim 0).
    pub fn wx(&self) -> usize {
        self.shares.len()
    }

    /// Total workers: `wy * wx` (== `shares.len()` when degenerate).
    pub fn workers(&self) -> usize {
        self.wy() * self.wx()
    }

    pub fn total_units(&self) -> usize {
        self.shares.iter().sum()
    }

    /// Total dim-1 cells across the bands (0 when degenerate).
    pub fn total_cols(&self) -> usize {
        self.cols.iter().sum()
    }

    /// Row spans [start, end) per grid column (dim-0, core
    /// coordinates).  One entry per worker when degenerate.
    pub fn spans(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.shares.len());
        let mut x = 0;
        for &s in &self.shares {
            out.push((x * self.unit, (x + s) * self.unit));
            x += s;
        }
        out
    }

    /// Column spans [start, end) per grid band (dim-1, core cell
    /// coordinates).  `n_cols` is the domain's dim-1 extent, returned
    /// as the single full-width band when degenerate.
    pub fn bands(&self, n_cols: usize) -> Vec<(usize, usize)> {
        if self.cols.is_empty() {
            return vec![(0, n_cols)];
        }
        let mut out = Vec::with_capacity(self.cols.len());
        let mut c = 0;
        for &w in &self.cols {
            out.push((c, c + w));
            c += w;
        }
        out
    }

    /// Per-worker 2-D rects `((r0, r1), (c0, c1))` in worker order
    /// `w = gy * wx + gx` — rows in dim-0 core coordinates, cols in
    /// dim-1 core cell coordinates.  Degenerate partitions yield one
    /// full-width rect per span.
    pub fn rects(&self, n_cols: usize) -> Vec<((usize, usize), (usize, usize))> {
        let spans = self.spans();
        let mut out = Vec::with_capacity(self.workers());
        for band in self.bands(n_cols) {
            for &span in &spans {
                out.push((span, band));
            }
        }
        out
    }

    /// Cells owned by each worker, scaled by `rest_cells` (the product
    /// of the dims the partition does not split: dims 1.. when
    /// degenerate, dims 2.. for a grid).  Worker order.
    pub fn worker_cells(&self, rest_cells: usize) -> Vec<usize> {
        if self.cols.is_empty() {
            return self.shares.iter().map(|&s| s * self.unit * rest_cells).collect();
        }
        let mut out = Vec::with_capacity(self.workers());
        for &c in &self.cols {
            for &s in &self.shares {
                out.push(s * self.unit * c * rest_cells);
            }
        }
        out
    }

    /// GPU:CPU style scheduling ratio of worker `i` (paper Fig. 14) —
    /// the fraction of domain cells worker `i` owns.
    pub fn ratio(&self, i: usize) -> f64 {
        if self.cols.is_empty() {
            return self.shares[i] as f64 / self.total_units() as f64;
        }
        let (gy, gx) = (i / self.wx(), i % self.wx());
        let total = self.total_units() as f64 * self.total_cols() as f64;
        (self.shares[gx] * self.cols[gy]) as f64 / total
    }

    /// Split `units` across workers proportionally to `weights`
    /// (typically 1/latency), honouring per-worker capacity in units.
    /// Every worker with weight > 0 gets at least 0; leftovers spill to
    /// the workers with remaining capacity, largest weight first.
    pub fn balanced(unit: usize, units: usize, weights: &[f64], cap_units: &[usize]) -> Partition {
        assert_eq!(weights.len(), cap_units.len());
        assert!(!weights.is_empty());
        let wsum: f64 = weights.iter().sum();
        assert!(wsum > 0.0, "all weights zero");
        let n = weights.len();
        // Ideal real-valued shares, floored; then distribute the
        // remainder by largest fractional part (Hamilton method).
        let ideal: Vec<f64> = weights.iter().map(|w| units as f64 * w / wsum).collect();
        let mut shares: Vec<usize> = ideal.iter().map(|x| x.floor() as usize).collect();
        let mut assigned: usize = shares.iter().sum();
        let mut frac: Vec<(usize, f64)> = ideal
            .iter()
            .enumerate()
            .map(|(i, x)| (i, x - x.floor()))
            .collect();
        frac.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut fi = 0;
        while assigned < units {
            let i = frac[fi % n].0;
            shares[i] += 1;
            assigned += 1;
            fi += 1;
        }
        // Memory squeeze: clamp to capacity, spill bidirectionally.
        let mut spill: usize = 0;
        for i in 0..n {
            if shares[i] > cap_units[i] {
                spill += shares[i] - cap_units[i];
                shares[i] = cap_units[i];
            }
        }
        if spill > 0 {
            // order receivers by weight, highest throughput first
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| weights[b].partial_cmp(&weights[a]).unwrap());
            for &i in &order {
                let room = cap_units[i] - shares[i];
                let take = room.min(spill);
                shares[i] += take;
                spill -= take;
                if spill == 0 {
                    break;
                }
            }
        }
        assert_eq!(spill, 0, "total capacity smaller than the domain");
        Partition::rows(unit, shares)
    }
}

/// Split `total` cells into `k` contiguous runs as evenly as possible
/// (the leading runs absorb the remainder) — the default band layout
/// for `--grid WyxWx`.
pub fn even_split(total: usize, k: usize) -> Vec<usize> {
    assert!(k > 0, "cannot split into zero runs");
    let (q, r) = (total / k, total % k);
    (0..k).map(|i| q + usize::from(i < r)).collect()
}

/// Units a worker with `capacity_bytes` can hold: each unit needs
/// input + output + one scratch copy of the unit slab.
pub fn capacity_units(capacity_bytes: usize, unit_rows: usize, rest_cells: usize) -> usize {
    let per_unit = 3 * unit_rows * rest_cells * 8;
    capacity_bytes / per_unit.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_are_contiguous_and_cover() {
        let p = Partition::rows(4, vec![3, 1, 2]);
        assert_eq!(p.spans(), vec![(0, 12), (12, 16), (16, 24)]);
        assert_eq!(p.total_units(), 6);
        assert_eq!((p.wy(), p.wx(), p.workers()), (1, 3, 3));
    }

    #[test]
    fn grid_rects_tile_the_domain() {
        // 2×3 grid over 24 rows × 10 cols: row-major worker rects.
        let p = Partition::rows(4, vec![3, 1, 2]).with_bands(vec![6, 4]);
        assert_eq!((p.wy(), p.wx(), p.workers()), (2, 3, 6));
        assert_eq!(p.bands(10), vec![(0, 6), (6, 10)]);
        assert_eq!(
            p.rects(10),
            vec![
                ((0, 12), (0, 6)),
                ((12, 16), (0, 6)),
                ((16, 24), (0, 6)),
                ((0, 12), (6, 10)),
                ((12, 16), (6, 10)),
                ((16, 24), (6, 10)),
            ]
        );
        // per-worker cells and ratios follow the area product
        assert_eq!(
            p.worker_cells(1),
            vec![72, 24, 48, 48, 16, 32]
        );
        assert!((p.ratio(0) - 72.0 / 240.0).abs() < 1e-12);
        assert!((p.ratio(4) - 16.0 / 240.0).abs() < 1e-12);
        let total: f64 = (0..p.workers()).map(|i| p.ratio(i)).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn single_band_normalizes_to_degenerate() {
        // a 1×wx grid IS the degenerate partition, by construction
        let p = Partition::rows(2, vec![2, 2]).with_bands(vec![10]);
        assert!(p.cols.is_empty());
        assert_eq!(p, Partition::rows(2, vec![2, 2]));
        assert_eq!(p.bands(10), vec![(0, 10)]);
        assert_eq!(p.rects(10), vec![((0, 4), (0, 10)), ((4, 8), (0, 10))]);
        assert_eq!(p.worker_cells(5), vec![20, 20]);
    }

    #[test]
    fn even_split_distributes_remainder_first() {
        assert_eq!(even_split(10, 3), vec![4, 3, 3]);
        assert_eq!(even_split(9, 3), vec![3, 3, 3]);
        assert_eq!(even_split(2, 4), vec![1, 1, 0, 0]);
        assert_eq!(even_split(0, 2), vec![0, 0]);
    }

    #[test]
    fn balanced_respects_weights() {
        let p = Partition::balanced(64, 10, &[1.0, 4.0], &[100, 100]);
        assert_eq!(p.total_units(), 10);
        assert_eq!(p.shares, vec![2, 8]);
    }

    #[test]
    fn balanced_equal_weights_splits_evenly() {
        let p = Partition::balanced(1, 9, &[1.0, 1.0, 1.0], &[10, 10, 10]);
        assert_eq!(p.total_units(), 9);
        assert!(p.shares.iter().all(|&s| s == 3));
    }

    #[test]
    fn squeezer_spills_over_capacity() {
        // fast worker capped at 3 units: spill lands on the slow one
        let p = Partition::balanced(64, 10, &[1.0, 9.0], &[100, 3]);
        assert_eq!(p.shares, vec![7, 3]);
        assert_eq!(p.total_units(), 10);
    }

    #[test]
    fn squeezer_bidirectional() {
        // both capped; spill routed wherever room remains
        let p = Partition::balanced(1, 12, &[1.0, 1.0, 1.0], &[2, 100, 2]);
        assert_eq!(p.total_units(), 12);
        assert!(p.shares[0] <= 2 && p.shares[2] <= 2);
        assert_eq!(p.shares[1], 8);
    }

    #[test]
    #[should_panic(expected = "total capacity")]
    fn impossible_capacity_panics() {
        Partition::balanced(1, 10, &[1.0, 1.0], &[2, 2]);
    }

    #[test]
    fn zero_capacity_worker_gets_nothing() {
        // A worker with no memory must end with share 0, regardless of
        // its weight; the whole domain spills to the others.
        let p = Partition::balanced(4, 10, &[9.0, 1.0], &[0, 100]);
        assert_eq!(p.shares, vec![0, 10]);
        assert_eq!(p.total_units(), 10);
        assert_eq!(p.ratio(0), 0.0);
        // spans stay contiguous even with an empty leading share
        assert_eq!(p.spans(), vec![(0, 0), (0, 40)]);
    }

    #[test]
    fn single_unit_grid_goes_to_heaviest() {
        let p = Partition::balanced(64, 1, &[0.2, 0.7, 0.1], &[10, 10, 10]);
        assert_eq!(p.total_units(), 1);
        assert_eq!(p.shares, vec![0, 1, 0]);
    }

    #[test]
    fn squeeze_underflow_spills_everything() {
        // The fast worker's floored ideal share (9) far exceeds its
        // capacity (2): the squeezer must not underflow, and the slow
        // worker absorbs the rest.
        let p = Partition::balanced(1, 10, &[99.0, 1.0], &[2, 100]);
        assert_eq!(p.shares, vec![2, 8]);
        assert_eq!(p.total_units(), 10);
    }

    #[test]
    fn exact_capacity_fit_is_feasible() {
        // Total capacity == units: every worker is filled to its cap.
        let p = Partition::balanced(2, 7, &[1.0, 1.0, 1.0], &[3, 2, 2]);
        assert_eq!(p.total_units(), 7);
        assert_eq!(p.shares, vec![3, 2, 2]);
    }

    #[test]
    fn capacity_units_zero_bytes() {
        assert_eq!(capacity_units(0, 64, 256), 0);
        // sub-unit capacity also rounds down to zero
        assert_eq!(capacity_units(3 * 64 * 256 * 8 - 1, 64, 256), 0);
    }

    #[test]
    fn ratio_matches_shares() {
        let p = Partition::rows(1, vec![1, 3]);
        assert!((p.ratio(1) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn capacity_units_math() {
        // 3 copies x 64 rows x 256 cells x 8B = 393216 B per unit
        assert_eq!(capacity_units(800_000, 64, 256), 2);
    }
}
