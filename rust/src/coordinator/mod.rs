//! The concurrent scheduler on memory-level tetrominoes (paper §5).
//!
//! * [`partition`] — two-way/N-way unit-quantized partitioning +
//!   bidirectional memory squeezing (§5.1);
//! * [`tuner`] — profile-initialized auto-tuning balance (§5.2);
//! * [`comm`] — α+β model + centralized-launch accounting (§5.3);
//! * [`worker`] — native-CPU and artifact workers;
//! * [`pool`] — work-stealing deque pool primitives used by both the
//!   engines and the pipeline (steal_map + dependency-DAG execution;
//!   each call runs its own scoped pool);
//! * [`pipeline`] — the heterogeneous driver (Fig. 11), boundary-aware
//!   (Dirichlet/Neumann/Periodic ghost refill per block) with optional
//!   in-run §5.2 adaptive re-partitioning, runnable as either the
//!   block-synchronous serial leader loop or the §5.3 pipelined loop
//!   (double-buffered globals, halo prefetch overlapped with compute);
//! * [`metrics`] — Eq.-5 throughput, bubbles, comm totals.

pub mod comm;
pub mod metrics;
pub mod partition;
pub mod pipeline;
pub mod pool;
pub mod tuner;
pub mod worker;

pub use comm::{CommLedger, CommModel};
pub use metrics::RunMetrics;
pub use partition::Partition;
pub use pipeline::{Overlap, Scheduler};
pub use worker::{NativeWorker, Worker, XlaWorker};
