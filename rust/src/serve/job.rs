//! Wire types of the serving line protocol: [`JobSpec`] (one JSON object
//! per request line) and [`JobResult`] (one JSON object per reply line),
//! with serde-free codecs over [`crate::util::json::Json`].
//!
//! Decoding is *tolerant*: unknown keys are ignored (a newer client may
//! send fields an older server does not know), and every known field has
//! a default, so the minimal job is just `{"bench":"heat2d"}`.  Encoding
//! is deterministic (object keys sort lexicographically through the
//! `BTreeMap` printer), which keeps the golden-file tests byte-stable.
//! Field payloads round-trip bit-exactly: the printer emits the shortest
//! decimal that re-parses to the same f64.

use std::collections::BTreeMap;

use crate::util::error::{Context, Result};
use crate::util::json::Json;

use crate::stencil::{spec, Boundary, Field};

/// Scheduling priority class; lower class index drains first, FIFO
/// within a class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Priority {
    Interactive,
    Normal,
    Batch,
}

/// Number of priority classes (queue lanes).
pub const PRIORITY_CLASSES: usize = 3;

impl Priority {
    /// Queue-lane index: 0 drains first.
    pub fn class(&self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Normal => 1,
            Priority::Batch => 2,
        }
    }
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Priority::Interactive => "interactive",
            Priority::Normal => "normal",
            Priority::Batch => "batch",
        })
    }
}

impl std::str::FromStr for Priority {
    type Err = crate::util::error::TetrisError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "interactive" | "0" => Ok(Priority::Interactive),
            "normal" | "1" => Ok(Priority::Normal),
            "batch" | "2" => Ok(Priority::Batch),
            other => Err(crate::err!(
                "unknown priority {other:?} (expected interactive, normal or batch)"
            )),
        }
    }
}

/// One evolution job: which dwarf, which physics, how far.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    /// Client-chosen tag, echoed verbatim in the result.
    pub id: String,
    pub bench: String,
    pub boundary: Boundary,
    /// Requested steps; the server aligns up to the session's Tb.
    pub steps: usize,
    pub priority: Priority,
    /// Core shape; `None` uses the server's default for the bench.
    pub shape: Option<Vec<usize>>,
    /// Input is `Field::random(shape, seed)` unless `field` is given.
    pub seed: u64,
    /// Inline input values (row-major; requires `shape`).
    pub field: Option<Vec<f64>>,
    /// Return the full final field in the result (costly on big grids).
    pub return_field: bool,
}

impl Default for JobSpec {
    fn default() -> Self {
        JobSpec {
            id: String::new(),
            bench: "heat2d".into(),
            boundary: Boundary::Dirichlet(0.0),
            steps: 4,
            priority: Priority::Normal,
            shape: None,
            seed: 1,
            field: None,
            return_field: false,
        }
    }
}

impl JobSpec {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("id".into(), Json::Str(self.id.clone()));
        m.insert("bench".into(), Json::Str(self.bench.clone()));
        m.insert("boundary".into(), Json::Str(self.boundary.to_string()));
        m.insert("steps".into(), Json::Num(self.steps as f64));
        m.insert("priority".into(), Json::Str(self.priority.to_string()));
        m.insert("seed".into(), Json::Num(self.seed as f64));
        m.insert("return_field".into(), Json::Bool(self.return_field));
        if let Some(shape) = &self.shape {
            m.insert("shape".into(), usize_arr(shape));
        }
        if let Some(field) = &self.field {
            m.insert("field".into(), f64_arr(field));
        }
        Json::Obj(m)
    }

    pub fn from_json(v: &Json) -> Result<JobSpec> {
        v.as_obj().context("job must be a JSON object")?;
        let d = JobSpec::default();
        let boundary: Boundary = match v.get("boundary") {
            Some(b) => b.as_str().context("boundary must be a string")?.parse()?,
            None => d.boundary,
        };
        let priority: Priority = match v.get("priority") {
            Some(p) => p.as_str().context("priority must be a string")?.parse()?,
            None => d.priority,
        };
        Ok(JobSpec {
            id: v.at(&["id"]).as_str().unwrap_or("").to_string(),
            bench: v.at(&["bench"]).as_str().unwrap_or(&d.bench).to_string(),
            boundary,
            steps: v.at(&["steps"]).as_usize().unwrap_or(d.steps),
            priority,
            shape: v.get("shape").and_then(|s| s.usize_vec()),
            seed: v.at(&["seed"]).as_u64().unwrap_or(d.seed),
            field: v.get("field").and_then(|f| f.f64_vec()),
            return_field: matches!(v.get("return_field"), Some(Json::Bool(true))),
        })
    }

    pub fn parse_line(line: &str) -> Result<JobSpec> {
        let v = Json::parse(line.trim()).context("job parse")?;
        JobSpec::from_json(&v)
    }

    /// Coalescing key: jobs with equal keys run as one multi-field
    /// dispatch (inputs differ per job; physics and geometry must not).
    pub fn batch_key(&self) -> String {
        format!("{}|{}|{}|{:?}", self.bench, self.boundary, self.steps, self.shape)
    }

    /// Resolve the input field: validate the bench/shape and build the
    /// initial core (inline values, else the seeded PRNG field).
    pub fn materialize(&self, default_shape: &[usize]) -> Result<Field> {
        let s = spec::get(&self.bench)
            .with_context(|| format!("unknown bench {:?}", self.bench))?;
        let shape: Vec<usize> = match &self.shape {
            Some(sh) => sh.clone(),
            None => default_shape.to_vec(),
        };
        crate::ensure!(
            shape.len() == s.ndim && shape.iter().all(|&n| n >= 1),
            "bench {} wants {} dims >= 1, got shape {shape:?}",
            self.bench,
            s.ndim
        );
        let cells = shape
            .iter()
            .try_fold(1usize, |a, &n| a.checked_mul(n))
            .with_context(|| format!("shape {shape:?} overflows the cell count"))?;
        match &self.field {
            Some(values) => {
                crate::ensure!(
                    values.len() == cells,
                    "inline field has {} values, shape {shape:?} wants {cells}",
                    values.len()
                );
                Ok(Field::from_vec(&shape, values.clone()))
            }
            None => Ok(Field::random(&shape, self.seed)),
        }
    }
}

/// One reply line.  `ok:false` replies (parse errors, admission rejects,
/// run failures) carry `error` and possibly `retry_after_ms`; `ok:true`
/// replies carry the run summary and, on request, the final field.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct JobResult {
    pub id: String,
    pub ok: bool,
    pub error: Option<String>,
    /// Backpressure hint on admission rejects (0 = do not retry).
    pub retry_after_ms: Option<u64>,
    pub bench: String,
    pub boundary: String,
    pub priority: String,
    /// Steps actually executed (the request aligned up to Tb).
    pub steps: usize,
    pub shape: Vec<usize>,
    pub mean: f64,
    pub l2: f64,
    pub field: Option<Vec<f64>>,
    /// Global admission order (per server).
    pub admit_seq: u64,
    /// Global queue-pop order, assigned under the queue lock — FIFO
    /// within a priority class for any dispatcher count (execution of
    /// already-popped batches may still overlap across dispatchers).
    pub start_seq: u64,
    /// Jobs coalesced into the same multi-field dispatch.
    pub batch_size: usize,
    pub queue_ms: f64,
    pub exec_ms: f64,
    /// The session's cached partition shares after this run.
    pub shares: Vec<usize>,
}

impl JobResult {
    /// Structured failure reply (connection stays open).
    pub fn failure(id: &str, error: impl Into<String>) -> JobResult {
        JobResult { id: id.into(), ok: false, error: Some(error.into()), ..Default::default() }
    }

    /// Admission reject with a backpressure hint.
    pub fn reject(id: &str, error: impl Into<String>, retry_after_ms: u64) -> JobResult {
        JobResult { retry_after_ms: Some(retry_after_ms), ..JobResult::failure(id, error) }
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("id".into(), Json::Str(self.id.clone()));
        m.insert("ok".into(), Json::Bool(self.ok));
        if let Some(e) = &self.error {
            m.insert("error".into(), Json::Str(e.clone()));
        }
        if let Some(ms) = self.retry_after_ms {
            m.insert("retry_after_ms".into(), Json::Num(ms as f64));
        }
        if !self.ok {
            return Json::Obj(m);
        }
        m.insert("bench".into(), Json::Str(self.bench.clone()));
        m.insert("boundary".into(), Json::Str(self.boundary.clone()));
        m.insert("priority".into(), Json::Str(self.priority.clone()));
        m.insert("steps".into(), Json::Num(self.steps as f64));
        m.insert("shape".into(), usize_arr(&self.shape));
        m.insert("mean".into(), Json::Num(self.mean));
        m.insert("l2".into(), Json::Num(self.l2));
        if let Some(field) = &self.field {
            m.insert("field".into(), f64_arr(field));
        }
        m.insert("admit_seq".into(), Json::Num(self.admit_seq as f64));
        m.insert("start_seq".into(), Json::Num(self.start_seq as f64));
        m.insert("batch_size".into(), Json::Num(self.batch_size as f64));
        m.insert("queue_ms".into(), Json::Num(self.queue_ms));
        m.insert("exec_ms".into(), Json::Num(self.exec_ms));
        m.insert("shares".into(), usize_arr(&self.shares));
        Json::Obj(m)
    }

    pub fn from_json(v: &Json) -> Result<JobResult> {
        v.as_obj().context("result must be a JSON object")?;
        Ok(JobResult {
            id: v.at(&["id"]).as_str().unwrap_or("").to_string(),
            ok: matches!(v.get("ok"), Some(Json::Bool(true))),
            error: v.get("error").and_then(|e| e.as_str()).map(String::from),
            retry_after_ms: v.get("retry_after_ms").and_then(|r| r.as_u64()),
            bench: v.at(&["bench"]).as_str().unwrap_or("").to_string(),
            boundary: v.at(&["boundary"]).as_str().unwrap_or("").to_string(),
            priority: v.at(&["priority"]).as_str().unwrap_or("").to_string(),
            steps: v.at(&["steps"]).as_usize().unwrap_or(0),
            shape: v.get("shape").and_then(|s| s.usize_vec()).unwrap_or_default(),
            mean: v.at(&["mean"]).as_f64().unwrap_or(0.0),
            l2: v.at(&["l2"]).as_f64().unwrap_or(0.0),
            field: v.get("field").and_then(|f| f.f64_vec()),
            admit_seq: v.at(&["admit_seq"]).as_u64().unwrap_or(0),
            start_seq: v.at(&["start_seq"]).as_u64().unwrap_or(0),
            batch_size: v.at(&["batch_size"]).as_usize().unwrap_or(0),
            queue_ms: v.at(&["queue_ms"]).as_f64().unwrap_or(0.0),
            exec_ms: v.at(&["exec_ms"]).as_f64().unwrap_or(0.0),
            shares: v.get("shares").and_then(|s| s.usize_vec()).unwrap_or_default(),
        })
    }

    pub fn parse_line(line: &str) -> Result<JobResult> {
        let v = Json::parse(line.trim()).context("result parse")?;
        JobResult::from_json(&v)
    }
}

fn usize_arr(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn f64_arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jobspec_roundtrips() {
        let spec = JobSpec {
            id: "j-7".into(),
            bench: "heat3d".into(),
            boundary: Boundary::Dirichlet(25.0),
            steps: 8,
            priority: Priority::Interactive,
            shape: Some(vec![16, 8, 8]),
            seed: 42,
            field: None,
            return_field: true,
        };
        let line = spec.to_json().to_string();
        assert!(!line.contains('\n'));
        assert_eq!(JobSpec::parse_line(&line).unwrap(), spec);
    }

    #[test]
    fn jobspec_defaults_and_unknown_fields() {
        // minimal job + a field from the future: both tolerated
        let spec =
            JobSpec::parse_line(r#"{"bench":"heat1d","x-tenant":"acme","quota":{"cpus":4}}"#)
                .unwrap();
        assert_eq!(spec.bench, "heat1d");
        assert_eq!(spec.boundary, Boundary::Dirichlet(0.0));
        assert_eq!(spec.priority, Priority::Normal);
        assert!(spec.shape.is_none() && spec.field.is_none() && !spec.return_field);
    }

    #[test]
    fn jobspec_rejects_bad_boundary_and_non_object() {
        assert!(JobSpec::parse_line(r#"{"boundary":"moebius"}"#).is_err());
        assert!(JobSpec::parse_line("[1,2,3]").is_err());
        assert!(JobSpec::parse_line("{oops").is_err());
    }

    #[test]
    fn jobresult_roundtrips_field_bits() {
        let values = vec![0.1 + 0.2, 1.0 / 3.0, 6.02e23, 2.5e-17, 0.0, 42.0];
        let r = JobResult {
            id: "j".into(),
            ok: true,
            bench: "heat2d".into(),
            boundary: "periodic".into(),
            priority: "normal".into(),
            steps: 4,
            shape: vec![2, 3],
            mean: values.iter().sum::<f64>() / 6.0,
            l2: 1.25,
            field: Some(values.clone()),
            admit_seq: 3,
            start_seq: 1,
            batch_size: 4,
            queue_ms: 0.75,
            exec_ms: 12.5,
            shares: vec![5, 11],
            ..Default::default()
        };
        let back = JobResult::parse_line(&r.to_json().to_string()).unwrap();
        assert_eq!(back, r);
        let got = back.field.unwrap();
        for (a, b) in got.iter().zip(&values) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }

    #[test]
    fn failure_reply_is_minimal() {
        let r = JobResult::reject("j9", "queue full (64 jobs)", 125);
        let line = r.to_json().to_string();
        assert!(!line.contains("shares") && !line.contains("mean"), "{line}");
        let back = JobResult::parse_line(&line).unwrap();
        assert!(!back.ok);
        assert_eq!(back.retry_after_ms, Some(125));
        assert_eq!(back.error.as_deref(), Some("queue full (64 jobs)"));
        assert_eq!(back.id, "j9");
    }

    #[test]
    fn batch_key_separates_physics_not_inputs() {
        let a = JobSpec { seed: 1, id: "a".into(), ..Default::default() };
        let b = JobSpec { seed: 9, id: "b".into(), return_field: true, ..Default::default() };
        assert_eq!(a.batch_key(), b.batch_key());
        let c = JobSpec { boundary: Boundary::Neumann, ..Default::default() };
        assert_ne!(a.batch_key(), c.batch_key());
        let d = JobSpec { boundary: Boundary::Dirichlet(25.0), ..Default::default() };
        assert_ne!(a.batch_key(), d.batch_key(), "wall value changes the physics");
    }

    #[test]
    fn materialize_validates_and_builds() {
        let spec = JobSpec { bench: "heat2d".into(), ..Default::default() };
        let f = spec.materialize(&[12, 8]).unwrap();
        assert_eq!(f.shape(), &[12, 8]);
        // same seed, same bits
        assert_eq!(f.data(), spec.materialize(&[12, 8]).unwrap().data());

        let inline = JobSpec {
            shape: Some(vec![2, 2]),
            field: Some(vec![1.0, 2.0, 3.0, 4.0]),
            ..Default::default()
        };
        assert_eq!(inline.materialize(&[12, 8]).unwrap().data(), &[1.0, 2.0, 3.0, 4.0]);

        let bad_dim = JobSpec { shape: Some(vec![8]), ..Default::default() };
        assert!(bad_dim.materialize(&[12, 8]).is_err());
        let bad_len = JobSpec {
            shape: Some(vec![2, 2]),
            field: Some(vec![1.0]),
            ..Default::default()
        };
        assert!(bad_len.materialize(&[12, 8]).is_err());
        let bad_bench = JobSpec { bench: "nope".into(), ..Default::default() };
        assert!(bad_bench.materialize(&[12, 8]).is_err());
    }
}
