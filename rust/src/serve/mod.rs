//! `tetris serve` — the long-lived stencil-serving subsystem (ROADMAP's
//! "Serving layer"): the paper's §5 concurrent scheduler behind a
//! stable submission API, so users drive jobs at a service instead of a
//! supercomputer.
//!
//! * [`job`] — [`JobSpec`]/[`JobResult`] line-protocol wire types with
//!   tolerant, serde-free JSON codecs;
//! * [`queue`] — bounded MPMC admission queue: priority classes, FIFO
//!   within a class, job-count + in-flight-byte backpressure
//!   (reject-with-retry-after, never block the socket);
//! * [`session`] — per-`(bench, boundary-kind, shape)` scheduler
//!   sessions that keep workers alive and cache the converged partition
//!   across jobs, invalidating on retune drift;
//! * [`batcher`] — coalesces queued jobs with identical spec/boundary
//!   into one multi-field dispatch ([`crate::coordinator::Scheduler::run_batch`]),
//!   amortizing pool spawns, ghost bookkeeping and retunes; consults
//!   the [`crate::plan`] store at session creation (adopting the tuned
//!   engine/Tb), writes back observed plans from live runs, and evicts
//!   cold sessions by TTL/LRU;
//! * [`server`] — `std::net` TCP line protocol (JSON job in, JSON
//!   result out, `STATS`, `METRICS`, graceful `SHUTDOWN`);
//! * [`client`] — blocking pipelined client (`tetris submit`);
//! * [`stats`] — counters + log₂ latency histogram behind `STATS`.

pub mod batcher;
pub mod client;
pub mod job;
pub mod queue;
pub mod server;
pub mod session;
pub mod stats;

pub use batcher::{ExecConfig, Executor, SessionMeta, WorkerFactory};
pub use client::{Client, RecvHalf, SendHalf};
pub use job::{JobResult, JobSpec, Priority};
pub use queue::{Admission, AdmissionQueue, QueuedJob};
pub use server::{default_worker_factory, ServeConfig, Server, ServerHandle};
pub use session::Session;
pub use stats::{LatencyHistogram, ServeStats};
