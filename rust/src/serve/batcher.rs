//! The dispatcher side of the serving layer: pop (possibly coalesced)
//! batches off the [`AdmissionQueue`], resolve the session for their
//! shared spec, run them as **one multi-field dispatch** through
//! [`crate::coordinator::Scheduler::run_batch`], and reply per job.
//!
//! Batching amortizes the per-block pool spawn, the ghost-ring
//! bookkeeping and the retune decision across every coalesced job, and
//! the session amortizes worker profiling and partition convergence
//! across the whole job stream — the two levers behind the `serve`
//! bench rung's batched-vs-unbatched gap.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::error::{Context, Result};

use crate::coordinator::Worker;
use crate::stencil::Field;

use super::job::{JobResult, JobSpec};
use super::queue::{AdmissionQueue, QueuedJob};
use super::session::Session;
use super::stats::ServeStats;

/// Builds the worker set for a new session: `(bench, shape, tb)`.
pub type WorkerFactory =
    Arc<dyn Fn(&str, &[usize], usize) -> Result<Vec<Box<dyn Worker>>> + Send + Sync>;

/// Per-session public counters for `STATS` (kept outside the session
/// mutex so a long-running batch never blocks a stats probe).
#[derive(Clone, Debug, Default)]
pub struct SessionMeta {
    pub shares: Vec<usize>,
    pub jobs: u64,
    pub cache_hits: u64,
    pub invalidations: u64,
}

/// Execution policy shared by every dispatcher thread.
pub struct ExecConfig {
    /// Default problem scale: session shapes come from
    /// [`crate::bench::scaled_problem`] unless the job overrides them.
    pub scale: f64,
    /// Engine threads for factory-built native workers.
    pub threads: usize,
    /// In-run §5.2 retune cadence for session schedulers.
    pub adapt_every: usize,
    /// Session partition-cache invalidation threshold (L1 share drift
    /// over total units).
    pub drift_threshold: f64,
}

pub struct Executor {
    pub queue: Arc<AdmissionQueue>,
    pub stats: Arc<Mutex<ServeStats>>,
    cfg: ExecConfig,
    factory: WorkerFactory,
    sessions: Mutex<HashMap<String, Arc<Mutex<Session>>>>,
    meta: Mutex<HashMap<String, SessionMeta>>,
}

impl Executor {
    pub fn new(
        queue: Arc<AdmissionQueue>,
        stats: Arc<Mutex<ServeStats>>,
        cfg: ExecConfig,
        factory: WorkerFactory,
    ) -> Executor {
        Executor {
            queue,
            stats,
            cfg,
            factory,
            sessions: Mutex::new(HashMap::new()),
            meta: Mutex::new(HashMap::new()),
        }
    }

    /// Dispatcher thread body: drain batches until the queue closes and
    /// empties.  Every popped job receives exactly one reply line.
    pub fn dispatch_loop(&self, max_batch: usize) {
        while let Some(batch) = self.queue.pop_batch(max_batch) {
            self.run_jobs(batch);
        }
    }

    /// Session key + default shape for a spec.
    fn plan(&self, spec: &JobSpec) -> Result<(String, Vec<usize>, usize)> {
        crate::stencil::spec::get(&spec.bench)
            .with_context(|| format!("unknown bench {:?}", spec.bench))?;
        let (default_shape, _, tb) = crate::bench::scaled_problem(&spec.bench, self.cfg.scale);
        let shape = spec.shape.clone().unwrap_or(default_shape);
        let key = format!("{}/{}/{:?}", spec.bench, spec.boundary.kind(), shape);
        Ok((key, shape, tb))
    }

    fn session_for(&self, spec: &JobSpec) -> Result<(String, Arc<Mutex<Session>>)> {
        let (key, shape, tb) = self.plan(spec)?;
        if let Some(s) = self.sessions.lock().unwrap().get(&key) {
            return Ok((key, s.clone()));
        }
        // Build workers + profile OUTSIDE the map lock: session creation
        // takes real timed slab runs, and other dispatchers must keep
        // resolving existing sessions meanwhile.  A racing creator for
        // the same key wastes one profile; first insert wins.
        let workers = (self.factory)(&spec.bench, &shape, tb)?;
        let session = Arc::new(Mutex::new(Session::new(
            &spec.bench,
            shape,
            tb,
            workers,
            self.cfg.adapt_every,
            self.cfg.drift_threshold,
        )?));
        let mut sessions = self.sessions.lock().unwrap();
        let entry = sessions.entry(key.clone()).or_insert(session);
        Ok((key, entry.clone()))
    }

    /// Snapshot of per-session counters (for `STATS`).
    pub fn session_meta(&self) -> Vec<(String, SessionMeta)> {
        let meta = self.meta.lock().unwrap();
        let mut out: Vec<(String, SessionMeta)> =
            meta.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Run one coalesced batch end-to-end and reply to every job.
    /// Errors never escape: they become structured per-job replies.
    pub fn run_jobs(&self, batch: Vec<QueuedJob>) {
        let released: usize = batch.iter().map(|j| j.cost_bytes).sum();
        let outcome = self.try_run(&batch);
        match outcome {
            Ok(results) => {
                let mut stats = self.stats.lock().unwrap();
                stats.completed += batch.len() as u64;
                stats.batches += 1;
                if batch.len() > 1 {
                    stats.batched_jobs += batch.len() as u64;
                }
                for (job, result) in batch.iter().zip(results) {
                    stats.record_latency(job.admitted_at.elapsed());
                    let _ = job.reply.send(result.to_json().to_string());
                }
            }
            Err(e) => {
                self.stats.lock().unwrap().errors += batch.len() as u64;
                for job in &batch {
                    let reply = JobResult::failure(&job.spec.id, format!("{e}"));
                    let _ = job.reply.send(reply.to_json().to_string());
                }
            }
        }
        self.queue.release(released);
    }

    fn try_run(&self, batch: &[QueuedJob]) -> Result<Vec<JobResult>> {
        let spec0 = &batch[0].spec;
        let (key, session) = self.session_for(spec0)?;
        let mut sess = session.lock().unwrap();
        let steps = sess.align_steps(spec0.steps);
        let inputs: Vec<Field> = batch.iter().map(|j| j.input.clone()).collect();
        let t0 = Instant::now();
        let (outs, _metrics) = sess.run_batch(spec0.boundary, &inputs, steps)?;
        let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
        let shares = sess.shares();
        {
            let mut meta = self.meta.lock().unwrap();
            let m = meta.entry(key).or_default();
            m.shares = shares.clone();
            m.jobs = sess.jobs_run;
            m.cache_hits = sess.cache_hits;
            m.invalidations = sess.invalidations;
        }
        drop(sess);
        Ok(batch
            .iter()
            .zip(outs)
            .map(|(job, out)| JobResult {
                id: job.spec.id.clone(),
                ok: true,
                error: None,
                retry_after_ms: None,
                bench: job.spec.bench.clone(),
                boundary: job.spec.boundary.to_string(),
                priority: job.spec.priority.to_string(),
                steps,
                shape: out.shape().to_vec(),
                mean: out.mean(),
                l2: out.l2(),
                field: if job.spec.return_field { Some(out.into_vec()) } else { None },
                admit_seq: job.admit_seq,
                start_seq: job.start_seq,
                batch_size: batch.len(),
                queue_ms: (t0 - job.admitted_at).as_secs_f64() * 1e3,
                exec_ms,
                shares: shares.clone(),
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NativeWorker;
    use crate::serve::job::Priority;
    use crate::stencil::Boundary;
    use std::sync::mpsc;

    fn native_factory() -> WorkerFactory {
        Arc::new(|_bench, _shape, _tb| {
            Ok(vec![
                Box::new(NativeWorker::new(crate::engine::by_name("simd", 1).unwrap(), 1 << 30))
                    as Box<dyn Worker>,
                Box::new(NativeWorker::new(crate::engine::by_name("simd", 1).unwrap(), 1 << 30)),
            ])
        })
    }

    fn executor() -> Executor {
        Executor::new(
            Arc::new(AdmissionQueue::new(64, 1 << 30)),
            Arc::new(Mutex::new(ServeStats::new())),
            ExecConfig { scale: 0.05, threads: 1, adapt_every: 0, drift_threshold: 0.25 },
            native_factory(),
        )
    }

    fn queued(spec: JobSpec, seq: u64) -> (QueuedJob, mpsc::Receiver<String>) {
        let input = spec
            .materialize(&crate::bench::scaled_problem(&spec.bench, 0.05).0)
            .unwrap();
        let (tx, rx) = mpsc::channel();
        (
            QueuedJob {
                cost_bytes: 3 * input.len() * 8,
                spec,
                input,
                admit_seq: seq,
                start_seq: seq, // the real queue assigns this at pop
                admitted_at: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    #[test]
    fn batch_replies_to_every_job_in_order() {
        let exec = executor();
        let specs: Vec<JobSpec> = (0..3)
            .map(|i| JobSpec {
                id: format!("j{i}"),
                bench: "heat1d".into(),
                shape: Some(vec![24]),
                steps: 8,
                seed: 90 + i,
                priority: Priority::Normal,
                ..Default::default()
            })
            .collect();
        let (jobs, rxs): (Vec<_>, Vec<_>) =
            specs.into_iter().enumerate().map(|(i, s)| queued(s, i as u64)).unzip();
        exec.run_jobs(jobs);
        for (i, rx) in rxs.iter().enumerate() {
            let r = JobResult::parse_line(&rx.recv().unwrap()).unwrap();
            assert!(r.ok, "{r:?}");
            assert_eq!(r.id, format!("j{i}"));
            assert_eq!(r.batch_size, 3);
            assert_eq!(r.start_seq, i as u64);
            assert_eq!(r.steps, 8);
        }
        let stats = exec.stats.lock().unwrap();
        assert_eq!(stats.completed, 3);
        assert_eq!(stats.batches, 1);
        assert_eq!(stats.batched_jobs, 3);
        assert_eq!(stats.latency_count(), 3);
    }

    #[test]
    fn bad_bench_becomes_structured_error_reply() {
        let exec = executor();
        let (mut job, rx) = queued(
            JobSpec {
                id: "bad".into(),
                bench: "heat1d".into(),
                shape: Some(vec![24]),
                ..Default::default()
            },
            0,
        );
        job.spec.bench = "not-a-bench".into();
        exec.run_jobs(vec![job]);
        let r = JobResult::parse_line(&rx.recv().unwrap()).unwrap();
        assert!(!r.ok);
        assert!(r.error.unwrap().contains("not-a-bench"));
        assert_eq!(exec.stats.lock().unwrap().errors, 1);
    }

    #[test]
    fn sessions_are_shared_per_key_and_counted() {
        let exec = executor();
        for seed in 0..2 {
            let (job, rx) = queued(
                JobSpec {
                    id: format!("s{seed}"),
                    bench: "heat1d".into(),
                    shape: Some(vec![24]),
                    seed,
                    ..Default::default()
                },
                seed,
            );
            exec.run_jobs(vec![job]);
            assert!(JobResult::parse_line(&rx.recv().unwrap()).unwrap().ok);
        }
        let meta = exec.session_meta();
        assert_eq!(meta.len(), 1, "same (bench, kind, shape) must share one session");
        assert_eq!(meta[0].1.jobs, 2);
        assert!(meta[0].0.contains("heat1d/dirichlet"));
        // same bench, different boundary kind: a second session
        let (job, rx) = queued(
            JobSpec {
                id: "p".into(),
                bench: "heat1d".into(),
                shape: Some(vec![24]),
                boundary: Boundary::Periodic,
                ..Default::default()
            },
            2,
        );
        exec.run_jobs(vec![job]);
        assert!(JobResult::parse_line(&rx.recv().unwrap()).unwrap().ok);
        assert_eq!(exec.session_meta().len(), 2);
    }

    #[test]
    fn return_field_round_trips_bits() {
        let exec = executor();
        let (job, rx) = queued(
            JobSpec {
                id: "f".into(),
                bench: "heat1d".into(),
                shape: Some(vec![24]),
                steps: 4,
                seed: 7,
                return_field: true,
                ..Default::default()
            },
            0,
        );
        let input = job.input.clone();
        exec.run_jobs(vec![job]);
        let r = JobResult::parse_line(&rx.recv().unwrap()).unwrap();
        let got = r.field.expect("field requested");
        // Direct scheduler run with the same engine and Tb: slab
        // decomposition is bit-invariant for the row-sweep engines, so
        // whatever partition the session profiled, the bits must match.
        let s = crate::stencil::spec::get("heat1d").unwrap();
        let tb = crate::bench::scaled_problem("heat1d", 0.05).2;
        let sched = crate::coordinator::Scheduler {
            spec: s,
            tb,
            workers: vec![Box::new(NativeWorker::new(
                crate::engine::by_name("simd", 1).unwrap(),
                1 << 30,
            ))],
            partition: crate::coordinator::Partition { unit: 24, shares: vec![1] },
            comm_model: crate::coordinator::CommModel::default(),
            boundary: Boundary::Dirichlet(0.0),
            adapt_every: 0,
        };
        let (want, _) = sched.run(&input, r.steps).unwrap();
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(want.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
