//! The dispatcher side of the serving layer: pop (possibly coalesced)
//! batches off the [`AdmissionQueue`], resolve the session for their
//! shared spec, run them as **one multi-field dispatch** through
//! [`crate::coordinator::Scheduler::run_batch`], and reply per job.
//!
//! Batching amortizes the per-block pool spawn, the ghost-ring
//! bookkeeping and the retune decision across every coalesced job, and
//! the session amortizes worker profiling and partition convergence
//! across the whole job stream — the two levers behind the `serve`
//! bench rung's batched-vs-unbatched gap.
//!
//! Two plan-store hooks close the autotuning loop (`--plan-store`):
//! a **new session consults the store** so a fresh server starts from
//! the best known `(engine, Tb, tile)` instead of defaults, and batches
//! **write back observed plans**.  Stored `tuned` gsps figures come
//! from proxy grids (a different basis than full-scale serving), so the
//! write-back trigger compares live-vs-live: an *unplanned* session
//! records its configuration on first observation (future `auto`
//! resolutions reuse it), while a *planned* session's first batch only
//! establishes the live baseline and later batches write back when they
//! beat it by >20% — serve traffic keeps the store honest without ever
//! running a search inline.
//!
//! Cold sessions are evicted by TTL and LRU cap ([`Executor::evict_cold`],
//! swept after every dispatched batch): an evicted session releases its
//! workers and cached partition, and `STATS` counts the evictions.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::error::{Context, Result};

use crate::coordinator::{Overlap, Worker};
use crate::plan::{Fingerprint, Plan, PlanStore};
use crate::stencil::Field;

use super::job::{JobResult, JobSpec};
use super::queue::{AdmissionQueue, QueuedJob};
use super::session::Session;
use super::stats::ServeStats;

/// Builds the worker set for a new session: `(bench, shape, tb, plan)`.
/// A stored plan, when present, names the engine/thread mix the session
/// should start from.
pub type WorkerFactory = Arc<
    dyn Fn(&str, &[usize], usize, Option<&Plan>) -> Result<Vec<Box<dyn Worker>>> + Send + Sync,
>;

/// Per-session public counters for `STATS` (kept outside the session
/// mutex so a long-running batch never blocks a stats probe).
#[derive(Clone, Debug, Default)]
pub struct SessionMeta {
    pub shares: Vec<usize>,
    pub jobs: u64,
    pub cache_hits: u64,
    pub invalidations: u64,
    /// Worker identities ("+"-joined), set at session creation.
    pub engine: String,
    /// Fused steps per block the session runs.
    pub tb: usize,
    /// Whether creation adopted a stored plan (vs defaults).
    pub planned: bool,
    /// §5.3 leader-loop mode the session runs ("on"/"off"/"auto").
    pub overlap: String,
    /// Thread count the session's lead worker runs (plan's figure when
    /// planned, the server default otherwise) — what a write-back must
    /// record, NOT the raw server flag.
    pub threads: usize,
    /// Tile-width override the session runs (from the plan).
    pub tile_w: Option<usize>,
    /// Best *live* GStencils/s observed for this key (0 until the first
    /// batch; stored-plan gsps is proxy-grid basis and never compared).
    pub best_gsps: f64,
}

/// A live session plus its LRU timestamp.
struct SessionEntry {
    session: Arc<Mutex<Session>>,
    last_used: Instant,
}

/// Execution policy shared by every dispatcher thread.
pub struct ExecConfig {
    /// Default problem scale: session shapes come from
    /// [`crate::bench::scaled_problem`] unless the job overrides them.
    pub scale: f64,
    /// Engine threads for factory-built native workers.
    pub threads: usize,
    /// In-run §5.2 retune cadence for session schedulers.
    pub adapt_every: usize,
    /// Session partition-cache invalidation threshold (L1 share drift
    /// over total units).
    pub drift_threshold: f64,
    /// Plan store consulted at session creation and written back from
    /// live runs (`None` = planning disabled).
    pub plan_store: Option<Arc<PlanStore>>,
    /// Machine fingerprint for store keys (`None` = detect lazily on
    /// first use; tests inject one to keep keys predictable).
    pub fingerprint: Option<Fingerprint>,
    /// Evict sessions idle longer than this (`ZERO` = never).
    pub session_ttl: Duration,
    /// LRU cap on live sessions (`0` = unbounded).
    pub max_sessions: usize,
    /// §5.3 leader-loop mode for session schedulers (`--overlap`);
    /// a stored plan's `overlap` field overrides it per session unless
    /// the operator passed the flag explicitly.
    pub overlap: Overlap,
    /// Whether the operator passed `--overlap` explicitly — an explicit
    /// flag beats stored plans, matching `run`/`hetero` semantics.
    pub overlap_explicit: bool,
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig {
            scale: 0.25,
            threads: 2,
            adapt_every: 2,
            drift_threshold: 0.25,
            plan_store: None,
            fingerprint: None,
            session_ttl: Duration::ZERO,
            max_sessions: 0,
            overlap: Overlap::Auto,
            overlap_explicit: false,
        }
    }
}

pub struct Executor {
    pub queue: Arc<AdmissionQueue>,
    pub stats: Arc<Mutex<ServeStats>>,
    cfg: ExecConfig,
    factory: WorkerFactory,
    sessions: Mutex<HashMap<String, SessionEntry>>,
    meta: Mutex<HashMap<String, SessionMeta>>,
    fp: Mutex<Option<Fingerprint>>,
}

impl Executor {
    pub fn new(
        queue: Arc<AdmissionQueue>,
        stats: Arc<Mutex<ServeStats>>,
        cfg: ExecConfig,
        factory: WorkerFactory,
    ) -> Executor {
        Executor {
            queue,
            stats,
            cfg,
            factory,
            sessions: Mutex::new(HashMap::new()),
            meta: Mutex::new(HashMap::new()),
            fp: Mutex::new(None),
        }
    }

    /// Dispatcher thread body: drain batches until the queue closes and
    /// empties.  Every popped job receives exactly one reply line; cold
    /// sessions are swept after each batch.
    pub fn dispatch_loop(&self, max_batch: usize) {
        while let Some(batch) = self.queue.pop_batch(max_batch) {
            self.run_jobs(batch);
        }
    }

    /// Session key + default shape + default Tb for a spec.
    fn session_key(&self, spec: &JobSpec) -> Result<(String, Vec<usize>, usize)> {
        crate::stencil::spec::get(&spec.bench)
            .with_context(|| format!("unknown bench {:?}", spec.bench))?;
        let (default_shape, _, tb) = crate::bench::scaled_problem(&spec.bench, self.cfg.scale);
        let shape = spec.shape.clone().unwrap_or(default_shape);
        let key = format!("{}/{}/{:?}", spec.bench, spec.boundary.kind(), shape);
        Ok((key, shape, tb))
    }

    /// The machine fingerprint for plan keys (configured, else detected
    /// once on first use).
    fn fingerprint(&self) -> Fingerprint {
        let mut g = self.fp.lock().unwrap();
        if g.is_none() {
            *g = Some(
                self.cfg.fingerprint.clone().unwrap_or_else(|| Fingerprint::detect(100)),
            );
        }
        g.clone().unwrap()
    }

    fn session_for(&self, spec: &JobSpec) -> Result<(String, Vec<usize>, Arc<Mutex<Session>>)> {
        let (key, shape, default_tb) = self.session_key(spec)?;
        if let Some(e) = self.sessions.lock().unwrap().get_mut(&key) {
            e.last_used = Instant::now();
            return Ok((key, shape, e.session.clone()));
        }
        // A stored plan decides the session's engine mix and Tb; without
        // one the factory falls back to its defaults.
        let plan = self.cfg.plan_store.as_ref().and_then(|store| {
            store.lookup(&self.fingerprint(), &spec.bench, spec.boundary.kind(), &shape)
        });
        let tb = plan.as_ref().map(|p| p.tb.max(1)).unwrap_or(default_tb);
        // A plan that searched the overlap knob decides the session's
        // leader-loop mode; otherwise (or when the operator forced a
        // mode with an explicit --overlap) the server flag does.
        let overlap = match plan.as_ref().and_then(|p| p.overlap) {
            Some(o) if !self.cfg.overlap_explicit => {
                if o {
                    Overlap::On
                } else {
                    Overlap::Off
                }
            }
            _ => self.cfg.overlap,
        };
        // Build workers + profile OUTSIDE the map lock: session creation
        // takes real timed slab runs, and other dispatchers must keep
        // resolving existing sessions meanwhile.  A racing creator for
        // the same key wastes one profile; first insert wins.
        let workers = (self.factory)(&spec.bench, &shape, tb, plan.as_ref())?;
        let built = Session::new(
            &spec.bench,
            shape.clone(),
            tb,
            workers,
            self.cfg.adapt_every,
            self.cfg.drift_threshold,
            overlap,
        )?;
        {
            let mut meta = self.meta.lock().unwrap();
            let m = meta.entry(key.clone()).or_default();
            m.engine = built.worker_names().join("+");
            m.tb = tb;
            m.planned = plan.is_some();
            m.overlap = overlap.to_string();
            m.threads =
                plan.as_ref().map(|p| p.threads.max(1)).unwrap_or(self.cfg.threads.max(1));
            m.tile_w = plan.as_ref().and_then(|p| p.tile_w);
            m.best_gsps = 0.0;
        }
        let session = Arc::new(Mutex::new(built));
        let mut sessions = self.sessions.lock().unwrap();
        let entry = sessions
            .entry(key.clone())
            .or_insert_with(|| SessionEntry { session, last_used: Instant::now() });
        Ok((key, shape, entry.session.clone()))
    }

    /// Snapshot of per-session counters (for `STATS`).
    pub fn session_meta(&self) -> Vec<(String, SessionMeta)> {
        let meta = self.meta.lock().unwrap();
        let mut out: Vec<(String, SessionMeta)> =
            meta.iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Live sessions (post-eviction).
    pub fn session_count(&self) -> usize {
        self.sessions.lock().unwrap().len()
    }

    /// TTL + LRU sweep: drop sessions idle past `session_ttl`, then trim
    /// past `max_sessions` oldest-first.  Dropping an entry releases the
    /// session's workers and cached partition (a batch already running
    /// on it finishes through its own `Arc`).  Returns evicted count.
    pub fn evict_cold(&self) -> usize {
        let mut evicted: Vec<String> = Vec::new();
        {
            let mut sessions = self.sessions.lock().unwrap();
            if self.cfg.session_ttl > Duration::ZERO {
                let now = Instant::now();
                let cold: Vec<String> = sessions
                    .iter()
                    .filter(|(_, e)| now.duration_since(e.last_used) > self.cfg.session_ttl)
                    .map(|(k, _)| k.clone())
                    .collect();
                for k in cold {
                    sessions.remove(&k);
                    evicted.push(k);
                }
            }
            if self.cfg.max_sessions > 0 && sessions.len() > self.cfg.max_sessions {
                let mut by_age: Vec<(String, Instant)> =
                    sessions.iter().map(|(k, e)| (k.clone(), e.last_used)).collect();
                by_age.sort_by_key(|(_, t)| *t);
                let excess = sessions.len() - self.cfg.max_sessions;
                for (k, _) in by_age.into_iter().take(excess) {
                    sessions.remove(&k);
                    evicted.push(k);
                }
            }
        }
        if !evicted.is_empty() {
            // Evicted keys drop their STATS row too: cumulative history
            // for a cold key is exactly what the sweep exists to shed.
            let mut meta = self.meta.lock().unwrap();
            for k in &evicted {
                meta.remove(k);
            }
            self.stats.lock().unwrap().evictions += evicted.len() as u64;
        }
        evicted.len()
    }

    /// Run one coalesced batch end-to-end and reply to every job.
    /// Errors never escape: they become structured per-job replies.
    pub fn run_jobs(&self, batch: Vec<QueuedJob>) {
        let released: usize = batch.iter().map(|j| j.cost_bytes).sum();
        let span = if crate::trace::enabled() {
            crate::trace::instant(
                "serve",
                "batch",
                &[
                    ("jobs", batch.len().into()),
                    ("job", batch[0].spec.id.as_str().into()),
                    ("bytes", released.into()),
                ],
            );
            crate::trace::span(
                "serve",
                "run",
                &[("job", batch[0].spec.id.as_str().into()), ("jobs", batch.len().into())],
            )
        } else {
            crate::trace::Span::off()
        };
        let outcome = self.try_run(&batch);
        drop(span);
        match outcome {
            Ok(results) => {
                let mut stats = self.stats.lock().unwrap();
                stats.completed += batch.len() as u64;
                stats.batches += 1;
                if batch.len() > 1 {
                    stats.batched_jobs += batch.len() as u64;
                }
                for (job, result) in batch.iter().zip(results) {
                    stats.record_latency(job.admitted_at.elapsed());
                    // instant BEFORE the send: once a client observes the
                    // reply line, the trace event is already recorded
                    if crate::trace::enabled() {
                        crate::trace::instant(
                            "serve",
                            "reply",
                            &[("job", job.spec.id.as_str().into()), ("ok", 1u64.into())],
                        );
                        crate::trace::flow_finish(
                            "serve",
                            "job",
                            crate::trace::flow_id(&job.spec.id),
                            &[],
                        );
                    }
                    let _ = job.reply.send(result.to_json().to_string());
                }
            }
            Err(e) => {
                self.stats.lock().unwrap().errors += batch.len() as u64;
                for job in &batch {
                    let reply = JobResult::failure(&job.spec.id, format!("{e}"));
                    if crate::trace::enabled() {
                        crate::trace::instant(
                            "serve",
                            "reply",
                            &[("job", job.spec.id.as_str().into()), ("ok", 0u64.into())],
                        );
                        crate::trace::flow_finish(
                            "serve",
                            "job",
                            crate::trace::flow_id(&job.spec.id),
                            &[],
                        );
                    }
                    let _ = job.reply.send(reply.to_json().to_string());
                }
            }
        }
        self.queue.release(released);
        self.evict_cold();
    }

    fn try_run(&self, batch: &[QueuedJob]) -> Result<Vec<JobResult>> {
        let spec0 = &batch[0].spec;
        let (key, shape, session) = self.session_for(spec0)?;
        let mut sess = session.lock().unwrap();
        let steps = sess.align_steps(spec0.steps);
        let tb = sess.tb();
        let inputs: Vec<Field> = batch.iter().map(|j| j.input.clone()).collect();
        let t0 = Instant::now();
        let (outs, metrics) = sess.run_batch(spec0.boundary, &inputs, steps)?;
        let exec_ms = t0.elapsed().as_secs_f64() * 1e3;
        let shares = sess.shares();
        let gsps = metrics.gstencils_per_sec();
        if metrics.overlap {
            self.stats.lock().unwrap().overlap_hidden_ms +=
                metrics.overlap_hidden.as_secs_f64() * 1e3;
        }
        let write_back = {
            let mut meta = self.meta.lock().unwrap();
            match meta.get_mut(&key) {
                Some(m) => {
                    m.shares = shares.clone();
                    m.jobs = sess.jobs_run;
                    m.cache_hits = sess.cache_hits;
                    m.invalidations = sess.invalidations;
                    let first = m.best_gsps == 0.0;
                    let improved =
                        gsps.is_finite() && gsps > 0.0 && gsps > m.best_gsps * 1.2;
                    if improved {
                        m.best_gsps = gsps;
                    }
                    // A planned session's first batch only establishes
                    // the live baseline (the stored gsps is proxy-grid
                    // basis, not comparable); unplanned sessions record
                    // their configuration immediately.
                    let write =
                        self.cfg.plan_store.is_some() && improved && !(m.planned && first);
                    write.then(|| (m.engine.clone(), m.threads, m.tile_w))
                }
                // Evicted mid-batch by another dispatcher: the row is
                // gone on purpose — don't resurrect a ghost entry.
                None => None,
            }
        };
        drop(sess);
        if let Some((engine_label, threads, tile_w)) = write_back {
            self.write_back_observed(spec0, &shape, &engine_label, threads, tb, tile_w, gsps);
        }
        Ok(batch
            .iter()
            .zip(outs)
            .map(|(job, out)| JobResult {
                id: job.spec.id.clone(),
                ok: true,
                error: None,
                retry_after_ms: None,
                bench: job.spec.bench.clone(),
                boundary: job.spec.boundary.to_string(),
                priority: job.spec.priority.to_string(),
                steps,
                shape: out.shape().to_vec(),
                mean: out.mean(),
                l2: out.l2(),
                field: if job.spec.return_field { Some(out.into_vec()) } else { None },
                admit_seq: job.admit_seq,
                start_seq: job.start_seq,
                batch_size: batch.len(),
                queue_ms: (t0 - job.admitted_at).as_secs_f64() * 1e3,
                exec_ms,
                shares: shares.clone(),
            })
            .collect())
    }

    /// Record what a live session measured as an `observed` plan,
    /// carrying the configuration the session *actually ran* (plan
    /// threads/tile when planned, factory defaults otherwise) — but
    /// only when the lead worker's engine is a name the store can
    /// resolve again (artifact workers are machine-local, not plans).
    fn write_back_observed(
        &self,
        spec: &JobSpec,
        shape: &[usize],
        engine_label: &str,
        threads: usize,
        tb: usize,
        tile_w: Option<usize>,
        gsps: f64,
    ) {
        let Some(store) = &self.cfg.plan_store else { return };
        let Some(bare) = engine_label
            .split('+')
            .next()
            .and_then(|n| n.strip_prefix("native:"))
        else {
            return;
        };
        if crate::plan::resolve_engine(bare, 1).is_none() {
            return;
        }
        let fp = self.fingerprint();
        let plan = Plan {
            version: crate::plan::PLAN_VERSION,
            fingerprint: fp.id(),
            bench: spec.bench.clone(),
            boundary: spec.boundary.kind().to_string(),
            bucket: crate::plan::shape_bucket(shape),
            engine: bare.to_string(),
            threads: threads.max(1),
            tb,
            tile_w,
            // observed plans record throughput, not a leader-loop
            // preference — the tuner's probe owns that knob
            overlap: None,
            grid: None,
            gsps,
            source: "observed".to_string(),
            seed: 0,
        };
        if let Err(e) = store.append(&plan) {
            eprintln!("tetris serve: plan write-back failed: {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NativeWorker;
    use crate::serve::job::Priority;
    use crate::stencil::Boundary;
    use std::sync::mpsc;

    fn native_factory() -> WorkerFactory {
        Arc::new(|_bench, _shape, _tb, _plan| {
            Ok(vec![
                Box::new(NativeWorker::new(crate::engine::by_name("simd", 1).unwrap(), 1 << 30))
                    as Box<dyn Worker>,
                Box::new(NativeWorker::new(crate::engine::by_name("simd", 1).unwrap(), 1 << 30)),
            ])
        })
    }

    fn executor() -> Executor {
        executor_with(ExecConfig {
            scale: 0.05,
            threads: 1,
            adapt_every: 0,
            drift_threshold: 0.25,
            ..Default::default()
        })
    }

    fn executor_with(cfg: ExecConfig) -> Executor {
        Executor::new(
            Arc::new(AdmissionQueue::new(64, 1 << 30)),
            Arc::new(Mutex::new(ServeStats::new())),
            cfg,
            native_factory(),
        )
    }

    fn queued(spec: JobSpec, seq: u64) -> (QueuedJob, mpsc::Receiver<String>) {
        let input = spec
            .materialize(&crate::bench::scaled_problem(&spec.bench, 0.05).0)
            .unwrap();
        let (tx, rx) = mpsc::channel();
        (
            QueuedJob {
                cost_bytes: 3 * input.len() * 8,
                spec,
                input,
                admit_seq: seq,
                start_seq: seq, // the real queue assigns this at pop
                admitted_at: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    fn heat1d_job(id: &str, seed: u64, seq: u64) -> (QueuedJob, mpsc::Receiver<String>) {
        queued(
            JobSpec {
                id: id.into(),
                bench: "heat1d".into(),
                shape: Some(vec![24]),
                steps: 8,
                seed,
                priority: Priority::Normal,
                ..Default::default()
            },
            seq,
        )
    }

    #[test]
    fn batch_replies_to_every_job_in_order() {
        let exec = executor();
        let (jobs, rxs): (Vec<_>, Vec<_>) =
            (0..3).map(|i| heat1d_job(&format!("j{i}"), 90 + i, i)).unzip();
        exec.run_jobs(jobs);
        for (i, rx) in rxs.iter().enumerate() {
            let r = JobResult::parse_line(&rx.recv().unwrap()).unwrap();
            assert!(r.ok, "{r:?}");
            assert_eq!(r.id, format!("j{i}"));
            assert_eq!(r.batch_size, 3);
            assert_eq!(r.start_seq, i as u64);
            assert_eq!(r.steps, 8);
        }
        let stats = exec.stats.lock().unwrap();
        assert_eq!(stats.completed, 3);
        assert_eq!(stats.batches, 1);
        assert_eq!(stats.batched_jobs, 3);
        assert_eq!(stats.latency_count(), 3);
    }

    #[test]
    fn bad_bench_becomes_structured_error_reply() {
        let exec = executor();
        let (mut job, rx) = heat1d_job("bad", 1, 0);
        job.spec.bench = "not-a-bench".into();
        exec.run_jobs(vec![job]);
        let r = JobResult::parse_line(&rx.recv().unwrap()).unwrap();
        assert!(!r.ok);
        assert!(r.error.unwrap().contains("not-a-bench"));
        assert_eq!(exec.stats.lock().unwrap().errors, 1);
    }

    #[test]
    fn sessions_are_shared_per_key_and_counted() {
        let exec = executor();
        for seed in 0..2 {
            let (job, rx) = heat1d_job(&format!("s{seed}"), seed, seed);
            exec.run_jobs(vec![job]);
            assert!(JobResult::parse_line(&rx.recv().unwrap()).unwrap().ok);
        }
        let meta = exec.session_meta();
        assert_eq!(meta.len(), 1, "same (bench, kind, shape) must share one session");
        assert_eq!(meta[0].1.jobs, 2);
        assert!(meta[0].0.contains("heat1d/dirichlet"));
        assert!(meta[0].1.engine.contains("simd"));
        assert!(meta[0].1.tb >= 1);
        assert!(!meta[0].1.planned, "no plan store configured");
        assert_eq!(meta[0].1.overlap, "auto", "server default leader-loop mode");
        // same bench, different boundary kind: a second session
        let (mut job, rx) = heat1d_job("p", 3, 2);
        job.spec.boundary = Boundary::Periodic;
        exec.run_jobs(vec![job]);
        assert!(JobResult::parse_line(&rx.recv().unwrap()).unwrap().ok);
        assert_eq!(exec.session_meta().len(), 2);
        assert_eq!(exec.session_count(), 2);
    }

    #[test]
    fn return_field_round_trips_bits() {
        let exec = executor();
        let (mut job, rx) = heat1d_job("f", 7, 0);
        job.spec.steps = 4;
        job.spec.return_field = true;
        let input = job.input.clone();
        exec.run_jobs(vec![job]);
        let r = JobResult::parse_line(&rx.recv().unwrap()).unwrap();
        let got = r.field.expect("field requested");
        // Direct scheduler run with the same engine and Tb: slab
        // decomposition is bit-invariant for the row-sweep engines, so
        // whatever partition the session profiled, the bits must match.
        let s = crate::stencil::spec::get("heat1d").unwrap();
        let tb = crate::bench::scaled_problem("heat1d", 0.05).2;
        let sched = crate::coordinator::Scheduler {
            spec: s,
            tb,
            workers: vec![Box::new(NativeWorker::new(
                crate::engine::by_name("simd", 1).unwrap(),
                1 << 30,
            ))],
            partition: crate::coordinator::Partition::rows(24, vec![1]),
            comm_model: crate::coordinator::CommModel::default(),
            boundary: Boundary::Dirichlet(0.0),
            adapt_every: 0,
            // serial reference vs the session's auto mode: overlap must
            // be bit-invisible end-to-end
            overlap: Overlap::Off,
        };
        let (want, _) = sched.run(&input, r.steps).unwrap();
        assert_eq!(got.len(), want.len());
        for (a, b) in got.iter().zip(want.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn ttl_sweep_evicts_cold_sessions_and_counts() {
        let exec = executor_with(ExecConfig {
            scale: 0.05,
            threads: 1,
            adapt_every: 0,
            session_ttl: Duration::from_millis(150),
            ..Default::default()
        });
        let (job, rx) = heat1d_job("warm", 1, 0);
        exec.run_jobs(vec![job]);
        assert!(JobResult::parse_line(&rx.recv().unwrap()).unwrap().ok);
        assert_eq!(exec.session_count(), 1);
        std::thread::sleep(Duration::from_millis(400));
        assert_eq!(exec.evict_cold(), 1, "idle session past the TTL must go");
        assert_eq!(exec.session_count(), 0);
        assert_eq!(exec.session_meta().len(), 0, "STATS row released with the session");
        assert_eq!(exec.stats.lock().unwrap().evictions, 1);
        // the key simply recreates on the next job
        let (job, rx) = heat1d_job("back", 2, 1);
        exec.run_jobs(vec![job]);
        assert!(JobResult::parse_line(&rx.recv().unwrap()).unwrap().ok);
        assert_eq!(exec.session_count(), 1);
        assert_eq!(exec.session_meta()[0].1.jobs, 1, "fresh session, fresh counters");
    }

    #[test]
    fn lru_cap_trims_oldest_session_after_dispatch() {
        let exec = executor_with(ExecConfig {
            scale: 0.05,
            threads: 1,
            adapt_every: 0,
            max_sessions: 1,
            ..Default::default()
        });
        let (job, rx) = heat1d_job("a", 1, 0);
        exec.run_jobs(vec![job]);
        assert!(JobResult::parse_line(&rx.recv().unwrap()).unwrap().ok);
        let (mut job, rx) = heat1d_job("b", 2, 1);
        job.spec.boundary = Boundary::Periodic; // second key
        exec.run_jobs(vec![job]);
        assert!(JobResult::parse_line(&rx.recv().unwrap()).unwrap().ok);
        // run_jobs sweeps after the batch: only the newest key survives
        assert_eq!(exec.session_count(), 1);
        assert!(exec.stats.lock().unwrap().evictions >= 1);
        assert!(exec.session_meta()[0].0.contains("periodic"), "LRU keeps the fresh key");
    }

    /// The observed record must carry the configuration the session
    /// actually ran — plan threads and tile override, not the raw
    /// server flags — and artifact-led sessions must never write plans.
    #[test]
    fn write_back_records_actual_session_config() {
        let path = std::env::temp_dir()
            .join(format!("tetris-writeback-cfg-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let store = Arc::new(PlanStore::open(&path));
        let exec = executor_with(ExecConfig {
            threads: 1, // server flag differs from the session's 4 below
            plan_store: Some(store.clone()),
            fingerprint: Some(Fingerprint::synthetic(2, 64, 1.0)),
            ..Default::default()
        });
        let spec = JobSpec { bench: "heat2d".into(), ..Default::default() };
        exec.write_back_observed(
            &spec,
            &[64, 64],
            "native:tetris-cpu+native:tetris-cpu",
            4,
            4,
            Some(64),
            1.5,
        );
        let plans = store.load();
        assert_eq!(plans.len(), 1);
        let p = &plans[0];
        assert_eq!(p.engine, "tetris-cpu");
        assert_eq!(p.threads, 4, "must record the session's threads, not the server flag");
        assert_eq!(p.tile_w, Some(64), "tile override must survive the write-back");
        assert_eq!(p.tb, 4);
        assert_eq!(p.source, "observed");
        exec.write_back_observed(&spec, &[64, 64], "xla:heat2d_block+native:simd", 2, 4, None, 9.9);
        assert_eq!(store.load().len(), 1, "artifact-led sessions are machine-local, not plans");
        let _ = std::fs::remove_file(&path);
    }

    /// An explicit `--overlap` beats a stored plan's searched
    /// preference (matching run/hetero); without the explicit flag the
    /// plan's preference wins.
    #[test]
    fn explicit_overlap_flag_beats_stored_plan_preference() {
        let path = std::env::temp_dir()
            .join(format!("tetris-overlap-flag-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let store = Arc::new(PlanStore::open(&path));
        let fp = Fingerprint::synthetic(2, 64, 1.0);
        store
            .append(&Plan {
                version: crate::plan::PLAN_VERSION,
                fingerprint: fp.id(),
                bench: "heat1d".into(),
                boundary: "dirichlet".into(),
                bucket: crate::plan::shape_bucket(&[24]),
                engine: "simd".into(),
                threads: 1,
                tb: 4,
                tile_w: None,
                overlap: Some(true),
                grid: None,
                gsps: 1.0,
                source: "tuned".into(),
                seed: 0,
            })
            .unwrap();
        let run = |overlap: Overlap, explicit: bool| {
            let exec = executor_with(ExecConfig {
                scale: 0.05,
                threads: 1,
                adapt_every: 0,
                plan_store: Some(store.clone()),
                fingerprint: Some(fp.clone()),
                overlap,
                overlap_explicit: explicit,
                ..Default::default()
            });
            let (job, rx) = heat1d_job("o", 1, 0);
            exec.run_jobs(vec![job]);
            assert!(JobResult::parse_line(&rx.recv().unwrap()).unwrap().ok);
            exec.session_meta()[0].1.overlap.clone()
        };
        assert_eq!(run(Overlap::Auto, false), "on", "plan preference adopted by default");
        assert_eq!(run(Overlap::Off, true), "off", "explicit operator flag must win");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn unplanned_session_writes_back_an_observed_plan() {
        let path = std::env::temp_dir()
            .join(format!("tetris-writeback-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let store = Arc::new(PlanStore::open(&path));
        let exec = executor_with(ExecConfig {
            scale: 0.05,
            threads: 1,
            adapt_every: 0,
            plan_store: Some(store.clone()),
            fingerprint: Some(Fingerprint::synthetic(2, 64, 1.0)),
            ..Default::default()
        });
        let (job, rx) = heat1d_job("w", 1, 0);
        exec.run_jobs(vec![job]);
        assert!(JobResult::parse_line(&rx.recv().unwrap()).unwrap().ok);
        let plans = store.load();
        assert!(
            plans.iter().any(|p| p.source == "observed"
                && p.bench == "heat1d"
                && p.engine == "simd"
                && p.gsps > 0.0),
            "live run must record an observed plan: {plans:?}"
        );
        let _ = std::fs::remove_file(&path);
    }
}
