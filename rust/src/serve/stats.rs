//! Serving counters + a log₂-bucketed latency histogram (`STATS` line).
//!
//! Latencies land in power-of-two microsecond buckets (bucket *i* holds
//! `[2^i, 2^{i+1})` µs), so percentiles are exact to a factor of two
//! over nine decades with a fixed 40-slot table — no allocation, no
//! sorting, O(1) record on the completion path.
//!
//! The histogram itself is the standalone [`LatencyHistogram`] so the
//! load harness ([`crate::load`]) records client-side queue/service/total
//! latencies through the *same* bucketing and percentile code the server
//! reports from — a suite report and a `STATS` line can never disagree
//! about what "p99.9" means.

use std::collections::BTreeMap;
use std::time::Duration;

use crate::util::json::Json;

const BUCKETS: usize = 40;

/// Log₂-bucketed latency histogram: O(1) record, allocation-free,
/// percentiles exact to a factor of √2 (geometric-midpoint estimate).
///
/// Shared by the server's [`ServeStats`] and the load harness recorder;
/// `merge` folds per-connection histograms into one report.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: [u64; BUCKETS],
    count: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: [0; BUCKETS], count: 0 }
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram::default()
    }

    pub fn record(&mut self, d: Duration) {
        let us = (d.as_micros() as u64).max(1);
        let b = (us.ilog2() as usize).min(BUCKETS - 1);
        self.buckets[b] += 1;
        self.count += 1;
    }

    /// Record a latency expressed in milliseconds (as wire reports are).
    pub fn record_ms(&mut self, ms: f64) {
        self.record(Duration::from_secs_f64((ms.max(0.0)) / 1e3));
    }

    /// Fold another histogram into this one (bucket-wise sum).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Geometric midpoint (ms) of the bucket holding the p-quantile
    /// (`0<p<=1`); 0 when nothing has been recorded.
    ///
    /// Bucket `i` holds `[2^i, 2^{i+1})` µs; reporting its *upper*
    /// bound (as this used to) biased every percentile up by ~2x — a
    /// uniform 1024 µs workload read as p50 = 2.048 ms.  The geometric
    /// midpoint `2^i · √2` is the unbiased point estimate for a
    /// log-uniform bucket: the same workload now reads ~1.448 ms, and
    /// any true latency is within a factor √2 of the report.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let midpoint_ms = |i: usize| (1u64 << i) as f64 * std::f64::consts::SQRT_2 / 1_000.0;
        let target = ((p.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            cum += n;
            if cum >= target {
                return midpoint_ms(i);
            }
        }
        midpoint_ms(BUCKETS - 1)
    }

    /// The standard percentile block (`count`, p50/p90/p99/p99.9 ms) —
    /// one shape everywhere, so `bench check` can assert monotonicity on
    /// any report that embeds a histogram.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("count".into(), Json::Num(self.count as f64));
        m.insert("p50_ms".into(), Json::Num(self.percentile_ms(0.50)));
        m.insert("p90_ms".into(), Json::Num(self.percentile_ms(0.90)));
        m.insert("p99_ms".into(), Json::Num(self.percentile_ms(0.99)));
        m.insert("p999_ms".into(), Json::Num(self.percentile_ms(0.999)));
        Json::Obj(m)
    }
}

/// Counters + end-to-end (admission -> reply) latency histogram.
///
/// `Clone` is load-bearing: the `STATS`/`METRICS` verbs snapshot the
/// shared `Mutex<ServeStats>` with one clone and format the reply
/// *after* releasing the lock, so a slow stats consumer can never stall
/// the dispatcher's completion path.
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    /// Malformed / invalid request lines answered with structured errors.
    pub errors: u64,
    /// Multi-field dispatches executed (a batch of 1 still counts).
    pub batches: u64,
    /// Jobs that rode a batch of width >= 2.
    pub batched_jobs: u64,
    /// Sessions dropped by the TTL/LRU sweep.
    pub evictions: u64,
    /// Leader-phase milliseconds hidden under compute by the §5.3
    /// pipelined scheduler loop, summed over every dispatched batch.
    pub overlap_hidden_ms: f64,
    hist: LatencyHistogram,
}

impl ServeStats {
    pub fn new() -> ServeStats {
        ServeStats::default()
    }

    pub fn record_latency(&mut self, d: Duration) {
        self.hist.record(d);
    }

    /// See [`LatencyHistogram::percentile_ms`].
    pub fn percentile_ms(&self, p: f64) -> f64 {
        self.hist.percentile_ms(p)
    }

    pub fn latency_count(&self) -> u64 {
        self.hist.count()
    }

    /// The end-to-end latency histogram (read-only view for the
    /// [`crate::trace::MetricsRegistry`] feed).
    pub fn histogram(&self) -> &LatencyHistogram {
        &self.hist
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("submitted".into(), Json::Num(self.submitted as f64));
        m.insert("completed".into(), Json::Num(self.completed as f64));
        m.insert("rejected".into(), Json::Num(self.rejected as f64));
        m.insert("errors".into(), Json::Num(self.errors as f64));
        m.insert("batches".into(), Json::Num(self.batches as f64));
        m.insert("batched_jobs".into(), Json::Num(self.batched_jobs as f64));
        m.insert("evictions".into(), Json::Num(self.evictions as f64));
        m.insert("overlap_hidden_ms".into(), Json::Num(self.overlap_hidden_ms));
        m.insert("latency".into(), self.hist.to_json());
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_reports_zero() {
        let s = ServeStats::new();
        assert_eq!(s.percentile_ms(0.5), 0.0);
        assert_eq!(s.latency_count(), 0);
    }

    #[test]
    fn percentiles_bracket_uniform_latencies() {
        let mut s = ServeStats::new();
        for _ in 0..100 {
            s.record_latency(Duration::from_micros(1_500)); // bucket [1024, 2048)
        }
        // geometric midpoint of [1.024, 2.048) ms = 1.024·√2 ≈ 1.448 ms:
        // inside the bucket, and within √2 of the true 1.5 ms
        let p50 = s.percentile_ms(0.50);
        assert!((1.024..2.048).contains(&p50), "{p50}");
        assert!((p50 - 1.024 * std::f64::consts::SQRT_2).abs() < 1e-9, "{p50}");
        assert_eq!(s.percentile_ms(0.99), p50, "single-bucket distribution");
    }

    /// Regression: a uniform power-of-two workload must NOT report the
    /// bucket's upper bound — 1024 µs used to read as p50 = 2.048 ms, a
    /// guaranteed ~2x upward bias.
    #[test]
    fn uniform_pow2_workload_is_not_biased_to_the_bucket_ceiling() {
        let mut s = ServeStats::new();
        for _ in 0..64 {
            s.record_latency(Duration::from_micros(1_024));
        }
        let p50 = s.percentile_ms(0.50);
        assert!(p50 < 2.0, "upper-bound bias is back: {p50}");
        assert!(p50 > 1.024, "midpoint must stay inside the bucket: {p50}");
        assert!((p50 - 1.4482).abs() < 1e-3, "geometric midpoint expected: {p50}");
    }

    #[test]
    fn tail_is_separated_from_the_body() {
        let mut s = ServeStats::new();
        for _ in 0..99 {
            s.record_latency(Duration::from_micros(100));
        }
        s.record_latency(Duration::from_millis(80));
        assert!(s.percentile_ms(0.50) < 1.0);
        assert!(s.percentile_ms(0.995) > 50.0);
    }

    /// p99.9 bracketing: 2000 samples with the 3 slowest at 80 ms put
    /// the p99.9 target (rank 1998) inside the slow bucket while p99
    /// (rank 1980) stays in the fast body — the new tail percentile
    /// separates what p99 averages away.
    #[test]
    fn p999_separates_a_3_in_2000_tail_that_p99_misses() {
        let mut h = LatencyHistogram::new();
        for _ in 0..1997 {
            h.record(Duration::from_micros(200));
        }
        for _ in 0..3 {
            h.record(Duration::from_millis(80));
        }
        assert!(h.percentile_ms(0.99) < 1.0, "p99 stays in the body");
        let p999 = h.percentile_ms(0.999);
        assert!(p999 > 50.0, "p99.9 must land in the 80 ms tail bucket: {p999}");
        assert!(h.percentile_ms(0.999) >= h.percentile_ms(0.99), "monotone");
    }

    #[test]
    fn percentiles_are_monotone_p50_through_p999() {
        let mut h = LatencyHistogram::new();
        // spread over four decades
        for us in [100u64, 1_000, 10_000, 100_000] {
            for _ in 0..250 {
                h.record(Duration::from_micros(us));
            }
        }
        let ps = [0.50, 0.90, 0.99, 0.999];
        let vals: Vec<f64> = ps.iter().map(|&p| h.percentile_ms(p)).collect();
        for w in vals.windows(2) {
            assert!(w[0] <= w[1], "{vals:?}");
        }
    }

    #[test]
    fn merge_is_bucketwise_sum() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for _ in 0..10 {
            a.record(Duration::from_micros(100));
            b.record(Duration::from_millis(50));
        }
        a.merge(&b);
        assert_eq!(a.count(), 20);
        assert!(a.percentile_ms(0.25) < 1.0, "fast half survives the merge");
        assert!(a.percentile_ms(0.99) > 30.0, "slow half survives the merge");
    }

    #[test]
    fn record_ms_matches_record_duration() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(Duration::from_micros(1_500));
        b.record_ms(1.5);
        assert_eq!(a.percentile_ms(0.5), b.percentile_ms(0.5));
        // negative/zero clamps into the first bucket instead of panicking
        b.record_ms(-3.0);
        assert_eq!(b.count(), 2);
    }

    #[test]
    fn extreme_latencies_clamp_into_range() {
        let mut s = ServeStats::new();
        s.record_latency(Duration::ZERO);
        s.record_latency(Duration::from_secs(1 << 30));
        assert_eq!(s.latency_count(), 2);
        assert!(s.percentile_ms(1.0) > 0.0);
    }

    #[test]
    fn json_shape() {
        let mut s = ServeStats::new();
        s.submitted = 5;
        s.completed = 4;
        s.record_latency(Duration::from_millis(3));
        let j = s.to_json();
        assert_eq!(j.at(&["submitted"]).as_usize(), Some(5));
        assert_eq!(j.at(&["latency", "count"]).as_usize(), Some(1));
        assert!(j.at(&["latency", "p99_ms"]).as_f64().unwrap() > 0.0);
        assert!(j.at(&["latency", "p999_ms"]).as_f64().unwrap() > 0.0);
        assert_eq!(j.at(&["overlap_hidden_ms"]).as_f64(), Some(0.0));
    }

    #[test]
    fn histogram_json_carries_the_full_percentile_ladder() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::from_millis(2));
        let j = h.to_json();
        for key in ["count", "p50_ms", "p90_ms", "p99_ms", "p999_ms"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
    }
}
