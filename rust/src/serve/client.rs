//! Minimal blocking client for the serving line protocol — used by
//! `tetris submit`, the examples and the end-to-end tests.
//!
//! Requests may be pipelined: [`Client::send_spec`] any number of jobs,
//! then [`Client::recv_result`] the same number of replies; the server
//! guarantees reply order matches request order per connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};

use crate::util::error::{Context, Result};
use crate::util::json::Json;

use super::job::{JobResult, JobSpec};

pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect<A: ToSocketAddrs + std::fmt::Debug>(addr: A) -> Result<Client> {
        let stream =
            TcpStream::connect(&addr).with_context(|| format!("connecting {addr:?}"))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { reader, writer: stream })
    }

    pub fn send_line(&mut self, line: &str) -> Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        Ok(())
    }

    pub fn recv_line(&mut self) -> Result<String> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        crate::ensure!(n > 0, "server closed the connection");
        Ok(line)
    }

    /// Queue one job (pipelined; pair with [`Client::recv_result`]).
    pub fn send_spec(&mut self, spec: &JobSpec) -> Result<()> {
        self.send_line(&spec.to_json().to_string())
    }

    pub fn recv_result(&mut self) -> Result<JobResult> {
        let line = self.recv_line()?;
        JobResult::parse_line(&line)
    }

    /// Submit one job and wait for its reply.
    pub fn submit(&mut self, spec: &JobSpec) -> Result<JobResult> {
        self.send_spec(spec)?;
        self.recv_result()
    }

    /// Fetch the server's `STATS` line.
    pub fn stats(&mut self) -> Result<Json> {
        self.send_line("STATS")?;
        let line = self.recv_line()?;
        Json::parse(line.trim()).context("stats parse")
    }

    /// Ask the server to drain and exit; returns the ack.
    pub fn shutdown(&mut self) -> Result<Json> {
        self.send_line("SHUTDOWN")?;
        let line = self.recv_line()?;
        Json::parse(line.trim()).context("shutdown ack parse")
    }
}
