//! Minimal blocking client for the serving line protocol — used by
//! `tetris submit`, `tetris load`, the examples and the end-to-end
//! tests.
//!
//! Requests may be pipelined: [`Client::send_spec`] any number of jobs,
//! then [`Client::recv_result`] the same number of replies; the server
//! guarantees reply order matches request order per connection.
//!
//! For open-loop load generation the two directions must run on
//! different threads (the sender paces arrivals while the receiver
//! drains replies), so [`Client::split`] hands out an independent
//! [`SendHalf`] and [`RecvHalf`] over the same connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpStream, ToSocketAddrs};

use crate::util::error::{Context, Result};
use crate::util::json::Json;

use super::job::{JobResult, JobSpec};

/// Write side of a serve connection (safe to move to a sender thread).
pub struct SendHalf {
    writer: TcpStream,
}

/// Read side of a serve connection (safe to move to a receiver thread).
pub struct RecvHalf {
    reader: BufReader<TcpStream>,
}

impl SendHalf {
    pub fn send_line(&mut self, line: &str) -> Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        Ok(())
    }

    /// Queue one job (pipelined; pair with [`RecvHalf::recv_result`]).
    pub fn send_spec(&mut self, spec: &JobSpec) -> Result<()> {
        self.send_line(&spec.to_json().to_string())
    }
}

impl RecvHalf {
    pub fn recv_line(&mut self) -> Result<String> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line)?;
        crate::ensure!(n > 0, "server closed the connection");
        Ok(line)
    }

    pub fn recv_result(&mut self) -> Result<JobResult> {
        let line = self.recv_line()?;
        JobResult::parse_line(&line)
    }
}

pub struct Client {
    send: SendHalf,
    recv: RecvHalf,
}

impl Client {
    pub fn connect<A: ToSocketAddrs + std::fmt::Debug>(addr: A) -> Result<Client> {
        let stream =
            TcpStream::connect(&addr).with_context(|| format!("connecting {addr:?}"))?;
        // One small JSON line per job: Nagle would serialize the whole
        // open-loop pipeline behind delayed ACKs, so turn it off.
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { send: SendHalf { writer: stream }, recv: RecvHalf { reader } })
    }

    /// Split into independently-owned halves so sending and receiving
    /// can proceed concurrently on one pipelined connection.
    pub fn split(self) -> (SendHalf, RecvHalf) {
        (self.send, self.recv)
    }

    pub fn send_line(&mut self, line: &str) -> Result<()> {
        self.send.send_line(line)
    }

    pub fn recv_line(&mut self) -> Result<String> {
        self.recv.recv_line()
    }

    /// Queue one job (pipelined; pair with [`Client::recv_result`]).
    pub fn send_spec(&mut self, spec: &JobSpec) -> Result<()> {
        self.send.send_spec(spec)
    }

    pub fn recv_result(&mut self) -> Result<JobResult> {
        self.recv.recv_result()
    }

    /// Submit one job and wait for its reply.
    pub fn submit(&mut self, spec: &JobSpec) -> Result<JobResult> {
        self.send_spec(spec)?;
        self.recv_result()
    }

    /// Fetch the server's `STATS` line.
    pub fn stats(&mut self) -> Result<Json> {
        self.send_line("STATS")?;
        let line = self.recv_line()?;
        Json::parse(line.trim()).context("stats parse")
    }

    /// Fetch the server's `METRICS` line: a flat map of stable metric
    /// names to numbers (see `trace::metrics` for the naming policy).
    pub fn metrics(&mut self) -> Result<Json> {
        self.send_line("METRICS")?;
        let line = self.recv_line()?;
        Json::parse(line.trim()).context("metrics parse")
    }

    /// Ask the server to drain and exit; returns the ack.
    pub fn shutdown(&mut self) -> Result<Json> {
        self.send_line("SHUTDOWN")?;
        let line = self.recv_line()?;
        Json::parse(line.trim()).context("shutdown ack parse")
    }
}
