//! Bounded multi-producer/multi-consumer admission queue with priority
//! classes and backpressure (the serving layer's §5.1-style memory
//! admission).
//!
//! Admission is bounded two ways: a job-count cap (`--queue N`) and an
//! in-flight-byte cap modeled like [`crate::coordinator::partition::capacity_units`]
//! — every admitted job accounts input + output + one scratch copy of
//! its core until its reply is sent.  A push that would exceed either
//! bound is rejected with a `retry_after_ms` hint instead of blocking
//! the connection thread (reject-with-retry-after backpressure).
//!
//! Consumers ([`AdmissionQueue::pop_batch`]) drain the lowest-numbered
//! non-empty class first and FIFO within a class; a pop also coalesces
//! the *head run* of jobs sharing one [`JobSpec::batch_key`] so the
//! dispatcher can run them as a single multi-field dispatch.  Only the
//! contiguous head run is taken — reaching deeper into the queue would
//! reorder jobs within the class and break the FIFO guarantee.

use std::collections::VecDeque;
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::stencil::Field;

use super::job::{JobSpec, PRIORITY_CLASSES};

/// An admitted job waiting for (or undergoing) dispatch.
pub struct QueuedJob {
    pub spec: JobSpec,
    pub input: Field,
    pub admit_seq: u64,
    /// Queue-pop order, assigned under the queue lock at
    /// [`AdmissionQueue::pop_batch`] — therefore FIFO within a priority
    /// class no matter how many dispatcher threads race on the pops.
    pub start_seq: u64,
    pub admitted_at: Instant,
    /// Bytes held against the queue's in-flight bound until release.
    pub cost_bytes: usize,
    /// Serialized reply line sink (one line per job).
    pub reply: Sender<String>,
}

/// Outcome of [`AdmissionQueue::push`].
#[derive(Debug)]
pub enum Admission {
    Admitted(u64),
    Rejected { reason: String, retry_after_ms: u64 },
}

struct Inner {
    classes: Vec<VecDeque<QueuedJob>>,
    queued: usize,
    inflight_bytes: usize,
    next_seq: u64,
    next_start: u64,
    closed: bool,
}

pub struct AdmissionQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    pub max_jobs: usize,
    pub max_bytes: usize,
}

impl AdmissionQueue {
    pub fn new(max_jobs: usize, max_bytes: usize) -> AdmissionQueue {
        AdmissionQueue {
            inner: Mutex::new(Inner {
                classes: (0..PRIORITY_CLASSES).map(|_| VecDeque::new()).collect(),
                queued: 0,
                inflight_bytes: 0,
                next_seq: 0,
                next_start: 0,
                closed: false,
            }),
            cv: Condvar::new(),
            max_jobs: max_jobs.max(1),
            max_bytes: max_bytes.max(1),
        }
    }

    /// Admit or reject a job.  The byte cost (input + output + scratch)
    /// stays accounted until [`AdmissionQueue::release`].
    pub fn push(&self, spec: JobSpec, input: Field, reply: Sender<String>) -> Admission {
        // only clone the id for the trace when recording is on
        let trace_id = crate::trace::enabled().then(|| spec.id.clone());
        let adm = self.push_inner(spec, input, reply);
        if let Some(id) = trace_id {
            match &adm {
                Admission::Admitted(seq) => {
                    crate::trace::instant(
                        "serve",
                        "admit",
                        &[("job", id.as_str().into()), ("seq", (*seq).into())],
                    );
                    // step in the job's accept→reply flow (rejects are
                    // finished by the caller's reject reply instead)
                    crate::trace::flow_step("serve", "job", crate::trace::flow_id(&id), &[]);
                }
                Admission::Rejected { retry_after_ms, .. } => crate::trace::instant(
                    "serve",
                    "reject",
                    &[("job", id.as_str().into()), ("retry_after_ms", (*retry_after_ms).into())],
                ),
            }
        }
        adm
    }

    fn push_inner(&self, spec: JobSpec, input: Field, reply: Sender<String>) -> Admission {
        let cost_bytes = 3 * input.len() * 8;
        let mut g = self.inner.lock().unwrap();
        if g.closed {
            return Admission::Rejected {
                reason: "server is shutting down".into(),
                retry_after_ms: 0,
            };
        }
        // A job whose footprint alone exceeds the queue's byte budget
        // can never be admitted: hint 0 ("do not retry") instead of
        // sending the client into a permanent retry loop.
        if cost_bytes > self.max_bytes {
            return Admission::Rejected {
                reason: format!(
                    "memory admission: job needs {cost_bytes} bytes, queue capacity {}",
                    self.max_bytes
                ),
                retry_after_ms: 0,
            };
        }
        // Backpressure hint: roughly one queue-drain's worth of patience,
        // growing with queue depth AND with in-flight byte pressure.
        // Depth alone is not enough: a byte-bound rejection with an
        // empty queue (the budget held by long in-flight jobs) would
        // hint the 25 ms floor and send clients into a hot retry loop
        // even though nothing frees until a multi-second job replies.
        // Byte pressure in eighths scales the hint up to +200 ms at a
        // full budget.
        let pressure_eighths = (g.inflight_bytes.saturating_mul(8) / self.max_bytes) as u64;
        let retry_after_ms =
            (25 * (g.queued as u64 + 1) + 25 * pressure_eighths).min(5_000);
        if g.queued >= self.max_jobs {
            return Admission::Rejected {
                reason: format!("queue full ({} jobs)", self.max_jobs),
                retry_after_ms,
            };
        }
        if g.inflight_bytes + cost_bytes > self.max_bytes {
            return Admission::Rejected {
                reason: format!(
                    "memory admission: {} in-flight + {} job bytes exceeds {}",
                    g.inflight_bytes, cost_bytes, self.max_bytes
                ),
                retry_after_ms,
            };
        }
        let seq = g.next_seq;
        g.next_seq += 1;
        g.queued += 1;
        g.inflight_bytes += cost_bytes;
        let class = spec.priority.class();
        g.classes[class].push_back(QueuedJob {
            spec,
            input,
            admit_seq: seq,
            start_seq: 0, // assigned at pop
            admitted_at: Instant::now(),
            cost_bytes,
            reply,
        });
        self.cv.notify_one();
        Admission::Admitted(seq)
    }

    /// Block until a job is available (or the queue is closed *and*
    /// drained — `None`).  Returns the head job of the best class plus
    /// up to `max_batch - 1` immediate successors sharing its batch key.
    pub fn pop_batch(&self, max_batch: usize) -> Option<Vec<QueuedJob>> {
        let mut g = self.inner.lock().unwrap();
        let class = loop {
            match (0..PRIORITY_CLASSES).find(|&c| !g.classes[c].is_empty()) {
                Some(c) => break c,
                None if g.closed => return None,
                None => g = self.cv.wait(g).unwrap(),
            }
        };
        let head = g.classes[class].pop_front().unwrap();
        let key = head.spec.batch_key();
        let mut batch = vec![head];
        while batch.len() < max_batch.max(1) {
            match g.classes[class].front() {
                Some(next) if next.spec.batch_key() == key => {
                    batch.push(g.classes[class].pop_front().unwrap());
                }
                _ => break,
            }
        }
        g.queued -= batch.len();
        for job in &mut batch {
            job.start_seq = g.next_start;
            g.next_start += 1;
        }
        drop(g);
        if crate::trace::enabled() {
            for job in &batch {
                crate::trace::instant(
                    "serve",
                    "dequeue",
                    &[
                        ("job", job.spec.id.as_str().into()),
                        ("queue_us", (job.admitted_at.elapsed().as_micros() as u64).into()),
                    ],
                );
                crate::trace::flow_step(
                    "serve",
                    "job",
                    crate::trace::flow_id(&job.spec.id),
                    &[],
                );
            }
        }
        Some(batch)
    }

    /// Return a finished batch's bytes to the admission budget.
    pub fn release(&self, cost_bytes: usize) {
        let mut g = self.inner.lock().unwrap();
        g.inflight_bytes = g.inflight_bytes.saturating_sub(cost_bytes);
    }

    /// Stop admitting; consumers drain what is queued, then get `None`.
    pub fn close(&self) {
        let mut g = self.inner.lock().unwrap();
        g.closed = true;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    /// Queued jobs per priority class (admitted, not yet popped).
    pub fn depths(&self) -> Vec<usize> {
        let g = self.inner.lock().unwrap();
        g.classes.iter().map(|q| q.len()).collect()
    }

    pub fn queued(&self) -> usize {
        self.inner.lock().unwrap().queued
    }

    pub fn inflight_bytes(&self) -> usize {
        self.inner.lock().unwrap().inflight_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::job::Priority;
    use std::sync::mpsc;

    fn job(id: &str, priority: Priority) -> (JobSpec, Field) {
        let spec = JobSpec {
            id: id.into(),
            bench: "heat1d".into(),
            priority,
            shape: Some(vec![8]),
            ..Default::default()
        };
        let input = spec.materialize(&[8]).unwrap();
        (spec, input)
    }

    fn push(q: &AdmissionQueue, id: &str, p: Priority) -> Admission {
        let (spec, input) = job(id, p);
        // tests here never reply, so the receiver can drop immediately
        let (tx, _rx) = mpsc::channel();
        q.push(spec, input, tx)
    }

    #[test]
    fn classes_drain_by_priority_then_fifo() {
        let q = AdmissionQueue::new(16, 1 << 20);
        push(&q, "b1", Priority::Batch);
        push(&q, "n1", Priority::Normal);
        push(&q, "i1", Priority::Interactive);
        push(&q, "i2", Priority::Interactive);
        assert_eq!(q.depths(), vec![2, 1, 1]);
        // interactive drains first, FIFO within the class
        let batch = q.pop_batch(8).unwrap();
        assert_eq!(
            batch.iter().map(|j| j.spec.id.as_str()).collect::<Vec<_>>(),
            vec!["i1", "i2"],
            "same batch key: both interactive jobs coalesce"
        );
        assert_eq!(q.pop_batch(8).unwrap()[0].spec.id, "n1");
        assert_eq!(q.pop_batch(8).unwrap()[0].spec.id, "b1");
        assert_eq!(q.queued(), 0);
    }

    #[test]
    fn batching_takes_only_the_matching_head_run() {
        let q = AdmissionQueue::new(16, 1 << 20);
        push(&q, "a1", Priority::Normal);
        push(&q, "a2", Priority::Normal);
        let (mut spec, input) = job("x", Priority::Normal);
        spec.boundary = crate::stencil::Boundary::Periodic; // different key
        let (tx, _rx) = mpsc::channel();
        q.push(spec, input, tx);
        push(&q, "a3", Priority::Normal);
        // a3 matches a1/a2's key but sits behind x: taking it would
        // reorder the class, so the batch stops at the run boundary.
        let batch = q.pop_batch(8).unwrap();
        assert_eq!(
            batch.iter().map(|j| j.spec.id.as_str()).collect::<Vec<_>>(),
            vec!["a1", "a2"]
        );
        assert_eq!(q.pop_batch(8).unwrap()[0].spec.id, "x");
        assert_eq!(q.pop_batch(8).unwrap()[0].spec.id, "a3");
    }

    #[test]
    fn max_batch_bounds_the_coalesced_run() {
        let q = AdmissionQueue::new(16, 1 << 20);
        for i in 0..5 {
            push(&q, &format!("j{i}"), Priority::Normal);
        }
        assert_eq!(q.pop_batch(2).unwrap().len(), 2);
        assert_eq!(q.pop_batch(2).unwrap().len(), 2);
        assert_eq!(q.pop_batch(2).unwrap().len(), 1);
    }

    #[test]
    fn job_cap_rejects_with_retry_hint() {
        let q = AdmissionQueue::new(2, 1 << 20);
        push(&q, "a", Priority::Normal);
        push(&q, "b", Priority::Normal);
        match push(&q, "c", Priority::Normal) {
            Admission::Rejected { reason, retry_after_ms } => {
                assert!(reason.contains("queue full"), "{reason}");
                assert!(retry_after_ms > 0);
            }
            other => panic!("expected reject, got {other:?}"),
        }
    }

    #[test]
    fn byte_admission_rejects_and_release_readmits() {
        // one 8-cell job costs 3*8*8 = 192 bytes
        let q = AdmissionQueue::new(16, 200);
        assert!(matches!(push(&q, "a", Priority::Normal), Admission::Admitted(_)));
        match push(&q, "b", Priority::Normal) {
            Admission::Rejected { reason, retry_after_ms } => {
                assert!(reason.contains("memory admission"), "{reason}");
                assert!(retry_after_ms > 0);
            }
            other => panic!("expected memory reject, got {other:?}"),
        }
        // popping does NOT free the budget — the job is still in flight
        let batch = q.pop_batch(1).unwrap();
        assert!(matches!(push(&q, "c", Priority::Normal), Admission::Rejected { .. }));
        q.release(batch[0].cost_bytes);
        assert!(matches!(push(&q, "d", Priority::Normal), Admission::Admitted(_)));
    }

    /// Regression: a byte-bound rejection with an EMPTY queue (budget
    /// held by in-flight jobs) must hint patience proportional to the
    /// byte pressure, not the bare 25 ms depth floor that sent clients
    /// into a hot retry loop.
    #[test]
    fn byte_bound_reject_with_empty_queue_scales_hint_by_pressure() {
        // one 8-cell job costs 192 bytes against a 200-byte budget
        let q = AdmissionQueue::new(16, 200);
        assert!(matches!(push(&q, "a", Priority::Normal), Admission::Admitted(_)));
        // pop it: the queue is now EMPTY but 96% of the bytes are still
        // in flight until release()
        let batch = q.pop_batch(1).unwrap();
        assert_eq!(q.queued(), 0);
        let hint_under_pressure = match push(&q, "b", Priority::Normal) {
            Admission::Rejected { reason, retry_after_ms } => {
                assert!(reason.contains("memory admission"), "{reason}");
                retry_after_ms
            }
            other => panic!("expected byte-bound reject, got {other:?}"),
        };
        assert!(
            hint_under_pressure >= 100,
            "96% byte pressure must raise the hint well past the 25 ms depth floor, \
             got {hint_under_pressure}"
        );
        // releasing the in-flight bytes readmits — the hint was about
        // waiting for exactly this release
        q.release(batch[0].cost_bytes);
        assert!(matches!(push(&q, "c", Priority::Normal), Admission::Admitted(_)));
    }

    #[test]
    fn job_that_can_never_fit_gets_do_not_retry_hint() {
        // an 8-cell job costs 192 bytes; a 100-byte queue can never take it
        let q = AdmissionQueue::new(16, 100);
        match push(&q, "whale", Priority::Normal) {
            Admission::Rejected { reason, retry_after_ms } => {
                assert!(reason.contains("memory admission"), "{reason}");
                assert_eq!(retry_after_ms, 0, "retrying a never-fitting job is futile");
            }
            other => panic!("expected reject, got {other:?}"),
        }
    }

    #[test]
    fn start_seq_follows_pop_order_within_class() {
        let q = AdmissionQueue::new(16, 1 << 20);
        push(&q, "n1", Priority::Normal);
        push(&q, "i1", Priority::Interactive);
        push(&q, "i2", Priority::Interactive);
        // interactive batch pops first: start_seqs 0, 1 in admit order
        let batch = q.pop_batch(8).unwrap();
        assert_eq!(
            batch.iter().map(|j| (j.spec.id.as_str(), j.start_seq)).collect::<Vec<_>>(),
            vec![("i1", 0), ("i2", 1)]
        );
        assert_eq!(q.pop_batch(8).unwrap()[0].start_seq, 2);
    }

    #[test]
    fn close_drains_then_returns_none_and_rejects_pushes() {
        let q = AdmissionQueue::new(16, 1 << 20);
        push(&q, "a", Priority::Normal);
        q.close();
        match push(&q, "late", Priority::Normal) {
            Admission::Rejected { reason, retry_after_ms } => {
                assert!(reason.contains("shutting down"), "{reason}");
                assert_eq!(retry_after_ms, 0);
            }
            other => panic!("expected shutdown reject, got {other:?}"),
        }
        assert_eq!(q.pop_batch(4).unwrap()[0].spec.id, "a");
        assert!(q.pop_batch(4).is_none(), "drained + closed must end the consumer");
    }

    #[test]
    fn pop_blocks_until_push_arrives() {
        let q = std::sync::Arc::new(AdmissionQueue::new(4, 1 << 20));
        let q2 = q.clone();
        let popper = std::thread::spawn(move || q2.pop_batch(1).map(|b| b[0].spec.id.clone()));
        std::thread::sleep(std::time::Duration::from_millis(20));
        push(&q, "late-arrival", Priority::Batch);
        assert_eq!(popper.join().unwrap().as_deref(), Some("late-arrival"));
    }

    #[test]
    fn admit_seq_is_monotonic_across_classes() {
        let q = AdmissionQueue::new(16, 1 << 20);
        let seqs: Vec<u64> = [Priority::Batch, Priority::Interactive, Priority::Normal]
            .into_iter()
            .enumerate()
            .map(|(i, p)| match push(&q, &format!("s{i}"), p) {
                Admission::Admitted(s) => s,
                other => panic!("{other:?}"),
            })
            .collect();
        assert_eq!(seqs, vec![0, 1, 2]);
    }
}
