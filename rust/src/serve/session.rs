//! Per-`(bench, boundary-kind, shape)` scheduler sessions.
//!
//! A session owns a long-lived [`Scheduler`] (workers included) and
//! **caches the converged partition across jobs** — the in-process loop
//! this subsystem replaces recomputed `final_shares` from a fresh
//! profile on every process start.  After each run the session compares
//! the run's converged shares against the cache:
//!
//! * drift within `drift_threshold` (L1 share distance / total units) —
//!   the cached partition still describes the hardware; keep it
//!   bit-stable so back-to-back jobs skip the §5.2 warm-up entirely
//!   (a cache *hit*);
//! * drift above the threshold — the worker mix genuinely changed
//!   (thermal throttling, a noisy neighbour, a device reclaimed); the
//!   cache is *invalidated* and replaced by the measured shares, which
//!   beat re-profiling because they come from real blocks, not a
//!   synthetic unit-slab probe.
//!
//! Sessions are disposable by design: the executor's TTL/LRU sweep
//! drops cold `(bench, boundary-kind, shape)` keys, and dropping a
//! session releases its workers *and* the cached partition.  Nothing is
//! lost that a plan-store lookup (or one warm-up job) cannot rebuild —
//! which is exactly why planned sessions also start from the stored
//! plan's engine/Tb instead of defaults.

use crate::util::error::{Context, Result};

use crate::coordinator::partition::capacity_units;
use crate::coordinator::{tuner, CommModel, Overlap, Partition, RunMetrics, Scheduler, Worker};
use crate::stencil::{spec, Boundary, Field};

pub struct Session {
    sched: Scheduler,
    /// Startup-profile weights, kept for diagnostics.
    pub profile_weights: Vec<f64>,
    drift_threshold: f64,
    pub jobs_run: u64,
    pub cache_hits: u64,
    pub invalidations: u64,
}

impl Session {
    /// Build a session: profile the workers once (§5.2 startup phase),
    /// derive the balanced row-granular partition, and keep everything —
    /// workers, scheduler, partition — alive for the jobs to come.
    pub fn new(
        bench: &str,
        shape: Vec<usize>,
        tb: usize,
        workers: Vec<Box<dyn Worker>>,
        adapt_every: usize,
        drift_threshold: f64,
        overlap: Overlap,
    ) -> Result<Session> {
        let s = spec::get(bench).with_context(|| format!("unknown bench {bench:?}"))?;
        crate::ensure!(!workers.is_empty(), "session needs at least one worker");
        crate::ensure!(
            shape.len() == s.ndim && shape.iter().all(|&n| n >= 1),
            "bench {bench} wants {} dims >= 1, got {shape:?}",
            s.ndim
        );
        crate::ensure!(tb >= 1, "tb must be >= 1");
        let rows = shape[0];
        let halo = s.radius * tb;
        let rest_cells: usize = shape[1..].iter().map(|n| n + 2 * halo).product::<usize>().max(1);
        // Profile one small unit slab per worker (warmup + 1 rep keeps
        // session creation cheap; the in-run retune refines from there).
        let mut unit_core = vec![rows.min(4)];
        unit_core.extend(&shape[1..]);
        let profile = tuner::profile_workers(&workers, &s, &unit_core, tb, 1)
            .with_context(|| format!("profiling session workers for {bench}"))?;
        let weights: Vec<f64> = profile.iter().map(|t| 1.0 / t.max(1e-12)).collect();
        let caps: Vec<usize> = workers
            .iter()
            .map(|w| capacity_units(w.mem_capacity(), 1, rest_cells))
            .collect();
        let partition = Partition::balanced(1, rows, &weights, &caps);
        Ok(Session {
            sched: Scheduler {
                spec: s,
                tb,
                workers,
                partition,
                comm_model: CommModel::default(),
                boundary: Boundary::Dirichlet(0.0),
                adapt_every,
                overlap,
            },
            profile_weights: weights,
            drift_threshold,
            jobs_run: 0,
            cache_hits: 0,
            invalidations: 0,
        })
    }

    pub fn tb(&self) -> usize {
        self.sched.tb
    }

    /// The §5.3 leader-loop mode the session's scheduler runs with.
    pub fn overlap(&self) -> Overlap {
        self.sched.overlap
    }

    /// Worker identities, in partition order (`STATS` + plan write-back).
    pub fn worker_names(&self) -> Vec<String> {
        self.sched.workers.iter().map(|w| w.name()).collect()
    }

    /// Round a requested step count up to a whole number of Tb-blocks.
    pub fn align_steps(&self, steps: usize) -> usize {
        steps.max(1).div_ceil(self.sched.tb) * self.sched.tb
    }

    /// The cached partition shares (what the next job will start from).
    pub fn shares(&self) -> Vec<usize> {
        self.sched.partition.shares.clone()
    }

    /// Run a batch of same-shape inputs under `boundary` for `steps`
    /// (already Tb-aligned), then reconcile the partition cache.
    pub fn run_batch(
        &mut self,
        boundary: Boundary,
        inputs: &[Field],
        steps: usize,
    ) -> Result<(Vec<Field>, RunMetrics)> {
        self.sched.boundary = boundary;
        let cached = self.sched.partition.shares.clone();
        let (outs, metrics) = self.sched.run_batch(inputs, steps)?;
        self.jobs_run += inputs.len() as u64;
        let total = self.sched.partition.total_units().max(1);
        let drift: usize =
            cached.iter().zip(&metrics.final_shares).map(|(a, b)| a.abs_diff(*b)).sum();
        if drift as f64 / total as f64 > self.drift_threshold {
            self.invalidations += 1;
            // Carry both axes: shares plus (for grid sessions) the
            // converged band widths, so the rebuild is the exact grid.
            self.sched.partition =
                Partition::rows(self.sched.partition.unit, metrics.final_shares.clone())
                    .with_bands(metrics.final_bands.clone());
        } else {
            self.cache_hits += 1;
        }
        Ok((outs, metrics))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::reference_evolution;
    use crate::coordinator::NativeWorker;
    use crate::stencil::StencilSpec;
    use std::time::Duration;

    fn native(eng: &str) -> Box<dyn Worker> {
        Box::new(NativeWorker::new(crate::engine::by_name(eng, 1).unwrap(), 1 << 30))
    }

    #[test]
    fn session_serves_boundary_diverse_jobs_correctly() {
        let mut sess = Session::new(
            "heat2d",
            vec![16, 8],
            2,
            vec![native("simd"), native("autovec")],
            0,
            0.25,
            Overlap::Auto,
        )
        .unwrap();
        for (i, boundary) in
            [Boundary::Dirichlet(25.0), Boundary::Neumann, Boundary::Periodic].into_iter().enumerate()
        {
            let core = Field::random(&[16, 8], 60 + i as u64);
            let (outs, m) = sess.run_batch(boundary, std::slice::from_ref(&core), 4).unwrap();
            let s = spec::get("heat2d").unwrap();
            let want = reference_evolution(&core, &s, 4, 2, boundary);
            assert!(
                outs[0].allclose(&want, 1e-12, 1e-14),
                "{boundary}: maxdiff={}",
                outs[0].max_abs_diff(&want)
            );
            assert_eq!(m.fields, 1);
        }
        assert_eq!(sess.jobs_run, 3);
        assert_eq!(sess.cache_hits + sess.invalidations, 3);
    }

    #[test]
    fn worker_names_report_partition_order() {
        let sess = Session::new(
            "heat1d",
            vec![16],
            2,
            vec![native("simd"), native("autovec")],
            0,
            0.25,
            Overlap::Off,
        )
        .unwrap();
        assert_eq!(sess.overlap(), Overlap::Off);
        assert_eq!(sess.worker_names(), vec!["native:simd", "native:autovec"]);
    }

    #[test]
    fn align_steps_rounds_up_to_blocks() {
        let sess =
            Session::new("heat1d", vec![16], 4, vec![native("naive")], 0, 0.25, Overlap::Auto)
                .unwrap();
        assert_eq!(sess.align_steps(0), 4);
        assert_eq!(sess.align_steps(1), 4);
        assert_eq!(sess.align_steps(4), 4);
        assert_eq!(sess.align_steps(5), 8);
    }

    /// Adds a fixed per-slab setup cost regardless of slab size — a
    /// launch-latency-dominated device.  The startup profile (one small
    /// unit slab each) cannot distinguish this from a per-row cost, so
    /// the profiled split is wrong and only the in-run retune finds the
    /// true balance: exactly the drift the session cache must handle.
    struct SlabDelayWorker {
        inner: Box<dyn Worker>,
        per_slab: Duration,
    }

    impl Worker for SlabDelayWorker {
        fn name(&self) -> String {
            format!("slabdelay:{}", self.inner.name())
        }
        fn mem_capacity(&self) -> usize {
            self.inner.mem_capacity()
        }
        fn run_slab(&self, spec: &StencilSpec, input: &Field, steps: usize) -> Result<Field> {
            std::thread::sleep(self.per_slab);
            self.inner.run_slab(spec, input, steps)
        }
    }

    fn slab_delayed(per_slab_us: u64) -> Box<dyn Worker> {
        Box::new(SlabDelayWorker {
            inner: native("simd"),
            per_slab: Duration::from_micros(per_slab_us),
        })
    }

    /// A conservative threshold keeps the cached partition bit-stable
    /// across jobs even though the in-run retune moved shares: small
    /// per-job drift is absorbed, and the session keeps serving correct
    /// results from the cache.
    #[test]
    fn conservative_threshold_keeps_cache_stable() {
        let mut sess = Session::new(
            "heat1d",
            vec![16],
            1,
            vec![slab_delayed(2000), slab_delayed(500)],
            1,
            10.0, // max possible drift is 2: never invalidate
            Overlap::Off,
        )
        .unwrap();
        let before = sess.shares();
        let core = Field::random(&[16], 71);
        let (_, m1) =
            sess.run_batch(Boundary::Dirichlet(0.0), std::slice::from_ref(&core), 8).unwrap();
        assert!(m1.retunes >= 1, "flat-cost pair must retune in-run: {m1:?}");
        assert_eq!(sess.invalidations, 0);
        assert_eq!(sess.cache_hits, 1);
        assert_eq!(sess.shares(), before, "cache must stay bit-stable under the threshold");
        let (outs, _) =
            sess.run_batch(Boundary::Dirichlet(0.0), std::slice::from_ref(&core), 4).unwrap();
        let s = spec::get("heat1d").unwrap();
        let want = reference_evolution(&core, &s, 4, 1, Boundary::Dirichlet(0.0));
        assert!(outs[0].allclose(&want, 1e-12, 1e-14));
    }

    /// drift_threshold = 0 turns every share move into an invalidation:
    /// the cache adopts the converged shares, so the next job starts
    /// from measured balance instead of the misleading profile split.
    #[test]
    fn zero_threshold_adopts_converged_shares() {
        let mut sess = Session::new(
            "heat1d",
            vec![16],
            1,
            vec![slab_delayed(2000), slab_delayed(500)],
            1,
            0.0,
            Overlap::Off,
        )
        .unwrap();
        let before = sess.shares();
        let core = Field::random(&[16], 73);
        let (_, m) =
            sess.run_batch(Boundary::Dirichlet(0.0), std::slice::from_ref(&core), 8).unwrap();
        assert_ne!(m.final_shares, before, "flat-cost pair must converge off the profile split");
        assert_eq!(sess.invalidations, 1);
        assert_eq!(sess.shares(), m.final_shares, "cache must adopt the converged shares");
    }

    #[test]
    fn rejects_bad_bench_and_shape() {
        let o = Overlap::Auto;
        assert!(Session::new("nope", vec![8], 1, vec![native("naive")], 0, 0.25, o).is_err());
        assert!(Session::new("heat2d", vec![8], 1, vec![native("naive")], 0, 0.25, o).is_err());
        assert!(Session::new("heat2d", vec![8, 8], 1, Vec::new(), 0, 0.25, o).is_err());
    }
}
