//! Zero-dependency TCP front end: one JSON job per line in, one JSON
//! reply per line out, **in request order per connection** (requests may
//! be pipelined; replies never reorder).  Two bare-word commands ride
//! the same framing:
//!
//! * `STATS` — one JSON line: queue depths, per-session shares and
//!   cache counters, latency percentiles;
//! * `METRICS` — one flat JSON line: the unified
//!   [`crate::trace::MetricsRegistry`] snapshot (monotone `serve.*_total`
//!   counters, queue gauges, flattened latency percentiles) under the
//!   stable naming policy `tetris bench check` gates on;
//! * `SHUTDOWN` — acks, stops admission, lets the dispatchers drain
//!   every queued job, then closes the listener.
//!
//! A malformed or invalid line yields a structured `{"ok":false,...}`
//! reply and the connection stays open — a typo must never cost a
//! client its pipelined jobs.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::trace::MetricsRegistry;
use crate::util::error::{Context, Result};
use crate::util::json::Json;

use crate::coordinator::{NativeWorker, Overlap, Worker, XlaWorker};
use crate::plan::{Candidate, Fingerprint, Plan, PlanStore};
use crate::runtime::XlaService;

use super::batcher::{ExecConfig, Executor, WorkerFactory};
use super::job::{JobResult, JobSpec};
use super::queue::{Admission, AdmissionQueue};
use super::stats::ServeStats;

/// Server policy — every knob has a CLI flag on `tetris serve`.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; port 0 picks a free port (see [`ServerHandle::addr`]).
    pub addr: String,
    /// Dispatcher threads (`--workers`): concurrent batches in flight.
    pub dispatchers: usize,
    /// Admission cap in queued jobs (`--queue`).
    pub queue_jobs: usize,
    /// Admission cap on in-flight bytes.
    pub queue_bytes: usize,
    /// Max jobs coalesced into one multi-field dispatch (`--batch`).
    pub max_batch: usize,
    /// Engine threads for factory-built native workers.
    pub threads: usize,
    /// In-run retune cadence for session schedulers (`--adapt`).
    pub adapt_every: usize,
    /// Session partition-cache invalidation threshold (`--drift`).
    pub drift_threshold: f64,
    /// Default problem scale for benches without an explicit shape.
    pub scale: f64,
    /// Evict sessions idle longer than this (`--session-ttl`; ZERO =
    /// keep forever).
    pub session_ttl: Duration,
    /// LRU cap on live sessions (`--max-sessions`; 0 = unbounded).
    pub max_sessions: usize,
    /// Plan-store path (`--plan-store`; None = planning disabled, the
    /// default here so embedded/test servers stay hermetic — the CLI
    /// defaults to the user store).
    pub plan_store: Option<String>,
    /// Machine fingerprint for plan keys (None = detect on first use).
    pub fingerprint: Option<Fingerprint>,
    /// §5.3 leader-loop mode for session schedulers (`--overlap`);
    /// per-session plans with a searched `overlap` field override it
    /// unless the flag was passed explicitly.
    pub overlap: Overlap,
    /// Whether `--overlap` was passed explicitly (beats stored plans).
    pub overlap_explicit: bool,
    /// Periodic metrics scrape (`--metrics-scrape FILE[:SECS]`):
    /// `Some((path, secs))` appends one timestamped
    /// [`MetricsRegistry`] snapshot per interval to `path` as JSONL,
    /// gated by `tetris bench check`.
    pub metrics_scrape: Option<(String, u64)>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7466".into(),
            dispatchers: 2,
            queue_jobs: 64,
            queue_bytes: 1 << 30,
            max_batch: 8,
            threads: 2,
            adapt_every: 2,
            drift_threshold: 0.25,
            scale: 0.25,
            session_ttl: Duration::ZERO,
            max_sessions: 0,
            plan_store: None,
            fingerprint: None,
            overlap: Overlap::Auto,
            overlap_explicit: false,
            metrics_scrape: None,
        }
    }
}

/// Default worker mix for a new session.
///
/// With a stored [`Plan`] the session runs a homogeneous pair of the
/// plan's engine (plan threads + a single-thread sibling): adopting the
/// tuned choice while keeping results bit-identical to the fixed-engine
/// path — the slab split across equal engines is numerically invisible.
///
/// Without a plan, the AOT artifact worker rides along when the
/// artifacts exist *and* fit the session's geometry (fused steps ==
/// session Tb, matching non-split dims, unit-aligned rows); otherwise
/// two native workers serve alone.  The artifact-less CI container
/// therefore serves fine — with a one-line warning instead of a
/// refusal.
pub fn default_worker_factory(threads: usize) -> WorkerFactory {
    Arc::new(move |bench, shape, tb, plan: Option<&Plan>| {
        let native = |eng: &str, t: usize| -> Result<Box<dyn Worker>> {
            Ok(Box::new(NativeWorker::new(
                crate::plan::resolve_engine(eng, t)
                    .with_context(|| format!("unknown engine {eng}"))?,
                1 << 33,
            )))
        };
        if let Some(p) = plan {
            // Candidate::build honors the whole tuned configuration —
            // including the tile-width override resolve_engine alone
            // would silently drop.
            let lead = p.candidate().build();
            let sibling = Candidate { threads: 1, ..p.candidate() }.build();
            if let (Some(a), Some(b)) = (lead, sibling) {
                return Ok(vec![
                    Box::new(NativeWorker::new(a, 1 << 33)) as Box<dyn Worker>,
                    Box::new(NativeWorker::new(b, 1 << 33)),
                ]);
            }
            eprintln!(
                "tetris serve: stored plan names unknown engine {:?}; using defaults",
                p.engine
            );
        }
        match XlaService::spawn_default() {
            Ok(svc) => {
                if let Some(xla) = compatible_artifact(&svc, bench, shape, tb) {
                    return Ok(vec![native("tetris-cpu", threads)?, xla]);
                }
                Ok(vec![native("tetris-cpu", threads)?, native("simd", 1)?])
            }
            Err(e) => {
                eprintln!(
                    "tetris serve: artifacts unavailable ({e}); \
                     falling back to two native workers"
                );
                Ok(vec![native("tetris-cpu", threads)?, native("simd", 1)?])
            }
        }
    })
}

fn compatible_artifact(
    svc: &XlaService,
    bench: &str,
    shape: &[usize],
    tb: usize,
) -> Option<Box<dyn Worker>> {
    let worker = XlaWorker::new(svc.clone(), &format!("{bench}_block"), 1 << 33).ok()?;
    let meta = worker.meta.clone();
    let fits = meta.steps == tb
        && shape.len() == meta.unit_core.len()
        && shape[0] % worker.unit() == 0
        && shape[1..] == meta.unit_core[1..];
    fits.then(|| Box::new(worker) as Box<dyn Worker>)
}

/// Replies enqueued to per-connection writers but not yet written to
/// their sockets.  `ServerHandle::join` waits (bounded) for this to hit
/// zero so drained-job replies are flushed before the process exits.
type Pending = Arc<(Mutex<u64>, Condvar)>;

/// A running server: listener + dispatcher threads.
pub struct ServerHandle {
    pub addr: SocketAddr,
    queue: Arc<AdmissionQueue>,
    shutdown: Arc<AtomicBool>,
    pending: Pending,
    threads: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    /// Initiate the same sequence as a `SHUTDOWN` line: stop admission,
    /// drain, close the listener.
    pub fn shutdown(&self) {
        trigger_shutdown(&self.shutdown, &self.queue, self.addr);
    }

    /// Wait for the drain to finish and every server thread to exit,
    /// then give the per-connection writers a bounded window to flush
    /// every already-produced reply to its socket (a stalled client
    /// can delay exit by at most ~5s, never block it).
    pub fn join(mut self) {
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
        let (lock, cv) = &*self.pending;
        let deadline = Instant::now() + Duration::from_secs(5);
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            n = cv.wait_timeout(n, deadline - now).unwrap().0;
        }
    }
}

fn trigger_shutdown(shutdown: &AtomicBool, queue: &AdmissionQueue, addr: SocketAddr) {
    shutdown.store(true, Ordering::SeqCst);
    queue.close();
    // Wake the accept loop so it observes the flag.
    let _ = TcpStream::connect(addr);
}

/// Shared connection context.
struct Ctx {
    queue: Arc<AdmissionQueue>,
    exec: Arc<Executor>,
    stats: Arc<Mutex<ServeStats>>,
    shutdown: Arc<AtomicBool>,
    pending: Pending,
    addr: SocketAddr,
    scale: f64,
}

pub struct Server;

impl Server {
    /// Bind, spawn the dispatchers and the accept loop, return a handle.
    pub fn start(cfg: ServeConfig, factory: WorkerFactory) -> Result<ServerHandle> {
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let queue = Arc::new(AdmissionQueue::new(cfg.queue_jobs, cfg.queue_bytes));
        let stats = Arc::new(Mutex::new(ServeStats::new()));
        let exec = Arc::new(Executor::new(
            queue.clone(),
            stats.clone(),
            ExecConfig {
                scale: cfg.scale,
                threads: cfg.threads,
                adapt_every: cfg.adapt_every,
                drift_threshold: cfg.drift_threshold,
                plan_store: cfg.plan_store.as_ref().map(|p| Arc::new(PlanStore::open(p))),
                fingerprint: cfg.fingerprint.clone(),
                session_ttl: cfg.session_ttl,
                max_sessions: cfg.max_sessions,
                overlap: cfg.overlap,
                overlap_explicit: cfg.overlap_explicit,
            },
            factory,
        ));
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut threads = Vec::new();
        for d in 0..cfg.dispatchers.max(1) {
            let exec = exec.clone();
            let max_batch = cfg.max_batch;
            threads.push(
                std::thread::Builder::new()
                    .name(format!("tetris-dispatch-{d}"))
                    .spawn(move || exec.dispatch_loop(max_batch))?,
            );
        }
        let pending: Pending = Arc::new((Mutex::new(0), Condvar::new()));
        let ctx = Arc::new(Ctx {
            queue: queue.clone(),
            exec,
            stats,
            shutdown: shutdown.clone(),
            pending: pending.clone(),
            addr,
            scale: cfg.scale,
        });
        if let Some((path, secs)) = cfg.metrics_scrape.clone() {
            let ctx = ctx.clone();
            threads.push(
                std::thread::Builder::new()
                    .name("tetris-scrape".into())
                    .spawn(move || scrape_loop(&path, secs, &ctx))?,
            );
        }
        threads.push(
            std::thread::Builder::new()
                .name("tetris-accept".into())
                .spawn(move || accept_loop(listener, ctx))?,
        );
        Ok(ServerHandle { addr, queue, shutdown, pending, threads })
    }
}

/// Append-only JSONL scraper: one [`metrics_line`] snapshot per
/// interval plus a `ts_ms` key (milliseconds since the scraper
/// started), flushed line by line so the file is valid mid-run.  The
/// snapshot reuses the same snapshot-then-format path as the `METRICS`
/// verb, so `_total` keys are monotone across lines by construction —
/// the two invariants (`ts_ms` strictly increasing, `_total` monotone)
/// are what `tetris bench check` gates on the file.
fn scrape_loop(path: &str, secs: u64, ctx: &Ctx) {
    let mut file = match std::fs::OpenOptions::new().create(true).append(true).open(path) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("tetris serve: cannot open metrics scrape file {path}: {e}");
            return;
        }
    };
    let start = Instant::now();
    let period = Duration::from_secs(secs.max(1));
    let mut next = start;
    loop {
        let mut m = match metrics_line(ctx) {
            Json::Obj(m) => m,
            _ => return,
        };
        m.insert("ts_ms".to_string(), Json::Num(start.elapsed().as_secs_f64() * 1e3));
        if writeln!(file, "{}", Json::Obj(m)).is_err() {
            return;
        }
        next += period;
        while Instant::now() < next {
            if ctx.shutdown.load(Ordering::SeqCst) {
                return;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
    }
}

fn accept_loop(listener: TcpListener, ctx: Arc<Ctx>) {
    for stream in listener.incoming() {
        if ctx.shutdown.load(Ordering::SeqCst) {
            break; // the wake-up connection (or a late client) lands here
        }
        match stream {
            Ok(stream) => {
                let ctx = ctx.clone();
                let _ = std::thread::Builder::new()
                    .name("tetris-conn".into())
                    .spawn(move || {
                        let _ = handle_conn(stream, &ctx);
                    });
            }
            Err(_) => continue,
        }
    }
}

/// Per-connection protocol loop: a reader thread (this function) admits
/// work line by line; a writer thread emits one reply line per request
/// line, strictly in request order, so clients may pipeline freely.
fn handle_conn(stream: TcpStream, ctx: &Ctx) -> Result<()> {
    let reader = BufReader::new(stream.try_clone()?);
    let (order_tx, order_rx) = mpsc::channel::<mpsc::Receiver<String>>();
    let mut out = stream;
    let pending = ctx.pending.clone();
    let writer = std::thread::Builder::new().name("tetris-conn-write".into()).spawn(
        move || {
            let mut dead = false;
            for rx in order_rx {
                let line = rx.recv().unwrap_or_else(|_| {
                    JobResult::failure("", "internal: reply channel dropped")
                        .to_json()
                        .to_string()
                });
                // A gone client stops the writes but not the drain: the
                // pending counter must still reach zero.
                if !dead && writeln!(out, "{line}").is_err() {
                    dead = true;
                }
                let (lock, cv) = &*pending;
                *lock.lock().unwrap() -= 1;
                cv.notify_all();
            }
        },
    )?;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let (tx, rx) = mpsc::channel::<String>();
        *ctx.pending.0.lock().unwrap() += 1;
        let _ = order_tx.send(rx);
        match line {
            "STATS" => {
                let _ = tx.send(stats_line(ctx).to_string());
            }
            "METRICS" => {
                let _ = tx.send(metrics_line(ctx).to_string());
            }
            "SHUTDOWN" => {
                let mut ack = BTreeMap::new();
                ack.insert("ok".to_string(), Json::Bool(true));
                ack.insert("shutdown".to_string(), Json::Bool(true));
                let _ = tx.send(Json::Obj(ack).to_string());
                trigger_shutdown(&ctx.shutdown, &ctx.queue, ctx.addr);
            }
            job_line => handle_job_line(job_line, ctx, tx),
        }
    }
    drop(order_tx);
    let _ = writer.join();
    Ok(())
}

fn handle_job_line(line: &str, ctx: &Ctx, tx: mpsc::Sender<String>) {
    let spec = match JobSpec::parse_line(line) {
        Ok(spec) => spec,
        Err(e) => {
            ctx.stats.lock().unwrap().errors += 1;
            let _ = tx.send(JobResult::failure("", format!("{e}")).to_json().to_string());
            return;
        }
    };
    if crate::trace::enabled() {
        crate::trace::instant(
            "serve",
            "accept",
            &[("job", spec.id.as_str().into()), ("bench", spec.bench.as_str().into())],
        );
        // One flow per job, started at the accept instant and finished
        // exactly once at whichever reply ends the job's life — the
        // dispatcher's reply for admitted jobs, the local error/reject
        // reply otherwise.  `trace check` enforces the pairing.
        crate::trace::flow_start("serve", "job", crate::trace::flow_id(&spec.id), &[]);
    }
    let default_shape = match crate::stencil::spec::get(&spec.bench) {
        Some(_) => crate::bench::scaled_problem(&spec.bench, ctx.scale).0,
        None => {
            ctx.stats.lock().unwrap().errors += 1;
            let reply = JobResult::failure(&spec.id, format!("unknown bench {:?}", spec.bench));
            flow_finish_job(&spec.id);
            let _ = tx.send(reply.to_json().to_string());
            return;
        }
    };
    // Footprint check on the *declared* shape BEFORE any allocation: a
    // hostile `{"shape":[100000,100000]}` must be bounced by admission
    // arithmetic, never by an OOM abort.  Overflowing the byte count is
    // an automatic reject.
    let shape = spec.shape.as_deref().unwrap_or(&default_shape);
    let declared_bytes = shape
        .iter()
        .try_fold(1usize, |a, &n| a.checked_mul(n.max(1)))
        .and_then(|cells| cells.checked_mul(3 * 8));
    match declared_bytes {
        Some(b) if b <= ctx.queue.max_bytes => {}
        _ => {
            ctx.stats.lock().unwrap().rejected += 1;
            if crate::trace::enabled() {
                crate::trace::instant(
                    "serve",
                    "reject",
                    &[("job", spec.id.as_str().into()), ("retry_after_ms", 0u64.into())],
                );
            }
            let reply = JobResult::reject(
                &spec.id,
                format!(
                    "memory admission: shape {shape:?} needs more than the queue's {} bytes",
                    ctx.queue.max_bytes
                ),
                0,
            );
            flow_finish_job(&spec.id);
            let _ = tx.send(reply.to_json().to_string());
            return;
        }
    }
    let input = match spec.materialize(&default_shape) {
        Ok(input) => input,
        Err(e) => {
            ctx.stats.lock().unwrap().errors += 1;
            flow_finish_job(&spec.id);
            let _ = tx.send(JobResult::failure(&spec.id, format!("{e}")).to_json().to_string());
            return;
        }
    };
    let id = spec.id.clone();
    match ctx.queue.push(spec, input, tx.clone()) {
        Admission::Admitted(_) => {
            ctx.stats.lock().unwrap().submitted += 1;
        }
        Admission::Rejected { reason, retry_after_ms } => {
            ctx.stats.lock().unwrap().rejected += 1;
            let reply = JobResult::reject(&id, reason, retry_after_ms);
            flow_finish_job(&id);
            let _ = tx.send(reply.to_json().to_string());
        }
    }
}

/// Finish a serve `job` flow (started at the accept instant).  Recorded
/// before the reply is sent, so a client observing the reply line is
/// guaranteed the trace already holds the flow finish.
fn flow_finish_job(id: &str) {
    if crate::trace::enabled() {
        crate::trace::flow_finish("serve", "job", crate::trace::flow_id(id), &[]);
    }
}

/// One STATS reply.  Snapshot-then-format: each shared lock (queue
/// internals, the session registry, the stats mutex) is held only long
/// enough to clone the state out, and all JSON formatting happens after
/// release — so a STATS request can never stall the dispatchers'
/// per-job stats updates behind string building.
fn stats_line(ctx: &Ctx) -> Json {
    let depths = ctx.queue.depths();
    let inflight_bytes = ctx.queue.inflight_bytes();
    let closed = ctx.queue.is_closed();
    let metas = ctx.exec.session_meta();
    let stats = ctx.stats.lock().unwrap().clone();
    // every lock released — format below
    let mut m = BTreeMap::new();
    m.insert("ok".to_string(), Json::Bool(true));
    let mut q = BTreeMap::new();
    q.insert(
        "depths".to_string(),
        Json::Arr(depths.into_iter().map(|d| Json::Num(d as f64)).collect()),
    );
    q.insert("inflight_bytes".to_string(), Json::Num(inflight_bytes as f64));
    q.insert("closed".to_string(), Json::Bool(closed));
    m.insert("queue".to_string(), Json::Obj(q));
    let mut sessions = BTreeMap::new();
    for (key, meta) in metas {
        let mut s = BTreeMap::new();
        s.insert(
            "shares".to_string(),
            Json::Arr(meta.shares.iter().map(|&x| Json::Num(x as f64)).collect()),
        );
        s.insert("jobs".to_string(), Json::Num(meta.jobs as f64));
        s.insert("cache_hits".to_string(), Json::Num(meta.cache_hits as f64));
        s.insert("invalidations".to_string(), Json::Num(meta.invalidations as f64));
        s.insert("engine".to_string(), Json::Str(meta.engine.clone()));
        s.insert("tb".to_string(), Json::Num(meta.tb as f64));
        s.insert("planned".to_string(), Json::Bool(meta.planned));
        s.insert("overlap".to_string(), Json::Str(meta.overlap.clone()));
        sessions.insert(key, Json::Obj(s));
    }
    m.insert("sessions".to_string(), Json::Obj(sessions));
    m.insert("stats".to_string(), stats.to_json());
    Json::Obj(m)
}

/// One METRICS reply: the flat [`MetricsRegistry`] snapshot.  The
/// registry is built fresh per request from the *cumulative* stats plus
/// point-in-time queue/session gauges (same snapshot-then-format
/// discipline as [`stats_line`]), so successive snapshots from one
/// server have monotone `_total` counters by construction.
fn metrics_line(ctx: &Ctx) -> Json {
    let stats = ctx.stats.lock().unwrap().clone();
    let queued = ctx.queue.queued();
    let inflight_bytes = ctx.queue.inflight_bytes();
    let sessions = ctx.exec.session_meta().len();
    // every lock released — format below
    let mut reg = MetricsRegistry::new();
    reg.feed_serve_stats(&stats);
    reg.gauge_set("serve.queue_depth", queued as f64);
    reg.gauge_set("serve.queue_capacity", ctx.queue.max_jobs as f64);
    reg.gauge_set("serve.inflight_bytes", inflight_bytes as f64);
    reg.gauge_set("serve.sessions", sessions as f64);
    reg.snapshot_json()
}
