//! Spatially tiled engine: cache blocking on top of the fused rows.
//!
//! Splits the leading dimension into slabs sized against an L2 budget so
//! each slab's working set stays resident across the row sweep.  Still one
//! sweep per time step — the temporal reuse comes from `tessellate`.

use crate::stencil::{Field, StencilSpec};

use super::{rowwise, Engine, FlatTaps};

pub struct TiledEngine {
    /// Target working-set bytes per slab (default: 1 MiB, ~L2-sized).
    pub l2_budget: usize,
}

impl Default for TiledEngine {
    fn default() -> Self {
        TiledEngine { l2_budget: 1 << 20 }
    }
}

impl TiledEngine {
    /// Slab height along dim0 so slab+halo fits the budget.
    fn slab_rows(&self, spec: &StencilSpec, ext_shape: &[usize]) -> usize {
        let row_bytes: usize = ext_shape[1..].iter().product::<usize>() * 8;
        let rows = (self.l2_budget / row_bytes.max(1)).max(2 * spec.radius + 1);
        rows.min(ext_shape[0])
    }
}

impl Engine for TiledEngine {
    fn name(&self) -> &'static str {
        "tiled"
    }

    fn block(&self, spec: &StencilSpec, input: &Field, steps: usize) -> Field {
        let r = spec.radius;
        let mut cur = input.clone();
        for _ in 0..steps {
            let ext = cur.shape().to_vec();
            let core: Vec<usize> = ext.iter().map(|n| n - 2 * r).collect();
            let mut out = Field::zeros(&core);
            let taps = FlatTaps::build(spec, &ext);
            let slab = self.slab_rows(spec, &ext);
            // Process core rows in slabs of `slab` leading-dim rows.
            let mut x0 = 0usize;
            while x0 < core[0] {
                let x1 = (x0 + slab).min(core[0]);
                rowwise::step_range_dim0(&cur, spec, &taps, &mut out, x0, x1, true);
                x0 = x1;
            }
            let _ = r;
            cur = out;
        }
        cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{reference, spec};

    #[test]
    fn matches_reference_all_benchmarks() {
        let eng = TiledEngine::default();
        for s in spec::benchmarks() {
            let ext: Vec<usize> = (0..s.ndim).map(|_| 12 + 2 * s.radius * 2).collect();
            let u = Field::random(&ext, 11);
            let got = eng.block(&s, &u, 2);
            let want = reference::block(&u, &s, 2);
            assert!(got.allclose(&want, 1e-13, 1e-15), "{}", s.name);
        }
    }

    #[test]
    fn tiny_budget_forces_many_slabs() {
        let eng = TiledEngine { l2_budget: 64 }; // pathological
        let s = spec::get("heat2d").unwrap();
        let u = Field::random(&[20, 20], 12);
        let got = eng.block(&s, &u, 1);
        assert!(got.allclose(&reference::step(&u, &s), 1e-14, 0.0));
    }

    #[test]
    fn slab_rows_at_least_kernel_height() {
        let eng = TiledEngine { l2_budget: 1 };
        let s = spec::get("box2d25p").unwrap();
        assert!(eng.slab_rows(&s, &[100, 100]) >= 5);
    }
}
