//! Temporal-wavefront engine — **tetris-wave**.
//!
//! Same non-redundant diamond decomposition of the Tb time-block as
//! [`tessellate`](super::tessellate) (triangle pyramids + inverted gap
//! triangles = the trapezoid split of §4.1), but scheduled as a
//! dependency DAG on the work-stealing pool instead of two fork-join
//! phases: the gap tile at boundary `b` is released the moment its two
//! neighbouring pyramids finish, so phase B overlaps phase A along the
//! wavefront and no thread waits at a global barrier.  Tiles are
//! oversubscribed (≥ 2x threads when the domain allows) so irregular
//! tile costs — boundary tiles, cache effects, noisy cores — are
//! absorbed by stealing rather than serialized on the slowest chunk.
//!
//! Geometry (and therefore numerics) are byte-identical to tessellation:
//! only the schedule differs.

use std::sync::OnceLock;

use crate::analyze::model::wave_model;
use crate::coordinator::pool::TaskGraph;
use crate::stencil::{Field, StencilSpec};

use super::tessellate::{assemble, build_inverted, build_pyramid, tile_boundaries, Inner, Pyramid};
use super::Engine;

pub struct WavefrontEngine {
    pub threads: usize,
    /// Tile width override along dim 0; None = cache heuristic.
    pub tile_w: Option<usize>,
}

impl WavefrontEngine {
    pub fn new(threads: usize) -> Self {
        WavefrontEngine { threads: threads.max(1), tile_w: None }
    }
}

impl Engine for WavefrontEngine {
    fn name(&self) -> &'static str {
        "tetris-wave"
    }

    fn preferred_tb(&self) -> usize {
        4
    }

    fn block(&self, spec: &StencilSpec, input: &Field, steps: usize) -> Field {
        assert!(steps >= 1);
        let halo = spec.radius * steps;
        let ext = input.shape().to_vec();
        let core: Vec<usize> = ext.iter().map(|n| n - 2 * halo).collect();
        assert!(core.iter().all(|&n| n > 0), "input too small for Tb={steps}");
        let rest_cells: usize = ext[1..].iter().product::<usize>().max(1);
        // Oversubscribe tiles vs threads so the deque pool has slack to
        // steal when individual tiles run long.
        let min_tiles = if self.threads > 1 { 2 * self.threads } else { 1 };
        let bs = tile_boundaries(self.tile_w, ext[0], halo, rest_cells, steps, min_tiles);
        let ntiles = bs.len() - 1;
        let inner = Inner::Fused;

        // Task graph: A_k = pyramid of tile k (no deps); B_k = inverted
        // triangle at boundary k+1, released by {A_k, A_{k+1}}.  Deps and
        // access summaries come from the analyzable model (`wave_model`)
        // so the executed DAG is the one the race checker certifies.
        let model = wave_model(&bs, halo);
        let pyramid_cells: Vec<OnceLock<Pyramid>> = (0..ntiles).map(|_| OnceLock::new()).collect();
        let gap_cells: Vec<OnceLock<Field>> = (0..ntiles - 1).map(|_| OnceLock::new()).collect();
        {
            let mut g = TaskGraph::new();
            for k in 0..ntiles {
                let (cells, bsr) = (&pyramid_cells, &bs);
                g.add_with_access(
                    move || {
                        let p = build_pyramid(inner, spec, input, bsr[k], bsr[k + 1], steps);
                        let _ = cells[k].set(p);
                    },
                    model.deps[k].clone(),
                    model.accesses[k].clone(),
                );
            }
            for k in 0..ntiles - 1 {
                let (pyrs, gaps, bsr, extr) = (&pyramid_cells, &gap_cells, &bs, &ext);
                g.add_with_access(
                    move || {
                        let l = pyrs[k].get().expect("left pyramid ready");
                        let r = pyrs[k + 1].get().expect("right pyramid ready");
                        let f = build_inverted(inner, spec, input, l, r, bsr[k + 1], steps, extr);
                        let _ = gaps[k].set(f);
                    },
                    model.deps[ntiles + k].clone(),
                    model.accesses[ntiles + k].clone(),
                );
            }
            debug_assert_eq!(g.len(), model.len(), "wave model/graph drift");
            g.assert_race_free();
            g.run(self.threads);
        }

        let pyramids: Vec<Pyramid> = pyramid_cells.into_iter().map(|c| c.into_inner().expect("pyramid computed")).collect();
        let inverted: Vec<Field> = gap_cells.into_iter().map(|c| c.into_inner().expect("gap computed")).collect();
        assemble(&ext, halo, steps, &bs, &pyramids, &inverted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::tessellate::TessellateEngine;
    use crate::stencil::{reference, spec};

    #[test]
    fn matches_reference_all_benchmarks_all_steps() {
        for s in spec::benchmarks() {
            for steps in [1usize, 2, 4] {
                let mut ext: Vec<usize> = (0..s.ndim).map(|_| 8 + 2 * s.radius * steps).collect();
                ext[0] = 40 + 2 * s.radius * steps; // several tiles along dim0
                let u = Field::random(&ext, 33);
                for threads in [1usize, 3, 8] {
                    let eng = WavefrontEngine { threads, tile_w: Some(2 * s.radius * steps) };
                    let got = eng.block(&s, &u, steps);
                    let want = reference::block(&u, &s, steps);
                    assert!(
                        got.allclose(&want, 1e-12, 1e-14),
                        "{} steps={steps} threads={threads} maxdiff={}",
                        s.name,
                        got.max_abs_diff(&want)
                    );
                }
            }
        }
    }

    #[test]
    fn agrees_with_tessellate_bitwise() {
        // Same per-cell arithmetic whatever the tiling: floats must match.
        let s = spec::get("box2d25p").unwrap();
        let u = Field::random(&[52, 28], 34);
        let tile_w = Some(12);
        let a = TessellateEngine { inner: Inner::Fused, threads: 2, tile_w }.block(&s, &u, 2);
        let b = WavefrontEngine { threads: 4, tile_w }.block(&s, &u, 2);
        assert!(a.allclose(&b, 0.0, 0.0), "maxdiff={}", a.max_abs_diff(&b));
    }

    #[test]
    fn single_tile_degenerates_to_trapezoid() {
        let s = spec::get("heat2d").unwrap();
        let u = Field::random(&[20, 20], 35);
        let eng = WavefrontEngine { threads: 2, tile_w: Some(1000) };
        let got = eng.block(&s, &u, 3);
        assert!(got.allclose(&reference::block(&u, &s, 3), 1e-13, 0.0));
    }

    #[test]
    fn many_threads_few_tiles() {
        let s = spec::get("heat1d").unwrap();
        let u = Field::random(&[64], 36);
        let eng = WavefrontEngine { threads: 16, tile_w: Some(8) };
        let got = eng.block(&s, &u, 2);
        assert!(got.allclose(&reference::block(&u, &s, 2), 1e-13, 0.0));
    }

    #[test]
    fn oversubscription_defaults_sane() {
        // Default heuristic with many threads on a small domain must not
        // create tiles below the 2*halo minimum.
        let s = spec::get("heat2d").unwrap();
        let u = Field::random(&[30, 30], 37);
        let got = WavefrontEngine::new(12).block(&s, &u, 3);
        assert!(got.allclose(&reference::block(&u, &s, 3), 1e-13, 0.0));
    }
}
