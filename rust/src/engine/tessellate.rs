//! Tessellate Tiling — the paper's §4.1 Locality Enhancer, two phases,
//! zero redundant computation.
//!
//! The extended input is cut into leading-dimension slabs (tiles).  Phase
//! A computes each tile's *triangle tetromino*: `Tb` successive valid
//! steps confined to the tile, each shrinking by `radius`, producing a
//! shrinking pyramid of time levels (all levels retained — they are the
//! triangle's slopes).  Phase B fills the *inverted triangles* between
//! adjacent tiles: level `t` of the gap at boundary `b` spans
//! `[b - r*t, b + r*t)` and is computed from level `t-1` of the gap plus
//! `r`-wide flanks of the two neighbouring pyramids.  Both phases are
//! embarrassingly parallel within themselves, which is exactly the
//! concurrency claim of the paper ("all tetrominoes between
//! synchronizations can execute concurrently without redundant
//! computation").
//!
//! With `fused` inner rows and thread parallelism this is **Tetris
//! (CPU)**; with tap-outer rows and one thread it is the bare
//! "Tessellate Tiling" rung of the Fig-12 breakdown.  The geometry
//! helpers ([`build_pyramid`], [`build_inverted`], [`tile_boundaries`],
//! [`assemble`]) are shared with the dependency-driven
//! [`wavefront`](super::wavefront) engine, which runs the same diamond
//! decomposition without the phase barrier.

use crate::stencil::{Field, StencilSpec};

use super::{rowwise, Engine, FlatTaps};

/// Inner-loop strategy for one valid step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Inner {
    /// Tap-outer axpy rows (pre-swizzling rung of Fig 12).
    Axpy,
    /// Fused single-pass rows (Vector Skewed Swizzling adaptation).
    Fused,
}

/// One full valid step with the chosen inner strategy.
pub(crate) fn step_once(inner: Inner, spec: &StencilSpec, f: &Field) -> Field {
    let taps = FlatTaps::build(spec, f.shape());
    match inner {
        Inner::Axpy => rowwise::axpy_step(f, spec, &taps),
        Inner::Fused => rowwise::fused_step(f, spec, &taps),
    }
}

/// Tile boundaries along dim 0 of the extended array.  The default width
/// targets an L2-sized pyramid: tile_w x rest_cells x 8 B x (steps+1
/// levels) ~ 512 KiB, so phase A stays cache-resident and the per-tile
/// bookkeeping amortizes (perf pass: the old fixed 256-element width made
/// 1-D tessellation slower than naive).  `min_tiles` lets dependency-
/// driven schedulers oversubscribe the pool with smaller tiles so
/// stealing has slack; it only adjusts the heuristic — an explicit
/// `tile_w` override wins — and every tile keeps width >= 2*halo.
pub(crate) fn tile_boundaries(
    tile_w: Option<usize>,
    ext0: usize,
    halo: usize,
    rest_cells: usize,
    steps: usize,
    min_tiles: usize,
) -> Vec<usize> {
    let min_w = (2 * halo).max(1);
    let budget_bytes = 512 << 10;
    let auto_w = budget_bytes / (rest_cells.max(1) * 8 * (steps + 1));
    let want_w = tile_w.unwrap_or(auto_w).max(min_w);
    let mut ntiles = (ext0 / want_w).max(1);
    if tile_w.is_none() {
        ntiles = ntiles.max(min_tiles);
    }
    // Every tile keeps width >= min_w because ntiles <= ext0 / min_w.
    let ntiles = ntiles.min((ext0 / min_w).max(1));
    let mut bs = Vec::with_capacity(ntiles + 1);
    for i in 0..=ntiles {
        bs.push(i * ext0 / ntiles);
    }
    bs
}

/// Phase-A pyramid for the tile [x0, x1): `levels[t]` (t >= 1) covers
/// dim0 `[x0 + r*t, x1 - r*t)` and rest dims `[r*t, Nj - r*t)`.  Level 0
/// is NOT materialized (perf pass: the tile copy doubled HBM traffic);
/// level 1 is computed straight off the shared input with offset rows.
pub(crate) struct Pyramid {
    /// levels[t-1] = time level t, for t in 1..=steps.
    pub(crate) levels: Vec<Field>,
    pub(crate) x0: usize,
}

impl Pyramid {
    pub(crate) fn level(&self, t: usize) -> &Field {
        debug_assert!(t >= 1);
        &self.levels[t - 1]
    }
}

pub(crate) fn build_pyramid(
    inner: Inner,
    spec: &StencilSpec,
    input: &Field,
    x0: usize,
    x1: usize,
    steps: usize,
) -> Pyramid {
    let taps = FlatTaps::build(spec, input.shape());
    let fused = inner == Inner::Fused;
    let mut levels = vec![rowwise::fused_step_slab(input, spec, &taps, x0, x1, fused)];
    for _ in 1..steps {
        let next = step_once(inner, spec, levels.last().unwrap());
        levels.push(next);
    }
    Pyramid { levels, x0 }
}

/// Phase-B inverted triangle at boundary `b` between pyramids `l`/`rp`.
/// Returns the final-level field covering dim0 `[b - H, b + H)` (ext
/// coordinates), rest dims equal to the core extent.
#[allow(clippy::too_many_arguments)]
pub(crate) fn build_inverted(
    inner: Inner,
    spec: &StencilSpec,
    input: &Field,
    l: &Pyramid,
    rp: &Pyramid,
    b: usize,
    steps: usize,
    ext: &[usize],
) -> Field {
    let r = spec.radius;
    let nd = ext.len();
    let input_taps = FlatTaps::build(spec, input.shape());
    let fused = inner == Inner::Fused;
    // Level 1 of the gap straight off the input (level 0 is virtual).
    let mut inv: Field = rowwise::fused_step_slab(input, spec, &input_taps, b - 2 * r, b + 2 * r, fused);
    for t in 2..=steps {
        // Source buffer at level t-1: dim0 [b - r*(t+1), b + r*(t+1)),
        // rest dims [r*(t-1), Nj - r*(t-1)).
        let rest: Vec<usize> = ext[1..].iter().map(|n| n - 2 * r * (t - 1)).collect();
        let mut buf_shape = vec![2 * r * (t + 1)];
        buf_shape.extend(&rest);
        let mut buf = Field::zeros(&buf_shape);

        // Left flank from l.level(t-1): dim0 [b - r*(t+1), b - r*(t-1)).
        let lf = l.level(t - 1); // origin dim0 = l.x0 + r*(t-1)
        let l_origin = l.x0 + r * (t - 1);
        let mut off = vec![b - r * (t + 1) - l_origin];
        off.extend(vec![0usize; nd - 1]);
        let mut shp = vec![2 * r];
        shp.extend(&rest);
        buf.paste(&vec![0; nd], &lf.extract(&off, &shp));

        // Middle from inv level t-1: dim0 [b - r*(t-1), b + r*(t-1)).
        let mut o = vec![2 * r];
        o.extend(vec![0usize; nd - 1]);
        buf.paste(&o, &inv);

        // Right flank from rp.level(t-1): dim0 [b + r*(t-1), b + r*(t+1)).
        let rf = rp.level(t - 1); // origin dim0 = rp.x0 + r*(t-1) = b + r*(t-1)
        let mut off_r = vec![0usize; nd];
        off_r[0] = 0;
        let mut shp_r = vec![2 * r];
        shp_r.extend(&rest);
        let mut dst_r = vec![2 * r * t];
        dst_r.extend(vec![0usize; nd - 1]);
        buf.paste(&dst_r, &rf.extract(&off_r, &shp_r));

        inv = step_once(inner, spec, &buf);
    }
    inv
}

/// Assemble the output core from pyramid tops and gap triangles.
pub(crate) fn assemble(ext: &[usize], halo: usize, steps: usize, bs: &[usize], pyramids: &[Pyramid], inverted: &[Field]) -> Field {
    let core: Vec<usize> = ext.iter().map(|n| n - 2 * halo).collect();
    let mut out = Field::zeros(&core);
    for p in pyramids {
        let top = p.level(steps); // dim0 [x0 + H, x1 - H)
        if top.shape().iter().any(|&n| n == 0) {
            continue;
        }
        let mut off = vec![p.x0]; // out dim0 = ext dim0 - H
        off.extend(vec![0usize; ext.len() - 1]);
        out.paste(&off, top);
    }
    for (k, f) in inverted.iter().enumerate() {
        let b = bs[k + 1];
        let mut off = vec![b - 2 * halo]; // [b - H, b + H) - H
        off.extend(vec![0usize; ext.len() - 1]);
        out.paste(&off, f);
    }
    out
}

pub struct TessellateEngine {
    pub inner: Inner,
    pub threads: usize,
    /// Tile width along dim 0; None = cache heuristic.
    pub tile_w: Option<usize>,
}

impl TessellateEngine {
    /// Bare tessellation: scalar-ish rows, single thread (Fig 12 rung 2).
    pub fn scalar() -> Self {
        TessellateEngine { inner: Inner::Axpy, threads: 1, tile_w: None }
    }

    /// Tetris (CPU): tessellation + fused rows + multicore.
    pub fn tetris(threads: usize) -> Self {
        TessellateEngine { inner: Inner::Fused, threads: threads.max(1), tile_w: None }
    }

    fn boundaries(&self, ext0: usize, halo: usize, rest_cells: usize, steps: usize) -> Vec<usize> {
        tile_boundaries(self.tile_w, ext0, halo, rest_cells, steps, 1)
    }
}

impl Engine for TessellateEngine {
    fn name(&self) -> &'static str {
        match (self.inner, self.threads) {
            (Inner::Axpy, _) => "tessellate",
            (Inner::Fused, _) => "tetris-cpu",
        }
    }

    fn preferred_tb(&self) -> usize {
        4
    }

    fn block(&self, spec: &StencilSpec, input: &Field, steps: usize) -> Field {
        assert!(steps >= 1);
        let r = spec.radius;
        let halo = r * steps;
        let ext = input.shape().to_vec();
        let core: Vec<usize> = ext.iter().map(|n| n - 2 * halo).collect();
        assert!(core.iter().all(|&n| n > 0), "input too small for Tb={steps}");
        let rest_cells: usize = ext[1..].iter().product::<usize>().max(1);
        let bs = self.boundaries(ext[0], halo, rest_cells, steps);
        let ntiles = bs.len() - 1;

        // ---- Phase A: triangle pyramids (work-stealing over tiles) -----
        let pyramids: Vec<Pyramid> =
            super::parallel_map(self.threads, ntiles, |k| build_pyramid(self.inner, spec, input, bs[k], bs[k + 1], steps));

        // ---- Phase B: inverted triangles (work-stealing, boundaries) ---
        let inverted: Vec<Field> = super::parallel_map(self.threads, ntiles - 1, |k| {
            build_inverted(self.inner, spec, input, &pyramids[k], &pyramids[k + 1], bs[k + 1], steps, &ext)
        });

        // ---- Assemble the output core ----------------------------------
        assemble(&ext, halo, steps, &bs, &pyramids, &inverted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{reference, spec};

    #[test]
    fn matches_reference_all_benchmarks_all_steps() {
        for s in spec::benchmarks() {
            for steps in [1usize, 2, 4] {
                let mut ext: Vec<usize> = (0..s.ndim).map(|_| 8 + 2 * s.radius * steps).collect();
                ext[0] = 40 + 2 * s.radius * steps; // several tiles along dim0
                let u = Field::random(&ext, 21);
                for eng in [
                    TessellateEngine { inner: Inner::Fused, threads: 1, tile_w: Some(2 * s.radius * steps) },
                    TessellateEngine { inner: Inner::Axpy, threads: 1, tile_w: Some(3 * s.radius * steps) },
                    TessellateEngine::tetris(3),
                ] {
                    let got = eng.block(&s, &u, steps);
                    let want = reference::block(&u, &s, steps);
                    assert!(
                        got.allclose(&want, 1e-12, 1e-14),
                        "{} steps={steps} inner={:?} thr={} maxdiff={}",
                        s.name,
                        eng.inner,
                        eng.threads,
                        got.max_abs_diff(&want)
                    );
                }
            }
        }
    }

    #[test]
    fn single_tile_degenerates_to_trapezoid() {
        let s = spec::get("heat2d").unwrap();
        let u = Field::random(&[20, 20], 22);
        let eng = TessellateEngine { inner: Inner::Fused, threads: 1, tile_w: Some(1000) };
        let got = eng.block(&s, &u, 3);
        assert!(got.allclose(&reference::block(&u, &s, 3), 1e-13, 0.0));
    }

    #[test]
    fn boundaries_respect_min_width() {
        let eng = TessellateEngine::tetris(2);
        let bs = eng.boundaries(100, 10, 1, 2);
        for w in bs.windows(2) {
            assert!(w[1] - w[0] >= 20, "{bs:?}");
        }
        assert_eq!(*bs.first().unwrap(), 0);
        assert_eq!(*bs.last().unwrap(), 100);
    }

    #[test]
    fn min_tiles_oversubscribes_but_respects_min_width() {
        // min_tiles asks for 8 tiles; min width 20 caps it at 5.
        let bs = tile_boundaries(None, 100, 10, 1, 2, 8);
        assert_eq!(bs.len() - 1, 5);
        for w in bs.windows(2) {
            assert!(w[1] - w[0] >= 20, "{bs:?}");
        }
    }

    #[test]
    fn parallel_helper_preserves_order() {
        let v = crate::engine::parallel_map(4, 13, |k| k * k);
        assert_eq!(v, (0..13).map(|k| k * k).collect::<Vec<_>>());
    }

    #[test]
    fn many_threads_few_tiles() {
        let s = spec::get("heat1d").unwrap();
        let u = Field::random(&[64], 23);
        let eng = TessellateEngine { inner: Inner::Fused, threads: 16, tile_w: Some(8) };
        let got = eng.block(&s, &u, 2);
        assert!(got.allclose(&reference::block(&u, &s, 2), 1e-13, 0.0));
    }
}
