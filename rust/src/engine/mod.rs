//! Optimized CPU stencil engines — the paper's §3.1 + §4.1 contribution.
//!
//! Every engine implements [`Engine`]: the same valid-mode block contract
//! as the Pallas kernels and the PJRT artifacts, so the coordinator can
//! mix-and-match workers and the test suite can diff any engine against
//! the reference oracle.
//!
//! Engines (paper Table 2 mapping):
//!   naive       — per-cell scalar sweep ("Naive" baseline)
//!   autovec     — row-wise axpy sweeps, compiler-vectorized
//!   simd        — fused single-pass rows: the Vector-Skewed-Swizzling
//!                 adaptation (one write pass, conflict-free tap loads)
//!   tiled       — spatial cache tiling on top of `simd` rows
//!   tessellate  — two-phase non-redundant temporal tessellation (§4.1)
//!                 with optional thread parallelism: Tetris (CPU)
//!   wavefront   — the same diamond decomposition scheduled as a
//!                 dependency DAG on the work-stealing pool: tetris-wave

pub mod autovec;
pub mod naive;
pub mod rowwise;
pub mod simd;
pub mod tessellate;
pub mod tiled;
pub mod wavefront;

use crate::stencil::{Field, StencilSpec};

/// A stencil executor with the valid-mode block contract:
/// input shape = core + 2*radius*steps per dim; output shape = core.
pub trait Engine: Send + Sync {
    fn name(&self) -> &'static str;

    /// Advance `steps` fused steps (valid mode).
    fn block(&self, spec: &StencilSpec, input: &Field, steps: usize) -> Field;

    /// Steps the engine prefers to fuse per block (temporal engines > 1).
    fn preferred_tb(&self) -> usize {
        1
    }
}

/// Flat taps precomputed for a given extended-array stride layout:
/// (flat_offset_relative_to_core_origin, coefficient).
#[derive(Clone, Debug)]
pub struct FlatTaps {
    pub offs: Vec<isize>,
    pub coeffs: Vec<f64>,
    /// Innermost-dim tap reach (for segment bounds checking).
    pub radius: usize,
}

impl FlatTaps {
    /// Build taps for an extended array with `ext_shape`, where the core
    /// origin sits at `+radius` in every dimension.
    pub fn build(spec: &StencilSpec, ext_shape: &[usize]) -> FlatTaps {
        let mut strides = vec![1isize; ext_shape.len()];
        for i in (0..ext_shape.len().saturating_sub(1)).rev() {
            strides[i] = strides[i + 1] * ext_shape[i + 1] as isize;
        }
        let r = spec.radius as i64;
        let (offs, coeffs) = spec.taps();
        let flat: Vec<isize> = offs
            .iter()
            .map(|off| {
                off.iter()
                    .zip(&strides)
                    .map(|(&o, &s)| (o + r) as isize * s)
                    .sum()
            })
            .collect();
        FlatTaps { offs: flat, coeffs, radius: spec.radius }
    }
}

/// Map `k in 0..n` over up to `threads` workers, preserving order.  The
/// shared parallel primitive for the tessellation phases and every
/// tile-parallel baseline.  Backed by the work-stealing deque pool
/// ([`crate::coordinator::pool::steal_map`]): workers self-schedule one
/// index at a time, so irregular tile costs no longer serialize on the
/// slowest even chunk.
pub fn parallel_map<T: Send>(threads: usize, n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    crate::coordinator::pool::steal_map(threads, n, f)
}

/// Registry of all CPU engines by CLI name.
pub fn by_name(name: &str, threads: usize) -> Option<Box<dyn Engine>> {
    match name {
        "naive" => Some(Box::new(naive::NaiveEngine)),
        "autovec" => Some(Box::new(autovec::AutoVecEngine)),
        "simd" => Some(Box::new(simd::SimdEngine)),
        "tiled" => Some(Box::new(tiled::TiledEngine::default())),
        "tessellate" => Some(Box::new(tessellate::TessellateEngine::scalar())),
        "tetris-cpu" => Some(Box::new(tessellate::TessellateEngine::tetris(threads))),
        "tetris-wave" => Some(Box::new(wavefront::WavefrontEngine::new(threads))),
        _ => None,
    }
}

/// All engine names, for CLI help and sweep benches.
pub const ENGINE_NAMES: &[&str] = &["naive", "autovec", "simd", "tiled", "tessellate", "tetris-cpu", "tetris-wave"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{reference, spec};

    /// Every engine must agree with the oracle on every benchmark.
    #[test]
    fn engines_match_reference() {
        for name in ENGINE_NAMES {
            let eng = by_name(name, 2).unwrap();
            for s in spec::benchmarks() {
                for steps in [1usize, 2, 3] {
                    let core = 10usize;
                    let ext: Vec<usize> =
                        (0..s.ndim).map(|_| core + 2 * s.radius * steps).collect();
                    let u = Field::random(&ext, 7);
                    let got = eng.block(&s, &u, steps);
                    let want = reference::block(&u, &s, steps);
                    assert!(
                        got.allclose(&want, 1e-12, 1e-14),
                        "{name} vs ref: {} steps={steps} maxdiff={}",
                        s.name,
                        got.max_abs_diff(&want)
                    );
                }
            }
        }
    }

    #[test]
    fn flat_taps_center_only() {
        let s = spec::get("heat1d").unwrap();
        let taps = FlatTaps::build(&s, &[10]);
        // offsets sorted: -1, 0, 1 -> flat 0, 1, 2
        assert_eq!(taps.offs, vec![0, 1, 2]);
    }

    #[test]
    fn flat_taps_2d() {
        let s = spec::get("heat2d").unwrap();
        let taps = FlatTaps::build(&s, &[8, 16]);
        // sorted offsets: (-1,0),(0,-1),(0,0),(0,1),(1,0)
        assert_eq!(taps.offs, vec![1, 16, 17, 18, 33]);
    }

    #[test]
    fn by_name_unknown() {
        assert!(by_name("bogus", 1).is_none());
    }
}
