//! SIMD engine — the Vector-Skewed-Swizzling adaptation (paper §3.1).
//!
//! Fused single-pass rows: an 8-slot register block accumulates every tap
//! before one store, so (a) the output is written once per step instead of
//! `points` times, and (b) every tap load is a contiguous slice whose
//! elements line up with the accumulator slots — the "conflict-free
//! pipeline" property that skewed tetrominoes buy on AVX2 (no cross-lane
//! permutes; see DESIGN.md §Hardware-Adaptation).

use crate::stencil::{Field, StencilSpec};

use super::{rowwise, Engine, FlatTaps};

pub struct SimdEngine;

impl Engine for SimdEngine {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn block(&self, spec: &StencilSpec, input: &Field, steps: usize) -> Field {
        let mut cur = input.clone();
        for _ in 0..steps {
            let taps = FlatTaps::build(spec, cur.shape());
            cur = rowwise::fused_step(&cur, spec, &taps);
        }
        cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{reference, spec};

    #[test]
    fn matches_reference_all_benchmarks() {
        for s in spec::benchmarks() {
            let ext: Vec<usize> = (0..s.ndim).map(|_| 13 + 2 * s.radius * 2).collect();
            let u = Field::random(&ext, 8);
            let got = SimdEngine.block(&s, &u, 2);
            let want = reference::block(&u, &s, 2);
            assert!(got.allclose(&want, 1e-13, 1e-15), "{}", s.name);
        }
    }

    #[test]
    fn single_step_odd_width() {
        let s = spec::get("star1d5p").unwrap();
        let u = Field::random(&[23], 9);
        let got = SimdEngine.block(&s, &u, 1);
        assert!(got.allclose(&reference::step(&u, &s), 1e-14, 0.0));
    }
}
