//! Auto-vectorization engine (paper baseline "Auto Vec. [35]").
//!
//! Row-wise tap-outer axpy sweeps: idiomatic loops the compiler
//! vectorizes, but the output row is written `points` times per step and
//! there is no temporal reuse — exactly the rung the paper's skewed
//! swizzling + tessellation improve on.

use crate::stencil::{Field, StencilSpec};

use super::{rowwise, Engine, FlatTaps};

pub struct AutoVecEngine;

impl Engine for AutoVecEngine {
    fn name(&self) -> &'static str {
        "autovec"
    }

    fn block(&self, spec: &StencilSpec, input: &Field, steps: usize) -> Field {
        let mut cur = input.clone();
        for _ in 0..steps {
            let taps = FlatTaps::build(spec, cur.shape());
            cur = rowwise::axpy_step(&cur, spec, &taps);
        }
        cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{reference, spec};

    #[test]
    fn matches_reference_all_benchmarks() {
        for s in spec::benchmarks() {
            let ext: Vec<usize> = (0..s.ndim).map(|_| 9 + 2 * s.radius * 2).collect();
            let u = Field::random(&ext, 6);
            let got = AutoVecEngine.block(&s, &u, 2);
            let want = reference::block(&u, &s, 2);
            assert!(got.allclose(&want, 1e-13, 1e-15), "{}", s.name);
        }
    }
}
