//! Row-decomposed inner loops shared by the CPU engines.
//!
//! A valid-mode step over an N-d array decomposes into independent 1-d
//! output rows (the innermost dimension); every tap contributes one
//! *contiguous* source segment per row.  Two inner-loop strategies:
//!
//! * [`axpy_step`] — tap-outer: one axpy pass over the row per tap.
//!   Simple, vectorizes, but writes the output row `points` times.
//! * [`fused_step`] — the Vector-Skewed-Swizzling adaptation: cell-block
//!   outer, taps inner, accumulating in a register block and writing the
//!   row exactly once.  No gather, no cross-lane shuffle: every tap load
//!   is a contiguous slice aligned to the accumulator slots (the paper's
//!   "conflict-free vectorized pipeline" — see DESIGN.md).

use crate::stencil::{Field, StencilSpec};

use super::FlatTaps;

/// y += c * x over contiguous slices (compiler-vectorized FMA chain).
#[inline]
pub fn axpy(dst: &mut [f64], c: f64, src: &[f64]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += c * s;
    }
}

/// Iterate output rows of a valid step: calls `f(dst_row_start, src_base)`
/// where `src_base` is the flat index in the extended array of the cell
/// that tap-offset 0 reads for the row's first output.
pub fn for_each_row(
    ext_shape: &[usize],
    core_shape: &[usize],
    mut f: impl FnMut(usize, usize),
) {
    let nd = ext_shape.len();
    if core_shape.iter().any(|&n| n == 0) {
        return; // empty core: nothing to iterate
    }
    let mut ext_strides = vec![1usize; nd];
    for i in (0..nd - 1).rev() {
        ext_strides[i] = ext_strides[i + 1] * ext_shape[i + 1];
    }
    let mut core_strides = vec![1usize; nd];
    for i in (0..nd - 1).rev() {
        core_strides[i] = core_strides[i + 1] * core_shape[i + 1];
    }
    let outer: usize = core_shape[..nd - 1].iter().product::<usize>().max(1);
    let mut idx = vec![0usize; nd.saturating_sub(1)];
    for _ in 0..outer {
        let mut src = 0usize;
        let mut dst = 0usize;
        for k in 0..nd - 1 {
            src += idx[k] * ext_strides[k];
            dst += idx[k] * core_strides[k];
        }
        f(dst, src);
        for k in (0..nd - 1).rev() {
            idx[k] += 1;
            if idx[k] < core_shape[k] {
                break;
            }
            idx[k] = 0;
        }
    }
}

/// One valid step, tap-outer axpy strategy.
pub fn axpy_step(src: &Field, spec: &StencilSpec, taps: &FlatTaps) -> Field {
    let r = spec.radius;
    let core: Vec<usize> = src.shape().iter().map(|n| n - 2 * r).collect();
    let w = *core.last().unwrap();
    let mut out = Field::zeros(&core);
    let sdata = src.data();
    let odata = out.data_mut();
    for_each_row(src.shape(), &core, |dst0, src0| {
        let dst_row = &mut odata[dst0..dst0 + w];
        for (off, c) in taps.offs.iter().zip(&taps.coeffs) {
            let s0 = (src0 as isize + off) as usize;
            axpy(dst_row, *c, &sdata[s0..s0 + w]);
        }
    });
    out
}

const BLK: usize = 8;

/// One valid step over a row: fused single-write-pass inner loop.
#[inline]
pub fn fused_row(dst_row: &mut [f64], sdata: &[f64], src0: usize, taps: &FlatTaps) {
    let w = dst_row.len();
    let mut x = 0usize;
    // 8-wide register blocks: accumulate all taps, write once.
    while x + BLK <= w {
        let mut acc = [0.0f64; BLK];
        for (off, c) in taps.offs.iter().zip(&taps.coeffs) {
            let s0 = (src0 as isize + off) as usize + x;
            let seg = &sdata[s0..s0 + BLK];
            for j in 0..BLK {
                acc[j] += c * seg[j];
            }
        }
        dst_row[x..x + BLK].copy_from_slice(&acc);
        x += BLK;
    }
    // scalar tail
    while x < w {
        let mut acc = 0.0;
        for (off, c) in taps.offs.iter().zip(&taps.coeffs) {
            let s0 = (src0 as isize + off) as usize + x;
            acc += c * sdata[s0];
        }
        dst_row[x] = acc;
        x += 1;
    }
}

/// One valid step, fused strategy (single write pass per row).
pub fn fused_step(src: &Field, spec: &StencilSpec, taps: &FlatTaps) -> Field {
    let r = spec.radius;
    let core: Vec<usize> = src.shape().iter().map(|n| n - 2 * r).collect();
    let w = *core.last().unwrap();
    let mut out = Field::zeros(&core);
    let sdata = src.data();
    let odata = out.data_mut();
    for_each_row(src.shape(), &core, |dst0, src0| {
        fused_row(&mut odata[dst0..dst0 + w], sdata, src0, taps);
    });
    out
}

/// One valid step restricted to dim-0 output range [lo, hi), writing into
/// an existing core-shaped `dst` (other cells untouched).  Handles the 1-D
/// case (where dim 0 *is* the row dimension) correctly.
pub fn step_range_dim0(
    src: &Field,
    spec: &StencilSpec,
    taps: &FlatTaps,
    dst: &mut Field,
    lo: usize,
    hi: usize,
    fused: bool,
) {
    let r = spec.radius;
    let core: Vec<usize> = src.shape().iter().map(|n| n - 2 * r).collect();
    debug_assert_eq!(dst.shape(), &core[..]);
    debug_assert!(hi <= core[0]);
    if lo >= hi {
        return;
    }
    let sdata = src.data();
    let nd = src.ndim();
    if nd == 1 {
        let odata = dst.data_mut();
        let w = hi - lo;
        if fused {
            fused_row_off(&mut odata[lo..hi], sdata, lo, taps);
        } else {
            for (off, c) in taps.offs.iter().zip(&taps.coeffs) {
                let s0 = (lo as isize + off) as usize;
                axpy(&mut odata[lo..hi], *c, &sdata[s0..s0 + w]);
            }
        }
        return;
    }
    let w = *core.last().unwrap();
    let mut sub_ext = src.shape().to_vec();
    sub_ext[0] = (hi - lo) + 2 * r;
    let mut sub_core = core.clone();
    sub_core[0] = hi - lo;
    let ext_stride0: usize = src.shape()[1..].iter().product();
    let core_stride0: usize = core[1..].iter().product();
    let odata = dst.data_mut();
    for_each_row(&sub_ext, &sub_core, |dst0, src0| {
        let d = dst0 + lo * core_stride0;
        let s = src0 + lo * ext_stride0;
        if fused {
            fused_row(&mut odata[d..d + w], sdata, s, taps);
        } else {
            for (off, c) in taps.offs.iter().zip(&taps.coeffs) {
                let s0 = (s as isize + off) as usize;
                axpy(&mut odata[d..d + w], *c, &sdata[s0..s0 + w]);
            }
        }
    });
}

/// fused_row variant whose source base is a plain element offset (1-D).
#[inline]
fn fused_row_off(dst_row: &mut [f64], sdata: &[f64], src0: usize, taps: &FlatTaps) {
    fused_row(dst_row, sdata, src0, taps);
}

/// One valid step of the dim-0 slab [x0, x1) of `src`, WITHOUT
/// materializing the slab: returns a fresh field of shape
/// ((x1-x0) - 2r, rest - 2r).  Equivalent to
/// `fused_step(&src.extract(slab))` minus the extract copy — the
/// level-0-copy elimination of the tessellation perf pass.
pub fn fused_step_slab(
    src: &Field,
    spec: &StencilSpec,
    taps: &FlatTaps,
    x0: usize,
    x1: usize,
    fused: bool,
) -> Field {
    let r = spec.radius;
    debug_assert!(x1 <= src.shape()[0] && x1 - x0 >= 2 * r);
    let mut out_shape = vec![(x1 - x0) - 2 * r];
    out_shape.extend(src.shape()[1..].iter().map(|n| n - 2 * r));
    let mut out = Field::zeros(&out_shape);
    if out_shape.iter().any(|&n| n == 0) {
        return out;
    }
    let mut sub_ext = src.shape().to_vec();
    sub_ext[0] = x1 - x0;
    let ext_stride0: usize = src.shape()[1..].iter().product::<usize>().max(1);
    let w = *out_shape.last().unwrap();
    let sdata = src.data();
    let odata = out.data_mut();
    for_each_row(&sub_ext, &out_shape, |dst0, src0| {
        let s = src0 + x0 * ext_stride0;
        if fused {
            fused_row(&mut odata[dst0..dst0 + w], sdata, s, taps);
        } else {
            for (off, c) in taps.offs.iter().zip(&taps.coeffs) {
                let s0 = (s as isize + off) as usize;
                axpy(&mut odata[dst0..dst0 + w], *c, &sdata[s0..s0 + w]);
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{reference, spec};

    #[test]
    fn axpy_basic() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(&mut y, 2.0, &[10.0, 20.0, 30.0]);
        assert_eq!(y, vec![21.0, 42.0, 63.0]);
    }

    #[test]
    fn both_strategies_match_reference() {
        for s in spec::benchmarks() {
            let ext: Vec<usize> = (0..s.ndim).map(|_| 11 + 2 * s.radius).collect();
            let u = Field::random(&ext, 3);
            let taps = FlatTaps::build(&s, &ext);
            let want = reference::step(&u, &s);
            let a = axpy_step(&u, &s, &taps);
            let f = fused_step(&u, &s, &taps);
            assert!(a.allclose(&want, 1e-13, 1e-15), "axpy {}", s.name);
            assert!(f.allclose(&want, 1e-13, 1e-15), "fused {}", s.name);
        }
    }

    #[test]
    fn fused_handles_tail() {
        // width not a multiple of the register block
        let s = spec::get("heat1d").unwrap();
        let u = Field::random(&[13], 4);
        let taps = FlatTaps::build(&s, &[13]);
        let want = reference::step(&u, &s);
        assert!(fused_step(&u, &s, &taps).allclose(&want, 1e-14, 0.0));
    }

    #[test]
    fn for_each_row_counts() {
        let mut rows = 0;
        for_each_row(&[6, 8, 10], &[4, 6, 8], |_, _| rows += 1);
        assert_eq!(rows, 4 * 6);
    }
}
