//! Naive engine — the paper's unoptimized baseline (Table 3 "Naive").
//!
//! Per-cell scalar tap loop, one full sweep (and one full HBM round-trip)
//! per time step; no tiling, no vectorization-friendly structure.

use crate::stencil::{reference, Field, StencilSpec};

use super::Engine;

pub struct NaiveEngine;

impl Engine for NaiveEngine {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn block(&self, spec: &StencilSpec, input: &Field, steps: usize) -> Field {
        reference::block(input, spec, steps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::spec;

    #[test]
    fn matches_reference_by_construction() {
        let s = spec::get("heat2d").unwrap();
        let u = Field::random(&[14, 14], 1);
        let out = NaiveEngine.block(&s, &u, 2);
        assert_eq!(out.shape(), &[10, 10]);
    }
}
