//! Stencil substrate: specifications, fields, and the reference oracle.

pub mod boundary;
pub mod field;
pub mod reference;
pub mod spec;

pub use boundary::Boundary;
pub use field::Field;
pub use spec::{Kind, StencilSpec};
