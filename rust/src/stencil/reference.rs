//! Naive reference sweeps — the rust-side correctness oracle.
//!
//! Mirrors `python/compile/kernels/ref.py` exactly (same tap order, same
//! arithmetic); every optimized engine and every PJRT artifact is tested
//! against these functions.

use super::field::Field;
use super::spec::StencilSpec;

/// One valid-mode update: shape (n+2r, ..) -> (n, ..).
pub fn step(u: &Field, spec: &StencilSpec) -> Field {
    let r = spec.radius;
    assert_eq!(u.ndim(), spec.ndim, "{}: rank mismatch", spec.name);
    let core: Vec<usize> = u.shape().iter().map(|n| n.checked_sub(2 * r).expect("too small")).collect();
    assert!(core.iter().all(|&n| n > 0), "{}: input too small", spec.name);
    let mut out = Field::zeros(&core);
    let (offs, cs) = spec.taps();
    // Precompute flat offsets into u for the tap at each core cell.
    let ustr = u.strides().to_vec();
    let flat_offs: Vec<usize> = offs
        .iter()
        .map(|off| {
            off.iter()
                .zip(&ustr)
                .map(|(&o, &s)| ((o + r as i64) as usize) * s)
                .sum()
        })
        .collect();
    let core_shape = core.clone();
    let mut idx = vec![0usize; core_shape.len()];
    let n = out.len();
    let udata = u.data();
    let odata = out.data_mut();
    for i in 0..n {
        // base = flat index of idx in u coordinates (without +r shift; the
        // shift is folded into flat_offs).
        let base: usize = idx.iter().zip(&ustr).map(|(&i, &s)| i * s).sum();
        let mut acc = 0.0;
        for (fo, c) in flat_offs.iter().zip(&cs) {
            acc += c * udata[base + fo];
        }
        odata[i] = acc;
        for k in (0..core_shape.len()).rev() {
            idx[k] += 1;
            if idx[k] < core_shape[k] {
                break;
            }
            idx[k] = 0;
        }
    }
    out
}

/// `steps` fused valid-mode updates: (n + 2*r*steps, ..) -> (n, ..).
pub fn block(u: &Field, spec: &StencilSpec, steps: usize) -> Field {
    let mut cur = u.clone();
    for _ in 0..steps {
        cur = step(&cur, spec);
    }
    cur
}

/// One valid-mode update computed in true FP32 arithmetic (taps cast to
/// f32, f32 accumulate), stored back in the f64 container — the oracle
/// for an all-FP32 pipeline (paper Table 4).
pub fn step_f32(u: &Field, spec: &StencilSpec) -> Field {
    let r = spec.radius;
    assert_eq!(u.ndim(), spec.ndim, "{}: rank mismatch", spec.name);
    let core: Vec<usize> = u.shape().iter().map(|n| n.checked_sub(2 * r).expect("too small")).collect();
    assert!(core.iter().all(|&n| n > 0), "{}: input too small", spec.name);
    let mut out = Field::zeros(&core);
    let (offs, cs) = spec.taps();
    let cs32: Vec<f32> = cs.iter().map(|&c| c as f32).collect();
    let ustr = u.strides().to_vec();
    let flat_offs: Vec<usize> = offs
        .iter()
        .map(|off| {
            off.iter()
                .zip(&ustr)
                .map(|(&o, &s)| ((o + r as i64) as usize) * s)
                .sum()
        })
        .collect();
    let core_shape = core.clone();
    let mut idx = vec![0usize; core_shape.len()];
    let n = out.len();
    let udata = u.data();
    let odata = out.data_mut();
    for i in 0..n {
        let base: usize = idx.iter().zip(&ustr).map(|(&i, &s)| i * s).sum();
        let mut acc = 0.0f32;
        for (fo, c) in flat_offs.iter().zip(&cs32) {
            acc += c * (udata[base + fo] as f32);
        }
        odata[i] = acc as f64;
        for k in (0..core_shape.len()).rev() {
            idx[k] += 1;
            if idx[k] < core_shape[k] {
                break;
            }
            idx[k] = 0;
        }
    }
    out
}

/// Shape-preserving periodic evolution in true FP32 arithmetic: every
/// load, multiply and add is f32, mirroring an all-f32 pipeline.
pub fn evolve_periodic_f32(u: &Field, spec: &StencilSpec, steps: usize) -> Field {
    let shape = u.shape().to_vec();
    let mut cur: Vec<f32> = u.data().iter().map(|&x| x as f32).collect();
    let (offs, cs) = spec.taps();
    let cs32: Vec<f32> = cs.iter().map(|&c| c as f32).collect();
    let strides: Vec<i64> = {
        let mut st = vec![1i64; shape.len()];
        for i in (0..shape.len().saturating_sub(1)).rev() {
            st[i] = st[i + 1] * shape[i + 1] as i64;
        }
        st
    };
    for _ in 0..steps {
        let mut out = vec![0.0f32; cur.len()];
        let mut idx = vec![0usize; shape.len()];
        for o in out.iter_mut() {
            let mut acc = 0.0f32;
            for (off, c) in offs.iter().zip(&cs32) {
                let mut flat = 0i64;
                for d in 0..shape.len() {
                    let n = shape[d] as i64;
                    let x = ((idx[d] as i64 + off[d]) % n + n) % n;
                    flat += x * strides[d];
                }
                acc += c * cur[flat as usize];
            }
            *o = acc;
            for k in (0..shape.len()).rev() {
                idx[k] += 1;
                if idx[k] < shape[k] {
                    break;
                }
                idx[k] = 0;
            }
        }
        cur = out;
    }
    Field::from_vec(&shape, cur.into_iter().map(|x| x as f64).collect())
}

/// Shape-preserving periodic evolution (thermal case study oracle).
pub fn evolve_periodic(u: &Field, spec: &StencilSpec, steps: usize) -> Field {
    let shape = u.shape().to_vec();
    let mut cur = u.clone();
    let (offs, cs) = spec.taps();
    for _ in 0..steps {
        let mut out = Field::zeros(&shape);
        let mut idx = vec![0usize; shape.len()];
        for i in 0..out.len() {
            let mut acc = 0.0;
            for (off, c) in offs.iter().zip(&cs) {
                let src: Vec<usize> = idx
                    .iter()
                    .zip(off.iter())
                    .zip(&shape)
                    .map(|((&i, &o), &n)| {
                        (((i as i64 + o) % n as i64 + n as i64) % n as i64) as usize
                    })
                    .collect();
                acc += c * cur.get(&src);
            }
            out.data_mut()[i] = acc;
            for k in (0..shape.len()).rev() {
                idx[k] += 1;
                if idx[k] < shape[k] {
                    break;
                }
                idx[k] = 0;
            }
        }
        cur = out;
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::spec;

    #[test]
    fn step_shrinks_by_radius() {
        for s in spec::benchmarks() {
            let shape: Vec<usize> = (0..s.ndim).map(|_| 8 + 2 * s.radius).collect();
            let u = Field::random(&shape, 1);
            let out = step(&u, &s);
            assert_eq!(out.shape(), &vec![8; s.ndim][..], "{}", s.name);
        }
    }

    #[test]
    fn heat1d_hand_computed() {
        let s = spec::get("heat1d").unwrap();
        let (_, cs) = s.taps();
        // coeffs sorted by offset: [-1], [0], [1]
        let u = Field::from_vec(&[5], vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        let out = step(&u, &s);
        for i in 0..3 {
            let expect = cs[0] * u.data()[i] + cs[1] * u.data()[i + 1] + cs[2] * u.data()[i + 2];
            assert!((out.data()[i] - expect).abs() < 1e-15);
        }
    }

    #[test]
    fn block_equals_iterated_step() {
        let s = spec::get("box2d9p").unwrap();
        let u = Field::random(&[12, 12], 2);
        let b = block(&u, &s, 3);
        let mut it = u.clone();
        for _ in 0..3 {
            it = step(&it, &s);
        }
        assert!(b.allclose(&it, 1e-14, 0.0));
    }

    #[test]
    fn uniform_field_is_fixed_point() {
        // Normalized coefficients: constant field stays constant.
        for s in spec::benchmarks() {
            let shape: Vec<usize> = (0..s.ndim).map(|_| 6 + 2 * s.radius).collect();
            let u = Field::full(&shape, 2.5);
            let out = step(&u, &s);
            assert!((out.min() - 2.5).abs() < 1e-12, "{}", s.name);
            assert!((out.max() - 2.5).abs() < 1e-12, "{}", s.name);
        }
    }

    #[test]
    fn f32_step_tracks_f64_within_single_precision() {
        let s = spec::get("heat2d").unwrap();
        let u = Field::random(&[12, 12], 6);
        let a = step(&u, &s);
        let b = step_f32(&u, &s);
        let d = a.max_abs_diff(&b);
        assert!(d > 0.0, "f32 arithmetic must differ from f64");
        assert!(d < 1e-5, "but only at single precision: {d}");
    }

    #[test]
    fn f32_periodic_drifts_but_stays_bounded() {
        let s = spec::get("heat2d").unwrap();
        let u = Field::random(&[8, 8], 7);
        let a = evolve_periodic(&u, &s, 20);
        let b = evolve_periodic_f32(&u, &s, 20);
        assert_eq!(b.shape(), u.shape());
        let d = a.max_abs_diff(&b);
        assert!(d > 0.0 && d < 1e-3, "drift {d}");
    }

    #[test]
    fn periodic_preserves_mean() {
        let s = spec::get("heat2d").unwrap();
        let u = Field::random(&[10, 10], 3);
        let out = evolve_periodic(&u, &s, 4);
        assert!((out.mean() - u.mean()).abs() < 1e-13);
        assert_eq!(out.shape(), u.shape());
    }

    #[test]
    fn linearity() {
        let s = spec::get("box2d25p").unwrap();
        let u = Field::random(&[14, 14], 4);
        let v = Field::random(&[14, 14], 5);
        let mut w = u.clone();
        for (a, b) in w.data_mut().iter_mut().zip(v.data()) {
            *a = 2.0 * *a + 3.0 * b;
        }
        let lhs = step(&w, &s);
        let su = step(&u, &s);
        let sv = step(&v, &s);
        let mut rhs = su.clone();
        for (a, (x, y)) in rhs.data_mut().iter_mut().zip(su.data().iter().zip(sv.data())) {
            *a = 2.0 * x + 3.0 * y;
        }
        assert!(lhs.allclose(&rhs, 1e-12, 1e-14));
    }
}
