//! Dense row-major FP64 field with shape/stride bookkeeping.
//!
//! The single data container shared by every engine, the coordinator and
//! the PJRT runtime.  Kept deliberately simple: contiguous `Vec<f64>`,
//! row-major strides, copy-based sub-region extract/paste (the halo
//! traffic the coordinator batches is exactly these copies).

use std::fmt;

#[derive(Clone)]
pub struct Field {
    shape: Vec<usize>,
    strides: Vec<usize>,
    data: Vec<f64>,
    /// Debug-only id linking this buffer to the race checker's dynamic
    /// mode (`analyze::dynamic`); 0 = untraced.  Absent in release.
    #[cfg(debug_assertions)]
    trace: u64,
}

/// Equality is over shape and contents only — the debug-only trace id
/// is bookkeeping, not data, and must never affect test assertions.
impl PartialEq for Field {
    fn eq(&self, other: &Field) -> bool {
        self.shape == other.shape && self.data == other.data
    }
}

impl fmt::Debug for Field {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Field{:?}", self.shape)
    }
}

fn strides_for(shape: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; shape.len()];
    for i in (0..shape.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * shape[i + 1];
    }
    s
}

/// Dim-1 column range of a region access, for the dynamic race
/// validator's 2-D rects; fields without a column axis report the
/// unconstrained full range.
#[cfg(debug_assertions)]
fn dim1_range(offset: &[usize], count: &[usize]) -> (usize, usize) {
    if offset.len() >= 2 {
        (offset[1], offset[1] + count[1])
    } else {
        (0, usize::MAX)
    }
}

impl Field {
    pub fn zeros(shape: &[usize]) -> Self {
        Self::full(shape, 0.0)
    }

    pub fn full(shape: &[usize], v: f64) -> Self {
        let n = shape.iter().product();
        Field {
            shape: shape.to_vec(),
            strides: strides_for(shape),
            data: vec![v; n],
            #[cfg(debug_assertions)]
            trace: 0,
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f64>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Field {
            shape: shape.to_vec(),
            strides: strides_for(shape),
            data,
            #[cfg(debug_assertions)]
            trace: 0,
        }
    }

    /// Deterministic pseudorandom field (SplitMix64), for tests/benches.
    pub fn random(shape: &[usize], seed: u64) -> Self {
        let n = shape.iter().product();
        Field {
            shape: shape.to_vec(),
            strides: strides_for(shape),
            data: crate::util::prng::SplitMix64::new(seed).fill(n),
            #[cfg(debug_assertions)]
            trace: 0,
        }
    }

    /// Tag this buffer for the debug-build dynamic race validator
    /// (`analyze::dynamic`): region primitives on a traced field report
    /// their dim-0 row ranges to the active task scope.  No-op in
    /// release builds.
    pub fn set_trace(&mut self, id: u64) {
        #[cfg(debug_assertions)]
        {
            self.trace = id;
        }
        #[cfg(not(debug_assertions))]
        {
            let _ = id;
        }
    }

    /// This buffer's trace id (always 0 in release builds).
    pub fn trace(&self) -> u64 {
        #[cfg(debug_assertions)]
        {
            self.trace
        }
        #[cfg(not(debug_assertions))]
        {
            0
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn strides(&self) -> &[usize] {
        &self.strides
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    #[inline]
    pub fn flat(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        idx.iter().zip(&self.strides).map(|(i, s)| i * s).sum()
    }

    #[inline]
    pub fn get(&self, idx: &[usize]) -> f64 {
        self.data[self.flat(idx)]
    }

    #[inline]
    pub fn set(&mut self, idx: &[usize], v: f64) {
        let f = self.flat(idx);
        self.data[f] = v;
    }

    /// Copy out the sub-region at `offset` with `shape`.
    pub fn extract(&self, offset: &[usize], shape: &[usize]) -> Field {
        assert_eq!(offset.len(), self.ndim());
        assert_eq!(shape.len(), self.ndim());
        for d in 0..self.ndim() {
            assert!(
                offset[d] + shape[d] <= self.shape[d],
                "extract oob: dim {d} {}+{} > {}",
                offset[d],
                shape[d],
                self.shape[d]
            );
        }
        #[cfg(debug_assertions)]
        if self.ndim() > 0 {
            let (c0, c1) = dim1_range(offset, shape);
            crate::analyze::dynamic::record(self.trace, false, offset[0], offset[0] + shape[0], c0, c1);
        }
        let mut out = Field::zeros(shape);
        copy_region(
            &self.data,
            &self.shape,
            offset,
            &mut out.data,
            shape,
            &vec![0; shape.len()],
            shape,
        );
        out
    }

    /// Paste `src` into this field at `offset`.
    pub fn paste(&mut self, offset: &[usize], src: &Field) {
        assert_eq!(offset.len(), self.ndim());
        assert_eq!(src.ndim(), self.ndim());
        for d in 0..self.ndim() {
            assert!(
                offset[d] + src.shape[d] <= self.shape[d],
                "paste oob: dim {d}"
            );
        }
        #[cfg(debug_assertions)]
        if self.ndim() > 0 {
            let (c0, c1) = dim1_range(offset, &src.shape);
            crate::analyze::dynamic::record(self.trace, true, offset[0], offset[0] + src.shape[0], c0, c1);
            let (s0, s1) = dim1_range(&vec![0; src.ndim()], &src.shape);
            crate::analyze::dynamic::record(src.trace, false, 0, src.shape[0], s0, s1);
        }
        let shape = self.shape.clone();
        copy_region(
            &src.data,
            &src.shape,
            &vec![0; src.ndim()],
            &mut self.data,
            &shape,
            offset,
            &src.shape.clone(),
        );
    }

    /// Copy the sub-region of `src` at `src_off` (extent `count`) into
    /// this field at `dst_off` — the allocation-free cross-field region
    /// copy behind the pipelined leader's slab assembly (extract+paste
    /// without the intermediate `Field`).
    pub fn copy_region_from(
        &mut self,
        src: &Field,
        src_off: &[usize],
        dst_off: &[usize],
        count: &[usize],
    ) {
        assert_eq!(src_off.len(), src.ndim());
        assert_eq!(dst_off.len(), self.ndim());
        assert_eq!(count.len(), self.ndim());
        assert_eq!(src.ndim(), self.ndim());
        for d in 0..self.ndim() {
            assert!(
                src_off[d] + count[d] <= src.shape[d] && dst_off[d] + count[d] <= self.shape[d],
                "copy_region_from oob: dim {d}"
            );
        }
        #[cfg(debug_assertions)]
        if self.ndim() > 0 {
            let (sc0, sc1) = dim1_range(src_off, count);
            crate::analyze::dynamic::record(src.trace, false, src_off[0], src_off[0] + count[0], sc0, sc1);
            let (dc0, dc1) = dim1_range(dst_off, count);
            crate::analyze::dynamic::record(self.trace, true, dst_off[0], dst_off[0] + count[0], dc0, dc1);
        }
        let dst_shape = self.shape.clone();
        copy_region(&src.data, &src.shape, src_off, &mut self.data, &dst_shape, dst_off, count);
    }

    /// Fill the sub-region at `offset` with extent `count` with `v`,
    /// row-by-row (no allocation) — the strided write primitive behind
    /// the O(surface) Dirichlet ghost fill.
    pub fn fill_region(&mut self, offset: &[usize], count: &[usize], v: f64) {
        assert_eq!(offset.len(), self.ndim());
        assert_eq!(count.len(), self.ndim());
        for d in 0..self.ndim() {
            assert!(
                offset[d] + count[d] <= self.shape[d],
                "fill_region oob: dim {d} {}+{} > {}",
                offset[d],
                count[d],
                self.shape[d]
            );
        }
        if count.iter().any(|&c| c == 0) {
            return;
        }
        let nd = self.ndim();
        if nd == 0 {
            self.data[0] = v;
            return;
        }
        #[cfg(debug_assertions)]
        {
            let (c0, c1) = dim1_range(offset, count);
            crate::analyze::dynamic::record(self.trace, true, offset[0], offset[0] + count[0], c0, c1);
        }
        let row = count[nd - 1];
        let outer: usize = count[..nd - 1].iter().product();
        let mut idx = vec![0usize; nd - 1];
        for _ in 0..outer.max(1) {
            let mut base = offset[nd - 1];
            for k in 0..nd - 1 {
                base += (offset[k] + idx[k]) * self.strides[k];
            }
            self.data[base..base + row].fill(v);
            for k in (0..nd - 1).rev() {
                idx[k] += 1;
                if idx[k] < count[k] {
                    break;
                }
                idx[k] = 0;
            }
        }
    }

    /// Copy the sub-region at `src_off` (extent `count`) onto `dst_off`
    /// within the same field, row-by-row via `slice::copy_within` — the
    /// allocation-free in-place strided copy behind the mapped ghost
    /// fills.  Overlap is only safe along the innermost dim (each row
    /// copy is a memmove); regions that overlap across an outer dim
    /// would read rows already overwritten, so that is rejected.
    pub fn copy_region_within(&mut self, src_off: &[usize], dst_off: &[usize], count: &[usize]) {
        assert_eq!(src_off.len(), self.ndim());
        assert_eq!(dst_off.len(), self.ndim());
        assert_eq!(count.len(), self.ndim());
        for d in 0..self.ndim() {
            assert!(
                src_off[d] + count[d] <= self.shape[d] && dst_off[d] + count[d] <= self.shape[d],
                "copy_region_within oob: dim {d}"
            );
        }
        if count.iter().any(|&c| c == 0) {
            return;
        }
        let nd = self.ndim();
        if nd == 0 {
            return;
        }
        // Rows alias only when every outer coordinate matches and the
        // inner ranges intersect: safe iff outer offsets are identical
        // (pure per-row memmove), some outer dim is disjoint, or the
        // inner ranges are disjoint.
        let outer_equal = src_off[..nd - 1] == dst_off[..nd - 1];
        let outer_disjoint = (0..nd - 1).any(|d| {
            src_off[d] + count[d] <= dst_off[d] || dst_off[d] + count[d] <= src_off[d]
        });
        let inner_disjoint = src_off[nd - 1] + count[nd - 1] <= dst_off[nd - 1]
            || dst_off[nd - 1] + count[nd - 1] <= src_off[nd - 1];
        assert!(
            outer_equal || outer_disjoint || inner_disjoint,
            "copy_region_within: regions overlap across an outer dimension"
        );
        #[cfg(debug_assertions)]
        {
            let (sc0, sc1) = dim1_range(src_off, count);
            crate::analyze::dynamic::record(self.trace, false, src_off[0], src_off[0] + count[0], sc0, sc1);
            let (dc0, dc1) = dim1_range(dst_off, count);
            crate::analyze::dynamic::record(self.trace, true, dst_off[0], dst_off[0] + count[0], dc0, dc1);
        }
        let row = count[nd - 1];
        let outer: usize = count[..nd - 1].iter().product();
        let mut idx = vec![0usize; nd - 1];
        for _ in 0..outer.max(1) {
            let mut s = src_off[nd - 1];
            let mut d = dst_off[nd - 1];
            for k in 0..nd - 1 {
                s += (src_off[k] + idx[k]) * self.strides[k];
                d += (dst_off[k] + idx[k]) * self.strides[k];
            }
            self.data.copy_within(s..s + row, d);
            for k in (0..nd - 1).rev() {
                idx[k] += 1;
                if idx[k] < count[k] {
                    break;
                }
                idx[k] = 0;
            }
        }
    }

    /// New field padded by `halo` cells of `value` on every side.
    pub fn pad(&self, halo: usize, value: f64) -> Field {
        let shape: Vec<usize> = self.shape.iter().map(|n| n + 2 * halo).collect();
        let mut out = Field::full(&shape, value);
        out.paste(&vec![halo; self.ndim()], self);
        out
    }

    /// Strip `halo` cells from every side.
    pub fn unpad(&self, halo: usize) -> Field {
        let shape: Vec<usize> = self.shape.iter().map(|n| n - 2 * halo).collect();
        self.extract(&vec![halo; self.ndim()], &shape)
    }

    pub fn mean(&self) -> f64 {
        self.data.iter().sum::<f64>() / self.data.len() as f64
    }

    pub fn l2(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.data.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.data.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Max |a - b| over all cells (shapes must match).
    pub fn max_abs_diff(&self, other: &Field) -> f64 {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// assert_allclose with rtol/atol semantics (numpy-style).
    pub fn allclose(&self, other: &Field, rtol: f64, atol: f64) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

/// Generic strided nd copy: src[src_off .. src_off+count] -> dst[dst_off ..].
fn copy_region(
    src: &[f64],
    src_shape: &[usize],
    src_off: &[usize],
    dst: &mut [f64],
    dst_shape: &[usize],
    dst_off: &[usize],
    count: &[usize],
) {
    let nd = src_shape.len();
    if nd == 0 {
        dst[0] = src[0];
        return;
    }
    if count.iter().any(|&c| c == 0) {
        return; // empty region: nothing to copy
    }
    let src_strides = strides_for(src_shape);
    let dst_strides = strides_for(dst_shape);
    // Iterate all but the innermost dimension; memcpy rows.
    let row = count[nd - 1];
    let outer: usize = count[..nd - 1].iter().product();
    let mut idx = vec![0usize; nd - 1];
    for _ in 0..outer.max(1) {
        let mut s = src_off[nd - 1];
        let mut d = dst_off[nd - 1];
        for k in 0..nd - 1 {
            s += (src_off[k] + idx[k]) * src_strides[k];
            d += (dst_off[k] + idx[k]) * dst_strides[k];
        }
        dst[d..d + row].copy_from_slice(&src[s..s + row]);
        // odometer increment
        for k in (0..nd - 1).rev() {
            idx[k] += 1;
            if idx[k] < count[k] {
                break;
            }
            idx[k] = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        let f = Field::zeros(&[2, 3, 4]);
        assert_eq!(f.strides(), &[12, 4, 1]);
    }

    #[test]
    fn get_set_roundtrip() {
        let mut f = Field::zeros(&[3, 4]);
        f.set(&[1, 2], 7.5);
        assert_eq!(f.get(&[1, 2]), 7.5);
        assert_eq!(f.data()[1 * 4 + 2], 7.5);
    }

    #[test]
    fn extract_paste_roundtrip() {
        let f = Field::random(&[6, 7], 1);
        let sub = f.extract(&[2, 3], &[3, 2]);
        assert_eq!(sub.get(&[0, 0]), f.get(&[2, 3]));
        assert_eq!(sub.get(&[2, 1]), f.get(&[4, 4]));
        let mut g = Field::zeros(&[6, 7]);
        g.paste(&[2, 3], &sub);
        assert_eq!(g.get(&[4, 4]), f.get(&[4, 4]));
        assert_eq!(g.get(&[0, 0]), 0.0);
    }

    #[test]
    fn pad_unpad_roundtrip() {
        let f = Field::random(&[4, 5], 2);
        let p = f.pad(2, 9.0);
        assert_eq!(p.shape(), &[8, 9]);
        assert_eq!(p.get(&[0, 0]), 9.0);
        assert_eq!(p.get(&[2, 2]), f.get(&[0, 0]));
        assert_eq!(p.unpad(2), f);
    }

    #[test]
    fn pad_3d() {
        let f = Field::random(&[3, 4, 5], 3);
        let p = f.pad(1, 0.0);
        assert_eq!(p.shape(), &[5, 6, 7]);
        assert_eq!(p.unpad(1), f);
    }

    #[test]
    fn fill_region_rows_and_corners() {
        let mut f = Field::zeros(&[4, 5]);
        f.fill_region(&[1, 2], &[2, 3], 7.0);
        assert_eq!(f.get(&[1, 2]), 7.0);
        assert_eq!(f.get(&[2, 4]), 7.0);
        assert_eq!(f.get(&[0, 2]), 0.0);
        assert_eq!(f.get(&[1, 1]), 0.0);
        assert_eq!(f.get(&[3, 2]), 0.0);
        // empty extent is a no-op
        f.fill_region(&[0, 0], &[0, 5], 9.0);
        assert_eq!(f.get(&[0, 0]), 0.0);
    }

    #[test]
    fn fill_region_1d_and_3d() {
        let mut a = Field::zeros(&[6]);
        a.fill_region(&[4], &[2], 1.5);
        assert_eq!(a.data()[3], 0.0);
        assert_eq!(a.data()[4], 1.5);
        assert_eq!(a.data()[5], 1.5);
        let mut b = Field::zeros(&[3, 3, 3]);
        b.fill_region(&[1, 0, 1], &[1, 3, 2], 2.0);
        assert_eq!(b.get(&[1, 2, 2]), 2.0);
        assert_eq!(b.get(&[1, 1, 0]), 0.0);
        assert_eq!(b.get(&[0, 0, 1]), 0.0);
    }

    #[test]
    #[should_panic(expected = "fill_region oob")]
    fn fill_region_oob_panics() {
        Field::zeros(&[3, 3]).fill_region(&[2, 0], &[2, 1], 1.0);
    }

    #[test]
    fn copy_region_from_matches_extract_paste() {
        let src = Field::random(&[6, 7], 21);
        let orig = Field::random(&[5, 6], 22);
        let mut a = orig.clone();
        a.copy_region_from(&src, &[1, 2], &[2, 0], &[3, 4]);
        let mut b = orig.clone();
        b.paste(&[2, 0], &src.extract(&[1, 2], &[3, 4]));
        assert_eq!(a, b);
        // empty extent is a no-op
        let mut c = orig.clone();
        c.copy_region_from(&src, &[0, 0], &[0, 0], &[0, 3]);
        assert_eq!(c, orig);
    }

    #[test]
    #[should_panic(expected = "copy_region_from oob")]
    fn copy_region_from_oob_panics() {
        let src = Field::zeros(&[3, 3]);
        Field::zeros(&[3, 3]).copy_region_from(&src, &[2, 0], &[0, 0], &[2, 2]);
    }

    #[test]
    fn copy_region_within_matches_extract_paste() {
        let orig = Field::random(&[5, 6], 8);
        let mut a = orig.clone();
        a.copy_region_within(&[1, 2], &[3, 0], &[2, 3]);
        let mut b = orig.clone();
        let sub = orig.extract(&[1, 2], &[2, 3]);
        b.paste(&[3, 0], &sub);
        assert_eq!(a, b);
        // 1-D and degenerate column counts
        let mut c = Field::from_vec(&[5], vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        c.copy_region_within(&[0], &[3], &[2]);
        assert_eq!(c.data(), &[1.0, 2.0, 3.0, 1.0, 2.0]);
        let mut d = orig.clone();
        d.copy_region_within(&[0, 1], &[0, 4], &[5, 1]);
        for i in 0..5 {
            assert_eq!(d.get(&[i, 4]), orig.get(&[i, 1]));
        }
    }

    #[test]
    #[should_panic(expected = "copy_region_within oob")]
    fn copy_region_within_oob_panics() {
        Field::zeros(&[4, 3]).copy_region_within(&[0, 0], &[3, 0], &[2, 2]);
    }

    #[test]
    fn copy_region_within_inner_overlap_is_memmove() {
        // same rows, overlapping column ranges: per-row memmove semantics
        let mut f = Field::from_vec(&[2, 4], vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        f.copy_region_within(&[0, 0], &[0, 1], &[2, 3]);
        assert_eq!(f.data(), &[1., 1., 2., 3., 5., 5., 6., 7.]);
    }

    #[test]
    #[should_panic(expected = "overlap across an outer dimension")]
    fn copy_region_within_outer_overlap_panics() {
        // shifting rows 0-2 down by one would read overwritten rows
        Field::zeros(&[4, 3]).copy_region_within(&[0, 0], &[1, 0], &[3, 3]);
    }

    #[test]
    fn allclose_tolerance() {
        let a = Field::from_vec(&[2], vec![1.0, 2.0]);
        let b = Field::from_vec(&[2], vec![1.0 + 1e-13, 2.0]);
        assert!(a.allclose(&b, 1e-12, 0.0));
        assert!(!a.allclose(&b, 1e-15, 0.0));
    }

    #[test]
    fn stats() {
        let f = Field::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(f.mean(), 2.5);
        assert_eq!(f.min(), 1.0);
        assert_eq!(f.max(), 4.0);
        assert!((f.l2() - 30f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "extract oob")]
    fn extract_oob_panics() {
        Field::zeros(&[3, 3]).extract(&[2, 2], &[2, 2]);
    }

    #[test]
    fn random_matches_python_stream() {
        // SplitMix64(seed).fill row-major — same draws as prng.py.
        let f = Field::random(&[2, 2], 42);
        let mut rng = crate::util::prng::SplitMix64::new(42);
        for i in 0..4 {
            assert_eq!(f.data()[i], rng.next_f64());
        }
    }
}
