//! Boundary conditions for global evolutions.
//!
//! The valid-mode engines are boundary-agnostic (they only consume the
//! ghost ring they are given); this module is the substrate that *fills*
//! the ring each block, so applications can pick the physics they need:
//!
//! * [`Boundary::Dirichlet`] — fixed value (the thermal plate's ambient);
//! * [`Boundary::Neumann`] — zero-flux: ghosts mirror the edge cells
//!   (insulated plate);
//! * [`Boundary::Periodic`] — torus wrap (matches `ref.evolve_periodic`
//!   and the thermal artifacts).

use super::field::Field;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Boundary {
    Dirichlet(f64),
    Neumann,
    Periodic,
}

impl Boundary {
    /// Fill the `halo`-wide ghost ring of `ext` (whose core occupies the
    /// centred region) according to the condition.  Corners are filled
    /// too (axis-by-axis passes make corners consistent for Neumann and
    /// Periodic).
    pub fn fill(&self, ext: &mut Field, halo: usize) {
        if halo == 0 {
            return;
        }
        match self {
            Boundary::Dirichlet(v) => fill_dirichlet(ext, halo, *v),
            Boundary::Neumann => fill_by_map(ext, halo, |x, lo, hi| x.clamp(lo, hi)),
            Boundary::Periodic => fill_by_map(ext, halo, |x, lo, hi| {
                let n = (hi - lo + 1) as i64;
                lo + (((x - lo) % n + n) % n)
            }),
        }
    }

    /// Convenience: pad `core` by `halo` and fill the ring.
    pub fn pad(&self, core: &Field, halo: usize) -> Field {
        let mut ext = core.pad(halo, 0.0);
        self.fill(&mut ext, halo);
        ext
    }
}

fn fill_dirichlet(ext: &mut Field, halo: usize, v: f64) {
    let shape = ext.shape().to_vec();
    let nd = shape.len();
    let mut idx = vec![0usize; nd];
    let n = ext.len();
    let data = ext.data_mut();
    for i in 0..n {
        let in_core = idx
            .iter()
            .zip(&shape)
            .all(|(&x, &s)| x >= halo && x < s - halo);
        if !in_core {
            data[i] = v;
        }
        for k in (0..nd).rev() {
            idx[k] += 1;
            if idx[k] < shape[k] {
                break;
            }
            idx[k] = 0;
        }
    }
}

/// Fill ghosts by mapping each out-of-core coordinate to an in-core one
/// (clamp => Neumann mirror-of-edge, modulo => periodic).
fn fill_by_map(ext: &mut Field, halo: usize, map: impl Fn(i64, i64, i64) -> i64) {
    let shape = ext.shape().to_vec();
    let nd = shape.len();
    let mut idx = vec![0usize; nd];
    let n = ext.len();
    for _ in 0..n {
        let in_core = idx
            .iter()
            .zip(&shape)
            .all(|(&x, &s)| x >= halo && x < s - halo);
        if !in_core {
            let src: Vec<usize> = idx
                .iter()
                .zip(&shape)
                .map(|(&x, &s)| map(x as i64, halo as i64, (s - halo - 1) as i64) as usize)
                .collect();
            let v = ext.get(&src);
            ext.set(&idx.clone(), v);
        }
        for k in (0..nd).rev() {
            idx[k] += 1;
            if idx[k] < shape[k] {
                break;
            }
            idx[k] = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{reference, spec};

    #[test]
    fn dirichlet_fills_ring_only() {
        let core = Field::random(&[4, 4], 1);
        let ext = Boundary::Dirichlet(9.0).pad(&core, 2);
        assert_eq!(ext.get(&[0, 0]), 9.0);
        assert_eq!(ext.get(&[7, 7]), 9.0);
        assert_eq!(ext.get(&[2, 2]), core.get(&[0, 0]));
        assert_eq!(ext.unpad(2), core);
    }

    #[test]
    fn neumann_mirrors_edges() {
        let core = Field::random(&[3, 3], 2);
        let ext = Boundary::Neumann.pad(&core, 1);
        assert_eq!(ext.get(&[0, 1]), core.get(&[0, 0]));
        assert_eq!(ext.get(&[4, 3]), core.get(&[2, 2]));
        // corner clamps both axes
        assert_eq!(ext.get(&[0, 0]), core.get(&[0, 0]));
    }

    #[test]
    fn periodic_wraps() {
        let core = Field::random(&[4], 3);
        let ext = Boundary::Periodic.pad(&core, 2);
        assert_eq!(ext.get(&[0]), core.get(&[2]));
        assert_eq!(ext.get(&[1]), core.get(&[3]));
        assert_eq!(ext.get(&[6]), core.get(&[0]));
        assert_eq!(ext.get(&[7]), core.get(&[1]));
    }

    #[test]
    fn periodic_step_matches_roll_oracle() {
        // valid step on a periodically padded field == one periodic step.
        let s = spec::get("heat2d").unwrap();
        let core = Field::random(&[6, 6], 4);
        let ext = Boundary::Periodic.pad(&core, s.radius);
        let got = reference::step(&ext, &s);
        let want = reference::evolve_periodic(&core, &s, 1);
        assert!(got.allclose(&want, 1e-13, 0.0));
    }

    #[test]
    fn neumann_conserves_uniform_field() {
        let s = spec::get("box2d9p").unwrap();
        let core = Field::full(&[5, 5], 3.0);
        let ext = Boundary::Neumann.pad(&core, s.radius);
        let out = reference::step(&ext, &s);
        assert!((out.min() - 3.0).abs() < 1e-12 && (out.max() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn zero_halo_noop() {
        let core = Field::random(&[3, 3], 5);
        let mut ext = core.clone();
        Boundary::Periodic.fill(&mut ext, 0);
        assert_eq!(ext, core);
    }
}
