//! Boundary conditions for global evolutions.
//!
//! The valid-mode engines are boundary-agnostic (they only consume the
//! ghost ring they are given); this module is the substrate that *fills*
//! the ring each block, so applications can pick the physics they need:
//!
//! * [`Boundary::Dirichlet`] — fixed value (the thermal plate's ambient);
//! * [`Boundary::Neumann`] — zero-flux: ghosts reflect the core about
//!   the wall face (insulated plate).  Reflection — not edge
//!   replication — is what keeps a *deep* halo (`radius*Tb`) exactly
//!   equivalent to refilling a 1-step halo every step: the even
//!   extension is invariant under the symmetric stencil, so fused
//!   Tb-blocks conserve total heat to machine precision;
//! * [`Boundary::Periodic`] — torus wrap (matches `ref.evolve_periodic`
//!   and the thermal artifacts).
//!
//! Fills are face-wise strided copies touching only the O(surface) ghost
//! ring — never a full-domain scan — so the coordinator can refresh the
//! ring every Tb-block (Neumann mirrors and Periodic wraps depend on the
//! evolving core) without it showing up in the block time.

use super::field::Field;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Boundary {
    Dirichlet(f64),
    Neumann,
    Periodic,
}

impl Boundary {
    /// Fill the `halo`-wide ghost ring of `ext` (whose core occupies the
    /// centred region) according to the condition.  Corners are filled
    /// too (axis-by-axis passes make corners consistent for Neumann and
    /// Periodic).
    pub fn fill(&self, ext: &mut Field, halo: usize) {
        if halo == 0 {
            return;
        }
        match self {
            Boundary::Dirichlet(v) => fill_dirichlet(ext, halo, *v),
            Boundary::Neumann => fill_by_row_map(ext, halo, reflect),
            Boundary::Periodic => fill_by_row_map(ext, halo, wrap),
        }
    }

    /// Convenience: pad `core` by `halo` and fill the ring.
    pub fn pad(&self, core: &Field, halo: usize) -> Field {
        let mut ext = core.pad(halo, 0.0);
        self.fill(&mut ext, halo);
        ext
    }

    /// The value used when first padding a core field (ghosts are then
    /// kept fresh by per-block [`Boundary::fill`] calls).
    pub fn pad_value(&self) -> f64 {
        match self {
            Boundary::Dirichlet(v) => *v,
            _ => 0.0,
        }
    }

    /// The condition's family, ignoring parameters — Dirichlet runs cost
    /// the same whatever the wall value, so serving sessions key their
    /// cached partition on the kind, not the exact condition.
    pub fn kind(&self) -> &'static str {
        match self {
            Boundary::Dirichlet(_) => "dirichlet",
            Boundary::Neumann => "neumann",
            Boundary::Periodic => "periodic",
        }
    }

    /// Map a padded index `x` along one dimension (core occupies
    /// `[halo, halo + core_len)`) to the padded *core* index that
    /// sources its value under this condition: identity for in-core
    /// `x`, reflection for Neumann, wrap for Periodic, and `None` for
    /// Dirichlet ghosts (they hold the wall constant, not a copy).
    /// This is exactly the per-axis map [`Boundary::fill`] applies, so
    /// the pipelined leader can assemble slab ghosts row-by-row
    /// bit-identically to a full-ring fill + extract.
    pub fn source_index(&self, x: usize, halo: usize, core_len: usize) -> Option<usize> {
        let lo = halo as i64;
        let hi = (halo + core_len - 1) as i64;
        let xi = x as i64;
        if xi >= lo && xi <= hi {
            return Some(x);
        }
        match self {
            Boundary::Dirichlet(_) => None,
            Boundary::Neumann => Some(reflect(xi, lo, hi) as usize),
            Boundary::Periodic => Some(wrap(xi, lo, hi) as usize),
        }
    }
}

impl std::fmt::Display for Boundary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Boundary::Dirichlet(v) => write!(f, "dirichlet:{v}"),
            Boundary::Neumann => write!(f, "neumann"),
            Boundary::Periodic => write!(f, "periodic"),
        }
    }
}

/// CLI syntax: `dirichlet[:V]` | `neumann` | `periodic`.
impl std::str::FromStr for Boundary {
    type Err = crate::util::error::TetrisError;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "neumann" => Ok(Boundary::Neumann),
            "periodic" => Ok(Boundary::Periodic),
            "dirichlet" => Ok(Boundary::Dirichlet(0.0)),
            other => match other.strip_prefix("dirichlet:") {
                Some(v) => v
                    .parse::<f64>()
                    .map(Boundary::Dirichlet)
                    .map_err(|e| crate::err!("bad dirichlet value {v:?}: {e}")),
                None => Err(crate::err!(
                    "unknown boundary {other:?} (expected dirichlet[:V], neumann or periodic)"
                )),
            },
        }
    }
}

/// Even reflection about the wall faces, folded until in-core: ghost at
/// depth k mirrors core row k-1 (`lo-1 -> lo`, `lo-2 -> lo+1`, ...).
/// The reflection group has period 2n, so arbitrary halo depths fold
/// correctly even when `halo > n`.
fn reflect(x: i64, lo: i64, hi: i64) -> i64 {
    let n = hi - lo + 1;
    let mut t = (x - lo).rem_euclid(2 * n);
    if t >= n {
        t = 2 * n - 1 - t;
    }
    lo + t
}

/// Torus wrap into `[lo, hi]`.
fn wrap(x: i64, lo: i64, hi: i64) -> i64 {
    let n = hi - lo + 1;
    lo + (x - lo).rem_euclid(n)
}

/// Dirichlet: overwrite the two `halo`-thick face slabs of every dim with
/// `v`.  Each slab spans the full extent of the other dims, so the union
/// is exactly the non-core set; corners get written once per incident
/// axis, which is idempotent.
fn fill_dirichlet(ext: &mut Field, halo: usize, v: f64) {
    let shape = ext.shape().to_vec();
    for d in 0..shape.len() {
        debug_assert!(shape[d] >= 2 * halo, "extended dim {d} smaller than ghost ring");
        let mut count = shape.clone();
        count[d] = halo;
        let mut off = vec![0usize; shape.len()];
        ext.fill_region(&off, &count, v);
        off[d] = shape[d] - halo;
        ext.fill_region(&off, &count, v);
    }
}

/// Fill ghosts by mapping each ghost *row* of each dim to the in-core row
/// `map` selects (reflection => Neumann, modulo => periodic), one
/// in-place strided hyperslab copy per ghost row (no allocation).
/// Passes run axis by axis over the full extent of the other dims: a
/// corner cell is rewritten by every incident axis, and because each
/// pass sources rows whose earlier axes were already mapped into the
/// core, the final corner value equals the all-axes-mapped core cell —
/// identical to a per-cell simultaneous map, in O(surface * halo) work
/// instead of O(volume).
fn fill_by_row_map(ext: &mut Field, halo: usize, map: impl Fn(i64, i64, i64) -> i64) {
    let shape = ext.shape().to_vec();
    let nd = shape.len();
    for d in 0..nd {
        assert!(shape[d] > 2 * halo, "dim {d}: core must be non-empty to source ghosts");
        let lo = halo as i64;
        let hi = (shape[d] - halo - 1) as i64;
        let mut count = shape.clone();
        count[d] = 1;
        for g in (0..halo).chain(shape[d] - halo..shape[d]) {
            let src_row = map(g as i64, lo, hi) as usize;
            debug_assert!((lo..=hi).contains(&(src_row as i64)));
            let mut src_off = vec![0usize; nd];
            src_off[d] = src_row;
            let mut dst_off = vec![0usize; nd];
            dst_off[d] = g;
            ext.copy_region_within(&src_off, &dst_off, &count);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{reference, spec};

    /// The pre-rewrite per-cell oracle: scan the whole volume, map every
    /// out-of-core coordinate simultaneously.  Kept as the equivalence
    /// reference for the face-wise fills.
    fn fill_by_scan(ext: &mut Field, halo: usize, map: impl Fn(i64, i64, i64) -> i64) {
        let shape = ext.shape().to_vec();
        let nd = shape.len();
        let mut idx = vec![0usize; nd];
        let n = ext.len();
        for _ in 0..n {
            let in_core = idx
                .iter()
                .zip(&shape)
                .all(|(&x, &s)| x >= halo && x < s - halo);
            if !in_core {
                let src: Vec<usize> = idx
                    .iter()
                    .zip(&shape)
                    .map(|(&x, &s)| map(x as i64, halo as i64, (s - halo - 1) as i64) as usize)
                    .collect();
                let v = ext.get(&src);
                ext.set(&idx.clone(), v);
            }
            for k in (0..nd).rev() {
                idx[k] += 1;
                if idx[k] < shape[k] {
                    break;
                }
                idx[k] = 0;
            }
        }
    }

    #[test]
    fn dirichlet_fills_ring_only() {
        let core = Field::random(&[4, 4], 1);
        let ext = Boundary::Dirichlet(9.0).pad(&core, 2);
        assert_eq!(ext.get(&[0, 0]), 9.0);
        assert_eq!(ext.get(&[7, 7]), 9.0);
        assert_eq!(ext.get(&[2, 2]), core.get(&[0, 0]));
        assert_eq!(ext.unpad(2), core);
    }

    #[test]
    fn dirichlet_covers_every_ghost_cell() {
        // Face-slab union must be exactly the non-core set, all dims.
        for shape in [vec![5usize], vec![5, 6], vec![3, 4, 5]] {
            let core = Field::random(&shape, 11);
            let halo = 2;
            let ext = Boundary::Dirichlet(-3.5).pad(&core, halo);
            let eshape = ext.shape().to_vec();
            let mut idx = vec![0usize; eshape.len()];
            for _ in 0..ext.len() {
                let in_core = idx
                    .iter()
                    .zip(&eshape)
                    .all(|(&x, &s)| x >= halo && x < s - halo);
                let got = ext.get(&idx);
                if in_core {
                    let cidx: Vec<usize> = idx.iter().map(|&x| x - halo).collect();
                    assert_eq!(got, core.get(&cidx));
                } else {
                    assert_eq!(got, -3.5, "ghost {idx:?} missed");
                }
                for k in (0..eshape.len()).rev() {
                    idx[k] += 1;
                    if idx[k] < eshape[k] {
                        break;
                    }
                    idx[k] = 0;
                }
            }
        }
    }

    #[test]
    fn neumann_mirrors_edges() {
        let core = Field::random(&[3, 3], 2);
        let ext = Boundary::Neumann.pad(&core, 1);
        assert_eq!(ext.get(&[0, 1]), core.get(&[0, 0]));
        assert_eq!(ext.get(&[4, 3]), core.get(&[2, 2]));
        // corner reflects both axes
        assert_eq!(ext.get(&[0, 0]), core.get(&[0, 0]));
    }

    #[test]
    fn periodic_wraps() {
        let core = Field::random(&[4], 3);
        let ext = Boundary::Periodic.pad(&core, 2);
        assert_eq!(ext.get(&[0]), core.get(&[2]));
        assert_eq!(ext.get(&[1]), core.get(&[3]));
        assert_eq!(ext.get(&[6]), core.get(&[0]));
        assert_eq!(ext.get(&[7]), core.get(&[1]));
    }

    #[test]
    fn facewise_fill_matches_percell_scan_oracle() {
        // The O(surface) axis-by-axis fill must agree cell-for-cell with
        // the per-cell simultaneous map, corners included, for both maps,
        // across ranks and halos (halo 3 > core 2 exercises multi-fold).
        for shape in [vec![7usize], vec![5, 4], vec![2, 3], vec![4, 3, 5]] {
            for halo in [1usize, 2, 3] {
                let core = Field::random(&shape, 0xC0DE + halo as u64);
                let wrap = |x: i64, lo: i64, hi: i64| {
                    let n = hi - lo + 1;
                    lo + (((x - lo) % n + n) % n)
                };
                for b in [Boundary::Neumann, Boundary::Periodic] {
                    let got = b.pad(&core, halo);
                    let mut want = core.pad(halo, 0.0);
                    match b {
                        Boundary::Neumann => fill_by_scan(&mut want, halo, reflect),
                        Boundary::Periodic => fill_by_scan(&mut want, halo, wrap),
                        _ => unreachable!(),
                    }
                    assert_eq!(got, want, "{b} shape {shape:?} halo {halo}");
                }
            }
        }
    }

    #[test]
    fn reflect_folds_about_the_wall_face() {
        // depth-1 ghost mirrors the edge row, depth-2 the next row in...
        assert_eq!(reflect(1, 2, 5), 2);
        assert_eq!(reflect(0, 2, 5), 3);
        assert_eq!(reflect(6, 2, 5), 5);
        assert_eq!(reflect(7, 2, 5), 4);
        // ...and deep halos fold with period 2n (n=2: 2,3,3,2,2,3,...)
        assert_eq!(reflect(1, 2, 3), 2);
        assert_eq!(reflect(0, 2, 3), 3);
        assert_eq!(reflect(-1, 2, 3), 3);
        assert_eq!(reflect(-2, 2, 3), 2);
        // single-row core: everything maps to the row
        assert_eq!(reflect(0, 1, 1), 1);
        assert_eq!(reflect(2, 1, 1), 1);
    }

    /// The load-bearing property behind fused Tb-blocks: one deep-halo
    /// reflection fill + tb valid steps == tb (1-step fill + step)s.
    /// Edge replication (clamp) does NOT have this property — it leaks
    /// flux from depth >= 2 — which is why Neumann reflects.
    #[test]
    fn neumann_deep_halo_block_equals_per_step() {
        for bench in ["heat2d", "star1d5p", "box2d25p"] {
            let s = spec::get(bench).unwrap();
            let shape: Vec<usize> = vec![8; s.ndim];
            let core = Field::random(&shape, 0xFACE);
            let tb = 3;
            // fused: one fill at halo = r*tb, then tb valid steps
            let ext = Boundary::Neumann.pad(&core, s.radius * tb);
            let fused = reference::block(&ext, &s, tb);
            // per-step: refill a 1-step halo before every step
            let mut cur = core.clone();
            for _ in 0..tb {
                let e = Boundary::Neumann.pad(&cur, s.radius);
                cur = reference::step(&e, &s);
            }
            assert!(
                fused.allclose(&cur, 1e-13, 0.0),
                "{bench}: maxdiff={}",
                fused.max_abs_diff(&cur)
            );
            // and zero-flux really means zero flux: the mean is conserved
            assert!((fused.mean() - core.mean()).abs() < 1e-13, "{bench}");
        }
    }

    #[test]
    fn periodic_step_matches_roll_oracle() {
        // valid step on a periodically padded field == one periodic step.
        let s = spec::get("heat2d").unwrap();
        let core = Field::random(&[6, 6], 4);
        let ext = Boundary::Periodic.pad(&core, s.radius);
        let got = reference::step(&ext, &s);
        let want = reference::evolve_periodic(&core, &s, 1);
        assert!(got.allclose(&want, 1e-13, 0.0));
    }

    #[test]
    fn neumann_conserves_uniform_field() {
        let s = spec::get("box2d9p").unwrap();
        let core = Field::full(&[5, 5], 3.0);
        let ext = Boundary::Neumann.pad(&core, s.radius);
        let out = reference::step(&ext, &s);
        assert!((out.min() - 3.0).abs() < 1e-12 && (out.max() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn zero_halo_noop() {
        let core = Field::random(&[3, 3], 5);
        let mut ext = core.clone();
        Boundary::Periodic.fill(&mut ext, 0);
        assert_eq!(ext, core);
    }

    #[test]
    fn parse_roundtrip() {
        for (txt, want) in [
            ("neumann", Boundary::Neumann),
            ("periodic", Boundary::Periodic),
            ("dirichlet", Boundary::Dirichlet(0.0)),
            ("dirichlet:25.5", Boundary::Dirichlet(25.5)),
            ("dirichlet:-1e3", Boundary::Dirichlet(-1000.0)),
        ] {
            assert_eq!(txt.parse::<Boundary>().unwrap(), want);
        }
        assert_eq!("dirichlet:25.5", Boundary::Dirichlet(25.5).to_string());
        assert!("torus".parse::<Boundary>().is_err());
        assert!("dirichlet:hot".parse::<Boundary>().is_err());
    }

    /// `source_index` must agree with the fill maps cell-for-cell: a
    /// ghost filled from the ring equals the core cell it names (and
    /// Dirichlet ghosts name nothing).
    #[test]
    fn source_index_matches_fill_maps() {
        let core_len = 5usize;
        let halo = 3usize;
        let core = Field::random(&[core_len], 77);
        for b in [Boundary::Neumann, Boundary::Periodic] {
            let ext = b.pad(&core, halo);
            for x in 0..core_len + 2 * halo {
                let src = b.source_index(x, halo, core_len).unwrap();
                assert!((halo..halo + core_len).contains(&src), "{b} x={x} -> {src}");
                assert_eq!(ext.get(&[x]), ext.get(&[src]), "{b} x={x}");
            }
        }
        let b = Boundary::Dirichlet(2.5);
        for x in 0..core_len + 2 * halo {
            let want = ((halo..halo + core_len).contains(&x)).then_some(x);
            assert_eq!(b.source_index(x, halo, core_len), want);
        }
    }

    #[test]
    fn pad_value_matches_variant() {
        assert_eq!(Boundary::Dirichlet(7.5).pad_value(), 7.5);
        assert_eq!(Boundary::Neumann.pad_value(), 0.0);
        assert_eq!(Boundary::Periodic.pad_value(), 0.0);
    }
}
