//! Stencil specifications — rust mirror of `python/compile/kernels/spec.py`.
//!
//! The eight Table-1 benchmarks are regenerated here with the *same*
//! normalization arithmetic as the python side; a cross-language test in
//! `rust/tests/manifest.rs` diffs these coefficients against the AOT
//! manifest to guarantee both stacks compute the same dwarf.

use std::collections::BTreeMap;

/// Star (axis-aligned arms) or box (dense hypercube) footprint.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Star,
    Box,
}

/// One stencil dwarf: offsets -> FP64 coefficients.
#[derive(Clone, Debug)]
pub struct StencilSpec {
    pub name: &'static str,
    pub ndim: usize,
    pub kind: Kind,
    pub radius: usize,
    /// Sorted offset -> coefficient map (BTreeMap keeps python's sorted()
    /// iteration order: lexicographic on the offset tuple).
    pub coeffs: BTreeMap<Vec<i64>, f64>,
}

impl StencilSpec {
    pub fn points(&self) -> usize {
        self.coeffs.len()
    }

    /// One multiply + one add per tap.
    pub fn flops_per_cell(&self) -> usize {
        2 * self.points()
    }

    /// Ghost-ring width consumed by `steps` fused valid-mode steps.
    pub fn halo(&self, steps: usize) -> usize {
        self.radius * steps
    }

    /// (offsets, coeffs) in deterministic sorted order.
    pub fn taps(&self) -> (Vec<Vec<i64>>, Vec<f64>) {
        let offs: Vec<Vec<i64>> = self.coeffs.keys().cloned().collect();
        let cs: Vec<f64> = self.coeffs.values().copied().collect();
        (offs, cs)
    }
}

/// Star coefficients: `center` at origin, `arm / dist` per axis tap,
/// normalized to sum 1 — identical arithmetic to spec.py `_star`.
pub fn star(ndim: usize, radius: usize, center: f64, arm: f64) -> BTreeMap<Vec<i64>, f64> {
    let mut coeffs = BTreeMap::new();
    coeffs.insert(vec![0i64; ndim], center);
    for d in 0..ndim {
        for r in 1..=radius as i64 {
            for sign in [-1i64, 1] {
                let mut off = vec![0i64; ndim];
                off[d] = sign * r;
                coeffs.insert(off, arm / r as f64);
            }
        }
    }
    normalize(coeffs)
}

/// Box coefficients: separable triangular profile, normalized to 1 —
/// identical arithmetic to spec.py `_box`.
pub fn boxc(ndim: usize, radius: usize) -> BTreeMap<Vec<i64>, f64> {
    let r = radius as i64;
    let axis: Vec<i64> = (-r..=r).collect();
    let w1: Vec<f64> = axis.iter().map(|&o| (r + 1) as f64 - o.abs() as f64).collect();
    let mut coeffs = BTreeMap::new();
    fn rec(
        axis: &[i64],
        w1: &[f64],
        ndim: usize,
        prefix: &mut Vec<i64>,
        weight: f64,
        out: &mut BTreeMap<Vec<i64>, f64>,
    ) {
        if prefix.len() == ndim {
            out.insert(prefix.clone(), weight);
            return;
        }
        for (i, &o) in axis.iter().enumerate() {
            prefix.push(o);
            rec(axis, w1, ndim, prefix, weight * w1[i], out);
            prefix.pop();
        }
    }
    rec(&axis, &w1, ndim, &mut Vec::new(), 1.0, &mut coeffs);
    normalize(coeffs)
}

/// Paper Eq. 3 heat-equation coefficients with CFL number mu.
pub fn heat2d_coeffs(mu: f64) -> BTreeMap<Vec<i64>, f64> {
    let mut m = BTreeMap::new();
    m.insert(vec![0, 0], 1.0 - 4.0 * mu);
    m.insert(vec![-1, 0], mu);
    m.insert(vec![1, 0], mu);
    m.insert(vec![0, -1], mu);
    m.insert(vec![0, 1], mu);
    m
}

fn normalize(mut m: BTreeMap<Vec<i64>, f64>) -> BTreeMap<Vec<i64>, f64> {
    let total: f64 = m.values().sum();
    for v in m.values_mut() {
        *v /= total;
    }
    m
}

/// CFL number of the paper's thermal-diffusion case study (§6.5).
pub const THERMAL_MU: f64 = 0.23;

/// The eight Table-1 benchmarks, same parameters as spec.py.
pub fn benchmarks() -> Vec<StencilSpec> {
    vec![
        StencilSpec { name: "heat1d", ndim: 1, kind: Kind::Star, radius: 1, coeffs: star(1, 1, 0.5, 0.25) },
        StencilSpec { name: "star1d5p", ndim: 1, kind: Kind::Star, radius: 2, coeffs: star(1, 2, 0.4, 0.2) },
        StencilSpec { name: "heat2d", ndim: 2, kind: Kind::Star, radius: 1, coeffs: heat2d_coeffs(THERMAL_MU) },
        StencilSpec { name: "star2d9p", ndim: 2, kind: Kind::Star, radius: 2, coeffs: star(2, 2, 0.3, 0.1) },
        StencilSpec { name: "box2d9p", ndim: 2, kind: Kind::Box, radius: 1, coeffs: boxc(2, 1) },
        StencilSpec { name: "box2d25p", ndim: 2, kind: Kind::Box, radius: 2, coeffs: boxc(2, 2) },
        StencilSpec { name: "heat3d", ndim: 3, kind: Kind::Star, radius: 1, coeffs: star(3, 1, 0.4, 0.1) },
        StencilSpec { name: "box3d27p", ndim: 3, kind: Kind::Box, radius: 1, coeffs: boxc(3, 1) },
    ]
}

/// Look up a benchmark by name.
pub fn get(name: &str) -> Option<StencilSpec> {
    benchmarks().into_iter().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn points_match_table1() {
        let expected = [
            ("heat1d", 3),
            ("star1d5p", 5),
            ("heat2d", 5),
            ("star2d9p", 9),
            ("box2d9p", 9),
            ("box2d25p", 25),
            ("heat3d", 7),
            ("box3d27p", 27),
        ];
        for (name, pts) in expected {
            assert_eq!(get(name).unwrap().points(), pts, "{name}");
        }
    }

    #[test]
    fn coeffs_normalized() {
        for s in benchmarks() {
            let sum: f64 = s.coeffs.values().sum();
            assert!((sum - 1.0).abs() < 1e-12, "{} sum={sum}", s.name);
        }
    }

    #[test]
    fn offsets_within_radius_and_symmetric() {
        for s in benchmarks() {
            for off in s.coeffs.keys() {
                assert_eq!(off.len(), s.ndim);
                assert!(off.iter().all(|o| o.unsigned_abs() as usize <= s.radius));
                let neg: Vec<i64> = off.iter().map(|o| -o).collect();
                assert!(s.coeffs.contains_key(&neg), "{} {off:?}", s.name);
                if s.kind == Kind::Star {
                    assert!(off.iter().filter(|&&o| o != 0).count() <= 1);
                }
            }
        }
    }

    #[test]
    fn heat2d_matches_eq3() {
        let s = get("heat2d").unwrap();
        assert!((s.coeffs[&vec![0, 0]] - (1.0 - 4.0 * THERMAL_MU)).abs() < 1e-15);
        assert!((s.coeffs[&vec![1, 0]] - THERMAL_MU).abs() < 1e-15);
    }

    #[test]
    fn halo_scaling() {
        let s = get("star2d9p").unwrap();
        assert_eq!(s.halo(4), 8);
    }

    #[test]
    fn unknown_name() {
        assert!(get("nope").is_none());
    }
}
