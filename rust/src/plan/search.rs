//! The Pattern Mapper's search: cost-model-pruned timed trials.
//!
//! The configuration space is the cross product of every engine (both
//! registries), thread count, fused block depth Tb and tile-width
//! override.  Timing all of it would cost seconds per key, so the
//! search runs in two passes:
//!
//! 1. **analytic pass** — [`CostModel`] scores every candidate in
//!    microseconds and keeps a shortlist;
//! 2. **timed pass** — each shortlisted candidate runs a real
//!    valid-mode block loop on a *shrunken proxy grid* (same ndim, same
//!    physics, ≤ `max_proxy_cells` cells), within `budget_ms`; measured
//!    GStencils/s picks the winner.
//!
//! Reproducibility: candidate enumeration is deterministic, analytic
//! scores are pure arithmetic, and every ordering/tie decision breaks
//! ties by a seeded FNV hash of the candidate — so a fixed seed plus a
//! deterministic trial function (the unit tests inject one) emits
//! byte-identical plans.  `tetris tune --seed` exposes the knob.

use std::cmp::Ordering;
use std::time::{Duration, Instant};

use crate::util::error::{Context, Result};
use crate::util::prng::fnv1a;

use crate::engine::tessellate::{Inner, TessellateEngine};
use crate::engine::Engine;
use crate::stencil::{spec, Field, StencilSpec};

use super::cost::CostModel;
use super::fingerprint::Fingerprint;
use super::{shape_bucket, Plan, PLAN_VERSION};

/// Steps every timed trial advances (all candidate Tbs divide it, so
/// throughputs compare like-for-like).
pub const TRIAL_STEPS: usize = 8;

/// One point of the configuration space.
#[derive(Clone, Debug, PartialEq)]
pub struct Candidate {
    pub engine: String,
    pub threads: usize,
    pub tb: usize,
    /// Tile-width override (tessellation family only).
    pub tile_w: Option<usize>,
}

impl Candidate {
    /// Instantiate the engine this candidate names.
    pub fn build(&self) -> Option<Box<dyn Engine>> {
        if let Some(w) = self.tile_w {
            if self.engine == "tetris-cpu" || self.engine == "tessellate" {
                return Some(Box::new(TessellateEngine {
                    inner: if self.engine == "tetris-cpu" { Inner::Fused } else { Inner::Axpy },
                    threads: self.threads.max(1),
                    tile_w: Some(w),
                }));
            }
        }
        super::resolve_engine(&self.engine, self.threads)
    }
}

/// Search policy — every knob has a `tetris tune` flag.
#[derive(Clone, Debug)]
pub struct SearchConfig {
    /// Timed-trial budget; the first trial always runs.
    pub budget_ms: u64,
    /// Trial ordering / tie-break seed (`tetris tune --seed`).
    pub seed: u64,
    /// Cost-model survivors admitted to the timed pass.
    pub shortlist: usize,
    /// Proxy-grid cell cap for the timed pass.
    pub max_proxy_cells: usize,
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig { budget_ms: 2_000, seed: 0xA11CE, shortlist: 6, max_proxy_cells: 4096 }
    }
}

/// Deterministic candidate enumeration for a machine with `cores`
/// logical cores: every engine name from both registries, thread counts
/// {1, cores/2, cores} for the scaling engines, Tb ∈ {1,2,4,8} capped
/// by the steps hint, plus a tile-width override point for the
/// tessellation flagship.
pub fn candidates(cores: usize, steps_hint: usize) -> Vec<Candidate> {
    let mut topts = vec![1usize, cores / 2, cores];
    topts.retain(|&t| t >= 1);
    topts.sort_unstable();
    topts.dedup();
    let tbs: Vec<usize> =
        [1usize, 2, 4, 8].into_iter().filter(|&tb| tb == 1 || tb <= steps_hint.max(1)).collect();
    let mut out = Vec::new();
    for name in crate::engine::ENGINE_NAMES {
        let (_, scales) = super::cost::engine_prior(name);
        for &t in &topts {
            if !scales && t != 1 {
                continue;
            }
            for &tb in &tbs {
                out.push(Candidate { engine: name.to_string(), threads: t, tb, tile_w: None });
            }
            if *name == "tetris-cpu" {
                let tb = *tbs.last().unwrap();
                out.push(Candidate { engine: name.to_string(), threads: t, tb, tile_w: Some(64) });
            }
        }
    }
    for name in crate::baselines::BASELINE_NAMES {
        for &tb in &tbs {
            out.push(Candidate { engine: name.to_string(), threads: 1, tb, tile_w: None });
        }
    }
    out
}

/// Shrink a shape to at most `max_cells` cells, preserving ndim and
/// aspect (dims floor at 8 so halos and tiles stay meaningful).
pub fn proxy_shape(shape: &[usize], max_cells: usize) -> Vec<usize> {
    let cells: usize = shape.iter().product();
    if cells <= max_cells.max(1) {
        return shape.to_vec();
    }
    let f = (max_cells as f64 / cells as f64).powf(1.0 / shape.len() as f64);
    shape.iter().map(|&n| ((n as f64 * f) as usize).max(8)).collect()
}

/// Seeded candidate hash — the single source of every tie-break.
fn tiebreak(seed: u64, c: &Candidate) -> u64 {
    fnv1a(&format!("{seed}|{}|{}|{}|{:?}", c.engine, c.threads, c.tb, c.tile_w))
}

/// Run the search with real timed trials and emit the winning [`Plan`],
/// including the §5.3 overlap preference from a quick scheduler probe
/// (two homogeneous workers of the winning engine on the proxy grid,
/// pipelined vs serial leader loop — bit-exact either way, so the probe
/// only decides wall-clock).
pub fn search(
    bench: &str,
    boundary_kind: &str,
    shape: &[usize],
    steps_hint: usize,
    fp: &Fingerprint,
    cfg: &SearchConfig,
) -> Result<Plan> {
    let mut plan = search_with(bench, boundary_kind, shape, steps_hint, fp, cfg, &mut timed_trial)?;
    let proxy = proxy_shape(shape, cfg.max_proxy_cells.max(64));
    plan.overlap = probe_overlap(bench, &plan, &proxy);
    Ok(plan)
}

/// Search core with an injectable trial runner (`candidate, spec,
/// proxy shape, steps` → seconds).  The unit tests inject deterministic
/// runners to prove seeded reproducibility; production uses
/// [`timed_trial`].
pub fn search_with(
    bench: &str,
    boundary_kind: &str,
    shape: &[usize],
    steps_hint: usize,
    fp: &Fingerprint,
    cfg: &SearchConfig,
    trial: &mut dyn FnMut(&Candidate, &StencilSpec, &[usize], usize) -> Result<f64>,
) -> Result<Plan> {
    let s = spec::get(bench).with_context(|| format!("unknown bench {bench:?}"))?;
    crate::ensure!(
        shape.len() == s.ndim && shape.iter().all(|&n| n >= 1),
        "bench {bench} wants {} dims >= 1, got {shape:?}",
        s.ndim
    );
    let model = CostModel::from_fingerprint(fp);
    let mut scored: Vec<(f64, u64, Candidate)> = candidates(fp.cores, steps_hint)
        .into_iter()
        .map(|c| (model.estimate_secs(&s, shape, steps_hint.max(1), &c), tiebreak(cfg.seed, &c), c))
        .collect();
    scored.sort_by(|a, b| {
        a.0.partial_cmp(&b.0).unwrap_or(Ordering::Equal).then_with(|| a.1.cmp(&b.1))
    });
    let proxy = proxy_shape(shape, cfg.max_proxy_cells.max(64));
    let cells: usize = proxy.iter().product();
    let deadline = Instant::now() + Duration::from_millis(cfg.budget_ms.max(1));
    let mut best: Option<(f64, u64, Candidate)> = None;
    let mut tried = 0usize;
    for (_, tie, cand) in scored.into_iter().take(cfg.shortlist.max(1)) {
        // The first trial always runs so a zero budget still yields a
        // calibrated guess; after that the budget has the final word.
        if tried > 0 && Instant::now() >= deadline {
            break;
        }
        let span = if crate::trace::enabled() {
            // engine names are dynamic — only marshal when recording
            crate::trace::span(
                "plan",
                "trial",
                &[
                    ("engine", cand.engine.as_str().into()),
                    ("threads", cand.threads.into()),
                    ("tb", cand.tb.into()),
                ],
            )
        } else {
            crate::trace::Span::off()
        };
        let outcome = trial(&cand, &s, &proxy, TRIAL_STEPS);
        drop(span);
        match outcome {
            Ok(secs) => {
                tried += 1;
                let gsps = (cells * TRIAL_STEPS) as f64 / secs.max(1e-9) / 1e9;
                let wins = match &best {
                    None => true,
                    Some((bg, bt, _)) => gsps > *bg || (gsps == *bg && tie < *bt),
                };
                if wins {
                    best = Some((gsps, tie, cand));
                }
            }
            Err(e) => eprintln!(
                "tetris plan: trial failed for {} t{} Tb{}: {e}; skipping",
                cand.engine, cand.threads, cand.tb
            ),
        }
    }
    let (gsps, _, c) = best.with_context(|| format!("no plan trial succeeded for {bench}"))?;
    // Worker-grid prior for scheduler-mode consumers: the Wy×Wx shape a
    // one-worker-per-core fleet would tile this domain with (pure
    // arithmetic — deterministic under the seed like everything else).
    let grid = model.choose_grid(fp.cores, shape, s.radius * c.tb.max(1));
    Ok(Plan {
        version: PLAN_VERSION,
        fingerprint: fp.id(),
        bench: bench.to_string(),
        boundary: boundary_kind.to_string(),
        bucket: shape_bucket(shape),
        engine: c.engine,
        threads: c.threads,
        tb: c.tb,
        tile_w: c.tile_w,
        overlap: None,
        grid,
        gsps,
        source: "tuned".to_string(),
        seed: cfg.seed,
    })
}

/// Time the §5.3 pipelined vs serial leader loop for `plan`'s winning
/// configuration on a 2-worker scheduler over the proxy grid and return
/// the faster mode (`None` when the probe cannot run — e.g. the engine
/// fails to build — leaving the scheduler's `auto` heuristic in charge).
fn probe_overlap(bench: &str, plan: &Plan, proxy: &[usize]) -> Option<bool> {
    use crate::coordinator::{NativeWorker, Overlap, Scheduler, Worker};
    let s = spec::get(bench)?;
    let tb = plan.tb.max(1);
    // At least 2 blocks: a 1-block "pipeline" has no next block to
    // prefetch, so timing it would systematically (and wrongly) favour
    // the serial loop for large-Tb plans.
    let steps = TRIAL_STEPS.div_ceil(tb).max(2) * tb;
    let core = Field::random(proxy, 0x0E21A9);
    let mut elapsed = [0f64; 2];
    for (i, mode) in [Overlap::Off, Overlap::On].into_iter().enumerate() {
        let mk = || -> Option<Box<dyn Worker>> {
            let c = Candidate { threads: 1, ..plan.candidate() };
            Some(Box::new(NativeWorker::new(c.build()?, 1 << 33)))
        };
        let workers: Vec<Box<dyn Worker>> = vec![mk()?, mk()?];
        let mut sched = Scheduler::from_plan(
            s.clone(),
            tb,
            workers,
            proxy[0],
            crate::stencil::Boundary::Dirichlet(0.0),
            0,
        );
        sched.overlap = mode;
        let t0 = Instant::now();
        sched.run(&core, steps).ok()?;
        elapsed[i] = t0.elapsed().as_secs_f64();
    }
    Some(elapsed[1] < elapsed[0])
}

/// Real proxy trial: one valid-mode block loop (extract/pad per block,
/// Dirichlet ring — the trial measures compute, the boundary family
/// only shifts a constant the comparison cancels).
pub fn timed_trial(
    c: &Candidate,
    s: &StencilSpec,
    proxy: &[usize],
    total_steps: usize,
) -> Result<f64> {
    let eng = c.build().with_context(|| format!("unknown engine {:?}", c.engine))?;
    let tb = c.tb.max(1);
    let halo = s.radius * tb;
    let ext: Vec<usize> = proxy.iter().map(|n| n + 2 * halo).collect();
    let input = Field::random(&ext, 0xCA11B);
    let blocks = (total_steps / tb).max(1);
    let t0 = Instant::now();
    let mut cur = input;
    for _ in 0..blocks {
        let out = eng.block(s, &cur, tb);
        cur = out.pad(halo, 0.0);
    }
    std::hint::black_box(&cur);
    Ok(t0.elapsed().as_secs_f64().max(1e-9))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidate_space_is_deterministic_and_covers_both_registries() {
        let a = candidates(8, 16);
        let b = candidates(8, 16);
        assert_eq!(a, b);
        assert!(a.iter().any(|c| c.engine == "tetris-cpu" && c.threads == 8));
        assert!(a.iter().any(|c| c.engine == "an5d"), "baselines must be searched too");
        assert!(a.iter().any(|c| c.tile_w.is_some()), "tile override point present");
        // thread-blind engines never fan out over threads
        assert!(a.iter().filter(|c| c.engine == "simd").all(|c| c.threads == 1));
        // a steps hint of 2 caps Tb
        assert!(candidates(4, 2).iter().all(|c| c.tb <= 2));
    }

    #[test]
    fn proxy_shrinks_preserving_ndim() {
        assert_eq!(proxy_shape(&[32], 4096), vec![32], "small shapes pass through");
        let p = proxy_shape(&[512, 512], 4096);
        assert_eq!(p.len(), 2);
        assert!(p.iter().product::<usize>() <= 4096 + 512, "{p:?}");
        let p3 = proxy_shape(&[640, 640, 640], 4096);
        assert!(p3.iter().all(|&n| n >= 8), "{p3:?}");
    }

    fn fake_trial(c: &Candidate, _s: &StencilSpec, _p: &[usize], _steps: usize) -> Result<f64> {
        // deterministic pseudo-times keyed on the candidate alone
        Ok(1e-3 + (fnv1a(&format!("{}|{}|{}|{:?}", c.engine, c.threads, c.tb, c.tile_w)) % 997) as f64 * 1e-6)
    }

    /// Determinism guard (satellite): two seeded searches over the same
    /// inputs emit byte-identical plans.
    #[test]
    fn seeded_search_emits_byte_identical_plans() {
        let fp = Fingerprint::synthetic(8, 64, 1.0);
        let cfg = SearchConfig { seed: 42, ..Default::default() };
        let mut t1 = fake_trial;
        let mut t2 = fake_trial;
        let a = search_with("heat2d", "periodic", &[100, 100], 16, &fp, &cfg, &mut t1).unwrap();
        let b = search_with("heat2d", "periodic", &[100, 100], 16, &fp, &cfg, &mut t2).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert_eq!(a, b);
        assert_eq!(a.seed, 42);
        assert_eq!(a.bucket, vec![128, 128]);
    }

    /// With all trials timing identical, the winner is still a pure
    /// function of the seed — and a different seed may legitimately pick
    /// a different (equally fast) winner.
    #[test]
    fn ties_break_by_seed_deterministically() {
        let fp = Fingerprint::synthetic(4, 64, 1.0);
        let mut flat =
            |_c: &Candidate, _s: &StencilSpec, _p: &[usize], _st: usize| -> Result<f64> {
                Ok(1e-3)
            };
        let cfg7 = SearchConfig { seed: 7, ..Default::default() };
        let a = search_with("heat1d", "dirichlet", &[256], 16, &fp, &cfg7, &mut flat).unwrap();
        let b = search_with("heat1d", "dirichlet", &[256], 16, &fp, &cfg7, &mut flat).unwrap();
        assert_eq!(a, b, "same seed, same flat times, same plan");
    }

    #[test]
    fn failed_trials_are_skipped_not_fatal() {
        let fp = Fingerprint::synthetic(2, 64, 1.0);
        let cfg = SearchConfig { shortlist: 4, ..Default::default() };
        let mut n = 0usize;
        let mut flaky = |c: &Candidate, s: &StencilSpec, p: &[usize], st: usize| {
            n += 1;
            if n == 1 {
                crate::bail!("device lost");
            }
            fake_trial(c, s, p, st)
        };
        let p = search_with("heat1d", "neumann", &[64], 8, &fp, &cfg, &mut flaky).unwrap();
        assert!(p.candidate().build().is_some());
        let mut dead =
            |_c: &Candidate, _s: &StencilSpec, _p: &[usize], _st: usize| -> Result<f64> {
                crate::bail!("no backend")
            };
        assert!(search_with("heat1d", "neumann", &[64], 8, &fp, &cfg, &mut dead).is_err());
    }

    /// Smoke the real timed path end-to-end on a tiny problem: the plan
    /// must name a resolvable engine and record positive throughput.
    #[test]
    fn real_search_smoke() {
        let fp = Fingerprint::synthetic(2, 64, 0.5);
        let cfg = SearchConfig { budget_ms: 150, shortlist: 3, max_proxy_cells: 1024, seed: 1 };
        let p = search("heat1d", "dirichlet", &[128], 8, &fp, &cfg).unwrap();
        assert!(p.gsps > 0.0);
        assert!(p.candidate().build().is_some(), "{p:?}");
        assert_eq!(p.bench, "heat1d");
        assert_eq!(p.source, "tuned");
        assert!(p.overlap.is_some(), "the real search must probe the overlap knob: {p:?}");
    }

    #[test]
    fn search_rejects_bad_inputs() {
        let fp = Fingerprint::synthetic(2, 64, 0.5);
        let cfg = SearchConfig::default();
        assert!(search("nope", "dirichlet", &[64], 8, &fp, &cfg).is_err());
        assert!(search("heat2d", "dirichlet", &[64], 8, &fp, &cfg).is_err(), "1-d shape, 2-d bench");
    }
}
